"""End-to-end driver: train a ~100M-param llama-style LM for a few hundred
steps on synthetic tokens, with checkpointing, fault injection + restart,
and straggler detection — the full production runtime at laptop scale.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.lm_harness import make_train_step
from repro.data.synthetic import lm_batch
from repro.models import transformer as tf
from repro.optim import adamw_init
from repro.runtime.fault import FaultPolicy, InjectedFault, StepResult, Supervisor
from repro.runtime.straggler import StragglerDetector, StepTimer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--inject-fault-at", type=int, default=150)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=32000)
    args = ap.parse_args()

    # defaults: ~100M params (12L × d=512 × ff=2048, vocab 32k); shrink with
    # --layers/--d-model/--vocab for quick CPU validation runs
    cfg = tf.TransformerConfig(
        name="lm-100m", num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 1), num_kv_heads=max(args.d_model // 128, 1),
        head_dim=64, d_ff=4 * args.d_model, vocab_size=args.vocab,
        attention="gqa", dtype=jnp.float32, attn_block_q=64, attn_block_k=64,
    )
    print(f"params: {cfg.num_params() / 1e6:.1f}M")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    detector = StragglerDetector(threshold=3.0)
    fired = {"done": False}

    def injector(step):
        if step == args.inject_fault_at and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("simulated node failure")

    sup = Supervisor(ckpt, FaultPolicy(checkpoint_every=50), fault_injector=injector)
    losses = []

    def one_step(state, step):
        p, o = state
        tok, lab = lm_batch(step, batch=args.batch, seq_len=args.seq, vocab=cfg.vocab_size)
        with StepTimer(detector) as t:
            p, o, m = step_fn(p, o, jnp.asarray(tok), jnp.asarray(lab))
            jax.block_until_ready(m["loss"])
        t.finish(step)
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}")
        return StepResult(state=(p, o), metrics=m)

    t0 = time.time()
    (params, opt), last = sup.run((params, opt), one_step, num_steps=args.steps)
    print(f"\n{last} steps in {time.time() - t0:.0f}s; restarts={sup.restarts}")
    print(f"events: {sup.history}")
    print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f} "
          f"(decreased: {losses[-1] < losses[0]})")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
