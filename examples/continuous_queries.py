"""Continuous-query workbench: every query class and every DC configuration
from the paper on one dynamic graph (SPSP / K-hop / RPQ / WCC / PageRank ×
VDC / JOD / Det-Drop / Prob-Drop), with live memory accounting.

    PYTHONPATH=src python examples/continuous_queries.py
"""

import numpy as np

from repro.core import dropping as dr
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.data.graphgen import ldbc_like_graph, split_90_10, update_stream

V = 160
labelled = ldbc_like_graph(V, 640, seed=2, num_labels=3)
initial, pool = split_90_10(labelled, seed=2)
stream = update_stream(initial, V, num_batches=15, insert_pool=pool, seed=3)

plain = [(u, v, w) for (u, v, w, _l) in initial]
plain_stream = [[(u, v, 0, w, s) for (u, v, _l, w, s) in b] for b in stream]
sym = plain + [(v, u, w) for (u, v, w) in plain]
sym_stream = [b + [(y, x, l, w, s) for (x, y, l, w, s) in b] for b in plain_stream]

drop = dr.DropConfig(mode="prob", selection="degree", p=0.4, tau_min=2,
                     tau_max=20, bloom_bits=1 << 12)

systems = {
    "spsp/vdc": q.sssp(DynamicGraph(V, plain, capacity=4096), [0, 1], mode="vdc"),
    "spsp/jod": q.sssp(DynamicGraph(V, plain, capacity=4096), [0, 1], mode="jod"),
    "spsp/probdrop": q.sssp(DynamicGraph(V, plain, capacity=4096), [0, 1], drop=drop),
    "khop/jod": q.khop(DynamicGraph(V, plain, capacity=4096), [0, 1], k=5),
    "wcc/jod": q.wcc(DynamicGraph(V, sym, capacity=8192)),
    "pagerank/jod": q.pagerank(DynamicGraph(V, plain, capacity=4096), iters=10),
    "rpq_a*/jod": q.RPQ(DynamicGraph(V, labelled, capacity=4096), q.NFA.star(1), [0, 1]),
}

for i, batch in enumerate(stream):
    for name, sys in systems.items():
        if name.startswith("rpq"):
            sys.apply_updates(batch)
        elif name.startswith("wcc"):
            sys.apply_updates(sym_stream[i])
        else:
            sys.apply_updates(plain_stream[i])

print(f"{'system':<16} {'diff bytes':>10}")
for name, sys in systems.items():
    print(f"{name:<16} {sys.nbytes():>10}")

reach = systems["rpq_a*/jod"].reachable()
print(f"\nRPQ a*: source 0 reaches {int(reach[0].sum())}/{V} vertices via label-1 paths")
d = systems["spsp/probdrop"].answers()
print(f"SPSP (prob-drop): {int(np.isfinite(d[0]).sum())}/{V} vertices reachable from 0")
