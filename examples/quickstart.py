"""Quickstart: one multi-operator plan on a dynamic graph.

Builds an RPQ plan graph — ``Ingest → Join(nfa) → Iterate → Aggregate`` —
registers it in a :class:`~repro.core.session.CQPSession`, streams δE
batches, then drops the *Join operator's* differences alone (the paper's
§4 operator-dropping scenario: recompute-on-demand) and watches the bytes
fall while every answer stays exactly equal to from-scratch re-execution.

    PYTHONPATH=src python examples/quickstart.py

With several devices visible (e.g. ``XLA_FLAGS=--xla_force_host_platform_
device_count=8``) the session shards the maintenance sweep over the mesh
``data`` axis automatically.  For the throughput-oriented batched pipeline
see ``examples/batched_cqp.py`` and ``python -m repro.launch.cqp_serve``.
"""

import jax
import numpy as np

from repro.core import CQPSession, dropping as dr, plan
from repro.core.graph import DynamicGraph
from repro.data.graphgen import ldbc_like_graph, split_90_10, update_stream
from repro.launch.mesh import make_data_mesh

V = 64
edges = ldbc_like_graph(V, 256, seed=0, num_labels=2)
initial, pool = split_90_10(edges)
stream = update_stream(initial, V, num_batches=10, insert_pool=pool,
                       delete_fraction=0.2, seed=1)

# Q2-style RPQ (label-1 then label-2*), top-8 nearest matches riding along.
# join_store="materialize" keeps the Join operator's per-edge message trace
# (VDC on the product graph) — the memory ceiling we will reclaim below.
nfa = plan.NFA.concat_star(1, 2)
plans = [
    plan.rpq(s, nfa, max_iters=24, join_store="materialize").with_aggregate(
        "topk", k=8
    )
    for s in (0, 5)
]
print("operator graph:", " -> ".join(plans[0].op_ids()))

mesh = make_data_mesh() if jax.device_count() > 1 else None
sess = CQPSession(DynamicGraph(V, initial, capacity=2048), engine="dense",
                  mesh=mesh)
scratch = CQPSession(DynamicGraph(V, initial, capacity=2048), engine="scratch")
handles = sess.register_many(plans)
oracle = scratch.register_many(plans)

for i, batch in enumerate(stream):
    sess.apply_updates(batch)
    scratch.apply_updates(batch)
    for h, o in zip(handles, oracle):
        assert np.array_equal(sess.reachable(h), scratch.reachable(o)), "mismatch!"
    if i % 3 == 0:
        per_op = sess.nbytes_per_operator()[0]
        print(f"batch {i:2d}: per-operator bytes {per_op} "
              f"(total {sess.nbytes()} over {sess.num_shards} shard(s))")

# drop ONE operator's differences: the Join trace goes, the Iterate stays,
# and §4 recompute-on-demand keeps answers exact
freed = sess.set_drop_policy(
    handles[0], dr.DropConfig(mode="det", p=1.0), op="join"
)
print(f"\ndropped query 0's Join differences: freed {freed} B "
      f"-> per-operator bytes {sess.nbytes_per_operator()[0]}")
for h, o in zip(handles, oracle):
    assert np.array_equal(sess.reachable(h), scratch.reachable(o))

top = sess.aggregate(handles[0])
print(f"top-{len(top['vertices'])} matches of query 0: "
      f"{list(zip(top['vertices'], top['values']))}")
print("all answers verified identical to from-scratch re-execution")
