"""Quickstart: register continuous SPSP queries on a dynamic graph and watch
differential maintenance beat from-scratch re-execution.

    PYTHONPATH=src python examples/quickstart.py

For the throughput-oriented batched pipeline (B updates per dispatch, ELL
kernel backend) see ``examples/batched_cqp.py`` and the serving driver
``python -m repro.launch.cqp_serve --smoke``.
"""

import numpy as np

from repro.core import dropping as dr
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.scratch import scratch_like
from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream

V = 200
edges = powerlaw_graph(V, 800, seed=0)
initial, pool = split_90_10(edges)
stream = update_stream(initial, V, num_batches=20, insert_pool=pool,
                       delete_fraction=0.2, seed=1)

# 8 continuous single-pair-shortest-path queries, maintained with
# Join-On-Demand + probabilistic degree-based dropping (the paper's best).
sources = list(range(8))
engine = q.sssp(
    DynamicGraph(V, initial, capacity=4096),
    sources,
    max_iters=48,
    mode="jod",
    drop=dr.DropConfig(mode="prob", selection="degree", p=0.5,
                       tau_min=2, tau_max=24, bloom_bits=1 << 13),
)
scratch = scratch_like(engine.cfg, DynamicGraph(V, initial, capacity=4096),
                       engine.state.init)

for i, batch in enumerate(stream):
    stats = engine.apply_updates(batch)
    scratch.apply_updates(batch)
    assert np.array_equal(engine.answers(), scratch.answers()), "mismatch!"
    if i % 5 == 0:
        print(f"batch {i:2d}: scheduled={int(stats.scheduled):5d} vertex-reruns "
              f"(scratch would do {int(scratch.last_stats.scheduled):7d}); "
              f"diff bytes={engine.nbytes()}")

print("\nall answers verified identical to from-scratch re-execution")
print(f"final memory: {engine.nbytes()} B of differences for {len(sources)} queries")
