"""Batched continuous-query pipeline: fold B edge updates per dispatch.

The per-update path (`quickstart.py`) re-enters the jitted sweep once per
batch from the host.  The throughput path chunks the δE log and folds each
chunk through ONE donated-buffer jitted step (edge scatter + dirty mask +
maintenance sweep compiled together) — same answers, a fraction of the
dispatches.  `backend="ell"` additionally swaps the aggregator for the
Pallas bucketed-ELL SpMV kernel (interpret-mode on CPU, Mosaic on TPU).

    PYTHONPATH=src python examples/batched_cqp.py
"""

import time

import numpy as np

from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream

V, B = 200, 16
edges = powerlaw_graph(V, 800, seed=0)
initial, pool = split_90_10(edges)
stream = update_stream(initial, V, num_batches=64, batch_size=1,
                       insert_pool=pool, delete_fraction=0.2, seed=1)
log = [u for batch in stream for u in batch]
sources = list(range(8))

# per-update baseline: one host round trip + sweep per update
seq = q.sssp(DynamicGraph(V, initial, capacity=4096), sources, max_iters=48)
t0 = time.perf_counter()
for u in log:
    seq.apply_updates([u])
t_seq = time.perf_counter() - t0

# batched pipeline: one donated-buffer dispatch per B updates
bat = q.sssp(DynamicGraph(V, initial, capacity=4096), sources,
             max_iters=48, batch_capacity=B)
bat.apply_updates_batched(log[:B])          # warmup chunk compiles the step
t0 = time.perf_counter()
stats = bat.apply_updates_batched(log[B:])
t_bat = time.perf_counter() - t0

assert np.array_equal(seq.answers(), bat.answers()), "batched must match!"
print(f"{len(log)} updates, {len(sources)} concurrent SSSP queries")
print(f"  per-update path : {len(log) / t_seq:8.1f} updates/sec")
print(f"  batched (B={B:2d}) : {len(log[B:]) / t_bat:8.1f} updates/sec "
      f"({(t_seq / len(log)) / (t_bat / len(log[B:])):.1f}x)")
print(f"  sweeps run: {int(stats.iters_run)} iterations for {len(log[B:])} updates; "
      f"diff bytes={bat.nbytes()}")

# the same log through the Pallas ELL-SpMV backend (interpret-mode on CPU)
ell = q.sssp(DynamicGraph(V, initial, capacity=4096), sources,
             max_iters=48, backend="ell", batch_capacity=B)
ell.apply_updates_batched(log)
assert np.array_equal(seq.answers(), ell.answers()), "ELL must match!"
print("ELL backend verified identical on the full log")
