"""Diff-IFE as a GNN-sampler index: maintain K-hop frontiers of minibatch
seeds incrementally while the graph changes under training.

``minibatch_lg`` needs fanout sampling over a *dynamic* graph.  The paper's
K-hop engine maintains, per seed, the set of vertices within K hops; the
sampler then only draws from fresh frontiers — no full re-walk after each
edge update.

    PYTHONPATH=src python examples/incremental_gnn_sampling.py
"""

import numpy as np

from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream
from repro.data.sampler import CSRGraph, sample_subgraph

V = 300
edges = powerlaw_graph(V, 1500, seed=4, weighted=False)
initial, pool = split_90_10(edges, seed=4)
stream = update_stream(initial, V, num_batches=10, insert_pool=pool, seed=5)

seeds = np.asarray([3, 17, 56, 81])
khop = q.khop(DynamicGraph(V, initial, capacity=8192), [int(s) for s in seeds], k=2)

present = list(initial)
for i, batch in enumerate(stream):
    stats = khop.apply_updates(batch)
    reachable = q.khop_reachable(khop)  # [num_seeds, V] — maintained, not recomputed
    for (u, v, l, w, s) in batch:
        if s > 0:
            present.append((u, v, 1.0))
        else:
            present = [(a, b, w_) for (a, b, w_) in present if (a, b) != (u, v)]
    # draw a fanout sample restricted to fresh 2-hop frontiers
    src = np.asarray([e[0] for e in present], np.int32)
    dst = np.asarray([e[1] for e in present], np.int32)
    csr = CSRGraph.from_edges(src, dst, V)
    sub = sample_subgraph(csr, seeds, (5, 3), max_nodes=128, max_edges=256,
                          rng=np.random.default_rng(i))
    sampled_nodes = sub.node_ids[sub.node_mask]
    in_frontier = reachable[:, sampled_nodes].any(axis=0)
    print(f"batch {i}: maintained reruns={int(stats.scheduled):4d}; "
          f"sample={len(sampled_nodes):3d} nodes, "
          f"{int(in_frontier.sum())} inside maintained 2-hop frontiers")

# every sampled non-seed node must lie inside some seed's maintained frontier
assert in_frontier.all(), "sampler escaped the maintained frontier"
print("\nincremental frontier index is consistent with the sampler")
