"""Substrate tests: checkpoint atomicity/roundtrip, fault recovery, elastic
resharding, straggler detection, mesh rules, optimizer, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step
from repro.optim import adamw_init, adamw_update
from repro.runtime.fault import FaultPolicy, InjectedFault, StepResult, Supervisor
from repro.runtime.straggler import StragglerDetector


def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 7, tree)
    got, step = restore_checkpoint(d, jax.tree.map(jnp.zeros_like, tree))
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    save_checkpoint(d, 2, _tree())
    entries = os.listdir(d)
    assert not any(e.endswith(".tmp") for e in entries)
    assert latest_step(d) == 2


def test_checkpoint_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    for s in (10, 20, 30, 40):
        mgr.save(s, _tree())
    mgr.wait()
    steps = sorted(os.listdir(str(tmp_path)))
    assert steps == ["step_00000030", "step_00000040"]


def test_fault_supervisor_restores_and_replays(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    fired = {"done": False}

    def injector(step):
        if step == 7 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("boom")

    sup = Supervisor(mgr, FaultPolicy(checkpoint_every=5), fault_injector=injector)
    executed = []

    def step_fn(state, step):
        executed.append(step)
        return StepResult(state={"x": state["x"] + 1}, metrics={})

    state, last = sup.run({"x": jnp.zeros(())}, step_fn, num_steps=10)
    assert last == 10
    assert sup.restarts == 1
    # steps 5 and 6 replayed after restoring the step-5 checkpoint
    assert executed.count(5) == 2 and executed.count(6) == 2
    assert float(state["x"]) == 10.0  # deterministic replay → correct count
    assert any(e.startswith("fault@7") for e in sup.history)


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(threshold=2.0, warmup=2)
    flagged = [det.observe(i, 0.1) for i in range(5)]
    assert not any(flagged)
    assert det.observe(5, 0.5) is True
    assert det.events and det.events[0].step == 5
    # EWMA not poisoned by the straggler
    assert det.ewma < 0.2


def test_elastic_reshard_roundtrip():
    from repro.runtime import elastic

    mesh8 = elastic.build_mesh(jax.devices()[:1], data=1, model=1)
    tree = {"emb": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    specs = {"emb": ("vocab", "embed")}
    out = elastic.reshard(tree, specs, mesh8)
    np.testing.assert_array_equal(out["emb"], tree["emb"])
    assert elastic.split_global_batch(256, mesh8) == 256


def test_mesh_rules_resolution():
    from repro.runtime import mesh_rules

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = mesh_rules.logical_to_spec(("layers", "embed", "heads"), mesh)
    assert spec == jax.sharding.PartitionSpec(None, "data", "model")
    # duplicate mesh axis collapses to None
    spec = mesh_rules.logical_to_spec(("embed", "embed"), mesh)
    assert spec == jax.sharding.PartitionSpec("data", None)
    # pod axis resolves only on the multipod mesh
    spec = mesh_rules.logical_to_spec(("batch",), mesh)
    assert spec == jax.sharding.PartitionSpec("data")


def test_adamw_decreases_quadratic():
    params = {"w": jnp.full((4,), 5.0)}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, lr=5e-2, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_gradient_compression_error_feedback():
    from repro.optim.compression import (
        compress_grads,
        decompress_grads,
        init_error_feedback,
    )

    rng = jax.random.PRNGKey(0)
    g = {"w": jnp.linspace(-1, 1, 1024)}
    err = init_error_feedback(g)
    # accumulated dequantized grads ≈ accumulated true grads (EF property)
    acc_q = jnp.zeros(1024)
    acc_t = jnp.zeros(1024)
    for i in range(20):
        rng, sub = jax.random.split(rng)
        q, s, err = compress_grads(g, err, sub)
        acc_q = acc_q + decompress_grads(q, s)["w"]
        acc_t = acc_t + g["w"]
    rel = float(jnp.abs(acc_q - acc_t).max() / jnp.abs(acc_t).max())
    assert rel < 0.05, rel


def test_landmark_index_and_pruned_scratch():
    from repro.core.graph import DynamicGraph
    from repro.core.landmark import ScratchLandmark
    from repro.core.queries import sssp
    from repro.data.graphgen import powerlaw_graph

    v = 64
    edges = powerlaw_graph(v, 256, seed=6)
    queries = [(0, 9), (3, 40), (11, 2)]
    lm = ScratchLandmark(DynamicGraph(v, edges, capacity=2048), queries,
                         num_landmarks=5, max_iters=32)
    ref = sssp(DynamicGraph(v, edges, capacity=2048), [s for s, _ in queries],
               max_iters=32)
    want = ref.answers()[np.arange(3), [t for _, t in queries]]
    np.testing.assert_allclose(lm.answers(), want)
    # and after updates
    lm.apply_updates([(0, 40, 0, 1.0, +1)])
    ref.apply_updates([(0, 40, 0, 1.0, +1)])
    want = ref.answers()[np.arange(3), [t for _, t in queries]]
    np.testing.assert_allclose(lm.answers(), want)


def test_neighbor_sampler_shapes_and_reachability():
    from repro.data.sampler import CSRGraph, sample_subgraph

    rng = np.random.default_rng(0)
    src = rng.integers(0, 100, 500).astype(np.int32)
    dst = rng.integers(0, 100, 500).astype(np.int32)
    g = CSRGraph.from_edges(src, dst, 100)
    sub = sample_subgraph(g, np.asarray([1, 2, 3]), (4, 3),
                          max_nodes=64, max_edges=128, rng=rng)
    assert sub.node_ids.shape == (64,) and sub.edge_src.shape == (128,)
    n = int(sub.node_mask.sum())
    e = int(sub.edge_mask.sum())
    assert n >= 3 and e > 0
    # all edges reference in-range local ids
    assert sub.edge_src[:e].max() < n and sub.edge_dst[:e].max() < n


# ------------------------------------------------ durability seam (ISSUE 6)


def test_fault_policy_not_shared_between_supervisors(tmp_path):
    """Each Supervisor gets its own FaultPolicy: mutating one must not leak
    into another (the dataclass-default-instance bug)."""
    a = Supervisor(CheckpointManager(str(tmp_path / "a")))
    b = Supervisor(CheckpointManager(str(tmp_path / "b")))
    assert a.policy is not b.policy
    a.policy.max_restarts = 0
    assert b.policy.max_restarts == FaultPolicy().max_restarts


def test_fault_supervisor_max_restarts_exhaustion_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)

    def injector(step):
        raise InjectedFault("permanent failure")

    sup = Supervisor(
        mgr, FaultPolicy(max_restarts=2, checkpoint_every=100),
        fault_injector=injector,
    )
    import pytest

    with pytest.raises(InjectedFault, match="permanent"):
        sup.run({"x": jnp.zeros(())},
                lambda s, k: StepResult(state=s, metrics={}), num_steps=3)
    # the failing step was retried max_restarts times before giving up
    assert sup.restarts == 3
    assert sum(e.startswith("fault@0") for e in sup.history) == 3


def test_straggler_warmup_and_policy_callback():
    det = StragglerDetector(threshold=2.0, warmup=3)
    hits = []
    det.on_straggler(lambda ev: hits.append(ev.step))
    # outliers INSIDE the warmup window never flag (the EWMA is seeding) —
    # they fold into the baseline instead of raising events
    assert det.observe(0, 0.1) is False
    assert det.observe(1, 1.0) is False
    for i in range(2, 8):
        det.observe(i, 0.1)
    # the baseline decayed back toward 0.1: a true outlier now flags and
    # fires the registered policy callback
    assert det.observe(8, 10.0) is True
    assert hits == [8]
    # flagged samples are excluded from the EWMA (no self-poisoning): the
    # next normal sample is judged against the clean baseline
    assert det.ewma < 1.0
    assert det.observe(9, 0.1) is False
    assert det.events[-1].step == 8


def test_checkpoint_manager_async_never_overlaps(tmp_path, monkeypatch):
    """The async writer double-buffers: a save waits for the in-flight write
    before spawning the next, so at most one write runs at any time."""
    from repro.checkpoint import store as store_mod

    live = {"n": 0, "max": 0}
    real = store_mod.save_checkpoint

    def tracked(directory, step, tree, **kw):
        live["n"] += 1
        live["max"] = max(live["max"], live["n"])
        try:
            return real(directory, step, tree, **kw)
        finally:
            live["n"] -= 1

    monkeypatch.setattr(store_mod, "save_checkpoint", tracked)
    m = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    for s in range(1, 6):
        m.save(s, _tree())
    m.wait()
    assert live["max"] == 1
    assert sorted(os.listdir(str(tmp_path))) == ["step_00000004", "step_00000005"]


def test_restore_validates_manifest(tmp_path):
    """Restore against a mismatched target tree names the bad leaf instead
    of crashing deep in numpy."""
    import pytest

    d = str(tmp_path)
    save_checkpoint(d, 3, _tree())
    bad_shape = {"w": jnp.zeros((2, 2)), "nested": {"b": jnp.ones((5,), jnp.int32)}}
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(d, bad_shape)
    bad_dtype = {
        "w": jnp.zeros((3, 4)),
        "nested": {"b": jnp.ones((5,), jnp.float32)},
    }
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(d, bad_dtype)
    missing = {"extra": jnp.zeros((1,)), **_tree()}
    with pytest.raises(ValueError, match="extra"):
        restore_checkpoint(d, missing)


def test_load_checkpoint_meta_roundtrip(tmp_path):
    from repro.checkpoint import load_checkpoint

    d = str(tmp_path)
    save_checkpoint(d, 11, _tree(), meta={"next_chunk": 4, "note": "hi"})
    arrays, manifest, step = load_checkpoint(d)
    assert step == 11
    assert manifest["meta"] == {"next_chunk": 4, "note": "hi"}
    assert set(arrays) == {"w", "nested/b"}
    np.testing.assert_array_equal(arrays["w"], _tree()["w"])


def test_recovery_supervisor_cqp_integration(tmp_path):
    """RecoverySupervisor drives a real CQPSession: fault mid-stream →
    restore + replay equals the uninterrupted run, and the session surfaces
    the runtime blocks in stats()."""
    from repro.core import plan as qplan
    from repro.core.graph import DynamicGraph
    from repro.core.session import CQPSession
    from repro.runtime.recovery import RecoverySupervisor

    v = 16
    edges = [(i, (i + 1) % v, 1.0) for i in range(v)]
    log = [((3 * k) % v, (5 * k + 1) % v, 0, 1.0, +1) for k in range(8)]
    log = [u for u in log if u[0] != u[1]]
    chunks = [log[i : i + 2] for i in range(0, len(log), 2)]

    def fresh():
        s = CQPSession(DynamicGraph(v, edges, capacity=128), engine="host")
        h = s.register(qplan.sssp(0, max_iters=16))
        return s, h

    ref, h_ref = fresh()
    for c in chunks:
        ref.apply_updates(c)

    fired = {"done": False}

    def injector(k):
        if k == 2 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("drill")

    def restore_fn(directory):
        if directory is None:
            s, _ = fresh()
            return s, 0
        s = CQPSession.restore(directory)
        return s, int(s.restore_info["extra"]["next_chunk"])

    det = StragglerDetector()
    sup = RecoverySupervisor(
        str(tmp_path),
        FaultPolicy(checkpoint_every=1, max_restarts=2),
        restore_fn=restore_fn,
        fault_injector=injector,
        straggler=det,
    )
    session, _h = fresh()
    session.attach_runtime(straggler=det, supervisor=sup)

    def step_fn(s, k, chunk):
        s.apply_updates(chunk)

    session = sup.run(session, chunks, step_fn)
    session.attach_runtime(straggler=det, supervisor=sup)  # post-restore obj
    (h,) = session.handles()
    np.testing.assert_array_equal(session.answers(h), ref.answers(h_ref))
    assert sup.restarts == 1
    assert sup.metrics()["replayed_chunks"] == 0  # ckpt@2 landed pre-fault
    rt = session.stats()["runtime"]
    assert rt["fault"]["restarts"] == 1
    assert rt["straggler"]["observed"] == len(chunks)
