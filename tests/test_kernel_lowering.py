"""Mosaic lowering record-and-regress for every Pallas kernel.

``jax.export.export(jit_fn, platforms=["tpu"])`` runs the real Mosaic
lowering pipeline on a CPU host, so CI can catch kernel regressions without
a TPU.  On this jax version Mosaic cannot lower most of the maintenance
kernels (their [Q, V] tiles use (1, block_v) block shapes, and the bodies
use gathers / integer reductions), so the contract is recorded per kernel:

* ``flash_attn`` MUST lower (its (bq, d) blocks satisfy the tiling rules);
* the others must either lower (a jax upgrade lifting a limitation is an
  improvement, not a failure) or fail with a *known Mosaic limitation* —
  anything else (TypeError, NameError, shape errors from our own code) is a
  kernel regression and fails the test.

The interpret-mode default (`kernels.ops.default_interpret`) keeps these
kernels correct off-TPU; this file is the tripwire that tells us when the
compiled path changes underneath them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import export

from repro.core import diffstore as ds
from repro.kernels.bloom import bloom_query, pack_bits
from repro.kernels.diff_lookup import diff_lookup
from repro.kernels.ell_spmv import ell_spmv
from repro.kernels.flash_attn import flash_attention
from repro.kernels.fused_sweep import fused_sweep

# Error-message fragments of known Mosaic lowering limitations.  A failure
# matching none of these is OUR bug, not a backend gap.
KNOWN_MOSAIC_LIMITS = (
    "last two dimensions of your block shape are divisible",
    "Reductions over integers not implemented",
    "Unimplemented primitive in Pallas TPU lowering",
    "Only 32-bit integer support",
    "not implemented",
)


def _lower(fn, *args, **kw):
    """(lowered_ok, error_message) for a TPU export on the CPU host."""
    try:
        export.export(jax.jit(functools.partial(fn, **kw)), platforms=["tpu"])(
            *args
        )
        return True, ""
    except Exception as e:  # noqa: BLE001 — classified below
        return False, str(e)


def _cases():
    v, q, d, cap = 24, 2, 8, 4
    states = jnp.zeros((q, v + 1), jnp.float32)
    nbr = jnp.full((v, d), v, jnp.int32)
    w = jnp.zeros((v, d), jnp.float32)
    carry = jnp.zeros((q, v), jnp.float32)
    sched = jnp.zeros((q, v), bool)
    store = ds.make((q, v), cap)
    words = jnp.asarray(pack_bits(np.zeros((q, 1024), bool)))
    ids = jnp.zeros((q, v), jnp.int32)
    att = jnp.zeros((1, 2, 128, 64), jnp.float32)
    return {
        "flash_attn": (flash_attention, (att, att, att), {"interpret": False}),
        "ell_spmv": (
            ell_spmv,
            (states, nbr, w, carry),
            {"semiring": "min_plus", "block_v": 8, "interpret": False},
        ),
        "diff_lookup": (
            diff_lookup,
            (
                store.iters.reshape(q * v, cap),
                store.vals.reshape(q * v, cap),
                jnp.zeros((q * v,), jnp.int32),
            ),
            {"interpret": False},
        ),
        "bloom": (
            bloom_query,
            (words, ids, ids, jnp.zeros((q,), jnp.int32)),
            {"interpret": False},
        ),
        "fused_sweep": (
            fused_sweep,
            (0, 0, sched, jnp.ones((q,), bool), carry, carry, sched, store, store),
            {
                "states": states,
                "nbr": nbr,
                "w": w,
                "kcarry": carry,
                "semiring": "min_plus",
                "block_v": 8,
                "interpret": False,
            },
        ),
    }


def test_flash_attn_must_lower_to_mosaic():
    """The one kernel whose tiles satisfy Mosaic's rules must keep lowering
    — this is the hard regression bar for the compiled TPU path."""
    fn, args, kw = _cases()["flash_attn"]
    ok, err = _lower(fn, *args, **kw)
    assert ok, f"flash_attn stopped lowering to Mosaic: {err}"


@pytest.mark.parametrize(
    "name", ["ell_spmv", "diff_lookup", "bloom", "fused_sweep"]
)
def test_kernel_lowering_fails_only_on_known_mosaic_limits(name):
    """Record-and-regress: each maintenance kernel either lowers (backend
    improvement) or hits a *known* Mosaic limitation.  Any other error class
    means the kernel itself regressed."""
    fn, args, kw = _cases()[name]
    ok, err = _lower(fn, *args, **kw)
    if ok:
        return  # a jax upgrade lifted the limitation — nothing to assert
    assert any(frag in err for frag in KNOWN_MOSAIC_LIMITS), (
        f"{name} failed Mosaic lowering with an unrecognized error "
        f"(kernel regression?): {err.splitlines()[0] if err else err}"
    )
