"""Sparse host engine == dense engine == scratch; AccessD == reassembly."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis (requirements.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dropping as dr
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.sparse_engine import SparseDiffIFE
from tests.test_property_dc_equals_scratch import dynamic_graph_workload


@settings(max_examples=10, deadline=None)
@given(wl=dynamic_graph_workload())
def test_sparse_engine_matches_dense_sssp(wl):
    v, edges, batches = wl
    dense = q.sssp(DynamicGraph(v, edges, capacity=256), [0, v // 2], max_iters=32)
    sparse = SparseDiffIFE(DynamicGraph(v, edges, capacity=256), [0, v // 2], max_iters=32)
    np.testing.assert_array_equal(dense.answers(), sparse.answers())
    for batch in batches:
        dense.apply_updates(batch)
        sparse.apply_updates(batch)
        np.testing.assert_array_equal(dense.answers(), sparse.answers())


@settings(max_examples=6, deadline=None)
@given(wl=dynamic_graph_workload())
def test_sparse_engine_khop(wl):
    v, edges, batches = wl
    dense = q.khop(DynamicGraph(v, edges, capacity=256), [0], k=4)
    sparse = SparseDiffIFE(DynamicGraph(v, edges, capacity=256), [0], max_iters=4, khop=4)
    for batch in batches:
        dense.apply_updates(batch)
        sparse.apply_updates(batch)
        np.testing.assert_array_equal(
            np.isfinite(dense.answers()), np.isfinite(sparse.answers())
        )


def test_sparse_work_tracks_affected_set():
    """The host path's wall-clock advantage: maintenance work ∝ affected
    neighbourhood, not graph size (the paper's Table-1 mechanism)."""
    from repro.data.graphgen import powerlaw_graph

    v, e = 400, 1600
    edges = powerlaw_graph(v, e, seed=0)
    eng = SparseDiffIFE(DynamicGraph(v, edges, capacity=4096), [0, 1], max_iters=48)
    init_work = eng.work
    eng.work = 0
    # a leaf-edge tweak should touch a tiny neighbourhood
    eng.apply_updates([(v - 1, v - 2, 0, 3.0, +1)])
    assert eng.work < init_work / 10, (eng.work, init_work)


def test_access_with_drops_matches_reassembly():
    from repro.core.access import access
    from repro.core.engine import reassemble

    edges = [(0, 1, 2.0), (1, 2, 3.0), (0, 2, 10.0), (2, 3, 1.0)]
    drop = dr.DropConfig(mode="det", selection="random", p=0.6, seed=5)
    eng = q.sssp(DynamicGraph(4, edges, capacity=32), [0], max_iters=16, drop=drop)
    eng.apply_updates([(0, 1, 0, 2.0, -1)])  # delete the short path
    g = eng.g
    want = np.asarray(reassemble(eng.cfg, eng.state, g))
    for v in range(4):
        got = access(eng.cfg, eng.state, g, v, eng.cfg.max_iters)
        np.testing.assert_allclose(got, want[:, v], err_msg=f"vertex {v}")
