"""Serving-loop runtime plumbing: straggler shedding, restart exhaustion,
and slot accounting across admission rejections.

Companion to ``tests/test_serving.py`` (admission/tenancy semantics); this
file drives the StragglerDetector and the fault supervisor *through the
serving loop* rather than in isolation.
"""

from __future__ import annotations

import asyncio

import pytest

from repro.core import plan as qp
from repro.core.governor import GovernorConfig
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession
from repro.data.graphgen import powerlaw_graph, split_90_10
from repro.runtime.fault import InjectedFault
from repro.serving.admission import AdmissionRejected, SLOConfig
from repro.serving.loadgen import tenant_update_streams
from repro.serving.server import CQPServer, ServerConfig, build_serving_session
from repro.serving.tenants import TenantSpec

V, E, BATCH, MAX_ITERS = 64, 256, 8, 16
LADDER = GovernorConfig(representation="prob")


def _workload(num_batches: int = 10, seed: int = 3):
    edges = powerlaw_graph(V, E, seed=seed)
    initial, pool = split_90_10(edges, seed=seed)
    streams = tenant_update_streams(
        initial, V, 1, num_batches=num_batches, batch_size=BATCH,
        delete_fraction=0.1, insert_pool=pool, seed=seed + 1,
    )
    return initial, streams["tenant0"]


def _session(initial) -> CQPSession:
    graph = DynamicGraph(V, initial, capacity=len(initial) * 8 + 1024)
    return build_serving_session(graph, ladder=LADDER, engine="host")


# --------------------------------------------------------------- stragglers
def test_straggler_shedding_fires_exactly_once_per_event():
    """One slow chunk in an otherwise steady stream must produce exactly ONE
    straggler event, ONE force-shed, and ONE ladder action — the server
    registers its policy hook once (double registration would walk the
    ladder twice per event)."""
    initial, batches = _workload(num_batches=10)
    spike_at = 6

    def delays(k: int) -> float:
        # steady 10ms cadence with a single 100ms spike: past the warmup,
        # 100ms > threshold(4) * ewma(~10ms) flags exactly chunk `spike_at`
        return 0.1 if k == spike_at else 0.01

    # a huge backlog high-water mark and an infinite cooldown: the *only*
    # ladder action in this run can then be the straggler escalation
    cfg = ServerConfig(
        chunk_updates=BATCH,
        drop_ladder=LADDER,
        slo=SLOConfig(backlog_high_updates=10**9, cooldown_epochs=10**9),
        straggler_threshold=4.0,
        straggler_warmup=3,
    )

    async def run():
        server = CQPServer(
            _session(initial), config=cfg, delay_injector=delays
        )
        async with server:
            server.add_tenant(TenantSpec(tenant_id="t"))
            ticket = await server.register_query(
                "t", qp.sssp(0, max_iters=MAX_ITERS)
            )
            for batch in batches:
                server.submit("t", batch)
                await server.drain()  # one chunk per epoch, steady cadence
            r = await server.read(ticket, timeout_s=30.0)
            stats = server.stats()
        return r, stats

    r, stats = asyncio.run(run())
    assert r.fresh
    assert stats["straggler_events"] == 1
    assert stats["admission"]["straggler_sheds"] == 1
    straggler_actions = [
        a for a in stats["actions"]
        if a["reason"].startswith("straggler@")
    ]
    assert len(straggler_actions) == 1
    assert straggler_actions[0]["reason"] == f"straggler@{spike_at}"
    assert straggler_actions[0]["kind"] == "degrade"
    # nothing else walked the ladder
    assert len(stats["actions"]) == 1


def test_straggler_detection_disabled_without_spike():
    initial, batches = _workload(num_batches=8)

    async def run():
        server = CQPServer(
            _session(initial),
            config=ServerConfig(
                chunk_updates=BATCH,
                drop_ladder=LADDER,
                slo=SLOConfig(backlog_high_updates=10**9),
            ),
            delay_injector=lambda k: 0.005,
        )
        async with server:
            server.add_tenant(TenantSpec(tenant_id="t"))
            await server.register_query("t", qp.sssp(0, max_iters=MAX_ITERS))
            for batch in batches:
                server.submit("t", batch)
                await server.drain()
            stats = server.stats()
        return stats

    stats = asyncio.run(run())
    assert stats["straggler_events"] == 0
    assert stats["admission"]["straggler_sheds"] == 0


# ----------------------------------------------------------------- restarts
def test_restart_exhaustion_surfaces_the_fault():
    """A fault that survives every genesis rebuild must exhaust
    ``max_restarts`` and surface to callers — not spin forever.  The fault
    count is restarts + 1 (the final attempt re-raises)."""
    initial, batches = _workload(num_batches=2)
    max_restarts = 2

    def factory() -> CQPSession:
        return _session(initial)

    def always_fail(k: int) -> None:
        raise InjectedFault("unrecoverable scripted fault")

    async def run():
        server = CQPServer(
            factory(),
            config=ServerConfig(
                chunk_updates=BATCH,
                drop_ladder=LADDER,
                max_restarts=max_restarts,
            ),
            session_factory=factory,
            fault_injector=always_fail,
        )
        await server.start()
        server.add_tenant(TenantSpec(tenant_id="t"))
        await server.register_query("t", qp.sssp(0, max_iters=MAX_ITERS))
        server.submit("t", batches[0])
        with pytest.raises(InjectedFault):
            await server.drain()
        # the loop is dead: every later call re-raises rather than hanging
        with pytest.raises(InjectedFault):
            server.submit("t", batches[1])
        faults = server.faults
        with pytest.raises(InjectedFault):
            await server.stop()
        return faults

    assert asyncio.run(run()) == max_restarts + 1


# -------------------------------------------------------------------- slots
def test_admission_rejects_do_not_leak_query_slots():
    """register → shed-reject → re-register round-trips must leave the
    session's slot pool exactly as a straight registration would: a
    rejected registration never reached the engine, so it must not consume
    a slot, a qid, or a ticket binding."""
    initial, batches = _workload(num_batches=4)

    async def run():
        server = CQPServer(
            _session(initial),
            config=ServerConfig(chunk_updates=BATCH, drop_ladder=LADDER),
        )
        async with server:
            server.add_tenant(TenantSpec(tenant_id="t"))
            first = await server.register_query(
                "t", qp.sssp(0, max_iters=MAX_ITERS)
            )
            assert server.session.stats()["active_queries"] == 1

            for _ in range(3):  # repeated rejects: still no slot motion
                server.admission.shedding = True
                with pytest.raises(AdmissionRejected):
                    await server.register_query(
                        "t", qp.sssp(1, max_iters=MAX_ITERS)
                    )
                server.admission.shedding = False
            stats_mid = server.stats()
            assert server.session.stats()["active_queries"] == 1
            assert stats_mid["tenants"]["t"]["queries"] == 1
            assert stats_mid["tenants"]["t"]["rejected_registers"] == 3

            second = await server.register_query(
                "t", qp.sssp(1, max_iters=MAX_ITERS)
            )
            assert server.session.stats()["active_queries"] == 2
            # both tickets stay live through maintenance
            for batch in batches:
                server.submit("t", batch)
            await server.drain()
            r1 = await server.read(first, timeout_s=30.0)
            r2 = await server.read(second, timeout_s=30.0)
            assert r1.fresh and r2.fresh

            freed = await server.deregister_query(second)
            assert freed >= 0
            assert server.session.stats()["active_queries"] == 1
            await server.deregister_query(first)
            assert server.session.stats()["active_queries"] == 0
            stats = server.stats()
        return stats

    stats = asyncio.run(run())
    assert stats["tenants"]["t"]["queries"] == 0
