"""Bit-parity matrix for the fused maintenance megakernel (backend="fused").

The megakernel fuses one sweep iteration — frontier expand over the blocked
ELL adjacency, semiring aggregate, diff-store append/remove, DroppedVT /
Bloom probe+update — into a single ``pallas_call``.  The contract is *bit
identity* with the stitched paths (backend="ell" for JOD, backend="coo" for
VDC) across semirings, shard counts, drop modes and join_mat gating, and
resumability through the PR 6 checkpoint/restore machinery.

Two regression guards ride along:

* ``ell_spmv`` must not retrace or pad when the caller hands it arrays the
  ELL build already padded (jit cache probe + jaxpr scan for concatenate);
* the fused path must issue exactly ONE pallas_call per sweep iteration.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dropping as dr
from repro.core import engine as E
from repro.core import plan as qplan
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession
from repro.kernels.ell_spmv import ell_spmv
from repro.launch.mesh import make_data_mesh

V = 24
MAX_ITERS = 24
NDEV = jax.device_count()

needs8 = pytest.mark.skipif(
    NDEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

DROPS = {
    "none": None,
    "det": dr.DropConfig(mode="det", selection="random", p=0.4, seed=7),
    "prob": dr.DropConfig(
        mode="prob", selection="random", p=0.4, seed=7, bloom_bits=1 << 12
    ),
}


def random_workload(seed: int, v: int = V, e: int = 96, num_batches: int = 4):
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < e:
        u, w = int(rng.integers(0, v)), int(rng.integers(0, v))
        if u != w:
            seen[(u, w)] = (u, w, float(rng.integers(1, 10)))
    edges = list(seen.values())
    initial, pool = edges[: e * 3 // 4], edges[e * 3 // 4 :]
    present = {(u, w) for (u, w, _x) in initial}
    batches = []
    for _ in range(num_batches):
        batch = []
        for _ in range(int(rng.integers(2, 5))):
            if present and rng.random() < 0.4:
                u, w = sorted(present)[int(rng.integers(0, len(present)))]
                batch.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            elif pool:
                u, w, x = pool.pop()
                batch.append((u, w, 0, x, +1))
                present.add((u, w))
        batches.append(batch)
    return initial, batches


def _engine(backend, mode, dropmode, shards, initial):
    mesh = make_data_mesh(shards) if shards > 1 else None
    kw = dict(mode=mode)
    if DROPS[dropmode] is not None:
        kw["drop"] = DROPS[dropmode]
    return q.sssp(
        DynamicGraph(V, initial, capacity=512),
        [0, V // 2],
        max_iters=MAX_ITERS,
        backend=backend,
        mesh=mesh,
        **kw,
    )


def _assert_state_equal(a, b):
    for la, lb in zip(
        jax.tree_util.tree_leaves(a.state), jax.tree_util.tree_leaves(b.state)
    ):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# fused realizes JOD in-kernel and composes with VDC (the J store stays in
# XLA; the per-vertex store phase runs fused).  Reference backend: the
# stitched path the cell previously took.
MATRIX = [
    ("jod", "none", "ell"),
    ("jod", "det", "ell"),
    ("jod", "prob", "ell"),
    ("vdc", "none", "coo"),
]


@pytest.mark.parametrize("shards", [1, pytest.param(8, marks=needs8)])
@pytest.mark.parametrize("mode,dropmode,ref_backend", MATRIX, ids=str)
def test_fused_parity_matrix(mode, dropmode, ref_backend, shards):
    """fused vs stitched: bit-identical answers AND engine state per batch."""
    initial, batches = random_workload(seed=11)
    ref = _engine(ref_backend, mode, dropmode, shards, initial)
    fused = _engine("fused", mode, dropmode, shards, initial)
    np.testing.assert_array_equal(ref.answers(), fused.answers())
    for batch in batches:
        ref.apply_updates(batch)
        fused.apply_updates(batch)
        np.testing.assert_array_equal(ref.answers(), fused.answers())
    if shards == 1:
        _assert_state_equal(ref, fused)


@pytest.mark.parametrize(
    "make",
    [
        pytest.param(
            lambda be: q.khop(
                DynamicGraph(V, _INIT, capacity=512), [0, 3], k=6, backend=be
            ),
            id="min_hop",
        ),
        pytest.param(
            lambda be: q.wcc(
                DynamicGraph(V, _INIT, capacity=512),
                max_iters=MAX_ITERS,
                backend=be,
            ),
            id="min_label",
        ),
        pytest.param(
            lambda be: q.pagerank(
                DynamicGraph(V, _INIT, capacity=512), iters=12, backend=be
            ),
            id="pr_sum",
        ),
    ],
)
def test_fused_semiring_parity(make):
    _, batches = random_workload(seed=5)
    ref, fused = make("ell"), make("fused")
    np.testing.assert_array_equal(ref.answers(), fused.answers())
    for batch in batches:
        ref.apply_updates(batch)
        fused.apply_updates(batch)
        np.testing.assert_array_equal(ref.answers(), fused.answers())


_INIT, _ = random_workload(seed=5)


def test_fused_join_mat_gating_parity():
    """Per-slot join_mat gating (RPQ materialize vs drop) through the fused
    VDC store phase — answers must match the stitched coo engine."""
    nfa = qplan.NFA.concat_star(1, 2)
    initial = [(i, (i + 1) % V, 1.0, 1 + (i % 2)) for i in range(V)]
    rng = np.random.default_rng(9)
    log = []
    for t in range(10):
        u, w = int(rng.integers(0, V)), int(rng.integers(0, V))
        if u != w:
            log.append((u, w, 1 + (t % 2), 1.0, +1))
    log.append((0, 1, 1, 1.0, -1))
    plans = [
        qplan.rpq(0, nfa, max_iters=MAX_ITERS, join_store="materialize"),
        qplan.rpq(4, nfa, max_iters=MAX_ITERS, join_store="drop"),
    ]

    def _sess(backend):
        return CQPSession(
            DynamicGraph(V, initial, capacity=256),
            engine="dense",
            backend=backend,
            mode="vdc",
        )

    ref, fused = _sess("coo"), _sess("fused")
    rh, fh = ref.register_many(plans), fused.register_many(plans)
    ref.apply_updates(log)
    fused.apply_updates(log)
    for a, b in zip(rh, fh):
        np.testing.assert_array_equal(
            np.asarray(ref.answers(a)), np.asarray(fused.answers(b))
        )


def test_fused_checkpoint_restore_replay(tmp_path):
    """checkpoint → crash → restore → replay on backend="fused" matches an
    uninterrupted fused run (PR 6 durability composes with the megakernel)."""
    initial, batches = random_workload(seed=17, num_batches=4)
    log = [op for b in batches for op in b]
    cut = len(log) // 2
    plans = [
        qplan.sssp(0, max_iters=MAX_ITERS, drop=DROPS["prob"]),
        qplan.sssp(7, max_iters=MAX_ITERS),
    ]

    def _sess():
        return CQPSession(
            DynamicGraph(V, initial, capacity=256),
            engine="dense",
            backend="fused",
        )

    ref = _sess()
    rh = ref.register_many(plans)
    ref.apply_updates(log)

    s = _sess()
    sh = s.register_many(plans)
    s.apply_updates(log[:cut])
    s.checkpoint(str(tmp_path))
    s.apply_updates(log[cut:])  # post-checkpoint progress the crash destroys

    r = CQPSession.restore(str(tmp_path))
    r.apply_updates(log[cut:])
    for a, b in zip(rh, sh):
        np.testing.assert_array_equal(
            np.asarray(ref.answers(a)), np.asarray(r.answers(b))
        )


# ---------------------------------------------------------------- regressions


def _prims(jaxpr, acc):
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                inner = getattr(x, "jaxpr", None)
                if inner is not None:
                    _prims(inner if hasattr(inner, "eqns") else inner.jaxpr, acc)
                elif hasattr(x, "eqns"):
                    _prims(x, acc)
    return acc


def test_ell_spmv_no_retrace_no_copy_when_padded():
    """Arrays padded once at ELL build time enter the kernel as-is: no in-jit
    concatenate (the old per-call pad), and a second call with the same
    shapes hits the jit cache (no retrace)."""
    g = DynamicGraph(V, _INIT, capacity=512)
    nbr_np, w_np, _ = g.snapshot().to_ell(row_multiple=8)
    assert nbr_np.shape[0] % 8 == 0  # build-time row padding
    nbr, w = jnp.asarray(nbr_np), jnp.asarray(w_np)
    states = jnp.zeros((2, V + 1), jnp.float32)
    carry = jnp.zeros((2, V), jnp.float32)

    call = functools.partial(ell_spmv, semiring="min_plus", block_v=8)
    before = ell_spmv._cache_size()
    out = jax.block_until_ready(call(states, nbr, w, carry))
    assert out.shape == (2, V)
    after_first = ell_spmv._cache_size()
    assert after_first == before + 1
    jax.block_until_ready(call(states, nbr, w, carry))
    assert ell_spmv._cache_size() == after_first  # cache hit — no retrace

    prims = _prims(
        jax.make_jaxpr(lambda s, n, ww, c: call(s, n, ww, c))(
            states, nbr, w, carry
        ).jaxpr,
        set(),
    )
    assert "concatenate" not in prims, "ell_spmv pads inside jit again"
    assert "pad" not in prims


def _count_pallas(jaxpr):
    n = 0
    for eqn in jaxpr.eqns:
        if "pallas" in eqn.primitive.name:
            n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                inner = getattr(x, "jaxpr", None)
                if inner is not None:
                    n += _count_pallas(
                        inner if hasattr(inner, "eqns") else inner.jaxpr
                    )
                elif hasattr(x, "eqns"):
                    n += _count_pallas(x)
    return n


@pytest.mark.parametrize("dropmode", ["none", "det", "prob"])
def test_fused_single_pallas_call_per_iteration(dropmode):
    """The acceptance bar: the fused sweep body contains exactly one
    pallas_call — expand, diff-store and drop maintenance are all inside."""
    eng = _engine("fused", "jod", dropmode, 1, _INIT)
    jx = jax.make_jaxpr(functools.partial(E.maintain, eng.cfg))(
        eng.state, eng.g, jnp.ones((V,), bool)
    )
    assert _count_pallas(jx.jaxpr) == 1
