"""Plan-optimizer subsystem tests (DESIGN.md §16).

Covers: rewrite parity across engines/shards/drop modes, mid-stream
admit/release refcounting of the shared landmark index, governor
shed/re-materialize round trips, checkpoint→restore→replay parity, and
provenance round-tripping through the JSON plan schema.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.core import dropping as dr
from repro.core import plan as qp
from repro.core.graph import DynamicGraph
from repro.core.landmark import transpose_graph, transpose_updates
from repro.core.session import CQPSession
from repro.planner import INDEX_OP, PLANNER_QID, CostModel, LandmarkRule, Planner

NDEV = jax.device_count()
needs8 = pytest.mark.skipif(
    NDEV != 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

V = 48
E = 240
SEED = 11


def workload(seed=SEED, n_updates=24):
    """Weighted edges + a non-colliding insert stream (duplicate-edge
    re-insertion semantics differ across engines and is out of scope)."""
    rng = np.random.default_rng(seed)
    seen, edges, ups = set(), [], []
    while len(edges) < E:
        u, w = int(rng.integers(V)), int(rng.integers(V))
        if (u, w) not in seen:
            seen.add((u, w))
            edges.append((u, w, float(rng.integers(1, 9))))
    while len(ups) < n_updates:
        u, w = int(rng.integers(V)), int(rng.integers(V))
        if (u, w) not in seen:
            seen.add((u, w))
            ups.append((u, w, 0, float(rng.integers(1, 9)), 1))
    return edges, ups


def fresh_graph(edges):
    return DynamicGraph(V, edges, capacity=1024, weighted=True)


QUERIES = [(0, 17), (5, 40), (7, 3), (23, 30)]


def spsp_plans(drop=None):
    return [qp.spsp(s, t, drop=drop) for s, t in QUERIES]


def reference_targets(edges, ups):
    """Exact target distances via un-rewritten scratch SSSP."""
    ref = CQPSession(fresh_graph(edges), engine="scratch")
    handles = ref.register_many([qp.sssp(s) for s, _ in QUERIES])
    ref.apply_updates(list(ups))
    return np.array(
        [ref.answers(h)[t] for h, (_, t) in zip(handles, QUERIES)], np.float32
    )


# ------------------------------------------------------------------ builders
def test_spsp_builder_shares_sssp_family():
    assert qp.spsp(0, 17).family_key() == qp.sssp(0).family_key()


def test_spsp_aggregate_validates_target():
    p = qp.spsp(3, 9)
    assert p.aggregate.agg == "target" and p.aggregate.vertex == 9
    from repro.core import dataflow as df

    with pytest.raises(ValueError, match="target vertex"):
        df.validate(
            df.canonical(
                semiring=p.semiring,
                init=p.init,
                max_iters=p.max_iters,
                aggregate=df.Aggregate(agg="target"),
            )
        )


def test_transpose_graph_reverses_edges():
    edges, ups = workload()
    g = fresh_graph(edges)
    gt = transpose_graph(g)
    fwd = {(int(u), int(v)): float(w) for u, v, w in zip(
        g.src[g.valid], g.dst[g.valid], g.weight[g.valid])}
    rev = {(int(u), int(v)): float(w) for u, v, w in zip(
        gt.src[gt.valid], gt.dst[gt.valid], gt.weight[gt.valid])}
    assert rev == {(v, u): w for (u, v), w in fwd.items()}
    gt.apply_batch(transpose_updates(ups[:4]))
    for (u, v, _l, w, _s) in ups[:4]:
        assert rev.get((v, u)) is None
        assert (v, u) in {
            (int(a), int(b)) for a, b in zip(gt.src[gt.valid], gt.dst[gt.valid])
        }


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("engine", ["dense", "host", "scratch"])
@pytest.mark.parametrize("drop_mode", ["none", "prob"])
def test_rewrite_parity_engines_and_drop(engine, drop_mode):
    drop = (
        None
        if drop_mode == "none"
        else dr.DropConfig(mode="prob", p=0.25, seed=3, bloom_bits=1 << 10)
    )
    edges, ups = workload()
    sess = CQPSession(fresh_graph(edges), engine=engine, optimize="always")
    handles = sess.register_many(spsp_plans(drop))
    sess.apply_updates(ups[:12])
    sess.apply_updates(ups[12:])
    expect = reference_targets(edges, ups)
    got = np.array(
        [sess.answers(h)[t] for h, (_, t) in zip(handles, QUERIES)], np.float32
    )
    # landmark answers are exact at the target even under dropping: the
    # pruned subquery re-runs from scratch, gated only by triangle bounds
    assert np.array_equal(got, expect), (got, expect)
    for h, (_, t) in zip(handles, QUERIES):
        agg = sess.aggregate(h)
        assert agg["agg"] == "target" and agg["vertex"] == t
    lmk = sess.stats()["planner"]["landmark"]
    assert lmk["queries"] == len(QUERIES) and lmk["live"]


@needs8
def test_rewrite_parity_sharded_dense():
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
    edges, ups = workload()
    sess = CQPSession(
        fresh_graph(edges), engine="dense", mesh=mesh, optimize="always"
    )
    handles = sess.register_many(spsp_plans())
    sess.apply_updates(ups)
    expect = reference_targets(edges, ups)
    got = np.array(
        [sess.answers(h)[t] for h, (_, t) in zip(handles, QUERIES)], np.float32
    )
    assert np.array_equal(got, expect)


def test_optimize_none_is_identity():
    edges, ups = workload()
    sess = CQPSession(fresh_graph(edges), engine="host")
    handles = sess.register_many(spsp_plans())
    assert all(h.plan.provenance == () for h in handles)
    assert sess._planner is None and sess._internal == set()


def test_per_call_override_beats_session_mode():
    edges, _ = workload()
    sess = CQPSession(fresh_graph(edges), engine="host", optimize="always")
    h_plain = sess.register(qp.sssp(1))  # no aggregate → no match
    h_off = sess.register(qp.spsp(2, 9), optimize="none")
    h_on = sess.register(qp.spsp(3, 11))
    assert h_plain.plan.provenance == () and h_off.plan.provenance == ()
    assert h_on.plan.provenance[0].rule == "landmark"
    assert sess._planner.owns(h_on.qid) and not sess._planner.owns(h_off.qid)


# ---------------------------------------------------------------- cost model
def test_cost_gate_auto_dense_single_query_declines():
    edges, _ = workload()
    sess = CQPSession(fresh_graph(edges), engine="dense", optimize="auto")
    h = sess.register(qp.spsp(0, 17))
    # 1 sharer < 2L break-even on a diff-maintaining engine → untouched
    assert h.plan.provenance == ()
    assert not sess._planner.owns(h.qid)
    assert sess._planner.decisions and not sess._planner.decisions[-1]["applied"]


def test_cost_gate_auto_scratch_always_pays():
    edges, _ = workload()
    sess = CQPSession(fresh_graph(edges), engine="scratch", optimize="auto")
    h = sess.register(qp.spsp(0, 17))
    assert h.plan.provenance and h.plan.provenance[0].rule == "landmark"


def test_cost_estimate_break_even_math():
    edges, _ = workload()
    sess = CQPSession(fresh_graph(edges), engine="dense")
    model = CostModel()
    est_lo = model.landmark(qp.spsp(0, 1), sess, num_landmarks=4, sharers=3)
    est_hi = model.landmark(qp.spsp(0, 1), sess, num_landmarks=4, sharers=8)
    assert not est_lo.pays and est_hi.pays
    assert est_lo.to_dict()["index_rows"] == 8


# ------------------------------------------------------------- refcounting
def test_midstream_register_deregister_refcounts_index():
    edges, ups = workload()
    sess = CQPSession(fresh_graph(edges), engine="dense", optimize="always")
    h0 = sess.register(qp.spsp(0, 17))
    rule = sess._planner.rules[0]
    assert rule._live and len(sess._internal) == rule.num_landmarks
    sess.apply_updates(ups[:8])
    h1 = sess.register(qp.spsp(5, 40))  # mid-stream admit shares the index
    assert len(sess._internal) == rule.num_landmarks  # not rebuilt
    sess.apply_updates(ups[8:16])
    assert sess.deregister(h0) == 0  # index survives: one sharer left
    assert rule._live and rule.queries == {h1.qid: (5, 40)}
    sess.apply_updates(ups[16:])
    expect = reference_targets(edges, ups)
    assert sess.answers(h1)[40] == expect[1]
    freed = sess.deregister(h1)  # last sharer → teardown
    assert freed > 0 and not rule._live
    assert sess._internal == set() and sess._plans == {}
    assert rule.rev_session is None


def test_internal_qids_hidden_from_public_views():
    edges, _ = workload()
    sess = CQPSession(fresh_graph(edges), engine="dense", optimize="always")
    h = sess.register(qp.spsp(0, 17))
    rule = sess._planner.rules[0]
    assert sess.num_queries == 1
    assert [x.qid for x in sess.handles()] == [h.qid]
    assert set(sess.answers_snapshot()) == {h.qid}
    assert len(sess.nbytes_per_query()) == 1
    assert sess.stats()["query_qids"] == [h.qid]
    # internal rows are real engine citizens: bytes live under their qids
    per_op = sess._nbytes_per_op_map()
    internal_bytes = sum(
        b for (q, op), b in per_op.items() if q in sess._internal
    )
    assert internal_bytes > 0
    assert (PLANNER_QID, INDEX_OP) in per_op
    with pytest.raises(ValueError, match="internal"):
        sess.deregister(
            type(h)(qid=next(iter(sess._internal)), plan=h.plan)
        )


def test_rewritten_query_rejects_engine_drop_policy():
    edges, _ = workload()
    sess = CQPSession(fresh_graph(edges), engine="dense", optimize="always")
    h = sess.register(qp.spsp(0, 17))
    with pytest.raises(ValueError, match="planner rewrite"):
        sess.set_drop_policy(h, dr.DropConfig(mode="prob", p=0.5))


# ---------------------------------------------------------------- governor
def test_governor_sheds_and_rematerializes_index():
    edges, ups = workload()
    sess = CQPSession(
        fresh_graph(edges), engine="dense", optimize="always", budget_bytes=1
    )
    handles = sess.register_many(spsp_plans())
    rule = sess._planner.rules[0]
    sess.apply_updates(ups[:12])
    lmk = sess.stats()["planner"]["landmark"]
    assert lmk["shed"] and lmk["sheds_total"] >= 1
    assert not rule._live and sess._internal == set()
    assert sess.stats()["bytes_shed_total"] > 0
    # shed answers stay exact (pruned scratch degrades to plain BF)
    mid = reference_targets(edges, ups[:12])
    got = np.array(
        [sess.answers(h)[t] for h, (_, t) in zip(handles, QUERIES)], np.float32
    )
    assert np.array_equal(got, mid)
    # relief: calm passes under the raised budget re-materialize the index
    sess.governor.budget_bytes = 1 << 24
    sess.apply_updates(ups[12:])
    for _ in range(8):
        if sess.stats()["planner"]["landmark"]["remats_total"]:
            break
        sess.apply_updates([])
    lmk = sess.stats()["planner"]["landmark"]
    assert lmk["remats_total"] >= 1 and lmk["live"]
    expect = reference_targets(edges, ups)
    got = np.array(
        [sess.answers(h)[t] for h, (_, t) in zip(handles, QUERIES)], np.float32
    )
    assert np.array_equal(got, expect)


def test_scratch_session_index_never_governed():
    edges, ups = workload()
    sess = CQPSession(
        fresh_graph(edges), engine="scratch", optimize="always", budget_bytes=1
    )
    sess.register_many(spsp_plans())
    sess.apply_updates(ups[:8])
    # scratch rows account 0 bytes → the zero-byte filter never picks the
    # landmark pseudo-op (an index shed would reclaim nothing)
    lmk = sess.stats()["planner"]["landmark"]
    assert lmk["sheds_total"] == 0 and lmk["live"]


# --------------------------------------------------------------- durability
def test_checkpoint_restore_replay_parity(tmp_path):
    edges, ups = workload()
    sess = CQPSession(fresh_graph(edges), engine="dense", optimize="always")
    handles = sess.register_many(spsp_plans())
    sess.apply_updates(ups[:12])
    sess.checkpoint(str(tmp_path))
    restored = CQPSession.restore(str(tmp_path))
    r_lmk = restored.stats()["planner"]["landmark"]
    s_lmk = sess.stats()["planner"]["landmark"]
    assert r_lmk["landmarks"] == s_lmk["landmarks"]
    assert r_lmk["queries"] == s_lmk["queries"]
    sess.apply_updates(ups[12:])
    restored.apply_updates(ups[12:])
    expect = reference_targets(edges, ups)
    for sess_i in (sess, restored):
        got = np.array(
            [sess_i.answers(h)[t] for h, (_, t) in zip(handles, QUERIES)],
            np.float32,
        )
        assert np.array_equal(got, expect)
    # full pruned fields match bit-for-bit, not just the targets
    for h in handles:
        assert np.array_equal(sess.answers(h), restored.answers(h))


def test_restore_while_shed_then_rematerialize(tmp_path):
    edges, ups = workload()
    sess = CQPSession(
        fresh_graph(edges), engine="dense", optimize="always", budget_bytes=1
    )
    handles = sess.register_many(spsp_plans())
    sess.apply_updates(ups[:8])
    assert sess.stats()["planner"]["landmark"]["shed"]
    sess.checkpoint(str(tmp_path))
    restored = CQPSession.restore(str(tmp_path))
    lmk = restored.stats()["planner"]["landmark"]
    assert lmk["shed"] and not lmk["live"]
    restored.governor.budget_bytes = 1 << 24
    restored.apply_updates(ups[8:])
    for _ in range(8):
        if restored.stats()["planner"]["landmark"]["remats_total"]:
            break
        restored.apply_updates([])
    assert restored.stats()["planner"]["landmark"]["live"]
    expect = reference_targets(edges, ups)
    got = np.array(
        [restored.answers(h)[t] for h, (_, t) in zip(handles, QUERIES)],
        np.float32,
    )
    assert np.array_equal(got, expect)


def test_planner_metrics_published():
    from repro.obs.metrics import MetricsRegistry

    edges, ups = workload()
    sess = CQPSession(fresh_graph(edges), engine="dense", optimize="always")
    sess.register_many(spsp_plans())
    sess.apply_updates(ups[:8])
    reg = sess.publish_metrics(MetricsRegistry())
    snap = reg.snapshot()
    assert {"cqp_planner_rewrites_total", "cqp_landmark_index_nbytes"} <= set(
        snap
    )


# --------------------------------------------------------------- provenance
def test_provenance_roundtrip_json():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    keys = st.text(
        alphabet="abcdefghijklmnopqrstuvwxyz_", min_size=1, max_size=8
    )
    vals = st.one_of(
        st.integers(-(2**31), 2**31), st.text(max_size=12), st.booleans()
    )

    @settings(max_examples=40)
    @given(
        rule=keys,
        kind=st.sampled_from(["spsp", "sssp", "khop"]),
        params=st.dictionaries(keys, vals, max_size=4),
    )
    def check(rule, kind, params):
        prov = qp.Provenance(
            rule=rule, original_kind=kind, params=tuple(params.items())
        )
        plan = qp.spsp(1, 2).with_provenance(prov)
        back = qp.QueryPlan.from_json(plan.to_json())
        assert back.provenance == plan.provenance
        assert back.provenance[-1].params == tuple(sorted(params.items()))
        assert qp.Provenance.from_dict(prov.to_dict()) == prov

    check()


def test_rewrite_stamps_provenance():
    edges, _ = workload()
    sess = CQPSession(fresh_graph(edges), engine="scratch", optimize="always")
    h = sess.register(qp.spsp(4, 31))
    (prov,) = h.plan.provenance
    assert prov.rule == "landmark" and prov.original_kind == "spsp"
    assert dict(prov.params)["source"] == 4
    assert dict(prov.params)["target"] == 31
    # the session's stored plan is the rewritten one (checkpoint carries it)
    assert sess._plans[h.qid].provenance == h.plan.provenance


def test_planner_rejects_unknown_mode():
    edges, _ = workload()
    with pytest.raises(ValueError, match="optimize"):
        CQPSession(fresh_graph(edges), optimize="sometimes")
    sess = CQPSession(fresh_graph(edges), engine="host")
    with pytest.raises(ValueError, match="optimize"):
        sess.register(qp.spsp(0, 1), optimize="sometimes")
    with pytest.raises(ValueError):
        Planner(sess, "sometimes")
