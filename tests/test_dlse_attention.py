"""Distributed-LSE decode attention == chunked reference (multi-device).

Runs in a subprocess so it can claim 8 host devices regardless of how the
test session initialized jax.
"""

import subprocess
import sys


def test_dlse_matches_chunked_subprocess():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import common as cm

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
b, hq, hkv, s, d = 4, 8, 2, 64, 16
q = jnp.asarray(rng.standard_normal((b, hq, 1, d)), jnp.float32)
ck = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
cv = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
valid = jnp.int32(37)
ref = cm.chunked_attention(q, ck, cv, causal=False, q_offset=36,
                           kv_valid_len=valid, block_q=8, block_k=16)
with mesh:
    with cm.activation_mesh(mesh):
        got = jax.jit(cm.dlse_decode_attention, in_shardings=(
            NamedSharding(mesh, P("data", None, None, None)),
            NamedSharding(mesh, P("data", None, "model", None)),
            NamedSharding(mesh, P("data", None, "model", None)),
            NamedSharding(mesh, P()),
        ))(q, ck, cv, valid)
err = float(jnp.abs(ref - got).max())
assert err < 1e-5, err
print("DLSE_OK", err)
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert "DLSE_OK" in out.stdout, out.stderr[-2000:]
