"""Memory governor: closed-loop budget enforcement (DESIGN.md §10).

The acceptance property: under a fixed ``budget_bytes``, a churny
multi-query stream (register/deregister + updates) keeps
``session.nbytes() ≤ budget`` after a bounded settling window, while every
answer stays exactly equal to the SCRATCH oracle — across the dense and
host engines and (dense) ≥2 shard counts.  Plus: policy-ladder mechanics,
``set_drop_policy`` shedding, de-escalation hysteresis, telemetry
surfacing, and a ``cqp_serve --budget-bytes`` subprocess smoke.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import dropping as dr
from repro.core import plan as qplan
from repro.core.governor import GovernorConfig, MemoryGovernor
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession
from repro.core.telemetry import RecomputeTelemetry
from repro.launch.mesh import make_data_mesh

V = 16
MAX_ITERS = 16
NDEV = jax.device_count()

needs8 = pytest.mark.skipif(
    NDEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def workload(seed=5, v=V, e=48, nbatches=6):
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < e:
        u, w = int(rng.integers(0, v)), int(rng.integers(0, v))
        if u != w:
            seen[(u, w)] = (u, w, float(rng.integers(1, 9)))
    edges = list(seen.values())
    initial, pool = edges[: e * 3 // 4], edges[e * 3 // 4 :]
    present = {(u, w) for (u, w, _x) in initial}
    batches = []
    for _ in range(nbatches):
        batch = []
        for _ in range(4):
            if present and rng.random() < 0.35:
                u, w = sorted(present)[int(rng.integers(0, len(present)))]
                batch.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            elif pool:
                u, w, x = pool.pop()
                batch.append((u, w, 0, x, +1))
                present.add((u, w))
        batches.append(batch)
    return initial, batches


def _graph(initial, v=V):
    return DynamicGraph(v, initial, capacity=256)


def _static_peak(initial, batches, plans):
    """Peak accounted bytes of the no-governor (static 'none') run."""
    s = CQPSession(_graph(initial), engine="dense")
    s.register_many(plans)
    peak = s.nbytes()
    for b in batches:
        s.apply_updates(b)
        peak = max(peak, s.nbytes())
    return peak


def _oracle_answers(initial, batches, live_plans, churn):
    """SCRATCH replay of the same churny stream → answers per live plan."""
    s = CQPSession(_graph(initial), engine="scratch")
    handles = s.register_many(live_plans[: churn["q0"]])
    for j, b in enumerate(batches):
        s.apply_updates(b)
        if j == churn["register_at"]:
            handles.append(s.register(churn["plan"]))
        if j == churn["deregister_at"]:
            s.deregister(handles.pop(0))
    return [s.answers(h) for h in handles]


# --------------------------------------------------------------- acceptance
@pytest.mark.parametrize(
    "engine,shards",
    [
        ("dense", 1),
        pytest.param("dense", 8, marks=needs8),
        ("host", 1),
    ],
)
def test_budget_closed_loop_churny_stream(engine, shards):
    """budget held after settling + answers exactly equal the scratch oracle."""
    initial, batches = workload(seed=7)
    q0 = 3
    plans = [qplan.sssp(i, max_iters=MAX_ITERS) for i in range(q0)]
    extra = qplan.sssp(9, max_iters=MAX_ITERS)
    churn = {"q0": q0, "register_at": 1, "deregister_at": 3, "plan": extra}

    peak = _static_peak(initial, batches, plans)
    # Prob-Drop's reclamation floor is the fixed per-query footprint (packed
    # Bloom row + params row); the budget must sit above it — representation
    # physics, not governor slack — yet well under the static peak.  Det-Drop
    # (whose floor grows with drop history, the paper's d/(d+s) bound) is
    # exercised by the shed/mechanics tests below.
    bloom_bits = 1 << 7
    floor = (q0 + 1) * (bloom_bits // 8 + dr.PARAMS_ROW_NBYTES)
    budget = max(int(peak * 0.5), floor + 48)
    assert budget < peak  # the governor has real work to do

    mesh = make_data_mesh(shards) if shards > 1 else None
    s = CQPSession(
        _graph(initial),
        engine=engine,
        mesh=mesh,
        budget_bytes=budget,
        governor=GovernorConfig(representation="prob", bloom_bits=bloom_bits),
    )
    handles = s.register_many(plans)
    settle = 1  # the governor enforces after every batch: one batch to settle
    for j, b in enumerate(batches):
        s.apply_updates(b)
        if j == churn["register_at"]:
            handles.append(s.register(extra))
        if j == churn["deregister_at"]:
            s.deregister(handles.pop(0))
        if j >= settle:
            assert s.nbytes() <= budget, (
                j,
                s.nbytes(),
                budget,
                s.governor.levels,
            )
    assert s.governor is not None and s.governor.actions
    assert any(a.kind == "escalate" for a in s.governor.actions)
    oracle = _oracle_answers(initial, batches, plans + [extra], churn)
    for h, want in zip(handles, oracle):
        np.testing.assert_array_equal(s.answers(h), want)


def test_budget_property_stream():
    """Hypothesis: arbitrary insert/delete streams — budget after settling +
    scratch-oracle exactness, dense and host."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    v = 12

    @st.composite
    def stream(draw):
        mk = st.tuples(
            st.integers(0, v - 1), st.integers(0, v - 1), st.integers(1, 9)
        )
        edges = [
            (u, w, float(x))
            for (u, w, x) in draw(st.lists(mk, min_size=8, max_size=20))
            if u != w
        ]
        edges = list({(u, w): (u, w, x) for (u, w, x) in edges}.values())
        present = {(u, w) for (u, w, _x) in edges}
        ops = []
        for _ in range(draw(st.integers(4, 12))):
            if present and draw(st.booleans()):
                u, w = draw(st.sampled_from(sorted(present)))
                ops.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            else:
                u, w = draw(st.integers(0, v - 1)), draw(st.integers(0, v - 1))
                if u == w:
                    continue
                ops.append((u, w, 0, float(draw(st.integers(1, 9))), +1))
                present.add((u, w))
        return edges, ops

    @settings(max_examples=6, deadline=None)
    @given(wl=stream())
    def run(wl):
        edges, ops = wl
        plans = [qplan.sssp(0, max_iters=12), qplan.sssp(v // 2, max_iters=12)]
        oracle = CQPSession(DynamicGraph(v, edges, capacity=128), engine="scratch")
        oh = oracle.register_many(plans)
        oracle.apply_updates(ops)
        for engine in ("dense", "host"):
            s = CQPSession(
                DynamicGraph(v, edges, capacity=128),
                engine=engine,
                budget_bytes=96,  # tight: forces deep escalation
                governor=GovernorConfig(representation="prob", bloom_bits=1 << 8),
            )
            hs = s.register_many(plans)
            half = len(ops) // 2
            s.apply_updates(ops[:half])
            s.apply_updates(ops[half:])  # ≥1 post-settle enforcement pass
            for a, b in zip(hs, oh):
                np.testing.assert_array_equal(s.answers(a), oracle.answers(b))
            # per-query floor: 256-bit bloom row (32 B) + 17 B params row
            floor = sum(32 + 17 for _ in hs)
            assert s.nbytes() <= max(96, floor), (engine, s.nbytes())

    run()


# ---------------------------------------------------------------- mechanics
def test_set_drop_policy_sheds_and_stays_exact():
    """Escalating one query's policy mid-stream sheds ITS stored diffs
    (bytes fall immediately), leaves the other query untouched, and answers
    stay exact; de-escalating back is a memory no-op (nested drop sets)."""
    initial, batches = workload(seed=11)
    s = CQPSession(_graph(initial), engine="dense", drop=dr.DropConfig(mode="det"))
    h0 = s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    h1 = s.register(qplan.sssp(5, max_iters=MAX_ITERS))
    s.apply_updates(batches[0])
    ref = CQPSession(_graph(initial), engine="host")
    r0 = ref.register(qplan.sssp(0, max_iters=MAX_ITERS))
    r1 = ref.register(qplan.sssp(5, max_iters=MAX_ITERS))
    ref.apply_updates(batches[0])

    per_before = s.nbytes_per_query()
    freed = s.set_drop_policy(h0, dr.DropConfig(mode="det", p=1.0, seed=2))
    per_after = s.nbytes_per_query()
    assert freed > 0
    assert per_after[0] == per_before[0] - freed
    assert per_after[1] == per_before[1]  # untouched neighbour
    assert s.bytes_shed_total == freed

    # still exact after the shed, including under later updates
    for b in batches[1:3]:
        s.apply_updates(b)
        ref.apply_updates(b)
    np.testing.assert_array_equal(s.answers(h0), ref.answers(r0))
    np.testing.assert_array_equal(s.answers(h1), ref.answers(r1))

    # de-escalation: stored survivors have coin u ≥ p, so a weaker policy
    # sheds nothing (drop sets are nested in p under the stateless hash)
    assert s.set_drop_policy(h0, dr.DropConfig(mode="det", p=0.3, seed=2)) == 0


def test_set_drop_policy_validation():
    initial, _ = workload()
    s = CQPSession(_graph(initial), engine="dense", drop=dr.DropConfig(mode="det"))
    h = s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    with pytest.raises(ValueError, match="drop mode"):
        s.set_drop_policy(h, dr.DropConfig(mode="prob", p=0.5))
    s.deregister(h)
    with pytest.raises(ValueError, match="not registered"):
        s.set_drop_policy(h, dr.DropConfig(mode="det", p=0.5))
    # no representation provisioned → the governor has no lever
    s2 = CQPSession(_graph(initial), engine="dense")
    h2 = s2.register(qplan.sssp(0, max_iters=MAX_ITERS))
    with pytest.raises(ValueError, match="representation"):
        s2.set_drop_policy(h2, dr.DropConfig(mode="det", p=0.5))
    with pytest.raises(ValueError, match="DroppedVT representation"):
        CQPSession(
            _graph(initial),
            engine="dense",
            drop=dr.DropConfig(mode="none"),
            budget_bytes=128,
        )
    with pytest.raises(ValueError, match="budget_bytes"):
        CQPSession(_graph(initial), engine="dense", governor=GovernorConfig())
    # an explicit session representation overrides the governor's default so
    # ladder rungs escalate within the session's DroppedVT mode
    s3 = CQPSession(
        _graph(initial),
        engine="dense",
        drop=dr.DropConfig(mode="det"),
        budget_bytes=64,
        governor=GovernorConfig(representation="prob"),
    )
    s3.register(qplan.sssp(0, max_iters=MAX_ITERS))  # escalates det rungs
    assert s3.governor.cfg.representation == "det"
    assert any(lvl > 0 for lvl in s3.governor.levels.values())


def test_governor_deescalates_after_headroom():
    """Hysteresis: once deregistration opens headroom below the low-water
    mark, the governor steps a query back down the ladder."""
    initial, batches = workload(seed=13)
    s = CQPSession(
        _graph(initial),
        engine="dense",
        budget_bytes=400,
        governor=GovernorConfig(
            representation="prob", bloom_bits=1 << 8, cooldown_passes=0
        ),
    )
    handles = s.register_many(
        [qplan.sssp(i, max_iters=MAX_ITERS) for i in range(3)]
    )
    s.apply_updates(batches[0])
    assert any(lvl > 0 for lvl in s.governor.levels.values())
    # retire two queries: bytes collapse far under low_water × budget, and
    # subsequent passes should relieve the survivor
    s.deregister(handles.pop(0))
    s.deregister(handles.pop(0))
    for b in batches[1:]:
        s.apply_updates(b)
    assert any(a.kind == "deescalate" for a in s.governor.actions)
    # full relief: the survivor walked back to its registered policy, and
    # regrowth stayed within budget (the predictive guard's whole point)
    assert s.governor.levels == {2: 0}
    assert s.nbytes() <= 400


def test_governor_stats_and_serving_surface():
    """stats() carries the per-query breakdown + governor snapshot."""
    initial, batches = workload(seed=3)
    s = CQPSession(
        _graph(initial),
        engine="dense",
        budget_bytes=512,
        governor=GovernorConfig(representation="prob", bloom_bits=1 << 8),
    )
    s.register_many([qplan.sssp(i, max_iters=MAX_ITERS) for i in range(2)])
    s.apply_updates(batches[0])
    st = s.stats()
    assert st["nbytes_per_query"] == s.nbytes_per_query()
    assert len(st["nbytes_per_query"]) == 2
    assert sum(st["nbytes_per_query"]) == st["nbytes"]
    gov = st["governor"]
    assert gov["budget_bytes"] == 512
    assert gov["headroom_bytes"] == 512 - st["nbytes"]
    assert gov["telemetry"]["observations"] >= 1
    assert set(gov["levels"]) == {"0", "1"}
    json.dumps(st["governor"])  # snapshot must be JSON-serializable


def test_plain_sessions_report_per_query_bytes():
    """nbytes_per_query works without a governor on every engine."""
    initial, batches = workload(seed=4)
    for engine in ("dense", "host", "scratch"):
        s = CQPSession(_graph(initial), engine=engine)
        s.register_many([qplan.sssp(i, max_iters=MAX_ITERS) for i in range(2)])
        s.apply_updates(batches[0])
        per = s.nbytes_per_query()
        assert len(per) == 2
        assert sum(per) == s.nbytes()


def test_telemetry_rates_and_eviction_guard():
    """RecomputeTelemetry differences cumulative counters into per-update
    EWMA rates and drops state for deregistered queries."""
    t = RecomputeTelemetry(alpha=0.5)
    t.observe(
        nbytes_per_query={0: 100, 1: 50},
        cost_per_query={0: 10, 1: 0},
        stats=None,
        updates_applied=10,
    )
    assert t.cost_rate(0) == pytest.approx(1.0)
    t.observe(
        nbytes_per_query={0: 80},  # qid 1 deregistered
        cost_per_query={0: 30},
        stats=None,
        updates_applied=20,
    )
    assert t.cost_rate(0) == pytest.approx(0.5 * 1.0 + 0.5 * 2.0)
    assert t.cost_rate(1) == 0.0
    assert t.bytes_held(0) == 80
    snap = t.snapshot()
    assert snap["observations"] == 2 and "1" not in snap["per_query"]


def test_host_degree_ladder_reaches_scratch_fallback():
    """Drop-all under Degree selection (no τ_max carve-out) must trigger the
    host scratch fallback too — the budget cannot silently go unenforced
    just because the ladder tightens τ instead of flipping a coin."""
    initial, batches = workload(seed=21)
    s = CQPSession(
        _graph(initial),
        engine="host",
        budget_bytes=64,
        governor=GovernorConfig(selection="degree"),
    )
    handles = s.register_many(
        [qplan.sssp(i, max_iters=MAX_ITERS) for i in range(2)]
    )
    for b in batches[:3]:
        s.apply_updates(b)
    assert s.nbytes() == 0  # both queries at the scratch-fallback floor
    ref = CQPSession(_graph(initial), engine="host")
    rh = ref.register_many([qplan.sssp(i, max_iters=MAX_ITERS) for i in range(2)])
    for b in batches[:3]:
        ref.apply_updates(b)
    for a, b_ in zip(handles, rh):
        np.testing.assert_array_equal(s.answers(a), ref.answers(b_))


def test_telemetry_ignores_replayed_stats_and_churn_passes():
    """An enforcement pass without a new sweep must not re-count the same
    MaintainStats det_overflow delta, and churn passes (no new updates) must
    not dilute the cost EWMAs toward zero."""

    class FakeStats:
        iters_run = 3
        scheduled = 10
        repairs = 2
        det_overflow = 4

    t = RecomputeTelemetry(alpha=0.5)
    stats = FakeStats()
    t.observe(
        nbytes_per_query={0: 100},
        cost_per_query={0: 10},
        stats=stats,
        updates_applied=10,
    )
    rate = t.cost_rate(0)
    assert t.det_overflow_total == 4 and rate == pytest.approx(1.0)
    # replayed pass: same stats object, no new updates (e.g. a deregister)
    t.observe(
        nbytes_per_query={0: 90},
        cost_per_query={0: 10},
        stats=stats,
        updates_applied=10,
    )
    assert t.det_overflow_total == 4  # not 8
    assert t.cost_rate(0) == rate  # not diluted
    assert t.bytes_held(0) == 90  # bytes still refresh
    # a genuinely new stats object counts again
    t.observe(
        nbytes_per_query={0: 90},
        cost_per_query={0: 16},
        stats=FakeStats(),
        updates_applied=12,
    )
    assert t.det_overflow_total == 8


def test_shed_det_evictions_surface_and_block_only_the_culprit():
    """A shed that evicts DroppedVT records (det_capacity exhausted) must
    surface the loss (stats()['governor']['det_overflow_shed']) and bar only
    the culprit query from further escalation — other queries keep
    absorbing the budget pressure."""
    initial, batches = workload(seed=19)
    s = CQPSession(
        _graph(initial),
        engine="dense",
        budget_bytes=64,  # far below the det floor: maximal pressure
        governor=GovernorConfig(representation="det", det_capacity=1),
    )
    s.register_many([qplan.sssp(i, max_iters=MAX_ITERS) for i in range(3)])
    for b in batches:
        s.apply_updates(b)
    gov = s.stats()["governor"]
    assert gov["det_overflow_shed"] > 0
    blocked = set(gov["overflow_blocked"])
    assert blocked  # the culprit was barred...
    unblocked = set(int(q) for q in gov["levels"]) - blocked
    assert unblocked  # ...but never every query (no global lockout)
    assert all(gov["levels"][str(q)] == 4 for q in unblocked)


def test_governor_config_validation():
    with pytest.raises(ValueError, match="representation"):
        GovernorConfig(representation="lossy")
    with pytest.raises(ValueError, match="selection"):
        GovernorConfig(selection="degrees")  # typo caught at construction
    with pytest.raises(ValueError, match="ladder_p"):
        GovernorConfig(ladder_p=(0.5, 0.25))
    with pytest.raises(ValueError, match="low_water"):
        GovernorConfig(low_water=1.5)
    with pytest.raises(ValueError, match="budget_bytes"):
        MemoryGovernor(0)
    # rung 0 restores the registered policy; the top rung is drop-all
    cfg = GovernorConfig()
    base = dr.DropConfig(mode="det", p=0.1, seed=9)
    assert cfg.rung_config(0, base) is base
    top = cfg.rung_config(cfg.top_level, base)
    assert top.p == 1.0 and top.seed == 9  # keeps the query's seed (nesting)


# -------------------------------------------------- dropping-layer edge cases
def test_set_params_row_rewrites_only_that_row():
    params = dr.make_params(
        [
            dr.DropConfig(mode="det", p=0.2, seed=1),
            dr.DropConfig(mode="det", p=0.4, seed=2),
        ]
    )
    out = dr.set_params_row(params, 1, dr.DropConfig(mode="det", p=0.9, seed=7))
    assert float(out.p[0]) == pytest.approx(0.2) and int(out.seed[0]) == 1
    assert float(out.p[1]) == pytest.approx(0.9) and int(out.seed[1]) == 7
    # a disabled config maps to the never-drop row
    off = dr.set_params_row(params, 0, dr.DropConfig())
    assert float(off.p[0]) == 0.0 and not bool(off.degree_sel[0])


def test_unregister_is_noop_for_bloom():
    """Bloom filters cannot delete: unregister must leave bits untouched
    (stale positives are spurious-but-safe repairs, never wrong answers)."""
    import jax.numpy as jnp

    st = dr.make_state(
        dr.DropConfig(mode="prob", p=1.0, bloom_bits=1 << 8), 2, 4
    )
    mask = jnp.ones((2, 4), bool)
    st = dr.register(st, 3, mask)
    bits_before = np.asarray(st.flt.bits)
    out = dr.unregister(st, 3, mask)
    np.testing.assert_array_equal(np.asarray(out.flt.bits), bits_before)
    # det mode DOES delete
    st2 = dr.make_state(dr.DropConfig(mode="det", p=1.0), 2, 4)
    st2 = dr.register(st2, 3, mask)
    assert int(st2.det.count.sum()) == 8
    st2 = dr.unregister(st2, 3, mask)
    assert int(st2.det.count.sum()) == 0


def test_select_stored_to_drop_matches_sweep_coin():
    """The shed audit must reuse the sweep's stateless coin exactly, and
    never select padding entries."""
    import jax.numpy as jnp

    from repro.core import diffstore as ds

    params = dr.make_params(dr.DropConfig(mode="det", p=0.5, seed=3), 2)
    iters = jnp.asarray(
        [[[1, 2, ds.IMAX], [3, ds.IMAX, ds.IMAX]]] * 2, jnp.int32
    )  # [2, 2, 3]
    degree = jnp.asarray([4.0, 1.0])
    sel = dr.select_stored_to_drop(params, degree, iters, ds.IMAX)
    assert not bool(sel[0, 0, 2]) and not bool(sel[0, 1, 1])  # padding never
    q_ids = jnp.arange(2, dtype=jnp.int32)[:, None]
    for v in range(2):
        for s in range(3):
            it = int(iters[0, v, s])
            if it == int(ds.IMAX):
                continue
            want = dr.select_to_drop(
                params,
                degree[None, :],
                q_ids,
                jnp.full((2, 2), v, jnp.int32),
                jnp.full((2, 2), it, jnp.int32),
            )[:, v]
            np.testing.assert_array_equal(np.asarray(sel[:, v, s]), np.asarray(want))


# ------------------------------------------------------------------- serving
def test_cqp_serve_budget_subprocess_smoke():
    """cqp_serve --budget-bytes: budget respected post-settle, actions
    logged, per-query bytes reported (the CI governor smoke's local twin)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.cqp_serve",
            "--smoke",
            "--json",
            "--budget-bytes",
            "1024",
            "--governor",
            "prob",
        ],
        env=env,
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    gov = payload["governor"]
    assert gov["budget_respected"], gov
    assert gov["settled_peak_bytes"] <= gov["budget_bytes"]
    assert gov["escalations"] > 0 and gov["actions"], gov
    assert len(payload["nbytes_per_query"]) == payload["final_queries"]
