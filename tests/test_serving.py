"""Async multi-tenant serving tier: epoch reads, tenancy, admission, SLOs.

No pytest-asyncio in the image — every test drives its own loop through
``asyncio.run``.  Host engine throughout (fast, jit-free); the dense-engine
serving path is exercised by the CI serving smoke
(``python -m repro.serving.server``).
"""

from __future__ import annotations

import asyncio
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.core import plan as qp
from repro.core.governor import GovernorConfig
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession
from repro.data.graphgen import powerlaw_graph, split_90_10
from repro.runtime.fault import InjectedFault
from repro.serving.admission import AdmissionRejected, SLOConfig
from repro.serving.loadgen import tenant_update_streams
from repro.serving.server import CQPServer, ServerConfig, build_serving_session
from repro.serving.tenants import TenantSpec

V, E, BATCH, MAX_ITERS = 64, 256, 8, 16
LADDER = GovernorConfig(representation="prob")


def _workload(tenants: int = 2, num_batches: int = 6, seed: int = 0):
    edges = powerlaw_graph(V, E, seed=seed)
    initial, pool = split_90_10(edges, seed=seed)
    streams = tenant_update_streams(
        initial, V, tenants, num_batches=num_batches, batch_size=BATCH,
        delete_fraction=0.1, insert_pool=pool, seed=seed + 1,
    )
    return initial, streams


def _graph(initial) -> DynamicGraph:
    return DynamicGraph(V, initial, capacity=len(initial) * 8 + 1024)


def _server(initial, *, config=None, **kw) -> CQPServer:
    session = build_serving_session(_graph(initial), ladder=LADDER, engine="host")
    return CQPServer(
        session,
        config=config
        or ServerConfig(chunk_updates=BATCH, drop_ladder=LADDER),
        **kw,
    )


def _oracle_answers(initial, plans, applied):
    oracle = CQPSession(_graph(initial), engine="scratch")
    handles = [oracle.register(p) for p in plans]
    if applied:
        oracle.apply_updates_batched(applied)
    return [np.asarray(oracle.answers(h)) for h in handles]


# ------------------------------------------------------------------ reads
def test_read_your_writes_and_epoch_snapshot_consistency():
    """Every read is fresh (covers the tenant's admitted writes) and serves
    values equal to a scratch replay of exactly its covered prefix — no
    read ever observes a half-applied chunk."""
    initial, streams = _workload()
    plans = [qp.sssp(0, max_iters=MAX_ITERS), qp.sssp(7, max_iters=MAX_ITERS)]

    async def run():
        server = _server(initial)
        reads = []
        async with server:
            tickets = {}
            for i, tid in enumerate(sorted(streams)):
                server.add_tenant(TenantSpec(tenant_id=tid, priority=i + 1))
                tickets[tid] = await server.register_query(tid, plans[i])
            for round_batches in zip(*(streams[t] for t in sorted(streams))):
                for tid, batch in zip(sorted(streams), round_batches):
                    res = server.submit(tid, batch)
                    assert res.admitted
                    r = await server.read(tickets[tid], timeout_s=30.0)
                    assert r.fresh and r.covered >= res.watermark
                    reads.append((tid, r))
            await server.drain()
            chunk_log = [list(c) for c in server._chunk_log]
            ticket_index = {tid: i for i, tid in enumerate(sorted(streams))}
        return reads, chunk_log, ticket_index

    reads, chunk_log, ticket_index = asyncio.run(run())
    assert reads
    # replay the applied log from scratch; check each read at its prefix
    prefixes = sorted({r.covered for _, r in reads})
    at = {}
    flat = []
    covered = 0
    for chunk in chunk_log:
        flat.extend(chunk)
        covered += len(chunk)
        if covered in prefixes:
            at[covered] = flat[:]
    plans = [qp.sssp(0, max_iters=MAX_ITERS), qp.sssp(7, max_iters=MAX_ITERS)]
    for tid, r in reads:
        want = _oracle_answers(initial, plans, at[r.covered])[ticket_index[tid]]
        np.testing.assert_array_equal(np.asarray(r.values), want)


# ---------------------------------------------------------------- admission
def test_rate_quota_rejects_and_recovers():
    """A tenant's token bucket rejects beyond its quota; the co-tenant with
    no quota is untouched; rejected submissions do not advance the
    watermark."""
    initial, streams = _workload()

    async def run():
        server = _server(initial)
        async with server:
            server.add_tenant(
                TenantSpec(tenant_id="limited", rate_per_s=1.0, burst=BATCH)
            )
            server.add_tenant(TenantSpec(tenant_id="free"))
            t_lim = await server.register_query(
                "limited", qp.sssp(0, max_iters=MAX_ITERS)
            )
            await server.register_query("free", qp.sssp(1, max_iters=MAX_ITERS))
            batches = streams["tenant0"]
            first = server.submit("limited", batches[0])  # burst covers this
            second = server.submit("limited", batches[1])  # bucket empty
            free = server.submit("free", batches[2])
            await server.drain()
            r = await server.read(t_lim, timeout_s=30.0)
            stats = server.stats()
        assert first.admitted
        assert not second.admitted and second.reason == "rate quota"
        assert second.watermark == first.watermark  # rejected ≠ watermark
        assert free.admitted
        assert r.fresh
        assert stats["tenants"]["limited"]["rejected_updates"] == len(batches[1])
        assert stats["tenants"]["free"]["rejected_updates"] == 0

    asyncio.run(run())


def test_overload_degrades_every_rung_before_first_shed_rejection():
    """The admission ladder: an overloaded tier degrades one rung per epoch
    until every tenant sits at the top rung, and only then starts rejecting
    submissions — the action log shows the full ladder before the first
    'overload shed'."""
    initial, streams = _workload()
    # backlog_high_updates=0: any queued update marks the tier overloaded
    cfg = ServerConfig(
        chunk_updates=BATCH,
        drop_ladder=LADDER,
        slo=SLOConfig(backlog_high_updates=0, cooldown_epochs=10**6),
    )

    async def run():
        server = _server(initial, config=cfg)
        rungs_total = LADDER.top_level * 2  # 2 tenants
        async with server:
            for i, tid in enumerate(sorted(streams)):
                server.add_tenant(TenantSpec(tenant_id=tid, priority=i + 1))
                await server.register_query(
                    tid, qp.sssp(i, max_iters=MAX_ITERS)
                )
            rejected = []
            k = 0
            all_batches = [b for t in sorted(streams) for b in streams[t]]
            while len(rejected) == 0 and k < 500:
                # several batches per round so the loop still sees a backlog
                # when it observes the epoch (one chunk is popped first)
                for _ in range(4):
                    res = server.submit(
                        "tenant0", all_batches[k % len(all_batches)]
                    )
                    if not res.admitted:
                        rejected.append(res)
                    k += 1
                # yield so the ingest loop can fold chunks and run epochs
                await asyncio.sleep(0.001)
            await server.drain()
            stats = server.stats()
        assert rejected and rejected[0].reason == "overload shed"
        # shedding only engages once next_degradable() is exhausted, so the
        # action log must show the full ladder before the first rejection
        # (cooldown is effectively infinite — no restores muddy the count)
        degrades = [a for a in stats["actions"] if a["kind"] == "degrade"]
        assert len(degrades) == rungs_total
        assert not any(a["kind"] == "restore" for a in stats["actions"])
        # low priority (tenant0) degraded strictly before the co-tenant
        first_t1 = next(
            i for i, a in enumerate(degrades) if a["tenant"] == "tenant1"
        )
        assert all(a["tenant"] == "tenant0" for a in degrades[:first_t1])

    asyncio.run(run())


def test_register_rejected_while_shedding_raises():
    initial, _ = _workload()

    async def run():
        server = _server(initial)
        async with server:
            server.add_tenant(TenantSpec(tenant_id="t"))
            server.admission.shedding = True
            with pytest.raises(AdmissionRejected):
                await server.register_query(
                    "t", qp.sssp(0, max_iters=MAX_ITERS)
                )
            server.admission.shedding = False
            ticket = await server.register_query(
                "t", qp.sssp(0, max_iters=MAX_ITERS)
            )
            r = await server.read(ticket, timeout_s=30.0)
            assert r.fresh

    asyncio.run(run())


# ------------------------------------------------------------------ budgets
def test_tenant_budget_isolation():
    """A tenant blowing its own byte budget degrades down the ladder; the
    co-tenant with no budget stays at level 0 (isolation)."""
    initial, streams = _workload(num_batches=8)
    # neutralize the admission-overload path entirely: the only ladder
    # actions left are per-tenant budget enforcement
    cfg = ServerConfig(
        chunk_updates=BATCH,
        drop_ladder=LADDER,
        slo=SLOConfig(backlog_high_updates=10**9, cooldown_epochs=10**9),
    )

    async def run():
        server = _server(initial, config=cfg)
        async with server:
            server.add_tenant(TenantSpec(tenant_id="tenant0", budget_bytes=64))
            server.add_tenant(TenantSpec(tenant_id="tenant1"))
            for tid in sorted(streams):
                await server.register_query(
                    tid, qp.sssp(0 if tid == "tenant0" else 1,
                                 max_iters=MAX_ITERS)
                )
            for t0_batch, t1_batch in zip(
                streams["tenant0"], streams["tenant1"]
            ):
                server.submit("tenant0", t0_batch)
                server.submit("tenant1", t1_batch)
            await server.drain()
            stats = server.stats()
        assert stats["tenants"]["tenant0"]["level"] > 0
        assert stats["tenants"]["tenant1"]["level"] == 0
        budget_actions = [
            a for a in stats["actions"] if a["reason"] == "tenant budget"
        ]
        assert budget_actions
        assert all(a["tenant"] == "tenant0" for a in budget_actions)

    asyncio.run(run())


# -------------------------------------------------------------- overload SLO
def test_overload_admission_keeps_reads_fresh_and_exact():
    """The ISSUE acceptance shape, scaled down: under sustained 2× overload
    the admitted run sheds work, keeps steady-state reads fresh, and serves
    exact answers; the no-admission control run lets the backlog grow
    without bound and its late reads blow the read-your-writes barrier."""
    rounds = 40
    initial, streams = _workload(tenants=3, num_batches=rounds)
    pace_s = 0.01  # floor on chunk time → service ≤ BATCH/pace_s updates/s
    round_gap_s = 0.015  # 3·BATCH updates per round → offered ≈ 2× service
    read_timeout_s = 0.15

    def make_cfg(admission: bool) -> ServerConfig:
        return ServerConfig(
            chunk_updates=BATCH,
            admission=admission,
            read_timeout_s=read_timeout_s,
            drop_ladder=LADDER,
            slo=SLOConfig(backlog_high_updates=BATCH, cooldown_epochs=10**6),
        )

    async def run(admission: bool):
        server = _server(
            initial,
            config=make_cfg(admission),
            delay_injector=lambda k: pace_s,
        )
        plans = {}
        round_reads: list[dict] = []
        async with server:
            tickets = {}
            for i, tid in enumerate(sorted(streams)):
                server.add_tenant(TenantSpec(tenant_id=tid, priority=i + 1))
                plans[tid] = qp.sssp(i * 11, max_iters=MAX_ITERS)
                tickets[tid] = await server.register_query(tid, plans[tid])

            async def read_back(rnd: int, tid: str) -> None:
                r = await server.read(tickets[tid])
                round_reads.append(
                    {"round": rnd, "tenant": tid, "fresh": r.fresh}
                )

            # open-loop: reads run as concurrent tasks so they never gate
            # the next round's submissions (the closed-loop trap)
            tasks = []
            for rnd, round_batches in enumerate(
                zip(*(streams[t] for t in sorted(streams)))
            ):
                for tid, batch in zip(sorted(streams), round_batches):
                    server.submit(tid, batch)
                    tasks.append(
                        asyncio.ensure_future(read_back(rnd, tid))
                    )
                await asyncio.sleep(round_gap_s)
            await asyncio.gather(*tasks)
            stats = server.stats()
            await server.drain()
            final = {
                tid: await server.read(t, timeout_s=30.0)
                for tid, t in tickets.items()
            }
            applied = server.applied_updates()
        return round_reads, final, stats, applied, plans

    round_reads, final, stats, applied, plans = asyncio.run(run(True))
    # admission shed work and kept the steady-state backlog bounded: every
    # read in the last quarter of the run is fresh
    assert stats["admission"]["rejected_updates"] > 0
    steady = [r for r in round_reads if r["round"] >= 3 * rounds // 4]
    assert steady and all(r["fresh"] for r in steady)
    # ...and every served answer is exact despite the degradation ladder
    order = sorted(final)
    oracle = _oracle_answers(initial, [plans[t] for t in order], applied)
    for tid, want in zip(order, oracle):
        assert final[tid].fresh
        np.testing.assert_array_equal(np.asarray(final[tid].values), want)

    control_reads, _, control_stats, _, _ = asyncio.run(run(False))
    # the control run admits everything; its late reads go stale
    assert control_stats["admission"]["rejected_updates"] == 0
    control_steady = [
        r for r in control_reads if r["round"] >= 3 * rounds // 4
    ]
    assert any(not r["fresh"] for r in control_steady)


# ----------------------------------------------------------------- recovery
def test_fault_recovery_preserves_tenants_genesis():
    """A mid-stream engine fault with no checkpoint on disk rebuilds from
    genesis, replays the applied log, and keeps every tenant's ticket live —
    answers match an uninterrupted run exactly."""
    initial, streams = _workload()
    plans = {"tenant0": qp.sssp(0, max_iters=MAX_ITERS),
             "tenant1": qp.sssp(3, max_iters=MAX_ITERS)}

    def factory() -> CQPSession:
        return build_serving_session(_graph(initial), ladder=LADDER, engine="host")

    fired = {"done": False}

    def injector(k: int) -> None:
        if k == 2 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("scripted fault at chunk 2")

    async def run(with_fault: bool):
        server = CQPServer(
            factory(),
            config=ServerConfig(chunk_updates=BATCH, drop_ladder=LADDER),
            session_factory=factory,
            fault_injector=injector if with_fault else None,
        )
        async with server:
            tickets = {}
            for i, tid in enumerate(sorted(streams)):
                server.add_tenant(TenantSpec(tenant_id=tid))
                tickets[tid] = await server.register_query(tid, plans[tid])
            for round_batches in zip(*(streams[t] for t in sorted(streams))):
                for tid, batch in zip(sorted(streams), round_batches):
                    server.submit(tid, batch)
            await server.drain()
            reads = {
                tid: await server.read(t, timeout_s=30.0)
                for tid, t in tickets.items()
            }
            stats = server.stats()
        return reads, stats

    fired["done"] = False
    faulted, f_stats = asyncio.run(run(with_fault=True))
    clean, c_stats = asyncio.run(run(with_fault=False))
    assert f_stats["faults"] == 1 and c_stats["faults"] == 0
    assert f_stats["covered_updates"] == c_stats["covered_updates"]
    for tid in faulted:
        assert faulted[tid].fresh
        np.testing.assert_array_equal(
            np.asarray(faulted[tid].values), np.asarray(clean[tid].values)
        )


def test_fault_recovery_restores_checkpoint(tmp_path):
    """With a checkpoint on disk the recovery path restores it and replays
    only the post-checkpoint suffix — tenants, tickets, and exactness all
    survive."""
    initial, streams = _workload()
    plans = {"tenant0": qp.sssp(0, max_iters=MAX_ITERS),
             "tenant1": qp.sssp(3, max_iters=MAX_ITERS)}

    def factory() -> CQPSession:
        return build_serving_session(_graph(initial), ladder=LADDER, engine="host")

    fired = {"done": False}

    def injector(k: int) -> None:
        if k == 3 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("scripted fault at chunk 3")

    async def run():
        server = CQPServer(
            factory(),
            config=ServerConfig(
                chunk_updates=BATCH, drop_ladder=LADDER,
                checkpoint_every=2,
            ),
            session_factory=factory,
            checkpoint_dir=str(tmp_path),
            fault_injector=injector,
        )
        async with server:
            tickets = {}
            for i, tid in enumerate(sorted(streams)):
                server.add_tenant(TenantSpec(tenant_id=tid))
                tickets[tid] = await server.register_query(tid, plans[tid])
            for round_batches in zip(*(streams[t] for t in sorted(streams))):
                for tid, batch in zip(sorted(streams), round_batches):
                    server.submit(tid, batch)
            await server.drain()
            reads = {
                tid: await server.read(t, timeout_s=30.0)
                for tid, t in tickets.items()
            }
            stats = server.stats()
            applied = server.applied_updates()
        return reads, stats, applied

    reads, stats, applied = asyncio.run(run())
    assert stats["faults"] == 1
    assert len(stats["recovery"]["restores"]) == 1
    order = sorted(reads)
    oracle = _oracle_answers(initial, [plans[t] for t in order], applied)
    for tid, want in zip(order, oracle):
        assert reads[tid].fresh
        np.testing.assert_array_equal(np.asarray(reads[tid].values), want)


# ---------------------------------------------------------------------- CLI
def test_cli_smoke_subprocess():
    """``python -m repro.serving.server --smoke`` is the CI entry point —
    it must exit 0 and report ok/exact on the host engine."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.serving.server", "--smoke",
         "--tenants", "2", "--engine", "host", "--updates", "48"],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    line = next(
        ln for ln in proc.stdout.splitlines()
        if ln.startswith("serving smoke JSON:")
    )
    summary = json.loads(line.split("serving smoke JSON:", 1)[1])
    assert summary["ok"] and summary["exact"]
