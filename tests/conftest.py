"""Shared test configuration.

Hypothesis: an explicit profile with ``deadline=None`` is registered and
loaded for EVERY suite.  CI boxes (and the emulated-8-device jobs) run the
jit-heavy property tests orders of magnitude slower on their first example
than on later ones, which trips Hypothesis's per-example deadline during
shrinking and produces intermittent ``DeadlineExceeded``/``too_slow`` flakes
— wall clock is bounded by ``max_examples`` at each ``@settings`` site
instead.
"""

try:  # hypothesis is an optional test dependency (importorskip elsewhere)
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro-ci",
        deadline=None,
        derandomize=True,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("repro-ci")
except ImportError:  # pragma: no cover
    pass
