"""Deterministic SparseDiffIFE regressions (no hypothesis dependency —
``tests/test_sparse_and_access.py`` skips entirely when the property-test
stack is absent, so pinned-workload regressions live here)."""

from repro.core.graph import DynamicGraph
from repro.core.sparse_engine import SparseDiffIFE


def test_sparse_delete_reconverges_through_late_change_points():
    """Regression: a deletion raises a vertex transitively, but an
    alternative derivation through a neighbour whose change point settles at
    a LATER iteration restores the lower value.  The sweep must keep every
    touched vertex scheduled through the trace horizon (retractions are not
    monotone) — dropping it at its first unchanged iteration leaves the
    raised value behind."""
    # d(9) = 9 two ways: the 2-hop 0→7→9 (settles at iteration 2) and the
    # 7-hop chain 0→1→…→6→9 (settles at iteration 7); 0→8→9 is a 10 decoy.
    edges = [
        (0, 1, 3.0), (1, 2, 1.0), (2, 3, 1.0), (3, 4, 1.0), (4, 5, 1.0),
        (5, 6, 1.0), (6, 9, 1.0),
        (0, 7, 8.0), (7, 9, 1.0),
        (0, 8, 9.0), (8, 9, 1.0),
    ]
    eng = SparseDiffIFE(DynamicGraph(10, edges, capacity=64), [0], max_iters=16)
    assert eng.answers()[0][9] == 9.0
    eng.apply_updates([(0, 7, 0, 8.0, -1)])  # kill the early 9-path
    assert eng.answers()[0][9] == 9.0, eng.answers()[0]
