"""Operator-graph plan IR (DESIGN.md §11): validation, family keys, JSON,
per-operator dropping, and governor attribution at (query, operator).

The acceptance property: per-operator dropping is demonstrably FINER than
per-query dropping — an RPQ session that drops only the Join operator's
differences holds fewer bytes than whole-query dropping at equal answer
exactness — and legacy single-node plans stay bit-identical through the
compatibility constructor.
"""

import json
import os
import subprocess
import sys
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import dataflow as df
from repro.core import dropping as dr
from repro.core import plan as qplan
from repro.core.governor import GovernorConfig
from repro.core.graph import DynamicGraph
from repro.core.session import ENGINES, CQPSession
from repro.launch.mesh import make_data_mesh

V = 16
MAX_ITERS = 16
NDEV = jax.device_count()

needs8 = pytest.mark.skipif(
    NDEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def labelled_workload(seed=3, v=V, e=56, nbatches=4):
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < e:
        u, w = int(rng.integers(0, v)), int(rng.integers(0, v))
        if u != w:
            seen[(u, w)] = (u, w, 1.0, 1 + int(rng.integers(0, 2)))
    edges = list(seen.values())
    initial, pool = edges[: e * 3 // 4], edges[e * 3 // 4 :]
    present = {(u, w) for (u, w, _x, _l) in initial}
    labels = {(u, w): l for (u, w, _x, l) in edges}
    batches = []
    for _ in range(nbatches):
        batch = []
        for _ in range(4):
            if present and rng.random() < 0.3:
                u, w = sorted(present)[int(rng.integers(0, len(present)))]
                batch.append((u, w, labels[(u, w)], 1.0, -1))
                present.discard((u, w))
            elif pool:
                u, w, x, l = pool.pop()
                batch.append((u, w, l, x, +1))
                present.add((u, w))
        batches.append(batch)
    return initial, batches


# ------------------------------------------------------------------ validation
def test_graph_validation_rejects_cycles_and_dangling_refs():
    nfa = df.NFA.star(1)
    with pytest.raises(ValueError, match="cycle"):
        df.validate(
            (
                df.Ingest(),
                df.Join(inputs=("iterate",), nfa=nfa),
                df.Iterate(inputs=("join",), semiring=qplan.sr.min_hop()),
            )
        )
    with pytest.raises(ValueError, match="dangling"):
        df.validate(
            (df.Ingest(), df.Iterate(inputs=("nope",), semiring=qplan.sr.min_plus()))
        )
    with pytest.raises(ValueError, match="duplicate"):
        df.validate(
            (
                df.Ingest(),
                df.Ingest(),
                df.Iterate(inputs=("ingest",), semiring=qplan.sr.min_plus()),
            )
        )
    with pytest.raises(ValueError, match="consumes itself"):
        df.validate(
            (
                df.Ingest(),
                df.Iterate(
                    op_id="it", inputs=("it",), semiring=qplan.sr.min_plus()
                ),
            )
        )
    with pytest.raises(ValueError, match="exactly one iterate"):
        df.validate((df.Ingest(),))
    with pytest.raises(ValueError, match="exactly one ingest"):
        df.validate((df.Iterate(inputs=(), semiring=qplan.sr.min_plus()),))
    with pytest.raises(ValueError, match="not connected"):
        df.validate(
            (df.Ingest(), df.Iterate(inputs=(), semiring=qplan.sr.min_plus()))
        )
    with pytest.raises(ValueError, match="must consume the iterate"):
        df.validate(
            (
                df.Ingest(),
                df.Iterate(inputs=("ingest",), semiring=qplan.sr.min_plus()),
                df.Aggregate(inputs=("ingest",)),
            )
        )
    # join dropping is all-or-nothing (§4): partial p rejected
    with pytest.raises(ValueError, match="completely"):
        df.validate(
            (
                df.Ingest(),
                df.Join(nfa=nfa, drop=dr.DropConfig(mode="det", p=0.5)),
                df.Iterate(inputs=("join",), semiring=qplan.sr.min_hop()),
            )
        )
    with pytest.raises(ValueError, match="needs an NFA"):
        df.validate(
            (
                df.Ingest(),
                df.Join(nfa=None),
                df.Iterate(inputs=("join",), semiring=qplan.sr.min_hop()),
            )
        )
    # store-owning nodes are engine-addressed by kind: ids are pinned
    with pytest.raises(ValueError, match="canonical id"):
        df.validate(
            (
                df.Ingest(),
                df.Iterate(
                    op_id="fixpoint",
                    inputs=("ingest",),
                    semiring=qplan.sr.min_plus(),
                ),
            )
        )


def test_family_key_stable_under_node_reordering():
    nfa = df.NFA.concat_star(1, 2)
    a = qplan.rpq(0, nfa, max_iters=MAX_ITERS)
    shuffled = qplan.QueryPlan.from_graph("rpq", tuple(reversed(a.ops)))
    assert a.family_key() == shuffled.family_key()
    # per-query knobs stay free: source, drop policies, aggregates
    assert a.family_key() == qplan.rpq(7, nfa, max_iters=MAX_ITERS).family_key()
    assert (
        a.family_key()
        == qplan.rpq(
            0, nfa, max_iters=MAX_ITERS, drop=dr.DropConfig(mode="det", p=0.5)
        ).family_key()
    )
    assert (
        a.family_key()
        == qplan.rpq(0, nfa, max_iters=MAX_ITERS, join_store="drop").family_key()
    )
    assert a.family_key() == a.with_aggregate("topk", k=3).family_key()
    # structural knobs are not free
    assert a.family_key() != qplan.rpq(0, df.NFA.star(1), max_iters=MAX_ITERS).family_key()
    assert a.family_key() != qplan.rpq(0, nfa, max_iters=MAX_ITERS + 1).family_key()
    assert qplan.sssp(0).family_key() != qplan.khop(0).family_key()


def test_nfa_and_initspec_hash_equality_edge_cases():
    # delta insertion order and per-label pair order are both normalized
    a = df.NFA(2, {1: [(0, 1)], 2: [(1, 1)]}, 0, (1,))
    b = df.NFA(2, {2: [(1, 1)], 1: [(0, 1)]}, 0, (1,))
    assert a == b and hash(a) == hash(b) and a.key() == b.key()
    c = df.NFA(2, {1: [(0, 1), (1, 1)]}, 0, (0, 1))
    d = df.NFA(2, {1: [(1, 1), (0, 1)]}, 0, (1, 0))
    assert c == d and hash(c) == hash(d)
    assert a != df.NFA(2, {1: [(0, 1)], 2: [(1, 1)]}, 1, (1,))  # start differs
    assert len({a, b, c, d}) == 2  # usable as dict/set keys
    # InitSpec: frozen value equality, inf fills included
    assert df.InitSpec(kind="source", source=3) == df.InitSpec(
        kind="source", source=3
    )
    assert hash(df.InitSpec(fill=float("inf"))) == hash(df.InitSpec())
    assert df.InitSpec(kind="source", source=0) != df.InitSpec(
        kind="source", source=None
    )
    # plans whose NFAs differ only in listing order share a family
    pa = qplan.rpq(0, a, max_iters=MAX_ITERS)
    pb = qplan.rpq(0, b, max_iters=MAX_ITERS)
    assert pa.family_key() == pb.family_key()


def test_plan_json_round_trip():
    nfa = df.NFA.concat_star(1, 2)
    plans = [
        qplan.sssp(3, max_iters=24, drop=dr.DropConfig(mode="det", p=0.4)),
        qplan.khop(1, k=4),
        qplan.wcc(max_iters=32),
        qplan.pagerank(iters=6, alpha=0.9),
        qplan.rpq(2, nfa, max_iters=24, join_store="materialize"),
        qplan.rpq(2, nfa, max_iters=24, join_store="drop"),
        qplan.sssp(0).with_aggregate("histogram", bins=4),
    ]
    for p in plans:
        blob = json.dumps(p.to_json())  # must be JSON-serializable
        p2 = qplan.QueryPlan.from_json(json.loads(blob))
        assert p2.kind == p.kind
        assert p2.family_key() == p.family_key()
        assert p2.to_json() == p.to_json()
        assert p2.join_policy() == p.join_policy()
        assert p2.drop == p.drop
        assert (p2.aggregate is None) == (p.aggregate is None)


def test_compatibility_constructor_and_graph_sync_guard():
    legacy = qplan.QueryPlan(
        kind="sssp",
        semiring=qplan.sr.min_plus(),
        init=df.InitSpec(kind="source", source=0),
        max_iters=MAX_ITERS,
    )
    built = qplan.sssp(0, max_iters=MAX_ITERS)
    assert legacy.family_key() == built.family_key()
    assert [n.kind for n in legacy.ops] == ["ingest", "iterate"]
    # pagerank's canonical graph routes through a Transform node
    assert [n.kind for n in qplan.pagerank().ops] == [
        "ingest",
        "transform",
        "iterate",
    ]
    # a bare replace would silently lose against the graph: rejected
    with pytest.raises(ValueError, match="with_op_drop"):
        dataclasses.replace(built, drop=dr.DropConfig(mode="det", p=0.5))
    p2 = built.with_op_drop("iterate", dr.DropConfig(mode="det", p=0.5))
    assert p2.drop.p == 0.5 and p2.node("iterate").drop.p == 0.5
    with pytest.raises(ValueError, match="owns no difference store"):
        built.with_op_drop("ingest", dr.DropConfig(mode="det", p=1.0))


# -------------------------------------------------- per-operator dropping
def test_join_only_dropping_finer_than_whole_query():
    """The acceptance inequality: on an RPQ with a materialized join, drop
    the Join's differences ALONE (keep the Iterate's) and hold fewer bytes
    than whole-query dropping — at equal (exact) answers."""
    initial, batches = labelled_workload(seed=5)
    nfa = qplan.NFA.concat_star(1, 2)

    def run(join_store, drop=None, **kw):
        s = CQPSession(DynamicGraph(V, initial, capacity=256), engine="dense", **kw)
        hs = s.register_many(
            [
                qplan.rpq(q, nfa, max_iters=MAX_ITERS, drop=drop, join_store=join_store)
                for q in (0, 5)
            ]
        )
        for b in batches:
            s.apply_updates(b)
        return s, hs

    ref = CQPSession(DynamicGraph(V, initial, capacity=256), engine="host")
    rh = ref.register_many(
        [qplan.rpq(q, nfa, max_iters=MAX_ITERS) for q in (0, 5)]
    )
    for b in batches:
        ref.apply_updates(b)

    whole, hw = run(
        "materialize",
        drop=dr.DropConfig(mode="det", selection="random", p=0.5, seed=7),
    )
    op_only, ho = run("drop")

    for s, hs in ((whole, hw), (op_only, ho)):
        for h, r in zip(hs, rh):
            np.testing.assert_array_equal(s.reachable(h), ref.reachable(r))
            np.testing.assert_array_equal(s.answers(h), ref.answers(r))
    assert op_only.nbytes() < whole.nbytes(), (
        op_only.nbytes(),
        whole.nbytes(),
    )
    # the refinement is visible per operator: whole-query kept the join
    # trace (it cannot partial-drop), operator dropping zeroed it
    per_w = whole.nbytes_per_operator()
    per_o = op_only.nbytes_per_operator()
    assert sum(ops["join"] for ops in per_w) > 0
    assert all(ops["join"] == 0 for ops in per_o)


@pytest.mark.parametrize("engine", ENGINES)
def test_nbytes_per_operator_sums_to_per_query(engine):
    initial, batches = labelled_workload(seed=9)
    plain = [(u, w, x) for (u, w, x, _l) in initial]
    s = CQPSession(DynamicGraph(V, plain, capacity=256), engine=engine)
    s.register_many([qplan.sssp(i, max_iters=MAX_ITERS) for i in range(3)])
    s.apply_updates([(u, w, 0, x, sg) for (u, w, _l, x, sg) in batches[0]])
    per_q = s.nbytes_per_query()
    per_op = s.nbytes_per_operator()
    assert len(per_q) == len(per_op) == 3
    for q_bytes, ops in zip(per_q, per_op):
        assert sum(ops.values()) == q_bytes
        assert "iterate" in ops
    assert sum(per_q) == s.nbytes()


def test_set_drop_policy_join_roundtrip_stays_exact():
    """Dropping the join mid-stream frees its bytes; re-materializing
    rebuilds the trace; answers stay exact throughout (vs a never-dropped
    twin and the host engine)."""
    initial, batches = labelled_workload(seed=11)
    nfa = qplan.NFA.star(1)

    def make():
        s = CQPSession(DynamicGraph(V, initial, capacity=256), engine="dense")
        h = s.register(
            qplan.rpq(0, nfa, max_iters=MAX_ITERS, join_store="materialize")
        )
        return s, h

    a, ha = make()
    b, hb = make()
    ref = CQPSession(DynamicGraph(V, initial, capacity=256), engine="host")
    rh = ref.register(qplan.rpq(0, nfa, max_iters=MAX_ITERS))

    a.apply_updates(batches[0])
    b.apply_updates(batches[0])
    ref.apply_updates(batches[0])
    before = a.nbytes_per_operator()[0]
    assert before["join"] > 0
    freed = a.set_drop_policy(ha, dr.DropConfig(mode="det", p=1.0), op="join")
    assert freed == before["join"]
    assert a.nbytes_per_operator()[0]["join"] == 0
    assert a.handles()[0].plan.join_policy() == "drop"
    # maintained through further updates in the dropped state
    a.apply_updates(batches[1])
    b.apply_updates(batches[1])
    ref.apply_updates(batches[1])
    np.testing.assert_array_equal(a.answers(ha), b.answers(hb))
    np.testing.assert_array_equal(a.reachable(ha), ref.reachable(rh))
    # re-materialize: join bytes regrow, answers unchanged
    assert a.set_drop_policy(ha, dr.DropConfig(), op="join") == 0
    assert a.nbytes_per_operator()[0]["join"] > 0
    a.apply_updates(batches[2])
    b.apply_updates(batches[2])
    ref.apply_updates(batches[2])
    np.testing.assert_array_equal(a.answers(ha), b.answers(hb))
    np.testing.assert_array_equal(a.reachable(ha), ref.reachable(rh))
    # partial join dropping is rejected end-to-end
    with pytest.raises(ValueError, match="completely|unsupported"):
        a.set_drop_policy(ha, dr.DropConfig(mode="det", p=0.5), op="join")


def test_vdc_with_iterate_dropping_stays_exact():
    """The operator IR decouples the join store from §5 dropping: a VDC
    engine (materialized join) now composes with iterate-partial dropping —
    answers stay exact against the host engine."""
    initial, batches = labelled_workload(seed=13)
    plain = [(u, w, x) for (u, w, x, _l) in initial]
    plog = [
        [(u, w, 0, x, sg) for (u, w, _l, x, sg) in b] for b in batches
    ]
    s = CQPSession(
        DynamicGraph(V, plain, capacity=256),
        engine="dense",
        mode="vdc",
        drop=dr.DropConfig(mode="det"),
    )
    hs = s.register_many(
        [
            qplan.sssp(
                0,
                max_iters=MAX_ITERS,
                drop=dr.DropConfig(mode="det", selection="random", p=0.5, seed=3),
            ),
            qplan.sssp(5, max_iters=MAX_ITERS),
        ]
    )
    ref = CQPSession(DynamicGraph(V, plain, capacity=256), engine="host")
    rh = ref.register_many(
        [qplan.sssp(0, max_iters=MAX_ITERS), qplan.sssp(5, max_iters=MAX_ITERS)]
    )
    for b in plog:
        s.apply_updates(b)
        ref.apply_updates(b)
        for h, r in zip(hs, rh):
            np.testing.assert_array_equal(s.answers(h), ref.answers(r))
    # the dropping query stores fewer iterate bytes; both hold join bytes
    per = s.nbytes_per_operator()
    assert per[0]["iterate"] < per[1]["iterate"]
    assert per[0]["join"] > 0 and per[1]["join"] > 0


@needs8
def test_join_dropping_sharded_answers_parity():
    """Join-only dropping under the 8-shard mesh stays answer-identical to
    the unsharded session across drops and re-materializations."""
    initial, batches = labelled_workload(seed=15)
    nfa = qplan.NFA.concat_star(1, 2)

    def make(shards):
        mesh = make_data_mesh(shards) if shards > 1 else None
        s = CQPSession(
            DynamicGraph(V, initial, capacity=256), engine="dense", mesh=mesh
        )
        hs = s.register_many(
            [
                qplan.rpq(q, nfa, max_iters=MAX_ITERS, join_store="materialize")
                for q in (0, 5)
            ]
        )
        return s, hs

    a, ha = make(1)
    b, hb = make(8)

    def check():
        for x, y in zip(ha, hb):
            np.testing.assert_array_equal(a.answers(x), b.answers(y))

    check()
    for j, batch in enumerate(batches):
        a.apply_updates(batch)
        b.apply_updates(batch)
        check()
        if j == 1:
            # each session frees exactly its own slot's join bytes (the
            # sharded edge-cell layout may store a slightly different J
            # change-point set, so cross-shard byte equality is not claimed
            # — answers are)
            fa = a.set_drop_policy(ha[0], dr.DropConfig(mode="det", p=1.0), op="join")
            fb = b.set_drop_policy(hb[0], dr.DropConfig(mode="det", p=1.0), op="join")
            assert fa >= 0 and fb >= 0
            assert a.nbytes_per_operator()[0]["join"] == 0
            assert b.nbytes_per_operator()[0]["join"] == 0
            check()
        if j == 2:
            a.set_drop_policy(ha[0], dr.DropConfig(), op="join")
            b.set_drop_policy(hb[0], dr.DropConfig(), op="join")
            check()


def test_aggregate_rpq_reduces_over_accepting_states_only():
    """An RPQ aggregate must report MATCHES: product entries at
    non-accepting states (e.g. the source's start-state init) are partial
    paths, not answers."""
    initial, batches = labelled_workload(seed=21)
    nfa = qplan.NFA.concat_star(1, 2)  # accept state 1 only
    s = CQPSession(DynamicGraph(V, initial, capacity=256), engine="dense")
    h = s.register(
        qplan.rpq(0, nfa, max_iters=MAX_ITERS).with_aggregate("topk", k=V)
    )
    s.apply_updates(batches[0])
    reach = s.reachable(h)
    top = s.aggregate(h)
    assert set(top["vertices"]) == set(np.nonzero(reach)[0])
    hist = s.aggregate(
        s.register(
            qplan.rpq(0, nfa, max_iters=MAX_ITERS).with_aggregate(
                "histogram", bins=4
            )
        )
    )
    assert hist["unreachable"] == int((~reach).sum())
    assert sum(hist["counts"]) == int(reach.sum())


# ------------------------------------------------------- governor attribution
def test_governor_attributes_actions_per_operator():
    """Under a budget, an RPQ session with materialized joins escalates at
    (query, operator) granularity — the action log names the operator, the
    join trace is reclaimed, and answers stay exact."""
    initial, batches = labelled_workload(seed=17, e=64, nbatches=5)
    nfa = qplan.NFA.concat_star(1, 2)
    plans = [
        qplan.rpq(q, nfa, max_iters=MAX_ITERS, join_store="materialize")
        for q in (0, 5)
    ]

    plain = CQPSession(DynamicGraph(V, initial, capacity=256), engine="dense")
    hp = plain.register_many(plans)
    for b in batches:
        plain.apply_updates(b)
    peak = plain.nbytes()
    join_bytes = sum(ops["join"] for ops in plain.nbytes_per_operator())
    assert join_bytes > 0

    budget = max(peak - join_bytes // 2, 64)  # reclaimable by join drops alone
    s = CQPSession(
        DynamicGraph(V, initial, capacity=256),
        engine="dense",
        budget_bytes=budget,
        governor=GovernorConfig(representation="prob", bloom_bits=1 << 7),
    )
    hs = s.register_many(plans)
    for b in batches:
        s.apply_updates(b)
    assert s.nbytes() <= budget
    gov = s.stats()["governor"]
    assert any(a["op"] == "join" and a["kind"] == "escalate" for a in gov["actions"])
    assert any(lvl > 0 for key, lvl in gov["op_levels"].items() if key.endswith("/join"))
    json.dumps(gov)  # snapshot stays JSON-serializable with op keys
    for h, p in zip(hs, hp):
        np.testing.assert_array_equal(s.answers(h), plain.answers(p))


# ------------------------------------------------------------------- serving
def test_cqp_serve_plan_file_subprocess(tmp_path):
    """cqp_serve --plan-file: operator-graph plans load from JSON and the
    report carries the per-(query, operator) byte breakdown."""
    nfa = qplan.NFA.star(0)  # the synthetic stream carries label 0
    plans = [
        qplan.rpq(s, nfa, max_iters=12, join_store="materialize").to_json()
        for s in (0, 3)
    ]
    plan_file = tmp_path / "plans.json"
    plan_file.write_text(json.dumps({"plans": plans}))
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.cqp_serve",
            "--smoke",
            "--json",
            "--backend",
            "coo",
            "--plan-file",
            str(plan_file),
        ],
        env=env,
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["queries"] == 2
    per_op = payload["nbytes_per_operator"]
    assert len(per_op) == payload["final_queries"]
    assert all("join" in ops and "iterate" in ops for ops in per_op)
    assert sum(sum(ops.values()) for ops in per_op) == sum(
        payload["nbytes_per_query"]
    )


def test_core_deprecation_shims_removed():
    """PR-3's repro.core shims are gone: the home modules are canonical."""
    import repro.core as core

    for name in ("SparseDiffIFE", "Scratch", "RPQ"):
        with pytest.raises(AttributeError):
            getattr(core, name)
    # the home modules keep working
    from repro.core.queries import RPQ  # noqa: F401
    from repro.core.scratch import Scratch, ScratchEngine  # noqa: F401
    from repro.core.sparse_engine import SparseDiffIFE  # noqa: F401
