"""Cross-engine parity matrix for the vertex-sharded maintenance sweep.

One parameterized matrix sweeps ``backend × mode × drop.mode × shards``
(valid combos only: dropping composes with JOD, the ELL kernel realizes JOD)
and asserts bit-identical answers against the host ``SparseDiffIFE`` pointer
engine and SCRATCH on a random insert+delete stream.  This also closes two
pre-existing gaps: dropping × ELL and dropping × batched had no direct
coverage.

The ``shards=8`` column runs when 8 devices are visible — CI provides them
via ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — and a
subprocess smoke keeps the sharded path exercised in every plain test run.
A Hypothesis property test checks the sharded batched path (including the
ELL width-overflow re-trace, per-shard cell-overflow regrow, and diff-row
eviction paths) against unsharded sequential per-update maintenance.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import dropping as dr
from repro.core import plan as qplan
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.scratch import scratch_like
from repro.core.session import CQPSession
from repro.core.sparse_engine import SparseDiffIFE
from repro.launch.mesh import make_data_mesh

V = 24
MAX_ITERS = 24
NDEV = jax.device_count()

needs8 = pytest.mark.skipif(
    NDEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def random_workload(seed: int, v: int = V, e: int = 96, num_batches: int = 4):
    """(initial edges, update batches) with insertion + deletion mixes."""
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < e:
        u, w = int(rng.integers(0, v)), int(rng.integers(0, v))
        if u != w:
            seen[(u, w)] = (u, w, float(rng.integers(1, 10)))
    edges = list(seen.values())
    initial, pool = edges[: e * 3 // 4], edges[e * 3 // 4 :]
    present = {(u, w) for (u, w, _x) in initial}
    batches = []
    for _ in range(num_batches):
        batch = []
        for _ in range(int(rng.integers(2, 5))):
            if present and rng.random() < 0.4:
                u, w = sorted(present)[int(rng.integers(0, len(present)))]
                batch.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            elif pool:
                u, w, x = pool.pop()
                batch.append((u, w, 0, x, +1))
                present.add((u, w))
        batches.append(batch)
    return initial, batches


DROPS = {
    "none": None,
    "det": dr.DropConfig(mode="det", selection="random", p=0.4, seed=7),
    "prob": dr.DropConfig(
        mode="prob", selection="random", p=0.4, seed=7, bloom_bits=1 << 12
    ),
}

# valid combos only: dropping needs JOD; the ELL kernel realizes JOD
MATRIX = [
    (backend, mode, dropmode)
    for backend in ("coo", "ell")
    for mode in ("jod", "vdc")
    for dropmode in ("none", "det", "prob")
    if not (mode == "vdc" and (dropmode != "none" or backend == "ell"))
]


def _make_engine(initial, backend, mode, dropmode, shards):
    mesh = make_data_mesh(shards) if shards > 1 else None
    kw = dict(mode=mode)
    if DROPS[dropmode] is not None:
        kw["drop"] = DROPS[dropmode]
    return q.sssp(
        DynamicGraph(V, initial, capacity=512),
        [0, V // 2],
        max_iters=MAX_ITERS,
        backend=backend,
        mesh=mesh,
        **kw,
    )


@pytest.mark.parametrize("shards", [1, pytest.param(8, marks=needs8)])
@pytest.mark.parametrize(
    "backend,mode,dropmode", MATRIX, ids=lambda m: str(m)
)
def test_parity_matrix(backend, mode, dropmode, shards):
    initial, batches = random_workload(seed=11)
    eng = _make_engine(initial, backend, mode, dropmode, shards)
    sparse = SparseDiffIFE(
        DynamicGraph(V, initial, capacity=512), [0, V // 2], max_iters=MAX_ITERS
    )
    scratch = scratch_like(
        eng.cfg, DynamicGraph(V, initial, capacity=512), eng.state.init
    )
    np.testing.assert_array_equal(eng.answers(), sparse.answers())
    np.testing.assert_array_equal(eng.answers(), scratch.answers())
    for batch in batches:
        eng.apply_updates(batch)
        sparse.apply_updates(batch)
        scratch.apply_updates(batch)
        np.testing.assert_array_equal(eng.answers(), sparse.answers())
        np.testing.assert_array_equal(eng.answers(), scratch.answers())


@pytest.mark.parametrize("shards", [1, pytest.param(8, marks=needs8)])
@pytest.mark.parametrize("engine", ["host", "scratch"])
@pytest.mark.parametrize(
    "backend,mode,dropmode", MATRIX, ids=lambda m: str(m)
)
def test_session_churn_engine_matrix(backend, mode, dropmode, shards, engine):
    """The parity matrix extended by an ENGINE axis, through the session
    facade and with query churn: a dense CQPSession in every (backend, mode,
    drop, shards) configuration must stay answer-identical to a host/scratch
    CQPSession across a stream that registers a query mid-stream and
    deregisters another (the dense engine initializes the new trace by
    in-engine recomputation; deregistration reclaims its diff rows)."""
    initial, batches = random_workload(seed=17, num_batches=3)
    drop = DROPS[dropmode]
    mesh = make_data_mesh(shards) if shards > 1 else None
    dense = CQPSession(
        DynamicGraph(V, initial, capacity=512),
        engine="dense",
        backend=backend,
        mode=mode,
        mesh=mesh,
        min_slots=2,
    )
    ref = CQPSession(DynamicGraph(V, initial, capacity=512), engine=engine)

    def dense_plan(src):
        return qplan.sssp(src, max_iters=MAX_ITERS, drop=drop)

    dh = dense.register_many([dense_plan(0), dense_plan(V // 2)])
    rh = ref.register_many(
        [
            qplan.sssp(0, max_iters=MAX_ITERS),
            qplan.sssp(V // 2, max_iters=MAX_ITERS),
        ]
    )

    def check():
        for a, b in zip(dh, rh):
            np.testing.assert_array_equal(dense.answers(a), ref.answers(b))

    check()
    for j, batch in enumerate(batches):
        dense.apply_updates(batch)
        ref.apply_updates(batch)
        check()
        if j == 0:  # mid-stream register (same family, new source)
            dh.append(dense.register(dense_plan(V // 3)))
            rh.append(ref.register(qplan.sssp(V // 3, max_iters=MAX_ITERS)))
            check()
        if j == 1:  # mid-stream deregister (oldest query retires)
            before = dense.nbytes()
            freed = dense.deregister(dh.pop(0))
            ref.deregister(rh.pop(0))
            assert freed >= 0 and dense.nbytes() <= before
            check()


@pytest.mark.parametrize("shards", [1, pytest.param(8, marks=needs8)])
@pytest.mark.parametrize("dropmode", ["det", "prob"])
def test_params_rewrite_midstream_parity(dropmode, shards):
    """Governor primitive through the session: rewriting a LIVE query's
    DropParams row mid-stream (escalate → shed, later de-escalate) must keep
    the sharded dense engine bit-identical to the unsharded one — the shed
    audit uses the stateless (seed, q, v, i) coin, so drop sets cannot
    depend on the mesh — and answers exactly equal to the host engine."""
    initial, batches = random_workload(seed=23, num_batches=3)
    drop_repr = DROPS[dropmode]
    escalate = dr.DropConfig(
        mode=dropmode, selection="degree", p=0.8, tau_min=6.0, seed=7,
        bloom_bits=1 << 12,
    )
    deescalate = dr.DropConfig(
        mode=dropmode, selection="random", p=0.2, seed=7, bloom_bits=1 << 12
    )

    def make(shards_):
        mesh = make_data_mesh(shards_) if shards_ > 1 else None
        s = CQPSession(
            DynamicGraph(V, initial, capacity=512),
            engine="dense",
            mesh=mesh,
            drop=drop_repr,
            min_slots=2,
        )
        hs = s.register_many(
            [
                qplan.sssp(0, max_iters=MAX_ITERS, drop=drop_repr),
                qplan.sssp(V // 2, max_iters=MAX_ITERS),
            ]
        )
        return s, hs

    a, ha = make(1)
    b, hb = make(shards)
    ref = CQPSession(DynamicGraph(V, initial, capacity=512), engine="host")
    rh = ref.register_many(
        [qplan.sssp(0, max_iters=MAX_ITERS), qplan.sssp(V // 2, max_iters=MAX_ITERS)]
    )

    def check():
        for x, y, r in zip(ha, hb, rh):
            np.testing.assert_array_equal(a.answers(x), b.answers(y))
            np.testing.assert_array_equal(a.answers(x), ref.answers(r))
        assert a.nbytes() == b.nbytes(), (a.nbytes(), b.nbytes())

    check()
    for j, batch in enumerate(batches):
        for s in (a, b):
            s.apply_updates(batch)
        ref.apply_updates(batch)
        check()
        if j == 0:  # escalate query 0 mid-stream: both sessions shed alike
            fa = a.set_drop_policy(ha[0], escalate)
            fb = b.set_drop_policy(hb[0], escalate)
            assert fa == fb >= 0, (fa, fb)
            check()
        if j == 1:  # de-escalate: survivors have audited coins — no reshed
            assert a.set_drop_policy(ha[0], deescalate) == b.set_drop_policy(
                hb[0], deescalate
            )
            check()


@pytest.mark.parametrize("dropmode", ["det", "prob"])
@pytest.mark.parametrize("backend", ["coo", "ell"])
def test_batched_dropping_parity(backend, dropmode):
    """Dropping × batched: the donated-buffer chunked stream must equal the
    per-update host path under both DroppedVT representations."""
    initial, batches = random_workload(seed=13)
    log = [u for b in batches for u in b]
    seq = _make_engine(initial, backend, "jod", dropmode, shards=1)
    bat = _make_engine(initial, backend, "jod", dropmode, shards=1)
    for u in log:
        seq.apply_updates([u])
    bat.apply_updates_batched(log, batch_size=4)
    np.testing.assert_array_equal(seq.answers(), bat.answers())


@needs8
@pytest.mark.parametrize("backend", ["coo", "ell"])
def test_sharded_batched_equals_unsharded_sequential_stream(backend):
    """Sharded batched ingestion == unsharded per-update ingestion, on a
    stream crafted to hit the growth paths: a hub vertex outruns both the
    fixed ELL width (re-trace) and its owner's shard cells (regrow)."""
    v = 16
    initial = [(i, (i + 1) % v, float(1 + i % 3)) for i in range(v)]
    hub = [(i, 3, 0, 1.0, +1) for i in range(v) if i != 3]  # in-degree 15
    rng = np.random.default_rng(3)
    mixed = [(int(rng.integers(0, v)), 7, 0, 2.0, +1) for _ in range(4)] + [
        (1, 2, 0, 1.0, -1),
        (3, 4, 0, 1.0, -1),
    ]
    log = hub + mixed
    kw = dict(
        max_iters=16,
        backend=backend,
        store_capacity=3,  # force diff-row evictions through the registry
        drop=dr.DropConfig(mode="det", selection="random", p=0.0),
    )
    seq = q.sssp(DynamicGraph(v, initial, capacity=64), [0, v // 2], **kw)
    bat = q.sssp(
        DynamicGraph(v, initial, capacity=64),
        [0, v // 2],
        mesh=make_data_mesh(8),
        **kw,
    )
    for u in log:
        seq.apply_updates([u])
    bat.apply_updates_batched(log, batch_size=4)
    np.testing.assert_array_equal(seq.answers(), bat.answers())


@needs8
def test_sharded_property_stream():
    """Hypothesis: sharded batched == unsharded sequential for arbitrary
    insert/delete streams."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    v = 16

    @st.composite
    def stream(draw):
        n_init = draw(st.integers(4, 20))
        mk = st.tuples(
            st.integers(0, v - 1), st.integers(0, v - 1), st.integers(1, 9)
        )
        edges = [
            (u, w, float(x))
            for (u, w, x) in draw(st.lists(mk, min_size=n_init, max_size=n_init))
            if u != w
        ]
        edges = list({(u, w): (u, w, x) for (u, w, x) in edges}.values())
        present = {(u, w) for (u, w, _x) in edges}
        ops = []
        for _ in range(draw(st.integers(1, 10))):
            if present and draw(st.booleans()):
                u, w = draw(st.sampled_from(sorted(present)))
                ops.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            else:
                u, w = draw(st.integers(0, v - 1)), draw(st.integers(0, v - 1))
                if u == w:
                    continue
                ops.append((u, w, 0, float(draw(st.integers(1, 9))), +1))
                present.add((u, w))
        return edges, ops

    @settings(max_examples=8, deadline=None)
    @given(wl=stream())
    def run(wl):
        edges, ops = wl
        seq = q.sssp(
            DynamicGraph(v, edges, capacity=96), [0, v // 2], max_iters=16
        )
        bat = q.sssp(
            DynamicGraph(v, edges, capacity=96),
            [0, v // 2],
            max_iters=16,
            mesh=make_data_mesh(8),
        )
        for u in ops:
            seq.apply_updates([u])
        bat.apply_updates_batched(ops, batch_size=4)
        np.testing.assert_array_equal(seq.answers(), bat.answers())

    run()


@needs8
def test_sharded_pagerank_and_wcc():
    """Non-SSSP query classes on the data mesh: WCC (min-label) stays
    bit-identical; PageRank's sum reductions reassociate across the sharded
    edge layout, so it carries float tolerance instead."""
    rng = np.random.default_rng(2)
    v = 16
    seen = {}
    while len(seen) < 48:
        u, w = int(rng.integers(0, v)), int(rng.integers(0, v))
        if u != w:
            seen[(u, w)] = (u, w, 1.0)
    edges = list(seen.values())
    log = [
        (int(rng.integers(0, v)), int(rng.integers(0, v)), 0, 1.0, s)
        for s in (+1, +1, -1, +1)
        for _ in range(2)
    ]
    log = [op for op in log if op[0] != op[1]]
    mesh = make_data_mesh(8)

    a = q.pagerank(DynamicGraph(v, edges, capacity=128), iters=8)
    b = q.pagerank(
        DynamicGraph(v, edges, capacity=128), iters=8, backend="ell", mesh=mesh
    )
    np.testing.assert_allclose(a.answers(), b.answers(), rtol=1e-6)
    a.apply_updates_batched(log, batch_size=4)
    b.apply_updates_batched(log, batch_size=4)
    np.testing.assert_allclose(a.answers(), b.answers(), rtol=1e-6)

    sym = [(u, w, 1.0) for (u, w, _x) in edges] + [
        (w, u, 1.0) for (u, w, _x) in edges
    ]
    c = q.wcc(DynamicGraph(v, sym, capacity=256), max_iters=16)
    d = q.wcc(DynamicGraph(v, sym, capacity=256), max_iters=16, mesh=mesh)
    np.testing.assert_array_equal(c.answers(), d.answers())


_SMOKE = textwrap.dedent(
    """
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import queries as q
    from repro.core.graph import DynamicGraph
    assert jax.device_count() == 8, jax.devices()
    mesh = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
    v = 16
    edges = [(i, (i + 1) % v, float(1 + i % 3)) for i in range(v)]
    edges += [(i, (i + 5) % v, 2.0) for i in range(0, v, 2)]
    a = q.sssp(DynamicGraph(v, edges, capacity=96), [0, 5], max_iters=16)
    b = q.sssp(DynamicGraph(v, edges, capacity=96), [0, 5], max_iters=16,
               mesh=mesh)
    np.testing.assert_array_equal(a.answers(), b.answers())
    log = [(2, 9, 0, 1.0, +1), (0, 1, 0, 1.0, -1), (4, 0, 0, 3.0, +1),
           (6, 7, 0, 1.0, -1)]
    a.apply_updates_batched(log, batch_size=2)
    b.apply_updates_batched(log, batch_size=2)
    np.testing.assert_array_equal(a.answers(), b.answers())
    assert sum(b.nbytes_per_device()) == b.nbytes() == a.nbytes()
    print("SHARDED-SMOKE-OK")
    """
)


def test_sharded_parity_subprocess_smoke():
    """Always-on sharded coverage: re-exec under 8 emulated host devices so
    plain single-device test runs still drive the shard_map sweep."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [sys.executable, "-c", _SMOKE],
        env=env,
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARDED-SMOKE-OK" in out.stdout
