"""Crash-safety: checkpoint → kill → restore → replay ≡ uninterrupted run.

The durability contract (DESIGN.md §12): a `CQPSession` checkpoint plus a
deterministic replay of the update-log suffix reproduces the answers of a
run that never crashed, bit for bit — across engines, drop policies, and
shard counts (including restoring an 8-shard checkpoint onto a smaller
mesh).  The "crash" is real in spirit: the post-checkpoint session object
is mutated further and then discarded, so the restored session can only
succeed from what hit the disk.

A subprocess test SIGKILLs `cqp_serve` mid-run and asserts the atomic-
rename invariant: every non-`.tmp` `step_*` directory on disk is complete
and loadable, no matter where the kill landed.
"""

from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.core import dropping as dr
from repro.core import plan as qplan
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession
from repro.launch.mesh import make_data_mesh

V = 16
MAX_ITERS = 16
NDEV = jax.device_count()

needs8 = pytest.mark.skipif(
    NDEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def workload(seed: int = 5, label: int = 0, steps: int = 12):
    """(initial edges, update log) over one edge label."""
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < 40:
        u, w = int(rng.integers(0, V)), int(rng.integers(0, V))
        if u != w:
            seen[(u, w)] = (u, w, float(rng.integers(1, 9)), label)
    edges = list(seen.values())
    initial, pool = edges[:30], edges[30:]
    present = {(u, w) for (u, w, _x, _l) in initial}
    log = []
    for _ in range(steps):
        if present and rng.random() < 0.35:
            u, w = sorted(present)[int(rng.integers(0, len(present)))]
            log.append((u, w, label, 1.0, -1))
            present.discard((u, w))
        elif pool:
            u, w, x, lbl = pool.pop()
            log.append((u, w, lbl, x, +1))
            present.add((u, w))
    return initial, log


def labeled_workload(seed: int = 9):
    """Cycle over labels {1, 2} plus a mixed-label update log (for RPQ)."""
    initial = [(i, (i + 1) % V, 1.0, 1 + (i % 2)) for i in range(V)]
    rng = np.random.default_rng(seed)
    log = []
    for t in range(10):
        u, w = int(rng.integers(0, V)), int(rng.integers(0, V))
        if u == w:
            continue
        log.append((u, w, 1 + (t % 2), 1.0, +1))
    log.append((0, 1, 1, 1.0, -1))  # delete a cycle edge mid-stream
    return initial, log


PROB = dr.DropConfig(
    mode="prob", selection="random", p=0.4, seed=7, bloom_bits=1 << 12
)


def _plans(policy):
    if policy == "join-drop":
        nfa = qplan.NFA.concat_star(1, 2)
        return [
            qplan.rpq(0, nfa, max_iters=MAX_ITERS, join_store="materialize"),
            qplan.rpq(4, nfa, max_iters=MAX_ITERS, join_store="drop"),
        ]
    drop = PROB if policy == "prob" else None
    return [
        qplan.sssp(0, max_iters=MAX_ITERS, drop=drop),
        qplan.sssp(7, max_iters=MAX_ITERS),
    ]


def _workload(policy):
    return labeled_workload() if policy == "join-drop" else workload()


def _session(initial, engine, shards, **kw):
    mesh = make_data_mesh(shards) if shards > 1 else None
    graph = DynamicGraph(V, initial, capacity=256)
    return CQPSession(graph, engine=engine, mesh=mesh, **kw)


# the full ISSUE matrix with invalid combos pruned: the sharded sweep and
# NFA-product joins are dense-only; the scratch baseline stores no trace,
# so its drop axis is vacuous
CELLS = [
    pytest.param(
        engine,
        shards,
        policy,
        id=f"{engine}-{shards}shard-{policy}",
        marks=(needs8,) if shards == 8 else (),
    )
    for engine in ("dense", "host", "scratch")
    for shards in (1, 8)
    for policy in ("none", "prob", "join-drop")
    if not (engine != "dense" and (shards == 8 or policy == "join-drop"))
    if not (engine == "scratch" and policy != "none")
]


@pytest.mark.parametrize("engine,shards,policy", CELLS)
def test_checkpoint_restore_replay_parity(engine, shards, policy, tmp_path):
    """checkpoint → crash → restore → replay suffix == uninterrupted run."""
    initial, log = _workload(policy)
    plans = _plans(policy)
    cut = len(log) // 2
    mesh = make_data_mesh(shards) if shards == 8 else None

    ref = _session(initial, engine, shards)
    rh = ref.register_many(plans)
    ref.apply_updates(log)

    s = _session(initial, engine, shards)
    sh = s.register_many(plans)
    s.apply_updates(log[:cut])
    s.checkpoint(str(tmp_path))
    # post-checkpoint progress that the crash destroys: the restored
    # session must not see any of it
    s.apply_updates(log[cut:])
    crashed = [np.asarray(s.answers(h)) for h in sh]
    del s

    r = CQPSession.restore(str(tmp_path), mesh=mesh)
    assert r.restore_info["step"] == cut or r.restore_info["step"] >= 0
    rhandles = r.handles()
    assert [h.qid for h in rhandles] == [h.qid for h in sh]
    r.apply_updates(log[cut:])

    for h_ref, h_r, crash in zip(rh, rhandles, crashed):
        want = np.asarray(ref.answers(h_ref))
        np.testing.assert_array_equal(np.asarray(r.answers(h_r)), want)
        np.testing.assert_array_equal(crash, want)  # crashed run was right too
    assert r.nbytes() == ref.nbytes()
    assert r.nbytes_per_operator() == ref.nbytes_per_operator()
    assert r.updates_applied == ref.updates_applied


@pytest.mark.parametrize("engine", ["dense", "host", "scratch"])
def test_churn_between_checkpoint_and_crash(engine, tmp_path):
    """register/deregister after the checkpoint are crash-lost session
    mutations; the replay re-issues them and still converges."""
    initial, log = workload()
    cut = len(log) // 2
    extra = qplan.sssp(3, max_iters=MAX_ITERS)

    def churn_and_finish(sess, handles):
        handles = list(handles)
        handles.append(sess.register(extra))
        sess.deregister(handles.pop(0))  # retire the oldest query
        sess.apply_updates(log[cut:])
        return handles

    ref = _session(initial, engine, 1)
    rh = ref.register_many(_plans("none"))
    ref.apply_updates(log[:cut])
    rh = churn_and_finish(ref, rh)

    s = _session(initial, engine, 1)
    sh = s.register_many(_plans("none"))
    s.apply_updates(log[:cut])
    s.checkpoint(str(tmp_path))
    churn_and_finish(s, sh)  # lost in the crash
    del s

    r = CQPSession.restore(str(tmp_path))
    rhand = churn_and_finish(r, r.handles())
    assert [h.qid for h in rhand] == [h.qid for h in rh]
    for h_ref, h_r in zip(rh, rhand):
        np.testing.assert_array_equal(
            np.asarray(r.answers(h_r)), np.asarray(ref.answers(h_ref))
        )
    assert r.nbytes_per_operator() == ref.nbytes_per_operator()


@needs8
@pytest.mark.parametrize("restore_shards", [1, 4])
def test_checkpoint_at_8_restores_on_smaller_mesh(restore_shards, tmp_path):
    """Elastic restore: an 8-shard checkpoint lands on a 1- or 4-shard mesh
    with identical answers and per-shard bytes summing to the global."""
    initial, log = workload()
    cut = len(log) // 2
    plans = _plans("none")

    ref = _session(initial, "dense", 1)
    rh = ref.register_many(plans)
    ref.apply_updates(log)

    s = _session(initial, "dense", 8)
    s.register_many(plans)
    s.apply_updates(log[:cut])
    s.checkpoint(str(tmp_path))
    del s

    mesh = make_data_mesh(restore_shards) if restore_shards > 1 else None
    r = CQPSession.restore(str(tmp_path), mesh=mesh)
    assert r.num_shards == restore_shards
    r.apply_updates(log[cut:])
    for h_ref, h_r in zip(rh, r.handles()):
        np.testing.assert_array_equal(
            np.asarray(r.answers(h_r)), np.asarray(ref.answers(h_ref))
        )
    per_dev = r.nbytes_per_device()
    assert len(per_dev) == restore_shards
    assert sum(per_dev) == r.nbytes() == ref.nbytes()


def test_governor_escalations_survive_checkpoint(tmp_path):
    """A budget-governed session checkpoints mid-escalation: the restored
    governor continues from the saved levels/EWMAs and lands on the same
    levels, bytes, and answers as the uninterrupted run."""
    edges = [(i, (i + 1) % V, 1.0) for i in range(V)]
    log = [
        ((3 * k) % V, (5 * k + 1) % V, 0, 1.0, +1)
        for k in range(10)
        if (3 * k) % V != (5 * k + 1) % V
    ]

    def build(budget):
        s = CQPSession(
            DynamicGraph(V, edges, capacity=128),
            engine="dense",
            budget_bytes=budget,
        )
        s.register_many([qplan.sssp(i, max_iters=16) for i in range(3)])
        return s

    probe = build(10**9)
    for u in log[:5]:
        probe.apply_updates([u])
    budget = int(probe.nbytes() * 0.6)  # force escalations before the cut

    ref = build(budget)
    for u in log:
        ref.apply_updates([u])

    s = build(budget)
    for u in log[:5]:
        s.apply_updates([u])
    assert any(v > 0 for v in s.governor._levels.values())
    s.checkpoint(str(tmp_path))
    del s

    r = CQPSession.restore(str(tmp_path))
    for u in log[5:]:
        r.apply_updates([u])
    for h_ref, h_r in zip(ref.handles(), r.handles()):
        np.testing.assert_array_equal(
            np.asarray(r.answers(h_r)), np.asarray(ref.answers(h_ref))
        )
    assert r.nbytes() == ref.nbytes()
    assert r.governor._levels == ref.governor._levels
    assert len(r.governor.actions) == len(ref.governor.actions)


def test_restore_validates_meta(tmp_path):
    """Foreign checkpoints (no session meta) are rejected with a clear error."""
    from repro.checkpoint import store

    store.save_checkpoint(str(tmp_path), 0, {"x": np.zeros(3)})
    with pytest.raises(ValueError, match="no session meta"):
        CQPSession.restore(str(tmp_path))


def test_property_checkpoint_roundtrip_random_streams(tmp_path):
    """Hypothesis: for random update streams and a random checkpoint point,
    restore(checkpoint(s)) + replay equals the uninterrupted run, and the
    per-operator byte accounting survives the round trip."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def stream(draw):
        mk = st.tuples(
            st.integers(0, V - 1), st.integers(0, V - 1), st.integers(1, 9)
        )
        edges = [
            (u, w, float(x))
            for (u, w, x) in draw(st.lists(mk, min_size=6, max_size=24))
            if u != w
        ]
        edges = list({(u, w): (u, w, x) for (u, w, x) in edges}.values())
        present = {(u, w) for (u, w, _x) in edges}
        ops = []
        for _ in range(draw(st.integers(2, 10))):
            if present and draw(st.booleans()):
                u, w = draw(st.sampled_from(sorted(present)))
                ops.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            else:
                u, w = draw(st.integers(0, V - 1)), draw(st.integers(0, V - 1))
                if u == w:
                    continue
                ops.append((u, w, 0, float(draw(st.integers(1, 9))), +1))
                present.add((u, w))
        cut = draw(st.integers(0, len(ops)))
        src = draw(st.integers(0, V - 1))
        return edges, ops, cut, src

    case = [0]

    @settings(max_examples=6, deadline=None)
    @given(wl=stream())
    def run(wl):
        edges, ops, cut, src = wl
        case[0] += 1
        for engine in ("dense", "host"):
            ref = CQPSession(
                DynamicGraph(V, edges, capacity=256), engine=engine
            )
            h_ref = ref.register(qplan.sssp(src, max_iters=MAX_ITERS))
            ref.apply_updates(ops)

            s = CQPSession(DynamicGraph(V, edges, capacity=256), engine=engine)
            s.register(qplan.sssp(src, max_iters=MAX_ITERS))
            s.apply_updates(ops[:cut])
            d = str(tmp_path / f"case{case[0]}-{engine}")
            s.checkpoint(d)
            del s

            r = CQPSession.restore(d)
            r.apply_updates(ops[cut:])
            (h_r,) = r.handles()
            np.testing.assert_array_equal(
                np.asarray(r.answers(h_r)), np.asarray(ref.answers(h_ref))
            )
            want = [sum(o.values()) for o in ref.nbytes_per_operator()]
            got = [sum(o.values()) for o in r.nbytes_per_operator()]
            assert got == want

    run()


# --------------------------------------------------------------- subprocess

SERVE = [sys.executable, "-m", "repro.launch.cqp_serve", "--smoke", "--json"]


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return env


def test_cqp_serve_fault_drill_subprocess(tmp_path):
    """`--inject-fault-at` restores the latest checkpoint, replays, and the
    final per-query bytes match a run that never faulted."""
    plain = subprocess.run(
        SERVE, env=_env(), capture_output=True, text=True, timeout=600
    )
    assert plain.returncode == 0, plain.stderr
    baseline = json.loads(plain.stdout.strip().splitlines()[-1])

    drill = subprocess.run(
        SERVE
        + [
            "--checkpoint-dir", str(tmp_path),
            "--checkpoint-every", "2",
            "--inject-fault-at", "3",
        ],
        env=_env(), capture_output=True, text=True, timeout=600,
    )
    assert drill.returncode == 0, drill.stderr
    out = json.loads(drill.stdout.strip().splitlines()[-1])
    rec = out["recovery"]
    assert rec["restarts"] == 1
    assert rec["replayed_chunks"] >= 0
    assert any(h.startswith("fault@3") for h in rec["history"])
    assert any(h.startswith("resume@") for h in rec["history"])
    assert rec["checkpoints"] >= 1 and rec["checkpoint_bytes"] > 0
    assert out["nbytes_per_query"] == baseline["nbytes_per_query"]
    assert out["runtime"]["fault"]["restarts"] == 1
    assert out["runtime"]["straggler"]["observed"] > 0


def test_cqp_serve_sigkill_leaves_only_complete_checkpoints(tmp_path):
    """SIGKILL mid-run: whatever landed in the checkpoint dir is either a
    `.tmp` staging dir (ignored, GCed later) or a fully loadable step —
    the atomic-rename invariant."""
    from repro.checkpoint import store

    proc = subprocess.Popen(
        SERVE
        + [
            "--updates", "4096", "--batch", "8",
            "--checkpoint-dir", str(tmp_path),
            "--checkpoint-every", "1",
        ],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE,
    )
    try:
        deadline = time.time() + 300
        while time.time() < deadline:
            if any(
                d.startswith("step_") and not d.endswith(".tmp")
                for d in os.listdir(tmp_path)
            ):
                break
            if proc.poll() is not None:
                pytest.fail(
                    "cqp_serve exited before its first checkpoint: "
                    + proc.stderr.read().decode()
                )
            time.sleep(0.01)
        else:
            pytest.fail("no checkpoint appeared before the deadline")
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)

    steps = sorted(
        d for d in os.listdir(tmp_path)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    assert steps, "at least one complete checkpoint must have survived"
    for d in steps:
        # completeness: manifest + every declared leaf present and typed
        arrays, manifest, step = store.load_checkpoint(
            str(tmp_path), int(d.split("_")[1])
        )
        assert set(arrays) == set(manifest["leaves"])
        for key, spec in manifest["leaves"].items():
            assert list(arrays[key].shape) == list(spec["shape"])
            assert str(arrays[key].dtype) == spec["dtype"]
    # and the latest one restores into a working session
    r = CQPSession.restore(str(tmp_path))
    assert r.restore_info["extra"]["next_chunk"] >= 1
    assert r.num_queries > 0
