"""Unified observability layer: tracer, metrics registry, DC probes.

DESIGN.md §15.  Covers:

* the span tracer — zero-allocation disabled path, bounded ring buffer,
  Chrome-trace export that passes the structural validator;
* the typed metrics registry — counters/gauges/histograms, label series,
  JSON snapshot, Prometheus text exposition;
* span coverage end to end — sweep/kernel-dispatch/update-batch spans from
  the engines, governor escalation spans, checkpoint spans;
* cross-engine ``MaintainStats`` parity — dense/host/scratch emit the same
  stat keys, zero-filled where a counter is structurally absent;
* Bloom probe math — the analytic FP estimate vs brute-force membership
  probing, and the FP-rate gauge rising monotonically as dropped diffs
  are inserted.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import bloom as bloom_lib
from repro.core import dropping as dr
from repro.core import plan as qplan
from repro.core.engine import ITER_TRACE, MaintainStats
from repro.core.graph import DynamicGraph
from repro.core.session import ENGINES, CQPSession
from repro.obs import metrics as obs_metrics
from repro.obs import probes
from repro.obs import trace as obs_trace

V = 16
MAX_ITERS = 16


def _workload(seed: int = 5):
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < 40:
        u, w = int(rng.integers(0, V)), int(rng.integers(0, V))
        if u != w:
            seen[(u, w)] = (u, w, float(rng.integers(1, 9)))
    edges = list(seen.values())
    initial, pool = edges[:30], edges[30:]
    present = {(u, w) for (u, w, _x) in initial}
    log = []
    for _ in range(12):
        if present and rng.random() < 0.35:
            u, w = sorted(present)[int(rng.integers(0, len(present)))]
            log.append((u, w, 0, 1.0, -1))
            present.discard((u, w))
        elif pool:
            u, w, x = pool.pop()
            log.append((u, w, 0, x, +1))
            present.add((u, w))
    return initial, log


def _session(initial, engine, **kw) -> CQPSession:
    return CQPSession(DynamicGraph(V, initial, capacity=256), engine=engine, **kw)


@pytest.fixture
def tracer():
    """A live tracer installed as the process default; restored after."""
    t = obs_trace.Tracer()
    prev = obs_trace.get_tracer()
    obs_trace.set_tracer(t)
    try:
        yield t
    finally:
        obs_trace.set_tracer(prev)


# ------------------------------------------------------------------- tracer
def test_disabled_tracer_is_zero_allocation_noop():
    """The default (disabled) tracer hands back ONE shared null span —
    tracing-off serving paths never allocate per call."""
    obs_trace.set_tracer(None)
    s1 = obs_trace.span("a", "sweep", pid="x", n=1)
    s2 = obs_trace.span("b", "sweep", pid="y", n=2)
    assert s1 is s2 is obs_trace.NULL_SPAN
    with s1 as sp:
        sp.set(anything=1)  # no-op, no error
    obs_trace.instant("evt", "sweep")
    obs_trace.counter_event("c", {"v": 1})
    assert obs_trace.get_tracer().events() == []


def test_span_records_duration_nesting_and_args(tracer):
    with obs_trace.span("outer", "update_batch", pid="engine:test", tid=3, a=1) as sp:
        with obs_trace.span("inner", "kernel_dispatch", pid="engine:test"):
            pass
        sp.set(b=2)
    evs = tracer.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    outer = evs[1]
    assert outer["ph"] == "X" and outer["cat"] == "update_batch"
    assert outer["pid"] == "engine:test" and outer["tid"] == 3
    assert outer["args"] == {"a": 1, "b": 2}
    assert outer["dur"] >= evs[0]["dur"] >= 0
    assert outer["ts"] <= evs[0]["ts"]


def test_ring_buffer_bounds_and_drop_accounting():
    t = obs_trace.Tracer(capacity=4)
    for i in range(10):
        with t.span(f"s{i}", "sweep"):
            pass
    assert len(t.events()) == 4
    assert t.emitted_events == 10
    assert t.dropped_events == 6
    assert [e["name"] for e in t.events()] == ["s6", "s7", "s8", "s9"]


def test_chrome_trace_export_validates(tracer, tmp_path):
    with obs_trace.span("sweep", "sweep", pid="engine:dense", tid=0, n=3):
        pass
    tracer.instant("shed", "admission", pid="serving", tid="t0")
    tracer.counter("queue", {"depth": 7})
    out = tmp_path / "trace.json"
    n = tracer.export(str(out))
    payload = json.loads(out.read_text())
    assert n == 3 and len(payload["traceEvents"]) == 3
    assert obs_trace.validate_chrome_trace(payload) == []


def test_validator_flags_malformed_traces():
    assert obs_trace.validate_chrome_trace([]) != []  # not object form
    bad = {"traceEvents": [{"ph": "X", "name": "x", "ts": 0}]}  # no dur
    assert obs_trace.validate_chrome_trace(bad) != []
    ok = {"traceEvents": [{"ph": "i", "name": "x", "ts": 0.0, "pid": "p", "tid": 0}]}
    assert obs_trace.validate_chrome_trace(ok) == []


# ----------------------------------------------------------------- registry
def test_counter_gauge_histogram_and_labels():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc()
    c.inc(2, tenant="a")
    assert c.value() == 1 and c.value(tenant="a") == 2
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth", "queue depth")
    g.set(5)
    g.inc(-2)
    assert g.value() == 3
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    series = snap["lat"]["series"][0]
    assert series["count"] == 3
    assert series["buckets"] == {"0.1": 1, "1.0": 2}  # cumulative; +Inf=count
    json.dumps(snap)  # JSON-safe end to end


def test_registry_registration_is_idempotent_and_typed():
    reg = obs_metrics.MetricsRegistry()
    a = reg.counter("x_total", "x")
    assert reg.counter("x_total", "x") is a
    with pytest.raises(ValueError):
        reg.gauge("x_total", "x")  # same name, different type
    assert reg.get("x_total") is a
    assert reg.get("missing") is None


def test_prometheus_text_exposition():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("updates_applied_total", "ingested").inc(3, engine="dense")
    reg.counter("repairs", "repairs").inc(2)
    reg.gauge("nbytes", "bytes").set(10)
    reg.histogram("sweep_s", "sweep time", buckets=(0.5,)).observe(0.1)
    text = reg.prometheus_text()
    # counters end in _total exactly once
    assert 'updates_applied_total{engine="dense"} 3' in text
    assert "repairs_total 2" in text and "repairs_total_total" not in text
    assert "# TYPE nbytes gauge" in text and "nbytes 10" in text
    assert 'sweep_s_bucket{le="0.5"} 1' in text
    assert 'sweep_s_bucket{le="+Inf"} 1' in text
    assert "sweep_s_count 1" in text


# ------------------------------------------------------- span coverage e2e
def test_host_engine_emits_update_batch_and_sweep_spans(tracer):
    initial, log = _workload()
    s = _session(initial, "host")
    s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    s.apply_updates(log)
    cats = {e["cat"] for e in tracer.events()}
    assert {"update_batch", "sweep"} <= cats
    sweep = [e for e in tracer.events() if e["cat"] == "sweep"][-1]
    assert sweep["pid"] == "engine:host"
    assert sweep["args"]["iters_run"] >= 1


def test_dense_batched_emits_sweep_and_kernel_dispatch_spans(tracer):
    initial, log = _workload()
    s = _session(initial, "dense", batch_capacity=4)
    s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    s.apply_updates_batched(log, batch_size=4)
    by_cat: dict[str, list] = {}
    for e in tracer.events():
        by_cat.setdefault(e["cat"], []).append(e)
    assert {"update_batch", "sweep", "kernel_dispatch"} <= set(by_cat)
    # session- and engine-level ingestion spans nest under the same cat
    pids = {e["pid"] for e in by_cat["update_batch"]}
    assert {"session", "engine:dense"} <= pids
    outer = [e for e in by_cat["update_batch"] if e["pid"] == "engine:dense"][-1]
    assert outer["args"]["iters_run"] >= 1
    # the per-iteration probe series rides on the update_batch span
    assert len(outer["args"]["sched_sizes"]) >= 1
    assert by_cat["kernel_dispatch"][0]["args"]["backend"] == "coo"


def test_governor_escalation_emits_governor_spans(tracer):
    initial, log = _workload(seed=7)
    s = _session(initial, "dense", budget_bytes=1)  # force escalation
    s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    s.apply_updates(log[:4])
    gov = [e for e in tracer.events() if e["cat"] == "governor"]
    assert gov, "no governor spans despite a 1-byte budget"
    assert gov[0]["name"] in ("escalate", "deescalate")
    assert {"qid", "op", "level_from", "level_to"} <= set(gov[0]["args"])


def test_checkpoint_emits_span_and_registry_counters(tracer, tmp_path):
    from repro.runtime.recovery import RecoverySupervisor

    initial, log = _workload()
    s = _session(initial, "host")
    s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    s.apply_updates(log[:4])
    reg = obs_metrics.get_registry()
    before = reg.counter("cqp_checkpoints_total", "checkpoints written").value()
    sup = RecoverySupervisor(
        str(tmp_path), restore_fn=lambda d: (s, 0), async_write=False
    )
    sup.checkpoint(s, next_chunk=1)
    ck = [e for e in tracer.events() if e["cat"] == "checkpoint"]
    assert ck and ck[-1]["pid"] == "recovery"
    assert ck[-1]["args"]["nbytes"] > 0
    assert reg.counter("cqp_checkpoints_total", "").value() == before + 1
    assert reg.gauge("cqp_checkpoint_last_bytes", "").value() > 0


# -------------------------------------------- cross-engine stats parity (S2)
@pytest.mark.parametrize("engine", ENGINES)
def test_last_stats_is_maintain_stats_everywhere(engine):
    initial, log = _workload()
    s = _session(initial, engine)
    s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    s.apply_updates(log)
    ls = s.last_stats
    assert ls is not None and tuple(ls._fields) == MaintainStats._fields
    lm = s.stats()["last_maintain"]
    assert set(lm) == set(MaintainStats._fields)
    assert lm["iters_run"] >= 1 and lm["scheduled"] >= 1
    # per-iteration probe vectors: trimmed to iterations run, bounded
    n = min(lm["iters_run"], ITER_TRACE)
    assert len(lm["sched_sizes"]) == n == len(lm["frontier_sizes"])


def test_cross_engine_key_parity_and_zero_fill():
    initial, log = _workload()
    views = {}
    for engine in ENGINES:
        s = _session(initial, engine)
        s.register(qplan.sssp(0, max_iters=MAX_ITERS))
        s.apply_updates(log)
        views[engine] = s.stats()["last_maintain"]
    key_sets = {e: set(v) for e, v in views.items()}
    assert key_sets["dense"] == key_sets["host"] == key_sets["scratch"]
    # structurally-absent counters are REPORTED, zero-filled: the host
    # pointer machine has no Det/Bloom drop store or join store...
    for k in ("dropped", "jwritten", "det_overflow"):
        assert views["host"][k] == 0
    # ...and from-scratch re-execution never repairs or drops
    for k in ("repairs", "dropped", "det_overflow"):
        assert views["scratch"][k] == 0
    # scratch's analytic schedule series accounts every (q, v) relaxation
    assert sum(views["scratch"]["sched_sizes"]) == views["scratch"]["scheduled"]


# ----------------------------------------------------------- Bloom math (S3)
def test_bloom_fp_rate_analytic_matches_brute_force():
    """fill^k vs empirically probing never-inserted keys on a small filter."""
    k = 4
    flt = bloom_lib.make((), num_bits=512, num_hashes=k)
    rng = np.random.default_rng(0)
    n = 64
    v_ins = rng.integers(0, 1 << 20, size=n).astype(np.uint32)
    i_ins = rng.integers(0, 32, size=n).astype(np.uint32)
    flt = bloom_lib.insert(flt, v_ins, i_ins, np.ones(n, bool))
    fill = float(bloom_lib.fill_fraction(flt))
    analytic = probes.bloom_fp_rate(fill, k)
    assert 0.05 < fill < 0.9 and 0.0 < analytic < 0.5
    # no false negatives, ever
    assert bool(np.asarray(bloom_lib.query(flt, v_ins, i_ins)).all())
    # brute-force FP rate over disjoint keys (vertex ids past the insert range)
    m = 4000
    v_neg = rng.integers(1 << 20, 1 << 24, size=m).astype(np.uint32)
    i_neg = rng.integers(0, 32, size=m).astype(np.uint32)
    hits = np.asarray(bloom_lib.query(flt, v_neg, i_neg))
    empirical = float(hits.mean())
    assert abs(empirical - analytic) < 0.02, (empirical, analytic)


def test_bloom_fp_rate_gauge_rises_with_dropped_diffs():
    """Prob-Drop session: every maintained batch inserts dropped diffs, so
    the published FP-rate gauge is non-decreasing and ends positive."""
    initial, log = _workload(seed=3)
    s = _session(
        initial,
        "dense",
        drop=dr.DropConfig(mode="prob", bloom_bits=256, bloom_hashes=4),
    )
    h = s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    # the session-level config provisions the representation; the per-query
    # POLICY row is what actually selects drops
    s.set_drop_policy(h, dr.DropConfig(mode="prob", p=1.0, bloom_bits=256))
    reg = obs_metrics.MetricsRegistry()
    rates = []
    for k in range(0, len(log), 3):
        s.apply_updates(log[k : k + 3])
        probes.publish_session_metrics(s, reg)
        rates.append(reg.gauge("cqp_bloom_fp_rate", "").value(qid=h.qid))
    assert all(b >= a for a, b in zip(rates, rates[1:])), rates
    assert rates[-1] > 0.0
    fill = reg.gauge("cqp_bloom_fill_ratio", "").value(qid=h.qid)
    assert rates[-1] == pytest.approx(probes.bloom_fp_rate(fill, 4))


# ------------------------------------------------------------ session scrape
def test_publish_session_metrics_scrape_is_idempotent():
    initial, log = _workload()
    s = _session(initial, "host")
    s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    s.apply_updates(log[:6])
    reg = obs_metrics.MetricsRegistry()
    probes.publish_session_metrics(s, reg)
    v1 = reg.counter("cqp_updates_applied_total", "").value()
    probes.publish_session_metrics(s, reg)  # double scrape: no double count
    assert reg.counter("cqp_updates_applied_total", "").value() == v1 == 6
    s.apply_updates(log[6:8])
    probes.publish_session_metrics(s, reg)
    assert reg.counter("cqp_updates_applied_total", "").value() == 8
    assert reg.gauge("cqp_active_queries", "").value() == 1
    assert reg.gauge("cqp_nbytes", "").value() == s.nbytes()
    # per-operator occupancy gauge carries (qid, op) labels
    occ = reg.get("cqp_diffstore_bytes")
    assert occ is not None and len(occ.series()) >= 1
