"""Memory accounting (§7 / paper Fig. 7) and Det-Drop overflow surfacing.

``nbytes_accounted`` is validated against a hand-counted trace on a path
graph under each drop mode, and asserted monotone-nonincreasing as the drop
probability rises (the paper's Fig-7 invariant: a dropped difference trades
an 8-byte change point for a ≤4-byte DroppedVT record).

With dropping enabled the account includes, per LIVE query row, the
``DropParams`` selection row itself (17 B — the governor rewrites these
online, so they are live state) and, under Prob-Drop, the packed Bloom row
(M/8 B).  The same totals must hold per query (``slot_nbytes`` sums to the
global figure) and per shard (``nbytes_per_shard`` sums to it in every drop
mode — replicated structures are apportioned, not double-counted).

``DropState.det_overflow`` — dropped-VT records lost to Det-Drop store
evictions, i.e. (v, i) pairs the engine can no longer repair on access —
must surface in ``MaintainStats`` instead of vanishing silently.
"""

import numpy as np
import pytest

from repro.core import dropping as dr
from repro.core import queries as q
from repro.core.graph import DynamicGraph

PARAMS_B = dr.PARAMS_ROW_NBYTES  # 17 B: p + tau_min + tau_max + sel + seed

# 0 → 1 → 2 → 3, unit weights: SSSP from 0 stores exactly one change point
# per reached vertex, at iteration = its distance.
PATH = [(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]


def _path_engine(**kw):
    return q.sssp(DynamicGraph(4, PATH, capacity=16), [0], max_iters=8, **kw)


def test_nbytes_hand_counted_jod():
    # change points: v1@1, v2@2, v3@3 → 3 diffs × (4B iter + 4B state)
    assert _path_engine().nbytes() == 3 * 8


def test_nbytes_hand_counted_vdc():
    # D store: 3 diffs.  J store: edge (1,2)'s message changes at i=2 and
    # edge (2,3)'s at i=3; edge (0,1)'s message is its implicit j0 forever.
    assert _path_engine(mode="vdc").nbytes() == 3 * 8 + 2 * 8


def test_nbytes_hand_counted_det():
    # p=1 drops every candidate: no change points, 3 DroppedVT pairs × 4B,
    # plus the one live query's 17 B DropParams selection row
    eng = _path_engine(
        drop=dr.DropConfig(mode="det", selection="random", p=1.0, seed=1)
    )
    assert eng.nbytes() == 3 * 4 + PARAMS_B
    # dropping must not have cost correctness (repair on the fly)
    np.testing.assert_array_equal(eng.answers()[0], [0.0, 1.0, 2.0, 3.0])


def test_nbytes_hand_counted_prob():
    # p=1 drops every candidate into the Bloom filter: the accounted cost is
    # the packed per-query filter row (bits/8) + the params row, independent
    # of the drop count.
    bits = 1 << 10
    eng = _path_engine(
        drop=dr.DropConfig(mode="prob", selection="random", p=1.0, seed=1,
                           bloom_bits=bits)
    )
    assert eng.nbytes() == bits // 8 + PARAMS_B
    np.testing.assert_array_equal(eng.answers()[0], [0.0, 1.0, 2.0, 3.0])


@pytest.mark.parametrize("mode", ["det", "prob"])
def test_per_query_breakdown_sums_to_global(mode):
    """slot_nbytes over the live slots == nbytes_accounted, per drop mode —
    the [Q] breakdown the memory governor meters must not double- or
    under-count the Bloom rows / params rows."""
    bits = 1 << 10
    eng = q.sssp(
        DynamicGraph(4, PATH, capacity=16),
        [0, 2],
        max_iters=8,
        drop=dr.DropConfig(mode=mode, selection="random", p=0.5, seed=1,
                           bloom_bits=bits),
    )
    per = eng.nbytes_per_query()
    assert sorted(per) == [0, 1]
    assert sum(per.values()) == eng.nbytes()
    if mode == "prob":
        # hand count of the fixed footprint: each live row carries its own
        # packed filter + params row; change points add 8 B each on top
        fixed = 2 * (bits // 8 + PARAMS_B)
        assert eng.nbytes() >= fixed
        assert (eng.nbytes() - fixed) % 4 == 0


@pytest.mark.parametrize("mode", ["none", "det", "prob"])
def test_nbytes_per_shard_sums_to_global(mode):
    """sum(nbytes_per_shard) == nbytes_accounted in every drop mode (the
    pre-governor code added the FULL replicated Bloom cost to every shard)."""
    from repro.core.engine import nbytes_per_shard

    kw = {}
    if mode != "none":
        kw["drop"] = dr.DropConfig(mode=mode, selection="random", p=0.6,
                                   seed=2, bloom_bits=1 << 9)
    eng = q.sssp(DynamicGraph(4, PATH, capacity=16), [0, 3], max_iters=8, **kw)
    per = nbytes_per_shard(eng.cfg, eng.state, 2)
    assert sum(per) == eng.nbytes(), (per, eng.nbytes())


def _workload(seed=5, v=16, e=48):
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < e:
        u, w = int(rng.integers(0, v)), int(rng.integers(0, v))
        if u != w:
            seen[(u, w)] = (u, w, float(rng.integers(1, 6)))
    edges = list(seen.values())
    return edges[: e - 8], [(u, w, 0, x, +1) for (u, w, x) in edges[e - 8 :]]


@pytest.mark.parametrize("mode", ["det", "prob"])
def test_nbytes_monotone_nonincreasing_in_p(mode):
    """Fig-7 invariant: with a counter-based drop coin the drop sets are
    nested in p, so accounted memory can only fall as p rises."""
    initial, updates = _workload()
    sizes = []
    for p in (0.0, 0.25, 0.5, 0.75, 1.0):
        eng = q.sssp(
            DynamicGraph(16, initial, capacity=96),
            [0, 8],
            max_iters=24,
            drop=dr.DropConfig(mode=mode, selection="random", p=p, seed=3,
                               bloom_bits=1 << 10),
        )
        eng.apply_updates(updates)
        sizes.append(eng.nbytes())
    assert sizes == sorted(sizes, reverse=True), sizes


def test_det_overflow_surfaced_in_stats():
    """An overflowing det_capacity run must report the lost records."""
    eng = _path_engine(
        drop=dr.DropConfig(mode="det", selection="random", p=1.0, seed=1,
                           det_capacity=1)
    )
    assert int(eng.last_stats.det_overflow) == 0  # one drop per vertex so far
    # the shortcut moves v3's change point to iteration 1: its single
    # DroppedVT slot (holding iteration 3) must evict → overflow reported
    stats = eng.apply_updates([(0, 3, 0, 1.0, +1)])
    assert int(stats.det_overflow) >= 1


def test_det_no_overflow_with_capacity():
    eng = _path_engine(
        drop=dr.DropConfig(mode="det", selection="random", p=1.0, seed=1,
                           det_capacity=8)
    )
    stats = eng.apply_updates([(0, 3, 0, 1.0, +1)])
    assert int(stats.det_overflow) == 0
    np.testing.assert_array_equal(eng.answers()[0], [0.0, 1.0, 2.0, 1.0])
