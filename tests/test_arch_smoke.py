"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStructs, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch

LM_ARCHS = ["qwen2-72b", "minicpm3-4b", "llama3.2-1b", "qwen2-moe-a2.7b", "arctic-480b"]
GNN_ARCHS = ["pna", "gatedgcn", "dimenet", "equiformer-v2"]


def test_registry_complete():
    assert len(ARCH_NAMES) == 11  # 10 assigned + diff-ife
    for name in ARCH_NAMES:
        arch = get_arch(name)
        assert arch.shapes, name
        assert callable(arch.full) and callable(arch.smoke)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_forward_and_train(name):
    from repro.configs.lm_harness import make_train_step
    from repro.models import transformer as tf
    from repro.optim import adamw_init

    arch = get_arch(name)
    cfg = arch.smoke()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)

    logits, _, _ = tf.forward(cfg, params, tokens)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN in logits"

    step = jax.jit(make_train_step(cfg))
    p2, o2, metrics = step(params, adamw_init(params), tokens, labels)
    assert bool(jnp.isfinite(metrics["loss"])), "NaN loss"
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved

    # decode smoke: one token against a cache
    cache = tf.init_cache(cfg, 2, 8)
    lg, cache2 = tf.decode_step(cfg, params, cache, tokens[:, 0], jnp.zeros((2,), jnp.int32))
    assert lg.shape == (2, cfg.vocab_size) and bool(jnp.isfinite(lg).all())


@pytest.mark.parametrize("name", GNN_ARCHS)
def test_gnn_smoke_train(name):
    from repro.models.gnn import common as g

    arch = get_arch(name)
    cfg = arch.smoke()
    rng = np.random.default_rng(1)
    geometric = name in ("dimenet", "equiformer-v2")
    batch = g.random_graph_batch(
        rng, 48, 160, getattr(cfg, "d_in", 16), edge_feat_dim=8,
        num_classes=getattr(cfg, "num_classes", 8), geometric=geometric,
    )
    if name == "pna":
        from repro.models.gnn import pna as m
        loss_fn = lambda p: m.loss_fn(cfg, p, batch)
        out = m.forward(cfg, m.init_params(cfg, jax.random.PRNGKey(0)), batch)
        assert out.shape == (48, cfg.num_classes)
    elif name == "gatedgcn":
        from repro.models.gnn import gatedgcn as m
        loss_fn = lambda p: m.loss_fn(cfg, p, batch)
        out = m.forward(cfg, m.init_params(cfg, jax.random.PRNGKey(0)), batch)
        assert out.shape == (48, cfg.num_classes)
    elif name == "dimenet":
        from repro.models.gnn import dimenet as m
        tri = m.build_triplets(
            np.asarray(batch.edge_src), np.asarray(batch.edge_dst),
            np.asarray(batch.edge_mask), 1024,
        )
        tri = tuple(jnp.asarray(t) for t in tri)
        loss_fn = lambda p: m.loss_fn(cfg, p, batch, tri)
        out = m.forward(cfg, m.init_params(cfg, jax.random.PRNGKey(0)), batch, tri)
        assert out.shape == (48, cfg.num_targets)
    else:
        from repro.models.gnn import equiformer_v2 as m
        loss_fn = lambda p: m.loss_fn(cfg, p, batch)
        out = m.forward(cfg, m.init_params(cfg, jax.random.PRNGKey(0)), batch)
        assert out.shape == (48, cfg.num_targets)
    assert bool(jnp.isfinite(out).all()), "NaN in forward"

    if name == "pna":
        from repro.models.gnn import pna as m
    elif name == "gatedgcn":
        from repro.models.gnn import gatedgcn as m
    params = None
    # one grad step sanity: loss finite, grads finite
    mod_params = loss_fn.__closure__  # noqa: F841 (documentation only)
    import repro.models.gnn as _  # noqa: F401

    # generic: re-init params through the arch's own module
    init = {
        "pna": "pna", "gatedgcn": "gatedgcn", "dimenet": "dimenet",
        "equiformer-v2": "equiformer_v2",
    }[name]
    mod = __import__(f"repro.models.gnn.{init}", fromlist=["init_params"])
    p0 = mod.init_params(cfg, jax.random.PRNGKey(0))
    l, grads = jax.value_and_grad(loss_fn)(p0)
    assert bool(jnp.isfinite(l))
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(grads))


def test_mind_smoke_train_and_serve():
    from repro.models.recsys import mind as m

    arch = get_arch("mind")
    cfg = arch.smoke()
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    beh = jnp.asarray(rng.integers(0, cfg.num_items, (8, cfg.seq_len)), jnp.int32)
    valid = jnp.ones((8, cfg.seq_len), bool)
    tgt = jnp.asarray(rng.integers(0, cfg.num_items, 8), jnp.int32)
    neg = jnp.asarray(rng.integers(0, cfg.num_items, (8, 20)), jnp.int32)
    loss = m.loss_fn(cfg, params, beh, valid, tgt, neg)
    assert bool(jnp.isfinite(loss))
    interests = m.user_interests(cfg, params, beh, valid)
    assert interests.shape == (8, cfg.n_interests, cfg.embed_dim)
    assert bool(jnp.isfinite(interests).all())
    scores = m.retrieval_scores(cfg, params, beh[:1], valid[:1],
                                jnp.arange(cfg.num_items, dtype=jnp.int32))
    assert scores.shape == (1, cfg.num_items)


def test_diff_ife_smoke_cell_runs_with_real_arrays():
    """The dc arch's maintain cell executes on a 1×1 mesh with real arrays."""
    from repro.configs.diff_ife import ARCH, _engine_cfg
    from repro.core import engine as eng

    z = ARCH.smoke()
    cfg = _engine_cfg(z)
    rng = np.random.default_rng(0)
    e = z.num_edges
    src = jnp.asarray(rng.integers(0, z.num_vertices, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, z.num_vertices, e), jnp.int32)
    g = eng.GraphArrays(
        src=src, dst=dst,
        weight=jnp.asarray(rng.integers(1, 10, e), jnp.float32),
        valid=jnp.ones((e,), bool),
        out_degree=jnp.zeros((z.num_vertices,), jnp.int32),
        in_degree=jnp.zeros((z.num_vertices,), jnp.int32),
    )
    init = jnp.full((z.num_queries, z.num_vertices), jnp.inf, jnp.float32)
    init = init.at[jnp.arange(z.num_queries), jnp.arange(z.num_queries)].set(0.0)
    state = eng.make_state(cfg, init, e)
    state2, stats = jax.jit(lambda s, g_, d: eng.maintain(cfg, s, g_, d))(
        state, g, jnp.ones((z.num_vertices,), bool)
    )
    assert int(stats.iters_run) > 0
    assert bool(jnp.isfinite(state2.cur[jnp.isfinite(state2.cur)]).all())
