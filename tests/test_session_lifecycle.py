"""Runtime query lifecycle: CQPSession register/deregister properties.

The session contract (DESIGN.md §9), asserted across all three engines and
(for the dense engine) sharded and unsharded:

* **register-convergence** — registering a plan mid-stream converges to
  exactly the answers of a session that had the plan from the start (the
  dense engine initializes the trace by in-engine recomputation; min-family
  fixpoints are unique, so WHEN a query registers can never change WHAT it
  answers).
* **deregister-monotonicity** — every deregistration monotonically reduces
  ``nbytes()`` (diff rows are zeroed and accounted bytes returned).
* **slot-pool mechanics** — geometric regrow past ``min_slots``, slot reuse
  after deregistration, per-query drop policies, family validation.

A Hypothesis property test generalizes the convergence check to arbitrary
insert/delete streams with a random registration point.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import dropping as dr
from repro.core import plan as qplan
from repro.core.graph import DynamicGraph
from repro.core.session import ENGINES, CQPSession
from repro.launch.mesh import make_data_mesh

V = 16
MAX_ITERS = 16
NDEV = jax.device_count()

needs8 = pytest.mark.skipif(
    NDEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

SHARD_AXIS = [1, pytest.param(8, marks=needs8)]


def workload(seed: int = 5):
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < 40:
        u, w = int(rng.integers(0, V)), int(rng.integers(0, V))
        if u != w:
            seen[(u, w)] = (u, w, float(rng.integers(1, 9)))
    edges = list(seen.values())
    initial, pool = edges[:30], edges[30:]
    present = {(u, w) for (u, w, _x) in initial}
    log = []
    for _ in range(12):
        if present and rng.random() < 0.35:
            u, w = sorted(present)[int(rng.integers(0, len(present)))]
            log.append((u, w, 0, 1.0, -1))
            present.discard((u, w))
        elif pool:
            u, w, x = pool.pop()
            log.append((u, w, 0, x, +1))
            present.add((u, w))
    return initial, log


def _graph(initial):
    return DynamicGraph(V, initial, capacity=256)


def _session(initial, engine, shards=1, **kw):
    mesh = make_data_mesh(shards) if shards > 1 else None
    return CQPSession(_graph(initial), engine=engine, mesh=mesh, **kw)


def _shards_for(engine):
    # the sharded sweep is dense-only; host/scratch run unsharded
    return [1, 8] if engine == "dense" and NDEV >= 8 else [1]


@pytest.mark.parametrize("engine", ENGINES)
def test_register_midstream_converges(engine):
    """register(plan) mid-stream == constructing with the plan from start."""
    initial, log = workload()
    plans = [qplan.sssp(0, max_iters=MAX_ITERS), qplan.sssp(7, max_iters=MAX_ITERS)]
    for shards in _shards_for(engine):
        a = _session(initial, engine, shards)
        ha = a.register_many(plans)
        b = _session(initial, engine, shards)
        hb0 = b.register(plans[0])
        a.apply_updates(log[:6])
        b.apply_updates(log[:6])
        hb1 = b.register(plans[1])  # mid-stream
        a.apply_updates(log[6:])
        b.apply_updates(log[6:])
        np.testing.assert_array_equal(a.answers(ha[0]), b.answers(hb0))
        np.testing.assert_array_equal(a.answers(ha[1]), b.answers(hb1))


@pytest.mark.parametrize("engine", ENGINES)
def test_deregister_monotonically_reduces_nbytes(engine):
    initial, log = workload(seed=9)
    for shards in _shards_for(engine):
        s = _session(initial, engine, shards)
        handles = s.register_many(
            [qplan.sssp(i, max_iters=MAX_ITERS) for i in range(4)]
        )
        s.apply_updates(log)
        sizes = [s.nbytes()]
        for h in handles:
            freed = s.deregister(h)
            assert freed >= 0
            sizes.append(s.nbytes())
        assert all(b <= a for a, b in zip(sizes, sizes[1:])), sizes
        assert sizes[-1] == 0  # no registered queries → no accounted diffs
        assert s.bytes_freed_total == sizes[0] - sizes[-1]


@pytest.mark.parametrize("shards", SHARD_AXIS)
def test_dense_slot_pool_regrow_and_reuse(shards):
    """min_slots=1 → geometric regrow to 8 slots for 5 queries; a freed slot
    is reused by the next registration and answers stay correct."""
    initial, log = workload(seed=11)
    s = _session(initial, "dense", shards, min_slots=1)
    handles = [s.register(qplan.sssp(i, max_iters=MAX_ITERS)) for i in range(5)]
    assert s.stats()["slot_capacity"] == 8
    s.apply_updates_batched(log, batch_size=4)
    s.deregister(handles[2])
    h_new = s.register(qplan.sssp(9, max_iters=MAX_ITERS))
    assert s.stats()["slot_capacity"] == 8  # reused the freed slot
    ref = _session(initial, "host")
    rh = ref.register(qplan.sssp(9, max_iters=MAX_ITERS))
    ref.apply_updates(log)
    np.testing.assert_array_equal(s.answers(h_new), ref.answers(rh))
    # survivors unaffected by the churn
    ref0 = ref.register(qplan.sssp(0, max_iters=MAX_ITERS))
    np.testing.assert_array_equal(s.answers(handles[0]), ref.answers(ref0))


def test_per_query_drop_policies_stay_exact():
    """Each query brings its own §5 selection policy; answers stay exact and
    the heavier-dropping query stores fewer diffs."""
    initial, log = workload(seed=13)
    s = _session(initial, "dense", drop=dr.DropConfig(mode="det"))
    h_heavy = s.register(
        qplan.sssp(
            0,
            max_iters=MAX_ITERS,
            drop=dr.DropConfig(mode="det", selection="random", p=0.9, seed=3),
        )
    )
    h_none = s.register(qplan.sssp(0, max_iters=MAX_ITERS))  # same query, no drops
    s.apply_updates_batched(log, batch_size=4)
    np.testing.assert_array_equal(s.answers(h_heavy), s.answers(h_none))
    slot_heavy = s._handles[h_heavy.qid]
    slot_none = s._handles[h_none.qid]
    impl = s._impl.impl
    assert impl.slot_nbytes(slot_heavy) < impl.slot_nbytes(slot_none)


def test_lifecycle_validation():
    initial, _ = workload()
    s = _session(initial, "dense")
    h = s.register(qplan.sssp(0, max_iters=MAX_ITERS))
    with pytest.raises(ValueError, match="family"):
        s.register(qplan.khop(1, k=4))
    with pytest.raises(ValueError, match="drop mode"):
        s.register(
            qplan.sssp(
                1, max_iters=MAX_ITERS, drop=dr.DropConfig(mode="det", p=0.5)
            )
        )
    s.deregister(h)
    with pytest.raises(ValueError, match="not registered"):
        s.deregister(h)
    with pytest.raises(ValueError, match="mesh"):
        CQPSession(_graph(initial), engine="host", mesh=object())


def test_failed_register_batch_leaves_session_untouched():
    """A rejected opening batch must not half-commit the family: the session
    still accepts a clean batch afterwards, and pre-engine updates keep
    landing on the base graph (not a phantom product space)."""
    initial, log = workload()
    s = _session(initial, "dense")
    nfa = qplan.NFA.star(1)
    with pytest.raises(ValueError, match="family"):
        s.register_many(
            [qplan.rpq(0, nfa, max_iters=MAX_ITERS), qplan.sssp(1, max_iters=MAX_ITERS)]
        )
    assert s.num_queries == 0
    s.apply_updates(log[:2])  # pre-engine: applies to the base graph
    h = s.register(qplan.sssp(0, max_iters=MAX_ITERS))  # non-NFA family works
    s.apply_updates(log[2:])
    ref = _session(initial, "host")
    rh = ref.register(qplan.sssp(0, max_iters=MAX_ITERS))
    ref.apply_updates(log)
    np.testing.assert_array_equal(s.answers(h), ref.answers(rh))

    # mixed DroppedVT representations in one batch are rejected up front,
    # and the session stays open for a clean retry
    s2 = _session(initial, "dense")
    with pytest.raises(ValueError, match="drop mode"):
        s2.register_many(
            [
                qplan.sssp(0, max_iters=MAX_ITERS, drop=dr.DropConfig(mode="det", p=0.5)),
                qplan.sssp(1, max_iters=MAX_ITERS, drop=dr.DropConfig(mode="prob", p=0.5)),
            ]
        )
    assert s2.num_queries == 0
    s2.register(qplan.sssp(0, max_iters=MAX_ITERS, drop=dr.DropConfig(mode="prob", p=0.5)))

    # an engine that cannot run the family rolls the whole commit back
    s3 = _session(initial, "host")
    with pytest.raises(ValueError, match="min-family"):
        s3.register(qplan.pagerank())
    h3 = s3.register(qplan.sssp(0, max_iters=MAX_ITERS))  # not bricked
    assert s3.answers(h3).shape == (V,)


def test_rpq_session_churn():
    """RPQ plans (NFA product) through the session lifecycle."""
    edges = [(i, (i + 1) % V, 1.0, 1 + (i % 2)) for i in range(V)]
    nfa = qplan.NFA.concat_star(1, 2)
    s = CQPSession(DynamicGraph(V, edges, capacity=128), engine="dense")
    h0 = s.register(qplan.rpq(0, nfa, max_iters=MAX_ITERS))
    s.apply_updates([(0, 5, 1, 1.0, +1)])
    h1 = s.register(qplan.rpq(4, nfa, max_iters=MAX_ITERS))  # mid-stream
    ref = CQPSession(DynamicGraph(V, edges, capacity=128), engine="dense")
    r0 = ref.register(qplan.rpq(0, nfa, max_iters=MAX_ITERS))
    r1 = ref.register(qplan.rpq(4, nfa, max_iters=MAX_ITERS))
    ref.apply_updates([(0, 5, 1, 1.0, +1)])
    np.testing.assert_array_equal(s.reachable(h0), ref.reachable(r0))
    np.testing.assert_array_equal(s.reachable(h1), ref.reachable(r1))
    assert s.deregister(h0) >= 0


def test_property_midstream_register_equals_from_start():
    """Hypothesis: for arbitrary insert/delete streams and a random split
    point, mid-stream registration converges to from-start answers on every
    engine (dense checked against host for cross-engine parity too)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def stream(draw):
        mk = st.tuples(
            st.integers(0, V - 1), st.integers(0, V - 1), st.integers(1, 9)
        )
        edges = [
            (u, w, float(x))
            for (u, w, x) in draw(st.lists(mk, min_size=6, max_size=24))
            if u != w
        ]
        edges = list({(u, w): (u, w, x) for (u, w, x) in edges}.values())
        present = {(u, w) for (u, w, _x) in edges}
        ops = []
        for _ in range(draw(st.integers(2, 10))):
            if present and draw(st.booleans()):
                u, w = draw(st.sampled_from(sorted(present)))
                ops.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            else:
                u, w = draw(st.integers(0, V - 1)), draw(st.integers(0, V - 1))
                if u == w:
                    continue
                ops.append((u, w, 0, float(draw(st.integers(1, 9))), +1))
                present.add((u, w))
        cut = draw(st.integers(0, len(ops)))
        src = draw(st.integers(0, V - 1))
        return edges, ops, cut, src

    @settings(max_examples=10, deadline=None)
    @given(wl=stream())
    def run(wl):
        edges, ops, cut, src = wl
        rows = {}
        for engine in ENGINES:
            a = CQPSession(DynamicGraph(V, edges, capacity=256), engine=engine)
            ha = a.register(qplan.sssp(src, max_iters=MAX_ITERS))
            a.apply_updates(ops)
            b = CQPSession(DynamicGraph(V, edges, capacity=256), engine=engine)
            b.apply_updates(ops[:cut])
            hb = b.register(qplan.sssp(src, max_iters=MAX_ITERS))
            b.apply_updates(ops[cut:])
            np.testing.assert_array_equal(a.answers(ha), b.answers(hb))
            rows[engine] = a.answers(ha)
        np.testing.assert_array_equal(rows["dense"], rows["host"])
        np.testing.assert_array_equal(rows["dense"], rows["scratch"])

    run()


def test_cqp_serve_churn_all_engines_subprocess():
    """Acceptance: ``cqp_serve --json`` runs a churn scenario (mid-stream
    register + deregister) on all three engines via CQPSession."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    for engine in ENGINES:
        out = subprocess.run(
            [
                sys.executable,
                "-m",
                "repro.launch.cqp_serve",
                "--smoke",
                "--json",
                "--engine",
                engine,
                "--register-at",
                "2",
                "--deregister-at",
                "3",
            ],
            env=env,
            capture_output=True,
            text=True,
            timeout=560,
        )
        assert out.returncode == 0, out.stdout + out.stderr
        payload = json.loads(out.stdout.strip().splitlines()[-1])
        assert payload["engine"] == engine
        assert payload["registers"] == 1 and payload["deregisters"] == 1
        assert payload["updates_served"] > 0
        if engine != "scratch":
            assert payload["bytes_freed"] > 0
