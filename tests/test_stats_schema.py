"""Golden key-set schema regression: ``session.stats()`` / ``server.stats()``.

The stats dicts are the JSON contract every consumer scrapes — the serving
tier, the obs registry bridge (``publish_session_metrics``), CI smokes, and
downstream dashboards.  These tests pin the key sets: a PR that renames,
drops, or adds a key fails here first and must update the goldens
deliberately (DESIGN.md §15).
"""

from __future__ import annotations

import asyncio

import numpy as np
import pytest

from repro.core import plan as qp
from repro.core.engine import MaintainStats
from repro.core.governor import GovernorConfig
from repro.core.graph import DynamicGraph
from repro.core.session import ENGINES, CQPSession
from repro.data.graphgen import powerlaw_graph, split_90_10
from repro.serving.loadgen import tenant_update_streams
from repro.serving.server import CQPServer, ServerConfig, build_serving_session
from repro.serving.tenants import TenantSpec

V, E, BATCH, MAX_ITERS = 64, 256, 8, 16

# ------------------------------------------------------------------- goldens
SESSION_KEYS = frozenset({
    "engine",
    "active_queries",
    "registered_total",
    "deregistered_total",
    "updates_applied",
    "bytes_freed_total",
    "bytes_shed_total",
    "nbytes",
    "nbytes_per_query",
    "nbytes_per_operator",
    "query_qids",
    "last_maintain",
})
SESSION_DENSE_EXTRA = frozenset({"slot_capacity", "shards"})
LAST_MAINTAIN_KEYS = frozenset(MaintainStats._fields)

SERVER_KEYS = frozenset({
    "epochs",
    "covered_updates",
    "admitted_total",
    "queue_depth",
    "chunks_applied",
    "faults",
    "tenants",
    "admission",
    "actions",
    "phases",
    "straggler_events",
    "session",
})
TENANT_KEYS = frozenset({
    "priority",
    "level",
    "queries",
    "nbytes",
    "budget_bytes",
    "rate_per_s",
    "watermark",
    "submitted_updates",
    "admitted_updates",
    "rejected_updates",
    "rejected_registers",
    "read_latency",
    "freshness_lag_updates",
    "stale_reads",
})
PHASE_KEYS = frozenset(
    {"count", "p50_ms", "p99_ms", "p999_ms", "mean_ms", "max_ms", "total_s"}
)


def _workload():
    edges = powerlaw_graph(V, E, seed=0)
    initial, pool = split_90_10(edges, seed=0)
    return edges, initial, pool


# ------------------------------------------------------------------- session
@pytest.mark.parametrize("engine", ENGINES)
def test_session_stats_golden_keys(engine):
    _, initial, pool = _workload()
    s = CQPSession(
        DynamicGraph(V, initial, capacity=len(initial) * 4 + 64), engine=engine
    )
    s.register(qp.sssp(0, max_iters=MAX_ITERS))
    s.apply_updates([(u, w, 0, x, +1) for (u, w, x) in pool[:6]])
    got = set(s.stats())
    want = SESSION_KEYS | (SESSION_DENSE_EXTRA if engine == "dense" else set())
    assert got == want, (
        f"session.stats() schema drifted for {engine}: "
        f"+{sorted(got - want)} -{sorted(want - got)}"
    )
    assert set(s.stats()["last_maintain"]) == LAST_MAINTAIN_KEYS


def test_session_stats_governor_and_runtime_blocks_are_opt_in():
    _, initial, pool = _workload()
    s = CQPSession(
        DynamicGraph(V, initial, capacity=len(initial) * 4 + 64),
        engine="dense",
        budget_bytes=1 << 20,
        governor=GovernorConfig(representation="prob"),
    )
    s.register(qp.sssp(0, max_iters=MAX_ITERS))
    s.apply_updates([(u, w, 0, x, +1) for (u, w, x) in pool[:4]])
    got = set(s.stats())
    want = SESSION_KEYS | SESSION_DENSE_EXTRA | {"governor"}
    assert got == want, f"+{sorted(got - want)} -{sorted(want - got)}"


# -------------------------------------------------------------------- server
def test_server_stats_golden_keys():
    _, initial, pool = _workload()
    streams = tenant_update_streams(
        initial, V, 2, num_batches=3, batch_size=BATCH,
        delete_fraction=0.1, insert_pool=pool, seed=1,
    )
    ladder = GovernorConfig(representation="prob")

    async def run():
        session = build_serving_session(
            DynamicGraph(V, initial, capacity=len(initial) * 8 + 1024),
            ladder=ladder,
            engine="host",
        )
        server = CQPServer(
            session,
            config=ServerConfig(chunk_updates=BATCH, drop_ladder=ladder),
        )
        async with server:
            for i, tid in enumerate(sorted(streams)):
                server.add_tenant(TenantSpec(tenant_id=tid, priority=i + 1))
                await server.register_query(tid, qp.sssp(i, max_iters=MAX_ITERS))
                for batch in streams[tid]:
                    server.submit(tid, batch)
            await server.drain()
            return server.stats()

    st = asyncio.run(run())
    got = set(st)
    assert got == SERVER_KEYS, (
        f"server.stats() schema drifted: "
        f"+{sorted(got - SERVER_KEYS)} -{sorted(SERVER_KEYS - got)}"
    )
    # the in-server session block carries the runtime observers on top of
    # the session golden (host engine: no dense-only extras)
    assert set(st["session"]) == SESSION_KEYS | {"runtime"}
    for tid, tstats in st["tenants"].items():
        assert set(tstats) == TENANT_KEYS, tid
    for phase, block in st["phases"].items():
        assert set(block) == PHASE_KEYS, phase
    assert st["covered_updates"] == sum(
        len(b) for s_ in streams.values() for b in s_
    )
    assert np.isfinite(st["admission"]["p99_ms"])
