"""Property tests: every DC configuration == SCRATCH after every batch.

This is the paper's correctness invariant (Thm 4.1 + §5 safety argument):
VDC, JOD, and JOD ± {Det,Prob}-Drop × {Random,Degree} must produce the same
final vertex states as from-scratch re-execution after every update batch —
dropping may only cost recomputation, never correctness.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis (requirements.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dropping as dr
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.scratch import scratch_like


# ---------------------------------------------------------------- generators
@st.composite
def dynamic_graph_workload(draw, max_v=12, max_e=28, max_batches=4, max_batch=3):
    """(num_vertices, initial edges, update batches) with ins+del mixes."""
    v = draw(st.integers(3, max_v))
    n_edges = draw(st.integers(2, max_e))
    mk_edge = st.tuples(
        st.integers(0, v - 1),
        st.integers(0, v - 1),
        st.integers(1, 10),  # integer weights like the paper's datasets
    )
    edges = draw(st.lists(mk_edge, min_size=n_edges, max_size=n_edges))
    edges = [(u, w, float(x)) for (u, w, x) in edges if u != w]
    # dedupe (u, v) pairs — DynamicGraph keys slots by (u, v, label)
    edges = list({(u, w): (u, w, x) for (u, w, x) in edges}.values())

    batches = []
    present = {(u, w) for (u, w, _) in edges}
    n_batches = draw(st.integers(1, max_batches))
    for _ in range(n_batches):
        batch = []
        for _ in range(draw(st.integers(1, max_batch))):
            if present and draw(st.booleans()) and draw(st.booleans()):
                # deletion of an existing edge
                u, w = draw(st.sampled_from(sorted(present)))
                batch.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            else:
                u = draw(st.integers(0, v - 1))
                w = draw(st.integers(0, v - 1))
                if u == w:
                    continue
                batch.append((u, w, 0, float(draw(st.integers(1, 10))), +1))
                present.add((u, w))
        if batch:
            batches.append(batch)
    return v, edges, batches


ENGINE_CONFIGS = [
    dict(mode="vdc"),
    dict(mode="jod"),
    dict(mode="jod", drop=dr.DropConfig(mode="det", selection="random", p=0.4, seed=7)),
    dict(mode="jod", drop=dr.DropConfig(mode="det", selection="degree", p=0.4, tau_min=2, tau_max=4, seed=7)),
    dict(mode="jod", drop=dr.DropConfig(mode="prob", selection="random", p=0.4, seed=7, bloom_bits=1 << 12)),
    dict(mode="jod", drop=dr.DropConfig(mode="prob", selection="degree", p=0.4, tau_min=2, tau_max=4, seed=7, bloom_bits=1 << 12)),
    dict(mode="jod", store_capacity=3),  # capacity pressure → silent evictions? must stay correct via drop registry
]


def _check(engine, scratch, batches):
    np.testing.assert_array_equal(engine.answers(), scratch.answers())
    for batch in batches:
        engine.apply_updates(batch)
        scratch.apply_updates(batch)
        np.testing.assert_array_equal(engine.answers(), scratch.answers())


@pytest.mark.parametrize("kw", ENGINE_CONFIGS, ids=lambda k: str(k)[:60])
@settings(max_examples=12, deadline=None)
@given(wl=dynamic_graph_workload())
def test_sssp_matches_scratch(kw, wl):
    v, edges, batches = wl
    if kw.get("store_capacity") == 3 and kw.get("drop") is None:
        # bounded store needs a drop registry to stay correct under eviction
        kw = dict(kw, drop=dr.DropConfig(mode="det", selection="random", p=0.0))
    eng = q.sssp(DynamicGraph(v, edges, capacity=256), sources=[0, v // 2], max_iters=32, **kw)
    sc = scratch_like(eng.cfg, DynamicGraph(v, edges, capacity=256), eng.state.init)
    _check(eng, sc, batches)


@settings(max_examples=8, deadline=None)
@given(wl=dynamic_graph_workload())
def test_khop_matches_scratch(wl):
    v, edges, batches = wl
    eng = q.khop(DynamicGraph(v, edges, capacity=256), sources=[0, 1], k=4)
    sc = scratch_like(eng.cfg, DynamicGraph(v, edges, capacity=256), eng.state.init)
    _check(eng, sc, batches)


@settings(max_examples=8, deadline=None)
@given(wl=dynamic_graph_workload())
def test_wcc_matches_scratch(wl):
    v, edges, batches = wl
    sym = lambda es: [(u, w, 1.0) for (u, w, *_) in es] + [(w, u, 1.0) for (u, w, *_) in es]
    sym_batches = [
        [(u, w, l, x, s) for (u, w, l, x, s) in b] + [(w, u, l, x, s) for (u, w, l, x, s) in b]
        for b in batches
    ]
    eng = q.wcc(DynamicGraph(v, sym(edges), capacity=512), max_iters=32)
    sc = scratch_like(eng.cfg, DynamicGraph(v, sym(edges), capacity=512), eng.state.init)
    _check(eng, sc, sym_batches)


@settings(max_examples=6, deadline=None)
@given(wl=dynamic_graph_workload())
def test_pagerank_matches_scratch(wl):
    v, edges, batches = wl
    eng = q.pagerank(DynamicGraph(v, edges, capacity=256), iters=8)
    sc = scratch_like(eng.cfg, DynamicGraph(v, edges, capacity=256), eng.state.init)
    _check(eng, sc, batches)


@settings(max_examples=6, deadline=None)
@given(wl=dynamic_graph_workload(), data=st.data())
def test_rpq_matches_scratch_reachability(wl, data):
    v, edges, batches = wl
    # random 2-label assignment
    lbl_edges = [(u, w, x, data.draw(st.integers(1, 2))) for (u, w, x) in edges]
    lbl_batches = [
        [(u, w, data.draw(st.integers(1, 2)), x, s) for (u, w, _, x, s) in b]
        for b in batches
    ]
    rpq = q.RPQ(DynamicGraph(v, lbl_edges, capacity=256), q.NFA.concat_star(1, 2), sources=[0])
    sc = scratch_like(rpq.engine.cfg, _clone_pgraph(rpq), rpq.engine.state.init)
    np.testing.assert_array_equal(rpq.engine.answers(), sc.answers())
    for b in lbl_batches:
        ins_only = [u for u in b if u[4] > 0]  # label-keyed deletes are fiddly; insertions exercise the path
        if not ins_only:
            continue
        rpq.apply_updates(ins_only)
        sc.apply_updates(rpq._translate(ins_only))
        np.testing.assert_array_equal(rpq.engine.answers(), sc.answers())


def _clone_pgraph(rpq: q.RPQ) -> DynamicGraph:
    g = rpq.pgraph
    edges = [
        (int(g.src[e]), int(g.dst[e]), float(g.weight[e]))
        for e in np.nonzero(g.valid)[0]
    ]
    return DynamicGraph(g.num_vertices, edges, capacity=g.capacity)


def test_bloom_no_false_negatives():
    from repro.core import bloom as bl
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    flt = bl.make((2,), 1 << 12, num_hashes=4)
    v = jnp.asarray(rng.integers(0, 1000, size=(2, 64)), jnp.int32)
    i = jnp.asarray(rng.integers(0, 50, size=(2, 64)), jnp.int32)
    mask = jnp.asarray(rng.random((2, 64)) < 0.7)
    flt = bl.insert(flt, v, i, mask, salt=jnp.arange(2)[:, None])
    got = bl.query(flt, v, i, salt=jnp.arange(2)[:, None])
    assert bool(jnp.all(jnp.where(mask, got, True)))  # inserted ⇒ positive
