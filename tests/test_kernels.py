"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bloom import pack_bits


def _ell_inputs(rng, q, v, d, semiring):
    nbr = rng.integers(0, v + 1, size=(v, d)).astype(np.int32)  # v = identity slot
    w = rng.integers(1, 10, size=(v, d)).astype(np.float32)
    if semiring == "pr_sum":
        states = np.concatenate(
            [rng.random((q, v), np.float32), np.zeros((q, 1), np.float32)], 1
        )
        carry = np.full((q, v), 0.15, np.float32)
    else:
        states = np.concatenate(
            [rng.random((q, v), np.float32) * 10, np.full((q, 1), np.inf, np.float32)], 1
        )
        carry = rng.random((q, v)).astype(np.float32) * 10
    return jnp.asarray(states), jnp.asarray(nbr), jnp.asarray(w), jnp.asarray(carry)


@pytest.mark.parametrize("semiring", ["min_plus", "min_hop", "min_label", "pr_sum"])
@pytest.mark.parametrize("q,v,d", [(1, 16, 4), (3, 100, 8), (2, 257, 16), (4, 128, 32)])
def test_ell_spmv_matches_ref(semiring, q, v, d):
    rng = np.random.default_rng(hash((semiring, q, v, d)) % 2**31)
    states, nbr, w, carry = _ell_inputs(rng, q, v, d, semiring)
    got = ops.spmv(states, nbr, w, carry, semiring=semiring, block_v=64, interpret=True)
    want = ref.ell_spmv_ref(states, nbr, w, carry, semiring=semiring)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("n,s", [(8, 4), (100, 8), (513, 16), (1024, 32)])
def test_diff_lookup_matches_ref(n, s):
    rng = np.random.default_rng(n * 1000 + s)
    iters = np.sort(rng.integers(0, 60, size=(n, s)), axis=1).astype(np.int32)
    counts = rng.integers(0, s + 1, size=n)
    imax = np.iinfo(np.int32).max
    for r in range(n):
        iters[r, counts[r]:] = imax
    vals = rng.random((n, s)).astype(np.float32)
    qi = rng.integers(0, 70, size=n).astype(np.int32)
    gv, gi, gf = ops.lookup(jnp.asarray(iters), jnp.asarray(vals), jnp.asarray(qi),
                            block_n=128, interpret=True)
    wv, wi, wf = ref.diff_lookup_ref(jnp.asarray(iters), jnp.asarray(vals), jnp.asarray(qi))
    np.testing.assert_array_equal(gf, wf)
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_allclose(np.where(gf, gv, 0), np.where(wf, wv, 0), rtol=1e-6)


@pytest.mark.parametrize("q,n,mbits,k", [(1, 64, 1 << 10, 2), (3, 500, 1 << 12, 4), (2, 1024, 1 << 14, 6)])
def test_bloom_kernel_matches_ref_and_filter(q, n, mbits, k):
    from repro.core import bloom as bl

    rng = np.random.default_rng(q * n)
    flt = bl.make((q,), mbits, num_hashes=k)
    v = jnp.asarray(rng.integers(0, 5000, size=(q, n)), jnp.int32)
    i = jnp.asarray(rng.integers(0, 64, size=(q, n)), jnp.int32)
    mask = jnp.asarray(rng.random((q, n)) < 0.5)
    salt = jnp.arange(q, dtype=jnp.int32)
    flt = bl.insert(flt, v, i, mask, salt=salt[:, None])
    words = pack_bits(flt.bits)

    got = ops.bloom(words, v, i, salt, num_hashes=k, block_n=256, interpret=True)
    want = ref.bloom_query_ref(words, v, i, salt, num_hashes=k)
    np.testing.assert_array_equal(got, want)
    # kernel agrees with the pure filter, and never false-negatives
    pure = bl.query(flt, v, i, salt=salt[:, None])
    np.testing.assert_array_equal(got, pure)
    assert bool(jnp.all(jnp.where(mask, got, True)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d,causal",
    [
        (1, 2, 2, 128, 128, 64, True),
        (2, 4, 2, 256, 256, 32, True),   # GQA 2:1
        (1, 8, 1, 128, 256, 64, False),  # MQA, cross-length
    ],
)
def test_flash_attention_matches_ref(b, hq, hkv, sq, sk, d, causal, dtype):
    rng = np.random.default_rng(sq + sk + hq)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    got = ops.attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_engine_step_equals_kernel_spmv():
    """The Pallas kernel computes the same IFE step as the engine's segment path."""
    from repro.core import queries as q
    from repro.core.engine import GraphArrays, ife_step
    from repro.core.graph import DynamicGraph
    from repro.data.graphgen import powerlaw_graph

    edges = powerlaw_graph(60, 240, seed=5)
    g = DynamicGraph(60, edges, capacity=512)
    eng = q.sssp(g, sources=[0, 7], max_iters=48)
    snap = g.snapshot()
    nbr, w, _ = snap.to_ell()
    cur = eng.state.cur
    states = jnp.concatenate([cur, jnp.full((2, 1), jnp.inf)], axis=1)
    got = ops.spmv(states, jnp.asarray(nbr), jnp.asarray(w), cur, semiring="min_plus", interpret=True)
    want = ife_step(eng.cfg, cur, GraphArrays.from_snapshot(snap))
    np.testing.assert_allclose(got, want)
