"""End-to-end behaviour: the paper's main experiment at reduced scale.

A continuous query processor registering 6 SPSP queries on a power-law
graph, ingesting 20 single-edge batches (mixed ins/del), with every system
configuration (VDC / JOD / Det-Drop / Prob-Drop × Degree) agreeing with
SCRATCH, and the memory ordering VDC > JOD > dropped configurations holding.
"""

import numpy as np

from repro.core import dropping as dr
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.scratch import scratch_like
from repro.data.graphgen import powerlaw_graph, update_stream


def _workload(seed=0, v=48, e=180, batches=20):
    g_edges = powerlaw_graph(v, e, seed=seed, weighted=True)
    stream = update_stream(g_edges, v, num_batches=batches, batch_size=1,
                           delete_fraction=0.25, seed=seed + 1)
    return g_edges, stream


CONFIGS = {
    "vdc": dict(mode="vdc"),
    "jod": dict(mode="jod"),
    "det-degree": dict(
        mode="jod",
        drop=dr.DropConfig(mode="det", selection="degree", p=0.5, tau_min=2, tau_max=12, seed=1),
    ),
    "prob-degree": dict(
        mode="jod",
        drop=dr.DropConfig(mode="prob", selection="degree", p=0.5, tau_min=2, tau_max=12, seed=1, bloom_bits=1 << 13),
    ),
}


def test_continuous_queries_end_to_end():
    edges, stream = _workload()
    v = 48
    sources = [0, 5, 11, 17, 23, 31]
    engines = {
        name: q.sssp(DynamicGraph(v, edges, capacity=1024), sources, max_iters=48, **kw)
        for name, kw in CONFIGS.items()
    }
    ref_cfg = engines["jod"].cfg
    scratch = scratch_like(ref_cfg, DynamicGraph(v, edges, capacity=1024), engines["jod"].state.init)

    for batch in stream:
        for eng in engines.values():
            eng.apply_updates(batch)
        scratch.apply_updates(batch)
        want = scratch.answers()
        for name, eng in engines.items():
            np.testing.assert_array_equal(eng.answers(), want, err_msg=name)

    nbytes = {name: eng.nbytes() for name, eng in engines.items()}
    assert nbytes["jod"] < nbytes["vdc"], nbytes  # JOD drops δJ entirely
    # dropped configs store fewer D-diffs than plain JOD
    assert int(engines["det-degree"].state.dstore.count.sum()) <= int(
        engines["jod"].state.dstore.count.sum()
    )
    # differential work ≪ scratch work (the paper's core claim, Table 1)
    jod_work = int(engines["jod"].last_stats.scheduled)
    scratch_work = int(scratch.last_stats.scheduled)
    assert jod_work < scratch_work


def test_memory_budget_scalability_shape():
    """More queries → more diff bytes; dropping reduces stored diffs at same Q."""
    edges, stream = _workload(seed=3)
    v = 48
    byts = {}
    for nq in (2, 6):
        eng = q.sssp(DynamicGraph(v, edges, capacity=1024), list(range(nq)), max_iters=48)
        for batch in stream[:5]:
            eng.apply_updates(batch)
        byts[nq] = eng.nbytes()
    assert byts[6] > byts[2]

    dropped = q.sssp(
        DynamicGraph(v, edges, capacity=1024),
        list(range(6)),
        max_iters=48,
        drop=dr.DropConfig(mode="prob", selection="degree", p=0.9, tau_min=2, tau_max=10, seed=0, bloom_bits=1 << 10),
    )
    for batch in stream[:5]:
        dropped.apply_updates(batch)
    assert int(dropped.state.dstore.count.sum()) < byts[6] // 8
