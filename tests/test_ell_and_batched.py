"""Parity: ELL kernel backend == COO backend == host engines, batched == seq.

The two new paths of the batched CQP pipeline are exercised against every
existing oracle:

* ``backend="ell"`` (Pallas bucketed-ELL SpMV, interpret-mode on CPU) must
  equal the dense COO segment-reduce backend, the host ``SparseDiffIFE``,
  and SCRATCH on random insert+delete streams (min_plus and min_hop).
* ``apply_updates_batched`` (donated-buffer batched step) must equal the
  per-update path on both backends — including one batched chunk of B
  updates vs B sequential single-update sweeps, the ELL width-growth
  (re-trace) fallback, and the degree-derived-weight (PageRank) dirty rule.
"""

import numpy as np
import pytest

from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.scratch import scratch_like
from repro.core.sparse_engine import SparseDiffIFE

V = 24
MAX_ITERS = 24


def random_workload(seed: int, v: int = V, e: int = 96, num_batches: int = 4):
    """(initial edges, update batches) with insertion + deletion mixes."""
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < e:
        u, w = int(rng.integers(0, v)), int(rng.integers(0, v))
        if u != w:
            seen[(u, w)] = (u, w, float(rng.integers(1, 10)))
    edges = list(seen.values())
    initial, pool = edges[: e * 3 // 4], edges[e * 3 // 4 :]
    present = {(u, w) for (u, w, _x) in initial}
    batches = []
    for _ in range(num_batches):
        batch = []
        for _ in range(int(rng.integers(2, 5))):
            if present and rng.random() < 0.4:
                u, w = sorted(present)[int(rng.integers(0, len(present)))]
                batch.append((u, w, 0, 1.0, -1))
                present.discard((u, w))
            elif pool:
                u, w, x = pool.pop()
                batch.append((u, w, 0, x, +1))
                present.add((u, w))
        batches.append(batch)
    return initial, batches


def _make(initial, semiring: str, backend: str, batch_capacity: int = 8):
    g = DynamicGraph(V, initial, capacity=512)
    if semiring == "min_plus":
        return q.sssp(g, [0, V // 2], max_iters=MAX_ITERS, backend=backend,
                      batch_capacity=batch_capacity)
    return q.khop(g, [0, V // 2], k=4, backend=backend,
                  batch_capacity=batch_capacity)


@pytest.mark.parametrize("semiring", ["min_plus", "min_hop"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ell_equals_coo_and_sparse(semiring, seed):
    initial, batches = random_workload(seed)
    coo = _make(initial, semiring, "coo")
    ell = _make(initial, semiring, "ell")
    khop = 4 if semiring == "min_hop" else None
    sparse = SparseDiffIFE(
        DynamicGraph(V, initial, capacity=512), [0, V // 2],
        max_iters=(khop or MAX_ITERS), khop=khop,
    )
    np.testing.assert_array_equal(coo.answers(), ell.answers())
    np.testing.assert_array_equal(coo.answers(), sparse.answers())
    for batch in batches:
        coo.apply_updates(batch)
        ell.apply_updates(batch)
        sparse.apply_updates(batch)
        np.testing.assert_array_equal(coo.answers(), ell.answers())
        np.testing.assert_array_equal(coo.answers(), sparse.answers())


def test_ell_equals_scratch():
    initial, batches = random_workload(seed=7)
    ell = _make(initial, "min_plus", "ell")
    scratch = scratch_like(
        ell.cfg, DynamicGraph(V, initial, capacity=512), ell.state.init
    )
    for batch in batches:
        ell.apply_updates(batch)
        scratch.apply_updates(batch)
        np.testing.assert_array_equal(ell.answers(), scratch.answers())


@pytest.mark.parametrize("backend", ["coo", "ell"])
def test_batched_stream_equals_sequential(backend):
    initial, batches = random_workload(seed=3)
    log = [u for b in batches for u in b]
    seq = _make(initial, "min_plus", backend)
    bat = _make(initial, "min_plus", backend, batch_capacity=4)
    for u in log:
        seq.apply_updates([u])
    bat.apply_updates_batched(log, batch_size=4)
    np.testing.assert_array_equal(seq.answers(), bat.answers())


@pytest.mark.parametrize("backend", ["coo", "ell"])
def test_one_batched_chunk_equals_b_single_steps(backend):
    """One batched step of B updates == B sequential single-update sweeps."""
    initial, batches = random_workload(seed=5, num_batches=2)
    updates = [u for b in batches for u in b][:6]
    b = len(updates)
    seq = _make(initial, "min_plus", backend)
    bat = _make(initial, "min_plus", backend, batch_capacity=b)
    for u in updates:
        seq.apply_updates([u])
    stats = bat.apply_updates_batched(updates)  # one chunk, one dispatch
    np.testing.assert_array_equal(seq.answers(), bat.answers())
    assert int(stats.iters_run) > 0


def test_batched_ell_width_growth():
    """Inserts that outrun the fixed ELL width trigger the rebuild fallback."""
    initial = [(i, i + 1, 1.0) for i in range(10)]
    ell = q.sssp(DynamicGraph(12, initial, capacity=256), [0], max_iters=16,
                 backend="ell", batch_capacity=4)
    ref = q.sssp(DynamicGraph(12, initial, capacity=256), [0], max_iters=16)
    w0 = ell._ell_width
    hub = [(i, 11, 0, 1.0, +1) for i in range(11)]  # in-degree 11 > width 8
    ell.apply_updates_batched(hub, batch_size=4)
    ref.apply_updates(hub)
    assert ell._ell_width > w0
    np.testing.assert_array_equal(ell.answers(), ref.answers())


def test_batched_pagerank_degree_dirty_rule():
    """Degree-derived weights: the batched dirty mask must retune siblings."""
    initial, batches = random_workload(seed=9)
    log = [u for b in batches for u in b]
    seq = q.pagerank(DynamicGraph(V, initial, capacity=512), iters=8)
    bat = q.pagerank(DynamicGraph(V, initial, capacity=512), iters=8,
                     backend="ell", batch_capacity=4)
    for u in log:
        seq.apply_updates([u])
    bat.apply_updates_batched(log, batch_size=4)
    np.testing.assert_allclose(seq.answers(), bat.answers(), rtol=1e-6)
