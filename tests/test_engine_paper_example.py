"""The paper's running example (Fig. 2 / Table 3) plus basic engine checks."""

import numpy as np
import pytest

from repro.core import dropping as dr
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.scratch import scratch_like


def fig2_graph() -> DynamicGraph:
    # a=0, b=1, c=2, d=3, e=4
    edges = [
        (0, 1, 30.0),
        (1, 2, 10.0),
        (2, 3, 10.0),
        (0, 3, 20.0),
        (3, 4, 10.0),
        (0, 4, 10.0),
        (3, 2, 20.0),
    ]
    return DynamicGraph(5, edges, capacity=16)


FIG2_UPDATES = [
    # G1: (a, d) weight 20 → 100
    [(0, 3, 0, 100.0, +1)],
    # G2: (b, c) weight 10 → 100
    [(1, 2, 0, 100.0, +1)],
]

# ground-truth SSSP distances from a after each version (hand-checked
# against Table 3's difference trace)
DIST_G0 = np.array([0.0, 30.0, 40.0, 20.0, 10.0])
DIST_G1 = np.array([0.0, 30.0, 40.0, 50.0, 10.0])
DIST_G2 = np.array([0.0, 30.0, 120.0, 100.0, 10.0])


@pytest.mark.parametrize("mode", ["vdc", "jod"])
def test_fig2_trace(mode):
    eng = q.sssp(fig2_graph(), sources=[0], mode=mode, max_iters=16)
    np.testing.assert_allclose(eng.answers()[0], DIST_G0)
    eng.apply_updates(FIG2_UPDATES[0])
    np.testing.assert_allclose(eng.answers()[0], DIST_G1)
    eng.apply_updates(FIG2_UPDATES[1])
    np.testing.assert_allclose(eng.answers()[0], DIST_G2)


def test_fig2_jod_stores_fewer_diffs_than_vdc():
    jod = q.sssp(fig2_graph(), sources=[0], mode="jod", max_iters=16)
    vdc = q.sssp(fig2_graph(), sources=[0], mode="vdc", max_iters=16)
    for batch in FIG2_UPDATES:
        jod.apply_updates(batch)
        vdc.apply_updates(batch)
    assert jod.nbytes() < vdc.nbytes()
    np.testing.assert_allclose(jod.answers(), vdc.answers())


@pytest.mark.parametrize(
    "drop_cfg",
    [
        dr.DropConfig(mode="det", selection="random", p=0.5, seed=3),
        dr.DropConfig(mode="prob", selection="random", p=0.5, seed=3, bloom_bits=1 << 12),
        dr.DropConfig(mode="det", selection="degree", p=0.5, tau_min=2, tau_max=3, seed=3),
        dr.DropConfig(mode="prob", selection="degree", p=0.5, tau_min=2, tau_max=3, seed=3, bloom_bits=1 << 12),
    ],
)
def test_fig2_with_dropping_matches_scratch(drop_cfg):
    eng = q.sssp(fig2_graph(), sources=[0], mode="jod", max_iters=16, drop=drop_cfg)
    np.testing.assert_allclose(eng.answers()[0], DIST_G0)
    eng.apply_updates(FIG2_UPDATES[0])
    np.testing.assert_allclose(eng.answers()[0], DIST_G1)
    eng.apply_updates(FIG2_UPDATES[1])
    np.testing.assert_allclose(eng.answers()[0], DIST_G2)


def test_deletion():
    eng = q.sssp(fig2_graph(), sources=[0], max_iters=16)
    # delete (a, e): e now reached via d (a→d 20, d→e 10 → 30)
    eng.apply_updates([(0, 4, 0, 10.0, -1)])
    np.testing.assert_allclose(eng.answers()[0], [0.0, 30.0, 40.0, 20.0, 30.0])
    # delete (a, d) too: d via b→c→d = 50, e via d = 60
    eng.apply_updates([(0, 3, 0, 20.0, -1)])
    np.testing.assert_allclose(eng.answers()[0], [0.0, 30.0, 40.0, 50.0, 60.0])


def test_scratch_agrees():
    eng = q.sssp(fig2_graph(), sources=[0, 1], max_iters=16)
    sc = scratch_like(eng.cfg, fig2_graph(), eng.state.init)
    for batch in FIG2_UPDATES:
        eng.apply_updates(batch)
        sc.apply_updates(batch)
        np.testing.assert_allclose(eng.answers(), sc.answers())


def test_khop_and_wcc_and_pagerank_run():
    kh = q.khop(fig2_graph(), sources=[0], k=2)
    reach = q.khop_reachable(kh)[0]
    assert reach.tolist() == [True, True, True, True, True]
    kh.apply_updates([(0, 1, 0, 30.0, -1), (0, 3, 0, 20.0, -1), (0, 4, 0, 10.0, -1)])
    assert q.khop_reachable(kh)[0].tolist() == [True, False, False, False, False]

    sym = [(int(u), int(v), 1.0) for u, v in [(0, 1), (1, 0), (2, 3), (3, 2)]]
    w = q.wcc(DynamicGraph(5, sym, capacity=32), max_iters=16)
    assert w.answers()[0].tolist() == [0.0, 0.0, 2.0, 2.0, 4.0]
    w.apply_updates([(1, 2, 0, 1.0, +1), (2, 1, 0, 1.0, +1)])
    assert w.answers()[0].tolist() == [0.0, 0.0, 0.0, 0.0, 4.0]

    pr = q.pagerank(fig2_graph(), iters=10)
    before = pr.answers()[0].copy()
    assert np.all(np.isfinite(before)) and before.min() > 0
    pr.apply_updates([(4, 0, 0, 1.0, +1)])
    after = pr.answers()[0]
    assert not np.allclose(before, after)  # e gained an out-edge → a gains rank


def test_rpq_q1_star():
    # labels: 1 = Knows.  a -K> b -K> c, a -X> d
    edges = [(0, 1, 1.0, 1), (1, 2, 1.0, 1), (0, 3, 1.0, 2)]
    g = DynamicGraph(4, edges, capacity=16)
    rpq = q.RPQ(g, q.NFA.star(1), sources=[0])
    assert rpq.reachable()[0].tolist() == [True, True, True, False]
    rpq.apply_updates([(2, 3, 1, 1.0, +1)])  # c -K> d
    assert rpq.reachable()[0].tolist() == [True, True, True, True]
    rpq.apply_updates([(1, 2, 1, 1.0, -1)])  # remove b -K> c
    assert rpq.reachable()[0].tolist() == [True, True, False, False]
