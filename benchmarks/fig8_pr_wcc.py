"""Figure 8: PageRank / WCC under a tight memory budget (Det vs Prob).

Single 'query' batch computations: find the smallest drop probability at
which the diff footprint fits the budget, then compare Det-Drop vs
Prob-Drop runtime.  Prob-Drop should need a lower p (its DroppedVT is
constant-size) and thus run no slower — the paper's Fig. 8 conclusion.
"""

from __future__ import annotations

from benchmarks.common import DROP_DEGREE, emit, paper_workload, run_stream
from repro.core import queries as q
from repro.core.graph import DynamicGraph


def find_p(make, budget, stream):
    for p in (0.0, 0.3, 0.5, 0.7, 0.9, 1.0):
        eng = make(p)
        t = run_stream(eng, stream)
        if eng.nbytes() <= budget:
            return p, t, eng.nbytes()
    return None


def main() -> None:
    v = 256
    initial, stream = paper_workload(v=v, e=1024, num_batches=8)
    cap = len(initial) * 4 + 64

    # WCC on symmetrized graph
    sym = initial + [(b, a, w) for (a, b, w) in initial]
    sym_stream = [bat + [(y, x, l, w, s) for (x, y, l, w, s) in bat] for bat in stream]
    for mode in ("det", "prob"):
        got = find_p(
            lambda p: q.wcc(DynamicGraph(v, sym, capacity=4 * len(sym) + 64),
                            max_iters=64, drop=DROP_DEGREE(p, mode)),
            budget=6 * 1024, stream=sym_stream,
        )
        if got:
            emit(f"fig8/wcc_{mode}", got[1] / len(sym_stream), f"p={got[0]};bytes={got[2]}")
        else:
            emit(f"fig8/wcc_{mode}", 0.0, "DID_NOT_FIT (DroppedVT floor)")

    for mode in ("det", "prob"):
        got = find_p(
            lambda p: q.pagerank(DynamicGraph(v, initial, capacity=cap),
                                 iters=10, drop=DROP_DEGREE(p, mode)),
            budget=8 * 1024, stream=stream,
        )
        if got:
            emit(f"fig8/pagerank_{mode}", got[1] / len(stream), f"p={got[0]};bytes={got[2]}")
        else:
            emit(f"fig8/pagerank_{mode}", 0.0,
                 "DID_NOT_FIT at any p (Det-Drop d/(d+s) floor — paper Fig8 needs 100% drop)")


if __name__ == "__main__":
    main()
