"""Figure 4: SCRATCH / VDC / JOD across query classes — time and memory.

VDC materializes δJ (memory ∝ E); JOD drops it (§4).  Expected shape:
JOD memory < VDC memory (paper: 1.2×–5.5×), both ≪ SCRATCH recompute work.
Runs SPSP, K-hop, WCC, PageRank and an RPQ on a labelled graph.
"""

from __future__ import annotations


from benchmarks.common import emit, paper_workload, run_stream
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.core.scratch import scratch_like
from repro.data.graphgen import ldbc_like_graph, split_90_10, update_stream


def _compare(name, make_engine, initial, stream, v):
    engines = {
        "vdc": make_engine(mode="vdc"),
        "jod": make_engine(mode="jod"),
    }
    for label, eng in engines.items():
        t = run_stream(eng, stream)
        emit(f"fig4/{name}/{label}", t / len(stream), f"bytes={eng.nbytes()}")
    sc = scratch_like(
        engines["jod"].cfg,
        DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
        engines["jod"].state.init,
    )
    t = run_stream(sc, stream)
    emit(f"fig4/{name}/scratch", t / len(stream), "bytes=0")
    ratio = engines["vdc"].nbytes() / max(engines["jod"].nbytes(), 1)
    emit(f"fig4/{name}/jod_memory_ratio", 0.0, f"vdc_over_jod={ratio:.2f}")


def main() -> None:
    v = 256
    initial, stream = paper_workload(v=v, e=1024, num_batches=10)
    cap = len(initial) * 4 + 64

    _compare(
        "spsp",
        lambda **kw: q.sssp(DynamicGraph(v, initial, capacity=cap), [0, 1, 2, 3], max_iters=48, **kw),
        initial, stream, v,
    )
    _compare(
        "khop",
        lambda **kw: q.khop(DynamicGraph(v, initial, capacity=cap), [0, 1, 2, 3], k=5, **kw),
        initial, stream, v,
    )
    sym = initial + [(b, a, w) for (a, b, w) in initial]
    sym_stream = [bat + [(y, x, l, w, s) for (x, y, l, w, s) in bat] for bat in stream]
    _compare(
        "wcc",
        lambda **kw: q.wcc(DynamicGraph(v, sym, capacity=4 * len(sym) + 64), max_iters=64, **kw),
        sym, sym_stream, v,
    )
    _compare(
        "pagerank",
        lambda **kw: q.pagerank(DynamicGraph(v, initial, capacity=cap), iters=10, **kw),
        initial, stream, v,
    )

    # RPQ Q1/Q2 on a labelled (LDBC-like) graph
    lg = ldbc_like_graph(v, 1024, seed=3)
    linit, lpool = split_90_10(lg, seed=3)
    lstream = update_stream(linit, v, num_batches=10, insert_pool=lpool, seed=4)
    for qname, nfa in [("rpq_q1", q.NFA.star(1)), ("rpq_q2", q.NFA.concat_star(1, 2))]:
        for mode in ("vdc", "jod"):
            rpq = q.RPQ(DynamicGraph(v, linit, capacity=4 * len(linit) + 64),
                        nfa, sources=[0, 1], mode=mode)
            t = run_stream(rpq, lstream)
            emit(f"fig4/{qname}/{mode}", t / len(lstream), f"bytes={rpq.nbytes()}")


if __name__ == "__main__":
    main()
