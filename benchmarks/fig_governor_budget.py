"""Memory governor budget sweep: bytes held vs update throughput, closed
loop (repo-native; the paper's Fig. 7 memory axis operated online).

A dense CQPSession serves Q standing SSSP queries over a chunked δE log
three ways: the static ``none`` baseline (no dropping — the paper's DC
memory ceiling), then under the memory governor at budgets set to fractions
of the baseline's observed peak.  The governor escalates per-query drop
policies along the ladder (Prob-Drop representation: fixed per-query Bloom
rows, the deepest reclamation) and sheds stored diffs in place, so peak
accounted bytes must track the budget while answers stay exactly equal to
the from-scratch oracle on the final graph.

Emits the usual CSV rows plus one JSON summary line
(``fig_governor_budget JSON: {...}``) with the static peak, each budget
run's settled peak / reduction / throughput, and the exact-answer check —
the closed-loop acceptance artifact (≥30 % peak reduction at equal answer
correctness).
"""

from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit, paper_workload
from repro.core import plan
from repro.core.governor import GovernorConfig
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession

V = 128
Q = 4
MAX_ITERS = 32
BATCH = 8
BLOOM_BITS = 1 << 8  # 32 B packed per query
BUDGET_FRACS = (0.7, 0.5, 0.35)


def _plans():
    return [plan.sssp(s * (V // Q), max_iters=MAX_ITERS) for s in range(Q)]


def _session(initial, budget=None):
    kw = {}
    if budget is not None:
        kw = dict(
            budget_bytes=budget,
            governor=GovernorConfig(representation="prob", bloom_bits=BLOOM_BITS),
        )
    return CQPSession(
        DynamicGraph(V, initial, capacity=len(initial) * 4 + 64),
        engine="dense",
        batch_capacity=BATCH,
        min_slots=Q,
        **kw,
    )


def _run(session, chunks):
    handles = session.register_many(_plans())
    session.apply_updates_batched(chunks[0], batch_size=BATCH)  # compile
    served = 0
    peak = session.nbytes()
    settled_peak = 0
    t0 = time.perf_counter()
    for k, chunk in enumerate(chunks[1:], start=1):
        session.apply_updates_batched(chunk, batch_size=BATCH)
        served += len(chunk)
        peak = max(peak, session.nbytes())
        if k > 2:  # governor settling window, as in cqp_serve
            settled_peak = max(settled_peak, session.nbytes())
    if len(chunks) <= 3:  # no post-settle sample: judge the final state
        settled_peak = session.nbytes()
    return {
        "t": time.perf_counter() - t0,
        "served": served,
        "peak": peak,
        "settled_peak": settled_peak,
        "answers": [session.answers(h) for h in handles],
    }


def main() -> None:
    initial, stream = paper_workload(
        v=V, e=512, num_batches=32, batch_size=BATCH, delete_fraction=0.2, seed=9
    )
    log = [u for batch in stream for u in batch]
    chunks = [log[i : i + BATCH] for i in range(0, len(log), BATCH)]

    # from-scratch oracle on the final graph (SSSP answers depend only on it)
    final_graph = DynamicGraph(V, initial, capacity=len(initial) * 4 + 64)
    final_graph.apply_batch(log)
    oracle = CQPSession(final_graph, engine="scratch")
    oracle_rows = [oracle.answers(h) for h in oracle.register_many(_plans())]

    def exact(rows):
        return all(
            np.array_equal(a, b) for a, b in zip(rows, oracle_rows)
        )

    base = _run(_session(initial), chunks)
    emit(
        "fig_governor_budget/static_none",
        base["t"] * 1e6 / base["served"],
        f"upd_per_s={base['served'] / base['t']:.1f};"
        f"peak_bytes={base['peak']};exact={int(exact(base['answers']))}",
    )

    summary = {
        "static_peak_bytes": int(base["peak"]),
        "static_updates_per_sec": base["served"] / base["t"],
        "static_answers_exact": exact(base["answers"]),
        "governor": [],
    }
    for frac in BUDGET_FRACS:
        budget = int(base["peak"] * frac)
        s = _session(initial, budget=budget)
        run = _run(s, chunks)
        gov = s.governor
        reduction = 1.0 - run["settled_peak"] / base["peak"]
        row = {
            "budget_bytes": budget,
            "budget_frac": frac,
            "settled_peak_bytes": int(run["settled_peak"]),
            "peak_bytes": int(run["peak"]),
            "peak_reduction_vs_static": round(reduction, 3),
            "budget_respected": bool(run["settled_peak"] <= budget),
            "updates_per_sec": run["served"] / run["t"],
            "answers_exact": exact(run["answers"]),
            "escalations": sum(1 for a in gov.actions if a.kind == "escalate"),
            "deescalations": sum(
                1 for a in gov.actions if a.kind == "deescalate"
            ),
        }
        summary["governor"].append(row)
        emit(
            f"fig_governor_budget/budget_{int(frac * 100)}pct",
            run["t"] * 1e6 / run["served"],
            f"upd_per_s={row['updates_per_sec']:.1f};"
            f"budget={budget};settled_peak={row['settled_peak_bytes']};"
            f"reduction={reduction:.0%};respected={int(row['budget_respected'])};"
            f"exact={int(row['answers_exact'])};"
            f"actions={row['escalations']}+{row['deescalations']}",
        )
    print("fig_governor_budget JSON:", json.dumps(summary))


if __name__ == "__main__":
    main()
