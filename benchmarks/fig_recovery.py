"""Durability cost/benefit: checkpoint overhead and restore-vs-replay gain.

Two rows for the DESIGN.md §12 recovery story, measured on a dense-engine
session serving the standard smoke workload:

* ``fig_recovery/checkpoint`` — mean wall time of one synchronous session
  checkpoint; ``derived`` reports the serving-time overhead percentage of
  checkpointing every K chunks, plus checkpoint bytes vs live accounted
  diff-store bytes (the snapshot carries the full arrays, the live figure
  only the accounted trace — their ratio is the durability tax on disk).
* ``fig_recovery/restore`` — wall time of restore-latest + replay of the
  post-checkpoint log suffix, against a cold *genesis replay* (rebuild the
  session from the initial graph and re-ingest the whole log); ``derived``
  carries the speedup, the number the checkpoint cadence buys at MTTR time.
"""

from __future__ import annotations

import tempfile
import time

from benchmarks.common import emit
from repro.core import plan as qplan
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession
from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream

V, E, QUERIES, UPDATES, BATCH, MAX_ITERS = 64, 256, 4, 64, 8, 24
EVERY = 2  # checkpoint every K chunks


def _workload():
    edges = powerlaw_graph(V, E, seed=0)
    initial, pool = split_90_10(edges, seed=0)
    stream = update_stream(
        initial, V, num_batches=UPDATES // BATCH, batch_size=BATCH,
        insert_pool=pool, delete_fraction=0.2, seed=1,
    )
    log = [u for batch in stream for u in batch]
    chunks = [log[i : i + BATCH] for i in range(0, len(log), BATCH)]
    return initial, chunks


def _session(initial):
    graph = DynamicGraph(V, initial, capacity=E * 4 + 64)
    s = CQPSession(
        graph, engine="dense", batch_capacity=BATCH, min_slots=QUERIES
    )
    s.register_many(
        [qplan.sssp(i, max_iters=MAX_ITERS) for i in range(QUERIES)]
    )
    return s


def main() -> None:
    initial, chunks = _workload()

    # baseline serve (warm chunk 0 first so compile stays out of both sides)
    s = _session(initial)
    s.apply_updates_batched(chunks[0], batch_size=BATCH)
    t0 = time.perf_counter()
    for c in chunks[1:]:
        s.apply_updates_batched(c, batch_size=BATCH)
    t_plain = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        s = _session(initial)
        s.apply_updates_batched(chunks[0], batch_size=BATCH)
        ckpt_s = []
        t0 = time.perf_counter()
        for k, c in enumerate(chunks[1:], start=1):
            s.apply_updates_batched(c, batch_size=BATCH)
            if (k + 1) % EVERY == 0:
                t1 = time.perf_counter()
                s.checkpoint(d, step=k + 1, extra={"next_chunk": k + 1})
                ckpt_s.append(time.perf_counter() - t1)
        t_ckpt = time.perf_counter() - t0
        arrays, _meta = s.state_dict()
        ckpt_bytes = sum(int(a.nbytes) for a in arrays.values())
        live_bytes = s.nbytes()
        overhead_pct = 100.0 * max(t_ckpt - t_plain, 0.0) / t_plain
        emit(
            "fig_recovery/checkpoint",
            sum(ckpt_s) / len(ckpt_s) * 1e6,
            f"overhead_pct={overhead_pct:.1f};every={EVERY};"
            f"ckpt_bytes={ckpt_bytes};live_bytes={live_bytes}",
        )

        # crash after the last chunk: restore latest + replay the suffix
        t0 = time.perf_counter()
        r = CQPSession.restore(d)
        cursor = int(r.restore_info["extra"]["next_chunk"])
        for c in chunks[cursor:]:
            r.apply_updates_batched(c, batch_size=BATCH)
        t_restore = time.perf_counter() - t0

        # genesis replay: no checkpoint, recompute everything from scratch
        t0 = time.perf_counter()
        g = _session(initial)
        for c in chunks:
            g.apply_updates_batched(c, batch_size=BATCH)
        t_genesis = time.perf_counter() - t0
        assert (
            r.nbytes_per_operator() == g.nbytes_per_operator()
        ), "restore+replay must land on the genesis-replay state"
        emit(
            "fig_recovery/restore",
            t_restore * 1e6,
            f"genesis_us={t_genesis * 1e6:.1f};"
            f"speedup={t_genesis / max(t_restore, 1e-9):.2f};"
            f"replayed_chunks={len(chunks) - cursor};"
            f"total_chunks={len(chunks)}",
        )


if __name__ == "__main__":
    main()
