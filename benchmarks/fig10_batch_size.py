"""Figure 10 (Appendix A): batch-size sweep — DC/SCRATCH time ratio.

The paper: DC is dramatically faster at batch size 1 and loses to SCRATCH
as batches grow past ~100K edges.  We sweep batch size at a fixed total
update count on the JOD engine and report the ratio (algorithmic work
ratio as `derived` — the machine-neutral signal).
"""

from __future__ import annotations

from benchmarks.common import emit, make_khop, run_stream
from repro.core.graph import DynamicGraph
from repro.core.scratch import scratch_like
from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream


def main() -> None:
    v = 256
    total_updates = 64
    edges = powerlaw_graph(v, 1024, seed=0, weighted=False)
    initial, pool = split_90_10(edges, seed=0)
    for bs in (1, 4, 16, 64):
        stream = update_stream(
            initial, v, num_batches=total_updates // bs, batch_size=bs,
            insert_pool=list(pool), seed=9,
        )
        eng = make_khop(initial, v, list(range(4)))
        t_dc = run_stream(eng, stream)
        sc = scratch_like(eng.cfg, DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
                          eng.state.init)
        t_sc = run_stream(sc, stream)
        work_ratio = int(eng.last_stats.scheduled) / max(int(sc.last_stats.scheduled), 1)
        emit(f"fig10/batch{bs}", t_dc / len(stream),
             f"dc_over_scratch_time={t_dc / max(t_sc, 1e-9):.2f};work_ratio={work_ratio:.3f}")


if __name__ == "__main__":
    main()
