"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``python -m benchmarks.run``
runs everything; ``--only fig4`` filters.
"""

from __future__ import annotations

import argparse
import importlib
import time
import traceback

MODULES = [
    "benchmarks.table1_scaling",
    "benchmarks.fig4_baselines",
    "benchmarks.fig5_degree_sweep",
    "benchmarks.fig6_drop_selection",
    "benchmarks.fig7_memory_scalability",
    "benchmarks.fig8_pr_wcc",
    "benchmarks.fig9_landmark",
    "benchmarks.fig10_batch_size",
    "benchmarks.fig12_deletions",
    "benchmarks.fig_batch_throughput",
    "benchmarks.fig_query_churn",
    "benchmarks.fig_governor_budget",
    "benchmarks.fig_operator_drop",
    "benchmarks.fig_shard_scaling",
    "benchmarks.fig_recovery",
    "benchmarks.fig_serving_slo",
    "benchmarks.fig_obs_overhead",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            importlib.import_module(mod_name).main()
            print(f"# {mod_name} done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures.append(mod_name)
            print(f"# {mod_name} FAILED:")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
