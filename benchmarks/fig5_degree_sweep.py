"""Figure 5: VDC vs JOD as average degree grows.

The paper's hypothesis: JOD recompute cost scales with average in-degree
(it re-joins over in-neighbours), while its benefit tracks the number of
J-diffs — which does NOT grow with degree.  So VDC catches up / wins as
degree rises.  We sweep average degree on a fixed vertex set and report the
per-update maintenance time and the average #diffs per vertex (the number
the paper prints on top of its Fig. 5 bars).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, paper_workload, run_stream
from repro.core import queries as q
from repro.core.graph import DynamicGraph


def main() -> None:
    v = 192
    for avg_deg in (4, 16, 48):
        e = v * avg_deg
        initial, stream = paper_workload(v=v, e=e, num_batches=8, seed=avg_deg)
        cap = int(len(initial) * 1.5) + 128
        for mode in ("vdc", "jod"):
            eng = q.sssp(DynamicGraph(v, initial, capacity=cap), [0, 1], max_iters=48, mode=mode)
            t = run_stream(eng, stream)
            counts = np.asarray(eng.state.dstore.count)
            nz = counts[counts > 0]
            avg_diffs = float(nz.mean()) if nz.size else 0.0
            emit(
                f"fig5/spsp_deg{avg_deg}/{mode}", t / len(stream),
                f"bytes={eng.nbytes()};avg_diffs_per_vertex={avg_diffs:.2f}",
            )
        for mode in ("vdc", "jod"):
            eng = q.khop(DynamicGraph(v, initial, capacity=cap), [0, 1], k=5, mode=mode)
            t = run_stream(eng, stream)
            emit(f"fig5/khop_deg{avg_deg}/{mode}", t / len(stream), f"bytes={eng.nbytes()}")


if __name__ == "__main__":
    main()
