"""Serving-tier SLO figure: tenants × budget × arrival rate under admission.

Drives the async multi-tenant serving tier (:mod:`repro.serving`) over a
powerlaw δE workload and reports per-cell p50/p99 read-your-writes read
latency, freshness lag, rejection rate, and the scratch-oracle exactness of
every served answer.  Cells:

* ``unloaded_1t`` / ``baseline`` — the unloaded reference (0.5× the
  calibrated sustainable rate; 1 and 3 tenants);
* ``overload_quota`` — offered 2× sustainable, per-tenant token-bucket
  quotas thin the admitted stream back under capacity;
* ``overload_ladder`` — offered 2× sustainable with no quotas: the
  admission controller walks every tenant down the drop ladder
  (degrade-before-reject), then sheds; steady-state reads stay fast+fresh;
* ``overload_control`` — the same 2× offered load with admission OFF: the
  backlog grows without bound, reads blow the read-your-writes barrier
  (p99 ≈ the timeout) and go stale — violating both the latency SLO and
  the exactness contract the admitted runs keep;
* ``budget_isolated`` — one tenant under a tight byte budget: only that
  tenant's queries degrade (isolation), co-tenants stay at level 0.

Per-chunk maintenance is paced with a fixed injected delay so the latency
ratios are timing-stable in CI; the host engine keeps the δE fold work
proportional to the affected set (the session API is engine-agnostic — the
dense-engine serving path is exercised by the CI serving smoke).

**Exactness** is the read-your-writes contract: a read is exact when it is
fresh (covers the tenant's admitted writes) AND its served values equal a
from-scratch oracle replay of exactly the covered update prefix.

Emits CSV rows plus one JSON summary line (``fig_serving_slo JSON: {...}``)
whose ``ok`` asserts: admitted-cell p99 ≤ 2× unloaded baseline with every
read exact, while the control run violates both.  ``--smoke`` runs a tiny
sweep and asserts the rejection rate falls to 0 once quotas/budgets are
unconstrained.
"""

from __future__ import annotations

import asyncio
import json
import sys
import time

import numpy as np

from benchmarks.common import emit, paper_workload
from repro.core import plan
from repro.core.governor import GovernorConfig
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession
from repro.data.graphgen import powerlaw_graph, split_90_10
from repro.serving.loadgen import (
    TenantLoad,
    arrival_schedule,
    tenant_update_streams,
)
from repro.serving.metrics import summarize_latency_s
from repro.serving.server import CQPServer, ServerConfig, build_serving_session
from repro.serving.tenants import TenantSpec
from repro.serving.admission import SLOConfig

V = 128
E = 512
BATCH = 16
MAX_ITERS = 24
TENANTS = 3
PACE_S = 0.015  # injected per-chunk floor: stabilizes latency ratios in CI
TIMEOUT_S = 0.4  # read-your-writes barrier timeout (the control run hits it)
LADDER = GovernorConfig(representation="prob")


def _plans(tenants: int):
    return [
        plan.sssp((i * 37) % V, max_iters=MAX_ITERS) for i in range(tenants)
    ]


def _workload(tenants: int, arrivals: int, seed: int):
    """initial edges + per-tenant lists of BATCH-sized submission batches.

    Streams are built with disjoint per-tenant edge universes (see
    :func:`tenant_update_streams`) so that concurrent submission — which
    interleaves tenants arbitrarily while preserving each tenant's own
    order — can never reorder a delete ahead of its insert.
    """
    edges = powerlaw_graph(V, E, seed=seed)
    initial, pool = split_90_10(edges, seed=seed)
    per_tenant = tenant_update_streams(
        initial, V, tenants,
        num_batches=arrivals, batch_size=BATCH,
        delete_fraction=0.1, insert_pool=pool, seed=seed + 1,
    )
    return initial, per_tenant


def _graph(initial):
    return DynamicGraph(V, initial, capacity=len(initial) * 4 + BATCH * 256)


def calibrate(initial) -> float:
    """Mean per-chunk wall time T_B (incl. the injected pace): fixed-shape
    B-update chunks cost ~constant, so sustainable = B / T_B updates/s."""
    session = build_serving_session(
        _graph(initial), ladder=LADDER, engine="host"
    )
    session.register_many(_plans(TENANTS))
    _, stream = paper_workload(
        v=V, e=E, num_batches=6, batch_size=BATCH, delete_fraction=0.1, seed=99
    )
    times = []
    for chunk in stream:
        t0 = time.perf_counter()
        session.apply_updates_batched(chunk, batch_size=BATCH)
        times.append(time.perf_counter() - t0)
    return float(np.mean(times[1:])) + PACE_S


async def _drive_tenant(server, load, ticket, batches, t_start, schedule):
    """One tenant's open-loop arrivals: submit a batch, read-your-writes.

    Reads run as concurrent tasks so they never gate the next submission —
    awaiting them inline would throttle the offered rate to the server's
    read latency (the closed-loop trap the control cell must not fall into).
    """
    recs = []
    tid = load.spec.tenant_id

    async def read_back(i: int, admitted: bool) -> None:
        r = await server.read(ticket)
        recs.append(
            {
                "tenant": tid,
                "arrival_frac": (i + 1) / len(schedule),
                "admitted": admitted,
                "wait_s": r.wait_s,
                "fresh": r.fresh,
                "covered": r.covered,
                "required": r.required,
                "values": r.values,
                "ticket_id": ticket.ticket_id,
            }
        )

    reads = []
    for i, offset in enumerate(schedule):
        delay = (t_start + float(offset)) - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        sub = server.submit(tid, batches[i % len(batches)])
        reads.append(asyncio.ensure_future(read_back(i, sub.admitted)))
    await asyncio.gather(*reads)
    return recs


def _oracle_exactness(server, initial, reads, plans_by_ticket):
    """Replay the server's applied chunk log from scratch; a read is exact
    iff it is fresh and its values equal the oracle at its covered prefix."""
    needed = sorted({r["covered"] for r in reads})
    oracle = CQPSession(_graph(initial), engine="scratch")
    tickets = sorted(plans_by_ticket)
    handles = {
        t: h
        for t, h in zip(
            tickets, oracle.register_many([plans_by_ticket[t] for t in tickets])
        )
    }
    answers_at = {}
    covered = 0
    if covered in needed:
        answers_at[0] = {
            t: np.array(oracle.answers(h), copy=True)
            for t, h in handles.items()
        }
    for chunk in server._chunk_log:
        oracle.apply_updates_batched(chunk)
        covered += len(chunk)
        if covered in needed:
            answers_at[covered] = {
                t: np.array(oracle.answers(h), copy=True)
                for t, h in handles.items()
            }
    value_exact = exact = 0
    for r in reads:
        want = answers_at[r["covered"]][r["ticket_id"]]
        v_ok = np.array_equal(np.asarray(r["values"]), want)
        value_exact += v_ok
        exact += v_ok and r["fresh"]
    n = max(len(reads), 1)
    return value_exact / n, exact / n


def run_cell(
    name: str,
    t_chunk_s: float,
    *,
    tenants: int = TENANTS,
    arrivals: int = 32,
    offered_x: float = 0.5,
    admission: bool = True,
    quota_x: float | None = None,  # per-tenant admitted quota, × sustainable
    budget_bytes_t0: int | None = None,  # tenant0's isolated byte budget
    slo: SLOConfig | None = None,
    seed: int = 0,
) -> dict:
    """One experiment cell; returns the summary row."""
    initial, per_tenant = _workload(tenants, arrivals, seed)
    sustainable_upd_s = BATCH / t_chunk_s
    rate_batches_s = offered_x * (1.0 / t_chunk_s) / tenants

    session = build_serving_session(
        _graph(initial), ladder=LADDER, engine="host",
        batch_capacity=BATCH, min_slots=tenants,
    )
    server = CQPServer(
        session,
        config=ServerConfig(
            chunk_updates=BATCH,
            admission=admission,
            read_timeout_s=TIMEOUT_S,
            slo=slo or SLOConfig(backlog_high_updates=BATCH),
            drop_ladder=LADDER,
        ),
        delay_injector=lambda k: PACE_S,
    )
    plans = _plans(tenants)

    async def run():
        async with server:
            loads, tickets, plans_by_ticket = [], {}, {}
            for i in range(tenants):
                tid = f"tenant{i}"
                spec = TenantSpec(
                    tenant_id=tid,
                    priority=i + 1,
                    budget_bytes=budget_bytes_t0 if i == 0 else None,
                    rate_per_s=(
                        None
                        if quota_x is None
                        else quota_x * sustainable_upd_s
                    ),
                    burst=2 * BATCH,
                )
                server.add_tenant(spec)
                ticket = await server.register_query(tid, plans[i])
                tickets[tid] = ticket
                plans_by_ticket[ticket.ticket_id] = plans[i]
                loads.append(
                    TenantLoad(
                        spec=spec,
                        arrival_rate_per_s=rate_batches_s,
                        updates_per_arrival=BATCH,
                        arrivals=arrivals,
                    )
                )
            t_start = time.perf_counter()
            recs = await asyncio.gather(
                *(
                    _drive_tenant(
                        server,
                        load,
                        tickets[load.spec.tenant_id],
                        per_tenant[load.spec.tenant_id],
                        t_start,
                        arrival_schedule(load, seed + 7919 * i),
                    )
                    for i, load in enumerate(loads)
                )
            )
            await server.drain()
            reads = [r for tenant_recs in recs for r in tenant_recs]
            value_exact, exact = _oracle_exactness(
                server, initial, reads, plans_by_ticket
            )
            stats = server.stats()
        return reads, value_exact, exact, stats

    reads, value_exact, exact, stats = asyncio.run(run())

    lat = summarize_latency_s([r["wait_s"] for r in reads])
    # steady-state window: the ladder walk (one rung per epoch) and the
    # drain of the backlog it accumulated are a bounded transient; SLOs are
    # judged once shedding/quotas hold the backlog at its equilibrium
    steady = [r for r in reads if r["arrival_frac"] > 0.6] or reads
    steady_lat = summarize_latency_s([r["wait_s"] for r in steady])
    submitted = sum(
        t["submitted_updates"] for t in stats["tenants"].values()
    )
    rejected = sum(t["rejected_updates"] for t in stats["tenants"].values())
    lags = [max(r["required"] - r["covered"], 0) for r in reads]
    row = {
        "cell": name,
        "tenants": tenants,
        "offered_x_sustainable": offered_x,
        "quota_x_sustainable": quota_x,
        "budget_bytes_t0": budget_bytes_t0,
        "admission": admission,
        "reads": len(reads),
        "p50_ms": lat["p50_ms"],
        "p99_ms": lat["p99_ms"],
        "steady_p99_ms": steady_lat["p99_ms"],
        "stale_reads": sum(not r["fresh"] for r in reads),
        "freshness_lag_mean_updates": float(np.mean(lags)) if lags else 0.0,
        "rejection_rate": rejected / submitted if submitted else 0.0,
        "value_exact_fraction": value_exact,
        "exact_fraction": exact,
        "degrade_actions": sum(
            1 for a in stats["actions"] if a["kind"] == "degrade"
        ),
        "restore_actions": sum(
            1 for a in stats["actions"] if a["kind"] == "restore"
        ),
        "tenant_levels": {
            t: s["level"] for t, s in stats["tenants"].items()
        },
        "shed_rejections": stats["admission"]["rejected_updates"]
        if admission
        else 0,
    }
    emit(
        f"fig_serving_slo/{name}",
        lat["p99_ms"] * 1e3,
        f"p50_ms={lat['p50_ms']:.1f};p99_ms={lat['p99_ms']:.1f};"
        f"steady_p99_ms={steady_lat['p99_ms']:.1f};"
        f"reject={row['rejection_rate']:.2f};stale={row['stale_reads']};"
        f"exact={row['exact_fraction']:.2f};"
        f"degrades={row['degrade_actions']}",
    )
    return row


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    # full mode runs long enough that the ladder-walk transient (rungs ×
    # epoch + backlog drain, ≈0.5 s at 2× overload) sits outside the steady
    # window judged against the SLO
    arrivals = 12 if smoke else 48
    initial, _ = _workload(TENANTS, 4, seed=0)
    t_chunk = calibrate(initial)
    emit(
        "fig_serving_slo/calibrate",
        t_chunk * 1e6,
        f"sustainable_upd_per_s={BATCH / t_chunk:.0f};pace_ms={PACE_S * 1e3}",
    )

    summary = {"t_chunk_ms": t_chunk * 1e3, "cells": []}

    def cell(name, **kw):
        row = run_cell(name, t_chunk, arrivals=arrivals, **kw)
        summary["cells"].append(row)
        return row

    unloaded = cell("unloaded_1t", tenants=1, offered_x=0.3)
    baseline = cell("baseline", offered_x=0.5)
    if smoke:
        # rejection-rate → 0 once quotas/budgets are unconstrained
        constrained = cell("smoke_quota", offered_x=1.0, quota_x=0.15)
        unconstrained = cell("smoke_unconstrained", offered_x=0.5)
        summary["smoke"] = {
            "constrained_rejection_rate": constrained["rejection_rate"],
            "unconstrained_rejection_rate": unconstrained["rejection_rate"],
        }
        summary["ok"] = bool(
            constrained["rejection_rate"] > 0.0
            and unconstrained["rejection_rate"] == 0.0
            and unconstrained["exact_fraction"] == 1.0
        )
        print("fig_serving_slo JSON:", json.dumps(summary))
        return

    quota = cell("overload_quota", offered_x=2.0, quota_x=0.5 / TENANTS)
    ladder = cell("overload_ladder", offered_x=2.0)
    control = cell("overload_control", offered_x=2.0, admission=False)
    # neutralize the shared admission-overload path (huge backlog high-water,
    # no cooldown restores): the only ladder actions left are per-tenant
    # budget enforcement, so end-state levels measure isolation directly
    budget = cell(
        "budget_isolated", offered_x=0.5, budget_bytes_t0=512,
        slo=SLOConfig(backlog_high_updates=10**9, cooldown_epochs=10**9),
    )

    # the acceptance bar: admitted-tenant p99 within 2× the unloaded
    # baseline.  (Not 2× the 3-tenant baseline cell — its p99 is dominated
    # by transient ladder walks and noisy enough to balloon the SLO past
    # the control run's read-timeout ceiling.)
    slo_ms = 2.0 * unloaded["p99_ms"]
    summary["slo_p99_ms"] = slo_ms
    summary["checks"] = {
        # the admission ladder keeps admitted tenants fast + fresh + exact...
        "quota_within_slo": quota["p99_ms"] <= slo_ms,
        "ladder_steady_within_slo": ladder["steady_p99_ms"] <= slo_ms,
        # every served answer matches the scratch oracle at its covered
        # prefix — even a read that missed its freshness barrier serves an
        # exact (bounded-stale) snapshot; latency/freshness SLOs are judged
        # by the steady-state checks above
        "admitted_all_exact": (
            quota["value_exact_fraction"] == 1.0
            and ladder["value_exact_fraction"] == 1.0
            and baseline["value_exact_fraction"] == 1.0
        ),
        "ladder_degraded_before_shedding": (
            ladder["degrade_actions"] >= 1
            and ladder["shed_rejections"] > 0
        ),
        # ...while the no-admission control run violates both
        "control_violates_latency": control["p99_ms"] > slo_ms,
        "control_violates_exactness": control["exact_fraction"] < 1.0,
        # a co-tenant's budget never degrades yours
        "budget_isolation": (
            budget["tenant_levels"]["tenant0"] > 0
            and all(
                lvl == 0
                for t, lvl in budget["tenant_levels"].items()
                if t != "tenant0"
            )
        ),
    }
    summary["ok"] = all(summary["checks"].values())
    print("fig_serving_slo JSON:", json.dumps(summary))


if __name__ == "__main__":
    main()
