"""Figure 9: SCRATCH vs the planner's landmark hub-cut rewrite (§6.6).

Q SPSP queries over a streaming power-law graph:

* **baseline** — a SCRATCH-engine session registering the plans untouched
  (``optimize="none"``): every batch re-runs Q full Bellman-Ford sweeps;
* **landmark** — a dense session with ``optimize="always"``: the planner
  rewrites every SPSP plan onto ONE shared landmark index (2·L SSSP fields,
  differentially maintained in-engine) and answers through triangle-bound
  pruned scratch.

The paper reports 43%–83% scratch-time reduction.  We assert the
deterministic analog — the pruned sweep's cumulative live-vertex work vs
the baseline's ``iters × Q × V`` — is cut ≥ 40%, with bit-exact target
answers, and report wall time (first batch excluded: compile).

A second cell runs the landmark session under a starved governor budget:
the index sheds (de-landmark-ize), the budget is then raised and the index
re-materializes — answers stay exact throughout (DESIGN.md §16).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit, paper_workload
from repro.core import plan as qp
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession


def _targets(session, handles, queries):
    return np.array(
        [session.answers(h)[t] for h, (_, t) in zip(handles, queries)],
        np.float32,
    )


def main(smoke: bool = False) -> dict:
    v, e, nb, num_q, num_l = (
        (96, 384, 4, 12, 3) if smoke else (192, 768, 8, 32, 8)
    )
    max_iters = 48
    initial, stream = paper_workload(v=v, e=e, num_batches=nb)
    rng = np.random.default_rng(7)
    queries = [
        (int(rng.integers(v)), int(rng.integers(v))) for _ in range(num_q)
    ]
    plans = [qp.spsp(s, t, max_iters=max_iters) for s, t in queries]
    cap = len(initial) * 4 + 64

    # ---- baseline: un-rewritten SPSP on SCRATCH
    base = CQPSession(DynamicGraph(v, initial, capacity=cap), engine="scratch")
    bh = base.register_many(plans)
    base_work = int(base.last_stats.iters_run) * num_q * v  # registration sweep
    base_wall = 0.0
    for i, batch in enumerate(stream):
        t0 = time.perf_counter()
        st = base.apply_updates(batch)
        _targets(base, bh, queries)  # serving read after every batch
        if i > 0:  # first batch pays compile
            base_wall += time.perf_counter() - t0
        base_work += int(st.iters_run) * num_q * v

    # ---- landmark: planner rewrite, index diff-maintained in-engine
    from repro.planner.landmark_rewrite import LandmarkRule
    from repro.planner.rules import Planner

    opt = CQPSession(
        DynamicGraph(v, initial, capacity=cap),
        engine="dense",
        optimize="always",
    )
    opt._planner = Planner(opt, "always", rules=[LandmarkRule(num_l)])
    oh = opt.register_many(plans)
    _targets(opt, oh, queries)  # registration read (one pruned sweep)
    lmk = opt.stats()["planner"]["landmark"]
    assert lmk["queries"] == num_q and lmk["live"], lmk
    opt_wall = 0.0
    for i, batch in enumerate(stream):
        t0 = time.perf_counter()
        opt.apply_updates(batch)
        _targets(opt, oh, queries)  # one pruned-scratch sweep per batch
        if i > 0:  # first batch pays compile
            opt_wall += time.perf_counter() - t0
    lmk = opt.stats()["planner"]["landmark"]
    opt_work = int(lmk["pruned_work_total"])

    # ---- exact parity at every target + the ≥40% work cut
    d_base = _targets(base, bh, queries)
    d_opt = _targets(opt, oh, queries)
    assert np.array_equal(d_base, d_opt), (d_base, d_opt)
    reduction = 1.0 - opt_work / max(base_work, 1)
    assert reduction >= 0.40, (
        f"landmark pruning cut only {reduction:.0%} of scratch work "
        f"({opt_work} vs {base_work})"
    )

    # ---- governor cell: shed under a starved budget, re-materialize after
    gov = CQPSession(
        DynamicGraph(v, initial, capacity=cap),
        engine="dense",
        optimize="always",
        budget_bytes=1,
    )
    gov._planner = Planner(gov, "always", rules=[LandmarkRule(num_l)])
    gh = gov.register_many(plans)
    half = nb // 2
    for batch in stream[:half]:
        gov.apply_updates(batch)
    g1 = gov.stats()["planner"]["landmark"]
    assert g1["shed"] and g1["sheds_total"] >= 1, g1
    gov.governor.budget_bytes = 1 << 24  # operator relief
    for batch in stream[half:]:
        gov.apply_updates(batch)
    while gov.stats()["planner"]["landmark"]["remats_total"] == 0:
        gov.apply_updates([])  # calm passes drain the hysteresis cooldown
    g2 = gov.stats()["planner"]["landmark"]
    assert g2["remats_total"] >= 1 and g2["live"], g2
    d_gov = _targets(gov, gh, queries)
    assert np.array_equal(d_base, d_gov), (d_base, d_gov)

    out = {
        "v": v,
        "queries": num_q,
        "num_landmarks": num_l,
        "batches": nb,
        "base_work": base_work,
        "pruned_work": opt_work,
        "work_reduction": round(reduction, 4),
        "base_wall_us": round(base_wall * 1e6, 1),
        "landmark_wall_us": round(opt_wall * 1e6, 1),
        "index_nbytes": int(lmk["index_nbytes"]),
        "exact_targets": True,
        "governor": {
            "sheds_total": int(g2["sheds_total"]),
            "remats_total": int(g2["remats_total"]),
            "exact_after_remat": True,
        },
    }
    emit(
        "fig9/scratch",
        base_wall * 1e6 / max(nb - 1, 1),
        f"work={base_work}",
    )
    emit(
        "fig9/scratch_landmark",
        opt_wall * 1e6 / max(nb - 1, 1),
        f"work={opt_work};index_bytes={out['index_nbytes']};"
        f"reduction={reduction:.0%};sheds={g2['sheds_total']};"
        f"remats={g2['remats_total']}",
    )
    print(f"fig9-summary {json.dumps(out)}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true", help="CI-scale workload"
    )
    main(smoke=ap.parse_args().smoke)
