"""Figure 9: SCRATCH vs SCRATCH-LANDMARK (Diff-IFE-maintained index).

100 SPSP queries, landmark index (10 highest-degree vertices) maintained
differentially; queries answered by pruned Bellman-Ford.  The paper reports
43%–83% scratch-time reduction; we report both wall time and the pruning
effect (iterations to converge).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_sssp, paper_workload, run_stream
from repro.core.graph import DynamicGraph
from repro.core.landmark import ScratchLandmark
from repro.core.scratch import scratch_like


def main() -> None:
    v = 192
    initial, stream = paper_workload(v=v, e=768, num_batches=8)
    rng = np.random.default_rng(7)
    queries = [(int(rng.integers(v)), int(rng.integers(v))) for _ in range(32)]

    # plain scratch
    eng = make_sssp(initial, v, [s for s, _ in queries])
    sc = scratch_like(eng.cfg, DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
                      eng.state.init)
    t_sc = run_stream(sc, stream)
    d_sc = sc.answers()[np.arange(len(queries)), [t for _, t in queries]]

    # landmark-pruned scratch (index maintained via Diff-IFE)
    lm = ScratchLandmark(
        DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
        queries, num_landmarks=10, max_iters=48,
    )
    t_lm = run_stream(lm, stream)
    d_lm = lm.answers()

    assert np.allclose(np.where(np.isfinite(d_sc), d_sc, -1),
                       np.where(np.isfinite(d_lm), d_lm, -1)), "landmark pruning broke SPSP"
    emit("fig9/scratch", t_sc / len(stream), "")
    emit("fig9/scratch_landmark", t_lm / len(stream),
         f"index_bytes={lm.nbytes()};reduction={100 * (1 - t_lm / max(t_sc, 1e-9)):.0f}%")


if __name__ == "__main__":
    main()
