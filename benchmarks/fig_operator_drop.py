"""Per-operator vs whole-query dropping on RPQ workloads (repo-native; the
paper's §4–§5 operator-dropping scenario made measurable).

An RPQ's dataflow is ``Ingest → Join(nfa) → Iterate``: with the join trace
materialized (VDC on the product graph) the Join operator holds per-edge
message change points — typically the dominant memory term (E_p ≥ V_p).
The legacy query-level lever ("whole-query dropping": ONE DropConfig per
query) can only thin the Iterate's change points — partial dropping does
not apply to the join trace — and pays DroppedVT bytes plus repair work for
every dropped point.  The operator-graph IR instead drops the *Join's*
differences completely (recompute-on-demand, zero bookkeeping) while
keeping the Iterate's untouched: "drop the Join's differences, keep the
Iterate's".

Three configurations over one chunked δE stream, all answer-exact against
the from-scratch oracle:

    materialize   join materialized, no dropping (the DC memory ceiling)
    whole_query   join materialized + query-level Det-Drop p on the iterate
                  (the only pre-operator-IR reclamation path)
    operator      join dropped completely, iterate untouched

Emits CSV rows plus one JSON summary line (``fig_operator_drop JSON:``)
asserting ``operator`` holds fewer peak bytes than ``whole_query`` at equal
answer exactness.
"""

from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core import dropping as dr
from repro.core import plan
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession

V = 64
E = 384
SOURCES = (0, V // 2)
MAX_ITERS = 24
BATCH = 8
NUM_BATCHES = 24
WHOLE_QUERY_P = 0.5


def workload(seed=3):
    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < E:
        u, w = int(rng.integers(0, V)), int(rng.integers(0, V))
        if u != w:
            seen[(u, w)] = (u, w, 1.0, 1 + int(rng.integers(0, 2)))  # labels 1/2
    edges = list(seen.values())
    initial, pool = edges[: E * 3 // 4], edges[E * 3 // 4 :]
    present = {(u, w) for (u, w, _x, _l) in initial}
    labels = {(u, w): l for (u, w, _x, l) in edges}
    chunks = []
    for _ in range(NUM_BATCHES):
        batch = []
        for _ in range(BATCH):
            if present and rng.random() < 0.3:
                u, w = sorted(present)[int(rng.integers(0, len(present)))]
                batch.append((u, w, labels[(u, w)], 1.0, -1))
                present.discard((u, w))
            elif pool:
                u, w, x, l = pool.pop()
                batch.append((u, w, l, x, +1))
                present.add((u, w))
        chunks.append(batch)
    return initial, chunks


def _plans(nfa, *, join_store, drop=None):
    return [
        plan.rpq(
            s, nfa, max_iters=MAX_ITERS, drop=drop, join_store=join_store
        )
        for s in SOURCES
    ]


def _run(initial, chunks, plans, **session_kw):
    s = CQPSession(
        DynamicGraph(V, initial, capacity=E * 4 + 64),
        engine="dense",
        batch_capacity=BATCH,
        min_slots=len(plans),
        **session_kw,
    )
    handles = s.register_many(plans)
    s.apply_updates_batched(chunks[0], batch_size=BATCH)  # compile
    peak = s.nbytes()
    peak_per_op: dict[str, int] = {}
    served = 0
    t0 = time.perf_counter()
    for chunk in chunks[1:]:
        s.apply_updates_batched(chunk, batch_size=BATCH)
        served += len(chunk)
        peak = max(peak, s.nbytes())
        for ops in s.nbytes_per_operator():
            for op, b in ops.items():
                peak_per_op[op] = max(peak_per_op.get(op, 0), b)
    return {
        "t": time.perf_counter() - t0,
        "served": served,
        "peak": int(peak),
        "peak_per_op": peak_per_op,
        "reach": [s.reachable(h) for h in handles],
    }


def main() -> None:
    nfa = plan.NFA.concat_star(1, 2)
    initial, chunks = workload()

    # from-scratch oracle on the final graph (reachability depends only on it)
    final_graph = DynamicGraph(V, initial, capacity=E * 4 + 64)
    final_graph.apply_batch([u for c in chunks for u in c])
    oracle = CQPSession(final_graph, engine="scratch")
    oracle_reach = [
        oracle.reachable(h)
        for h in oracle.register_many(_plans(nfa, join_store="auto"))
    ]

    def exact(rows):
        return all(np.array_equal(a, b) for a, b in zip(rows, oracle_reach))

    runs = {
        "materialize": _run(
            initial, chunks, _plans(nfa, join_store="materialize")
        ),
        "whole_query": _run(
            initial,
            chunks,
            _plans(
                nfa,
                join_store="materialize",
                drop=dr.DropConfig(
                    mode="det", selection="random", p=WHOLE_QUERY_P, seed=7
                ),
            ),
            drop=dr.DropConfig(mode="det"),
        ),
        "operator": _run(initial, chunks, _plans(nfa, join_store="drop")),
    }

    summary = {}
    for name, run in runs.items():
        row = {
            "peak_bytes": run["peak"],
            "peak_per_op": run["peak_per_op"],
            "updates_per_sec": run["served"] / run["t"],
            "answers_exact": exact(run["reach"]),
        }
        summary[name] = row
        emit(
            f"fig_operator_drop/{name}",
            run["t"] * 1e6 / run["served"],
            f"upd_per_s={row['updates_per_sec']:.1f};"
            f"peak_bytes={row['peak_bytes']};"
            f"exact={int(row['answers_exact'])}",
        )

    assert all(r["answers_exact"] for r in summary.values()), summary
    assert (
        summary["operator"]["peak_bytes"] < summary["whole_query"]["peak_bytes"]
    ), summary  # the acceptance inequality: operator-granular dropping wins
    summary["operator_vs_whole_query_reduction"] = round(
        1.0
        - summary["operator"]["peak_bytes"]
        / summary["whole_query"]["peak_bytes"],
        3,
    )
    print("fig_operator_drop JSON:", json.dumps(summary))


if __name__ == "__main__":
    main()
