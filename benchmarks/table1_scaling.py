"""Table 1: SCRATCH vs differential computation as query count grows.

Reproduces the shape of the paper's Table 1 — DC maintenance work stays
~flat per update while SCRATCH re-execution grows linearly with Q — and the
memory column that explains DC's OOM wall: diff bytes grow linearly in Q.
"""

from __future__ import annotations

from benchmarks.common import emit, make_sssp, paper_workload, run_stream
from repro.core.scratch import scratch_like
from repro.core.graph import DynamicGraph


def main() -> None:
    v = 256
    initial, stream = paper_workload(v=v, e=1024, num_batches=10)
    for nq in (2, 4, 8, 16):
        sources = list(range(nq))
        eng = make_sssp(initial, v, sources)
        t_dc = run_stream(eng, stream)
        sc = scratch_like(eng.cfg, DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
                          eng.state.init)
        t_sc = run_stream(sc, stream)
        # algorithmic work (vertex aggregator reruns) — the machine-neutral
        # Table-1 metric: DC's advantage on a pointer machine
        work_dc = int(eng.last_stats.scheduled)
        work_sc = int(sc.last_stats.scheduled)
        emit(f"table1/dc_q{nq}", t_dc / len(stream),
             f"bytes={eng.nbytes()};work={work_dc}")
        emit(f"table1/scratch_q{nq}", t_sc / len(stream),
             f"bytes=0;work={work_sc};work_ratio={work_sc / max(work_dc, 1):.1f}")


if __name__ == "__main__":
    main()
