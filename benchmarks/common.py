"""Shared benchmark machinery: workloads, timing, CSV reporting.

Benchmarks mirror the paper's experimental protocol (§6.1) at
container-friendly scale: power-law graphs, 90/10 split, batches of single
edge updates, Q registered queries.  Each module emits
``name,us_per_call,derived`` rows; ``derived`` carries the figure-specific
metric (memory bytes, #diffs, max queries, …).
"""

from __future__ import annotations

import time



from repro.core import dropping as dr
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # µs


def paper_workload(
    *,
    v: int = 256,
    e: int = 1024,
    num_batches: int = 20,
    batch_size: int = 1,
    delete_fraction: float = 0.0,
    seed: int = 0,
    weighted: bool = True,
):
    """90/10 split + insert stream from the held-out pool (paper §6.1)."""
    edges = powerlaw_graph(v, e, seed=seed, weighted=weighted)
    initial, pool = split_90_10(edges, seed=seed)
    stream = update_stream(
        initial, v,
        num_batches=num_batches, batch_size=batch_size,
        delete_fraction=delete_fraction, insert_pool=pool, seed=seed + 1,
    )
    return initial, stream


# bloom sized for container-scale graphs: 2^11 bits = 256 B packed per query
DROP_DEGREE = lambda p, mode="det", seed=1: dr.DropConfig(
    mode=mode, selection="degree", p=p, tau_min=2, tau_max=24, seed=seed,
    bloom_bits=1 << 11,
)
DROP_RANDOM = lambda p, mode="det", seed=1: dr.DropConfig(
    mode=mode, selection="random", p=p, seed=seed, bloom_bits=1 << 11
)

def run_stream_stats(system, stream):
    """(total µs, cumulative MaintainStats dict) over a stream."""
    import time as _t
    tot = {}
    def acc(st):
        for k, v in st._asdict().items():
            if getattr(v, "ndim", 0):  # per-iteration probe vectors
                continue
            tot[k] = tot.get(k, 0) + int(v)
    if getattr(system, "last_stats", None) is not None:
        acc(system.last_stats)  # the initial computation sweep
    t0 = _t.perf_counter()
    for batch in stream:
        acc(system.apply_updates(batch))
    return (_t.perf_counter() - t0) * 1e6, tot


def run_stream(system, stream) -> float:
    """Total maintenance wall time (µs) over an update stream."""
    t0 = time.perf_counter()
    for batch in stream:
        system.apply_updates(batch)
    return (time.perf_counter() - t0) * 1e6


def make_sssp(initial, v, sources, **kw):
    return q.sssp(DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
                  sources, max_iters=48, **kw)


def make_khop(initial, v, sources, k=5, **kw):
    return q.khop(DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
                  sources, k=k, **kw)
