"""Figure 6: Random vs Degree drop selection (and 6b's recompute profile).

(a) sweep drop probability p for Det/Prob × Random/Degree, reporting
    dropped-diff counts vs maintenance time — Degree should dominate Random.
(b) per-degree-bucket average recompute counts under Random dropping — low
    degree buckets recompute rarely; high-degree vertices are hammered
    (the paper's justification for Degree selection).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (DROP_DEGREE, DROP_RANDOM, emit, make_khop,
    paper_workload, run_stream, run_stream_stats)


def main() -> None:
    v = 256
    initial, stream = paper_workload(v=v, e=1024, num_batches=10, weighted=False)
    sources = list(range(10))  # paper: 10 K-hop queries

    for p in (0.25, 0.75):
        for sel, mk in (("random", DROP_RANDOM), ("degree", DROP_DEGREE)):
            for mode in ("det", "prob"):
                eng = make_khop(initial, v, sources, drop=mk(p, mode))
                t, tot = run_stream_stats(eng, stream)
                dropped = tot["dropped"]
                repairs = int(eng.state.repair_counts.sum())
                emit(
                    f"fig6a/{mode}-{sel}_p{p}", t / len(stream),
                    f"dropped={dropped};repairs={repairs};bytes={eng.nbytes()}",
                )

    # (b) recompute counts by degree bucket, Random Det-Drop p=0.1
    eng = make_khop(initial, v, sources, drop=DROP_RANDOM(0.1))
    run_stream(eng, stream)
    repair = np.asarray(eng.state.repair_counts).sum(axis=0)  # [V]
    deg = eng.graph.degrees_total()
    buckets = [(1, 4), (4, 16), (16, 64), (64, 1 << 30)]
    for lo, hi in buckets:
        m = (deg >= lo) & (deg < hi)
        avg = float(repair[m].mean()) if m.any() else 0.0
        emit(f"fig6b/recomputes_deg[{lo},{hi})", 0.0,
             f"avg_recomputes={avg:.2f};vertices={int(m.sum())}")


if __name__ == "__main__":
    main()
