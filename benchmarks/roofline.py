"""§Roofline: aggregate the dry-run reports into the per-cell roofline table.

Reads ``reports/dryrun/*.json`` — produced by ``repro.launch.dryrun`` (LM
cells) and ``repro.launch.sweep_dryrun`` (stitched vs fused maintenance
sweep) — and prints, per (arch × shape × mesh): the three roofline terms in
seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline
fraction.  Exits nonzero when no reports exist (run a producer first).

    PYTHONPATH=src python -m repro.launch.sweep_dryrun
    PYTHONPATH=src python -m benchmarks.roofline [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")


def load() -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(REPORT_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    for unit, scale in (("s", 1), ("ms", 1e-3), ("us", 1e-6), ("ns", 1e-9)):
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.1e}s"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    recs = load()
    if not recs:
        print(
            f"roofline: no dry-run reports in {os.path.abspath(REPORT_DIR)} — "
            "produce them first, e.g.\n"
            "  PYTHONPATH=src python -m repro.launch.sweep_dryrun\n"
            "  PYTHONPATH=src python -m repro.launch.dryrun --all",
            file=sys.stderr,
        )
        sys.exit(2)
    ok = [r for r in recs if r.get("status") == "ok"]
    bad = [r for r in recs if r.get("status") != "ok"]

    if args.markdown:
        print("| cell | mesh | t_compute | t_memory | t_collective | bottleneck "
              "| useful-FLOP ratio | roofline frac | HBM/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
    else:
        print("name,us_per_call,derived")

    for r in ok:
        ro = r["roofline"]
        cell = f"{r['arch']}:{r['shape']}"
        dom = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        mem = ro.get("per_device_hbm_bytes") or 0
        if args.markdown:
            print(
                f"| {cell} | {r['mesh']} | {fmt_s(ro['t_compute_s'])} "
                f"| {fmt_s(ro['t_memory_s'])} | {fmt_s(ro['t_collective_s'])} "
                f"| {ro['bottleneck']} | {ro['useful_flop_ratio']:.2f} "
                f"| {ro['roofline_fraction']:.2%} | {mem / 2**30:.1f}GiB |"
            )
        else:
            print(
                f"roofline/{cell}/{r['mesh']},{dom * 1e6:.1f},"
                f"bottleneck={ro['bottleneck']};frac={ro['roofline_fraction']:.3f};"
                f"useful={ro['useful_flop_ratio']:.2f};hbm_gib={mem / 2**30:.1f}"
            )
    for r in bad:
        print(f"roofline/{r['arch']}:{r['shape']}/{r['mesh']},0,STATUS={r['status']}")


if __name__ == "__main__":
    main()
