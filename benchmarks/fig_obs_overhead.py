"""Observability overhead: tracing-enabled vs tracing-off serving throughput.

Not a paper figure — the acceptance gate for the unified observability layer
(DESIGN.md §15).  The tracer's disabled path must be a no-op (a module-level
null-span singleton, no allocation), and the *enabled* path must stay cheap
enough to leave on in production: a bounded ring-buffer append per span, a
few spans per streamed chunk.  This benchmark streams one fixed update log
through the dense engine's batched step repeatedly, alternating the tracer
off/on between passes, and compares best-of-N updates/sec per mode.

The run FAILS (non-zero exit) if enabling tracing costs more than
``MAX_OVERHEAD_FRAC`` (5%) of throughput — the bound the ISSUE/DESIGN
overhead budget promises.  ``--smoke`` shrinks the workload for CI; the
assertion still runs.  The closing line is a JSON summary::

    fig_obs_overhead JSON: {"updates_per_sec_off": ..., "updates_per_sec_on":
        ..., "overhead_frac": ..., "max_overhead_frac": 0.05, "ok": true, ...}
"""

from __future__ import annotations

import json
import sys
import time

from benchmarks.common import emit, paper_workload
from repro.core import queries as q
from repro.core.graph import DynamicGraph
from repro.obs import trace as obs_trace

MAX_OVERHEAD_FRAC = 0.05


def _timed_pass(eng, log, b: int) -> float:
    """One pass of the log through the batched step; returns seconds."""
    t0 = time.perf_counter()
    eng.apply_updates_batched(log, batch_size=b)
    return time.perf_counter() - t0


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    v = 64 if smoke else 256
    e = 256 if smoke else 1024
    b = 8 if smoke else 16
    num_batches = 12 if smoke else 40
    initial, stream = paper_workload(
        v=v, e=e, num_batches=num_batches, batch_size=b,
        delete_fraction=0.2, seed=11,
    )
    log = [u for batch in stream for u in batch]

    eng = q.sssp(
        DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
        [0, 1, 2, 3],
        max_iters=16 if smoke else 32,
        backend="coo",  # machine-neutral: compiled on CPU and TPU alike
        batch_capacity=b,
    )
    eng.apply_updates_batched(log[:b], batch_size=b)  # compile warmup
    rest = log[b:]

    # alternate modes symmetrically (off,on,on,off,off,on) so graph-state
    # drift across passes hits both modes equally; best-of-N denoises
    tracer = obs_trace.Tracer()  # bounded ring buffer, default capacity
    prev = obs_trace.get_tracer()
    obs_trace.set_tracer(None)  # make sure we start from the null path
    times = {"off": [], "on": []}
    try:
        for mode in ("off", "on", "on", "off", "off", "on"):
            obs_trace.set_tracer(tracer if mode == "on" else None)
            times[mode].append(_timed_pass(eng, rest, b))
    finally:
        obs_trace.set_tracer(prev)

    t_off, t_on = min(times["off"]), min(times["on"])
    ups_off, ups_on = len(rest) / t_off, len(rest) / t_on
    overhead = max(0.0, (ups_off - ups_on) / ups_off)
    emit(
        "fig_obs_overhead/tracing_off",
        t_off * 1e6 / len(rest),
        f"upd_per_s={ups_off:.1f}",
    )
    emit(
        "fig_obs_overhead/tracing_on",
        t_on * 1e6 / len(rest),
        f"upd_per_s={ups_on:.1f};overhead_frac={overhead:.4f};"
        f"events={tracer.emitted_events}",
    )
    summary = {
        "smoke": smoke,
        "updates": len(rest),
        "passes_per_mode": len(times["off"]),
        "updates_per_sec_off": round(ups_off, 1),
        "updates_per_sec_on": round(ups_on, 1),
        "overhead_frac": round(overhead, 4),
        "max_overhead_frac": MAX_OVERHEAD_FRAC,
        "trace_events": tracer.emitted_events,
        "ok": overhead <= MAX_OVERHEAD_FRAC,
    }
    print("fig_obs_overhead JSON:", json.dumps(summary))
    assert tracer.emitted_events > 0, "tracing-on passes emitted no spans"
    assert summary["ok"], (
        f"tracing overhead {overhead:.1%} exceeds the "
        f"{MAX_OVERHEAD_FRAC:.0%} budget "
        f"({ups_off:.1f} -> {ups_on:.1f} updates/sec)"
    )


if __name__ == "__main__":
    main()
