"""Device-count scaling of the vertex-sharded sweep (fig7-style axis).

Each shard count runs in its own subprocess — the emulated host device count
is fixed at XLA init — serving the same smoke workload through
``repro.launch.cqp_serve --mesh data``.  Reported per row:

* ``us_per_call`` — steady-state p50 maintenance latency per update chunk
* ``derived``     — peak accounted diff-store bytes per device (the paper's
  Table-1 per-machine memory axis): should shrink ~linearly with shard
  count while the global total stays flat.

Override the sweep with ``SHARD_SWEEP=1,8`` (comma-separated device counts).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

V, E, UPDATES, BATCH = 64, 192, 48, 8


def run_one(devices: int) -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    # drop any inherited device-count flag (e.g. the CI job's =8): the
    # subprocess's --emulate-devices must be the only one XLA sees
    env["XLA_FLAGS"] = " ".join(
        f
        for f in env.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count")
    )
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    cmd = [
        sys.executable, "-m", "repro.launch.cqp_serve",
        "--v", str(V), "--e", str(E), "--queries", "4",
        "--updates", str(UPDATES), "--batch", str(BATCH),
        "--max-iters", "16", "--backend", "coo", "--json",
        "--emulate-devices", str(devices),
        "--mesh", "none" if devices == 1 else "data",
    ]
    out = subprocess.run(
        cmd, env=env, capture_output=True, text=True, timeout=560
    )
    if out.returncode != 0:
        raise RuntimeError(f"devices={devices} failed:\n{out.stdout}{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def main() -> None:
    sweep = [int(x) for x in os.environ.get("SHARD_SWEEP", "1,2,4,8").split(",")]
    for n in sweep:
        r = run_one(n)
        emit(
            f"fig_shard/devices{n}",
            r["p50_ms"] * 1e3,
            f"per_device_bytes={r['peak_diff_bytes_per_device']};"
            f"total_bytes={r['peak_diff_bytes']};"
            f"updates_per_sec={r['updates_per_sec']:.1f}",
        )


if __name__ == "__main__":
    main()
