"""Batched-update CQP throughput: donated-buffer batched step vs per-update.

Not a paper figure — the repo-native throughput study motivating the batched
pipeline (DBSP/Graphsurge-style: batch deltas through one compiled dataflow).
For each backend (COO segment-reduce, Pallas ELL-SpMV, fused maintenance
megakernel) and batch size B, a fixed update log is streamed through
``apply_updates_batched``; B=1 via the per-update host path is the baseline.
``us_per_call`` is µs per update; ``derived`` carries updates/sec and the
speedup over the per-update path.  The closing ``fused_vs_stitched`` rows
compare the fused megakernel directly against the stitched ELL path at each
batch size (>1 means the single-dispatch sweep wins).

Off-TPU the ELL and fused rows run their kernels in interpret mode (a
correctness fallback an order of magnitude slower than the segment-reduce),
so on CPU the machine-neutral signal is the COO speedup column; on TPU the
compiled Mosaic kernels make the ELL/fused rows the headline and the
``fused_vs_stitched`` ratio measures the dispatch-fusion payoff.
"""

from __future__ import annotations

import time

from benchmarks.common import emit, paper_workload
from repro.core import queries as q
from repro.core.graph import DynamicGraph


def _engine(initial, v, backend, batch):
    return q.sssp(
        DynamicGraph(v, initial, capacity=len(initial) * 4 + 64),
        [0, 1, 2, 3],
        max_iters=32,
        backend=backend,
        batch_capacity=batch,
    )


def main() -> None:
    v = 128
    initial, stream = paper_workload(
        v=v, e=512, num_batches=48, batch_size=1, delete_fraction=0.2, seed=4
    )
    log = [u for batch in stream for u in batch]

    batch_us: dict[tuple[str, int], float] = {}
    for backend in ("coo", "ell", "fused"):
        # per-update baseline (host path, one dispatch per update)
        eng = _engine(initial, v, backend, 1)
        t0 = time.perf_counter()
        for u in log:
            eng.apply_updates([u])
        t_seq = time.perf_counter() - t0
        base = eng.answers()
        emit(
            f"fig_batch/{backend}/per_update",
            t_seq * 1e6 / len(log),
            f"upd_per_s={len(log) / t_seq:.1f}",
        )

        for b in (4, 16):
            eng = _engine(initial, v, backend, b)
            eng.apply_updates_batched(log[:b], batch_size=b)  # compile warmup
            rest = log[b:]
            t0 = time.perf_counter()
            eng.apply_updates_batched(rest, batch_size=b)
            t_bat = time.perf_counter() - t0
            assert (eng.answers() == base).all(), "batched != sequential answers"
            us = t_bat * 1e6 / len(rest)
            batch_us[(backend, b)] = us
            emit(
                f"fig_batch/{backend}/batch{b}",
                us,
                f"upd_per_s={len(rest) / t_bat:.1f};"
                f"speedup_vs_per_update={(t_seq / len(log)) / (t_bat / len(rest)):.2f}",
            )

    # stitched-vs-fused: same workload, same batch size, one compiled sweep
    # each — the ratio isolates what fusing the iteration into a single
    # pallas_call buys over the stitched ELL path
    for b in (4, 16):
        stitched, fused = batch_us[("ell", b)], batch_us[("fused", b)]
        emit(
            f"fig_batch/fused_vs_stitched/batch{b}",
            fused,
            f"stitched_us={stitched:.1f};speedup={stitched / fused:.2f}",
        )


if __name__ == "__main__":
    main()
