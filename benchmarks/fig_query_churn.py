"""Query-churn serving: updates/sec and peak diff bytes under register/
deregister traffic (repo-native; the lifecycle the paper's CQP serves).

A CQPSession streams a fixed δE log in B-chunks while queries come and go:
every ``PERIOD`` chunks one new SSSP query registers (its trace initialized
by in-engine recomputation) and the oldest live query deregisters (its diff
rows reclaimed).  The no-churn run over the same log is the baseline, so
``derived`` separates the steady-state maintenance rate from the churn tax
(amortized register/deregister cost) and shows peak accounted diff bytes
held flat by deregistration.  Engines: dense (batched path) and host
(pointer path); SCRATCH is omitted — it holds no diffs, so churn is free
there by construction.
"""

from __future__ import annotations

import time

from benchmarks.common import emit, paper_workload
from repro.core import plan
from repro.core.graph import DynamicGraph
from repro.core.session import CQPSession

V = 128
Q0 = 4  # standing queries
PERIOD = 4  # chunks between churn events
MAX_ITERS = 32
BATCH = 8


def _session(initial, engine: str) -> CQPSession:
    return CQPSession(
        DynamicGraph(V, initial, capacity=len(initial) * 4 + 64),
        engine=engine,
        batch_capacity=BATCH,
        min_slots=Q0,
    )


def _run(session: CQPSession, chunks, churn: bool) -> dict:
    handles = session.register_many(
        [plan.sssp(s, max_iters=MAX_ITERS) for s in range(Q0)]
    )
    session.apply_updates_batched(chunks[0], batch_size=BATCH)  # compile
    served = 0
    peak = session.nbytes()
    next_src = Q0
    t_churn = 0.0
    t0 = time.perf_counter()
    for k, chunk in enumerate(chunks[1:], start=1):
        if churn and k % PERIOD == 0:
            tc = time.perf_counter()
            handles.append(
                session.register(plan.sssp(next_src % V, max_iters=MAX_ITERS))
            )
            session.deregister(handles.pop(0))
            t_churn += time.perf_counter() - tc
            next_src += 1
        session.apply_updates_batched(chunk, batch_size=BATCH)
        served += len(chunk)
        peak = max(peak, session.nbytes())
    return {
        "t_total": time.perf_counter() - t0,
        "t_churn": t_churn,
        "served": served,
        "peak": peak,
        "events": session.registered_total - Q0,
        "freed": session.bytes_freed_total,
    }


def main() -> None:
    initial, stream = paper_workload(
        v=V, e=512, num_batches=32, batch_size=BATCH, delete_fraction=0.2, seed=6
    )
    log = [u for batch in stream for u in batch]
    chunks = [log[i : i + BATCH] for i in range(0, len(log), BATCH)]

    for engine in ("dense", "host"):
        base = _run(_session(initial, engine), chunks, churn=False)
        churn = _run(_session(initial, engine), chunks, churn=True)
        t_maint = churn["t_total"] - churn["t_churn"]
        emit(
            f"fig_query_churn/{engine}/steady",
            base["t_total"] * 1e6 / base["served"],
            f"upd_per_s={base['served'] / base['t_total']:.1f};"
            f"peak_bytes={base['peak']}",
        )
        emit(
            f"fig_query_churn/{engine}/churn",
            churn["t_total"] * 1e6 / churn["served"],
            f"upd_per_s={churn['served'] / churn['t_total']:.1f};"
            f"maint_upd_per_s={churn['served'] / t_maint:.1f};"
            f"churn_events={churn['events']};"
            f"churn_ms_per_event={churn['t_churn'] * 1e3 / max(churn['events'], 1):.1f};"
            f"peak_bytes={churn['peak']};bytes_freed={churn['freed']}",
        )


if __name__ == "__main__":
    main()
