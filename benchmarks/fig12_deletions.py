"""Figures 11–12 (Appendix B): deletion-ratio sweep.

Delete probabilities 0/25/50/75% over the same stream length; the paper
finds JOD & dropping configurations are insensitive (or improve) while
VDC's negative-multiplicity load grows with deletions.
"""

from __future__ import annotations

from benchmarks.common import DROP_DEGREE, emit, make_sssp, paper_workload, run_stream


def main() -> None:
    v = 256
    for frac in (0.0, 0.25, 0.5, 0.75):
        initial, stream = paper_workload(
            v=v, e=1024, num_batches=12, delete_fraction=frac, seed=11
        )
        for label, kw in (
            ("vdc", dict(mode="vdc")),
            ("jod", dict(mode="jod")),
            ("detdrop", dict(drop=DROP_DEGREE(0.5, "det"))),
            ("probdrop", dict(drop=DROP_DEGREE(0.5, "prob"))),
        ):
            eng = make_sssp(initial, v, [0, 1, 2, 3], **kw)
            t = run_stream(eng, stream)
            emit(f"fig12/del{int(frac * 100)}/{label}", t / len(stream),
                 f"bytes={eng.nbytes()};diffs={int(eng.state.dstore.count.sum())}")


if __name__ == "__main__":
    main()
