"""Figure 7: max concurrent queries under a fixed memory budget.

For each system (VDC / JOD / Det-Drop / Prob-Drop, Degree selection) find
the largest Q whose post-stream diff footprint fits the budget; for the
dropping systems, find the smallest p that fits (paper's ideal-knob
assumption) and report the runtime at that p.  Expected ordering:
VDC < JOD < Det-Drop < Prob-Drop (paper: JOD 2.3–10×, dropping up to 20×,
Prob ~1.5× over Det).
"""

from __future__ import annotations

from benchmarks.common import DROP_DEGREE, emit, make_sssp, paper_workload, run_stream

BUDGET = 96 * 1024  # bytes of diff state — container-scale stand-in for 10GB


def fits(make, qs, budget, stream):
    """Largest q in qs whose footprint fits; returns (q, engine, time)."""
    best = None
    for nq in qs:
        eng = make(nq)
        t = run_stream(eng, stream)
        if eng.nbytes() <= budget:
            best = (nq, eng, t)
        else:
            break
    return best


def main() -> None:
    v = 256
    initial, stream = paper_workload(v=v, e=1024, num_batches=8)
    qs = [1, 2, 4, 8, 16, 32, 64, 128]

    vdc = fits(lambda nq: make_sssp(initial, v, list(range(nq)), mode="vdc"), qs, BUDGET, stream)
    emit("fig7/vdc_max_q", vdc[2] / len(stream), f"max_queries={vdc[0]};bytes={vdc[1].nbytes()}")

    jod = fits(lambda nq: make_sssp(initial, v, list(range(nq)), mode="jod"), qs, BUDGET, stream)
    emit("fig7/jod_max_q", jod[2] / len(stream), f"max_queries={jod[0]};bytes={jod[1].nbytes()}")

    for mode in ("det", "prob"):
        best = None
        for nq in qs:
            # smallest p ∈ grid that fits the budget at this Q
            for p in (0.0, 0.3, 0.6, 0.9, 1.0):
                eng = make_sssp(initial, v, list(range(nq)), drop=DROP_DEGREE(p, mode))
                t = run_stream(eng, stream)
                if eng.nbytes() <= BUDGET:
                    best = (nq, p, t, eng.nbytes())
                    break
            else:
                break
        if best:
            nq, p, t, b = best
            emit(f"fig7/{mode}drop_max_q", t / len(stream),
                 f"max_queries={nq};p={p};bytes={b}")
    emit("fig7/speedup_summary", 0.0,
         f"jod_over_vdc={jod[0] / max(vdc[0], 1):.1f}x")


if __name__ == "__main__":
    main()
