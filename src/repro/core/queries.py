"""Query classes from the paper (§6.1.2) on top of the Diff-IFE engine.

Each query family supplies its semiring, initial states (the implicit
iteration-0 difference set) and an answer extractor.  SPSP/SSSP/K-hop/RPQ are
*continuous registered queries* (Q of them batched in the leading axis); WCC
and PageRank are single batch computations (Q = 1).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import dropping as dr
from repro.core import semiring as sr
from repro.core.engine import DiffIFE, EngineConfig
from repro.core.graph import DynamicGraph, product_graph

INF = np.float32(np.inf)


def _source_init(sources: Sequence[int], num_vertices: int, value: float = 0.0) -> np.ndarray:
    init = np.full((len(sources), num_vertices), INF, dtype=np.float32)
    for q, s in enumerate(sources):
        init[q, int(s)] = value
    return init


def _engine_cfg(
    num_queries: int,
    num_vertices: int,
    semiring: sr.Semiring,
    *,
    max_iters: int,
    mode: str = "jod",
    drop: dr.DropConfig | None = None,
    weight_from_degree: bool = False,
    **kw,
) -> EngineConfig:
    return EngineConfig(
        num_queries=num_queries,
        num_vertices=num_vertices,
        max_iters=max_iters,
        semiring=semiring,
        mode=mode,
        drop=drop or dr.DropConfig(),
        weight_from_degree=weight_from_degree,
        **kw,
    )


# --------------------------------------------------------------------------- SSSP / SPSP
def sssp(
    graph: DynamicGraph,
    sources: Sequence[int],
    *,
    max_iters: int = 64,
    batch_capacity: int = 32,
    mesh=None,
    **kw,
) -> DiffIFE:
    """Q concurrent single-source shortest-distance fields (Bellman-Ford IFE)."""
    cfg = _engine_cfg(
        len(sources), graph.num_vertices, sr.min_plus(), max_iters=max_iters, **kw
    )
    return DiffIFE(
        cfg, graph, _source_init(sources, graph.num_vertices),
        batch_capacity=batch_capacity, mesh=mesh,
    )


def spsp_answers(engine: DiffIFE, targets: Sequence[int]) -> np.ndarray:
    """SPSP = SSSP field read at the target (paper's query form)."""
    d = engine.answers()
    return np.asarray([d[q, int(t)] for q, t in enumerate(targets)], np.float32)


# --------------------------------------------------------------------------- K-hop
def khop(
    graph: DynamicGraph,
    sources: Sequence[int],
    k: int = 5,
    *,
    batch_capacity: int = 32,
    mesh=None,
    **kw,
) -> DiffIFE:
    """Vertices within ≤ k hops of each source; iterations bounded by k."""
    cfg = _engine_cfg(
        len(sources), graph.num_vertices, sr.min_hop(float(k)), max_iters=k, **kw
    )
    return DiffIFE(
        cfg, graph, _source_init(sources, graph.num_vertices),
        batch_capacity=batch_capacity, mesh=mesh,
    )


def khop_reachable(engine: DiffIFE) -> np.ndarray:
    return np.isfinite(engine.answers())


# --------------------------------------------------------------------------- WCC
def wcc(
    graph: DynamicGraph, *, max_iters: int = 128, batch_capacity: int = 32,
    mesh=None, **kw
) -> DiffIFE:
    """Weakly connected components: min-label propagation on the symmetrized
    graph (caller supplies a graph with both edge directions)."""
    v = graph.num_vertices
    init = np.arange(v, dtype=np.float32)[None, :]
    cfg = _engine_cfg(1, v, sr.min_label(), max_iters=max_iters, **kw)
    return DiffIFE(cfg, graph, init, batch_capacity=batch_capacity, mesh=mesh)


# --------------------------------------------------------------------------- PageRank
def pagerank(
    graph: DynamicGraph,
    *,
    iters: int = 10,
    alpha: float = 0.85,
    batch_capacity: int = 32,
    mesh=None,
    **kw,
) -> DiffIFE:
    """Pregel-style PageRank, fixed ``iters`` rounds (paper §6.1.2)."""
    v = graph.num_vertices
    init = np.ones((1, v), dtype=np.float32)
    cfg = _engine_cfg(
        1,
        v,
        sr.pagerank(alpha),
        max_iters=iters,
        weight_from_degree=True,
        alpha=alpha,
        **kw,
    )
    return DiffIFE(cfg, graph, init, batch_capacity=batch_capacity, mesh=mesh)


# --------------------------------------------------------------------------- RPQ
@dataclasses.dataclass(frozen=True)
class NFA:
    """Nondeterministic automaton over edge labels.

    ``delta``: label → [(state, state')] transitions; used to build the
    product graph (v, q) whose reachability answers the RPQ.
    """

    num_states: int
    delta: dict[int, list[tuple[int, int]]]
    start: int
    accept: tuple[int, ...]

    @staticmethod
    def star(label: int) -> "NFA":
        """Q1 = a*"""
        return NFA(1, {label: [(0, 0)]}, 0, (0,))

    @staticmethod
    def concat_star(a: int, b: int) -> "NFA":
        """Q2 = a ∘ b*"""
        return NFA(2, {a: [(0, 1)], b: [(1, 1)]}, 0, (1,))

    @staticmethod
    def chain(labels: Sequence[int]) -> "NFA":
        """Q3 = l1 ∘ l2 ∘ … ∘ lk (fixed-length path template)."""
        delta: dict[int, list[tuple[int, int]]] = {}
        for j, lbl in enumerate(labels):
            delta.setdefault(int(lbl), []).append((j, j + 1))
        return NFA(len(labels) + 1, delta, 0, (len(labels),))


class RPQ:
    """Continuous RPQ evaluation via Diff-IFE on the NFA-product graph.

    Base-graph updates are translated into product-graph updates (one product
    edge per matching transition); the engine then maintains reachability
    (min-hop semiring) from (source, start-state).
    """

    def __init__(
        self,
        graph: DynamicGraph,
        nfa: NFA,
        sources: Sequence[int],
        *,
        max_iters: int = 64,
        product_capacity: int | None = None,
        batch_capacity: int = 32,
        **kw,
    ) -> None:
        self.base = graph
        self.nfa = nfa
        self.sources = [int(s) for s in sources]
        n, src, dst, w, _ = product_graph(graph, nfa.delta, nfa.num_states)
        cap = product_capacity
        if cap is None:
            # worst case: every base slot × max transitions per label
            per = max((len(v) for v in nfa.delta.values()), default=1)
            cap = max(16, graph.capacity * per)
        self.pgraph = DynamicGraph(
            n, list(zip(src.tolist(), dst.tolist(), w.tolist())), capacity=cap
        )
        init = _source_init(
            [s * nfa.num_states + nfa.start for s in self.sources], n
        )
        cfg = _engine_cfg(len(sources), n, sr.min_hop(), max_iters=max_iters, **kw)
        self.engine = DiffIFE(cfg, self.pgraph, init, batch_capacity=batch_capacity)

    def _translate(self, updates) -> list[tuple[int, int, int, float, int]]:
        out = []
        for (u, v, lbl, w, sign) in updates:
            for (q, q2) in self.nfa.delta.get(int(lbl), ()):  # non-matching labels: no-op
                out.append(
                    (
                        int(u) * self.nfa.num_states + q,
                        int(v) * self.nfa.num_states + q2,
                        0,
                        1.0,
                        int(sign),
                    )
                )
        return out

    def apply_updates(self, updates):
        self.base.apply_batch(updates)
        pu = self._translate(updates)
        if pu:
            return self.engine.apply_updates(pu)
        return self.engine.last_stats

    def reachable(self) -> np.ndarray:
        """bool [Q, V_base]: which base vertices match the RPQ per source."""
        d = self.engine.answers().reshape(
            len(self.sources), self.base.num_vertices, self.nfa.num_states
        )
        return np.isfinite(d[:, :, list(self.nfa.accept)]).any(axis=-1)

    def nbytes(self) -> int:
        return self.engine.nbytes()
