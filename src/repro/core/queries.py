"""Query classes from the paper (§6.1.2) — thin builders over the plan IR.

Each query family is a :mod:`repro.core.plan` builder; the functions here
assemble a *batch* of plans and stand up the dense engine for them (the
legacy one-shot API: the query set is fixed at construction).  For a runtime
query lifecycle — register/deregister mid-stream, engine choice — use
:class:`repro.core.session.CQPSession` with the same plans.

SPSP/SSSP/K-hop/RPQ are *continuous registered queries* (Q of them batched
in the leading axis); WCC and PageRank are single batch computations (Q=1).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import dropping as dr
from repro.core import plan as qplan
from repro.core.engine import DiffIFE
from repro.core.graph import DynamicGraph
from repro.core.plan import NFA  # noqa: F401  (legacy re-export)
from repro.core.session import CQPSession, engine_config_for


def engine_from_plans(
    graph: DynamicGraph,
    plans: Sequence[qplan.QueryPlan],
    *,
    batch_capacity: int = 32,
    mesh=None,
    mode: str = "jod",
    drop: dr.DropConfig | None = None,
    store_capacity: int = 16,
    jstore_capacity: int = 8,
    backend: str = "coo",
    ell_block_v: int = 128,
    interpret: bool | None = None,
) -> DiffIFE:
    """Dense engine for a fixed batch of same-family plans (legacy shape:
    Q slots, all active, no padding).  ``drop`` is the session-level
    DroppedVT representation; each plan's own ``drop`` supplies its
    per-query selection row."""
    first = plans[0]
    for p in plans[1:]:
        if p.family_key() != first.family_key():
            raise ValueError(
                "plans in one engine batch must share a family "
                f"({p.family_key()} vs {first.family_key()})"
            )
    spec = drop or next((p.drop for p in plans if p.drop.enabled()), dr.DropConfig())
    for p in plans:
        if p.drop.enabled() and p.drop.mode != spec.mode:
            raise ValueError(
                f"plan drop mode {p.drop.mode!r} does not match the "
                f"engine's DroppedVT representation {spec.mode!r}"
            )
    # a plan whose Join node materializes its trace needs the VDC join store
    if any(p.join_policy() == "materialize" for p in plans):
        mode = "vdc"
    v = graph.num_vertices
    cfg = engine_config_for(
        first,
        num_queries=len(plans),
        num_vertices=v,
        mode=mode,
        drop=spec,
        store_capacity=store_capacity,
        jstore_capacity=jstore_capacity,
        backend=backend,
        ell_block_v=ell_block_v,
        interpret=interpret,
    )
    init = np.stack([p.build_init(v) for p in plans])
    return DiffIFE(
        cfg,
        graph,
        init,
        batch_capacity=batch_capacity,
        mesh=mesh,
        drop_rows=[p.drop for p in plans],
        join_rows=[p.join_policy() != "drop" for p in plans],
    )


# --------------------------------------------------------------------------- SSSP / SPSP
def sssp(
    graph: DynamicGraph,
    sources: Sequence[int],
    *,
    max_iters: int = 64,
    batch_capacity: int = 32,
    mesh=None,
    drop: dr.DropConfig | None = None,
    **kw,
) -> DiffIFE:
    """Q concurrent single-source shortest-distance fields (Bellman-Ford IFE)."""
    plans = [
        qplan.sssp(int(s), max_iters=max_iters, drop=drop) for s in sources
    ]
    return engine_from_plans(
        graph, plans, batch_capacity=batch_capacity, mesh=mesh, drop=drop, **kw
    )


def spsp_answers(engine: DiffIFE, targets: Sequence[int]) -> np.ndarray:
    """SPSP = SSSP field read at the target (paper's query form)."""
    d = engine.answers()
    return np.asarray([d[q, int(t)] for q, t in enumerate(targets)], np.float32)


# --------------------------------------------------------------------------- K-hop
def khop(
    graph: DynamicGraph,
    sources: Sequence[int],
    k: int = 5,
    *,
    batch_capacity: int = 32,
    mesh=None,
    drop: dr.DropConfig | None = None,
    **kw,
) -> DiffIFE:
    """Vertices within ≤ k hops of each source; iterations bounded by k."""
    plans = [qplan.khop(int(s), k=int(k), drop=drop) for s in sources]
    return engine_from_plans(
        graph, plans, batch_capacity=batch_capacity, mesh=mesh, drop=drop, **kw
    )


def khop_reachable(engine: DiffIFE) -> np.ndarray:
    return np.isfinite(engine.answers())


# --------------------------------------------------------------------------- WCC
def wcc(
    graph: DynamicGraph,
    *,
    max_iters: int = 128,
    batch_capacity: int = 32,
    mesh=None,
    drop: dr.DropConfig | None = None,
    **kw,
) -> DiffIFE:
    """Weakly connected components: min-label propagation on the symmetrized
    graph (caller supplies a graph with both edge directions)."""
    plans = [qplan.wcc(max_iters=max_iters, drop=drop)]
    return engine_from_plans(
        graph, plans, batch_capacity=batch_capacity, mesh=mesh, drop=drop, **kw
    )


# --------------------------------------------------------------------------- PageRank
def pagerank(
    graph: DynamicGraph,
    *,
    iters: int = 10,
    alpha: float = 0.85,
    batch_capacity: int = 32,
    mesh=None,
    drop: dr.DropConfig | None = None,
    **kw,
) -> DiffIFE:
    """Pregel-style PageRank, fixed ``iters`` rounds (paper §6.1.2)."""
    plans = [qplan.pagerank(iters=iters, alpha=alpha, drop=drop)]
    return engine_from_plans(
        graph, plans, batch_capacity=batch_capacity, mesh=mesh, drop=drop, **kw
    )


# --------------------------------------------------------------------------- RPQ
class RPQ:
    """Continuous RPQ evaluation via Diff-IFE on the NFA-product graph.

    Legacy wrapper over :class:`~repro.core.session.CQPSession`: the session
    owns the product-graph construction and translates base-graph updates
    into product updates (one product edge per matching NFA transition); the
    engine maintains reachability (min-hop semiring) from (source, start).
    """

    def __init__(
        self,
        graph: DynamicGraph,
        nfa: NFA,
        sources: Sequence[int],
        *,
        max_iters: int = 64,
        product_capacity: int | None = None,
        batch_capacity: int = 32,
        drop: dr.DropConfig | None = None,
        join_store: str = "auto",
        **kw,
    ) -> None:
        self.base = graph
        self.nfa = nfa
        self.sources = [int(s) for s in sources]
        self.session = CQPSession(
            graph,
            engine="dense",
            batch_capacity=batch_capacity,
            product_capacity=product_capacity,
            min_slots=len(self.sources),
            drop=drop,
            **kw,
        )
        self.handles = self.session.register_many(
            [
                qplan.rpq(
                    s, nfa, max_iters=max_iters, drop=drop, join_store=join_store
                )
                for s in self.sources
            ]
        )

    @property
    def pgraph(self) -> DynamicGraph:
        return self.session._egraph

    @property
    def engine(self) -> DiffIFE:
        return self.session._impl.impl

    def _translate(self, updates) -> list[tuple[int, int, int, float, int]]:
        return self.session._translate(updates)

    def apply_updates(self, updates):
        return self.session.apply_updates(updates)

    def reachable(self) -> np.ndarray:
        """bool [Q, V_base]: which base vertices match the RPQ per source."""
        return np.stack([self.session.reachable(h) for h in self.handles])

    def nbytes(self) -> int:
        return self.session.nbytes()
