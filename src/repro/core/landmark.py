"""Landmark-index application of Diff-IFE (paper §6.6, Fig. 9).

A landmark index stores shortest distances between every vertex and a small
set of high-degree "landmark" vertices.  We maintain, per landmark l, two
SSSP fields differentially (Diff-IFE):

    fwd[l, v] = d(l → v)     — SSSP on G from l
    rev[l, v] = d(v → l)     — SSSP on Gᵀ from l

From these, triangle bounds prune the Bellman-Ford search of SCRATCH:

    ub(s, t)  = min_l rev[l, s] + fwd[l, t]                 (d(s,t) ≤ ub)
    lb(v, t)  = max_l max(fwd[l, t] − fwd[l, v],
                          rev[l, v] − rev[l, t])            (d(v,t) ≥ lb)

During the SPSP scratch run from s to t, a vertex v with
``dist(v) + lb(v, t) > ub`` cannot lie on a shortest s→t path, so it never
propagates — the paper's SCRATCH-LANDMARK.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr
from repro.core.engine import DiffIFE, EngineConfig, GraphArrays, edge_messages
from repro.core.graph import DynamicGraph
from repro.core.queries import _engine_cfg, _source_init

Array = jnp.ndarray


def _transpose_updates(updates):
    return [(v, u, lbl, w, sign) for (u, v, lbl, w, sign) in updates]


class LandmarkIndex:
    """Differentially-maintained landmark distance index."""

    def __init__(
        self,
        graph: DynamicGraph,
        landmarks: Sequence[int],
        *,
        max_iters: int = 64,
        **kw,
    ) -> None:
        self.landmarks = [int(l) for l in landmarks]
        v = graph.num_vertices
        self.graph = graph
        # forward engine shares the caller's graph object; the reverse engine
        # owns a transposed twin fed with transposed update batches.
        rev_edges = [
            (int(graph.dst[e]), int(graph.src[e]), float(graph.weight[e]))
            for e in np.nonzero(graph.valid)[0]
        ]
        self.rgraph = DynamicGraph(v, rev_edges, capacity=graph.capacity)
        cfg = _engine_cfg(
            len(self.landmarks), v, sr.min_plus(), max_iters=max_iters, **kw
        )
        init = _source_init(self.landmarks, v)
        self.fwd_engine = DiffIFE(cfg, graph, init)
        self.rev_engine = DiffIFE(cfg, self.rgraph, init)

    def apply_updates(self, updates) -> None:
        self.fwd_engine.apply_updates(updates)
        self.rev_engine.apply_updates(_transpose_updates(updates))

    @property
    def fwd(self) -> np.ndarray:  # [L, V] d(l → v)
        return self.fwd_engine.answers()

    @property
    def rev(self) -> np.ndarray:  # [L, V] d(v → l)
        return self.rev_engine.answers()

    def nbytes(self) -> int:
        return self.fwd_engine.nbytes() + self.rev_engine.nbytes()


@partial(jax.jit, static_argnums=0)
def _pruned_bf(
    cfg: EngineConfig,
    g: GraphArrays,
    init: Array,  # [Q, V]
    lb: Array,  # [Q, V]  lower bound d(v → t)
    ub: Array,  # [Q]     upper bound d(s → t)
) -> tuple[Array, Array]:
    """Bellman-Ford with landmark pruning: pruned vertices never propagate."""

    def body(carry):
        i, cur, _ = carry
        live = (cur + lb) <= ub[:, None]  # can still be on a shortest path
        masked = jnp.where(live, cur, jnp.inf)
        new = jnp.minimum(
            cur,
            jax.vmap(
                lambda m: jax.ops.segment_min(m, g.dst, num_segments=cur.shape[1])
            )(edge_messages(cfg, masked, g)),
        )
        return (i + 1, new, (new != cur).any())

    def cond(carry):
        i, _, changed = carry
        return (i <= jnp.int32(cfg.max_iters)) & changed

    i, final, _ = jax.lax.while_loop(cond, body, (jnp.int32(1), init, jnp.bool_(True)))
    return final, i - 1


class ScratchLandmark:
    """SCRATCH-LANDMARK (§6.6): scratch SPSP with landmark pruning.

    Updates first maintain the landmark index differentially, then each
    registered (s, t) query re-runs pruned Bellman-Ford from scratch.
    """

    def __init__(
        self,
        graph: DynamicGraph,
        queries: Sequence[tuple[int, int]],
        num_landmarks: int = 10,
        *,
        max_iters: int = 64,
        **kw,
    ) -> None:
        self.graph = graph
        self.queries = [(int(s), int(t)) for s, t in queries]
        deg = graph.degrees_total()
        landmarks = np.argsort(-deg)[:num_landmarks]
        self.index = LandmarkIndex(graph, landmarks, max_iters=max_iters, **kw)
        self.cfg = _engine_cfg(
            len(queries), graph.num_vertices, sr.min_plus(), max_iters=max_iters
        )
        self._recompute()

    def _bounds(self) -> tuple[np.ndarray, np.ndarray]:
        fwd, rev = self.index.fwd, self.index.rev  # [L, V]
        s = np.asarray([q[0] for q in self.queries])
        t = np.asarray([q[1] for q in self.queries])
        ub = np.min(rev[:, s] + fwd[:, t], axis=0)  # [Q]
        lb = np.maximum(
            fwd[:, t][:, :, None] - fwd[:, None, :],  # [L, Q, V]
            rev[:, None, :] - rev[:, t][:, :, None],
        )
        # inf − inf → nan: no information → 0.  A +inf bound is *valid*
        # (l reaches v but not t ⇒ v cannot reach t) and prunes v outright.
        lb = np.where(np.isnan(lb), 0.0, lb)
        return np.maximum(lb, 0.0).max(axis=0), ub  # [Q, V], [Q]

    def _recompute(self) -> None:
        g = GraphArrays.from_snapshot(self.graph.snapshot())
        lb, ub = self._bounds()
        init = _source_init([q[0] for q in self.queries], self.graph.num_vertices)
        final, iters = _pruned_bf(
            self.cfg,
            g,
            jnp.asarray(init),
            jnp.asarray(lb, jnp.float32),
            jnp.asarray(ub, jnp.float32),
        )
        self._dists = np.asarray(final)
        self.last_iters = int(iters)

    def apply_updates(self, updates) -> None:
        self.index.apply_updates(updates)  # graph mutated here (fwd engine)
        self._recompute()

    def answers(self) -> np.ndarray:
        """Shortest s→t distance per registered query."""
        t = np.asarray([q[1] for q in self.queries])
        return self._dists[np.arange(len(self.queries)), t]

    def nbytes(self) -> int:
        return self.index.nbytes()
