"""Landmark-index application of Diff-IFE (paper §6.6, Fig. 9).

A landmark index stores shortest distances between every vertex and a small
set of high-degree "landmark" vertices.  We maintain, per landmark l, two
SSSP fields differentially (Diff-IFE):

    fwd[l, v] = d(l → v)     — SSSP on G from l
    rev[l, v] = d(v → l)     — SSSP on Gᵀ from l

From these, triangle bounds prune the Bellman-Ford search of SCRATCH:

    ub(s, t)  = min_l rev[l, s] + fwd[l, t]                 (d(s,t) ≤ ub)
    lb(v, t)  = max_l max(fwd[l, t] − fwd[l, v],
                          rev[l, v] − rev[l, t])            (d(v,t) ≥ lb)

During the SPSP scratch run from s to t, a vertex v with
``dist(v) + lb(v, t) > ub`` cannot lie on a shortest s→t path, so it never
propagates — the paper's SCRATCH-LANDMARK.

This module is self-contained math + a legacy direct-engine wrapper
(:class:`LandmarkIndex`).  The *production* form is the plan-optimizer
rewrite (`repro.planner.landmark_rewrite`): there the 2·L SSSP fields are
registered as operator-addressed queries of a :class:`CQPSession`, so byte
accounting, drop policies and the memory governor apply to the index like
any other operator.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dropping as dr
from repro.core import semiring as sr
from repro.core.engine import DiffIFE, EngineConfig, GraphArrays, edge_messages
from repro.core.graph import DynamicGraph

Array = jnp.ndarray
INF = np.float32(np.inf)


# ----------------------------------------------------------------- helpers
def source_init(
    sources: Sequence[int], num_vertices: int, value: float = 0.0
) -> np.ndarray:
    """Stacked source-init rows [Q, V] (the plan-IR form is
    ``InitSpec(kind="source")``; this is the raw-engine equivalent)."""
    init = np.full((len(sources), num_vertices), INF, dtype=np.float32)
    for q, s in enumerate(sources):
        init[q, int(s)] = value
    return init


def engine_cfg(
    num_queries: int,
    num_vertices: int,
    semiring,
    *,
    max_iters: int,
    mode: str = "jod",
    drop: dr.DropConfig | None = None,
    weight_from_degree: bool = False,
    **kw,
) -> EngineConfig:
    """Raw :class:`EngineConfig` builder for the direct-engine wrappers and
    the planner's pruned-scratch runs (plan families go through
    ``session.engine_config_for`` instead)."""
    return EngineConfig(
        num_queries=num_queries,
        num_vertices=num_vertices,
        max_iters=max_iters,
        semiring=semiring,
        mode=mode,
        drop=drop or dr.DropConfig(),
        weight_from_degree=weight_from_degree,
        **kw,
    )


def transpose_updates(updates) -> list[tuple[int, int, int, float, int]]:
    """δE on G → δE on Gᵀ (swap endpoints, keep label/weight/sign)."""
    return [(v, u, lbl, w, sign) for (u, v, lbl, w, sign) in updates]


def transpose_graph(graph: DynamicGraph) -> DynamicGraph:
    """Gᵀ as a fresh :class:`DynamicGraph` (same capacity and vertex space).

    Vectorized: the live-edge arrays are gathered and written through fancy
    indexing — no Python loop over edge slots.  Live edges compact to the
    low slots, so the twin's free list is the plain tail range.
    """
    v, cap = graph.num_vertices, graph.capacity
    out = DynamicGraph(v, [], capacity=cap, weighted=graph.weighted)
    live = np.nonzero(graph.valid)[0]
    n = int(live.size)
    src = graph.dst[live].astype(np.int32)  # transposed endpoints
    dst = graph.src[live].astype(np.int32)
    out.src[:n] = src
    out.dst[:n] = dst
    out.weight[:n] = graph.weight[live]
    out.label[:n] = graph.label[live]
    out.valid[:n] = True
    out.out_degree[:] = np.bincount(src, minlength=v)
    out.in_degree[:] = np.bincount(dst, minlength=v)
    out._slot = {
        (int(u), int(w), int(lbl)): i
        for i, (u, w, lbl) in enumerate(zip(src, dst, out.label[:n]))
    }
    out._free = list(range(cap - 1, n - 1, -1))
    return out


def select_landmarks(graph: DynamicGraph, num_landmarks: int) -> list[int]:
    """The ``num_landmarks`` highest-total-degree vertices (§6.6)."""
    deg = graph.degrees_total()
    return [int(l) for l in np.argsort(-deg, kind="stable")[: int(num_landmarks)]]


def triangle_bounds(
    fwd: np.ndarray,  # [L, V] d(l → v)
    rev: np.ndarray,  # [L, V] d(v → l)
    sources: Sequence[int],
    targets: Sequence[int],
) -> tuple[np.ndarray, np.ndarray]:
    """Per-query pruning bounds: ``(lb [Q, V], ub [Q])``.

    inf − inf → nan: no information → 0.  A +inf lower bound is *valid*
    (l reaches v but not t ⇒ v cannot reach t) and prunes v outright.
    """
    s = np.asarray(sources, dtype=np.int64)
    t = np.asarray(targets, dtype=np.int64)
    with np.errstate(invalid="ignore"):  # inf − inf → nan, mapped to 0 below
        ub = np.min(rev[:, s] + fwd[:, t], axis=0)  # [Q]
        lb = np.maximum(
            fwd[:, t][:, :, None] - fwd[:, None, :],  # [L, Q, V]
            rev[:, None, :] - rev[:, t][:, :, None],
        )
    lb = np.where(np.isnan(lb), 0.0, lb)
    return np.maximum(lb, 0.0).max(axis=0), ub  # [Q, V], [Q]


# -------------------------------------------------------------- legacy index
class LandmarkIndex:
    """Differentially-maintained landmark distance index (direct engines)."""

    def __init__(
        self,
        graph: DynamicGraph,
        landmarks: Sequence[int],
        *,
        max_iters: int = 64,
        **kw,
    ) -> None:
        self.landmarks = [int(l) for l in landmarks]
        v = graph.num_vertices
        self.graph = graph
        # forward engine shares the caller's graph object; the reverse engine
        # owns a transposed twin fed with transposed update batches.
        self.rgraph = transpose_graph(graph)
        cfg = engine_cfg(
            len(self.landmarks), v, sr.min_plus(), max_iters=max_iters, **kw
        )
        init = source_init(self.landmarks, v)
        self.fwd_engine = DiffIFE(cfg, graph, init)
        self.rev_engine = DiffIFE(cfg, self.rgraph, init)

    def apply_updates(self, updates) -> None:
        self.fwd_engine.apply_updates(updates)
        self.rev_engine.apply_updates(transpose_updates(updates))

    @property
    def fwd(self) -> np.ndarray:  # [L, V] d(l → v)
        return self.fwd_engine.answers()

    @property
    def rev(self) -> np.ndarray:  # [L, V] d(v → l)
        return self.rev_engine.answers()

    def nbytes(self) -> int:
        return self.fwd_engine.nbytes() + self.rev_engine.nbytes()


@partial(jax.jit, static_argnums=0)
def _pruned_bf(
    cfg: EngineConfig,
    g: GraphArrays,
    init: Array,  # [Q, V]
    lb: Array,  # [Q, V]  lower bound d(v → t)
    ub: Array,  # [Q]     upper bound d(s → t)
) -> tuple[Array, Array, Array]:
    """Bellman-Ford with landmark pruning: pruned vertices never propagate.

    Returns ``(final [Q, V], iters, work)`` where ``work`` counts the live
    (propagating) vertex slots summed over iterations — the deterministic
    scratch-work meter Fig. 9 reports alongside wall time (the un-pruned
    baseline's analog is ``iters · Q · V``).
    """

    def body(carry):
        i, cur, _, work = carry
        live = (cur + lb) <= ub[:, None]  # can still be on a shortest path
        masked = jnp.where(live, cur, jnp.inf)
        new = jnp.minimum(
            cur,
            jax.vmap(
                lambda m: jax.ops.segment_min(m, g.dst, num_segments=cur.shape[1])
            )(edge_messages(cfg, masked, g)),
        )
        return (i + 1, new, (new != cur).any(), work + live.sum(dtype=jnp.int32))

    def cond(carry):
        i, _, changed, _ = carry
        return (i <= jnp.int32(cfg.max_iters)) & changed

    i, final, _, work = jax.lax.while_loop(
        cond, body, (jnp.int32(1), init, jnp.bool_(True), jnp.int32(0))
    )
    return final, i - 1, work


def pruned_scratch_run(
    cfg: EngineConfig,
    graph: DynamicGraph,
    sources: Sequence[int],
    targets: Sequence[int],
    fwd: np.ndarray | None,
    rev: np.ndarray | None,
) -> tuple[np.ndarray, int, int]:
    """One SCRATCH-LANDMARK evaluation: ``(dists [Q, V], iters, work)``.

    ``fwd``/``rev`` are the index fields ([L, V]); pass ``None`` for both to
    run with trivial bounds (lb = 0, ub = ∞ — plain scratch, used while the
    governor holds the index shed).  Distances are exact at each query's
    target; pruned vertices elsewhere may read +inf.
    """
    v = graph.num_vertices
    if fwd is None or rev is None:
        lb = np.zeros((len(sources), v), np.float32)
        ub = np.full(len(sources), np.inf, np.float32)
    else:
        lb, ub = triangle_bounds(fwd, rev, sources, targets)
    g = GraphArrays.from_snapshot(graph.snapshot())
    final, iters, work = _pruned_bf(
        cfg,
        g,
        jnp.asarray(source_init(sources, v)),
        jnp.asarray(lb, jnp.float32),
        jnp.asarray(ub, jnp.float32),
    )
    return np.asarray(final), int(iters), int(work)


class ScratchLandmark:
    """SCRATCH-LANDMARK (§6.6): scratch SPSP with landmark pruning.

    Updates first maintain the landmark index differentially, then each
    registered (s, t) query re-runs pruned Bellman-Ford from scratch.
    Legacy direct-engine wrapper — the session form is
    ``CQPSession.register(plan.spsp(s, t), optimize="always")``.
    """

    def __init__(
        self,
        graph: DynamicGraph,
        queries: Sequence[tuple[int, int]],
        num_landmarks: int = 10,
        *,
        max_iters: int = 64,
        **kw,
    ) -> None:
        self.graph = graph
        self.queries = [(int(s), int(t)) for s, t in queries]
        landmarks = select_landmarks(graph, num_landmarks)
        self.index = LandmarkIndex(graph, landmarks, max_iters=max_iters, **kw)
        self.cfg = engine_cfg(
            len(queries), graph.num_vertices, sr.min_plus(), max_iters=max_iters
        )
        self._recompute()

    def _recompute(self) -> None:
        self._dists, self.last_iters, self.last_work = pruned_scratch_run(
            self.cfg,
            self.graph,
            [q[0] for q in self.queries],
            [q[1] for q in self.queries],
            self.index.fwd,
            self.index.rev,
        )

    def apply_updates(self, updates) -> None:
        self.index.apply_updates(updates)  # graph mutated here (fwd engine)
        self._recompute()

    def answers(self) -> np.ndarray:
        """Shortest s→t distance per registered query."""
        t = np.asarray([q[1] for q in self.queries])
        return self._dists[np.arange(len(self.queries)), t]

    def nbytes(self) -> int:
        return self.index.nbytes()
