"""Vectorized Bloom filter for Prob-Drop (paper §5.1.2).

The paper inserts 8-byte ``vertex_id ‖ iteration`` keys into a heap-allocated
Bloom filter (lemire/bloofi).  The TPU form is a flat bit array with k probes
derived by double hashing (Kirsch–Mitzenmacher): ``probe_j = h1 + j·h2 mod M``
with murmur3-finalizer mixes — branch-free, gather-only, and batchable over
every (query, vertex) pair at once.

The pure-JAX state is a ``bool[Q, M]`` array (simple scatter/gather); the
*accounted* memory is the packed size ``M/8`` bytes, which is also the layout
the Pallas ``bloom`` kernel operates on (u32 words, bit tests in VMEM).

Guarantee: no false negatives (a dropped VT pair always probes positive), so
Prob-Drop can only cause spurious recomputation — never a wrong answer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

# numpy scalars, NOT jnp: committed jnp scalars surface as captured
# constants inside the fused Pallas kernel body (pallas_call rejects them),
# while np scalars inline as jaxpr literals.
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_C3 = np.uint32(0x27D4EB2F)


def _mix(x: Array) -> Array:
    """murmur3 fmix32."""
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= _C1
    x ^= x >> 13
    x *= _C2
    x ^= x >> 16
    return x


def hash_key(v: Array, i: Array, salt: Array | int = 0) -> tuple[Array, Array]:
    """(h1, h2) for double hashing of the (vertex, iteration) key.

    Mirrors the paper's 8-byte concatenated key: both halves enter the mix.
    ``salt`` decorrelates per-query filters sharing one array.
    """
    v = jnp.asarray(v, jnp.uint32)
    i = jnp.asarray(i, jnp.uint32)
    s = jnp.asarray(salt, jnp.uint32)
    h1 = _mix(v * _C3 ^ _mix(i + s))
    h2 = _mix(i * _C1 ^ _mix(v ^ (s * _C2))) | 1  # odd → full cycle
    return h1, h2


@jax.tree_util.register_pytree_node_class
class BloomFilter:
    """bits: bool [..., M]; num_hashes is static (pytree aux data)."""

    def __init__(self, bits: Array, num_hashes: int) -> None:
        self.bits = bits
        self.num_hashes = num_hashes

    def tree_flatten(self):
        return (self.bits,), self.num_hashes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    def _replace(self, bits: Array) -> "BloomFilter":
        return BloomFilter(bits, self.num_hashes)

    @property
    def num_bits(self) -> int:
        return int(self.bits.shape[-1])

    @property
    def nbytes_accounted(self) -> int:
        """Packed size — what a production filter occupies (M/8 per filter)."""
        import numpy as np

        lead = int(np.prod(self.bits.shape[:-1])) if self.bits.ndim > 1 else 1
        return lead * ((self.num_bits + 7) // 8)


def make(shape: tuple[int, ...], num_bits: int, num_hashes: int = 4) -> BloomFilter:
    return BloomFilter(bits=jnp.zeros((*shape, num_bits), dtype=bool), num_hashes=num_hashes)


def _probes(flt: BloomFilter, v: Array, i: Array, salt: Array | int) -> Array:
    h1, h2 = hash_key(v, i, salt)
    j = jnp.arange(flt.num_hashes, dtype=jnp.uint32)
    probes = (h1[..., None] + j * h2[..., None]) % flt.num_bits
    return probes.astype(jnp.int32)  # [..., k]


def insert(flt: BloomFilter, v: Array, i: Array, mask: Array, salt: Array | int = 0) -> BloomFilter:
    """Set bits for keys (v, i) where ``mask``.

    ``v``/``i``/``mask`` share shape ``[..., N]`` matching the filter's
    leading dims; inserts are scattered along the last axis.
    """
    probes = _probes(flt, v, i, salt)  # [..., N, k]
    # Masked inserts scatter to a sacrificial bit slot (M) that is dropped.
    tgt = jnp.where(mask[..., None], probes, flt.num_bits)
    padded = jnp.concatenate(
        [flt.bits, jnp.zeros((*flt.bits.shape[:-1], 1), dtype=bool)], axis=-1
    )
    flat = tgt.reshape(*tgt.shape[:-2], -1)
    if flat.ndim == 1:
        new = padded.at[flat].set(True)
    else:
        # batched leading dims: flatten them, scatter per row, restore.
        lead = flt.bits.shape[:-1]
        p2 = padded.reshape(-1, padded.shape[-1])
        f2 = flat.reshape(p2.shape[0], -1)
        rows = jnp.arange(p2.shape[0])[:, None]
        new = p2.at[rows, f2].set(True).reshape(*lead, -1)
    return BloomFilter(bits=new[..., : flt.num_bits], num_hashes=flt.num_hashes)


def query(flt: BloomFilter, v: Array, i: Array, salt: Array | int = 0) -> Array:
    """True where (v, i) *may* have been inserted (no false negatives)."""
    probes = _probes(flt, v, i, salt)  # [..., N, k]
    got = jnp.take_along_axis(
        flt.bits[..., None, :], probes, axis=-1
    )
    return got.all(axis=-1)


def fill_fraction(flt: BloomFilter) -> Array:
    return flt.bits.mean(axis=-1)
