"""Dense difference store — the TPU form of the paper's eager-merged δD index.

After eager merging (§4.2) timestamps are one-dimensional (IFE iteration) and
negative multiplicities are implied, so each key holds a sorted list of
``(iteration, state)`` *change points*.  GraphflowDB stores these as a hash
table of sorted Java lists; here they are fixed-capacity sorted rows of a
dense tensor so every operation vectorizes over all (query, key) pairs:

    iters : int32  [..., S]   sorted ascending, padded with IMAX
    vals  : f32    [..., S]
    count : int32  [...]

The leading axes are ``[Q, V]`` for the vertex-state collection ``D`` and
``[Q, E]`` for VDC's join-output collection ``J``.

Two deliberate deviations from the paper (see DESIGN.md §2):

* **Implicit init diffs** — the paper's trace stores ``+(v, ∞)`` for every
  vertex at iteration 0; we make the initial state implicit (a lookup that
  finds nothing returns the query's init), saving one stored diff per key.
* **Bounded capacity** — rows hold at most ``S`` change points.  On overflow
  the *oldest* change point is evicted and routed through the dropping
  machinery (DroppedVT / Bloom), so capacity pressure degrades to recompute
  (paper §5 semantics), never to a wrong answer.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

Array = jnp.ndarray

# Plain Python int (weakly-typed in jnp ops, so int32 is preserved): a
# traced jnp scalar here would become a captured constant inside the fused
# Pallas kernel body, which pallas_call rejects.
IMAX = int(jnp.iinfo(jnp.int32).max)


class DiffStore(NamedTuple):
    iters: Array  # int32 [..., S]
    vals: Array  # float32 [..., S]
    count: Array  # int32 [...]

    @property
    def capacity(self) -> int:
        return int(self.iters.shape[-1])


def make(shape: tuple[int, ...], capacity: int) -> DiffStore:
    return DiffStore(
        iters=jnp.full((*shape, capacity), IMAX, dtype=jnp.int32),
        vals=jnp.zeros((*shape, capacity), dtype=jnp.float32),
        count=jnp.zeros(shape, dtype=jnp.int32),
    )


def used_entries(store: DiffStore) -> Array:
    return store.count.sum()


def lookup_le(store: DiffStore, i: Array | int) -> tuple[Array, Array, Array]:
    """Latest stored change point at iteration ≤ i.

    Returns ``(val, found_iter, found)``; where ``found`` is False the caller
    substitutes the implicit init state.  Padding is IMAX so a simple
    ≤-count reduction finds the insertion point (rows are sorted).
    """
    i = jnp.asarray(i, dtype=jnp.int32)
    mask = store.iters <= i[..., None] if i.ndim else store.iters <= i
    idx = mask.sum(axis=-1) - 1  # [-1 .. S-1]
    found = idx >= 0
    safe = jnp.maximum(idx, 0)
    val = jnp.take_along_axis(store.vals, safe[..., None], axis=-1)[..., 0]
    it = jnp.take_along_axis(store.iters, safe[..., None], axis=-1)[..., 0]
    return val, jnp.where(found, it, -1), found


def lookup_lt(store: DiffStore, i: Array | int) -> tuple[Array, Array, Array]:
    """Latest stored change point strictly before iteration i."""
    return lookup_le(store, jnp.asarray(i, dtype=jnp.int32) - 1)


def value_at(store: DiffStore, i: Array | int) -> tuple[Array, Array]:
    """(has_entry_at_i, value_at_i) for an exact iteration."""
    i = jnp.asarray(i, dtype=jnp.int32)
    eq = store.iters == (i[..., None] if i.ndim else i)
    has = eq.any(axis=-1)
    idx = jnp.argmax(eq, axis=-1)
    val = jnp.take_along_axis(store.vals, idx[..., None], axis=-1)[..., 0]
    return has, val


def has_at(store: DiffStore, i: Array | int) -> Array:
    i = jnp.asarray(i, dtype=jnp.int32)
    return (store.iters == (i[..., None] if i.ndim else i)).any(axis=-1)


def _shift_left(x: Array, fill) -> Array:
    return jnp.concatenate(
        [x[..., 1:], jnp.full_like(x[..., :1], fill)], axis=-1
    )


def _shift_right(x: Array) -> Array:
    return jnp.concatenate([x[..., :1], x[..., :-1]], axis=-1)


def upsert(
    store: DiffStore, i: Array | int, write: Array, new_vals: Array
) -> tuple[DiffStore, Array, Array]:
    """Insert-or-overwrite change point ``(i, new_vals)`` where ``write``.

    Eager-merge semantics: one change point per (key, iteration); a second
    write at the same iteration overwrites (the paper merges the new graph
    version's diff into the row).  Returns ``(store, evicted_mask,
    evicted_iter)`` — evictions happen only when a full row receives a new
    iteration and must shed its *oldest* change point; the engine registers
    them with the dropping structures.
    """
    i = jnp.asarray(i, dtype=jnp.int32)
    icol = i[..., None] if i.ndim else i
    s = store.capacity

    exists = (store.iters == icol).any(axis=-1)
    # --- overwrite path -------------------------------------------------
    eqidx = jnp.argmax(store.iters == icol, axis=-1)
    ow_vals = jnp.where(
        (write & exists)[..., None]
        & (jnp.arange(s) == eqidx[..., None]),
        (new_vals[..., None] if new_vals.ndim == store.count.ndim else new_vals),
        store.vals,
    )

    # --- insert path (row may be full → evict oldest) --------------------
    ins = write & ~exists
    full = store.count >= s
    evict = ins & full
    evicted_iter = store.iters[..., 0]
    base_iters = jnp.where(evict[..., None], _shift_left(store.iters, IMAX), store.iters)
    base_vals = jnp.where(evict[..., None], _shift_left(store.vals, 0.0), ow_vals)
    base_count = jnp.where(evict, store.count - 1, store.count)

    pos = (base_iters < icol).sum(axis=-1)
    ar = jnp.arange(s)
    sel_keep = ar < pos[..., None]
    sel_new = ar == pos[..., None]
    nv = new_vals[..., None] if new_vals.ndim == store.count.ndim else new_vals
    ins_iters = jnp.where(
        sel_keep, base_iters, jnp.where(sel_new, icol, _shift_right(base_iters))
    )
    ins_vals = jnp.where(sel_keep, base_vals, jnp.where(sel_new, nv, _shift_right(base_vals)))

    out_iters = jnp.where(ins[..., None], ins_iters, base_iters)
    out_vals = jnp.where(ins[..., None], ins_vals, base_vals)
    out_count = jnp.where(ins, base_count + 1, base_count)
    return DiffStore(out_iters, out_vals, out_count), evict, evicted_iter


def remove_at(store: DiffStore, i: Array | int, mask: Array) -> DiffStore:
    """Remove the change point at exactly iteration ``i`` where ``mask``.

    Used when maintenance finds that a previously-stored diff vanishes (the
    new value equals the preceding change point: the +/- pair cancels).
    """
    i = jnp.asarray(i, dtype=jnp.int32)
    icol = i[..., None] if i.ndim else i
    eq = store.iters == icol
    do = mask & eq.any(axis=-1)
    pos = jnp.argmax(eq, axis=-1)
    ar = jnp.arange(store.capacity)
    after = ar >= pos[..., None]
    sl_iters = _shift_left(store.iters, IMAX)
    sl_vals = _shift_left(store.vals, 0.0)
    out_iters = jnp.where(do[..., None] & after, sl_iters, store.iters)
    out_vals = jnp.where(do[..., None] & after, sl_vals, store.vals)
    out_count = jnp.where(do, store.count - 1, store.count)
    return DiffStore(out_iters, out_vals, out_count)


def gather_rows(store: DiffStore, idx: Array) -> DiffStore:
    """Reindex the key axis (second-to-last): result row ``k`` is input row
    ``idx[k]``; ``idx[k] < 0`` yields an empty row.

    Used when the vertex-sharded edge layout regrows (``ShardIndex``
    overflow): VDC's per-edge J store rows must follow their edge slots to
    the new cell assignment, with cells that never held a live edge left
    empty (the ``j0`` implicit-init fallback is then correct for them).
    """
    idx = jnp.asarray(idx, jnp.int32)
    safe = jnp.maximum(idx, 0)
    ok = idx >= 0
    iters = jnp.where(ok[..., None], jnp.take(store.iters, safe, axis=-2), IMAX)
    vals = jnp.where(ok[..., None], jnp.take(store.vals, safe, axis=-2), 0.0)
    count = jnp.where(ok, jnp.take(store.count, safe, axis=-1), 0)
    return DiffStore(iters, vals, count)


def nbytes_used(store: DiffStore, bytes_per_entry: int = 8) -> Array:
    """Accountant view: live entries × (4B iter + 4B state) — matches the
    paper's difference-count-based memory metering."""
    return store.count.sum() * bytes_per_entry
