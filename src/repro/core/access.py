"""AccessDᵢᵛWithDrops — the paper's §5.1 access procedure, faithful form.

The engine's maintenance sweep repairs dropped diffs inline (forward form,
see engine.py); this module exposes the paper's *standalone* access path —
"give me D_i^v right now" against a store with dropped change points — used
by read-only consumers (answer extraction mid-epoch, debugging, tests) and
as the executable specification the dense sweep is validated against.

Steps (paper §5.1.1 / §5.1.2):
  1. g* ← latest stored change point ≤ i for v.
  2. d* ← latest dropped VT pair ≤ i for v (Det: sorted store lookup;
     Prob: Bloom probes downward from i — false positives allowed).
  3. If d* > g*: recompute the value at d* by rerunning the aggregator at
     d*−1, whose in-neighbour reads recurse through this same procedure.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import dropping as dr
from repro.core.engine import EngineConfig, EngineState, GraphArrays
from repro.core.semiring import reduce_pair


def access(
    cfg: EngineConfig,
    state: EngineState,
    g: GraphArrays,
    v: int,
    i: int,
    *,
    _depth: int = 0,
) -> np.ndarray:
    """D_i^v per query — the recursive scalar procedure. Returns [Q]."""
    iters = np.asarray(state.dstore.iters[:, v])  # [Q, S]
    vals = np.asarray(state.dstore.vals[:, v])
    init = np.asarray(state.init[:, v])
    q = iters.shape[0]

    # step 1: latest stored ≤ i
    le = iters <= i
    g_star = np.where(le.any(axis=1), np.max(np.where(le, iters, -1), axis=1), -1)
    idx = np.clip(le.sum(axis=1) - 1, 0, None)
    stored_val = np.where(g_star >= 0, vals[np.arange(q), idx], init)

    if not cfg.drop.enabled() or _depth > cfg.max_iters:
        return stored_val

    # step 2: latest dropped ≤ i (per query) — probe downward like §5.1.2
    d_star = np.full(q, -1, np.int64)
    for j in range(i, -1, -1):
        probe = np.asarray(
            dr.dropped_at(state.drop, jnp.int32(j), cfg.num_vertices)[:, v]
        )
        d_star = np.where((d_star < 0) & probe & (j > g_star), j, d_star)
        if (d_star >= 0).all():
            break

    out = stored_val.copy()
    need = d_star > g_star
    if need.any():
        # step 3: recompute at d* from in-neighbour values at d*−1
        src = np.asarray(g.src)
        dst = np.asarray(g.dst)
        valid = np.asarray(g.valid)
        w = np.asarray(g.weight)
        in_edges = np.nonzero(valid & (dst == v))[0]
        for qi in np.nonzero(need)[0]:
            di = int(d_star[qi])
            best = access(cfg, state, g, v, di - 1, _depth=_depth + 1)[qi]
            for e in in_edges:
                u = int(src[e])
                uval = access(cfg, state, g, u, di - 1, _depth=_depth + 1)[qi]
                cand = float(
                    np.asarray(cfg.semiring.msg(jnp.float32(uval), jnp.float32(w[e])))
                )
                best = float(
                    np.asarray(reduce_pair(cfg.semiring, jnp.float32(cand), jnp.float32(best)))
                )
            out[qi] = best
    return out
