"""Core: the paper's differential computation engine and optimizations."""

from repro.core.engine import (  # noqa: F401
    DiffIFE,
    EngineConfig,
    EngineState,
    GraphArrays,
    MaintainStats,
    maintain,
    make_state,
    nbytes_accounted,
    reassemble,
)
from repro.core.graph import DynamicGraph, GraphSnapshot  # noqa: F401
