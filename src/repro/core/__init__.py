"""Core: the paper's differential computation engine and optimizations.

Public API (the session model over the operator-graph plan IR —
DESIGN.md §9/§11):

    from repro.core import CQPSession, plan
    sess = CQPSession(graph, engine="dense")
    h = sess.register(plan.rpq(0, plan.NFA.star(1), join_store="materialize"))
    sess.apply_updates_batched(log)
    sess.answers(h)
    sess.nbytes_per_operator()          # per-(query, operator) bytes
    sess.set_drop_policy(h, cfg, op="join")

The engine layer (``DiffIFE``, ``EngineConfig``, …) stays importable for
direct use.  The PR-3 deprecation shims (``repro.core.SparseDiffIFE`` /
``Scratch`` / ``RPQ``) are gone: import those classes from their home
modules (``repro.core.sparse_engine``, ``repro.core.scratch``,
``repro.core.queries``) — the session API is canonical.
"""

from repro.core import dataflow, plan  # noqa: F401  (builder namespaces)
from repro.core.dataflow import (
    NFA,
    Aggregate,
    Ingest,
    InitSpec,
    Iterate,
    Join,
    Transform,
)
from repro.core.engine import (
    DiffIFE,
    EngineConfig,
    EngineState,
    GraphArrays,
    MaintainStats,
    maintain,
    make_state,
    nbytes_accounted,
    reassemble,
)
from repro.core.governor import GovernorConfig, MemoryGovernor
from repro.core.graph import DynamicGraph, GraphSnapshot
from repro.core.plan import QueryPlan
from repro.core.session import CQPSession, EngineProtocol, QueryHandle
from repro.core.telemetry import RecomputeTelemetry

__all__ = [
    # session model
    "CQPSession",
    "QueryHandle",
    "QueryPlan",
    "InitSpec",
    "NFA",
    "EngineProtocol",
    "plan",
    # operator-graph IR
    "dataflow",
    "Ingest",
    "Transform",
    "Join",
    "Iterate",
    "Aggregate",
    # memory governor
    "GovernorConfig",
    "MemoryGovernor",
    "RecomputeTelemetry",
    # engine layer
    "DiffIFE",
    "EngineConfig",
    "EngineState",
    "GraphArrays",
    "MaintainStats",
    "maintain",
    "make_state",
    "nbytes_accounted",
    "reassemble",
    # graph layer
    "DynamicGraph",
    "GraphSnapshot",
]
