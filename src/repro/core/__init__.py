"""Core: the paper's differential computation engine and optimizations.

Public API (the session model — DESIGN.md §9):

    from repro.core import CQPSession, plan
    sess = CQPSession(graph, engine="dense")
    h = sess.register(plan.sssp(0))
    sess.apply_updates_batched(log)
    sess.answers(h)

The engine layer (``DiffIFE``, ``EngineConfig``, …) stays importable for
direct use; legacy one-shot entry points (``queries.sssp`` returning a bare
engine, ``SparseDiffIFE``, ``Scratch``, ``RPQ``) keep working for one
release via the deprecation shims below — new code should go through
:class:`CQPSession` with :mod:`repro.core.plan` builders.
"""

import warnings

from repro.core import plan  # noqa: F401  (the plan-builder namespace)
from repro.core.engine import (
    DiffIFE,
    EngineConfig,
    EngineState,
    GraphArrays,
    MaintainStats,
    maintain,
    make_state,
    nbytes_accounted,
    reassemble,
)
from repro.core.governor import GovernorConfig, MemoryGovernor
from repro.core.graph import DynamicGraph, GraphSnapshot
from repro.core.plan import NFA, InitSpec, QueryPlan
from repro.core.session import CQPSession, EngineProtocol, QueryHandle
from repro.core.telemetry import RecomputeTelemetry

__all__ = [
    # session model
    "CQPSession",
    "QueryHandle",
    "QueryPlan",
    "InitSpec",
    "NFA",
    "EngineProtocol",
    "plan",
    # memory governor
    "GovernorConfig",
    "MemoryGovernor",
    "RecomputeTelemetry",
    # engine layer
    "DiffIFE",
    "EngineConfig",
    "EngineState",
    "GraphArrays",
    "MaintainStats",
    "maintain",
    "make_state",
    "nbytes_accounted",
    "reassemble",
    # graph layer
    "DynamicGraph",
    "GraphSnapshot",
]

# Deprecated aliases — importable from repro.core for one more release.
_DEPRECATED = {
    "SparseDiffIFE": ("repro.core.sparse_engine", "SparseDiffIFE"),
    "Scratch": ("repro.core.scratch", "Scratch"),
    "ScratchEngine": ("repro.core.scratch", "ScratchEngine"),
    "RPQ": ("repro.core.queries", "RPQ"),
}


def __getattr__(name: str):
    if name in _DEPRECATED:
        mod_name, attr = _DEPRECATED[name]
        warnings.warn(
            f"repro.core.{name} is deprecated; import it from {mod_name} or "
            "use repro.core.CQPSession with repro.core.plan builders",
            DeprecationWarning,
            stacklevel=2,
        )
        import importlib

        return getattr(importlib.import_module(mod_name), attr)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
