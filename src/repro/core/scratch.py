"""SCRATCH baseline (§6.1.3): re-execute the static IFE after every batch.

Identical step function to the engine's JOD path — the same "incremental"
fixpoint loop the original DD paper calls the static algorithm — but no
difference sets are kept (zero maintenance memory, maximal recompute cost).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, GraphArrays, ife_step
from repro.core.graph import DynamicGraph

Array = jnp.ndarray


class ScratchStats(NamedTuple):
    iters_run: Array
    scheduled: Array  # V × iters (every vertex reruns every iteration)


@partial(jax.jit, static_argnums=0)
def scratch_run(cfg: EngineConfig, g: GraphArrays, init: Array) -> tuple[Array, ScratchStats]:
    """Run IFE to fixpoint (or max_iters) from the initial states."""

    def body(carry):
        i, cur, _ = carry
        new = ife_step(cfg, cur, g)
        changed = (new != cur).any()
        return (i + 1, new, changed)

    def cond(carry):
        i, _, changed = carry
        return (i <= jnp.int32(cfg.max_iters)) & changed

    i, final, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(1), init, jnp.bool_(True))
    )
    iters = i - 1
    q, v = init.shape
    return final, ScratchStats(iters, iters * jnp.int32(q * v))


class Scratch:
    """From-scratch continuous query processor (the paper's SCRATCH)."""

    def __init__(self, cfg: EngineConfig, graph: DynamicGraph, init) -> None:
        self.cfg = cfg
        self.graph = graph
        self.init = jnp.asarray(init, jnp.float32)
        self.g = GraphArrays.from_snapshot(graph.snapshot(), backend=cfg.backend)
        self._answers, self.last_stats = scratch_run(cfg, self.g, self.init)

    def apply_updates(self, updates) -> ScratchStats:
        self.graph.apply_batch(updates)
        self.g = GraphArrays.from_snapshot(self.graph.snapshot(), backend=self.cfg.backend)
        self._answers, self.last_stats = scratch_run(self.cfg, self.g, self.init)
        return self.last_stats

    def answers(self) -> np.ndarray:
        return np.asarray(self._answers)

    def nbytes(self) -> int:
        return 0  # no differences maintained


def scratch_like(engine_cfg: EngineConfig, graph: DynamicGraph, init) -> Scratch:
    """Scratch twin of a Diff-IFE engine (same semiring/query batch)."""
    return Scratch(engine_cfg, graph, init)
