"""SCRATCH baseline (§6.1.3): re-execute the static IFE after every batch.

Identical step function to the engine's JOD path — the same "incremental"
fixpoint loop the original DD paper calls the static algorithm — but no
difference sets are kept (zero maintenance memory, maximal recompute cost).

:class:`ScratchEngine` is the session-protocol form (`core/session.py`):
queries register/deregister as :class:`~repro.core.plan.QueryPlan` rows of
a host-side init matrix; every update batch re-runs the static IFE for the
whole matrix.  :class:`Scratch` remains the fixed-batch legacy wrapper.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as qp
from repro.core.engine import (
    ITER_TRACE,
    EngineConfig,
    GraphArrays,
    MaintainStats,
    ife_step,
    zeros_stats,
)
from repro.core.graph import DynamicGraph

Array = jnp.ndarray


@partial(jax.jit, static_argnums=0)
def scratch_run(
    cfg: EngineConfig, g: GraphArrays, init: Array
) -> tuple[Array, MaintainStats]:
    """Run IFE to fixpoint (or max_iters) from the initial states.

    Stats come back in the dense engine's :class:`MaintainStats` schema so
    telemetry / governor / metrics observe one uniform shape across engines;
    fields SCRATCH has no analog for (change points, drops, repairs) are
    structurally zero.  ``scheduled`` is V × iters per query — every vertex
    reruns every iteration, the baseline's defining cost.
    """

    def body(carry):
        i, cur, _ = carry
        new = ife_step(cfg, cur, g)
        changed = (new != cur).any()
        return (i + 1, new, changed)

    def cond(carry):
        i, _, changed = carry
        return (i <= jnp.int32(cfg.max_iters)) & changed

    i, final, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(1), init, jnp.bool_(True))
    )
    iters = i - 1
    q, v = init.shape
    per_iter = jnp.int32(q * v)
    # per-iteration schedule series: every iteration reruns the full matrix;
    # iterations beyond the trace depth fold into the last bin (as dense)
    bins = jnp.arange(ITER_TRACE, dtype=jnp.int32)
    sched_sizes = jnp.where(bins < jnp.minimum(iters, ITER_TRACE), per_iter, 0)
    overflow = jnp.maximum(iters - ITER_TRACE, 0) * per_iter
    sched_sizes = sched_sizes.at[ITER_TRACE - 1].add(overflow)
    stats = zeros_stats()._replace(
        iters_run=iters,
        scheduled=iters * per_iter,
        sched_sizes=sched_sizes,
    )
    return final, stats


class Scratch:
    """From-scratch continuous query processor (the paper's SCRATCH)."""

    def __init__(self, cfg: EngineConfig, graph: DynamicGraph, init) -> None:
        self.cfg = cfg
        self.graph = graph
        self.init = jnp.asarray(init, jnp.float32)
        self.g = GraphArrays.from_snapshot(graph.snapshot(), backend=cfg.backend)
        self._answers, self.last_stats = scratch_run(cfg, self.g, self.init)

    def apply_updates(self, updates) -> MaintainStats:
        self.graph.apply_batch(updates)
        self.g = GraphArrays.from_snapshot(self.graph.snapshot(), backend=self.cfg.backend)
        self._answers, self.last_stats = scratch_run(self.cfg, self.g, self.init)
        return self.last_stats

    def answers(self) -> np.ndarray:
        return np.asarray(self._answers)

    def nbytes(self) -> int:
        return 0  # no differences maintained


def scratch_like(engine_cfg: EngineConfig, graph: DynamicGraph, init) -> Scratch:
    """Scratch twin of a Diff-IFE engine (same semiring/query batch)."""
    return Scratch(engine_cfg, graph, init)


class ScratchEngine:
    """From-scratch CQP with a runtime query lifecycle (session protocol).

    Registered plans occupy rows of a host-side init matrix; re-execution
    covers all live rows in one jitted run (a row-count change retraces —
    SCRATCH is the baseline, not the throughput path).  ``nbytes`` is 0 by
    construction: no differences are ever maintained.
    """

    def __init__(self, cfg: EngineConfig, graph: DynamicGraph) -> None:
        self.cfg = cfg  # num_queries tracks the slot count
        self.graph = graph
        self.plans: dict[int, qp.QueryPlan] = {}
        self._rows: dict[int, np.ndarray] = {}
        self._free: list[int] = []
        self._num_slots = 0
        self.g = GraphArrays.from_snapshot(graph.snapshot(), backend=cfg.backend)
        self._answers = np.zeros((0, cfg.num_vertices), np.float32)
        self.last_stats: MaintainStats | None = None

    # ---------------------------------------------------------------- slots
    def register_plan(self, plan: qp.QueryPlan) -> int:
        return self.register_plans([plan])[0]

    def register_plans(self, plans: list[qp.QueryPlan]) -> list[int]:
        """Batch registration: claim all slots first, re-execute ONCE (a
        per-plan rerun would retrace for every new row count)."""
        slots = []
        for plan in plans:
            slot = self._free.pop() if self._free else self._num_slots
            self._num_slots = max(self._num_slots, slot + 1)
            self.plans[slot] = plan
            self._rows[slot] = plan.build_init(self.cfg.num_vertices)
            slots.append(slot)
        self._rerun()
        return slots

    def deregister_plan(self, slot: int) -> int:
        if slot not in self.plans:
            raise ValueError(f"slot {slot} is not registered")
        del self.plans[slot], self._rows[slot]
        self._free.append(slot)
        self._free.sort(reverse=True)
        # keep answers() slot-aligned with the other engines: a freed slot
        # reads as the identity row, without re-running the computation
        if slot < self._answers.shape[0]:
            self._answers[slot] = self.cfg.semiring.identity
        if not self.plans:
            self._answers = np.zeros((0, self.cfg.num_vertices), np.float32)
        return 0  # SCRATCH holds no differences

    def active_slots(self) -> list[int]:
        return sorted(self.plans)

    # ----------------------------------------------------- governor surface
    def nbytes_per_query(self) -> dict[int, int]:
        return {s: 0 for s in sorted(self.plans)}  # SCRATCH holds no diffs

    def nbytes_per_operator(self) -> dict[int, dict[str, int]]:
        """Operator-addressed view: zero by construction for every store."""
        return {s: {"iterate": 0} for s in sorted(self.plans)}

    def recompute_cost_per_query(self) -> dict[int, int]:
        """Every slot pays the full re-execution; apportion the cumulative
        scheduled count evenly so the governor's signals stay comparable."""
        n = max(len(self.plans), 1)
        total = 0 if self.last_stats is None else int(self.last_stats.scheduled)
        return {s: total // n for s in sorted(self.plans)}

    def recompute_cost_per_operator(self) -> dict[int, dict[str, int]]:
        per = self.recompute_cost_per_query()
        return {s: {"iterate": c} for s, c in per.items()}

    def set_drop_params(self, slot: int, cfg, op_id: str = "iterate") -> int:
        """SCRATCH is already the zero-memory endpoint of the ladder."""
        if slot not in self.plans:
            raise ValueError(f"slot {slot} is not registered")
        return 0

    # ------------------------------------------------------------ execution
    def _init_matrix(self) -> np.ndarray:
        """[num_slots, V]; retired slots re-run as identity rows (their
        lanes are dead weight until the slot is reused — SCRATCH is the
        recompute-everything baseline by definition)."""
        ident = self.cfg.semiring.identity
        init = np.full(
            (self._num_slots, self.cfg.num_vertices), ident, np.float32
        )
        for slot, row in self._rows.items():
            init[slot] = row
        return init

    def _rerun(self) -> None:
        if not self.plans:
            self._answers = np.zeros((0, self.cfg.num_vertices), np.float32)
            return
        cfg = dataclasses.replace(self.cfg, num_queries=self._num_slots)
        ans, self.last_stats = scratch_run(cfg, self.g, jnp.asarray(self._init_matrix()))
        self._answers = np.array(ans)  # writable copy: deregister blanks rows

    def apply_updates(self, updates):
        self.graph.apply_batch(updates)
        self.g = GraphArrays.from_snapshot(
            self.graph.snapshot(), backend=self.cfg.backend
        )
        self._rerun()
        return self.last_stats

    def apply_updates_batched(self, updates, batch_size: int | None = None):
        del batch_size
        return self.apply_updates(list(updates))

    # ------------------------------------------------------------------ api
    def answers_row(self, slot: int) -> np.ndarray:
        if slot not in self.plans:
            raise ValueError(f"slot {slot} is not registered")
        return self._answers[slot].copy()

    def answers(self) -> np.ndarray:
        return self._answers.copy()

    def nbytes(self) -> int:
        return 0  # no differences maintained

    # ------------------------------------------------------------ durability
    def export_state(self) -> tuple[dict[str, np.ndarray], dict]:
        """SCRATCH holds no differences: the checkpoint is just the plan
        rows plus the work counters the governor reads.  Answers are
        re-derived from the restored graph at import time."""
        meta = {
            "num_slots": int(self._num_slots),
            "free_slots": [int(s) for s in self._free],
            "plans": {str(s): p.to_json() for s, p in self.plans.items()},
            "last_iters": (
                None if self.last_stats is None else int(self.last_stats.iters_run)
            ),
            "last_scheduled": (
                None if self.last_stats is None else int(self.last_stats.scheduled)
            ),
        }
        return {}, meta

    def import_state(self, arrays: dict, meta: dict) -> None:
        del arrays
        self.plans = {
            int(s): qp.QueryPlan.from_json(p) for s, p in meta["plans"].items()
        }
        self._num_slots = int(meta["num_slots"])
        self._free = [int(s) for s in meta["free_slots"]]
        self._rows = {
            s: p.build_init(self.cfg.num_vertices) for s, p in self.plans.items()
        }
        self._rerun()
        if meta["last_iters"] is not None:
            # the pre-crash run's counters, not the import rerun's, so the
            # governor's recompute signal continues where it left off
            self.last_stats = zeros_stats()._replace(
                iters_run=jnp.int32(meta["last_iters"]),
                scheduled=jnp.int32(meta["last_scheduled"]),
            )
