"""Operator-graph plan IR — typed dataflow nodes with per-operator stores.

The paper's optimizations are defined over the differences *of operators* in
a recursive dataflow: JOD (§4) drops the Join operator's difference trace
completely and recomputes it on demand; partial dropping (§5) thins the
Iterate operator's trace under a selection policy.  DBSP shows that an
explicit operator-circuit IR is the right substrate for incremental
maintenance, so a :class:`~repro.core.plan.QueryPlan` is a validated DAG of
the node types below — **each operator owns its own difference store and
drop policy**:

    ``Ingest``     edge deltas entering the dataflow (δE); stateless — the
                   dynamic graph itself is session state, not differences.
    ``Transform``  per-edge weight/label maps (PageRank's α/outdeg
                   derivation); stateless, recomputed per sweep.
    ``Join``       product-graph construction for RPQs (base edges ⋈ NFA
                   transitions) *and* the materialized join trace inside the
                   fixed point: ``drop=None`` inherits the engine mode
                   (legacy), a disabled DropConfig materializes the trace
                   (VDC), an enabled one with p ≥ 1 drops it completely and
                   recomputes messages on demand (JOD, per §4 — partial join
                   dropping is not supported).
    ``Iterate``    the semiring fixed point (today's IFE); owns the
                   change-point difference store and the §5 partial-dropping
                   policy.
    ``Aggregate``  post-processing over the fixed point's answers (top-k /
                   distance histogram); stateless, holds no differences.

Node identity (``op_id``) is threaded through the whole stack: engines
report ``nbytes_per_operator`` keyed ``(slot, op_id)``, drop policies are
rewritten per ``(slot, op_id)``, and the memory governor escalates the
*operator* with the worst bytes-per-recompute-cost.

``family_key`` is stable under node *listing order* — two graphs with the
same nodes in a different tuple order are the same family — and excludes
per-query knobs (source vertex, drop selection, aggregate shaping).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import dropping as dr
from repro.core import semiring as sr

INF = np.float32(np.inf)

OP_KINDS = ("ingest", "transform", "join", "iterate", "aggregate")
# operators that may own a difference store (and hence a drop policy)
DROPPABLE_OPS = ("iterate", "join")


# --------------------------------------------------------------------------- NFA
@dataclasses.dataclass(frozen=True)
class NFA:
    """Nondeterministic automaton over edge labels.

    ``delta``: label → [(state, state')] transitions; used to build the
    product graph (v, q) whose reachability answers the RPQ.
    """

    num_states: int
    delta: dict[int, list[tuple[int, int]]]
    start: int
    accept: tuple[int, ...]

    @staticmethod
    def star(label: int) -> "NFA":
        """Q1 = a*"""
        return NFA(1, {label: [(0, 0)]}, 0, (0,))

    @staticmethod
    def concat_star(a: int, b: int) -> "NFA":
        """Q2 = a ∘ b*"""
        return NFA(2, {a: [(0, 1)], b: [(1, 1)]}, 0, (1,))

    @staticmethod
    def chain(labels: Sequence[int]) -> "NFA":
        """Q3 = l1 ∘ l2 ∘ … ∘ lk (fixed-length path template)."""
        delta: dict[int, list[tuple[int, int]]] = {}
        for j, lbl in enumerate(labels):
            delta.setdefault(int(lbl), []).append((j, j + 1))
        return NFA(len(labels) + 1, delta, 0, (len(labels),))

    def key(self) -> tuple:
        """Hashable structural identity, independent of ``delta`` insertion
        order AND of the listing order of one label's transition pairs."""
        delta = tuple(
            (lbl, tuple(sorted(pairs))) for lbl, pairs in sorted(self.delta.items())
        )
        return (self.num_states, delta, self.start, tuple(sorted(self.accept)))

    def __hash__(self) -> int:  # delta is a dict → default frozen hash fails
        return hash(self.key())

    def __eq__(self, other) -> bool:
        return isinstance(other, NFA) and self.key() == other.key()

    def to_dict(self) -> dict:
        return {
            "num_states": self.num_states,
            "delta": [
                [int(lbl), [[int(s), int(s2)] for (s, s2) in pairs]]
                for lbl, pairs in sorted(self.delta.items())
            ],
            "start": self.start,
            "accept": list(self.accept),
        }

    @staticmethod
    def from_dict(obj: dict) -> "NFA":
        return NFA(
            num_states=int(obj["num_states"]),
            delta={
                int(lbl): [(int(s), int(s2)) for (s, s2) in pairs]
                for lbl, pairs in obj["delta"]
            },
            start=int(obj["start"]),
            accept=tuple(int(a) for a in obj["accept"]),
        )


# --------------------------------------------------------------------------- init spec
@dataclasses.dataclass(frozen=True)
class InitSpec:
    """How to build a query's D_0 row (the implicit iteration-0 diffs).

    ``kind``:
      * ``"source"``   — ``value`` at ``source``, ``fill`` elsewhere
        (SSSP/K-hop/RPQ; for RPQ ``source`` is the product-space id).
      * ``"labels"``   — vertex id as the initial label (WCC).
      * ``"constant"`` — ``fill`` everywhere (PageRank's all-ones).
    """

    kind: str = "source"
    source: int | None = None
    value: float = 0.0
    fill: float = float(INF)

    def build(self, num_vertices: int) -> np.ndarray:
        if self.kind == "source":
            row = np.full(num_vertices, self.fill, dtype=np.float32)
            row[int(self.source)] = self.value
            return row
        if self.kind == "labels":
            return np.arange(num_vertices, dtype=np.float32)
        if self.kind == "constant":
            return np.full(num_vertices, self.fill, dtype=np.float32)
        raise ValueError(f"unknown init kind {self.kind!r}")

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "source": self.source,
            "value": self.value,
            "fill": self.fill,
        }

    @staticmethod
    def from_dict(obj: dict) -> "InitSpec":
        return InitSpec(
            kind=obj.get("kind", "source"),
            source=None if obj.get("source") is None else int(obj["source"]),
            value=float(obj.get("value", 0.0)),
            fill=float(obj.get("fill", INF)),
        )


# --------------------------------------------------------------------------- nodes
@dataclasses.dataclass(frozen=True, kw_only=True)
class Ingest:
    """Edge deltas entering the dataflow (one per plan, no inputs)."""

    kind = "ingest"
    op_id: str = "ingest"
    inputs: tuple[str, ...] = ()

    def family_key(self) -> tuple:
        return ("ingest", self.op_id, self.inputs)


@dataclasses.dataclass(frozen=True, kw_only=True)
class Transform:
    """Per-edge weight derivation (PageRank: w = α / outdeg(src))."""

    kind = "transform"
    op_id: str = "weights"
    inputs: tuple[str, ...] = ("ingest",)
    weight_from_degree: bool = True
    alpha: float = 0.85

    def family_key(self) -> tuple:
        return (
            "transform",
            self.op_id,
            self.inputs,
            bool(self.weight_from_degree),
            float(self.alpha),
        )


@dataclasses.dataclass(frozen=True, kw_only=True)
class Join:
    """NFA-product construction + the join trace inside the fixed point.

    ``drop`` is the operator's OWN storage policy:
      * ``None``     — inherit the engine mode (legacy ``mode="vdc"|"jod"``);
      * disabled     — materialize the per-edge message trace (VDC);
      * enabled      — complete dropping, p ≥ 1 (JOD §4): the trace is never
                       stored; messages recompute on demand every sweep.
    """

    kind = "join"
    op_id: str = "join"
    inputs: tuple[str, ...] = ("ingest",)
    nfa: NFA | None = None
    drop: dr.DropConfig | None = None

    def family_key(self) -> tuple:
        # drop is a per-query knob (free within a family)
        return (
            "join",
            self.op_id,
            self.inputs,
            None if self.nfa is None else self.nfa.key(),
        )


@dataclasses.dataclass(frozen=True, kw_only=True)
class Iterate:
    """The semiring fixed point (IFE) — owns the change-point store."""

    kind = "iterate"
    op_id: str = "iterate"
    inputs: tuple[str, ...] = ("ingest",)
    semiring: sr.Semiring | None = None
    init: InitSpec = dataclasses.field(default_factory=InitSpec)
    max_iters: int = 64
    drop: dr.DropConfig = dataclasses.field(default_factory=dr.DropConfig)

    def family_key(self) -> tuple:
        s = self.semiring
        return (
            "iterate",
            self.op_id,
            self.inputs,
            s.name,
            s.reduce,
            s.identity,
            s.carry_prev,
            s.base,
            s.hop_cap,
            int(self.max_iters),
        )


@dataclasses.dataclass(frozen=True, kw_only=True)
class Aggregate:
    """Stateless post-processing of the fixed point's answers.

    ``agg``: ``"topk"`` (k best finite values + their vertices),
    ``"histogram"`` (finite-value counts in ``bins`` equal-width bins) or
    ``"target"`` (the answer field read at one ``vertex`` — SPSP reads an
    SSSP field at t; the planner's landmark pass pattern-matches on it).
    A per-query output-shaping knob: excluded from the family key.
    """

    kind = "aggregate"
    op_id: str = "aggregate"
    inputs: tuple[str, ...] = ("iterate",)
    agg: str = "topk"
    k: int = 8
    bins: int = 8
    vertex: int | None = None  # target vertex for agg="target"

    def family_key(self) -> tuple | None:
        return None  # free knob — never constrains session compatibility


OpNode = Ingest | Transform | Join | Iterate | Aggregate


# ----------------------------------------------------------------- validation
def _toposort(nodes: dict[str, OpNode]) -> list[str]:
    """Kahn topological order; raises on cycles."""
    indeg = {op_id: 0 for op_id in nodes}
    consumers: dict[str, list[str]] = {op_id: [] for op_id in nodes}
    for node in nodes.values():
        for ref in node.inputs:
            indeg[node.op_id] += 1
            consumers[ref].append(node.op_id)
    ready = sorted(op_id for op_id, d in indeg.items() if d == 0)
    order: list[str] = []
    while ready:
        op_id = ready.pop()
        order.append(op_id)
        for c in consumers[op_id]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(c)
    if len(order) != len(nodes):
        cyclic = sorted(op_id for op_id, d in indeg.items() if d > 0)
        raise ValueError(f"operator graph has a cycle through {cyclic}")
    return order


def validate(ops: Sequence[OpNode]) -> dict[str, OpNode]:
    """Validate an operator graph; returns the id → node map.

    Checks: unique ids, no dangling input references, acyclicity, exactly
    one Ingest (no inputs) and one Iterate, at most one Join / Transform /
    Aggregate, the Iterate reachable from the Ingest, the Aggregate fed by
    the Iterate, and join drop configs restricted to complete dropping.
    """
    if not ops:
        raise ValueError("operator graph is empty")
    nodes: dict[str, OpNode] = {}
    for node in ops:
        if not isinstance(node, (Ingest, Transform, Join, Iterate, Aggregate)):
            raise ValueError(f"unknown operator node {node!r}")
        if node.op_id in nodes:
            raise ValueError(f"duplicate operator id {node.op_id!r}")
        nodes[node.op_id] = node
    for node in ops:
        for ref in node.inputs:
            if ref not in nodes:
                raise ValueError(
                    f"operator {node.op_id!r} references dangling input {ref!r}"
                )
            if ref == node.op_id:
                raise ValueError(f"operator {node.op_id!r} consumes itself")
    _toposort(nodes)

    by_kind: dict[str, list[OpNode]] = {}
    for node in ops:
        by_kind.setdefault(node.kind, []).append(node)
    for kind in ("ingest", "iterate"):
        if len(by_kind.get(kind, [])) != 1:
            raise ValueError(
                f"operator graph needs exactly one {kind} node, "
                f"got {len(by_kind.get(kind, []))}"
            )
    for kind in ("join", "transform", "aggregate"):
        if len(by_kind.get(kind, [])) > 1:
            raise ValueError(f"operator graph allows at most one {kind} node")
    if by_kind["ingest"][0].inputs:
        raise ValueError("the ingest node consumes nothing (it IS the δE source)")

    it = by_kind["iterate"][0]
    if it.semiring is None:
        raise ValueError("the iterate node needs a semiring")
    # store-owning operators are engine-addressed by kind (a plan holds at
    # most one of each), so their ids must BE their kind — a free-form id
    # would make the node unaddressable and surface phantom 0-byte twins
    for kind in DROPPABLE_OPS:
        for node in by_kind.get(kind, []):
            if node.op_id != kind:
                raise ValueError(
                    f"{kind} nodes own a difference store and must keep the "
                    f"canonical id {kind!r} (got {node.op_id!r})"
                )
    # the iterate must (transitively) consume the ingest
    seen, stack = set(), [it.op_id]
    while stack:
        cur = stack.pop()
        if cur in seen:
            continue
        seen.add(cur)
        stack.extend(nodes[cur].inputs)
    if by_kind["ingest"][0].op_id not in seen:
        raise ValueError("the iterate node is not connected to the ingest")

    for agg in by_kind.get("aggregate", []):
        if it.op_id not in agg.inputs:
            raise ValueError(
                f"aggregate {agg.op_id!r} must consume the iterate node "
                f"{it.op_id!r}"
            )
        if agg.agg == "target" and agg.vertex is None:
            raise ValueError("aggregate agg='target' needs a target vertex")
    for join in by_kind.get("join", []):
        if join.nfa is None:
            raise ValueError(f"join {join.op_id!r} needs an NFA")
        cfg = join.drop
        if cfg is not None and cfg.enabled() and not cfg.drops_all():
            raise ValueError(
                "the join's differences drop completely (p ≥ 1, recompute"
                "-on-demand per §4); partial join dropping is unsupported"
            )
    return nodes


def family_key(ops: Sequence[OpNode]) -> tuple:
    """Session-compatibility key over the graph, stable under node listing
    order; per-query knobs (init source, drop policies, aggregates) free."""
    keys = [n.family_key() for n in ops]
    return tuple(sorted((k for k in keys if k is not None), key=repr))


# ------------------------------------------------------------ canonical graphs
def canonical(
    *,
    semiring: sr.Semiring,
    init: InitSpec,
    max_iters: int,
    drop: dr.DropConfig | None = None,
    nfa: NFA | None = None,
    weight_from_degree: bool = False,
    alpha: float = 0.85,
    join_drop: dr.DropConfig | None = None,
    aggregate: Aggregate | None = None,
) -> tuple[OpNode, ...]:
    """The canonical operator graph for one legacy-shaped query."""
    ops: list[OpNode] = [Ingest()]
    upstream = "ingest"
    if weight_from_degree:
        ops.append(
            Transform(
                inputs=(upstream,), weight_from_degree=True, alpha=float(alpha)
            )
        )
        upstream = "weights"
    if nfa is not None:
        ops.append(Join(inputs=(upstream,), nfa=nfa, drop=join_drop))
        upstream = "join"
    ops.append(
        Iterate(
            inputs=(upstream,),
            semiring=semiring,
            init=init,
            max_iters=int(max_iters),
            drop=drop if drop is not None else dr.DropConfig(),
        )
    )
    if aggregate is not None:
        ops.append(dataclasses.replace(aggregate, inputs=("iterate",)))
    return tuple(ops)


# ----------------------------------------------------------------------- JSON
def _semiring_to_dict(s: sr.Semiring) -> dict:
    out: dict = {"name": s.name}
    if s.name == "min_hop":
        out["hop_cap"] = s.hop_cap
    if s.name == "pagerank":
        out["alpha"] = 1.0 - s.base
    return out


def _semiring_from_dict(obj: dict) -> sr.Semiring:
    name = obj["name"]
    if name == "min_plus":
        return sr.min_plus()
    if name == "min_hop":
        return sr.min_hop(float(obj.get("hop_cap", float("inf"))))
    if name == "min_label":
        return sr.min_label()
    if name == "pagerank":
        return sr.pagerank(float(obj.get("alpha", 0.85)))
    raise ValueError(f"unknown semiring {name!r}")


def _drop_to_dict(cfg: dr.DropConfig | None) -> dict | None:
    return None if cfg is None else dataclasses.asdict(cfg)


def _drop_from_dict(obj: dict | None) -> dr.DropConfig | None:
    if obj is None:
        return None
    fields = {f.name for f in dataclasses.fields(dr.DropConfig)}
    return dr.DropConfig(**{k: v for k, v in obj.items() if k in fields})


def node_to_dict(node: OpNode) -> dict:
    out: dict = {"op": node.kind, "id": node.op_id, "inputs": list(node.inputs)}
    if isinstance(node, Transform):
        out["weight_from_degree"] = node.weight_from_degree
        out["alpha"] = node.alpha
    elif isinstance(node, Join):
        out["nfa"] = node.nfa.to_dict()
        out["drop"] = _drop_to_dict(node.drop)
    elif isinstance(node, Iterate):
        out["semiring"] = _semiring_to_dict(node.semiring)
        out["init"] = node.init.to_dict()
        out["max_iters"] = node.max_iters
        out["drop"] = _drop_to_dict(node.drop)
    elif isinstance(node, Aggregate):
        out["agg"] = node.agg
        out["k"] = node.k
        out["bins"] = node.bins
        out["vertex"] = node.vertex
    return out


def node_from_dict(obj: dict) -> OpNode:
    kind = obj.get("op")
    common = dict(
        op_id=obj.get("id", kind), inputs=tuple(obj.get("inputs", ()))
    )
    if kind == "ingest":
        return Ingest(**common)
    if kind == "transform":
        return Transform(
            **common,
            weight_from_degree=bool(obj.get("weight_from_degree", True)),
            alpha=float(obj.get("alpha", 0.85)),
        )
    if kind == "join":
        return Join(
            **common,
            nfa=NFA.from_dict(obj["nfa"]),
            drop=_drop_from_dict(obj.get("drop")),
        )
    if kind == "iterate":
        drop = _drop_from_dict(obj.get("drop"))
        return Iterate(
            **common,
            semiring=_semiring_from_dict(obj["semiring"]),
            init=InitSpec.from_dict(obj.get("init", {})),
            max_iters=int(obj.get("max_iters", 64)),
            drop=drop if drop is not None else dr.DropConfig(),
        )
    if kind == "aggregate":
        vertex = obj.get("vertex")
        return Aggregate(
            **common,
            agg=obj.get("agg", "topk"),
            k=int(obj.get("k", 8)),
            bins=int(obj.get("bins", 8)),
            vertex=None if vertex is None else int(vertex),
        )
    raise ValueError(f"unknown operator kind {kind!r}")
