"""Work-efficient host execution of Diff-IFE (the paper's pointer machine).

The dense TPU engine (`core.engine`) sweeps O(E)-wide masked lanes — ideal
for accelerators, but per-update wall clock is flat in |affected set|.  A
GDBMS also serves small-update workloads from the host, where the paper's
original pointer design wins: hash-map difference indexes, per-iteration
frontier sets, and join work proportional to the touched neighbourhood.

This module is that host path: same eager-merged change-point semantics,
same JOD direct/upper-bound rules, numpy/dict state.  It reproduces the
paper's Table-1 shape in *wall clock* (maintenance cost ∝ affected set, not
graph size) and is cross-validated against both the dense engine and
SCRATCH by property tests.

Queries are registered as :class:`~repro.core.plan.QueryPlan`s — the same
IR the dense engine consumes — so the host engine satisfies the session
``EngineProtocol`` (`core/session.py`): ``register_plan`` computes the new
query's difference trace from the live adjacency, ``deregister_plan`` drops
its index and returns the bytes released.  The legacy
``SparseDiffIFE(graph, sources, ...)`` constructor builds SSSP/K-hop plans
internally.

Supports the min-family semirings (SPSP/SSSP, K-hop/RPQ reachability, WCC
label propagation) — the query classes the paper's scalability study runs.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Sequence

import numpy as np

from repro.core import dropping as dr
from repro.core import plan as qp
from repro.core.engine import ITER_TRACE, MaintainStats
from repro.core.graph import DynamicGraph
from repro.obs import trace as obs_trace

INF = float("inf")


class SparseDiffIFE:
    """Host CQP: JOD + eager merging with pointer data structures.

    State per registered query slot q:
      diffs[q][v]   sorted list of (iteration, value) change points
      init_rows[q]  the implicit iteration-0 states (never stored as diffs)
    Graph adjacency lives in dicts of dicts (in/out), mirroring a GDBMS
    adjacency-list index.
    """

    def __init__(
        self,
        graph: DynamicGraph,
        sources: Sequence[int] | None = None,
        *,
        max_iters: int = 64,
        khop: int | None = None,  # legacy: None = min_plus; else hop query
    ) -> None:
        self.graph = graph
        self.max_iters = int(max_iters)
        self.in_nbrs: dict[int, dict[int, float]] = defaultdict(dict)
        self.out_nbrs: dict[int, dict[int, float]] = defaultdict(dict)
        for e in np.nonzero(graph.valid)[0]:
            u, v, w = int(graph.src[e]), int(graph.dst[e]), float(graph.weight[e])
            self.out_nbrs[u][v] = w
            self.in_nbrs[v][u] = w
        self.plans: dict[int, qp.QueryPlan] = {}
        self.diffs: dict[int, dict[int, list[tuple[int, float]]]] = {}
        self._init_rows: dict[int, np.ndarray] = {}
        self._free: list[int] = []
        self._num_slots = 0
        self.work = 0  # aggregator re-runs (the paper's work metric)
        self.work_per_slot: dict[int, int] = {}  # per-query recompute signal
        # governor scratch fallback: slots whose difference index was dropped
        # entirely — answers re-executed from scratch per batch (slot → row)
        self._scratch_rows: dict[int, np.ndarray] = {}
        self.last_stats: MaintainStats | None = None  # last sweep, dense schema
        # recorded policies, keyed slot (iterate) or (slot, op_id)
        self._drop_cfg: dict = {}
        self.sources = [] if sources is None else [int(s) for s in sources]
        for s in self.sources:
            if khop is not None:
                self.register_plan(qp.khop(s, k=int(khop)))
                self.max_iters = int(max_iters)  # legacy: cap ≠ sweep bound
            else:
                self.register_plan(qp.sssp(s, max_iters=max_iters))

    # ---------------------------------------------------------------- slots
    def register_plan(self, plan: qp.QueryPlan) -> int:
        """Register one query: claim a slot, compute its trace from the live
        adjacency (the static IFE run, recorded as change points)."""
        if plan.semiring.reduce != "min":
            raise ValueError(
                f"host engine supports min-family semirings only, "
                f"got {plan.semiring.name!r}"
            )
        slot = self._free.pop() if self._free else self._num_slots
        self._num_slots = max(self._num_slots, slot + 1)
        self.plans[slot] = plan
        self.diffs[slot] = defaultdict(list)
        self._init_rows[slot] = plan.build_init(self.graph.num_vertices)
        self.work_per_slot[slot] = 0
        self.max_iters = max(self.max_iters, int(plan.max_iters))
        self._initial(slot)
        return slot

    def deregister_plan(self, slot: int) -> int:
        """Drop a query's difference index; returns the bytes released."""
        if slot not in self.plans:
            raise ValueError(f"slot {slot} is not registered")
        freed = self.slot_nbytes(slot)
        del self.plans[slot], self.diffs[slot], self._init_rows[slot]
        self._scratch_rows.pop(slot, None)
        self._drop_cfg.pop(slot, None)
        self._drop_cfg.pop((slot, "join"), None)
        self.work_per_slot.pop(slot, None)
        self._free.append(slot)
        self._free.sort(reverse=True)
        return freed

    def active_slots(self) -> list[int]:
        return sorted(self.plans)

    # ----------------------------------------------------- governor surface
    def slot_nbytes(self, slot: int) -> int:
        return sum(len(p) for p in self.diffs[slot].values()) * 8

    def nbytes_per_query(self) -> dict[int, int]:
        """slot → accounted diff bytes (scratch-fallback slots hold none)."""
        return {s: self.slot_nbytes(s) for s in sorted(self.plans)}

    def nbytes_per_operator(self) -> dict[int, dict[str, int]]:
        """slot → {op_id → bytes}: the host engine is the paper's pointer
        machine — JOD by construction, so the Iterate's difference index is
        the only store (the Join's differences are always recomputed)."""
        return {s: {"iterate": self.slot_nbytes(s)} for s in sorted(self.plans)}

    def recompute_cost_per_query(self) -> dict[int, int]:
        """slot → cumulative aggregator re-runs charged to that query."""
        return {s: self.work_per_slot.get(s, 0) for s in sorted(self.plans)}

    def recompute_cost_per_operator(self) -> dict[int, dict[str, int]]:
        return {
            s: {"iterate": self.work_per_slot.get(s, 0)}
            for s in sorted(self.plans)
        }

    def set_drop_params(
        self, slot: int, cfg: dr.DropConfig, op_id: str = "iterate"
    ) -> int:
        """Host form of the policy ladder — two effective rungs.

        The pointer engine has no DroppedVT repair path, so partial rungs
        (0 < p < 1) are recorded but shed nothing; **drop-all** (p ≥ 1)
        triggers the scratch fallback: the slot's whole difference index is
        released and its answers are re-executed from scratch per batch
        (paper's SCRATCH endpoint, applied per query).  De-escalating below
        drop-all rebuilds the index from the live adjacency (one static IFE
        run — register-convergence makes this exact).  Returns bytes freed.

        ``op_id="join"`` is a recorded no-op: the pointer engine never
        materializes the Join's differences (it is the paper's JOD machine),
        so there is nothing to drop or re-materialize.
        """
        if slot not in self.plans:
            raise ValueError(f"slot {slot} is not registered")
        if op_id == "join":
            self._drop_cfg[(slot, "join")] = cfg
            return 0
        if op_id != "iterate":
            raise ValueError(
                f"operator {op_id!r} owns no engine difference store"
            )
        self._drop_cfg[slot] = cfg
        scratch = cfg.drops_all()
        if scratch and slot not in self._scratch_rows:
            freed = self.slot_nbytes(slot)
            self.diffs[slot] = defaultdict(list)
            self._scratch_rows[slot] = self._scratch_eval(slot)
            return freed
        if not scratch and slot in self._scratch_rows:
            del self._scratch_rows[slot]
            self.diffs[slot] = defaultdict(list)
            self._initial(slot)  # rebuild the trace from the live adjacency
        return 0

    def _scratch_eval(self, q: int) -> np.ndarray:
        """Static IFE run to fixpoint — value rows only, no change points.

        This is the host engine's repair-on-access path: the slot's trace
        was dropped entirely, so answers are recomputed from the live
        adjacency (traced under the ``repair`` category).
        """
        with obs_trace.span("scratch_eval", "repair", pid="engine:host", tid=q):
            return self._scratch_eval_inner(q)

    def _scratch_eval_inner(self, q: int) -> np.ndarray:
        vals = np.asarray(self._init_rows[q], np.float32).copy()
        for _ in range(self.max_iters):
            nxt = vals.copy()
            for v, ins in self.in_nbrs.items():
                best = nxt[v]
                for u, w in ins.items():
                    cand = self._msg(q, float(vals[u]), w)
                    if cand < best:
                        best = cand
                nxt[v] = best
                self.work += 1
                self.work_per_slot[q] = self.work_per_slot.get(q, 0) + 1
            if np.array_equal(nxt, vals):
                break
            vals = nxt
        return vals

    # ------------------------------------------------------------- semiring
    def _msg(self, q: int, val: float, w: float) -> float:
        s = self.plans[q].semiring
        if s.name == "min_plus":
            return val + w
        if s.name == "min_hop":
            nxt = val + 1.0
            return nxt if nxt <= s.hop_cap else INF
        if s.name == "min_label":
            return val
        raise ValueError(f"unsupported semiring {s.name!r}")

    # ---------------------------------------------------------------- state
    def _value_at(self, q: int, v: int, i: int) -> float:
        """Latest change point ≤ i (implicit init from the plan's D_0)."""
        best = float(self._init_rows[q][v])
        for (it, val) in self.diffs[q].get(v, ()):
            if it <= i:
                best = val
            else:
                break
        return best

    def _recompute(self, q: int, v: int, i: int) -> float:
        """Rerun the aggregator (Min) for v at iteration i — the join is
        computed on demand from in-neighbour states at i−1 (JOD §4)."""
        self.work += 1
        self.work_per_slot[q] = self.work_per_slot.get(q, 0) + 1
        best = self._value_at(q, v, i - 1)  # carry (includes implicit init)
        for u, w in self.in_nbrs.get(v, {}).items():
            cand = self._msg(q, self._value_at(q, u, i - 1), w)
            if cand < best:
                best = cand
        return best

    def _set_point(self, q: int, v: int, i: int, val: float) -> tuple[int, int]:
        """Upsert/cancel the change point at iteration ``i``; returns
        (written, removed) — 1/0 flags for the sweep's stat counters."""
        pts = self.diffs[q][v]
        prev = self._value_at(q, v, i - 1)
        # drop/replace any existing point at i, then insert if a true change
        n0 = len(pts)
        pts[:] = [(it, x) for (it, x) in pts if it != i]
        had = len(pts) < n0
        wrote = val != prev
        if wrote:
            pts.append((i, val))
            pts.sort()
        if not pts:
            del self.diffs[q][v]
        return int(wrote), int(had and not wrote)

    # ------------------------------------------------------------ procedures
    def _initial(self, q: int) -> None:
        # vertices with a non-identity implicit init feed their
        # out-neighbours at iteration 1 (SSSP: the source; WCC: everyone)
        ident = self.plans[q].semiring.identity
        seeds = {
            int(v) for v in np.nonzero(self._init_rows[q] != ident)[0]
        }
        frontier = set(seeds)
        for s in seeds:
            frontier.update(self.out_nbrs.get(s, ()))
        for i in range(1, self.max_iters + 1):
            nxt: set[int] = set()
            for v in sorted(frontier):
                new = self._recompute(q, v, i)
                if new != self._value_at(q, v, i):
                    self._set_point(q, v, i, new)
                    nxt.add(v)
                    nxt.update(self.out_nbrs.get(v, ()))
            # values settled at i propagate to consumers at i+1
            frontier = {v for v in nxt}
            if not frontier:
                break

    def _horizon(self, q: int) -> int:
        h = 0
        for pts in self.diffs[q].values():
            if pts:
                h = max(h, pts[-1][0])
        return h

    def apply_updates(self, updates) -> MaintainStats:
        """One δE batch: update adjacency, then per-query sparse sweep.

        Returns (and keeps in ``last_stats``) the dense engine's
        :class:`MaintainStats` schema so telemetry / governor / metrics see
        one uniform shape across engines.  The pointer machine has no
        DroppedVT path, so ``dropped`` / ``jwritten`` / ``det_overflow``
        are structurally zero; scratch-fallback re-executions (the host's
        repair-on-access analog) land in ``repairs``.
        """
        dirty: set[int] = set()
        for (u, v, _lbl, w, sign) in updates:
            u, v = int(u), int(v)
            if sign > 0:
                self.out_nbrs[u][v] = float(w)
                self.in_nbrs[v][u] = float(w)
            else:
                self.out_nbrs.get(u, {}).pop(v, None)
                self.in_nbrs.get(v, {}).pop(u, None)
            dirty.add(v)
        self.graph.apply_batch(updates)

        iters_max = 0
        scheduled = changed = repairs = written = removed = 0
        sched_sizes = np.zeros(ITER_TRACE, np.int64)
        frontier_sizes = np.zeros(ITER_TRACE, np.int64)
        sweep = obs_trace.span(
            "sweep", "sweep", pid="engine:host", num_updates=len(updates)
        )
        with sweep:
            for q in sorted(self.plans):
                if q in self._scratch_rows:  # drop-all: re-execute, no diffs
                    w0 = self.work
                    self._scratch_rows[q] = self._scratch_eval(q)
                    repairs += self.work - w0
                    continue
                horizon = self._horizon(q)
                frontier: set[int] = set()
                # Retractions are not monotone: a vertex raised at iteration
                # i may regain a lower value at a later iteration from an
                # in-neighbour whose change point settles later.  Every
                # vertex touched by this sweep therefore stays scheduled
                # through the trace horizon — exactly the treatment the
                # direct update heads (`dirty`) already get — instead of
                # dropping out of the frontier at its first unchanged
                # iteration.
                touched: set[int] = set()
                i = 1
                while i <= self.max_iters and (
                    frontier or ((dirty or touched) and i <= horizon + 1)
                ):
                    sched = frontier | (
                        (dirty | touched) if i <= horizon + 1 else set()
                    )
                    nxt: set[int] = set()
                    for v in sorted(sched):
                        old = self._value_at(q, v, i)
                        new = self._recompute(q, v, i)
                        if new != old:
                            nxt.add(v)
                            nxt.update(self.out_nbrs.get(v, ()))
                            touched.add(v)
                        w_, r_ = self._set_point(q, v, i, new)
                        written += w_
                        removed += r_
                    bin_i = min(i - 1, ITER_TRACE - 1)
                    scheduled += len(sched)
                    changed += len(nxt)
                    sched_sizes[bin_i] += len(sched)
                    frontier_sizes[bin_i] += len(nxt)
                    horizon = max(horizon, self._horizon(q))
                    frontier = nxt
                    i += 1
                iters_max = max(iters_max, i - 1)

            z = np.int32
            self.last_stats = MaintainStats(
                iters_run=z(iters_max),
                scheduled=z(scheduled),
                changed=z(changed),
                repairs=z(repairs),
                written=z(written),
                removed=z(removed),
                dropped=z(0),
                jwritten=z(0),
                det_overflow=z(0),
                sched_sizes=sched_sizes.astype(np.int32),
                frontier_sizes=frontier_sizes.astype(np.int32),
            )
            sweep.set(
                iters_run=iters_max, scheduled=scheduled, changed=changed,
                repairs=repairs, written=written, removed=removed,
            )
        return self.last_stats

    def apply_updates_batched(self, updates, batch_size: int | None = None):
        """Protocol twin of the dense engine's chunked path: the host sweep
        is already per-update work-efficient, so this just applies the log."""
        del batch_size
        return self.apply_updates(list(updates))

    # ------------------------------------------------------------------ api
    def answers_row(self, slot: int) -> np.ndarray:
        if slot in self._scratch_rows:
            return self._scratch_rows[slot].copy()
        out = np.asarray(self._init_rows[slot], np.float32).copy()
        for vtx, pts in self.diffs[slot].items():
            if pts:
                out[vtx] = pts[-1][1]
        return out

    def answers(self) -> np.ndarray:
        """[num_slots, V] over every slot ever allocated (deregistered slots
        read as the identity row) — slot-aligned with the dense engine."""
        v = self.graph.num_vertices
        out = np.full((self._num_slots, v), np.inf, np.float32)
        for slot in self.plans:
            out[slot] = self.answers_row(slot)
        return out

    def nbytes(self) -> int:
        return self.num_diffs() * 8

    def num_diffs(self) -> int:
        return sum(
            len(p) for q in self.plans for p in self.diffs[q].values()
        )

    # ------------------------------------------------------------ durability
    def export_state(self) -> tuple[dict[str, np.ndarray], dict]:
        """(arrays, meta) snapshot: change points flattened to parallel
        arrays, plans/policies/work counters as JSON-able meta.  Adjacency is
        NOT saved — it is rebuilt from the restored :class:`DynamicGraph`."""
        slots: list[int] = []
        vtxs: list[int] = []
        its: list[int] = []
        vals: list[float] = []
        for s in sorted(self.diffs):
            for v in sorted(self.diffs[s]):
                for (i, val) in self.diffs[s][v]:
                    slots.append(s)
                    vtxs.append(v)
                    its.append(i)
                    vals.append(val)
        arrays = {
            "diff_slot": np.asarray(slots, np.int64),
            "diff_vtx": np.asarray(vtxs, np.int64),
            "diff_iter": np.asarray(its, np.int64),
            "diff_val": np.asarray(vals, np.float64),
        }
        for s, row in self._scratch_rows.items():
            arrays[f"scratch_row/{s}"] = np.asarray(row, np.float32)
        drop_cfg = []
        for key, cfg in self._drop_cfg.items():
            slot, op = (key if isinstance(key, tuple) else (key, None))
            drop_cfg.append({
                "slot": int(slot),
                "op": op,
                "cfg": None if cfg is None else dataclasses.asdict(cfg),
            })
        meta = {
            "num_slots": int(self._num_slots),
            "free_slots": [int(s) for s in self._free],
            "max_iters": int(self.max_iters),
            "work": int(self.work),
            "work_per_slot": {str(s): int(w) for s, w in self.work_per_slot.items()},
            "plans": {str(s): p.to_json() for s, p in self.plans.items()},
            "drop_cfg": drop_cfg,
            "sources": [int(s) for s in self.sources],
        }
        return arrays, meta

    def import_state(self, arrays: dict, meta: dict) -> None:
        """Load a snapshot produced by :meth:`export_state`.  The engine
        must have been constructed on the restored graph (adjacency dicts
        come from the constructor); init rows rebuild deterministically from
        each plan."""
        self.plans = {
            int(s): qp.QueryPlan.from_json(p) for s, p in meta["plans"].items()
        }
        self._num_slots = int(meta["num_slots"])
        self._free = [int(s) for s in meta["free_slots"]]
        self.max_iters = int(meta["max_iters"])
        self.work = int(meta["work"])
        self.work_per_slot = {
            int(s): int(w) for s, w in meta["work_per_slot"].items()
        }
        self.sources = [int(s) for s in meta.get("sources", [])]
        self.diffs = {s: defaultdict(list) for s in self.plans}
        for s, v, i, val in zip(
            arrays["diff_slot"], arrays["diff_vtx"],
            arrays["diff_iter"], arrays["diff_val"],
        ):
            # saved in per-(slot, vertex) list order, so the sorted-by-
            # iteration change-point invariant is preserved verbatim
            self.diffs[int(s)][int(v)].append((int(i), float(val)))
        self._init_rows = {
            s: p.build_init(self.graph.num_vertices) for s, p in self.plans.items()
        }
        self._scratch_rows = {
            int(k.split("/", 1)[1]): np.asarray(arrays[k], np.float32)
            for k in arrays
            if k.startswith("scratch_row/")
        }
        self._drop_cfg = {}
        for entry in meta["drop_cfg"]:
            key = (
                (int(entry["slot"]), entry["op"])
                if entry["op"] is not None
                else int(entry["slot"])
            )
            cfg = entry["cfg"]
            self._drop_cfg[key] = None if cfg is None else dr.DropConfig(**cfg)
