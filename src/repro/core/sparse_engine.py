"""Work-efficient host execution of Diff-IFE (the paper's pointer machine).

The dense TPU engine (`core.engine`) sweeps O(E)-wide masked lanes — ideal
for accelerators, but per-update wall clock is flat in |affected set|.  A
GDBMS also serves small-update workloads from the host, where the paper's
original pointer design wins: hash-map difference indexes, per-iteration
frontier sets, and join work proportional to the touched neighbourhood.

This module is that host path: same eager-merged change-point semantics,
same JOD direct/upper-bound rules, numpy/dict state.  It reproduces the
paper's Table-1 shape in *wall clock* (maintenance cost ∝ affected set, not
graph size) and is cross-validated against both the dense engine and
SCRATCH by property tests.

Supports the min-family semirings (SPSP/SSSP, K-hop, WCC reachability) —
the query classes the paper's scalability study runs.
"""

from __future__ import annotations

import math
from collections import defaultdict
from typing import Sequence

import numpy as np

from repro.core.graph import DynamicGraph

INF = float("inf")


class SparseDiffIFE:
    """Host CQP: JOD + eager merging with pointer data structures.

    State per query q:
      diffs[q][v]   sorted list of (iteration, value) change points
    Graph adjacency lives in dicts of dicts (in/out), mirroring a GDBMS
    adjacency-list index.
    """

    def __init__(
        self,
        graph: DynamicGraph,
        sources: Sequence[int],
        *,
        max_iters: int = 64,
        khop: int | None = None,  # None = min_plus (weights); else hop query
    ) -> None:
        self.graph = graph
        self.sources = [int(s) for s in sources]
        self.max_iters = max_iters
        self.khop = khop
        self.in_nbrs: dict[int, dict[int, float]] = defaultdict(dict)
        self.out_nbrs: dict[int, dict[int, float]] = defaultdict(dict)
        for e in np.nonzero(graph.valid)[0]:
            u, v, w = int(graph.src[e]), int(graph.dst[e]), float(graph.weight[e])
            self.out_nbrs[u][v] = w
            self.in_nbrs[v][u] = w
        self.diffs: list[dict[int, list[tuple[int, float]]]] = [
            defaultdict(list) for _ in self.sources
        ]
        self.work = 0  # aggregator re-runs (the paper's work metric)
        for q, s in enumerate(self.sources):
            self._initial(q, s)

    # ------------------------------------------------------------- semiring
    def _msg(self, val: float, w: float) -> float:
        if self.khop is not None:
            nxt = val + 1.0
            return nxt if nxt <= self.khop else INF
        return val + w

    # ---------------------------------------------------------------- state
    def _value_at(self, q: int, v: int, i: int) -> float:
        """Latest change point ≤ i (implicit init: 0 at source, ∞ else)."""
        best = 0.0 if v == self.sources[q] else INF
        for (it, val) in self.diffs[q].get(v, ()):
            if it <= i:
                best = val
            else:
                break
        return best

    def _recompute(self, q: int, v: int, i: int) -> float:
        """Rerun the aggregator (Min) for v at iteration i — the join is
        computed on demand from in-neighbour states at i−1 (JOD §4)."""
        self.work += 1
        best = self._value_at(q, v, i - 1)  # carry
        if v == self.sources[q]:
            best = min(best, 0.0)
        for u, w in self.in_nbrs.get(v, {}).items():
            cand = self._msg(self._value_at(q, u, i - 1), w)
            if cand < best:
                best = cand
        return best

    def _set_point(self, q: int, v: int, i: int, val: float) -> None:
        pts = self.diffs[q][v]
        prev = self._value_at(q, v, i - 1)
        # drop/replace any existing point at i, then insert if a true change
        pts[:] = [(it, x) for (it, x) in pts if it != i]
        if val != prev:
            pts.append((i, val))
            pts.sort()
        if not pts:
            del self.diffs[q][v]

    # ------------------------------------------------------------ procedures
    def _initial(self, q: int, s: int) -> None:
        # the source's implicit 0 at iteration 0 feeds its out-neighbours
        frontier = {s} | set(self.out_nbrs.get(s, ()))
        for i in range(1, self.max_iters + 1):
            nxt: set[int] = set()
            for v in sorted(frontier):
                new = self._recompute(q, v, i)
                if new != self._value_at(q, v, i):
                    self._set_point(q, v, i, new)
                    nxt.add(v)
                    nxt.update(self.out_nbrs.get(v, ()))
            # values settled at i propagate to consumers at i+1
            frontier = {v for v in nxt}
            if not frontier:
                break

    def _horizon(self, q: int) -> int:
        h = 0
        for pts in self.diffs[q].values():
            if pts:
                h = max(h, pts[-1][0])
        return h

    def apply_updates(self, updates) -> None:
        """One δE batch: update adjacency, then per-query sparse sweep."""
        dirty: set[int] = set()
        for (u, v, lbl, w, sign) in updates:
            u, v = int(u), int(v)
            if sign > 0:
                self.out_nbrs[u][v] = float(w)
                self.in_nbrs[v][u] = float(w)
            else:
                self.out_nbrs.get(u, {}).pop(v, None)
                self.in_nbrs.get(v, {}).pop(u, None)
            dirty.add(v)
        self.graph.apply_batch(updates)

        for q in range(len(self.sources)):
            horizon = self._horizon(q)
            frontier: set[int] = set()
            i = 1
            while i <= self.max_iters and (frontier or (dirty and i <= horizon + 1)):
                sched = frontier | (dirty if i <= horizon + 1 else set())
                nxt: set[int] = set()
                for v in sorted(sched):
                    old = self._value_at(q, v, i)
                    new = self._recompute(q, v, i)
                    if new != old:
                        nxt.add(v)
                        nxt.update(self.out_nbrs.get(v, ()))
                    self._set_point(q, v, i, new)
                horizon = max(horizon, self._horizon(q))
                frontier = nxt
                i += 1

    # ------------------------------------------------------------------ api
    def answers(self) -> np.ndarray:
        v = self.graph.num_vertices
        out = np.full((len(self.sources), v), np.inf, np.float32)
        for q in range(len(self.sources)):
            out[q, self.sources[q]] = 0.0
            for vtx, pts in self.diffs[q].items():
                if pts:
                    out[q, vtx] = pts[-1][1]
        return out

    def nbytes(self) -> int:
        return sum(len(p) for d in self.diffs for p in d.values()) * 8

    def num_diffs(self) -> int:
        return sum(len(p) for d in self.diffs for p in d.values())
