"""Declarative query plans — the IR between query classes and engines.

The paper's system is a *continuous query processor*: clients register and
deregister recursive queries against a dynamic graph over time, with the
memory optimizations (dropping, recomputation) tuned per query.  Following
DBSP's split between a declarative circuit IR and its incremental executor,
a :class:`QueryPlan` captures everything a query means — semiring, initial
states, iteration bound, optional NFA product (RPQ), and its own
:class:`~repro.core.dropping.DropConfig` — without naming an engine.  Any
engine implementing the session protocol (`core/session.py`) can register a
plan: the dense TPU engine, the host pointer engine, or SCRATCH.

One plan is ONE query — one row of the dense engine's leading Q axis, one
difference index of the host engine.  Multi-source helpers return a list of
plans (one per source).

Plans in one session must share a **family**: the static shape of the
compiled sweep (semiring, iteration bound, PageRank weight derivation, NFA).
:func:`family_key` is that compatibility key; per-query knobs (source,
drop policy) stay free.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import dropping as dr
from repro.core import semiring as sr

INF = np.float32(np.inf)


# --------------------------------------------------------------------------- NFA
@dataclasses.dataclass(frozen=True)
class NFA:
    """Nondeterministic automaton over edge labels.

    ``delta``: label → [(state, state')] transitions; used to build the
    product graph (v, q) whose reachability answers the RPQ.
    """

    num_states: int
    delta: dict[int, list[tuple[int, int]]]
    start: int
    accept: tuple[int, ...]

    @staticmethod
    def star(label: int) -> "NFA":
        """Q1 = a*"""
        return NFA(1, {label: [(0, 0)]}, 0, (0,))

    @staticmethod
    def concat_star(a: int, b: int) -> "NFA":
        """Q2 = a ∘ b*"""
        return NFA(2, {a: [(0, 1)], b: [(1, 1)]}, 0, (1,))

    @staticmethod
    def chain(labels: Sequence[int]) -> "NFA":
        """Q3 = l1 ∘ l2 ∘ … ∘ lk (fixed-length path template)."""
        delta: dict[int, list[tuple[int, int]]] = {}
        for j, lbl in enumerate(labels):
            delta.setdefault(int(lbl), []).append((j, j + 1))
        return NFA(len(labels) + 1, delta, 0, (len(labels),))

    def key(self) -> tuple:
        """Hashable structural identity (``delta`` is a dict)."""
        delta = tuple(
            (lbl, tuple(pairs)) for lbl, pairs in sorted(self.delta.items())
        )
        return (self.num_states, delta, self.start, self.accept)

    def __hash__(self) -> int:  # delta is a dict → default frozen hash fails
        return hash(self.key())


# --------------------------------------------------------------------------- init spec
@dataclasses.dataclass(frozen=True)
class InitSpec:
    """How to build a query's D_0 row (the implicit iteration-0 diffs).

    ``kind``:
      * ``"source"``   — ``value`` at ``source``, ``fill`` elsewhere
        (SSSP/K-hop/RPQ; for RPQ ``source`` is the product-space id).
      * ``"labels"``   — vertex id as the initial label (WCC).
      * ``"constant"`` — ``fill`` everywhere (PageRank's all-ones).
    """

    kind: str = "source"
    source: int | None = None
    value: float = 0.0
    fill: float = float(INF)

    def build(self, num_vertices: int) -> np.ndarray:
        if self.kind == "source":
            row = np.full(num_vertices, self.fill, dtype=np.float32)
            row[int(self.source)] = self.value
            return row
        if self.kind == "labels":
            return np.arange(num_vertices, dtype=np.float32)
        if self.kind == "constant":
            return np.full(num_vertices, self.fill, dtype=np.float32)
        raise ValueError(f"unknown init kind {self.kind!r}")


# --------------------------------------------------------------------------- plan
@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One registered query, declaratively.

    Engine-independent: the session maps a plan onto whichever engine backs
    it.  ``drop`` is the query's OWN dropping policy (paper §5 is tuned per
    query/operator); the DroppedVT *representation* (det store vs Bloom) is
    session-level because it fixes array shapes.
    """

    kind: str  # "sssp" | "khop" | "wcc" | "pagerank" | "rpq"
    semiring: sr.Semiring
    init: InitSpec
    max_iters: int
    drop: dr.DropConfig = dataclasses.field(default_factory=dr.DropConfig)
    nfa: NFA | None = None
    # PageRank: edge weights derive from out-degrees (alpha / outdeg)
    weight_from_degree: bool = False
    alpha: float = 0.85

    def family_key(self) -> tuple:
        """Static-compatibility key: plans sharing a session must agree on
        everything that shapes the compiled sweep (per-query knobs — source,
        drop selection — stay free)."""
        s = self.semiring
        return (
            s.name,
            s.reduce,
            s.identity,
            s.carry_prev,
            s.base,
            s.hop_cap,
            int(self.max_iters),
            bool(self.weight_from_degree),
            float(self.alpha),
            None if self.nfa is None else self.nfa.key(),
        )

    def build_init(self, num_vertices: int) -> np.ndarray:
        """D_0 row over the engine's vertex space.

        With an NFA, ``num_vertices`` is the product-space count and the
        source maps to its (source, start-state) product id.
        """
        if self.nfa is not None and self.init.kind == "source":
            spec = dataclasses.replace(
                self.init,
                source=int(self.init.source) * self.nfa.num_states + self.nfa.start,
            )
            return spec.build(num_vertices)
        return self.init.build(num_vertices)


# --------------------------------------------------------------------------- builders
def sssp(
    source: int,
    *,
    max_iters: int = 64,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Single-source shortest-distance field (Bellman-Ford IFE)."""
    return QueryPlan(
        kind="sssp",
        semiring=sr.min_plus(),
        init=InitSpec(kind="source", source=int(source)),
        max_iters=int(max_iters),
        drop=drop or dr.DropConfig(),
    )


def khop(
    source: int,
    k: int = 5,
    *,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Vertices within ≤ k hops of the source; iterations bounded by k."""
    return QueryPlan(
        kind="khop",
        semiring=sr.min_hop(float(k)),
        init=InitSpec(kind="source", source=int(source)),
        max_iters=int(k),
        drop=drop or dr.DropConfig(),
    )


def wcc(
    *,
    max_iters: int = 128,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Weakly connected components: min-label propagation (the caller's
    graph must carry both edge directions)."""
    return QueryPlan(
        kind="wcc",
        semiring=sr.min_label(),
        init=InitSpec(kind="labels"),
        max_iters=int(max_iters),
        drop=drop or dr.DropConfig(),
    )


def pagerank(
    *,
    iters: int = 10,
    alpha: float = 0.85,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Pregel-style PageRank, fixed ``iters`` rounds (paper §6.1.2)."""
    return QueryPlan(
        kind="pagerank",
        semiring=sr.pagerank(alpha),
        init=InitSpec(kind="constant", fill=1.0),
        max_iters=int(iters),
        drop=drop or dr.DropConfig(),
        weight_from_degree=True,
        alpha=float(alpha),
    )


def rpq(
    source: int,
    nfa: NFA,
    *,
    max_iters: int = 64,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Regular path query: reachability on the NFA-product graph.

    The session owns the product construction; ``init.source`` is stored in
    *base* space and mapped to (source, start-state) at registration.
    """
    return QueryPlan(
        kind="rpq",
        semiring=sr.min_hop(),
        init=InitSpec(kind="source", source=int(source)),
        max_iters=int(max_iters),
        drop=drop or dr.DropConfig(),
        nfa=nfa,
    )
