"""Declarative query plans — the IR between query classes and engines.

The paper's system is a *continuous query processor*: clients register and
deregister recursive queries against a dynamic graph over time, with the
memory optimizations (dropping, recomputation) tuned **per operator** of the
query's dataflow.  Following DBSP's split between a declarative circuit IR
and its incremental executor, a :class:`QueryPlan` is a validated DAG of
typed operator nodes (:mod:`repro.core.dataflow`): ``Ingest → [Transform] →
[Join] → Iterate → [Aggregate]``, where each operator owns its own
difference store and :class:`~repro.core.dropping.DropConfig`.  Any engine
implementing the session protocol (`core/session.py`) can register a plan:
the dense TPU engine, the host pointer engine, or SCRATCH.

One plan is ONE query — one row of the dense engine's leading Q axis, one
difference index of the host engine.  Multi-source helpers return a list of
plans (one per source).

Two constructors:

* the **compatibility constructor** — ``QueryPlan(kind=..., semiring=...,
  init=..., max_iters=..., drop=..., nfa=...)`` — synthesizes the canonical
  operator graph from the legacy single-node fields (bit-identical answers
  and byte accounting to the pre-graph IR);
* ``QueryPlan.from_graph(kind, ops)`` — an explicit node tuple, validated
  (cycle detection, dangling references, node-count constraints) with the
  legacy accessor fields derived from the graph.

Plans in one session must share a **family**: the static shape of the
compiled sweep (semiring, iteration bound, PageRank weight derivation, NFA
— i.e. everything but per-query knobs like source, drop policies, and
aggregates).  :func:`dataflow.family_key` is that compatibility key, stable
under node listing order.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core import dataflow as df
from repro.core import dropping as dr
from repro.core import semiring as sr
from repro.core.dataflow import NFA, Aggregate, InitSpec  # noqa: F401  (re-export)

INF = np.float32(np.inf)


def _semiring_eq(a: sr.Semiring, b: sr.Semiring) -> bool:
    """Structural semiring equality (msg callables compare by identity)."""
    return (a.name, a.reduce, a.identity, a.carry_prev, a.base, a.hop_cap) == (
        b.name,
        b.reduce,
        b.identity,
        b.carry_prev,
        b.base,
        b.hop_cap,
    )


# --------------------------------------------------------------------- provenance
@dataclasses.dataclass(frozen=True)
class Provenance:
    """One rewrite applied to a plan by the optimizer (`repro.planner`).

    Rewritten answers stay attributable: the plan records which rule fired,
    what the pre-rewrite kind was, and the rule's parameters as a sorted
    ``(name, value)`` tuple (values are JSON scalars).  Excluded from the
    family key — a rewrite is an execution strategy, not a new sweep shape.
    """

    rule: str
    original_kind: str = ""
    params: tuple[tuple[str, object], ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "params", tuple(sorted((str(k), v) for k, v in self.params))
        )

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "original_kind": self.original_kind,
            "params": [[k, v] for k, v in self.params],
        }

    @staticmethod
    def from_dict(obj: dict) -> "Provenance":
        return Provenance(
            rule=str(obj["rule"]),
            original_kind=str(obj.get("original_kind", "")),
            params=tuple((str(k), v) for k, v in obj.get("params", [])),
        )


# --------------------------------------------------------------------------- plan
@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One registered query: a validated DAG of operator nodes.

    ``ops`` is the graph (the source of truth); the legacy fields
    (``semiring``/``init``/``max_iters``/``drop``/``nfa``/…) are accessor
    mirrors synced from the graph nodes, kept as dataclass fields so the
    compatibility constructor and existing call sites keep working.  To
    change a node's drop policy use :meth:`with_op_drop` — a bare
    ``dataclasses.replace(plan, drop=...)`` is rejected because the graph
    would silently win.
    """

    kind: str  # "sssp" | "khop" | "wcc" | "pagerank" | "rpq" | free-form
    semiring: sr.Semiring | None = None
    init: InitSpec | None = None
    max_iters: int | None = None
    drop: dr.DropConfig | None = None
    nfa: NFA | None = None
    # PageRank: edge weights derive from out-degrees (alpha / outdeg)
    weight_from_degree: bool = False
    alpha: float = 0.85
    ops: tuple[df.OpNode, ...] | None = None
    # optimizer rewrite trail (oldest first); free knob like aggregates
    provenance: tuple[Provenance, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "provenance", tuple(self.provenance))
        if self.ops is None:
            if self.semiring is None or self.init is None or self.max_iters is None:
                raise ValueError(
                    "the compatibility constructor needs semiring, init and "
                    "max_iters (or pass an explicit operator graph via ops=)"
                )
            if self.drop is None:
                object.__setattr__(self, "drop", dr.DropConfig())
            object.__setattr__(
                self,
                "ops",
                df.canonical(
                    semiring=self.semiring,
                    init=self.init,
                    max_iters=int(self.max_iters),
                    drop=self.drop,
                    nfa=self.nfa,
                    weight_from_degree=self.weight_from_degree,
                    alpha=self.alpha,
                ),
            )
            return
        nodes = df.validate(self.ops)
        it = next(n for n in nodes.values() if n.kind == "iterate")
        join = next((n for n in nodes.values() if n.kind == "join"), None)
        tf = next((n for n in nodes.values() if n.kind == "transform"), None)
        derived = dict(
            semiring=it.semiring,
            init=it.init,
            max_iters=int(it.max_iters),
            drop=it.drop,
            nfa=None if join is None else join.nfa,
            weight_from_degree=tf is not None and tf.weight_from_degree,
            alpha=0.85 if tf is None else float(tf.alpha),
        )
        mismatched = []
        if self.semiring is not None and not _semiring_eq(
            self.semiring, derived["semiring"]
        ):
            mismatched.append("semiring")
        for name in ("init", "max_iters", "drop", "nfa"):
            given = getattr(self, name)
            if given is not None and given != derived[name]:
                mismatched.append(name)
        if self.weight_from_degree and not derived["weight_from_degree"]:
            mismatched.append("weight_from_degree")
        if self.alpha != 0.85 and self.alpha != derived["alpha"]:
            mismatched.append("alpha")
        if mismatched:
            raise ValueError(
                f"legacy fields {mismatched} disagree with the operator graph"
                " — the graph is the source of truth; use with_op_drop() /"
                " from_graph() instead of dataclasses.replace"
            )
        for name, val in derived.items():
            object.__setattr__(self, name, val)

    # ----------------------------------------------------------- constructors
    @staticmethod
    def from_graph(kind: str, ops, *, provenance=()) -> "QueryPlan":
        """Build a plan from an explicit (validated) operator-node tuple."""
        return QueryPlan(kind=kind, ops=tuple(ops), provenance=tuple(provenance))

    # ------------------------------------------------------------- graph api
    def node(self, op_id: str) -> df.OpNode:
        for n in self.ops:
            if n.op_id == op_id:
                return n
        raise KeyError(f"plan has no operator {op_id!r}")

    def op_ids(self) -> tuple[str, ...]:
        return tuple(n.op_id for n in self.ops)

    def op_of_kind(self, kind: str) -> df.OpNode | None:
        return next((n for n in self.ops if n.kind == kind), None)

    def droppable_ops(self) -> tuple[str, ...]:
        """Operators that own a difference store (governor-addressable)."""
        return tuple(
            n.op_id for n in self.ops if n.kind in df.DROPPABLE_OPS
        )

    @property
    def aggregate(self) -> Aggregate | None:
        return self.op_of_kind("aggregate")

    @property
    def join_drop(self) -> dr.DropConfig | None:
        join = self.op_of_kind("join")
        return None if join is None else join.drop

    def join_policy(self) -> str:
        """The Join operator's storage policy: ``"none"`` (no join node),
        ``"auto"`` (inherit the engine mode — legacy), ``"materialize"``
        (VDC trace) or ``"drop"`` (complete dropping, JOD §4)."""
        join = self.op_of_kind("join")
        if join is None:
            return "none"
        if join.drop is None:
            return "auto"
        return "drop" if join.drop.enabled() else "materialize"

    def with_op_drop(self, op_id: str, cfg: dr.DropConfig | None) -> "QueryPlan":
        """A copy with operator ``op_id``'s drop policy replaced (the
        session's primitive for mid-stream policy rewrites)."""
        node = self.node(op_id)
        if node.kind not in df.DROPPABLE_OPS:
            raise ValueError(
                f"operator {op_id!r} ({node.kind}) owns no difference store"
            )
        if node.kind == "iterate" and cfg is None:
            cfg = dr.DropConfig()
        new_ops = tuple(
            dataclasses.replace(n, drop=cfg) if n.op_id == op_id else n
            for n in self.ops
        )
        return QueryPlan(kind=self.kind, ops=new_ops, provenance=self.provenance)

    def with_aggregate(
        self,
        agg: str = "topk",
        *,
        k: int = 8,
        bins: int = 8,
        vertex: int | None = None,
    ) -> "QueryPlan":
        """A copy with an Aggregate node appended (or replaced)."""
        it = self.op_of_kind("iterate")
        node = Aggregate(
            inputs=(it.op_id,),
            agg=agg,
            k=int(k),
            bins=int(bins),
            vertex=None if vertex is None else int(vertex),
        )
        new_ops = tuple(n for n in self.ops if n.kind != "aggregate") + (node,)
        return QueryPlan(kind=self.kind, ops=new_ops, provenance=self.provenance)

    def with_provenance(self, prov: Provenance) -> "QueryPlan":
        """A copy with one more rewrite recorded on the trail."""
        return QueryPlan(
            kind=self.kind, ops=self.ops, provenance=self.provenance + (prov,)
        )

    # ---------------------------------------------------------------- family
    def family_key(self) -> tuple:
        """Static-compatibility key: plans sharing a session must agree on
        everything that shapes the compiled sweep (per-query knobs — source,
        drop selection, aggregates — stay free).  Stable under node listing
        order (``dataflow.family_key`` sorts node keys)."""
        return df.family_key(self.ops)

    def build_init(self, num_vertices: int) -> np.ndarray:
        """D_0 row over the engine's vertex space.

        With an NFA, ``num_vertices`` is the product-space count and the
        source maps to its (source, start-state) product id.
        """
        if self.nfa is not None and self.init.kind == "source":
            spec = dataclasses.replace(
                self.init,
                source=int(self.init.source) * self.nfa.num_states + self.nfa.start,
            )
            return spec.build(num_vertices)
        return self.init.build(num_vertices)

    # ------------------------------------------------------------------ JSON
    def to_json(self) -> dict:
        """JSON-able plan graph (``from_json`` round-trips it)."""
        out: dict = {
            "kind": self.kind,
            "nodes": [df.node_to_dict(n) for n in self.ops],
        }
        if self.provenance:
            out["provenance"] = [p.to_dict() for p in self.provenance]
        return out

    @staticmethod
    def from_json(obj: dict | str) -> "QueryPlan":
        if isinstance(obj, str):
            obj = json.loads(obj)
        return QueryPlan.from_graph(
            obj.get("kind", "custom"),
            tuple(df.node_from_dict(n) for n in obj["nodes"]),
            provenance=tuple(
                Provenance.from_dict(p) for p in obj.get("provenance", [])
            ),
        )


# --------------------------------------------------------------------------- builders
def sssp(
    source: int,
    *,
    max_iters: int = 64,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Single-source shortest-distance field (Bellman-Ford IFE)."""
    return QueryPlan.from_graph(
        "sssp",
        df.canonical(
            semiring=sr.min_plus(),
            init=InitSpec(kind="source", source=int(source)),
            max_iters=int(max_iters),
            drop=drop,
        ),
    )


def spsp(
    source: int,
    target: int,
    *,
    max_iters: int = 64,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Single-pair shortest path: an SSSP field read at one target vertex
    (``Aggregate(agg="target")``).  Family-compatible with :func:`sssp`
    plans of the same ``max_iters`` — the aggregate is a free knob — and the
    match pattern of the planner's landmark rewrite (§6.6)."""
    return QueryPlan.from_graph(
        "spsp",
        df.canonical(
            semiring=sr.min_plus(),
            init=InitSpec(kind="source", source=int(source)),
            max_iters=int(max_iters),
            drop=drop,
            aggregate=Aggregate(agg="target", vertex=int(target)),
        ),
    )


def khop(
    source: int,
    k: int = 5,
    *,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Vertices within ≤ k hops of the source; iterations bounded by k."""
    return QueryPlan.from_graph(
        "khop",
        df.canonical(
            semiring=sr.min_hop(float(k)),
            init=InitSpec(kind="source", source=int(source)),
            max_iters=int(k),
            drop=drop,
        ),
    )


def wcc(
    *,
    max_iters: int = 128,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Weakly connected components: min-label propagation (the caller's
    graph must carry both edge directions)."""
    return QueryPlan.from_graph(
        "wcc",
        df.canonical(
            semiring=sr.min_label(),
            init=InitSpec(kind="labels"),
            max_iters=int(max_iters),
            drop=drop,
        ),
    )


def pagerank(
    *,
    iters: int = 10,
    alpha: float = 0.85,
    drop: dr.DropConfig | None = None,
) -> QueryPlan:
    """Pregel-style PageRank, fixed ``iters`` rounds (paper §6.1.2): the
    canonical graph routes the ingest through a Transform node deriving
    edge weights from out-degrees (α / outdeg)."""
    return QueryPlan.from_graph(
        "pagerank",
        df.canonical(
            semiring=sr.pagerank(alpha),
            init=InitSpec(kind="constant", fill=1.0),
            max_iters=int(iters),
            drop=drop,
            weight_from_degree=True,
            alpha=float(alpha),
        ),
    )


def rpq(
    source: int,
    nfa: NFA,
    *,
    max_iters: int = 64,
    drop: dr.DropConfig | None = None,
    join_store: str = "auto",
) -> QueryPlan:
    """Regular path query: reachability on the NFA-product graph.

    The canonical graph is ``Ingest → Join(nfa) → Iterate``: the session
    reads the Join node to own the product construction, so the engines
    never see automata; ``init.source`` is stored in *base* space and mapped
    to (source, start-state) at registration.

    ``join_store`` is the Join operator's own storage policy:

    * ``"auto"``        — inherit the engine mode (legacy behavior);
    * ``"materialize"`` — keep the per-edge message trace (VDC on the
      product graph);
    * ``"drop"``        — complete dropping (§4): the trace is never stored,
      messages recompute on demand ("drop the Join's differences, keep the
      Iterate's").
    """
    if join_store not in ("auto", "materialize", "drop"):
        raise ValueError(
            f"unknown join_store {join_store!r}; "
            "choose auto | materialize | drop"
        )
    join_drop = {
        "auto": None,
        "materialize": dr.DropConfig(),
        "drop": dr.DropConfig(mode="det", selection="random", p=1.0),
    }[join_store]
    return QueryPlan.from_graph(
        "rpq",
        df.canonical(
            semiring=sr.min_hop(),
            init=InitSpec(kind="source", source=int(source)),
            max_iters=int(max_iters),
            drop=drop,
            nfa=nfa,
            join_drop=join_drop,
        ),
    )
