"""Memory governor — budget-driven adaptive dropping (closed-loop §5).

The paper shows *what* to drop (Det/Bloom DroppedVT, Random/Degree
selection) and measures the memory/recompute trade-off per hand-tuned
policy.  This module operates it: DBSP and Graphsurge both make the system,
not the user, decide what incremental state to materialize, and a CQP
serving a churning query population needs the same — a global byte budget
enforced online by retuning each query's drop policy.

**Operator granularity.**  Enforcement is addressed at ``(query, operator)``
— the plan IR (`core/dataflow.py`) gives every query a dataflow of operators
each owning its own difference store, and the governor walks *operators*
along per-operator ladders:

* ``iterate`` — the §5 selection ladder:

      0   its own registered policy (usually no dropping)
      1…  escalating selection pressure — ``p`` rises along
          ``GovernorConfig.ladder_p`` and, under Degree selection, τ_min
          tightens by ``tau_tighten`` per rung
      top drop-all (p = 1): the dense engine keeps only ≤4 B DroppedVT
          records / Bloom bits and repairs on access; the host engine
          interprets drop-all as its **scratch fallback** — the query's
          difference index is dropped entirely and its answers are
          re-executed from scratch per batch (zero diff bytes, maximal
          recompute — the paper's SCRATCH endpoint, per query).

* ``join`` — a single rung: the operator's differences drop *completely*
  (§4's JOD, per slot): rung 1 zeroes the query's J-store rows and its
  messages recompute on demand; stepping back down re-materializes the
  trace with one re-derivation sweep.  This is the paper's
  operator-dropping scenario — "drop the Join's differences, keep the
  Iterate's" — and needs no DroppedVT bookkeeping, because complete
  dropping repairs deterministically.

* ``landmark`` — the plan optimizer's shared-index pseudo-operator (keyed
  ``(PLANNER_QID, "landmark")`` by `repro.planner`), another single rung:
  rung 1 sheds the landmark index (its 2·L maintained SSSP rows deregister
  and the rewritten queries degrade to un-pruned scratch — answers stay
  exact, latency rises), rung 0 re-materializes it.  "Landmark-ize /
  de-landmark-ize" is thereby an online memory↔latency knob alongside
  dropping (DESIGN.md §16).

Escalation rewrites the operator's policy in place — traced ``[Q]`` rows,
no engine recompile — and sheds already-stored diffs under the new policy
(``engine.shed_slot`` / ``engine.set_join_store``), so memory falls
immediately, not just for future writes.

**Victim choice.**  Over budget, the governor escalates the ``(query,
operator)`` with the most reclaimable bytes per unit of recent recompute
cost (``bytes / (1 + cost_rate)`` from :class:`RecomputeTelemetry`) — i.e.
it spends recomputation where it is cheapest.  For an RPQ with a
materialized join that is typically the join trace first (large, cheap to
re-derive), the iterate's change points only under further pressure.
Operators whose escalation coincides with Det-Drop overflow growth are
skipped (records lost to eviction cannot be repaired, so pushing them
harder risks staleness).

**Hysteresis.**  Under ``low_water × budget`` for ``cooldown_passes``
consecutive passes, the most escalated operator steps DOWN one rung (diffs
regrow naturally as sweeps write points), so a transient spike does not
pin the population at drop-all forever, and the escalate/de-escalate bands
never overlap.
"""

from __future__ import annotations

import dataclasses

from repro.core import dropping as dr
from repro.core.telemetry import RecomputeTelemetry
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    """Budget-enforcement knobs (the budget itself is ``CQPSession``'s
    ``budget_bytes``)."""

    representation: str = "det"  # auto-provisioned DroppedVT repr: det | prob
    ladder_p: tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)  # rungs 1..top
    selection: str = "random"  # random | degree
    tau_tighten: float = 4.0  # degree selection: τ_min += k·tau_tighten
    low_water: float = 0.7  # de-escalate below low_water × budget
    cooldown_passes: int = 2  # consecutive calm passes before de-escalating
    max_actions_per_pass: int = 16
    det_capacity: int = 32  # provisioned representation capacities
    bloom_bits: int = 1 << 10
    seed: int = 0

    def __post_init__(self):
        if self.representation not in ("det", "prob"):
            raise ValueError(f"unknown representation {self.representation!r}")
        if self.selection not in ("random", "degree"):
            # fail at construction, not on the first over-budget pass
            raise ValueError(f"unknown selection {self.selection!r}")
        if not self.ladder_p or list(self.ladder_p) != sorted(self.ladder_p):
            raise ValueError("ladder_p must be a nondecreasing, nonempty tuple")
        if not (0.0 < self.low_water < 1.0):
            raise ValueError("low_water must be in (0, 1)")

    @property
    def top_level(self) -> int:
        return len(self.ladder_p)

    def representation_config(self) -> dr.DropConfig:
        """The p=0 DroppedVT provisioning a governor session installs when no
        registered plan brings one: shapes are allocated, nothing drops until
        the governor escalates."""
        return dr.DropConfig(
            mode=self.representation,
            selection=self.selection,
            p=0.0,
            det_capacity=self.det_capacity,
            bloom_bits=self.bloom_bits,
            seed=self.seed,
        )

    def rung_config(self, level: int, base: dr.DropConfig) -> dr.DropConfig:
        """The Iterate operator's DropConfig at ladder ``level``.

        Level 0 restores ``base`` (the query's registered policy).  Higher
        rungs keep the query's seed when it already had one — the stateless
        coin then makes successive rungs' drop sets nested, so escalation
        monotonically sheds and de-escalation never thrashes the store.
        """
        if level <= 0:
            return base
        p = self.ladder_p[min(level, self.top_level) - 1]
        degree_sel = self.selection == "degree"
        return dr.DropConfig(
            mode=self.representation,
            selection=self.selection,
            p=float(p),
            tau_min=(2.0 + self.tau_tighten * level) if degree_sel else 2.0,
            det_capacity=self.det_capacity,
            bloom_bits=self.bloom_bits,
            seed=base.seed if base.enabled() else self.seed,
        )

    def join_rung(self, level: int, base: dr.DropConfig | None) -> dr.DropConfig:
        """The Join operator's single-rung ladder: level 0 restores the
        registered policy (materialize, unless the plan registered the join
        dropped), level ≥ 1 drops the trace completely (recompute-on-demand
        — no partial rungs and no DroppedVT footprint, §4)."""
        if level <= 0:
            return base if base is not None else dr.DropConfig()
        return dr.DropConfig(mode=self.representation, selection="random", p=1.0)

    def top_level_for(self, op: str) -> int:
        # single-rung operators: the join trace (complete dropping, §4) and
        # the planner's shared landmark index (shed / re-materialize)
        return 1 if op in ("join", "landmark") else self.top_level


@dataclasses.dataclass
class GovernorAction:
    """One retuning decision, attributed at (query, operator) granularity,
    for the serving log / JSON report."""

    seq: int  # session.updates_applied when the action fired
    qid: int
    kind: str  # "escalate" | "deescalate"
    level_from: int
    level_to: int
    bytes_freed: int
    nbytes_after: int
    reason: str
    op: str = "iterate"  # the operator whose store the action retuned

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class MemoryGovernor:
    """Budget-enforcement loop over one :class:`~repro.core.session.CQPSession`.

    The session calls :meth:`enforce` after every ingest / register /
    deregister; the governor meters per-query bytes through the engine
    protocol, folds recompute signals into :class:`RecomputeTelemetry`, and
    walks queries along the policy ladder until the byte budget holds.
    """

    def __init__(
        self,
        budget_bytes: int,
        cfg: GovernorConfig | None = None,
        telemetry: RecomputeTelemetry | None = None,
    ) -> None:
        if budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive")
        self.budget_bytes = int(budget_bytes)
        self.cfg = cfg or GovernorConfig()
        self.telemetry = telemetry or RecomputeTelemetry()
        # ladder rung per (qid, op_id) — the governor's (query, operator)
        # address space; ``levels`` exposes the legacy per-query iterate view
        self._levels: dict[tuple[int, str], int] = {}
        self.actions: list[GovernorAction] = []
        # (qid, op_id) → registered policy (level-0 restore point)
        self._base: dict[tuple[int, str], dr.DropConfig | None] = {}
        # det-overflow escalation guard: overflow growth is attributed to the
        # most recently escalated operator (sheds and the drops its new
        # policy causes are the prime suspects), which is then barred from
        # further escalation until it de-escalates — never a global lockout
        self._overflow_blocked: set[tuple[int, str]] = set()
        self._last_escalated: tuple[int, str] | None = None
        self._overflow_mark = 0
        # bytes each operator's escalations reclaimed (net of observed
        # regrowth) — the de-escalation guard's regrowth estimate
        self._reclaimed: dict[tuple[int, str], int] = {}
        self._calm_passes = 0
        self.passes = 0

    @property
    def levels(self) -> dict[int, int]:
        """Legacy per-query view: each query's Iterate-operator rung."""
        return {
            qid: lvl for (qid, op), lvl in self._levels.items() if op == "iterate"
        }

    @property
    def op_levels(self) -> dict[tuple[int, str], int]:
        return dict(self._levels)

    # ------------------------------------------------------------ lifecycle
    def on_register(self, qid: int, plan) -> None:
        """Track a registered plan's droppable operators (its graph nodes;
        engine-implicit operators surface lazily through the byte meters)."""
        self._levels[(qid, "iterate")] = 0
        self._base[(qid, "iterate")] = plan.drop
        if "join" in plan.droppable_ops():
            self._levels[(qid, "join")] = 0
            self._base[(qid, "join")] = plan.join_drop

    def on_deregister(self, qid: int) -> None:
        for key in [k for k in self._levels if k[0] == qid]:
            self._levels.pop(key, None)
            self._base.pop(key, None)
            self._overflow_blocked.discard(key)
            self._reclaimed.pop(key, None)
            if self._last_escalated == key:
                self._last_escalated = None

    # ---------------------------------------------------------- enforcement
    def enforce(self, session) -> list[GovernorAction]:
        """One budget-enforcement pass over the (query, operator) table;
        returns the actions taken."""
        per_op = session._nbytes_per_op_map()
        self.telemetry.observe(
            nbytes_per_query=per_op,
            cost_per_query=session._recompute_cost_op_map(),
            stats=session.last_stats,
            updates_applied=session.updates_applied,
        )
        new_actions: list[GovernorAction] = []
        total = sum(per_op.values())
        self._check_overflow(session)
        while total > self.budget_bytes and len(new_actions) < self.cfg.max_actions_per_pass:
            cands = [
                key
                for key in per_op
                if self._levels.get(key, 0) < self.cfg.top_level_for(key[1])
                and key not in self._overflow_blocked
                # an empty store has nothing to reclaim — escalating it only
                # burns a rung (the iterate rung still thins future writes,
                # but a join flip or an index shed would be a pure no-op)
                and not (key[1] in ("join", "landmark") and per_op[key] == 0)
            ]
            if not cands:
                break
            key = max(
                cands,
                key=lambda k: per_op[k] / (1.0 + self.telemetry.cost_rate(k)),
            )
            # a shed's delta is exactly the global delta (it touches one
            # slot's accounted rows), so the loop never re-meters the engine
            action = self._step(session, key, +1, "over budget", total)
            new_actions.append(action)
            per_op[key] = max(per_op[key] - action.bytes_freed, 0)
            total = action.nbytes_after
            self._check_overflow(session)
        if new_actions:
            self._calm_passes = 0
        elif total <= self.cfg.low_water * self.budget_bytes:
            self._calm_passes += 1
            # predictive guard: only relieve an operator whose reclaimed
            # bytes would still fit under the low-water mark if they all
            # came back — de-escalating at the floor just to re-escalate
            # next pass (host: a full index rebuild each way) is the flap
            # hysteresis exists to prevent
            headroom_for = self.cfg.low_water * self.budget_bytes - total
            escalated = [
                key
                for key in per_op
                if self._levels.get(key, 0) > 0
                and self._reclaimed.get(key, 0) <= headroom_for
            ]
            if escalated and self._calm_passes > self.cfg.cooldown_passes:
                # relieve the operator paying the most recompute per update
                key = max(escalated, key=self.telemetry.cost_rate)
                new_actions.append(
                    self._step(session, key, -1, "headroom recovered", total)
                )
                self._calm_passes = 0
        else:
            self._calm_passes = 0
        self.actions.extend(new_actions)
        self.passes += 1
        return new_actions

    def _check_overflow(self, session) -> None:
        """Attribute DroppedVT record loss (sweep evictions + shed evictions)
        to the most recently escalated operator and bar it from further
        escalation — lost records cannot be repaired, so pushing the same
        store harder risks stale answers.  De-escalation lifts the bar."""
        overflow = self.telemetry.det_overflow_total + session._det_overflow_shed()
        if overflow > self._overflow_mark and self._last_escalated is not None:
            self._overflow_blocked.add(self._last_escalated)
            self._last_escalated = None
        self._overflow_mark = overflow

    def _step(
        self, session, key: tuple[int, str], direction: int, reason: str, total: int
    ) -> GovernorAction:
        qid, op = key
        lvl = self._levels.get(key, 0)
        new_lvl = max(lvl + direction, 0)
        base = self._base.get(key, dr.DropConfig() if op != "join" else None)
        if op in ("join", "landmark"):
            # both are single-rung complete-drop ladders: rung 1 sheds the
            # store (join trace / shared landmark index), rung 0 restores it
            cfg_new = self.cfg.join_rung(new_lvl, base)
        else:
            cfg_new = self.cfg.rung_config(new_lvl, base)
        with obs_trace.span(
            "escalate" if direction > 0 else "deescalate",
            "governor",
            pid="governor",
            tid=qid,
            qid=qid,
            op=op,
            level_from=lvl,
            level_to=new_lvl,
            reason=reason,
        ) as sp:
            freed = session._set_op_drop_policy_qid(qid, op, cfg_new)
            sp.set(bytes_freed=int(freed))
        if direction > 0:
            self._last_escalated = key
            self._reclaimed[key] = self._reclaimed.get(key, 0) + max(int(freed), 0)
            after = total - int(freed)
        else:
            # de-escalation may regrow state (host scratch-fallback exit and
            # join re-materialization rebuild stores), so re-meter this one
            self._overflow_blocked.discard(key)
            after = session.nbytes()
            regrow = max(after - total, 0)
            self._reclaimed[key] = (
                0 if new_lvl == 0 else max(self._reclaimed.get(key, 0) - regrow, 0)
            )
        self._levels[key] = new_lvl
        return GovernorAction(
            seq=session.updates_applied,
            qid=qid,
            kind="escalate" if direction > 0 else "deescalate",
            level_from=lvl,
            level_to=new_lvl,
            bytes_freed=int(freed),
            nbytes_after=after,
            reason=reason,
            op=op,
        )

    # ------------------------------------------------------------ durability
    def state_dict(self) -> dict:
        """JSON-able full state: ladder rungs, restore-point policies,
        overflow guard, hysteresis counters, action log, telemetry EWMAs."""

        def cfg_dict(cfg: dr.DropConfig | None) -> dict | None:
            return None if cfg is None else dataclasses.asdict(cfg)

        return {
            "budget_bytes": self.budget_bytes,
            "cfg": dataclasses.asdict(self.cfg),
            "levels": [
                {"qid": q, "op": op, "level": lvl}
                for (q, op), lvl in self._levels.items()
            ],
            "base": [
                {"qid": q, "op": op, "cfg": cfg_dict(cfg)}
                for (q, op), cfg in self._base.items()
            ],
            "overflow_blocked": [list(k) for k in self._overflow_blocked],
            "last_escalated": (
                None if self._last_escalated is None else list(self._last_escalated)
            ),
            "overflow_mark": self._overflow_mark,
            "reclaimed": [
                {"qid": q, "op": op, "bytes": b}
                for (q, op), b in self._reclaimed.items()
            ],
            "calm_passes": self._calm_passes,
            "passes": self.passes,
            "actions": [a.to_dict() for a in self.actions],
            "telemetry": self.telemetry.state_dict(),
        }

    def load_state(self, state: dict) -> None:
        self.budget_bytes = int(state["budget_bytes"])
        cfg = dict(state["cfg"])
        cfg["ladder_p"] = tuple(cfg["ladder_p"])
        self.cfg = GovernorConfig(**cfg)
        self._levels = {
            (int(e["qid"]), e["op"]): int(e["level"]) for e in state["levels"]
        }
        self._base = {
            (int(e["qid"]), e["op"]): (
                None if e["cfg"] is None else dr.DropConfig(**e["cfg"])
            )
            for e in state["base"]
        }
        self._overflow_blocked = {
            (int(q), op) for q, op in state["overflow_blocked"]
        }
        self._last_escalated = (
            None
            if state["last_escalated"] is None
            else (int(state["last_escalated"][0]), state["last_escalated"][1])
        )
        self._overflow_mark = int(state["overflow_mark"])
        self._reclaimed = {
            (int(e["qid"]), e["op"]): int(e["bytes"]) for e in state["reclaimed"]
        }
        self._calm_passes = int(state["calm_passes"])
        self.passes = int(state["passes"])
        self.actions = [GovernorAction(**a) for a in state["actions"]]
        self.telemetry.load_state(state["telemetry"])

    # ------------------------------------------------------------------ api
    def headroom(self, session) -> int:
        return self.budget_bytes - session.nbytes()

    def headroom_fraction(self, session) -> float:
        """Headroom as a fraction of the budget (≤ 0 when over budget) —
        the admission controller's governor-pressure signal."""
        return self.headroom(session) / self.budget_bytes

    def snapshot(self, session=None) -> dict:
        out = {
            "budget_bytes": self.budget_bytes,
            "passes": self.passes,
            "escalations": sum(1 for a in self.actions if a.kind == "escalate"),
            "deescalations": sum(
                1 for a in self.actions if a.kind == "deescalate"
            ),
            "levels": {str(q): lvl for q, lvl in sorted(self.levels.items())},
            "op_levels": {
                f"{q}/{op}": lvl
                for (q, op), lvl in sorted(self._levels.items())
            },
            "overflow_blocked": sorted({q for (q, _op) in self._overflow_blocked}),
            "actions": [a.to_dict() for a in self.actions],
            "telemetry": self.telemetry.snapshot(),
        }
        if session is not None:
            out["headroom_bytes"] = self.headroom(session)
            out["det_overflow_shed"] = session._det_overflow_shed()
        return out
