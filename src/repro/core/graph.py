"""Dynamic property graph: host-side mutable store + device COO views.

The paper's graph model (§3.1): directed property graph, edge labels and
weights, update batches ``[(u, v, label, weight, +/-)]``.  A GDBMS keeps the
adjacency index on the host; the IFE compute consumes fixed-shape device
arrays.  We preallocate edge capacity so update batches never change array
shapes (no recompile), and mark deleted slots invalid.

Device layout is COO (``src``, ``dst``, ``w``, ``valid``) — the engine's
pure-JAX SpMV uses ``segment_min``/``segment_max``/``segment_sum`` over
``dst``.  The Pallas ``ell_spmv`` kernel consumes the bucketed-ELL view
produced by :meth:`GraphSnapshot.to_ell`.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

# An update is (u, v, label, weight, +1|-1) as in the paper §3.1.
Update = tuple[int, int, int, float, int]

# A resolved op is (kind, slot, u, v, weight) where kind ∈ {"insert",
# "update", "delete"}: the slot-level effect of one accepted update
# ("update" = weight change in place; no-op deletions are filtered out).
ResolvedOp = tuple[str, int, int, int, float]

NO_LABEL = 0


@dataclasses.dataclass
class GraphSnapshot:
    """Immutable fixed-shape device-friendly view of the graph."""

    num_vertices: int
    src: np.ndarray  # int32 [E_cap]
    dst: np.ndarray  # int32 [E_cap]
    weight: np.ndarray  # float32 [E_cap]
    label: np.ndarray  # int32 [E_cap]
    valid: np.ndarray  # bool [E_cap]
    out_degree: np.ndarray  # int32 [V]
    in_degree: np.ndarray  # int32 [V]

    @property
    def capacity(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.valid.sum())

    def degrees_total(self) -> np.ndarray:
        return self.out_degree + self.in_degree

    def to_ell(
        self, pad_to_multiple: int = 8, min_width: int = 0, row_multiple: int = 1
    ) -> tuple[np.ndarray, np.ndarray, int]:
        """In-adjacency in ELL layout (for the Pallas kernel).

        Returns ``(nbr, w)`` with shape ``[Vr, D]`` where ``D`` is the max
        in-degree rounded up; padded slots have ``nbr == V`` (a sentinel row;
        callers pad the state vector with the reduce identity at index V).
        ``min_width`` lets the continuous processor keep ``D`` fixed across
        update batches (a ``D`` change means a re-trace of the jitted sweep).

        ``row_multiple`` pads the ROW count to a multiple (``Vr ≥ V``) with
        all-sentinel rows, once, at build time — the kernels never pad or
        copy operands per call (their blocked grid needs the row count to be
        a block multiple; see ``ell_spmv``'s shape contract).  Padding rows
        gather only the identity, and callers slice their outputs back to V.
        """
        v = self.num_vertices
        live = self.valid
        indeg = np.bincount(self.dst[live], minlength=v)
        d = max(int(indeg.max()) if v else 0, min_width)
        d = max(pad_to_multiple, ((d + pad_to_multiple - 1) // pad_to_multiple) * pad_to_multiple)
        vr = ((v + row_multiple - 1) // row_multiple) * row_multiple
        nbr = np.full((vr, d), v, dtype=np.int32)
        w = np.zeros((vr, d), dtype=np.float32)
        fill = np.zeros(v, dtype=np.int64)
        for e in np.nonzero(live)[0]:
            u, t = int(self.src[e]), int(self.dst[e])
            nbr[t, fill[t]] = u
            w[t, fill[t]] = self.weight[e]
            fill[t] += 1
        return nbr, w, d


class DynamicGraph:
    """Host-side dynamic graph with slot-recycling edge storage."""

    def __init__(
        self,
        num_vertices: int,
        edges: Sequence[tuple] | np.ndarray,
        *,
        capacity: int | None = None,
        weighted: bool = True,
    ) -> None:
        edges = list(edges)
        n = len(edges)
        cap = capacity if capacity is not None else max(16, int(n * 1.5))
        if cap < n:
            raise ValueError("capacity below initial edge count")
        self.num_vertices = int(num_vertices)
        self.weighted = weighted
        self.src = np.full(cap, 0, dtype=np.int32)
        self.dst = np.full(cap, 0, dtype=np.int32)
        self.weight = np.zeros(cap, dtype=np.float32)
        self.label = np.zeros(cap, dtype=np.int32)
        self.valid = np.zeros(cap, dtype=bool)
        self.out_degree = np.zeros(self.num_vertices, dtype=np.int32)
        self.in_degree = np.zeros(self.num_vertices, dtype=np.int32)
        self._slot: dict[tuple[int, int, int], int] = {}
        self._free: list[int] = list(range(cap - 1, n - 1, -1))
        self.version = 0  # G_k
        for i, e in enumerate(edges):
            u, v = int(e[0]), int(e[1])
            w = float(e[2]) if (weighted and len(e) > 2) else 1.0
            lbl = int(e[3]) if len(e) > 3 else NO_LABEL
            self.src[i], self.dst[i] = u, v
            self.weight[i], self.label[i] = w, lbl
            self.valid[i] = True
            self.out_degree[u] += 1
            self.in_degree[v] += 1
            self._slot[(u, v, lbl)] = i

    # ------------------------------------------------------------ durability
    def state_dict(self) -> tuple[dict[str, np.ndarray], dict]:
        """(arrays, meta) capturing the full mutable state.

        The free list is saved as an *ordered* array: slot recycling order
        decides which slot a replayed insert lands in, so replay determinism
        requires restoring it exactly — not recomputing it from ``valid``.
        """
        arrays = {
            "src": self.src.copy(),
            "dst": self.dst.copy(),
            "weight": self.weight.copy(),
            "label": self.label.copy(),
            "valid": self.valid.copy(),
            "out_degree": self.out_degree.copy(),
            "in_degree": self.in_degree.copy(),
            "free": np.asarray(self._free, dtype=np.int64),
        }
        meta = {
            "num_vertices": self.num_vertices,
            "weighted": self.weighted,
            "version": self.version,
        }
        return arrays, meta

    @classmethod
    def from_state(cls, meta: dict, arrays: dict) -> "DynamicGraph":
        g = cls(
            int(meta["num_vertices"]),
            [],
            capacity=int(arrays["src"].shape[0]),
            weighted=bool(meta["weighted"]),
        )
        for name in ("src", "dst", "weight", "label", "valid",
                     "out_degree", "in_degree"):
            getattr(g, name)[:] = arrays[name]
        g._free = [int(x) for x in arrays["free"]]
        g._slot = {
            (int(g.src[i]), int(g.dst[i]), int(g.label[i])): int(i)
            for i in np.nonzero(g.valid)[0]
        }
        g.version = int(meta["version"])
        return g

    # ------------------------------------------------------------------ api
    @property
    def num_edges(self) -> int:
        return int(self.valid.sum())

    @property
    def capacity(self) -> int:
        return int(self.src.shape[0])

    def snapshot(self) -> GraphSnapshot:
        return GraphSnapshot(
            num_vertices=self.num_vertices,
            src=self.src.copy(),
            dst=self.dst.copy(),
            weight=self.weight.copy(),
            label=self.label.copy(),
            valid=self.valid.copy(),
            out_degree=self.out_degree.copy(),
            in_degree=self.in_degree.copy(),
        )

    def apply_batch(self, updates: Iterable[Update]) -> list[tuple[int, int]]:
        """Apply one δE batch; returns the touched (src, dst) endpoints.

        Insertions of an existing (u, v, label) update the weight in place
        (the paper models weight updates as delete+insert; both forms are
        accepted).  Endpoints — not slots — are returned because a later
        insert in the same batch may recycle a freed slot.
        """
        return [(u, v) for (_kind, _slot, u, v, _w) in self.apply_batch_resolved(updates)]

    def apply_batch_resolved(self, updates: Iterable[Update]) -> list[ResolvedOp]:
        """Apply one δE batch, returning the slot-level effect of every
        accepted update (the device mirror the batched engine step scatters).
        """
        ops: list[ResolvedOp] = []
        for (u, v, lbl, w, sign) in updates:
            u, v, lbl = int(u), int(v), int(lbl)
            key = (u, v, lbl)
            if sign > 0:
                if key in self._slot:
                    i = self._slot[key]
                    self.weight[i] = float(w)
                    ops.append(("update", i, u, v, float(w)))
                else:
                    if not self._free:
                        raise MemoryError("edge capacity exhausted")
                    i = self._free.pop()
                    self.src[i], self.dst[i] = u, v
                    self.weight[i], self.label[i] = float(w), lbl
                    self.valid[i] = True
                    self._slot[key] = i
                    self.out_degree[u] += 1
                    self.in_degree[v] += 1
                    ops.append(("insert", i, u, v, float(w)))
            else:
                if key not in self._slot:
                    continue  # deleting a non-existent edge is a no-op
                i = self._slot.pop(key)
                self.valid[i] = False
                self._free.append(i)
                self.out_degree[u] -= 1
                self.in_degree[v] -= 1
                ops.append(("delete", i, u, v, float(w)))
        self.version += 1
        return ops

    def degree_percentile(self, pct: float) -> float:
        """Degree threshold at the given percentile (paper: τ_max = 80th)."""
        deg = self.degrees_total()
        return float(np.percentile(deg[deg > 0], pct)) if (deg > 0).any() else 0.0

    def degrees_total(self) -> np.ndarray:
        return self.out_degree + self.in_degree


@dataclasses.dataclass
class EllWrite:
    """One ELL cell assignment: ``nbr[row, col] = nbr_val; w[row, col] = w_val``."""

    row: int
    col: int
    nbr_val: int
    w_val: float


class EllOverflow(Exception):
    """A row ran out of ELL columns — the caller must rebuild at a wider D."""


class EllIndex:
    """Host mirror of the device ELL buffers (``GraphSnapshot.to_ell``).

    Tracks the (row = dst, col) cell of every live edge slot plus per-row free
    columns, so a δE batch becomes O(B) scatter writes on the device instead
    of an O(V·D) host rebuild + transfer.  Construction replays the exact fill
    order of :meth:`GraphSnapshot.to_ell` (ascending live slot index), so a
    freshly-built index agrees cell-for-cell with ``to_ell`` output.
    """

    def __init__(self, snap: GraphSnapshot, width: int) -> None:
        self.v = snap.num_vertices
        self.width = int(width)
        self.col_of: dict[int, tuple[int, int]] = {}  # edge slot → (row, col)
        self.fill = np.zeros(self.v, dtype=np.int64)
        self.free: dict[int, list[int]] = {}
        for e in np.nonzero(snap.valid)[0]:
            t = int(snap.dst[e])
            if self.fill[t] >= self.width:
                raise EllOverflow(f"in-degree of vertex {t} exceeds width {self.width}")
            self.col_of[int(e)] = (t, int(self.fill[t]))
            self.fill[t] += 1

    def _alloc(self, row: int) -> int:
        cols = self.free.get(row)
        if cols:
            return cols.pop()
        if self.fill[row] >= self.width:
            raise EllOverflow(f"in-degree of vertex {row} exceeds width {self.width}")
        col = int(self.fill[row])
        self.fill[row] += 1
        return col

    def writes_for(self, ops: Sequence[ResolvedOp]) -> list[EllWrite]:
        """Translate resolved slot ops into coalesced ELL cell writes.

        Raises :class:`EllOverflow` when an insert exceeds the fixed width;
        the index is then stale and must be rebuilt from the (already
        updated) host graph at a larger width.
        """
        writes: dict[tuple[int, int], EllWrite] = {}
        for (kind, slot, u, v, w) in ops:
            if kind == "delete":
                row, col = self.col_of.pop(slot)
                self.free.setdefault(row, []).append(col)
                writes[(row, col)] = EllWrite(row, col, self.v, 0.0)
            elif kind == "insert":
                col = self._alloc(v)
                self.col_of[slot] = (v, col)
                writes[(v, col)] = EllWrite(v, col, u, float(w))
            else:  # weight update in place
                row, col = self.col_of[slot]
                writes[(row, col)] = EllWrite(row, col, u, float(w))
        return list(writes.values())


@dataclasses.dataclass
class ShardWrite:
    """One sharded edge-cell assignment at linear index ``lin``
    (= shard · shard_capacity + position within the shard's cell range)."""

    lin: int
    src: int
    dst: int
    weight: float
    valid: bool


class ShardOverflow(Exception):
    """A destination shard ran out of edge cells — rebuild at a larger
    per-shard capacity (the index is stale once this is raised)."""


class ShardIndex:
    """Host mirror of the vertex-sharded edge layout (mesh ``data`` axis).

    Shard ``k`` of ``n`` owns the contiguous vertex block
    ``[k·V/n, (k+1)·V/n)`` and every edge whose DESTINATION falls in it, laid
    out in a fixed-capacity cell range ``[k·C, (k+1)·C)`` so a δE chunk
    becomes one device-side scatter into the owning shards (the engine's
    ``shard_map`` splits the ``[n·C]`` edge arrays along the cell axis).
    Plays the same role for the sharded COO view that :class:`EllIndex`
    plays for the ELL view; deletions keep the cell's endpoints (the VDC
    J-store identity-overwrite rule still needs the old destination) and
    recycle the cell through a per-shard free list.
    """

    def __init__(
        self, snap: GraphSnapshot, num_shards: int, *, min_capacity: int = 0
    ) -> None:
        v, n = snap.num_vertices, int(num_shards)
        if v % n:
            raise ValueError(f"num_vertices {v} not divisible by {n} shards")
        self.num_shards = n
        self.vertices_per_shard = v // n
        live = np.nonzero(snap.valid)[0]
        counts = np.bincount(
            snap.dst[live] // self.vertices_per_shard, minlength=n
        )
        cap = max(
            int(counts.max(initial=0)),
            -(-snap.capacity // n),  # even spread of the host capacity
            int(min_capacity),
            8,
        )
        self.shard_capacity = -(-cap // 8) * 8
        self.cell_of: dict[int, int] = {}  # edge slot → linear cell index
        self.dead: dict[int, tuple[int, int]] = {}  # freed cell → endpoints
        self.fill = np.zeros(n, dtype=np.int64)
        self.free: dict[int, list[int]] = {}
        for e in live:  # ascending slot order, like EllIndex / to_ell
            sh = int(snap.dst[e]) // self.vertices_per_shard
            self.cell_of[int(e)] = sh * self.shard_capacity + int(self.fill[sh])
            self.fill[sh] += 1

    def _alloc(self, shard: int) -> int:
        cells = self.free.get(shard)
        if cells:
            return cells.pop()
        if self.fill[shard] >= self.shard_capacity:
            raise ShardOverflow(
                f"shard {shard} edge cells exhausted at {self.shard_capacity}"
            )
        lin = shard * self.shard_capacity + int(self.fill[shard])
        self.fill[shard] += 1
        return lin

    def writes_for(self, ops: Sequence[ResolvedOp]) -> list[ShardWrite]:
        """Translate resolved slot ops into coalesced sharded-cell writes.

        Raises :class:`ShardOverflow` when an insert exceeds a shard's fixed
        capacity; the index is then stale and must be rebuilt from the
        (already updated) host graph.
        """
        writes: dict[int, ShardWrite] = {}
        for (kind, slot, u, v, w) in ops:
            if kind == "delete":
                lin = self.cell_of.pop(slot)
                self.free.setdefault(lin // self.shard_capacity, []).append(lin)
                self.dead[lin] = (u, v)
                writes[lin] = ShardWrite(lin, u, v, float(w), False)
            elif kind == "insert":
                lin = self._alloc(v // self.vertices_per_shard)
                self.cell_of[slot] = lin
                self.dead.pop(lin, None)
                writes[lin] = ShardWrite(lin, u, v, float(w), True)
            else:  # weight update in place
                lin = self.cell_of[slot]
                writes[lin] = ShardWrite(lin, u, v, float(w), True)
        return list(writes.values())

    def edge_arrays(
        self, snap: GraphSnapshot
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Sharded-layout COO arrays ``[n · shard_capacity]`` from a snapshot."""
        size = self.num_shards * self.shard_capacity
        src = np.zeros(size, dtype=np.int32)
        dst = np.zeros(size, dtype=np.int32)
        w = np.zeros(size, dtype=np.float32)
        valid = np.zeros(size, dtype=bool)
        for slot, lin in self.cell_of.items():
            src[lin] = snap.src[slot]
            dst[lin] = snap.dst[slot]
            w[lin] = snap.weight[slot]
            valid[lin] = snap.valid[slot]
        # freed cells keep their last endpoints, matching the scatter path
        # (writes_for) and the unsharded snapshot: the VDC identity-overwrite
        # rule still needs a deleted edge's old destination to look dirty.
        for lin, (u, v) in self.dead.items():
            src[lin], dst[lin] = u, v
        return src, dst, w, valid


def product_graph(
    g: DynamicGraph | GraphSnapshot,
    nfa_delta: dict[int, list[tuple[int, int]]],
    num_states: int,
) -> tuple[int, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """RPQ product construction: vertex (v, q) with id ``v * num_states + q``.

    ``nfa_delta`` maps edge label → list of (q, q') NFA transitions.  Returns
    ``(num_product_vertices, src, dst, w, parent_edge_slot)`` COO arrays (one
    product edge per (graph edge, matching transition)).
    """
    live = np.nonzero(g.valid)[0]
    srcs, dsts, slots = [], [], []
    for e in live:
        for (q, q2) in nfa_delta.get(int(g.label[e]), ()):
            srcs.append(int(g.src[e]) * num_states + q)
            dsts.append(int(g.dst[e]) * num_states + q2)
            slots.append(int(e))
    n = g.num_vertices * num_states
    src = np.asarray(srcs, dtype=np.int32)
    dst = np.asarray(dsts, dtype=np.int32)
    w = np.ones(len(srcs), dtype=np.float32)
    return n, src, dst, w, np.asarray(slots, dtype=np.int32)
