"""Differential IFE engine — the paper's maintenance procedure, dense on TPU.

One engine serves every configuration in the paper:

* ``mode="vdc"``  — vanilla DC: the Join output ``J`` is materialized as a
  per-edge difference store (memory ∝ E, the paper's Table-1 bottleneck) and
  the aggregator reassembles messages *from that store*.
* ``mode="jod"``  — Join-On-Demand (§4): no J store; messages are recomputed
  from in-neighbour states on the fly (δE/δD direct rules + upper-bound rule
  realized as the dirty/frontier schedule below).
* ``drop.mode="det"|"prob"`` on top of JOD — partial dropping (§5) with
  deterministic or Bloom-filter DroppedVT and Random/Degree selection.

Timestamps are eager-merged (§4.2) so each (query, vertex) holds a 1-D sorted
list of (iteration, state) change points; negative multiplicities are implied
(DESIGN.md §2).

Maintenance is a bounded forward sweep over IFE iterations.  Per iteration i:

    cur        exact D_{i-1} for every vertex (repaired on the fly)
    sched_i    vertices whose aggregator must rerun: frontier (δD direct
               rule) ∪ dirty (δE direct rule + upper-bound rule: touched
               endpoints are rerun at every live iteration — spurious reruns
               are safe, Thm 4.1 corollary)
    repair_i   vertices whose change point at i was dropped → recompute to
               keep ``cur`` exact (AccessDᵢᵛWithDrops, forward form)
    changed_i  sched_i whose recomputed value differs from the pre-update
               trajectory → out-neighbours enter frontier_{i+1}

The sweep ends when the frontier is empty and i exceeds the stored horizon
(max change-point iteration), bounded by ``max_iters``.  Every step is pure
and fixed-shape → one ``lax.while_loop`` jits/lowers for the production mesh.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diffstore as ds
from repro.core import dropping as dr
from repro.core.graph import DynamicGraph, EllIndex, EllOverflow, GraphSnapshot
from repro.core.semiring import Semiring, reduce_pair
from repro.kernels.ell_spmv import ell_spmv

Array = jnp.ndarray


# --------------------------------------------------------------------------- graph arrays
class GraphArrays(NamedTuple):
    """Fixed-shape device view of the graph (COO + degrees).

    With ``backend="ell"`` the bucketed in-adjacency (``nbr``/``ell_w``,
    shape [V, D]) rides along for the Pallas SpMV; the COO arrays stay — the
    frontier push, the VDC join store and the δE dirty propagation are edge-
    indexed and keep using them.
    """

    src: Array  # int32 [E]
    dst: Array  # int32 [E]
    weight: Array  # f32 [E]
    valid: Array  # bool [E]
    out_degree: Array  # int32 [V]
    in_degree: Array  # int32 [V]
    nbr: Array | None = None  # int32 [V, D] in-neighbour ids (== V padding)
    ell_w: Array | None = None  # f32 [V, D] edge weights

    @property
    def num_vertices(self) -> int:
        return self.out_degree.shape[0]

    @property
    def ell_width(self) -> int:
        return 0 if self.nbr is None else int(self.nbr.shape[1])

    @classmethod
    def from_snapshot(
        cls, s: GraphSnapshot, *, backend: str = "coo", ell_min_width: int = 0
    ) -> "GraphArrays":
        nbr = ell_w = None
        if backend == "ell":
            nbr_np, w_np, _ = s.to_ell(min_width=ell_min_width)
            nbr, ell_w = jnp.asarray(nbr_np), jnp.asarray(w_np)
        return cls(
            src=jnp.asarray(s.src, jnp.int32),
            dst=jnp.asarray(s.dst, jnp.int32),
            weight=jnp.asarray(s.weight, jnp.float32),
            valid=jnp.asarray(s.valid),
            out_degree=jnp.asarray(s.out_degree, jnp.int32),
            in_degree=jnp.asarray(s.in_degree, jnp.int32),
            nbr=nbr,
            ell_w=ell_w,
        )


# --------------------------------------------------------------------------- config / state
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_queries: int
    num_vertices: int
    max_iters: int
    semiring: Semiring
    mode: str = "jod"  # "vdc" | "jod"
    store_capacity: int = 16  # S: change points per (q, v)
    jstore_capacity: int = 8  # S_J: per-edge change points (vdc only)
    drop: dr.DropConfig = dataclasses.field(default_factory=dr.DropConfig)
    # PageRank: edge weight is alpha / outdeg(src), recomputed from degrees so
    # deletions retune every sibling message (dirty mask covers them).
    weight_from_degree: bool = False
    alpha: float = 0.85
    # Aggregator backend: "coo" = masked segment-reduce over the edge list;
    # "ell" = the Pallas bucketed-ELL SpMV kernel (JOD only — the kernel *is*
    # the fused Join+Min; interpret-mode fallback runs it off-TPU).
    backend: str = "coo"
    ell_block_v: int = 128
    # None → interpret off-TPU, compiled Mosaic on TPU (kernels.ops default).
    interpret: bool | None = None

    def __post_init__(self):
        if self.mode not in ("vdc", "jod"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.mode == "vdc" and self.drop.enabled():
            raise ValueError("partial dropping composes with JOD only (paper §5)")
        if self.backend not in ("coo", "ell"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.backend == "ell" and self.mode != "jod":
            raise ValueError("backend='ell' realizes JOD; VDC reads the J store")


class EngineState(NamedTuple):
    dstore: ds.DiffStore  # [Q, V, S]
    jstore: ds.DiffStore | None  # [Q, E, S_J] (vdc only)
    drop: dr.DropState
    init: Array  # f32 [Q, V] — D_0 (implicit iteration-0 diffs)
    cur: Array  # f32 [Q, V] — exact values at the last swept iteration
    repair_counts: Array  # int32 [Q, V] — dropped-diff recomputations (Fig 6b)


class MaintainStats(NamedTuple):
    iters_run: Array  # int32
    scheduled: Array  # int32 — Σ|sched_i| (algorithmic work, vertex reruns)
    changed: Array  # int32 — Σ|changed_i| (δD differences produced)
    repairs: Array  # int32 — Σ|repair_i \ sched_i| (dropped diffs recomputed)
    written: Array  # int32 — change points upserted
    removed: Array  # int32 — change points deleted (cancelled +/- pairs)
    dropped: Array  # int32 — change points dropped instead of stored
    jwritten: Array  # int32 — J change points upserted (vdc)


def zeros_stats() -> MaintainStats:
    z = jnp.zeros((), jnp.int32)
    return MaintainStats(z, z, z, z, z, z, z, z)


# --------------------------------------------------------------------------- IFE primitives
def effective_weight(cfg: EngineConfig, g: GraphArrays) -> Array:
    if cfg.weight_from_degree:
        outd = jnp.maximum(g.out_degree[g.src], 1).astype(jnp.float32)
        return jnp.float32(cfg.alpha) / outd
    return g.weight


def edge_messages(cfg: EngineConfig, states: Array, g: GraphArrays) -> Array:
    """J from D: per-edge messages, identity on invalid slots. [Q, E]"""
    sr = cfg.semiring
    msgs = sr.msg(states[:, g.src], effective_weight(cfg, g)[None, :])
    return jnp.where(g.valid[None, :], msgs, sr.identity)


def aggregate(cfg: EngineConfig, msgs: Array, cur: Array, g: GraphArrays) -> Array:
    """D_i from J_i (+ carry of D_{i-1}): the Min/Sum operator. [Q, V]"""
    sr = cfg.semiring
    v = cfg.num_vertices
    if sr.reduce == "min":
        seg = jax.vmap(lambda m: jax.ops.segment_min(m, g.dst, num_segments=v))
    else:
        seg = jax.vmap(lambda m: jax.ops.segment_sum(m, g.dst, num_segments=v))
    agg = seg(msgs)
    if sr.carry_prev:
        return reduce_pair(sr, agg, cur)
    return jnp.float32(sr.base) + agg


def _ell_weights(cfg: EngineConfig, g: GraphArrays) -> Array:
    """ELL weight tile; degree-derived weights are re-gathered every step so
    a δE batch retunes every sibling message without rewriting [V, D] cells."""
    if cfg.weight_from_degree:
        outd = jnp.concatenate(
            [jnp.maximum(g.out_degree, 1), jnp.ones((1,), jnp.int32)]
        )  # index V (padding sentinel) → 1; its state is the identity 0 anyway
        return jnp.float32(cfg.alpha) / outd[g.nbr].astype(jnp.float32)
    return g.ell_w


def _interpret(cfg: EngineConfig) -> bool:
    if cfg.interpret is not None:
        return cfg.interpret
    return jax.default_backend() != "tpu"


def ell_step(cfg: EngineConfig, cur: Array, g: GraphArrays) -> Array:
    """One exact IFE step through the Pallas bucketed-ELL SpMV (JOD fused)."""
    sr = cfg.semiring
    q = cur.shape[0]
    states = jnp.concatenate(
        [cur, jnp.full((q, 1), sr.identity, cur.dtype)], axis=1
    )  # padding rows gather the reduce identity at index V
    carry = cur if sr.carry_prev else jnp.full_like(cur, sr.base)
    return ell_spmv(
        states,
        g.nbr,
        _ell_weights(cfg, g),
        carry,
        semiring=sr.kernel_name,
        block_v=cfg.ell_block_v,
        interpret=_interpret(cfg),
        hop_cap=sr.hop_cap,
    )


def ife_step(cfg: EngineConfig, cur: Array, g: GraphArrays) -> Array:
    """One exact IFE step D_{i-1} → D_i (join recomputed — the JOD path)."""
    if cfg.backend == "ell":
        return ell_step(cfg, cur, g)
    return aggregate(cfg, edge_messages(cfg, cur, g), cur, g)


def push_frontier(changed: Array, g: GraphArrays) -> Array:
    """Out-neighbour mask of changed vertices (δD direct rule). [Q, V]"""
    v = changed.shape[-1]
    hit = (changed[:, g.src] & g.valid[None, :]).astype(jnp.int32)
    out = jax.vmap(lambda h: jax.ops.segment_max(h, g.dst, num_segments=v))(hit)
    return out > 0


# --------------------------------------------------------------------------- maintenance
def make_state(cfg: EngineConfig, init: Array, num_edges: int) -> EngineState:
    q, v = cfg.num_queries, cfg.num_vertices
    assert init.shape == (q, v)
    jstore = (
        ds.make((q, num_edges), cfg.jstore_capacity) if cfg.mode == "vdc" else None
    )
    return EngineState(
        dstore=ds.make((q, v), cfg.store_capacity),
        jstore=jstore,
        drop=dr.make_state(cfg.drop, q, v),
        init=init.astype(jnp.float32),
        cur=init.astype(jnp.float32),
        repair_counts=jnp.zeros((q, v), jnp.int32),
    )


def stored_horizon(store: ds.DiffStore) -> Array:
    """Max change-point iteration present anywhere (the upper-bound frontier)."""
    live = jnp.where(store.iters < ds.IMAX, store.iters, -1)
    return live.max()


class _Carry(NamedTuple):
    i: Array
    cur: Array  # exact D_{i-1}
    cur_old: Array  # pre-update trajectory value at i-1 (store-lookup based)
    stale_old: Array  # bool [Q,V]: old trajectory obscured by a dropped diff
    frontier: Array  # bool [Q,V]: δD direct-rule schedule for iteration i
    changed_prev: Array  # bool [Q,V]: value changed at i-1 (feeds J updates)
    dstore: ds.DiffStore
    jstore: ds.DiffStore | None
    drop: dr.DropState
    repair_counts: Array
    horizon: Array  # int32 — running max change-point iteration (upper bound;
    # removals may leave it stale high, costing at most a few empty sweeps,
    # but avoids a full iters-store scan per iteration)
    stats: MaintainStats


def _sweep_body(
    cfg: EngineConfig,
    g: GraphArrays,
    dirty: Array,
    init: Array,
    old_dstore: ds.DiffStore,
    c: _Carry,
) -> _Carry:
    i = c.i
    q_ids = jnp.arange(cfg.num_queries, dtype=jnp.int32)[:, None]
    v_ids = jnp.arange(cfg.num_vertices, dtype=jnp.int32)[None, :]
    degree = (g.out_degree + g.in_degree)[None, :].astype(jnp.float32)

    # -- δE direct + upper-bound rules: dirty endpoints rerun at every live i.
    sched = c.frontier | dirty[None, :]

    # -- dropped change points at i must be recomputed to keep `cur` exact
    #    (AccessDᵢᵛWithDrops, forward form).  Prob-Drop may false-positive
    #    here → spurious but safe recompute.
    dropped_here = (
        dr.dropped_at(c.drop, i, cfg.num_vertices)
        if cfg.drop.enabled()
        else jnp.zeros_like(sched)
    )
    repair = dropped_here & ~sched

    # -- recompute D_i (dense; `sched|repair` is the algorithmic work mask).
    if cfg.mode == "vdc":
        # Maintain J at iteration i before reading it: an edge's message
        # changes when its source changed at i-1, or the edge itself (or a
        # sibling in-edge of its target) was touched by δE.
        live_msgs = edge_messages(cfg, c.cur, g)
        jprev, _, jfound = ds.lookup_le(c.jstore, i)
        j0 = edge_messages(cfg, init, g)  # implicit J from D_0
        jprev = jnp.where(jfound, jprev, j0)
        # NOTE: deliberately NOT masked by g.valid — a deleted edge must
        # overwrite its stored message with the identity.
        jdirty = c.changed_prev[:, g.src] | dirty[g.dst][None, :]
        jwrite = jdirty & (live_msgs != jprev)
        jstore, _, _ = ds.upsert(c.jstore, i, jwrite, live_msgs)
        # VDC path: the aggregator *reads* the materialized J difference sets.
        jval, _, jfound2 = ds.lookup_le(jstore, i)
        msgs = jnp.where(jfound2, jval, j0)
        new = aggregate(cfg, msgs, c.cur, g)
        jwritten = c.stats.jwritten + jwrite.sum(dtype=jnp.int32)
    else:
        jstore = c.jstore
        new = ife_step(cfg, c.cur, g)
        jwritten = c.stats.jwritten

    # -- pre-update trajectory at i (for δ detection), from the frozen store.
    old_has, old_val = ds.value_at(old_dstore, i)
    old_i = jnp.where(old_has, old_val, c.cur_old)
    # A dropped old change point leaves old_i stale until the next stored old
    # point re-anchors it; stale scheduled vertices propagate conservatively.
    stale = (c.stale_old | dropped_here) & ~old_has

    changed = sched & ((new != old_i) | stale)

    # -- new trajectory change point at i?  (vs exact D_{i-1} = cur)
    want_point = sched & (new != c.cur)
    has_cur, cur_stored_val = ds.value_at(c.dstore, i)

    if cfg.drop.enabled():
        to_drop = want_point & dr.select_to_drop(cfg.drop, degree, q_ids, v_ids, i)
        to_store = want_point & ~to_drop
    else:
        to_drop = jnp.zeros_like(want_point)
        to_store = want_point

    dstore, evicted, evicted_iter = ds.upsert(c.dstore, i, to_store, new)
    # one fused removal pass (each full remove_at rewrites the store):
    #   · a dropped point at i that had a stored twin loses the twin
    #   · a vanished change point (+/- pair cancelled) is deleted
    vanish = sched & ~want_point & has_cur
    dstore = ds.remove_at(dstore, i, (to_drop & has_cur) | vanish)

    drop_state = c.drop
    if cfg.drop.enabled():
        drop_state = dr.register(drop_state, i, to_drop)
        drop_state = dr.register(drop_state, evicted_iter, evicted)
        # a dropped record is stale once the point is stored or vanished
        drop_state = dr.unregister(drop_state, i, to_store | vanish)

    # -- advance exact/old trajectories, schedule next iteration.
    recompute = sched | repair
    cur_next = jnp.where(
        recompute, new, jnp.where(has_cur, cur_stored_val, c.cur)
    )
    frontier_next = push_frontier(changed, g) | changed  # carry: own next value

    stats = MaintainStats(
        iters_run=c.stats.iters_run + 1,
        scheduled=c.stats.scheduled + sched.sum(dtype=jnp.int32),
        changed=c.stats.changed + changed.sum(dtype=jnp.int32),
        repairs=c.stats.repairs + repair.sum(dtype=jnp.int32),
        written=c.stats.written + to_store.sum(dtype=jnp.int32),
        removed=c.stats.removed + vanish.sum(dtype=jnp.int32),
        dropped=c.stats.dropped + to_drop.sum(dtype=jnp.int32),
        jwritten=jwritten,
    )
    horizon = jnp.where(to_store.any(), jnp.maximum(c.horizon, i), c.horizon)
    return _Carry(
        i=i + 1,
        cur=cur_next,
        cur_old=old_i,
        stale_old=stale,
        frontier=frontier_next,
        changed_prev=changed,
        dstore=dstore,
        jstore=jstore,
        drop=drop_state,
        repair_counts=c.repair_counts + repair.astype(jnp.int32),
        horizon=horizon,
        stats=stats,
    )


def maintain(
    cfg: EngineConfig,
    state: EngineState,
    g: GraphArrays,
    dirty: Array,
) -> tuple[EngineState, MaintainStats]:
    """One maintenance sweep after a δE batch (or initial computation).

    ``dirty`` is the bool [V] mask of vertices whose in-edge set (or, for
    degree-derived weights, whose incoming message weights) changed.  For the
    initial computation pass ``dirty = ones`` with an empty store — the sweep
    then *is* the static IFE run, recording change points as it goes.
    """
    old_dstore = state.dstore  # frozen pre-maintenance snapshot (functional)

    def body(c: _Carry) -> _Carry:
        return _sweep_body(cfg, g, dirty, state.init, old_dstore, c)

    def cond(c: _Carry) -> Array:
        # Continue while work is scheduled (frontier/dirty) AND the sweep can
        # still mutate the store.  Mutations happen only at i ≤ horizon+1:
        # an in-neighbour change point at j feeds a consumer at j+1 (upper
        # bound rule), and fresh writes at i extend the horizon to ≥ i, so a
        # still-converging new trajectory keeps the loop alive while a
        # permanently-diverged-from-old frontier (no mutations) drains at
        # horizon+1 instead of max_iters.  i==1 always runs when anything is
        # dirty (δE direct rule).  The horizon rides the carry (one store
        # scan per maintain, not per iteration).
        live = c.frontier.any() | dirty.any()
        horizon = c.horizon
        if cfg.drop.enabled():
            # dropped change points still anchor the upper-bound rule (and
            # must be swept past so `cur` picks up their repaired values)
            horizon = jnp.maximum(horizon, c.drop.max_iter)
        return (
            (c.i <= jnp.int32(cfg.max_iters))
            & live
            & ((c.i == 1) | (c.i <= horizon + 1))
        )

    c0 = _Carry(
        i=jnp.int32(1),
        cur=state.init,
        cur_old=state.init,
        stale_old=jnp.zeros((cfg.num_queries, cfg.num_vertices), bool),
        frontier=jnp.zeros((cfg.num_queries, cfg.num_vertices), bool),
        changed_prev=jnp.zeros((cfg.num_queries, cfg.num_vertices), bool),
        dstore=state.dstore,
        jstore=state.jstore,
        drop=state.drop,
        repair_counts=state.repair_counts,
        horizon=stored_horizon(state.dstore),
        stats=zeros_stats(),
    )
    c = jax.lax.while_loop(cond, body, c0)
    new_state = EngineState(
        dstore=c.dstore,
        jstore=c.jstore,
        drop=c.drop,
        init=state.init,
        cur=c.cur,
        repair_counts=c.repair_counts,
    )
    return new_state, c.stats


def reassemble(
    cfg: EngineConfig, state: EngineState, g: GraphArrays, upto: int | None = None
) -> Array:
    """Repair-aware reassembly of D at iteration ``upto`` (paper's Access).

    Bounded forward repair: walk iterations 1..upto; stored points are exact,
    dropped points are recomputed from the exact previous front.  Cost is
    O(upto × E) dense, but only dropped lanes represent algorithmic work.
    """
    upto = cfg.max_iters if upto is None else upto

    def body(i, cur):
        has, val = ds.value_at(state.dstore, i)
        if cfg.drop.enabled():
            dropped = dr.dropped_at(state.drop, i, cfg.num_vertices)
            new = ife_step(cfg, cur, g)
            return jnp.where(has, val, jnp.where(dropped, new, cur))
        return jnp.where(has, val, cur)

    return jax.lax.fori_loop(1, upto + 1, body, state.init)


def answers(cfg: EngineConfig, state: EngineState) -> Array:
    """Final vertex states after the last maintenance sweep. [Q, V]"""
    return state.cur


# --------------------------------------------------------------------------- memory accounting
def nbytes_accounted(cfg: EngineConfig, state: EngineState) -> int:
    """Difference-entry bytes, the paper's memory metric (8 B per diff:
    4 B iteration + 4 B state; DroppedVT per §5.1 costings)."""
    total = int(state.dstore.count.sum()) * 8
    if state.jstore is not None:
        total += int(state.jstore.count.sum()) * 8
    if cfg.drop.enabled():
        total += int(state.drop.nbytes_accounted())
    return total


# --------------------------------------------------------------------------- batched updates
class UpdateBatch(NamedTuple):
    """Fixed-shape device encoding of ≤ B resolved edge updates.

    One row per touched edge slot, holding the slot's *final* contents after
    the whole chunk (the host coalesces, so duplicate-index scatter order
    never matters).  Padding rows carry out-of-range indices — slot == E_cap,
    vertex == V, ell_row == V — and are dropped by the scatters / sliced off
    the dirty mask.  The shape ``[B]`` is the jit cache key: every chunk of a
    long update log reuses one compiled program.
    """

    slot: Array  # int32 [B] — edge slot; E_cap padding
    src: Array  # int32 [B] — final slot source
    dst: Array  # int32 [B] — final slot destination
    weight: Array  # f32  [B] — final slot weight
    valid: Array  # bool [B] — final slot validity
    dirty_v: Array  # int32 [B] — endpoint to dirty (δE direct rule); V padding
    touched_src: Array  # int32 [B] — update source (degree-retune rule); V padding
    ell_row: Array  # int32 [B] — ELL cell writes (backend="ell"); V padding
    ell_col: Array  # int32 [B]
    ell_nbr: Array  # int32 [B]
    ell_w: Array  # f32  [B]


def batched_step(
    cfg: EngineConfig, state: EngineState, g: GraphArrays, upd: UpdateBatch
) -> tuple[EngineState, GraphArrays, MaintainStats]:
    """Fold one δE chunk into the graph arrays and run ONE maintenance sweep.

    This is the device-side twin of ``DiffIFE.apply_updates``: edge scatter,
    degree refresh, dirty-mask construction and the ``lax.while_loop`` sweep
    compile into a single program.  ``DiffIFE`` jits it with donated
    ``(state, g)`` so the stores update in place (no per-update host round
    trip, no buffer churn); host work per chunk is an O(B) encode.
    """
    v = cfg.num_vertices
    src = g.src.at[upd.slot].set(upd.src, mode="drop")
    dst = g.dst.at[upd.slot].set(upd.dst, mode="drop")
    weight = g.weight.at[upd.slot].set(upd.weight, mode="drop")
    valid = g.valid.at[upd.slot].set(upd.valid, mode="drop")
    # degrees recomputed from the edge list — O(E) on-device, far below one
    # sweep iteration, and immune to host/device drift
    live = valid.astype(jnp.int32)
    out_degree = jax.ops.segment_sum(live, src, num_segments=v)
    in_degree = jax.ops.segment_sum(live, dst, num_segments=v)
    nbr, ell_w = g.nbr, g.ell_w
    if cfg.backend == "ell":
        nbr = nbr.at[upd.ell_row, upd.ell_col].set(upd.ell_nbr, mode="drop")
        ell_w = ell_w.at[upd.ell_row, upd.ell_col].set(upd.ell_w, mode="drop")
    g2 = GraphArrays(src, dst, weight, valid, out_degree, in_degree, nbr, ell_w)

    dirty = jnp.zeros(v + 1, bool).at[upd.dirty_v].set(True)[:v]
    if cfg.weight_from_degree:
        # outdeg(u) changed → every out-message of u retunes (δE dirty rule)
        tsrc = jnp.zeros(v + 1, bool).at[upd.touched_src].set(True)[:v]
        hit = (tsrc[g2.src] & g2.valid).astype(jnp.int32)
        dirty = dirty | (jax.ops.segment_max(hit, g2.dst, num_segments=v) > 0)

    new_state, stats = maintain(cfg, state, g2, dirty)
    return new_state, g2, stats


def _sum_stats(a: MaintainStats, b: MaintainStats) -> MaintainStats:
    return MaintainStats(*(x + y for x, y in zip(a, b)))


# --------------------------------------------------------------------------- host-facing wrapper
class DiffIFE:
    """Continuous-query processor: owns the dynamic graph + engine state.

    ``DiffIFE`` is the host driver (the GDBMS's continuous query processor);
    all device work happens in the pure functions above, jitted per graph
    capacity so update batches never recompile.

    Two ingestion paths:

    * :meth:`apply_updates` — per-batch host path: mutate the host graph,
      re-upload the device view, run one sweep.  Simple, but each batch pays
      a host round trip + full graph transfer.
    * :meth:`apply_updates_batched` — the throughput path: updates are folded
      in fixed-shape chunks of ``batch_capacity`` through the donated-buffer
      :func:`batched_step`, so the jit cache is hit once per chunk and the
      graph/stores never leave the device.

    With ``cfg.backend == "ell"`` the bucketed in-adjacency rides along; its
    width ``D`` is kept fixed across updates (host :class:`EllIndex` mirror)
    and grows geometrically — with a one-off re-trace — only when a vertex's
    in-degree outruns it.
    """

    def __init__(
        self,
        cfg: EngineConfig,
        graph: DynamicGraph,
        init: np.ndarray | Array,
        *,
        batch_capacity: int = 32,
    ) -> None:
        self.cfg = cfg
        self.graph = graph
        self.batch_capacity = int(batch_capacity)
        self._ell_width = 0
        self._ell_index: EllIndex | None = None
        self.g = self._device_graph(graph.snapshot())
        self.state = make_state(cfg, jnp.asarray(init, jnp.float32), graph.capacity)
        self._maintain = jax.jit(partial(maintain, cfg))
        self._step = jax.jit(partial(batched_step, cfg), donate_argnums=(0, 1))
        self.last_stats: MaintainStats | None = None
        # initial computation: every vertex dirty, empty store
        self._run(np.ones(cfg.num_vertices, dtype=bool))

    # ------------------------------------------------------------ device views
    def _device_graph(self, snap: GraphSnapshot) -> GraphArrays:
        if self.cfg.backend == "ell":
            g = GraphArrays.from_snapshot(
                snap, backend="ell", ell_min_width=self._ell_width
            )
            self._ell_width = g.ell_width
            self._ell_index = EllIndex(snap, self._ell_width)
            return g
        return GraphArrays.from_snapshot(snap)

    def _run(self, dirty: np.ndarray) -> None:
        self.state, stats = self._maintain(self.state, self.g, jnp.asarray(dirty))
        self.last_stats = jax.tree.map(jax.device_get, stats)

    def _dirty_mask(self, touched, snap: GraphSnapshot) -> np.ndarray:
        dirty = np.zeros(self.cfg.num_vertices, dtype=bool)
        for (u, v) in touched:
            dirty[v] = True
            if self.cfg.weight_from_degree:
                # outdeg(src) changed → every out-message of src retunes
                dirty[snap.dst[(snap.src == u) & snap.valid]] = True
        return dirty

    # ------------------------------------------------------------- ingestion
    def apply_updates(self, updates) -> MaintainStats:
        """Ingest one δE batch and maintain all registered queries."""
        touched = self.graph.apply_batch(updates)
        snap = self.graph.snapshot()
        self.g = self._device_graph(snap)
        self._run(self._dirty_mask(touched, snap))
        return self.last_stats

    def apply_updates_batched(
        self, updates, batch_size: int | None = None
    ) -> MaintainStats:
        """Stream a δE log through the donated-buffer batched step.

        The log is folded in fixed-shape chunks of ``batch_size`` (default:
        ``batch_capacity``); per chunk ONE jitted call scatters the edge
        slots, refreshes degrees, builds the dirty mask on device and runs
        the maintenance sweep.  Returns the cumulative stats over the log.
        """
        b = int(batch_size if batch_size is not None else self.batch_capacity)
        updates = list(updates)
        total = zeros_stats()
        for lo in range(0, len(updates), b):
            ops = self.graph.apply_batch_resolved(updates[lo : lo + b])
            if not ops:
                continue
            ell_writes: list = []
            if self.cfg.backend == "ell":
                try:
                    ell_writes = self._ell_index.writes_for(ops)
                except EllOverflow:
                    # a vertex outran the fixed D: grow geometrically and fall
                    # back to a full-view sweep for this chunk (one re-trace)
                    self._ell_width = max(8, self._ell_width * 2)
                    snap = self.graph.snapshot()
                    self.g = self._device_graph(snap)
                    touched = [(u, v) for (_k, _s, u, v, _w) in ops]
                    self._run(self._dirty_mask(touched, snap))
                    total = _sum_stats(total, self.last_stats)
                    continue
            upd = self._encode_chunk(ops, ell_writes, b)
            self.state, self.g, stats = self._step(self.state, self.g, upd)
            # accumulate on device — one host sync per log, not per chunk
            total = _sum_stats(total, stats)
        self.last_stats = jax.tree.map(jax.device_get, total)
        return self.last_stats

    def _encode_chunk(self, ops, ell_writes, b: int) -> UpdateBatch:
        """Host O(B) encode of resolved ops → fixed-shape UpdateBatch."""
        if len(ops) > b:
            raise ValueError(f"chunk of {len(ops)} ops exceeds capacity {b}")
        cap, v = self.graph.capacity, self.cfg.num_vertices
        slot = np.full(b, cap, np.int32)
        src = np.zeros(b, np.int32)
        dst = np.zeros(b, np.int32)
        weight = np.zeros(b, np.float32)
        valid = np.zeros(b, bool)
        dirty_v = np.full(b, v, np.int32)
        touched_src = np.full(b, v, np.int32)
        ell_row = np.full(b, v, np.int32)
        ell_col = np.zeros(b, np.int32)
        ell_nbr = np.zeros(b, np.int32)
        ell_wv = np.zeros(b, np.float32)
        # final slot contents come from the already-updated host graph, so a
        # delete+reinsert of one slot inside a chunk coalesces to one row
        for j, s in enumerate(dict.fromkeys(op[1] for op in ops)):
            slot[j] = s
            src[j] = self.graph.src[s]
            dst[j] = self.graph.dst[s]
            weight[j] = self.graph.weight[s]
            valid[j] = self.graph.valid[s]
        for j, (_kind, _s, u, d, _w) in enumerate(ops):
            dirty_v[j] = d
            touched_src[j] = u
        for j, wr in enumerate(ell_writes):
            ell_row[j], ell_col[j] = wr.row, wr.col
            ell_nbr[j], ell_wv[j] = wr.nbr_val, wr.w_val
        return UpdateBatch(
            slot=jnp.asarray(slot),
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            weight=jnp.asarray(weight),
            valid=jnp.asarray(valid),
            dirty_v=jnp.asarray(dirty_v),
            touched_src=jnp.asarray(touched_src),
            ell_row=jnp.asarray(ell_row),
            ell_col=jnp.asarray(ell_col),
            ell_nbr=jnp.asarray(ell_nbr),
            ell_w=jnp.asarray(ell_wv),
        )

    # ------------------------------------------------------------------- api
    def answers(self) -> np.ndarray:
        return np.asarray(answers(self.cfg, self.state))

    def nbytes(self) -> int:
        return nbytes_accounted(self.cfg, self.state)
