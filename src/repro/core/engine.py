"""Differential IFE engine — the paper's maintenance procedure, dense on TPU.

One engine serves every configuration in the paper:

* ``mode="vdc"``  — vanilla DC: the Join output ``J`` is materialized as a
  per-edge difference store (memory ∝ E, the paper's Table-1 bottleneck) and
  the aggregator reassembles messages *from that store*.
* ``mode="jod"``  — Join-On-Demand (§4): no J store; messages are recomputed
  from in-neighbour states on the fly (δE/δD direct rules + upper-bound rule
  realized as the dirty/frontier schedule below).
* ``drop.mode="det"|"prob"`` on top of JOD — partial dropping (§5) with
  deterministic or Bloom-filter DroppedVT and Random/Degree selection.

Timestamps are eager-merged (§4.2) so each (query, vertex) holds a 1-D sorted
list of (iteration, state) change points; negative multiplicities are implied
(DESIGN.md §2).

Maintenance is a bounded forward sweep over IFE iterations.  Per iteration i:

    cur        exact D_{i-1} for every vertex (repaired on the fly)
    sched_i    vertices whose aggregator must rerun: frontier (δD direct
               rule) ∪ dirty (δE direct rule + upper-bound rule: touched
               endpoints are rerun at every live iteration — spurious reruns
               are safe, Thm 4.1 corollary)
    repair_i   vertices whose change point at i was dropped → recompute to
               keep ``cur`` exact (AccessDᵢᵛWithDrops, forward form)
    changed_i  sched_i whose recomputed value differs from the pre-update
               trajectory → out-neighbours enter frontier_{i+1}

The sweep ends when the frontier is empty and i exceeds the stored horizon
(max change-point iteration), bounded by ``max_iters``.  Every step is pure
and fixed-shape → one ``lax.while_loop`` jits/lowers for the production mesh.

**Vertex-sharded sweep** (DESIGN.md §8): every per-vertex carry — diff-store
rows, DroppedVT/Bloom state, frontier/dirty masks, repair counts — partitions
by destination vertex over the mesh ``data`` axis (``maintain_sharded`` /
``batched_step_sharded`` run the same ``_sweep_body`` under ``shard_map``).
Cross-shard edges are handled by all-gathering the O(V) exact front ``cur``
once per iteration: messages are formed shard-locally against the gathered
row, so the COO segment-reduce and the ELL kernel both run unchanged on
their local partition, and the termination check becomes a ``psum``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import bloom as bloom_lib
from repro.core import diffstore as ds
from repro.core import dropping as dr
from repro.core.graph import (
    DynamicGraph,
    EllIndex,
    EllOverflow,
    GraphSnapshot,
    ShardIndex,
    ShardOverflow,
)
from repro.core.semiring import Semiring, reduce_pair
from repro.kernels.ell_spmv import ell_spmv
from repro.obs import trace as obs_trace

# module (not name) import: kernels/fused_sweep.py imports repro.core for the
# diff-store/dropping primitives it runs in-kernel, so importing the *name*
# here would complete the cycle before the function exists
from repro.kernels import fused_sweep as fused_sweep_lib

Array = jnp.ndarray

# Mesh axis the sweep shards over (vertex partition).  The ``model`` axis is
# reserved for a future Q-axis model-parallel split.
DATA_AXIS = "data"


# --------------------------------------------------------------------------- graph arrays
class GraphArrays(NamedTuple):
    """Fixed-shape device view of the graph (COO + degrees).

    With ``backend="ell"`` the bucketed in-adjacency (``nbr``/``ell_w``,
    shape [V, D]) rides along for the Pallas SpMV; the COO arrays stay — the
    frontier push, the VDC join store and the δE dirty propagation are edge-
    indexed and keep using them.
    """

    src: Array  # int32 [E]
    dst: Array  # int32 [E]
    weight: Array  # f32 [E]
    valid: Array  # bool [E]
    out_degree: Array  # int32 [V]
    in_degree: Array  # int32 [V]
    nbr: Array | None = None  # int32 [V, D] in-neighbour ids (== V padding)
    ell_w: Array | None = None  # f32 [V, D] edge weights

    @property
    def num_vertices(self) -> int:
        return self.out_degree.shape[0]

    @property
    def ell_width(self) -> int:
        return 0 if self.nbr is None else int(self.nbr.shape[1])

    @classmethod
    def from_snapshot(
        cls, s: GraphSnapshot, *, backend: str = "coo", ell_min_width: int = 0
    ) -> "GraphArrays":
        nbr = ell_w = None
        if backend in ("ell", "fused"):
            nbr_np, w_np, _ = s.to_ell(min_width=ell_min_width)
            nbr, ell_w = jnp.asarray(nbr_np), jnp.asarray(w_np)
        return cls(
            src=jnp.asarray(s.src, jnp.int32),
            dst=jnp.asarray(s.dst, jnp.int32),
            weight=jnp.asarray(s.weight, jnp.float32),
            valid=jnp.asarray(s.valid),
            out_degree=jnp.asarray(s.out_degree, jnp.int32),
            in_degree=jnp.asarray(s.in_degree, jnp.int32),
            nbr=nbr,
            ell_w=ell_w,
        )


# --------------------------------------------------------------------------- config / state
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_queries: int
    num_vertices: int
    max_iters: int
    semiring: Semiring
    mode: str = "jod"  # "vdc" | "jod"
    store_capacity: int = 16  # S: change points per (q, v)
    jstore_capacity: int = 8  # S_J: per-edge change points (vdc only)
    drop: dr.DropConfig = dataclasses.field(default_factory=dr.DropConfig)
    # PageRank: edge weight is alpha / outdeg(src), recomputed from degrees so
    # deletions retune every sibling message (dirty mask covers them).
    weight_from_degree: bool = False
    alpha: float = 0.85
    # Aggregator backend: "coo" = masked segment-reduce over the edge list;
    # "ell" = the Pallas bucketed-ELL SpMV kernel (JOD only — the kernel *is*
    # the fused Join+Min; interpret-mode fallback runs it off-TPU);
    # "fused" = the maintenance megakernel (kernels/fused_sweep.py): ONE
    # pallas_call per sweep iteration fuses expand + diff-store append +
    # DroppedVT probe/update (JOD fully in-kernel; VDC keeps its J-store
    # maintenance in XLA and fuses the per-vertex store phase).
    backend: str = "coo"
    ell_block_v: int = 128
    # None → interpret off-TPU, compiled Mosaic on TPU (kernels.ops default).
    interpret: bool | None = None

    def __post_init__(self):
        if self.mode not in ("vdc", "jod"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.backend not in ("coo", "ell", "fused"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.backend == "ell" and self.mode != "jod":
            raise ValueError("backend='ell' realizes JOD; VDC reads the J store")


class EngineState(NamedTuple):
    dstore: ds.DiffStore  # [Q, V, S] — the Iterate operator's difference store
    jstore: ds.DiffStore | None  # [Q, E, S_J] — the Join operator's store (vdc)
    drop: dr.DropState
    init: Array  # f32 [Q, V] — D_0 (implicit iteration-0 diffs)
    cur: Array  # f32 [Q, V] — exact values at the last swept iteration
    repair_counts: Array  # int32 [Q, V] — dropped-diff recomputations (Fig 6b)
    active: Array  # bool [Q] — live query slots; inactive slots are scheduled
    # for no work and hold no diffs (the session's padded slot pool)
    join_mat: Array | None = None  # bool [Q] — per-slot Join materialization
    # (vdc engines only): False = that slot's join differences are dropped
    # completely and its messages recompute on demand (JOD, §4) even though
    # the engine carries a J store for its neighbours


# Per-iteration probe depth: sweep iterations beyond this fold into the last
# bin.  Bounded so the stats pytree keeps a fixed shape inside the while_loop
# (one jit cache entry) and the host-side export stays O(1) per sweep.
ITER_TRACE = 32


class MaintainStats(NamedTuple):
    iters_run: Array  # int32
    scheduled: Array  # int32 — Σ|sched_i| (algorithmic work, vertex reruns)
    changed: Array  # int32 — Σ|changed_i| (δD differences produced)
    repairs: Array  # int32 — Σ|repair_i \ sched_i| (dropped diffs recomputed)
    written: Array  # int32 — change points upserted
    removed: Array  # int32 — change points deleted (cancelled +/- pairs)
    dropped: Array  # int32 — change points dropped instead of stored
    jwritten: Array  # int32 — J change points upserted (vdc)
    det_overflow: Array  # int32 — dropped VT records lost to Det-Drop store
    # evictions THIS sweep: each one is a (v, i) the engine can no longer
    # repair on access, so a nonzero value flags answers at risk of staleness
    sched_sizes: Array  # int32 [ITER_TRACE] — |sched_i| per iteration
    frontier_sizes: Array  # int32 [ITER_TRACE] — |frontier_{i+1}| per iteration

    SCALAR_FIELDS = (
        "iters_run", "scheduled", "changed", "repairs", "written",
        "removed", "dropped", "jwritten", "det_overflow",
    )
    VECTOR_FIELDS = ("sched_sizes", "frontier_sizes")


def zeros_stats() -> MaintainStats:
    z = jnp.zeros((), jnp.int32)
    t = jnp.zeros((ITER_TRACE,), jnp.int32)
    return MaintainStats(z, z, z, z, z, z, z, z, z, t, t)


# --------------------------------------------------------------------------- IFE primitives
def effective_weight(cfg: EngineConfig, g: GraphArrays) -> Array:
    if cfg.weight_from_degree:
        outd = jnp.maximum(g.out_degree[g.src], 1).astype(jnp.float32)
        return jnp.float32(cfg.alpha) / outd
    return g.weight


def edge_messages(cfg: EngineConfig, states: Array, g: GraphArrays) -> Array:
    """J from D: per-edge messages, identity on invalid slots. [Q, E]"""
    sr = cfg.semiring
    msgs = sr.msg(states[:, g.src], effective_weight(cfg, g)[None, :])
    return jnp.where(g.valid[None, :], msgs, sr.identity)


def aggregate(
    cfg: EngineConfig,
    msgs: Array,
    cur: Array,
    g: GraphArrays,
    *,
    dst: Array | None = None,
    num_segments: int | None = None,
) -> Array:
    """D_i from J_i (+ carry of D_{i-1}): the Min/Sum operator. [Q, V]

    The sharded sweep passes shard-local destination ids and segment count;
    out-of-range ids (foreign/padding edges) are dropped by the segment op.
    """
    sr = cfg.semiring
    dst = g.dst if dst is None else dst
    v = cfg.num_vertices if num_segments is None else num_segments
    if sr.reduce == "min":
        seg = jax.vmap(lambda m: jax.ops.segment_min(m, dst, num_segments=v))
    else:
        seg = jax.vmap(lambda m: jax.ops.segment_sum(m, dst, num_segments=v))
    agg = seg(msgs)
    if sr.carry_prev:
        return reduce_pair(sr, agg, cur)
    return jnp.float32(sr.base) + agg


def _ell_weights(cfg: EngineConfig, g: GraphArrays) -> Array:
    """ELL weight tile; degree-derived weights are re-gathered every step so
    a δE batch retunes every sibling message without rewriting [V, D] cells."""
    if cfg.weight_from_degree:
        outd = jnp.concatenate(
            [jnp.maximum(g.out_degree, 1), jnp.ones((1,), jnp.int32)]
        )  # index V (padding sentinel) → 1; its state is the identity 0 anyway
        return jnp.float32(cfg.alpha) / outd[g.nbr].astype(jnp.float32)
    return g.ell_w


def _interpret(cfg: EngineConfig) -> bool:
    if cfg.interpret is not None:
        return cfg.interpret
    return jax.default_backend() != "tpu"


def ell_step(
    cfg: EngineConfig, cur: Array, g: GraphArrays, *, carry: Array | None = None
) -> Array:
    """One exact IFE step through the Pallas bucketed-ELL SpMV (JOD fused).

    ``cur`` is the full state row the kernel gathers from; ``carry`` (default
    ``cur``) is the shard-local slice matching ``g.nbr``'s rows.
    """
    sr = cfg.semiring
    q = cur.shape[0]
    loc = cur if carry is None else carry
    states = jnp.concatenate(
        [cur, jnp.full((q, 1), sr.identity, cur.dtype)], axis=1
    )  # padding cells gather the reduce identity at the sentinel index
    kcarry = loc if sr.carry_prev else jnp.full_like(loc, sr.base)
    return ell_spmv(
        states,
        g.nbr,
        _ell_weights(cfg, g),
        kcarry,
        semiring=sr.kernel_name,
        block_v=cfg.ell_block_v,
        interpret=_interpret(cfg),
        hop_cap=sr.hop_cap,
    )


def ife_step(
    cfg: EngineConfig,
    cur: Array,
    g: GraphArrays,
    *,
    carry: Array | None = None,
    dst: Array | None = None,
    num_segments: int | None = None,
) -> Array:
    """One exact IFE step D_{i-1} → D_i (join recomputed — the JOD path).

    ``cur`` is the full [Q, V] front; under the sharded sweep the optional
    ``carry``/``dst``/``num_segments`` restrict the output to the local
    vertex partition.
    """
    if cfg.backend in ("ell", "fused"):
        # the fused backend carries the same blocked-ELL adjacency; the
        # standalone expand (reassembly/repair paths) is bit-identical to
        # the megakernel's in-kernel tile (shared ``expand_tile``)
        return ell_step(cfg, cur, g, carry=carry)
    return aggregate(
        cfg,
        edge_messages(cfg, cur, g),
        cur if carry is None else carry,
        g,
        dst=dst,
        num_segments=num_segments,
    )


def push_frontier(
    changed: Array,
    g: GraphArrays,
    *,
    dst: Array | None = None,
    num_segments: int | None = None,
) -> Array:
    """Out-neighbour mask of changed vertices (δD direct rule).

    ``changed`` spans the full vertex axis (sources are global); the output
    covers ``num_segments`` destinations (the local partition when sharded).
    """
    dst = g.dst if dst is None else dst
    v = changed.shape[-1] if num_segments is None else num_segments
    hit = (changed[:, g.src] & g.valid[None, :]).astype(jnp.int32)
    out = jax.vmap(lambda h: jax.ops.segment_max(h, dst, num_segments=v))(hit)
    return out > 0


def _local_dst(dst: Array, off: Array, num_local: int) -> Array:
    """Map global destination ids to the local partition; foreign/padding
    ids collapse to ``num_local`` (out of range → dropped by segment ops)."""
    dl = dst - off
    return jnp.where((dl >= 0) & (dl < num_local), dl, num_local)


# --------------------------------------------------------------------------- maintenance
def make_state(
    cfg: EngineConfig,
    init: Array,
    num_edges: int,
    *,
    active: Array | None = None,
    drop_rows: list[dr.DropConfig] | None = None,
    join_rows: list[bool] | None = None,
) -> EngineState:
    """Engine state for ``cfg.num_queries`` slots.

    ``active`` marks the live slots (default: all); ``drop_rows`` supplies
    each slot's selection parameters (default: ``cfg.drop`` broadcast);
    ``join_rows`` each slot's Join materialization flag (vdc engines only;
    default: every slot materializes — the legacy uniform VDC).
    """
    q, v = cfg.num_queries, cfg.num_vertices
    assert init.shape == (q, v)
    jstore = (
        ds.make((q, num_edges), cfg.jstore_capacity) if cfg.mode == "vdc" else None
    )
    join_mat = None
    if jstore is not None:
        join_mat = (
            jnp.ones((q,), bool)
            if join_rows is None
            else jnp.asarray(join_rows, bool)
        )
    return EngineState(
        dstore=ds.make((q, v), cfg.store_capacity),
        jstore=jstore,
        drop=dr.make_state(cfg.drop, q, v, per_query=drop_rows),
        init=init.astype(jnp.float32),
        cur=init.astype(jnp.float32),
        repair_counts=jnp.zeros((q, v), jnp.int32),
        active=jnp.ones((q,), bool) if active is None else jnp.asarray(active, bool),
        join_mat=join_mat,
    )


def stored_horizon(store: ds.DiffStore) -> Array:
    """Max change-point iteration present anywhere (the upper-bound frontier)."""
    live = jnp.where(store.iters < ds.IMAX, store.iters, -1)
    return live.max()


class _Carry(NamedTuple):
    i: Array
    cur: Array  # exact D_{i-1} (local partition when sharded)
    cur_old: Array  # pre-update trajectory value at i-1 (store-lookup based)
    stale_old: Array  # bool [Q,V]: old trajectory obscured by a dropped diff
    frontier: Array  # bool [Q,V]: δD direct-rule schedule for iteration i
    changed_prev: Array  # bool [Q,V]: value changed at i-1 (feeds J updates;
    # sharded VDC carries it FULL-width — the gather from the previous
    # iteration's frontier push is reused instead of re-gathered)
    dstore: ds.DiffStore
    jstore: ds.DiffStore | None
    drop: dr.DropState
    repair_counts: Array
    horizon: Array  # int32 — running max change-point iteration (upper bound;
    # removals may leave it stale high, costing at most a few empty sweeps,
    # but avoids a full iters-store scan per iteration)
    live: Array  # bool — work remains (frontier ∪ dirty nonempty, globally);
    # precomputed in the body so the sharded cond stays collective-free
    stats: MaintainStats


def _sweep_body(
    cfg: EngineConfig,
    g: GraphArrays,
    dirty: Array,
    init: Array,
    old_dstore: ds.DiffStore,
    active: Array,
    join_mat: Array | None,
    axis: str | None,
    c: _Carry,
) -> _Carry:
    i = c.i
    num_local = c.cur.shape[-1]  # V, or V/n under shard_map
    q_ids = jnp.arange(cfg.num_queries, dtype=jnp.int32)[:, None]
    if axis is None:
        off = jnp.int32(0)
        cur_full = c.cur  # the exact front IS the full row
        dst = g.dst
        outd_local = g.out_degree
    else:
        off = jax.lax.axis_index(axis).astype(jnp.int32) * num_local
        # the one O(V) exchange per iteration: the exact front, gathered so
        # cross-shard edges form their messages against remote sources
        cur_full = jax.lax.all_gather(c.cur, axis, axis=1, tiled=True)
        dst = _local_dst(g.dst, off, num_local)
        outd_local = jax.lax.dynamic_slice_in_dim(g.out_degree, off, num_local)
    v_ids = off + jnp.arange(num_local, dtype=jnp.int32)[None, :]
    degree = (outd_local + g.in_degree)[None, :].astype(jnp.float32)

    # -- δE direct + upper-bound rules: dirty endpoints rerun at every live i.
    #    ``dirty`` is per-query [Q, V]: a δE batch dirties every query's row,
    #    a mid-stream register dirties only the new slot's.  Inactive slots
    #    (the session's free pool) are scheduled for no work at all.
    sched = (c.frontier | dirty) & active[:, None]

    # -- recompute D_i (dense; `sched|repair` is the algorithmic work mask).
    if cfg.mode == "vdc":
        # Maintain J at iteration i before reading it: an edge's message
        # changes when its source changed at i-1, or the edge itself (or a
        # sibling in-edge of its target) was touched by δE.  ``join_mat``
        # gates the store per slot: a slot whose Join differences are
        # dropped completely (§4) writes nothing and recomputes its
        # messages on demand — JOD inside a VDC engine.
        live_msgs = edge_messages(cfg, cur_full, g)
        jprev, _, jfound = ds.lookup_le(c.jstore, i)
        j0 = edge_messages(cfg, init, g)  # implicit J from D_0
        jprev = jnp.where(jfound, jprev, j0)
        # NOTE: deliberately NOT masked by g.valid — a deleted edge must
        # overwrite its stored message with the identity.
        dirty_pad = jnp.concatenate(
            [dirty, jnp.zeros((dirty.shape[0], 1), bool)], axis=1
        )
        jmat = join_mat[:, None]
        jdirty = c.changed_prev[:, g.src] | dirty_pad[:, dst]
        jwrite = jdirty & (live_msgs != jprev) & jmat
        jstore, _, _ = ds.upsert(c.jstore, i, jwrite, live_msgs)
        # VDC path: the aggregator *reads* the materialized J difference
        # sets for materializing slots, the on-demand messages otherwise.
        jval, _, jfound2 = ds.lookup_le(jstore, i)
        msgs = jnp.where(jmat, jnp.where(jfound2, jval, j0), live_msgs)
        new = aggregate(cfg, msgs, c.cur, g, dst=dst, num_segments=num_local)
        jwritten = c.stats.jwritten + jwrite.sum(dtype=jnp.int32)
    else:
        jstore = c.jstore
        jwritten = c.stats.jwritten
        # backend="fused" realizes JOD's expand *inside* the megakernel;
        # VDC hands the aggregated `new` to the kernel (partial fusion).
        new = (
            None
            if cfg.backend == "fused"
            else ife_step(
                cfg, cur_full, g, carry=c.cur, dst=dst, num_segments=num_local
            )
        )

    if cfg.backend == "fused":
        # -- the maintenance megakernel: ONE pallas_call per iteration fuses
        #    frontier expand (JOD), DroppedVT/Bloom probe + repair masking,
        #    δ detection against the frozen old store, and the diff-store
        #    append/remove — intermediate tiles never leave VMEM.  The body
        #    calls the same library primitives as the stitched path below
        #    (expand_tile, ds.*, dr.select_to_drop, bloom.query), so results
        #    are bit-identical.
        sr = cfg.semiring
        kw: dict = {}
        if new is None:
            nq = cur_full.shape[0]
            kw["states"] = jnp.concatenate(
                [cur_full, jnp.full((nq, 1), sr.identity, cur_full.dtype)],
                axis=1,
            )
            kw["nbr"] = g.nbr
            kw["w"] = _ell_weights(cfg, g)
            kw["kcarry"] = (
                c.cur if sr.carry_prev else jnp.full_like(c.cur, sr.base)
            )
        else:
            kw["new"] = new
        if cfg.drop.enabled():
            kw["degree"] = degree
            kw["params"] = c.drop.params
            if cfg.drop.mode == "det":
                kw["det"] = c.drop.det
            else:
                kw["bloom_bits"] = c.drop.flt.bits
                kw["bloom_hashes"] = c.drop.flt.num_hashes
        out = fused_sweep_lib.fused_sweep(
            i,
            off,
            sched,
            active,
            c.cur,
            c.cur_old,
            c.stale_old,
            c.dstore,
            old_dstore,
            semiring=sr.kernel_name,
            hop_cap=sr.hop_cap,
            block_v=cfg.ell_block_v,
            drop_mode=cfg.drop.mode if cfg.drop.enabled() else "none",
            interpret=_interpret(cfg),
            **kw,
        )
        dstore = ds.DiffStore(out.d_iters, out.d_vals, out.d_count)
        cur_next = out.cur
        old_i, stale = out.old, out.stale
        changed, repair = out.changed, out.repair
        to_store, to_drop, vanish = out.to_store, out.to_drop, out.vanish
        drop_state = c.drop
        if cfg.drop.enabled():
            if cfg.drop.mode == "det":
                # DroppedVT was maintained in VMEM; adopt the kernel's rows
                # and fold the per-tile overflow/horizon partials back into
                # the replicated scalars (sum/max are associative).
                drop_state = drop_state._replace(
                    det=ds.DiffStore(
                        out.det_iters, c.drop.det.vals, out.det_count
                    ),
                    det_overflow=c.drop.det_overflow
                    + out.det_overflow.sum(dtype=jnp.int32),
                    max_iter=jnp.maximum(
                        c.drop.max_iter, out.det_max_iter.max()
                    ),
                )
            else:
                # Bloom inserts stay outside the kernel (XLA scatter; the OR
                # is idempotent so ordering is immaterial) — identical bits
                # to the stitched register pair; unregister is a prob no-op.
                drop_state = dr.register(drop_state, i, out.to_drop, v_offset=off)
                drop_state = dr.register(
                    drop_state, out.evicted_iter, out.evicted, v_offset=off
                )
    else:
        # -- dropped change points at i must be recomputed to keep `cur`
        #    exact (AccessDᵢᵛWithDrops, forward form).  Prob-Drop may
        #    false-positive here → spurious but safe recompute.
        dropped_here = (
            dr.dropped_at(c.drop, i, num_local, v_offset=off)
            if cfg.drop.enabled()
            else jnp.zeros_like(sched)
        )
        repair = dropped_here & active[:, None] & ~sched

        # -- pre-update trajectory at i (δ detection), from the frozen store.
        old_has, old_val = ds.value_at(old_dstore, i)
        old_i = jnp.where(old_has, old_val, c.cur_old)
        # A dropped old change point leaves old_i stale until the next
        # stored old point re-anchors it; stale scheduled vertices propagate
        # conservatively.
        stale = (c.stale_old | dropped_here) & ~old_has

        changed = sched & ((new != old_i) | stale)

        # -- new trajectory change point at i?  (vs exact D_{i-1} = cur)
        want_point = sched & (new != c.cur)
        has_cur, cur_stored_val = ds.value_at(c.dstore, i)

        if cfg.drop.enabled():
            to_drop = want_point & dr.select_to_drop(
                c.drop.params, degree, q_ids, v_ids, i
            )
            to_store = want_point & ~to_drop
        else:
            to_drop = jnp.zeros_like(want_point)
            to_store = want_point

        dstore, evicted, evicted_iter = ds.upsert(c.dstore, i, to_store, new)
        # one fused removal pass (each full remove_at rewrites the store):
        #   · a dropped point at i that had a stored twin loses the twin
        #   · a vanished change point (+/- pair cancelled) is deleted
        vanish = sched & ~want_point & has_cur
        dstore = ds.remove_at(dstore, i, (to_drop & has_cur) | vanish)

        drop_state = c.drop
        if cfg.drop.enabled():
            drop_state = dr.register(drop_state, i, to_drop, v_offset=off)
            drop_state = dr.register(
                drop_state, evicted_iter, evicted, v_offset=off
            )
            # a dropped record is stale once the point is stored or vanished
            drop_state = dr.unregister(drop_state, i, to_store | vanish)

        # -- advance the exact trajectory.
        recompute = sched | repair
        cur_next = jnp.where(
            recompute, new, jnp.where(has_cur, cur_stored_val, c.cur)
        )

    if cfg.drop.enabled() and axis is not None:
        # per-shard inserts merge back into the shared structures: OR the
        # Bloom bits (psum of bools), pmax the horizon anchor, psum the
        # overflow delta — all scalars/filters stay replicated.
        if drop_state.flt is not None:
            bits = jax.lax.psum(drop_state.flt.bits.astype(jnp.int32), axis) > 0
            drop_state = drop_state._replace(flt=drop_state.flt._replace(bits))
        drop_state = drop_state._replace(
            det_overflow=c.drop.det_overflow
            + jax.lax.psum(drop_state.det_overflow - c.drop.det_overflow, axis),
            max_iter=jax.lax.pmax(drop_state.max_iter, axis),
        )
    changed_full = (
        changed
        if axis is None
        else jax.lax.all_gather(changed, axis, axis=1, tiled=True)
    )
    frontier_next = (
        push_frontier(changed_full, g, dst=dst, num_segments=num_local) | changed
    )  # | changed: carry a changed vertex's own next value

    # per-iteration probe: iteration i lands in bin i-1 (clamped to the last
    # bin) so short sweeps read directly as a size-per-iteration series
    bin_i = jnp.minimum(i - 1, jnp.int32(ITER_TRACE - 1))
    stats = MaintainStats(
        iters_run=c.stats.iters_run + 1,
        scheduled=c.stats.scheduled + sched.sum(dtype=jnp.int32),
        changed=c.stats.changed + changed.sum(dtype=jnp.int32),
        repairs=c.stats.repairs + repair.sum(dtype=jnp.int32),
        written=c.stats.written + to_store.sum(dtype=jnp.int32),
        removed=c.stats.removed + vanish.sum(dtype=jnp.int32),
        dropped=c.stats.dropped + to_drop.sum(dtype=jnp.int32),
        jwritten=jwritten,
        det_overflow=c.stats.det_overflow,  # folded in after the loop
        sched_sizes=c.stats.sched_sizes.at[bin_i].add(
            sched.sum(dtype=jnp.int32)
        ),
        frontier_sizes=c.stats.frontier_sizes.at[bin_i].add(
            frontier_next.sum(dtype=jnp.int32)
        ),
    )
    any_store = to_store.any()
    live_next = frontier_next.any() | dirty.any()
    if axis is not None:
        any_store = jax.lax.psum(any_store.astype(jnp.int32), axis) > 0
        live_next = jax.lax.psum(live_next.astype(jnp.int32), axis) > 0
    horizon = jnp.where(any_store, jnp.maximum(c.horizon, i), c.horizon)
    return _Carry(
        i=i + 1,
        cur=cur_next,
        cur_old=old_i,
        stale_old=stale,
        frontier=frontier_next,
        # sharded VDC reuses this iteration's gathered mask next iteration
        changed_prev=changed_full if cfg.mode == "vdc" else changed,
        dstore=dstore,
        jstore=jstore,
        drop=drop_state,
        repair_counts=c.repair_counts + repair.astype(jnp.int32),
        horizon=horizon,
        live=live_next,
        stats=stats,
    )


def _maintain_core(
    cfg: EngineConfig,
    state: EngineState,
    g: GraphArrays,
    dirty: Array,
    *,
    axis: str | None = None,
) -> tuple[EngineState, MaintainStats]:
    """The maintenance while_loop, shared by the single-device path
    (``axis=None``) and the per-shard body under ``shard_map``.

    ``dirty`` is the per-query [Q, V] schedule seed (local vertex partition
    when sharded).  In sharded mode every per-vertex argument arrives as its
    local partition; loop-control scalars (``live``, ``horizon``,
    ``drop.max_iter``) are kept replicated by collectives in the body, so
    ``cond`` itself runs no communication and all shards take identical trip
    counts.
    """
    old_dstore = state.dstore  # frozen pre-maintenance snapshot (functional)
    if axis is None:
        init_full = state.init
        live0 = dirty.any()
        horizon0 = stored_horizon(state.dstore)
    else:
        init_full = jax.lax.all_gather(state.init, axis, axis=1, tiled=True)
        live0 = jax.lax.psum(dirty.any().astype(jnp.int32), axis) > 0
        horizon0 = jax.lax.pmax(stored_horizon(state.dstore), axis)

    body = partial(
        _sweep_body,
        cfg,
        g,
        dirty,
        init_full,
        old_dstore,
        state.active,
        state.join_mat,
        axis,
    )

    def cond(c: _Carry) -> Array:
        # Continue while work is scheduled (frontier/dirty) AND the sweep can
        # still mutate the store.  Mutations happen only at i ≤ horizon+1:
        # an in-neighbour change point at j feeds a consumer at j+1 (upper
        # bound rule), and fresh writes at i extend the horizon to ≥ i, so a
        # still-converging new trajectory keeps the loop alive while a
        # permanently-diverged-from-old frontier (no mutations) drains at
        # horizon+1 instead of max_iters.  i==1 always runs when anything is
        # dirty (δE direct rule).  The horizon rides the carry (one store
        # scan per maintain, not per iteration).
        horizon = c.horizon
        if cfg.drop.enabled():
            # dropped change points still anchor the upper-bound rule (and
            # must be swept past so `cur` picks up their repaired values)
            horizon = jnp.maximum(horizon, c.drop.max_iter)
        return (
            (c.i <= jnp.int32(cfg.max_iters))
            & c.live
            & ((c.i == 1) | (c.i <= horizon + 1))
        )

    num_local = state.cur.shape[-1]
    zeros = jnp.zeros((cfg.num_queries, num_local), bool)
    c0 = _Carry(
        i=jnp.int32(1),
        cur=state.init,
        cur_old=state.init,
        stale_old=zeros,
        frontier=zeros,
        changed_prev=(
            jnp.zeros((cfg.num_queries, cfg.num_vertices), bool)
            if cfg.mode == "vdc"
            else zeros
        ),
        dstore=state.dstore,
        jstore=state.jstore,
        drop=state.drop,
        repair_counts=state.repair_counts,
        horizon=horizon0,
        live=live0,
        stats=zeros_stats(),
    )
    c = jax.lax.while_loop(cond, body, c0)
    stats = c.stats
    if axis is not None:
        # per-shard partial sums → global; iters_run is already replicated
        stats = stats._replace(
            scheduled=jax.lax.psum(stats.scheduled, axis),
            changed=jax.lax.psum(stats.changed, axis),
            repairs=jax.lax.psum(stats.repairs, axis),
            written=jax.lax.psum(stats.written, axis),
            removed=jax.lax.psum(stats.removed, axis),
            dropped=jax.lax.psum(stats.dropped, axis),
            jwritten=jax.lax.psum(stats.jwritten, axis),
            sched_sizes=jax.lax.psum(stats.sched_sizes, axis),
            frontier_sizes=jax.lax.psum(stats.frontier_sizes, axis),
        )
    # Det-Drop record loss this sweep (replicated in sharded mode: the body
    # psums the per-shard eviction deltas into the carried counter).
    stats = stats._replace(
        det_overflow=c.drop.det_overflow - state.drop.det_overflow
    )
    new_state = EngineState(
        dstore=c.dstore,
        jstore=c.jstore,
        drop=c.drop,
        init=state.init,
        cur=c.cur,
        repair_counts=c.repair_counts,
        active=state.active,
        join_mat=state.join_mat,
    )
    return new_state, stats


def _dirty_2d(cfg: EngineConfig, dirty: Array) -> Array:
    """Normalize a [V] vertex mask to the per-query [Q, V] schedule seed."""
    dirty = jnp.asarray(dirty, bool)
    if dirty.ndim == 1:
        dirty = jnp.broadcast_to(dirty[None, :], (cfg.num_queries, dirty.shape[0]))
    return dirty


def maintain(
    cfg: EngineConfig,
    state: EngineState,
    g: GraphArrays,
    dirty: Array,
) -> tuple[EngineState, MaintainStats]:
    """One maintenance sweep after a δE batch (or initial computation).

    ``dirty`` is the bool mask of vertices whose in-edge set (or, for
    degree-derived weights, whose incoming message weights) changed — [V]
    (broadcast to every query, the δE case) or [Q, V] (per-query: a
    mid-stream ``register`` seeds only the new slot's row, which makes the
    sweep the new query's initial computation while every other query is
    scheduled for zero work).  For the initial computation pass
    ``dirty = ones`` with an empty store — the sweep then *is* the static
    IFE run, recording change points as it goes.
    """
    return _maintain_core(cfg, state, g, _dirty_2d(cfg, dirty), axis=None)


# --------------------------------------------------------------------------- sharded sweep
def _store_pspec() -> ds.DiffStore:
    """Partition spec for a [Q, K, S] diff store: keys sharded, rest whole."""
    return ds.DiffStore(
        iters=P(None, DATA_AXIS, None),
        vals=P(None, DATA_AXIS, None),
        count=P(None, DATA_AXIS),
    )


def _state_pspecs(state: EngineState) -> EngineState:
    """EngineState partition specs: every per-vertex (and, for VDC, per-edge-
    cell) axis shards over ``data``; scalars and Bloom bits stay replicated."""
    drop = state.drop
    return EngineState(
        dstore=_store_pspec(),
        jstore=None if state.jstore is None else _store_pspec(),
        drop=dr.DropState(
            det=None if drop.det is None else _store_pspec(),
            flt=None
            if drop.flt is None
            else bloom_lib.BloomFilter(P(), drop.flt.num_hashes),
            det_overflow=P(),
            max_iter=P(),
            # per-query selection rows replicate (the Q axis never shards)
            params=None
            if drop.params is None
            else dr.DropParams(*([P()] * len(dr.DropParams._fields))),
        ),
        init=P(None, DATA_AXIS),
        cur=P(None, DATA_AXIS),
        repair_counts=P(None, DATA_AXIS),
        active=P(),
        join_mat=None if state.join_mat is None else P(),
    )


def _graph_pspecs(g: GraphArrays) -> GraphArrays:
    """GraphArrays partition specs for the vertex-sharded edge layout:
    edge cells and in-rows shard by destination; out-degrees replicate
    (message weights gather them at arbitrary global sources)."""
    return GraphArrays(
        src=P(DATA_AXIS),
        dst=P(DATA_AXIS),
        weight=P(DATA_AXIS),
        valid=P(DATA_AXIS),
        out_degree=P(),
        in_degree=P(DATA_AXIS),
        nbr=None if g.nbr is None else P(DATA_AXIS, None),
        ell_w=None if g.ell_w is None else P(DATA_AXIS, None),
    )


def _stats_pspecs() -> MaintainStats:
    return MaintainStats(*([P()] * len(MaintainStats._fields)))


def maintain_sharded(
    cfg: EngineConfig,
    mesh: Mesh,
    state: EngineState,
    g: GraphArrays,
    dirty: Array,
) -> tuple[EngineState, MaintainStats]:
    """``maintain`` with every per-vertex carry partitioned over the mesh
    ``data`` axis.  ``g`` must be in the :class:`ShardIndex` edge layout
    (cells grouped by destination shard) and V divisible by the axis size."""
    sspec = _state_pspecs(state)
    fn = shard_map(
        partial(_maintain_core, cfg, axis=DATA_AXIS),
        mesh=mesh,
        in_specs=(sspec, _graph_pspecs(g), P(None, DATA_AXIS)),
        out_specs=(sspec, _stats_pspecs()),
        check_rep=False,
    )
    return fn(state, g, _dirty_2d(cfg, dirty))


def _batched_core_sharded(
    cfg: EngineConfig,
    state: EngineState,
    g: GraphArrays,
    upd: UpdateBatch,
    *,
    axis: str,
) -> tuple[EngineState, GraphArrays, MaintainStats]:
    """Per-shard body of the donated-buffer batched step: the (replicated)
    UpdateBatch is scattered to the owning shards — each shard localizes the
    chunk's indices and drops the rows it does not own — then the sharded
    sweep runs in the same dispatch."""
    es = g.src.shape[0]  # edge cells per shard
    num_local = state.cur.shape[-1]  # vertices per shard
    v = cfg.num_vertices
    shard = jax.lax.axis_index(axis).astype(jnp.int32)
    off = shard * num_local

    # edge-cell scatter: upd.slot is the linear ShardIndex cell (shard·C + pos)
    slot = upd.slot - shard * es
    slot = jnp.where((slot >= 0) & (slot < es), slot, es)  # foreign → dropped
    src = g.src.at[slot].set(upd.src, mode="drop")
    dst = g.dst.at[slot].set(upd.dst, mode="drop")
    weight = g.weight.at[slot].set(upd.weight, mode="drop")
    valid = g.valid.at[slot].set(upd.valid, mode="drop")

    # degrees recomputed from the (distributed) edge list: out-degrees need a
    # cross-shard psum (any shard may hold out-edges of any source); in-
    # degrees are a shard-local property of the owned destination block.
    live = valid.astype(jnp.int32)
    out_degree = jax.lax.psum(
        jax.ops.segment_sum(live, src, num_segments=v), axis
    )
    dst_l = _local_dst(dst, off, num_local)
    in_degree = jax.ops.segment_sum(live, dst_l, num_segments=num_local)

    nbr, ell_w = g.nbr, g.ell_w
    if cfg.backend in ("ell", "fused"):
        row = upd.ell_row - off
        row = jnp.where((row >= 0) & (row < num_local), row, num_local)
        nbr = nbr.at[row, upd.ell_col].set(upd.ell_nbr, mode="drop")
        ell_w = ell_w.at[row, upd.ell_col].set(upd.ell_w, mode="drop")
    g2 = GraphArrays(src, dst, weight, valid, out_degree, in_degree, nbr, ell_w)

    dv = upd.dirty_v - off
    dv = jnp.where((dv >= 0) & (dv < num_local), dv, num_local)
    dirty = jnp.zeros(num_local + 1, bool).at[dv].set(True)[:num_local]
    if cfg.weight_from_degree:
        # outdeg(u) changed → every out-message of u retunes (δE dirty rule)
        tsrc = jnp.zeros(v + 1, bool).at[upd.touched_src].set(True)[:v]
        hit = (tsrc[src] & valid).astype(jnp.int32)
        dirty = dirty | (
            jax.ops.segment_max(hit, dst_l, num_segments=num_local) > 0
        )
    dirty = jnp.broadcast_to(dirty[None, :], (cfg.num_queries, num_local))

    new_state, stats = _maintain_core(cfg, state, g2, dirty, axis=axis)
    return new_state, g2, stats


def batched_step_sharded(
    cfg: EngineConfig,
    mesh: Mesh,
    state: EngineState,
    g: GraphArrays,
    upd: UpdateBatch,
) -> tuple[EngineState, GraphArrays, MaintainStats]:
    """Sharded twin of :func:`batched_step`: one dispatch scatters a δE chunk
    to the owning shards and runs the vertex-sharded maintenance sweep."""
    sspec, gspec = _state_pspecs(state), _graph_pspecs(g)
    fn = shard_map(
        partial(_batched_core_sharded, cfg, axis=DATA_AXIS),
        mesh=mesh,
        in_specs=(sspec, gspec, UpdateBatch(*([P()] * len(UpdateBatch._fields)))),
        out_specs=(sspec, gspec, _stats_pspecs()),
        check_rep=False,
    )
    return fn(state, g, upd)


def shed_slot(
    cfg: EngineConfig, state: EngineState, g: GraphArrays, slot: Array | int
) -> EngineState:
    """Re-audit ONE query slot's stored diffs under its (just-rewritten)
    selection params: points the escalated policy selects move from the diff
    store into the DroppedVT structures (8 B change point → ≤4 B record, or
    Bloom bits), exactly as if they had been dropped at write time.

    This is the governor's reclamation primitive: raising a query's drop
    probability only thins FUTURE writes; ``shed_slot`` makes the escalation
    retroactive so memory falls immediately.  Correctness is the existing §5
    machinery — the sweep repairs dropped points on access — and because the
    selection coin is the stateless (seed, q, v, i) hash, a shed is
    bit-identical under any sharding.  ``cur`` (the answers) is untouched.
    """
    drop = state.drop
    degree = (g.out_degree + g.in_degree).astype(jnp.float32)
    sel = dr.select_stored_to_drop(
        drop.params, degree, state.dstore.iters, ds.IMAX
    )
    qmask = (
        jnp.arange(cfg.num_queries, dtype=jnp.int32) == jnp.asarray(slot)
    )[:, None, None]
    mask = sel & qmask & state.active[:, None, None]

    # record the shed points as dropped VTs, one store column per step —
    # dr.register takes per-(q, v) iteration arrays, and the Det-Drop store
    # is keyed by (q, v) so multiple iterations of one vertex cannot land in
    # a single upsert.  A traced fori_loop keeps the compiled program size
    # independent of the store capacity S (which regrows geometrically).
    def register_col(col, d):
        i_col = jax.lax.dynamic_index_in_dim(
            state.dstore.iters, col, axis=-1, keepdims=False
        )
        m_col = jax.lax.dynamic_index_in_dim(mask, col, axis=-1, keepdims=False)
        return dr.register(d, i_col, m_col)

    drop = jax.lax.fori_loop(0, state.dstore.capacity, register_col, drop)
    # remove them from the store, preserving the sorted-row invariant
    it = jnp.where(mask, ds.IMAX, state.dstore.iters)
    val = jnp.where(mask, 0.0, state.dstore.vals)
    order = jnp.argsort(it, axis=-1, stable=True)
    it = jnp.take_along_axis(it, order, axis=-1)
    val = jnp.take_along_axis(val, order, axis=-1)
    dstore = ds.DiffStore(
        iters=it, vals=val, count=(it < ds.IMAX).sum(axis=-1, dtype=jnp.int32)
    )
    return state._replace(dstore=dstore, drop=drop)


def reassemble(
    cfg: EngineConfig, state: EngineState, g: GraphArrays, upto: int | None = None
) -> Array:
    """Repair-aware reassembly of D at iteration ``upto`` (paper's Access).

    Bounded forward repair: walk iterations 1..upto; stored points are exact,
    dropped points are recomputed from the exact previous front.  Cost is
    O(upto × E) dense, but only dropped lanes represent algorithmic work.
    """
    upto = cfg.max_iters if upto is None else upto

    def body(i, cur):
        has, val = ds.value_at(state.dstore, i)
        if cfg.drop.enabled():
            dropped = dr.dropped_at(state.drop, i, cfg.num_vertices)
            new = ife_step(cfg, cur, g)
            return jnp.where(has, val, jnp.where(dropped, new, cur))
        return jnp.where(has, val, cur)

    return jax.lax.fori_loop(1, upto + 1, body, state.init)


def answers(cfg: EngineConfig, state: EngineState) -> Array:
    """Final vertex states after the last maintenance sweep. [Q, V]"""
    return state.cur


# --------------------------------------------------------------------------- memory accounting
def nbytes_accounted(cfg: EngineConfig, state: EngineState) -> int:
    """Difference-entry bytes, the paper's memory metric (8 B per diff:
    4 B iteration + 4 B state; DroppedVT per §5.1 costings, including the
    per-query selection rows and Bloom rows of LIVE slots only — a retired
    slot's zeroed rows are reclaimable and charge nothing)."""
    total = int(state.dstore.count.sum()) * 8
    if state.jstore is not None:
        total += int(state.jstore.count.sum()) * 8
    if cfg.drop.enabled():
        total += int(state.drop.nbytes_accounted(state.active))
    return total


def nbytes_per_shard(
    cfg: EngineConfig, state: EngineState, num_shards: int
) -> list[int]:
    """Accounted difference bytes resident on each shard of the vertex
    partition (the paper's Table-1 per-machine memory axis): diff-store and
    DroppedVT rows live with their owning vertex block, VDC's J rows with
    their owning edge-cell block.  Bloom bits and DropParams rows are
    *replicated* device-side, but accounted ONCE and apportioned evenly
    across the shards, so ``sum(nbytes_per_shard(...)) == nbytes_accounted``
    in every drop mode (the remainder lands on shard 0)."""
    q = cfg.num_queries
    per = (
        np.asarray(state.dstore.count).reshape(q, num_shards, -1).sum(axis=(0, 2))
        * 8
    )
    if state.jstore is not None:
        per = per + (
            np.asarray(state.jstore.count)
            .reshape(q, num_shards, -1)
            .sum(axis=(0, 2))
            * 8
        )
    if cfg.drop.enabled():
        if state.drop.det is not None:
            per = per + (
                np.asarray(state.drop.det.count)
                .reshape(q, num_shards, -1)
                .sum(axis=(0, 2))
                * 4
            )
            replicated = int(state.drop.nbytes_accounted(state.active)) - int(
                state.drop.det.count.sum() * 4
            )
        else:
            replicated = int(state.drop.nbytes_accounted(state.active))
        per = per + replicated // num_shards
        per[0] += replicated - (replicated // num_shards) * num_shards
    return [int(x) for x in per]


# --------------------------------------------------------------------------- batched updates
class UpdateBatch(NamedTuple):
    """Fixed-shape device encoding of ≤ B resolved edge updates.

    One row per touched edge slot, holding the slot's *final* contents after
    the whole chunk (the host coalesces, so duplicate-index scatter order
    never matters).  Padding rows carry out-of-range indices — slot == E_cap,
    vertex == V, ell_row == V — and are dropped by the scatters / sliced off
    the dirty mask.  The shape ``[B]`` is the jit cache key: every chunk of a
    long update log reuses one compiled program.
    """

    slot: Array  # int32 [B] — edge slot; E_cap padding
    src: Array  # int32 [B] — final slot source
    dst: Array  # int32 [B] — final slot destination
    weight: Array  # f32  [B] — final slot weight
    valid: Array  # bool [B] — final slot validity
    dirty_v: Array  # int32 [B] — endpoint to dirty (δE direct rule); V padding
    touched_src: Array  # int32 [B] — update source (degree-retune rule); V padding
    ell_row: Array  # int32 [B] — ELL cell writes (backend="ell"); V padding
    ell_col: Array  # int32 [B]
    ell_nbr: Array  # int32 [B]
    ell_w: Array  # f32  [B]


def batched_step(
    cfg: EngineConfig, state: EngineState, g: GraphArrays, upd: UpdateBatch
) -> tuple[EngineState, GraphArrays, MaintainStats]:
    """Fold one δE chunk into the graph arrays and run ONE maintenance sweep.

    This is the device-side twin of ``DiffIFE.apply_updates``: edge scatter,
    degree refresh, dirty-mask construction and the ``lax.while_loop`` sweep
    compile into a single program.  ``DiffIFE`` jits it with donated
    ``(state, g)`` so the stores update in place (no per-update host round
    trip, no buffer churn); host work per chunk is an O(B) encode.
    """
    v = cfg.num_vertices
    src = g.src.at[upd.slot].set(upd.src, mode="drop")
    dst = g.dst.at[upd.slot].set(upd.dst, mode="drop")
    weight = g.weight.at[upd.slot].set(upd.weight, mode="drop")
    valid = g.valid.at[upd.slot].set(upd.valid, mode="drop")
    # degrees recomputed from the edge list — O(E) on-device, far below one
    # sweep iteration, and immune to host/device drift
    live = valid.astype(jnp.int32)
    out_degree = jax.ops.segment_sum(live, src, num_segments=v)
    in_degree = jax.ops.segment_sum(live, dst, num_segments=v)
    nbr, ell_w = g.nbr, g.ell_w
    if cfg.backend in ("ell", "fused"):
        nbr = nbr.at[upd.ell_row, upd.ell_col].set(upd.ell_nbr, mode="drop")
        ell_w = ell_w.at[upd.ell_row, upd.ell_col].set(upd.ell_w, mode="drop")
    g2 = GraphArrays(src, dst, weight, valid, out_degree, in_degree, nbr, ell_w)

    dirty = jnp.zeros(v + 1, bool).at[upd.dirty_v].set(True)[:v]
    if cfg.weight_from_degree:
        # outdeg(u) changed → every out-message of u retunes (δE dirty rule)
        tsrc = jnp.zeros(v + 1, bool).at[upd.touched_src].set(True)[:v]
        hit = (tsrc[g2.src] & g2.valid).astype(jnp.int32)
        dirty = dirty | (jax.ops.segment_max(hit, g2.dst, num_segments=v) > 0)

    new_state, stats = maintain(cfg, state, g2, _dirty_2d(cfg, dirty))
    return new_state, g2, stats


def _sum_stats(a: MaintainStats, b: MaintainStats) -> MaintainStats:
    return MaintainStats(*(x + y for x, y in zip(a, b)))


def _span_stats(stats: MaintainStats | None) -> dict:
    """Sweep attribution for trace spans: scalar counters plus the
    per-iteration size series trimmed to the iterations actually run."""
    if stats is None:
        return {}
    out = {k: int(getattr(stats, k)) for k in MaintainStats.SCALAR_FIELDS}
    n = min(max(out["iters_run"], 0), ITER_TRACE)
    out["sched_sizes"] = [int(x) for x in stats.sched_sizes[:n]]
    out["frontier_sizes"] = [int(x) for x in stats.frontier_sizes[:n]]
    return out


# --------------------------------------------------------------------------- host-facing wrapper
class DiffIFE:
    """Continuous-query processor: owns the dynamic graph + engine state.

    ``DiffIFE`` is the host driver (the GDBMS's continuous query processor);
    all device work happens in the pure functions above, jitted per graph
    capacity so update batches never recompile.

    Two ingestion paths:

    * :meth:`apply_updates` — per-batch host path: mutate the host graph,
      re-upload the device view, run one sweep.  Simple, but each batch pays
      a host round trip + full graph transfer.
    * :meth:`apply_updates_batched` — the throughput path: updates are folded
      in fixed-shape chunks of ``batch_capacity`` through the donated-buffer
      :func:`batched_step`, so the jit cache is hit once per chunk and the
      graph/stores never leave the device.

    With ``cfg.backend == "ell"`` the bucketed in-adjacency rides along; its
    width ``D`` is kept fixed across updates (host :class:`EllIndex` mirror)
    and grows geometrically — with a one-off re-trace — only when a vertex's
    in-degree outruns it.

    With ``mesh`` given (data axis > 1), every per-vertex carry partitions by
    destination vertex over the mesh ``data`` axis and both ingestion paths
    dispatch through ``shard_map`` (:func:`maintain_sharded` /
    :func:`batched_step_sharded`); the edge list moves into the
    :class:`ShardIndex` cell layout (cells grouped by owning shard, host
    mirror kept in sync per chunk) and grows geometrically per shard — with
    a one-off re-trace, and a J-store row permutation under VDC — when a
    shard's cells run out.

    **Query slot pool** (DESIGN.md §9): the leading Q axis is a padded pool
    of query slots gated by ``state.active``.  :meth:`register_slot` claims a
    free slot (growing the pool geometrically — one re-trace — when none is
    left) and initializes the new query's trace *in-engine*: one maintenance
    sweep whose per-query dirty mask seeds only the new row, so every other
    registered query is scheduled for zero work.  :meth:`deregister_slot`
    zeroes the slot's diff-store rows and returns the accounted bytes freed.
    """

    def __init__(
        self,
        cfg: EngineConfig,
        graph: DynamicGraph,
        init: np.ndarray | Array,
        *,
        batch_capacity: int = 32,
        mesh: Mesh | None = None,
        active: np.ndarray | None = None,
        drop_rows: list[dr.DropConfig] | None = None,
        join_rows: list[bool] | None = None,
    ) -> None:
        self.cfg = cfg
        self.graph = graph
        self.batch_capacity = int(batch_capacity)
        self.mesh = mesh
        self.num_shards = int(mesh.shape[DATA_AXIS]) if mesh is not None else 1
        if self.num_shards > 1 and cfg.num_vertices % self.num_shards:
            raise ValueError(
                f"num_vertices {cfg.num_vertices} not divisible by the mesh "
                f"data axis ({self.num_shards})"
            )
        self._ell_width = 0
        self._ell_index: EllIndex | None = None
        self._shard_index: ShardIndex | None = None
        self.g = self._device_graph(graph.snapshot())
        num_rows = (
            self.num_shards * self._shard_index.shard_capacity
            if self._shard_index is not None
            else graph.capacity
        )
        self.state = make_state(
            cfg,
            jnp.asarray(init, jnp.float32),
            num_rows,
            active=active,
            drop_rows=drop_rows,
            join_rows=join_rows,
        )
        # descending so pop() hands out the lowest free slot first
        self._free_slots: list[int] = sorted(
            (
                q
                for q in range(cfg.num_queries)
                if active is not None and not bool(active[q])
            ),
            reverse=True,
        )
        self._build_dispatch()
        self.last_stats: MaintainStats | None = None
        # DroppedVT records lost to Det-Drop evictions DURING sheds (policy
        # rewrites).  Sweep-time losses surface per sweep in
        # MaintainStats.det_overflow; a shed runs between sweeps, so its
        # losses would otherwise vanish from telemetry entirely.
        self.det_overflow_shed = 0
        # cumulative scheduled vertex-reruns across all sweeps: the shared
        # recompute-volume signal apportioned to the Join operator (dropping
        # a join trades its stored messages for exactly this recomputation)
        self._sched_total = 0
        # initial computation: every vertex dirty, empty store (inactive
        # slots are masked out of the schedule by ``state.active``); an
        # all-inactive pool (the session's deferred-register path) has
        # nothing to compute and skips the dispatch entirely
        if active is None or bool(np.asarray(active).any()):
            self._run_counted(np.ones(cfg.num_vertices, dtype=bool))

    def _build_dispatch(self) -> None:
        """(Re)jit the two dispatch paths for the current static config."""
        if self.num_shards > 1:
            self._maintain = jax.jit(partial(maintain_sharded, self.cfg, self.mesh))
            self._step = jax.jit(
                partial(batched_step_sharded, self.cfg, self.mesh),
                donate_argnums=(0, 1),
            )
        else:
            self._maintain = jax.jit(partial(maintain, self.cfg))
            self._step = jax.jit(
                partial(batched_step, self.cfg), donate_argnums=(0, 1)
            )
        # governor reclamation primitive; slot is traced so every rewrite of
        # any slot reuses one compiled program
        self._shed = jax.jit(partial(shed_slot, self.cfg))

    # ------------------------------------------------------------ device views
    def _device_graph(self, snap: GraphSnapshot) -> GraphArrays:
        if self.num_shards > 1:
            return self._device_graph_sharded(snap)
        if self.cfg.backend in ("ell", "fused"):
            g = GraphArrays.from_snapshot(
                snap, backend=self.cfg.backend, ell_min_width=self._ell_width
            )
            self._ell_width = g.ell_width
            self._ell_index = EllIndex(snap, self._ell_width)
            return g
        return GraphArrays.from_snapshot(snap)

    def _device_graph_sharded(self, snap: GraphSnapshot) -> GraphArrays:
        if self._shard_index is None:
            self._shard_index = ShardIndex(snap, self.num_shards)
        src, dst, w, valid = self._shard_index.edge_arrays(snap)
        nbr = ell_w = None
        if self.cfg.backend in ("ell", "fused"):
            # ELL rows are keyed by destination, so the [V, D] view shards
            # row-wise as-is; neighbour ids stay global (the kernel gathers
            # from the all-gathered state row).
            nbr_np, w_np, width = snap.to_ell(min_width=self._ell_width)
            self._ell_width = width
            self._ell_index = EllIndex(snap, width)
            nbr, ell_w = jnp.asarray(nbr_np), jnp.asarray(w_np)
        return GraphArrays(
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            weight=jnp.asarray(w),
            valid=jnp.asarray(valid),
            out_degree=jnp.asarray(snap.out_degree, jnp.int32),
            in_degree=jnp.asarray(snap.in_degree, jnp.int32),
            nbr=nbr,
            ell_w=ell_w,
        )

    def _shard_sync(self, ops, snap: GraphSnapshot | None = None) -> list | None:
        """Fold resolved ops into the shard index; regrow on overflow.

        Returns the coalesced cell writes, or None when the index had to be
        rebuilt (the caller must then re-upload the full edge layout).  The
        snapshot is only needed on the overflow path — callers without one at
        hand (the per-chunk batched loop) let it be taken lazily there, so
        the hot path stays O(B) on the host."""
        try:
            return self._shard_index.writes_for(ops)
        except ShardOverflow:
            self._regrow_shards(snap if snap is not None else self.graph.snapshot())
            return None

    def _regrow_shards(self, snap: GraphSnapshot) -> None:
        """Rebuild the shard layout at 2× per-shard capacity (one re-trace).

        VDC's per-edge-cell J store follows its edges to the new cells; cells
        without a surviving edge start empty (the implicit-``j0`` fallback is
        exact for both fresh inserts and vacated cells)."""
        old = self._shard_index
        self._shard_index = ShardIndex(
            snap, self.num_shards, min_capacity=old.shard_capacity * 2
        )
        if self.state.jstore is not None:
            size = self.num_shards * self._shard_index.shard_capacity
            idx = np.full(size, -1, np.int32)
            for slot, lin in self._shard_index.cell_of.items():
                idx[lin] = old.cell_of.get(slot, -1)
            self.state = self.state._replace(
                jstore=ds.gather_rows(self.state.jstore, jnp.asarray(idx))
            )

    def _run(self, dirty: np.ndarray) -> None:
        self.state, stats = self._maintain(self.state, self.g, jnp.asarray(dirty))
        self.last_stats = jax.tree.map(jax.device_get, stats)

    def _run_counted(self, dirty: np.ndarray) -> None:
        """_run + fold the sweep into the cumulative recompute-volume signal
        (the batched path folds its own totals, fallback sweeps included)."""
        self._run(dirty)
        self._sched_total += int(self.last_stats.scheduled)

    def _dirty_mask(self, touched, snap: GraphSnapshot) -> np.ndarray:
        dirty = np.zeros(self.cfg.num_vertices, dtype=bool)
        for (u, v) in touched:
            dirty[v] = True
            if self.cfg.weight_from_degree:
                # outdeg(src) changed → every out-message of src retunes
                dirty[snap.dst[(snap.src == u) & snap.valid]] = True
        return dirty

    # ------------------------------------------------------------- ingestion
    def apply_updates(self, updates) -> MaintainStats:
        """Ingest one δE batch and maintain all registered queries."""
        with obs_trace.span(
            "sweep", "sweep", pid="engine:dense", shards=self.num_shards
        ) as sp:
            ops = self.graph.apply_batch_resolved(updates)
            snap = self.graph.snapshot()
            if self.num_shards > 1:
                self._shard_sync(ops, snap)  # keep cells stable (VDC)
            self.g = self._device_graph(snap)
            touched = [(u, v) for (_k, _s, u, v, _w) in ops]
            self._run_counted(self._dirty_mask(touched, snap))
            sp.set(num_updates=len(ops), **_span_stats(self.last_stats))
        return self.last_stats

    def _full_sweep_fallback(self, ops, total: MaintainStats) -> MaintainStats:
        """Re-upload the full device graph and run one host-path sweep (the
        once-per-growth escape hatch of the batched stream)."""
        with obs_trace.span(
            "full_sweep_fallback", "sweep", pid="engine:dense", num_ops=len(ops)
        ):
            snap = self.graph.snapshot()
            self.g = self._device_graph(snap)
            touched = [(u, v) for (_k, _s, u, v, _w) in ops]
            self._run(self._dirty_mask(touched, snap))
        return _sum_stats(total, self.last_stats)

    def apply_updates_batched(
        self, updates, batch_size: int | None = None
    ) -> MaintainStats:
        """Stream a δE log through the donated-buffer batched step.

        The log is folded in fixed-shape chunks of ``batch_size`` (default:
        ``batch_capacity``); per chunk ONE jitted call scatters the edge
        slots, refreshes degrees, builds the dirty mask on device and runs
        the maintenance sweep.  Returns the cumulative stats over the log.
        """
        b = int(batch_size if batch_size is not None else self.batch_capacity)
        updates = list(updates)
        total = zeros_stats()
        with obs_trace.span(
            "update_batch",
            "update_batch",
            pid="engine:dense",
            num_updates=len(updates),
            chunk_size=b,
            shards=self.num_shards,
        ) as outer:
            for lo in range(0, len(updates), b):
                ops = self.graph.apply_batch_resolved(updates[lo : lo + b])
                if not ops:
                    continue
                shard_writes = None
                if self.num_shards > 1:
                    shard_writes = self._shard_sync(ops)
                    if shard_writes is None:
                        # a shard's cells overflowed: layout regrown (jstore
                        # rows permuted), one full-view sweep for this chunk
                        total = self._full_sweep_fallback(ops, total)
                        continue
                ell_writes: list = []
                if self.cfg.backend in ("ell", "fused"):
                    try:
                        ell_writes = self._ell_index.writes_for(ops)
                    except EllOverflow:
                        # a vertex outran the fixed D: grow geometrically and
                        # fall back to a full-view sweep (one re-trace)
                        self._ell_width = max(8, self._ell_width * 2)
                        total = self._full_sweep_fallback(ops, total)
                        continue
                upd = self._encode_chunk(ops, ell_writes, b, shard_writes)
                # the sweep span covers one chunk's maintenance sweep; the
                # nested dispatch span is the jitted call itself.  Per-chunk
                # stats stay on device (one host sync per log) — the outer
                # update_batch span carries the cumulative counters.
                with obs_trace.span(
                    "sweep", "sweep", pid="engine:dense",
                    chunk_lo=lo, num_ops=len(ops),
                ):
                    with obs_trace.span(
                        "kernel_dispatch",
                        "kernel_dispatch",
                        pid="engine:dense",
                        chunk_lo=lo,
                        num_ops=len(ops),
                        backend=self.cfg.backend,
                    ):
                        self.state, self.g, stats = self._step(
                            self.state, self.g, upd
                        )
                    # accumulate on device — one host sync per log, not per chunk
                    total = _sum_stats(total, stats)
            self.last_stats = jax.tree.map(jax.device_get, total)
            outer.set(**_span_stats(self.last_stats))
        self._sched_total += int(self.last_stats.scheduled)
        return self.last_stats

    def _encode_chunk(self, ops, ell_writes, b: int, shard_writes=None) -> UpdateBatch:
        """Host O(B) encode of resolved ops → fixed-shape UpdateBatch."""
        if len(ops) > b:
            raise ValueError(f"chunk of {len(ops)} ops exceeds capacity {b}")
        v = self.cfg.num_vertices
        cap = (
            self.num_shards * self._shard_index.shard_capacity
            if shard_writes is not None
            else self.graph.capacity
        )
        slot = np.full(b, cap, np.int32)
        src = np.zeros(b, np.int32)
        dst = np.zeros(b, np.int32)
        weight = np.zeros(b, np.float32)
        valid = np.zeros(b, bool)
        dirty_v = np.full(b, v, np.int32)
        touched_src = np.full(b, v, np.int32)
        ell_row = np.full(b, v, np.int32)
        ell_col = np.zeros(b, np.int32)
        ell_nbr = np.zeros(b, np.int32)
        ell_wv = np.zeros(b, np.float32)
        if shard_writes is not None:
            # sharded layout: coalesced cell writes carry the final contents
            for j, wr in enumerate(shard_writes):
                slot[j] = wr.lin
                src[j], dst[j] = wr.src, wr.dst
                weight[j], valid[j] = wr.weight, wr.valid
        else:
            # final slot contents come from the already-updated host graph, so
            # a delete+reinsert of one slot inside a chunk coalesces to one row
            for j, s in enumerate(dict.fromkeys(op[1] for op in ops)):
                slot[j] = s
                src[j] = self.graph.src[s]
                dst[j] = self.graph.dst[s]
                weight[j] = self.graph.weight[s]
                valid[j] = self.graph.valid[s]
        for j, (_kind, _s, u, d, _w) in enumerate(ops):
            dirty_v[j] = d
            touched_src[j] = u
        for j, wr in enumerate(ell_writes):
            ell_row[j], ell_col[j] = wr.row, wr.col
            ell_nbr[j], ell_wv[j] = wr.nbr_val, wr.w_val
        return UpdateBatch(
            slot=jnp.asarray(slot),
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            weight=jnp.asarray(weight),
            valid=jnp.asarray(valid),
            dirty_v=jnp.asarray(dirty_v),
            touched_src=jnp.asarray(touched_src),
            ell_row=jnp.asarray(ell_row),
            ell_col=jnp.asarray(ell_col),
            ell_nbr=jnp.asarray(ell_nbr),
            ell_w=jnp.asarray(ell_wv),
        )

    # ------------------------------------------------------- query slot pool
    def _clear_slot_state(self, st: EngineState, slot: int) -> EngineState:
        """Zero every per-slot row: diff stores, DroppedVT, repair counts."""

        def clear_store(store: ds.DiffStore) -> ds.DiffStore:
            return ds.DiffStore(
                iters=store.iters.at[slot].set(ds.IMAX),
                vals=store.vals.at[slot].set(0.0),
                count=store.count.at[slot].set(0),
            )

        drop = st.drop
        if drop.det is not None:
            drop = drop._replace(det=clear_store(drop.det))
        if drop.flt is not None:
            drop = drop._replace(
                flt=drop.flt._replace(drop.flt.bits.at[slot].set(False))
            )
        return st._replace(
            dstore=clear_store(st.dstore),
            jstore=None if st.jstore is None else clear_store(st.jstore),
            drop=drop,
            repair_counts=st.repair_counts.at[slot].set(0),
        )

    def register_slot(
        self,
        init_row: np.ndarray | Array,
        drop_cfg: dr.DropConfig | None = None,
        materialize_join: bool | None = None,
    ) -> int:
        """Claim a slot for a new query and compute its trace in-engine.

        ``init_row`` is the query's D_0 ([V]); ``drop_cfg`` its selection
        policy (default: the engine's).  The slot's trace is initialized by
        one maintenance sweep whose dirty mask seeds only the new row — the
        sweep *is* the static IFE run for that query while every other
        registered query is scheduled for zero work.  Returns the slot id.
        """
        return self.register_slots([(init_row, drop_cfg, materialize_join)])[0]

    def register_slots(self, requests: list[tuple]) -> list[int]:
        """Batch form of :meth:`register_slot`: claim one slot per
        (init_row, drop_cfg[, materialize_join]) request and initialize ALL
        the new traces in a single maintenance sweep (the per-query dirty
        mask seeds exactly the new rows).  ``materialize_join`` gates the
        slot's Join store on vdc engines (None → materialize)."""
        requests = [
            (req[0], req[1], req[2] if len(req) > 2 else None)
            for req in requests
        ]
        for _row, drop_cfg, _jm in requests:
            if drop_cfg is not None and drop_cfg.enabled():
                if drop_cfg.mode != self.cfg.drop.mode:
                    raise ValueError(
                        f"plan drop mode {drop_cfg.mode!r} does not match the "
                        f"engine's DroppedVT representation "
                        f"{self.cfg.drop.mode!r}"
                    )
        while len(self._free_slots) < len(requests):
            self._grow_queries()
        slots = []
        st = self.state
        for init_row, drop_cfg, join_flag in requests:
            slot = self._free_slots.pop()
            row = jnp.asarray(init_row, jnp.float32)
            st = self._clear_slot_state(st, slot)
            st = st._replace(
                init=st.init.at[slot].set(row),
                cur=st.cur.at[slot].set(row),
                active=st.active.at[slot].set(True),
            )
            if st.join_mat is not None:
                st = st._replace(
                    join_mat=st.join_mat.at[slot].set(
                        True if join_flag is None else bool(join_flag)
                    )
                )
            if st.drop.params is not None:
                st = st._replace(
                    drop=st.drop._replace(
                        params=dr.set_params_row(
                            st.drop.params,
                            slot,
                            drop_cfg if drop_cfg is not None else self.cfg.drop,
                        )
                    )
                )
            slots.append(slot)
        self.state = st
        dirty = np.zeros((self.cfg.num_queries, self.cfg.num_vertices), bool)
        dirty[slots] = True
        self._run_counted(dirty)
        return slots

    def deregister_slot(self, slot: int) -> int:
        """Retire a query slot: zero its diff-store rows, free the slot.

        Returns the accounted difference bytes released (the slot's D/J/
        DroppedVT rows; Bloom bits are fixed-size and only zeroed).
        """
        if not bool(np.asarray(self.state.active)[slot]):
            raise ValueError(f"slot {slot} is not active")
        freed = self.slot_nbytes(slot)
        ident = jnp.full(
            (self.cfg.num_vertices,), self.cfg.semiring.identity, jnp.float32
        )
        st = self._clear_slot_state(self.state, slot)
        st = st._replace(
            init=st.init.at[slot].set(ident),
            cur=st.cur.at[slot].set(ident),
            active=st.active.at[slot].set(False),
        )
        if st.join_mat is not None:  # freed slots rejoin the pool materialized
            st = st._replace(join_mat=st.join_mat.at[slot].set(True))
        if st.drop.params is not None:
            st = st._replace(
                drop=st.drop._replace(
                    params=dr.set_params_row(st.drop.params, slot, dr.DropConfig())
                )
            )
        if st.drop.det is not None:
            # re-anchor the dropped-VT horizon from the surviving rows so a
            # retired heavy-drop query stops inflating every later sweep's
            # trip count (Bloom mode keeps the old anchor: bits can't delete)
            live = jnp.where(st.drop.det.iters < ds.IMAX, st.drop.det.iters, -1)
            st = st._replace(drop=st.drop._replace(max_iter=live.max()))
        self.state = st
        self._free_slots.append(slot)
        self._free_slots.sort(reverse=True)
        return freed

    def slot_nbytes(self, slot: int) -> int:
        """Accounted difference bytes held by one query slot: its D/J diff
        rows, its DroppedVT records (det rows, or its packed Bloom row), and
        its DropParams row — so summing over the live slots reproduces
        :func:`nbytes_accounted` exactly."""
        total = int(np.asarray(self.state.dstore.count[slot]).sum()) * 8
        if self.state.jstore is not None:
            total += int(np.asarray(self.state.jstore.count[slot]).sum()) * 8
        if self.state.drop.det is not None:
            total += int(np.asarray(self.state.drop.det.count[slot]).sum()) * 4
        if self.cfg.drop.enabled() and bool(np.asarray(self.state.active)[slot]):
            if self.state.drop.flt is not None:
                total += (self.state.drop.flt.num_bits + 7) // 8
            if self.state.drop.params is not None:
                total += dr.PARAMS_ROW_NBYTES
        return total

    def nbytes_per_query(self) -> dict[int, int]:
        """slot → accounted bytes, for every live slot (the governor's
        per-[Q] memory breakdown).  One device→host pull per array — this
        runs on every enforcement pass, so per-slot fetches would cost
        O(Q) syncs per batch."""
        per = np.asarray(self.state.dstore.count).sum(axis=1) * 8
        if self.state.jstore is not None:
            per = per + np.asarray(self.state.jstore.count).sum(axis=1) * 8
        if self.state.drop.det is not None:
            per = per + np.asarray(self.state.drop.det.count).sum(axis=1) * 4
        fixed = 0
        if self.cfg.drop.enabled():
            if self.state.drop.flt is not None:
                fixed += (self.state.drop.flt.num_bits + 7) // 8
            if self.state.drop.params is not None:
                fixed += dr.PARAMS_ROW_NBYTES
        return {s: int(per[s]) + fixed for s in self.active_slots()}

    def nbytes_per_operator(self) -> dict[int, dict[str, int]]:
        """slot → {op_id → accounted bytes}: the per-query breakdown refined
        to the operators that own difference stores.  ``"iterate"`` carries
        the change-point rows plus the slot's DroppedVT/params footprint;
        ``"join"`` (vdc engines) its J-store rows.  Per slot the operator
        bytes sum exactly to :meth:`nbytes_per_query`'s entry."""
        per_d = np.asarray(self.state.dstore.count).sum(axis=1) * 8
        if self.state.drop.det is not None:
            per_d = per_d + np.asarray(self.state.drop.det.count).sum(axis=1) * 4
        fixed = 0
        if self.cfg.drop.enabled():
            if self.state.drop.flt is not None:
                fixed += (self.state.drop.flt.num_bits + 7) // 8
            if self.state.drop.params is not None:
                fixed += dr.PARAMS_ROW_NBYTES
        per_j = (
            None
            if self.state.jstore is None
            else np.asarray(self.state.jstore.count).sum(axis=1) * 8
        )
        out: dict[int, dict[str, int]] = {}
        for s in self.active_slots():
            ops = {"iterate": int(per_d[s]) + fixed}
            if per_j is not None:
                ops["join"] = int(per_j[s])
            out[s] = ops
        return out

    def recompute_cost_per_query(self) -> dict[int, int]:
        """slot → cumulative dropped-diff repair count (the engine's cheap
        online recompute-cost signal, Fig. 6b's counter per query row)."""
        per = np.asarray(self.state.repair_counts).sum(axis=1)
        return {s: int(per[s]) for s in self.active_slots()}

    def recompute_cost_per_operator(self) -> dict[int, dict[str, int]]:
        """slot → {op_id → cumulative recompute cost}.  ``"iterate"`` is the
        slot's dropped-diff repair count; ``"join"`` (vdc engines) the
        cumulative scheduled vertex-rerun volume apportioned evenly across
        live slots — message recomputation tracks sweep breadth, which is
        shared, so the join signal ranks queries by bytes alone."""
        per = np.asarray(self.state.repair_counts).sum(axis=1)
        live = self.active_slots()
        share = self._sched_total // max(len(live), 1)
        out: dict[int, dict[str, int]] = {}
        for s in live:
            ops = {"iterate": int(per[s])}
            if self.state.jstore is not None:
                ops["join"] = int(share)
            out[s] = ops
        return out

    def set_join_store(self, slot: int, materialize: bool) -> int:
        """Flip one slot's Join-operator storage policy (vdc engines).

        ``materialize=False`` drops the slot's join differences completely
        (§4): its J-store rows are zeroed — the accounted bytes released are
        returned — and subsequent sweeps recompute its messages on demand
        (``join_mat`` is a traced [Q] row: no recompile).  No DroppedVT
        record is needed: complete dropping is deterministic, so repair
        needs no per-record memory.

        ``materialize=True`` re-materializes: the slot's ``cur`` is reset to
        its D_0 and one maintenance sweep re-walks the stored trajectory
        (register-convergence), rewriting the J rows as it goes.  Answers
        are recomputed exactly; returns 0.
        """
        if not bool(np.asarray(self.state.active)[slot]):
            raise ValueError(f"slot {slot} is not active")
        if self.state.jstore is None:
            if materialize:
                raise ValueError(
                    "engine built without a join store (mode='jod'); open "
                    "the session with a join-materializing plan in the "
                    "first registered batch"
                )
            return 0  # JOD engines hold no join differences to begin with
        already = bool(np.asarray(self.state.join_mat)[slot])
        if materialize == already:
            return 0
        st = self.state
        if not materialize:
            freed = int(np.asarray(st.jstore.count[slot]).sum()) * 8
            jstore = ds.DiffStore(
                iters=st.jstore.iters.at[slot].set(ds.IMAX),
                vals=st.jstore.vals.at[slot].set(0.0),
                count=st.jstore.count.at[slot].set(0),
            )
            self.state = st._replace(
                jstore=jstore, join_mat=st.join_mat.at[slot].set(False)
            )
            return freed
        self.state = st._replace(
            cur=st.cur.at[slot].set(st.init[slot]),
            join_mat=st.join_mat.at[slot].set(True),
        )
        dirty = np.zeros((self.cfg.num_queries, self.cfg.num_vertices), bool)
        dirty[slot] = True
        self._run_counted(dirty)
        return 0

    def set_drop_params(
        self, slot: int, drop_cfg: dr.DropConfig, op_id: str = "iterate"
    ) -> int:
        """Rewrite a LIVE slot's drop policy for ONE operator.

        ``op_id="iterate"`` (default) rewrites the slot's §5 selection
        params in place (no recompile — the params are traced ``[Q]`` rows)
        and sheds its stored diffs under the new policy.  ``op_id="join"``
        routes to :meth:`set_join_store` — an enabled config (complete
        dropping) drops the slot's join trace, a disabled one
        re-materializes it.  Returns the accounted bytes released (≥ 0 for
        iterate: a shed trades 8 B change points for ≤4 B DroppedVT records
        or Bloom bits).
        """
        if op_id == "join":
            if drop_cfg.enabled() and not drop_cfg.drops_all():
                raise ValueError(
                    "the join's differences drop completely (p ≥ 1); "
                    "partial join dropping is unsupported"
                )
            return self.set_join_store(slot, not drop_cfg.enabled())
        if op_id != "iterate":
            raise ValueError(
                f"operator {op_id!r} owns no engine difference store"
            )
        if not bool(np.asarray(self.state.active)[slot]):
            raise ValueError(f"slot {slot} is not active")
        if self.state.drop.params is None:
            if drop_cfg.enabled():
                raise ValueError(
                    "cannot enable dropping on an engine built without a "
                    "DroppedVT representation (cfg.drop.mode='none')"
                )
            return 0
        if drop_cfg.enabled() and drop_cfg.mode != self.cfg.drop.mode:
            raise ValueError(
                f"drop mode {drop_cfg.mode!r} does not match the engine's "
                f"DroppedVT representation {self.cfg.drop.mode!r}"
            )
        before = self.slot_nbytes(slot)
        self.state = self.state._replace(
            drop=self.state.drop._replace(
                params=dr.set_params_row(self.state.drop.params, slot, drop_cfg)
            )
        )
        if drop_cfg.enabled():
            ovf_before = int(self.state.drop.det_overflow)
            self.state = self._shed(self.state, self.g, jnp.int32(slot))
            self.det_overflow_shed += int(self.state.drop.det_overflow) - ovf_before
        return before - self.slot_nbytes(slot)

    def active_slots(self) -> list[int]:
        return [int(q) for q in np.nonzero(np.asarray(self.state.active))[0]]

    @property
    def slot_capacity(self) -> int:
        return self.cfg.num_queries

    def _grow_queries(self) -> None:
        """Double the slot pool (geometric growth, one re-trace).

        Every [Q, ...] leaf pads along the query axis: stores stay empty,
        init/cur pad with the semiring identity, new slots join the free
        list.  The next dispatch retraces once for the new static Q.
        """
        old_q = self.cfg.num_queries
        new_q = max(1, old_q * 2)
        pad = new_q - old_q

        def padq(x, fill, dtype=None):
            x = np.asarray(x)
            block = np.full((pad, *x.shape[1:]), fill, dtype or x.dtype)
            return jnp.asarray(np.concatenate([x, block], axis=0))

        def pad_store(store: ds.DiffStore) -> ds.DiffStore:
            return ds.DiffStore(
                iters=padq(store.iters, np.iinfo(np.int32).max),
                vals=padq(store.vals, 0.0),
                count=padq(store.count, 0),
            )

        st = self.state
        drop = st.drop
        if drop.det is not None:
            drop = drop._replace(det=pad_store(drop.det))
        if drop.flt is not None:
            drop = drop._replace(flt=drop.flt._replace(padq(drop.flt.bits, False)))
        if drop.params is not None:
            fresh = dr.make_params(self.cfg.drop, pad)
            drop = drop._replace(
                params=dr.DropParams(
                    *(
                        jnp.concatenate([jnp.asarray(a), b])
                        for a, b in zip(drop.params, fresh)
                    )
                )
            )
        ident = self.cfg.semiring.identity
        self.state = EngineState(
            dstore=pad_store(st.dstore),
            jstore=None if st.jstore is None else pad_store(st.jstore),
            drop=drop,
            init=padq(st.init, ident),
            cur=padq(st.cur, ident),
            repair_counts=padq(st.repair_counts, 0),
            active=padq(st.active, False),
            join_mat=None if st.join_mat is None else padq(st.join_mat, True),
        )
        self.cfg = dataclasses.replace(self.cfg, num_queries=new_q)
        self._free_slots.extend(range(new_q - 1, old_q - 1, -1))
        self._build_dispatch()

    # ------------------------------------------------------------ durability
    def export_state(self) -> tuple[dict[str, np.ndarray], dict]:
        """(arrays, meta) snapshot of the difference trace.

        Arrays are *global* (device_get assembles sharded carries) and the
        VDC J store is converted from the mesh-dependent cell layout to the
        canonical edge-slot layout ``[Q, E_cap, S_J]`` — so a checkpoint
        taken at 8 shards is layout-independent and restores at any shard
        count (:meth:`import_state` scatters rows into the new cell layout).
        """
        st = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), self.state)
        arrays: dict[str, np.ndarray] = {}

        def put_store(prefix: str, store: ds.DiffStore) -> None:
            arrays[prefix + "/iters"] = np.asarray(store.iters)
            arrays[prefix + "/vals"] = np.asarray(store.vals)
            arrays[prefix + "/count"] = np.asarray(store.count)

        put_store("dstore", st.dstore)
        if st.jstore is not None:
            jst = st.jstore
            if self._shard_index is not None:
                idx = np.full(self.graph.capacity, -1, np.int32)
                for slot, lin in self._shard_index.cell_of.items():
                    idx[slot] = lin
                jst = jax.tree.map(
                    lambda x: np.asarray(jax.device_get(x)),
                    ds.gather_rows(self.state.jstore, jnp.asarray(idx)),
                )
            put_store("jstore", jst)
        drop = st.drop
        if drop.det is not None:
            put_store("drop_det", drop.det)
        if drop.flt is not None:
            arrays["drop_flt/bits"] = np.asarray(drop.flt.bits)
        arrays["drop/det_overflow"] = np.asarray(drop.det_overflow)
        arrays["drop/max_iter"] = np.asarray(drop.max_iter)
        if drop.params is not None:
            for f in dr.DropParams._fields:
                arrays[f"drop_params/{f}"] = np.asarray(getattr(drop.params, f))
        arrays["init"] = st.init
        arrays["cur"] = st.cur
        arrays["repair_counts"] = st.repair_counts
        arrays["active"] = st.active
        if st.join_mat is not None:
            arrays["join_mat"] = st.join_mat
        meta = {
            "slot_capacity": self.cfg.num_queries,
            "mode": self.cfg.mode,
            "free_slots": [int(s) for s in self._free_slots],
            "det_overflow_shed": int(self.det_overflow_shed),
            "sched_total": int(self._sched_total),
            "ell_width": int(self._ell_width),
        }
        return arrays, meta

    def import_state(self, arrays: dict, meta: dict) -> None:
        """Load a snapshot produced by :meth:`export_state`.

        The engine must have been constructed for the same restored graph
        and slot capacity (an all-inactive pool skips the initial sweep, so
        construction is cheap); the J store is scattered into *this* mesh's
        cell layout and every carry is placed via ``elastic.reshard`` when
        sharded.
        """
        if int(meta["slot_capacity"]) != self.cfg.num_queries:
            raise ValueError(
                f"checkpoint has {meta['slot_capacity']} query slots but the "
                f"engine was built with {self.cfg.num_queries}"
            )

        def get_store(prefix: str) -> ds.DiffStore:
            return ds.DiffStore(
                iters=jnp.asarray(arrays[prefix + "/iters"]),
                vals=jnp.asarray(arrays[prefix + "/vals"]),
                count=jnp.asarray(arrays[prefix + "/count"]),
            )

        jstore = None
        if "jstore/iters" in arrays:
            jstore = get_store("jstore")
            if self._shard_index is not None:
                size = self.num_shards * self._shard_index.shard_capacity
                idx = np.full(size, -1, np.int32)
                for slot, lin in self._shard_index.cell_of.items():
                    idx[lin] = slot
                jstore = ds.gather_rows(jstore, jnp.asarray(idx))
        det = get_store("drop_det") if "drop_det/iters" in arrays else None
        flt = None
        if "drop_flt/bits" in arrays:
            flt = bloom_lib.BloomFilter(
                jnp.asarray(arrays["drop_flt/bits"]), self.cfg.drop.bloom_hashes
            )
        params = None
        if "drop_params/p" in arrays:
            params = dr.DropParams(
                **{
                    f: jnp.asarray(arrays[f"drop_params/{f}"])
                    for f in dr.DropParams._fields
                }
            )
        state = EngineState(
            dstore=get_store("dstore"),
            jstore=jstore,
            drop=dr.DropState(
                det=det,
                flt=flt,
                det_overflow=jnp.asarray(arrays["drop/det_overflow"]),
                max_iter=jnp.asarray(arrays["drop/max_iter"]),
                params=params,
            ),
            init=jnp.asarray(arrays["init"]),
            cur=jnp.asarray(arrays["cur"]),
            repair_counts=jnp.asarray(arrays["repair_counts"]),
            active=jnp.asarray(arrays["active"]),
            join_mat=jnp.asarray(arrays["join_mat"]) if "join_mat" in arrays else None,
        )
        if self.num_shards > 1:
            from repro.runtime import elastic

            state = elastic.reshard(state, _state_pspecs(state), self.mesh)
        self.state = state
        self._free_slots = [int(s) for s in meta["free_slots"]]
        self.det_overflow_shed = int(meta["det_overflow_shed"])
        self._sched_total = int(meta["sched_total"])
        width = int(meta.get("ell_width", 0))
        if self.cfg.backend in ("ell", "fused") and width > self._ell_width:
            # the saved run had grown its bucketed-ELL width; match it so the
            # replayed suffix hits the same compiled shapes
            self._ell_width = width
            self.g = self._device_graph(self.graph.snapshot())
        self.last_stats = None

    # ------------------------------------------------------------------- api
    def answers(self) -> np.ndarray:
        return np.asarray(answers(self.cfg, self.state))

    def answers_row(self, slot: int) -> np.ndarray:
        """One query slot's final vertex states. [V]"""
        return np.asarray(self.state.cur[slot])

    def nbytes(self) -> int:
        return nbytes_accounted(self.cfg, self.state)

    def nbytes_per_device(self) -> list[int]:
        """Accounted bytes per shard of the vertex partition (unsharded: one
        entry — the whole store)."""
        if self.num_shards == 1:
            return [self.nbytes()]
        return nbytes_per_shard(self.cfg, self.state, self.num_shards)
