"""Semirings parameterizing the Iterative Frontier Expansion (IFE) dataflow.

The paper's IFE template (Fig. 1a) is a ``Join`` (per-edge message) feeding an
aggregator (``Min`` for Bellman-Ford, Fig. 1b).  We factor that pair as a
semiring-like structure so one engine serves every query class in the paper
(SPSP/SSSP, K-hop, RPQ, WCC, PageRank):

    new_state[u] = reduce_{(v,u) in E} msg(state[v], w(v,u))   (+ carry of
                   state[u] when ``carry_prev``)

``identity`` is the reduce identity (also the "no value yet" state for
vertices other than the query source).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    # reduce: 'min' | 'sum'  (segment reduction used by the SpMV)
    reduce: str
    # msg(src_state, edge_weight) -> message value
    msg: Callable[[Array, Array], Array]
    # identity element of the reduction (also the implicit initial state of
    # non-source vertices; see DiffStore: init diffs are implicit).
    identity: float
    # Whether D_i includes the vertex's own previous value:
    #   D_i(u) = reduce(msg over in-edges, D_{i-1}(u))       (min queries)
    #   D_i(u) = base + reduce(msg over in-edges)            (PageRank)
    carry_prev: bool = True
    # Additive per-vertex base applied after the reduction (PageRank teleport).
    base: float = 0.0
    # Hop truncation for min_hop: messages past this hop count collapse to the
    # identity (K-hop queries).  inf = no truncation.
    hop_cap: float = float("inf")

    @property
    def kernel_name(self) -> str:
        """Name of this semiring in the Pallas ELL-SpMV kernel."""
        return {"pagerank": "pr_sum"}.get(self.name, self.name)


def min_plus() -> Semiring:
    """Shortest paths: msg = d_v + w, reduce = min."""
    return Semiring(
        name="min_plus",
        reduce="min",
        msg=lambda s, w: s + w,
        identity=float(jnp.inf),
        carry_prev=True,
    )


def min_hop(max_hops: float = jnp.inf) -> Semiring:
    """K-hop / BFS: msg = hops_v + 1, reduce = min.

    ``max_hops`` truncates propagation (a reached vertex at exactly K hops
    does not propagate further); the engine also bounds iterations by K.
    """

    def msg(s, w):  # noqa: ANN001
        del w
        cand = s + 1.0
        return jnp.where(cand > max_hops, jnp.inf, cand)

    return Semiring(
        name="min_hop",
        reduce="min",
        msg=msg,
        identity=float(jnp.inf),
        carry_prev=True,
        hop_cap=float(max_hops),
    )


def min_label() -> Semiring:
    """WCC label propagation: msg = label_v, reduce = min."""
    return Semiring(
        name="min_label",
        reduce="min",
        msg=lambda s, w: s,
        identity=float(jnp.inf),
        carry_prev=True,
    )


def pagerank(alpha: float = 0.85) -> Semiring:
    """Pregel-style PageRank: msg = alpha * pr_v / outdeg_v, reduce = sum.

    The engine passes ``w = alpha / outdeg(src)`` as the edge weight so the
    message is a plain product; teleport enters via ``base``.
    """
    return Semiring(
        name="pagerank",
        reduce="sum",
        msg=lambda s, w: s * w,
        identity=0.0,
        carry_prev=False,
        base=1.0 - alpha,
    )


def reduce_pair(sr: Semiring, a: Array, b: Array) -> Array:
    if sr.reduce == "min":
        return jnp.minimum(a, b)
    if sr.reduce == "sum":
        return a + b
    raise ValueError(f"unknown reduce {sr.reduce!r}")
