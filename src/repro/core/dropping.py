"""Partial difference dropping (paper §5): Det-Drop, Prob-Drop, selection.

Two components, mirroring the paper:

* **Dropped-difference maintenance** — either a deterministic dense store of
  (vertex, iteration) pairs (Det-Drop; hash-table-of-sorted-lists → sorted
  rows, like the diff store but iteration-only), or a Bloom filter
  (Prob-Drop).  Det-Drop keeps ~4 bytes per dropped diff (the paper's
  d/(d+s) scalability floor); Prob-Drop's footprint is fixed.

* **Selection** — Random (Bernoulli p) or Degree (τ_min / τ_max / p,
  §5.2.1).  Decisions use a counter-based stateless hash of
  (seed, query, vertex, iteration) so drop sets are reproducible and
  independent of sharding.

Selection parameters are **per query**: the paper's CQP tunes dropping per
registered query, so (p, τ_min, τ_max, selection, seed) live as ``[Q]``
arrays (:class:`DropParams`) inside :class:`DropState` — a query registered
mid-stream brings its own drop policy without recompiling the sweep.  The
DroppedVT *representation* (Det store vs Bloom filter) and its capacities
stay session-level: they fix array shapes and static branches.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import bloom as bloom_lib
from repro.core import diffstore as ds

Array = jnp.ndarray

# Accounted bytes of one query's DropParams row: p (f32) + tau_min (f32) +
# tau_max (f32) + degree_sel (1 B) + seed (u32).  The governor retunes these
# rows online, so they are live per-query state and count toward the budget.
PARAMS_ROW_NBYTES = 17


@dataclasses.dataclass(frozen=True)
class DropConfig:
    mode: str = "none"  # none | det | prob
    selection: str = "random"  # random | degree
    p: float = 0.0  # drop probability
    tau_min: float = 2.0  # drop everything below (degree policy)
    tau_max: float = float("inf")  # keep everything above (80th pctile)
    det_capacity: int = 32  # S_d (Det-Drop slots per vertex)
    bloom_bits: int = 1 << 16  # per-query filter bits
    bloom_hashes: int = 4
    seed: int = 0

    def enabled(self) -> bool:
        return self.mode != "none"

    def drops_all(self) -> bool:
        """True when this policy selects EVERY candidate difference —
        complete dropping (§4): p ≥ 1 under Random, or p ≥ 1 with no τ_max
        carve-out under Degree (everything at or below τ_max drops by coin,
        below τ_min unconditionally).  Complete dropping is what a Join
        operator's trace supports (all-or-nothing) and what triggers the
        host engine's per-query scratch fallback."""
        return self.enabled() and self.p >= 1.0 and (
            self.selection == "random" or self.tau_max == float("inf")
        )


class DropParams(NamedTuple):
    """Per-query selection parameters (``[Q]`` arrays, traced — not static).

    A registered query's drop policy is a row of these arrays; updating a row
    (register/deregister) never retraces the maintenance sweep.  ``degree_sel``
    encodes the selection strategy (False = Random, True = Degree).
    """

    p: Array  # f32 [Q] — drop probability
    tau_min: Array  # f32 [Q] — degree policy: drop everything below
    tau_max: Array  # f32 [Q] — degree policy: keep everything above
    degree_sel: Array  # bool [Q] — True = Degree selection, False = Random
    seed: Array  # uint32 [Q] — per-query hash seed


def _check_selection(cfg: DropConfig) -> bool:
    if cfg.selection not in ("random", "degree"):
        raise ValueError(f"unknown selection {cfg.selection!r}")
    return cfg.selection == "degree"


def params_row(cfg: DropConfig) -> tuple[float, float, float, bool, int]:
    """One query's selection parameters from its :class:`DropConfig`.

    A disabled config maps to the never-drop row (Random with p = 0).
    """
    degree_sel = _check_selection(cfg)
    if not cfg.enabled():
        return (0.0, 0.0, float("inf"), False, int(cfg.seed))
    return (cfg.p, cfg.tau_min, cfg.tau_max, degree_sel, int(cfg.seed))


def make_params(
    configs: "list[DropConfig] | DropConfig", num_queries: int | None = None
) -> DropParams:
    """Stack per-query configs into :class:`DropParams` arrays.

    A single config broadcasts over ``num_queries`` (the legacy one-global-
    DropConfig behavior, bit-identical to the pre-session engine).
    """
    if isinstance(configs, DropConfig):
        assert num_queries is not None
        configs = [configs] * num_queries
    rows = [params_row(c) for c in configs]
    p, tmin, tmax, sel, seed = zip(*rows)
    return DropParams(
        p=jnp.asarray(p, jnp.float32),
        tau_min=jnp.asarray(tmin, jnp.float32),
        tau_max=jnp.asarray(tmax, jnp.float32),
        degree_sel=jnp.asarray(sel, bool),
        seed=jnp.asarray(seed, jnp.uint32),
    )


def set_params_row(params: DropParams, q: int, cfg: DropConfig) -> DropParams:
    """Return ``params`` with query ``q``'s row replaced by ``cfg``."""
    p, tmin, tmax, sel, seed = params_row(cfg)
    return DropParams(
        p=params.p.at[q].set(p),
        tau_min=params.tau_min.at[q].set(tmin),
        tau_max=params.tau_max.at[q].set(tmax),
        degree_sel=params.degree_sel.at[q].set(sel),
        seed=params.seed.at[q].set(seed),
    )


class DropState(NamedTuple):
    """DroppedVT — tracks dropped (vertex, iteration) pairs."""

    det: ds.DiffStore | None  # iters used; vals carry zeros
    flt: bloom_lib.BloomFilter | None
    det_overflow: Array  # counter: det evictions would lose dropped VTs
    max_iter: Array  # int32 — highest iteration ever dropped (horizon term:
    # dropped change points still bound the engine's upper-bound-rule sweep)
    params: DropParams | None = None  # per-query selection ([Q] rows)

    def nbytes_accounted(self, active: Array | None = None) -> Array:
        """Accounted DroppedVT bytes (paper §5.1 costings), consistently:

        * Det-Drop — 4 B per dropped VT record (inactive rows hold none);
        * Prob-Drop — the packed filter, M/8 B **per live query row** (the
          filter array is [Q, M]: each query owns one row, and a retired
          slot's zeroed row is reclaimable, so it is not charged);
        * plus :data:`PARAMS_ROW_NBYTES` per live query for the selection
          rows themselves (the governor rewrites them online).

        ``active`` is the live-slot mask (default: every row counts — the
        legacy fixed-batch engines have no slot pool).
        """
        total = jnp.zeros((), jnp.int32)
        nrows = None
        if self.params is not None:
            nrows = (
                jnp.asarray(self.params.p.shape[0], jnp.int32)
                if active is None
                else jnp.asarray(active, bool).sum().astype(jnp.int32)
            )
            total = total + nrows * PARAMS_ROW_NBYTES
        if self.det is not None:
            return total + self.det.count.sum() * 4  # d bytes per dropped VT
        assert self.flt is not None
        per_row = (self.flt.num_bits + 7) // 8
        if nrows is None:
            nrows = (
                jnp.asarray(self.flt.bits.shape[0], jnp.int32)
                if active is None
                else jnp.asarray(active, bool).sum().astype(jnp.int32)
            )
        return total + nrows * per_row


def make_state(
    cfg: DropConfig,
    num_queries: int,
    num_keys: int,
    per_query: "list[DropConfig] | None" = None,
) -> DropState:
    """DroppedVT state for ``num_queries`` slots.

    ``cfg`` fixes the representation (mode, capacities); ``per_query``
    optionally supplies each slot's selection parameters (default: ``cfg``
    broadcast — the legacy uniform policy).
    """
    if cfg.mode not in ("none", "det", "prob"):
        raise ValueError(f"unknown drop mode {cfg.mode!r}")
    z = jnp.zeros((), jnp.int32)
    neg = jnp.full((), -1, jnp.int32)
    if not cfg.enabled():
        return DropState(det=None, flt=None, det_overflow=z, max_iter=neg)
    params = make_params(per_query if per_query is not None else cfg, num_queries)
    if cfg.mode == "det":
        return DropState(
            det=ds.make((num_queries, num_keys), cfg.det_capacity),
            flt=None,
            det_overflow=z,
            max_iter=neg,
            params=params,
        )
    return DropState(
        det=None,
        flt=bloom_lib.make((num_queries,), cfg.bloom_bits, cfg.bloom_hashes),
        det_overflow=z,
        max_iter=neg,
        params=params,
    )


def _uniform01(seed: Array | int, q: Array, v: Array, i: Array) -> Array:
    """Deterministic per-(seed, q, v, i) uniform in [0, 1).

    ``seed`` may be a scalar or a per-query array broadcasting against ``q``;
    a uniform seed array produces bit-identical draws to the legacy scalar.
    """
    h = bloom_lib._mix(
        jnp.asarray(v, jnp.uint32)
        ^ bloom_lib._mix(jnp.asarray(i, jnp.uint32) * np.uint32(0x9E3779B9))
        ^ bloom_lib._mix(jnp.asarray(q, jnp.uint32) + jnp.asarray(seed, jnp.uint32))
    )
    return h.astype(jnp.float32) / float(2**32)


def select_to_drop(
    params: DropParams, degree: Array, q: Array, v: Array, i: Array
) -> Array:
    """Which candidate differences to drop (paper §5.2, Fig. 3).

    ``degree`` broadcasts against q/v/i (total degree of the vertex); the
    per-query rows of ``params`` broadcast over the vertex axis, so one fused
    evaluation serves every registered query's own policy.
    """
    u = _uniform01(params.seed[:, None], q, v, i)
    coin = u < params.p[:, None]
    by_degree = jnp.where(
        degree < params.tau_min[:, None],
        True,
        jnp.where(degree > params.tau_max[:, None], False, coin),
    )
    return jnp.where(params.degree_sel[:, None], by_degree, coin)


def select_stored_to_drop(
    params: DropParams, degree: Array, iters: Array, imax
) -> Array:
    """Which *stored* change points to shed under the current params. [Q,V,S]

    The governor escalates a query's policy mid-stream; already-stored diffs
    must then be re-audited with the SAME stateless coin the sweep uses —
    ``_uniform01(seed, q, v, i)`` — so a shed drops exactly the points the
    escalated policy would have dropped at write time (drop sets stay nested
    in p, and decisions stay independent of sharding).  ``iters`` is the
    diff-store iteration tensor; rows padded with ``imax`` never select.
    """
    q, v, s = iters.shape
    v_ids = jnp.broadcast_to(
        jnp.arange(v, dtype=jnp.int32)[None, :, None], (q, v, s)
    ).reshape(q, v * s)
    deg = jnp.broadcast_to(
        jnp.asarray(degree, jnp.float32)[None, :, None], (q, v, s)
    ).reshape(q, v * s)
    q_ids = jnp.arange(q, dtype=jnp.int32)[:, None]
    sel = select_to_drop(params, deg, q_ids, v_ids, iters.reshape(q, v * s))
    return sel.reshape(q, v, s) & (iters < imax)


def register(
    state: DropState, i: Array | int, mask: Array, v_offset: Array | int = 0
) -> DropState:
    """Record dropped VT pairs (v, i) where ``mask`` [Q, V].

    ``i`` may be a scalar iteration or a per-(q, v) array (evictions drop
    each row's own oldest iteration).  ``v_offset`` maps the mask's local
    vertex axis to global vertex ids (vertex-sharded sweep: each shard
    registers only its own partition, hashed by global id so the Bloom bit
    pattern is independent of sharding).
    """
    hi = jnp.where(mask, jnp.asarray(i, jnp.int32), -1).max()
    max_iter = jnp.maximum(state.max_iter, hi)
    if state.det is not None:
        det, evicted, _ = ds.upsert(
            state.det, jnp.asarray(i, jnp.int32), mask, jnp.zeros(mask.shape, jnp.float32)
        )
        return state._replace(
            det=det,
            det_overflow=state.det_overflow + evicted.sum(),
            max_iter=max_iter,
        )
    if state.flt is not None:
        qn, vn = mask.shape
        v_ids = v_offset + jnp.broadcast_to(
            jnp.arange(vn, dtype=jnp.int32)[None, :], (qn, vn)
        )
        it = jnp.broadcast_to(jnp.asarray(i, jnp.int32), (qn, vn))
        salt = jnp.arange(qn, dtype=jnp.int32)[:, None]
        flt = bloom_lib.insert(state.flt, v_ids, it, mask, salt=salt)
        return state._replace(flt=flt, max_iter=max_iter)
    return state


def unregister(state: DropState, i: Array | int, mask: Array) -> DropState:
    """Remove dropped records at (v, i) — only possible deterministically.

    Bloom filters cannot delete; stale positives are harmless (the recompute
    reproduces the stored/current value — see DESIGN.md §2 precedence rule).
    """
    if state.det is not None:
        return state._replace(det=ds.remove_at(state.det, jnp.asarray(i, jnp.int32), mask))
    return state


def dropped_at(
    state: DropState, i: Array | int, num_vertices: int, v_offset: Array | int = 0
) -> Array:
    """Mask [Q, V]: was a diff for (v, i) dropped? (Prob: may false-positive.)

    ``num_vertices`` is the extent of the (possibly shard-local) vertex axis;
    ``v_offset`` shifts it to global ids for the Bloom probe.
    """
    if state.det is not None:
        return ds.has_at(state.det, jnp.asarray(i, jnp.int32))
    if state.flt is not None:
        qn = state.flt.bits.shape[0]
        v_ids = v_offset + jnp.broadcast_to(
            jnp.arange(num_vertices, dtype=jnp.int32)[None, :], (qn, num_vertices)
        )
        it = jnp.full((qn, num_vertices), i, dtype=jnp.int32)
        salt = jnp.arange(qn, dtype=jnp.int32)[:, None]
        return bloom_lib.query(state.flt, v_ids, it, salt=salt)
    raise ValueError("dropped_at called with dropping disabled")


def latest_dropped_le(
    state: DropState, i: int, num_vertices: int
) -> tuple[Array, Array]:
    """(found, iter) of the latest dropped VT at iteration ≤ i.

    Paper's AccessDᵢᵛWithDrops step 2.  For Prob-Drop this probes each
    iteration from i downward (§5.1.2) — vectorized as an all-iteration probe
    plus an argmax.
    """
    if state.det is not None:
        _, it, found = ds.lookup_le(state.det, jnp.int32(i))
        return found, it
    if state.flt is not None:
        hits = jnp.stack(
            [dropped_at(state, j, num_vertices) for j in range(i + 1)], axis=-1
        )  # [Q, V, i+1]
        found = hits.any(axis=-1)
        it = jnp.where(
            found, (i) - jnp.argmax(hits[..., ::-1], axis=-1), -1
        )
        return found, it.astype(jnp.int32)
    raise ValueError("dropping disabled")
