"""Recompute-cost telemetry — the governor's cheap online signals.

The paper's memory/recompute trade-off (§5) is governed offline: a human
picks p/τ per query.  Operating it closed-loop needs an online estimate of
what dropping *costs* each query, without instrumenting the sweep beyond
what it already counts.  Three signal families ride for free:

* **per-query repairs** — the dense engine's ``repair_counts`` rows (host:
  per-slot aggregator-rerun counters): dropped-diff recomputations actually
  paid, the direct marginal cost of that query's drop policy;
* **sweep shape** — ``MaintainStats`` scalars per update batch: iterations
  run (dropped change points extend the upper-bound horizon), scheduled /
  dirty-front sizes (work breadth), repairs;
* **safety** — ``det_overflow`` deltas: DroppedVT records lost to Det-Drop
  evictions, i.e. (v, i) pairs no longer repairable.  A query whose
  escalation coincides with overflow growth is flagged, and the governor
  backs off escalating it further.

Counters arrive cumulative; :class:`RecomputeTelemetry` differences them per
observation and folds the per-update rates into EWMAs, so the governor ranks
queries by *recent* recompute pressure, not lifetime totals.
"""

from __future__ import annotations

import dataclasses

from repro.obs import metrics as obs_metrics


def _ewma(old: float | None, new: float, alpha: float) -> float:
    return new if old is None else (1.0 - alpha) * old + alpha * new


@dataclasses.dataclass
class _QuerySignals:
    cost_total: int = 0  # last cumulative recompute counter seen
    cost_rate: float | None = None  # EWMA of recompute work per update
    nbytes: int = 0  # last per-query accounted bytes seen


class RecomputeTelemetry:
    """EWMA tracker over per-query recompute cost and global sweep signals.

    ``observe`` is called once per enforcement pass with the session's
    cumulative per-query counters and the last ``MaintainStats``-like
    object; ``cost_rate(qid)`` is the governor's ranking signal (recent
    recompute work per ingested update, higher = more expensive to escalate).
    """

    GLOBAL_FIELDS = ("iters_run", "scheduled", "repairs", "det_overflow")

    def __init__(self, alpha: float = 0.5) -> None:
        self.alpha = float(alpha)
        self._per_query: dict[int, _QuerySignals] = {}
        self._updates_seen = 0
        self._global: dict[str, float] = {}
        self._last_stats_id: int | None = None
        self.det_overflow_total = 0
        self.observations = 0

    # ----------------------------------------------------------- ingestion
    def observe(
        self,
        *,
        nbytes_per_query: dict[int, int],
        cost_per_query: dict[int, int],
        stats=None,
        updates_applied: int = 0,
    ) -> None:
        """Fold one enforcement pass's counters into the EWMAs.

        ``cost_per_query`` is cumulative per qid (monotone while a query
        lives); ``updates_applied`` is the session's cumulative ingested
        update count, used to normalize deltas into per-update rates.

        Enforcement passes fire after EVERY session mutation, including
        register/deregister passes that ran no new sweep: an already-seen
        ``stats`` object (identity-tracked) is not re-folded — re-counting
        it would double the per-sweep ``det_overflow`` delta — and the cost
        EWMAs only fold when new updates were actually ingested (otherwise
        a churn-heavy phase would dilute every rate toward zero).
        """
        live = set(nbytes_per_query)
        for qid in list(self._per_query):
            if qid not in live:
                del self._per_query[qid]  # deregistered
        updates_new = updates_applied > self._updates_seen
        d_updates = max(updates_applied - self._updates_seen, 1)
        self._updates_seen = max(self._updates_seen, updates_applied)
        for qid, nbytes in nbytes_per_query.items():
            sig = self._per_query.setdefault(qid, _QuerySignals())
            sig.nbytes = int(nbytes)
            if updates_new:
                cost = int(cost_per_query.get(qid, 0))
                delta = max(cost - sig.cost_total, 0)
                sig.cost_total = cost
                sig.cost_rate = _ewma(
                    sig.cost_rate, delta / d_updates, self.alpha
                )
        if stats is not None and id(stats) != self._last_stats_id:
            self._last_stats_id = id(stats)
            for field in self.GLOBAL_FIELDS:
                val = getattr(stats, field, None)
                if val is None:
                    continue
                self._global[field] = _ewma(
                    self._global.get(field), float(val), self.alpha
                )
            ovf = getattr(stats, "det_overflow", None)
            if ovf is not None:
                self.det_overflow_total += int(ovf)
        self.observations += 1
        self._publish()

    def _publish(self) -> None:
        """Mirror the EWMAs into the obs metrics registry — telemetry is a
        *consumer* of the unified registry, not a parallel surface."""
        reg = obs_metrics.get_registry()
        g = reg.gauge(
            "cqp_telemetry_ewma", "recompute-telemetry EWMAs, by signal"
        )
        for field, val in self._global.items():
            g.set(val, signal=field)
        rate = reg.gauge(
            "cqp_recompute_cost_rate",
            "EWMA recompute work per ingested update, per (query, operator)",
        )
        for key, sig in self._per_query.items():
            if sig.cost_rate is None:
                continue
            if isinstance(key, tuple):
                rate.set(sig.cost_rate, qid=key[0], op=key[1])
            else:
                rate.set(sig.cost_rate, qid=key)
        reg.gauge(
            "cqp_det_overflow_total",
            "DroppedVT records lost to Det-Drop evictions (unrepairable)",
        ).set(self.det_overflow_total)

    # ----------------------------------------------------------------- api
    def cost_rate(self, qid: int) -> float:
        sig = self._per_query.get(qid)
        return 0.0 if sig is None or sig.cost_rate is None else sig.cost_rate

    def global_ewma(self, field: str, default: float = 0.0) -> float:
        """Sweep-shape EWMA (``GLOBAL_FIELDS``) — the planner's cost model
        reads ``iters_run``/``scheduled`` to price recompute strategies."""
        return float(self._global.get(field, default))

    def bytes_held(self, qid: int) -> int:
        sig = self._per_query.get(qid)
        return 0 if sig is None else sig.nbytes

    # ------------------------------------------------------------ durability
    def state_dict(self) -> dict:
        """JSON-able full state (EWMAs as exact float reprs via JSON doubles)."""
        return {
            "alpha": self.alpha,
            "updates_seen": self._updates_seen,
            "global": dict(self._global),
            "det_overflow_total": self.det_overflow_total,
            "observations": self.observations,
            "per_query": [
                {
                    "key": list(k) if isinstance(k, tuple) else k,
                    "cost_total": sig.cost_total,
                    "cost_rate": sig.cost_rate,
                    "nbytes": sig.nbytes,
                }
                for k, sig in self._per_query.items()
            ],
        }

    def load_state(self, state: dict) -> None:
        self.alpha = float(state["alpha"])
        self._updates_seen = int(state["updates_seen"])
        self._global = {k: float(v) for k, v in state["global"].items()}
        self.det_overflow_total = int(state["det_overflow_total"])
        self.observations = int(state["observations"])
        self._per_query = {}
        for entry in state["per_query"]:
            k = entry["key"]
            key = tuple(k) if isinstance(k, list) else k
            self._per_query[key] = _QuerySignals(
                cost_total=int(entry["cost_total"]),
                cost_rate=(
                    None if entry["cost_rate"] is None else float(entry["cost_rate"])
                ),
                nbytes=int(entry["nbytes"]),
            )
        # the stats object identity from the saved process is meaningless
        # here; None means the next observe() folds its stats exactly once —
        # the same thing the uninterrupted run would have done next
        self._last_stats_id = None

    def snapshot(self) -> dict:
        """JSON-friendly view for serving telemetry."""

        def fmt(key) -> str:
            # the governor meters (qid, op_id) keys; legacy callers use qids
            return "/".join(str(p) for p in key) if isinstance(key, tuple) else str(key)

        return {
            "observations": self.observations,
            "det_overflow_total": self.det_overflow_total,
            "global_ewma": {k: round(v, 3) for k, v in self._global.items()},
            "per_query": {
                fmt(qid): {
                    "nbytes": sig.nbytes,
                    "cost_rate": round(sig.cost_rate or 0.0, 3),
                }
                for qid, sig in sorted(self._per_query.items(), key=lambda kv: fmt(kv[0]))
            },
        }
