"""CQPSession — the continuous query processor's client facade.

The paper's system serves *registered* queries: clients register and
deregister recursive queries against one dynamic graph over time while δE
batches stream in.  ``CQPSession`` is that lifecycle, decoupled from any
engine (DBSP's plan/executor split):

    sess = CQPSession(graph, engine="dense")            # or "host"/"scratch"
    h0 = sess.register(plan.sssp(0))
    h1 = sess.register(plan.khop(3, k=4))               # mid-stream is fine
    sess.apply_updates_batched(update_log)
    d = sess.answers(h0)                                # [V]
    freed = sess.deregister(h1)                         # bytes released

Every engine implements one :class:`EngineProtocol` —

    * ``"dense"``   — the TPU engine (`core/engine.py`): a padded query-slot
      pool in the leading Q axis (active mask, host free-list, geometric
      regrow with a one-off re-trace); optionally vertex-sharded over a mesh.
    * ``"host"``    — the pointer engine (`core/sparse_engine.py`).
    * ``"scratch"`` — from-scratch re-execution (`core/scratch.py`).

so parity tests and the serving driver are engine-agnostic.

Plans in one session must share a **family** (`QueryPlan.family_key`): the
semiring, iteration bound, PageRank weight derivation and NFA fix the shape
of the compiled sweep.  Per-query knobs — source vertex, drop selection
policy — are free per registration.  The DroppedVT *representation* (Det
store vs Bloom filter and capacities) is fixed per session by ``drop`` (or
inferred from the first registered plan).

RPQ plans carry an NFA: the session owns the product-graph construction and
translates base-graph updates into product updates, so the engines never
know about automata.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

from repro.checkpoint import store as ckpt_store
from repro.core import dropping as dr
from repro.core import plan as qp
from repro.core.engine import DiffIFE, EngineConfig, MaintainStats
from repro.core.governor import GovernorConfig, MemoryGovernor
from repro.core.graph import DynamicGraph, product_graph
from repro.core.scratch import ScratchEngine
from repro.core.sparse_engine import SparseDiffIFE
from repro.obs import trace as obs_trace
from repro.obs.probes import maintain_stats_dict, publish_session_metrics

ENGINES = ("dense", "host", "scratch")

# session checkpoint manifest-meta layout version
CHECKPOINT_FORMAT = 1


# --------------------------------------------------------------------------- protocol
@runtime_checkable
class EngineProtocol(Protocol):
    """What a session expects from an engine: a runtime query lifecycle on
    one dynamic graph.  ``register_plan`` computes the new query's state
    in-engine; ``deregister_plan`` returns the accounted bytes released.

    Difference state is **operator-addressed**: every per-query meter has an
    operator-granular refinement keyed ``(slot, op_id)`` — per slot the
    operator bytes sum to the query bytes — and ``set_drop_params`` rewrites
    ONE operator's policy (``"iterate"``: §5 selection params; ``"join"``:
    complete dropping / re-materialization of the join trace)."""

    def register_plan(self, plan: qp.QueryPlan) -> int: ...

    def deregister_plan(self, slot: int) -> int: ...

    def apply_updates(self, updates): ...

    def apply_updates_batched(self, updates, batch_size: int | None = None): ...

    def answers_row(self, slot: int) -> np.ndarray: ...

    def answers(self) -> np.ndarray: ...

    def nbytes(self) -> int: ...

    def nbytes_per_query(self) -> dict[int, int]: ...

    def nbytes_per_operator(self) -> dict[int, dict[str, int]]: ...

    def recompute_cost_per_query(self) -> dict[int, int]: ...

    def recompute_cost_per_operator(self) -> dict[int, dict[str, int]]: ...

    def set_drop_params(
        self, slot: int, cfg: dr.DropConfig, op_id: str = "iterate"
    ) -> int: ...

    def active_slots(self) -> list[int]: ...


def engine_config_for(
    first_plan: qp.QueryPlan,
    *,
    num_queries: int,
    num_vertices: int,
    mode: str = "jod",
    drop: dr.DropConfig | None = None,
    store_capacity: int = 16,
    jstore_capacity: int = 8,
    backend: str = "coo",
    ell_block_v: int = 128,
    interpret: bool | None = None,
) -> EngineConfig:
    """The one place a plan family becomes an :class:`EngineConfig` — shared
    by the dense adapter, the scratch engine, and the legacy fixed-batch
    builder (`queries.engine_from_plans`).

    ``backend`` picks the sweep aggregator: ``"coo"`` (segment-reduce),
    ``"ell"`` (Pallas bucketed-ELL SpMV, JOD only), or ``"fused"`` (the
    maintenance megakernel — one ``pallas_call`` per sweep iteration,
    bit-identical to the stitched paths)."""
    return EngineConfig(
        num_queries=num_queries,
        num_vertices=num_vertices,
        max_iters=int(first_plan.max_iters),
        semiring=first_plan.semiring,
        mode=mode,
        store_capacity=store_capacity,
        jstore_capacity=jstore_capacity,
        drop=drop or dr.DropConfig(),
        weight_from_degree=first_plan.weight_from_degree,
        alpha=first_plan.alpha,
        backend=backend,
        ell_block_v=ell_block_v,
        interpret=interpret,
    )


# --------------------------------------------------------------------------- dense adapter
class DenseEngine:
    """Session protocol over :class:`DiffIFE`'s query-slot pool."""

    def __init__(
        self,
        graph: DynamicGraph,
        first_plan: qp.QueryPlan,
        *,
        drop_spec: dr.DropConfig,
        mode: str = "jod",
        backend: str = "coo",
        store_capacity: int = 16,
        jstore_capacity: int = 8,
        ell_block_v: int = 128,
        interpret: bool | None = None,
        batch_capacity: int = 32,
        mesh=None,
        min_slots: int = 1,
    ) -> None:
        q_cap = 1 << (max(int(min_slots), 1) - 1).bit_length()
        v = graph.num_vertices
        cfg = engine_config_for(
            first_plan,
            num_queries=q_cap,
            num_vertices=v,
            mode=mode,
            drop=drop_spec,
            store_capacity=store_capacity,
            jstore_capacity=jstore_capacity,
            backend=backend,
            ell_block_v=ell_block_v,
            interpret=interpret,
        )
        init = np.full((q_cap, v), first_plan.semiring.identity, np.float32)
        self.impl = DiffIFE(
            cfg,
            graph,
            init,
            batch_capacity=batch_capacity,
            mesh=mesh,
            active=np.zeros(q_cap, bool),
        )

    def _join_flag(self, plan: qp.QueryPlan) -> bool | None:
        """The plan's Join materialization flag for the engine slot;
        validates that an explicitly materializing plan lands on an engine
        that carries a join store."""
        policy = plan.join_policy()
        if policy == "materialize" and self.impl.state.jstore is None:
            raise ValueError(
                "plan materializes the Join but the session engine runs JOD "
                "(no join store); include a join-materializing plan in the "
                "opening batch or open the session with mode='vdc'"
            )
        return policy != "drop"

    def register_plan(self, plan: qp.QueryPlan) -> int:
        return self.register_plans([plan])[0]

    def register_plans(self, plans: list[qp.QueryPlan]) -> list[int]:
        v = self.impl.cfg.num_vertices
        # validate the whole batch before any slot commits (atomicity)
        flags = [self._join_flag(p) for p in plans]
        return self.impl.register_slots(
            [(p.build_init(v), p.drop, f) for p, f in zip(plans, flags)]
        )

    def deregister_plan(self, slot: int) -> int:
        return self.impl.deregister_slot(slot)

    def apply_updates(self, updates):
        return self.impl.apply_updates(updates)

    def apply_updates_batched(self, updates, batch_size: int | None = None):
        return self.impl.apply_updates_batched(updates, batch_size=batch_size)

    def answers_row(self, slot: int) -> np.ndarray:
        return self.impl.answers_row(slot)

    def answers(self) -> np.ndarray:
        return self.impl.answers()

    def nbytes(self) -> int:
        return self.impl.nbytes()

    def nbytes_per_query(self) -> dict[int, int]:
        return self.impl.nbytes_per_query()

    def nbytes_per_operator(self) -> dict[int, dict[str, int]]:
        return self.impl.nbytes_per_operator()

    def recompute_cost_per_query(self) -> dict[int, int]:
        return self.impl.recompute_cost_per_query()

    def recompute_cost_per_operator(self) -> dict[int, dict[str, int]]:
        return self.impl.recompute_cost_per_operator()

    def set_drop_params(
        self, slot: int, cfg: dr.DropConfig, op_id: str = "iterate"
    ) -> int:
        return self.impl.set_drop_params(slot, cfg, op_id=op_id)

    @property
    def det_overflow_shed(self) -> int:
        return self.impl.det_overflow_shed

    @property
    def last_stats(self):
        return self.impl.last_stats

    def active_slots(self) -> list[int]:
        return self.impl.active_slots()


# --------------------------------------------------------------------------- handles
@dataclasses.dataclass(frozen=True)
class QueryHandle:
    """Opaque ticket for one registered query (stable across slot reuse)."""

    qid: int
    plan: qp.QueryPlan


# --------------------------------------------------------------------------- session
class CQPSession:
    """Runtime query lifecycle over one dynamic graph and one engine.

    See the module docstring for the model.  Keyword knobs mirror the dense
    engine's; ``"host"``/``"scratch"`` accept and ignore the dense-only ones
    except ``mesh``, which they reject (the sharded sweep is dense-only).
    """

    def __init__(
        self,
        graph: DynamicGraph,
        *,
        engine: str = "dense",
        mesh=None,
        mode: str = "jod",
        backend: str = "coo",
        drop: dr.DropConfig | None = None,
        store_capacity: int = 16,
        jstore_capacity: int = 8,
        ell_block_v: int = 128,
        interpret: bool | None = None,
        batch_capacity: int = 32,
        min_slots: int = 1,
        product_capacity: int | None = None,
        budget_bytes: int | None = None,
        governor: GovernorConfig | None = None,
        optimize: str = "none",
    ) -> None:
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
        if optimize not in ("none", "auto", "always"):
            raise ValueError(
                f"unknown optimize mode {optimize!r}; choose none | auto | always"
            )
        if mesh is not None and engine != "dense":
            raise ValueError("mesh sharding is a dense-engine feature")
        if governor is not None and budget_bytes is None:
            raise ValueError("a GovernorConfig needs budget_bytes to enforce")
        self._governor: MemoryGovernor | None = None
        if budget_bytes is not None:
            gcfg = governor or GovernorConfig()
            if engine == "dense":
                # the governor escalates by rewriting DropParams rows, so the
                # dense engine needs a DroppedVT representation provisioned
                # up front (p = 0: nothing drops until an escalation)
                if drop is None:
                    drop = gcfg.representation_config()
                elif not drop.enabled():
                    raise ValueError(
                        "budget_bytes on a dense session needs an enabled "
                        "DroppedVT representation (drop=None auto-provisions "
                        "one; drop.mode='none' leaves the governor no lever)"
                    )
                elif drop.mode != gcfg.representation:
                    # the session's representation is fixed by `drop`; the
                    # ladder must escalate within it
                    gcfg = dataclasses.replace(gcfg, representation=drop.mode)
            self._governor = MemoryGovernor(int(budget_bytes), gcfg)
        self.graph = graph
        self.engine_kind = engine
        self.mesh = mesh
        self._kw = dict(
            mode=mode,
            backend=backend,
            store_capacity=store_capacity,
            jstore_capacity=jstore_capacity,
            ell_block_v=ell_block_v,
            interpret=interpret,
            batch_capacity=batch_capacity,
            min_slots=min_slots,
        )
        self._drop_spec = drop
        self._product_capacity = product_capacity
        self._impl: EngineProtocol | None = None
        self._family: tuple | None = None
        self._family_plan: qp.QueryPlan | None = None  # fixed the sweep shape
        self._nfa: qp.NFA | None = None
        self._egraph: DynamicGraph = graph  # product graph under an NFA family
        self._handles: dict[int, int] = {}  # qid → engine slot
        self._plans: dict[int, qp.QueryPlan] = {}
        self._next_qid = 0
        self._runtime: dict = {}  # serving-runtime observers (stats()["runtime"])
        self.restore_info: dict | None = None  # set by CQPSession.restore
        # plan optimizer (repro.planner): rewrites matching plans at
        # registration; qids it owns answer through rule-owned runtimes, and
        # qids it registers for shared subplans are *internal* — excluded
        # from every public per-query view but governor-addressable
        self._optimize = optimize
        self._planner = None
        self._internal: set[int] = set()
        self._governing = False  # re-entrancy guard (remat registers inside enforce)
        if optimize != "none":
            self._ensure_planner()
        # lifetime counters (stats())
        self.registered_total = 0
        self.deregistered_total = 0
        self.updates_applied = 0
        self.bytes_freed_total = 0
        self.bytes_shed_total = 0  # reclaimed by drop-policy rewrites

    # ------------------------------------------------------------ lifecycle
    def register(
        self, plan: qp.QueryPlan, *, optimize: str | None = None
    ) -> QueryHandle:
        """Register one query; its trace is computed in-engine (mid-stream
        registration converges to the same answers as from-start).

        ``optimize`` overrides the session's optimizer mode for this call
        (``"none"`` | ``"auto"`` | ``"always"`` — see `repro.planner`)."""
        return self.register_many([plan], optimize=optimize)[0]

    def _ensure_planner(self):
        if self._planner is None:
            from repro.planner.rules import Planner

            self._planner = Planner(
                self, self._optimize if self._optimize != "none" else "auto"
            )
        return self._planner

    def register_many(
        self, plans: list[qp.QueryPlan], *, optimize: str | None = None
    ) -> list[QueryHandle]:
        """Register a batch of queries — the dense engine initializes all of
        their traces in ONE maintenance sweep.

        Atomic: a rejected batch (family mismatch, drop-mode conflict, an
        engine that cannot run the family) leaves the session exactly as it
        was — including across the deferred first engine build.

        With the plan optimizer active (session ``optimize=`` or the
        per-call override), each plan first runs through the rewrite rules:
        matches that pay are admitted to the owning rule's shared runtime
        instead of an engine slot, and their returned handles carry the
        rewritten (provenance-stamped) plan.
        """
        if not plans:
            return []
        plans = list(plans)
        mode = self._optimize if optimize is None else optimize
        if mode not in ("none", "auto", "always"):
            raise ValueError(
                f"unknown optimize mode {mode!r}; choose none | auto | always"
            )
        # validate the WHOLE batch before committing any session state
        base = self._family if self._family is not None else plans[0].family_key()
        spec = self._drop_spec
        if spec is None and self._impl is None:
            spec = next((p.drop for p in plans if p.drop.enabled()), None)
        for plan in plans:
            self._check_family(plan, base)
            if plan.drop.enabled() and spec is not None and plan.drop.mode != spec.mode:
                raise ValueError(
                    f"plan drop mode {plan.drop.mode!r} does not match the "
                    f"session's DroppedVT representation {spec.mode!r}"
                )
        rules: dict[int, object] = {}
        if mode != "none":
            planner = self._ensure_planner()
            for i, plan in enumerate(plans):
                rule = planner.consider(plan, mode)
                if rule is not None:
                    rules[i] = rule
        handles: list[QueryHandle | None] = [None] * len(plans)
        engine_idx = [i for i in range(len(plans)) if i not in rules]
        if engine_idx:
            qids = self._register_engine_plans([plans[i] for i in engine_idx])
            for i, qid in zip(engine_idx, qids):
                handles[i] = QueryHandle(qid=qid, plan=self._plans[qid])
        for i in sorted(rules):
            qid = self._next_qid
            self._next_qid += 1
            new_plan = self._planner.admit(qid, plans[i], rules[i])
            self._plans[qid] = new_plan
            self.registered_total += 1
            handles[i] = QueryHandle(qid=qid, plan=new_plan)
        self._govern()
        return handles

    def _register_engine_plans(
        self, plans: list[qp.QueryPlan], *, internal: bool = False
    ) -> list[int]:
        """The engine-slot registration path (family commit, deferred first
        build, atomic unwind).  ``internal=True`` registers planner-owned
        subplan rows: full engine/governor citizens, excluded from the
        public per-query views and the ``registered_total`` counter."""
        base = self._family if self._family is not None else plans[0].family_key()
        spec = self._drop_spec
        if spec is None and self._impl is None:
            spec = next((p.drop for p in plans if p.drop.enabled()), None)
        for plan in plans:
            self._check_family(plan, base)
            if plan.drop.enabled() and spec is not None and plan.drop.mode != spec.mode:
                raise ValueError(
                    f"plan drop mode {plan.drop.mode!r} does not match the "
                    f"session's DroppedVT representation {spec.mode!r}"
                )
        fresh = self._impl is None
        saved = (self._family, self._nfa, self._drop_spec, self._egraph)
        if self._family is None:
            self._family = base
            self._nfa = plans[0].nfa
        slots: list[int] = []
        try:
            if fresh:
                self._build_engine(plans)
            if hasattr(self._impl, "register_plans"):
                slots = self._impl.register_plans(plans)
            else:
                done: list[int] = []
                try:
                    for p in plans:
                        done.append(self._impl.register_plan(p))
                except Exception:
                    for s in done:
                        self._impl.deregister_plan(s)
                    raise
                slots = done
        except Exception:
            # unwind everything this call committed (the engine itself is
            # discarded when it was built for this batch)
            if fresh:
                self._impl = None
                self._family, self._nfa, self._drop_spec, self._egraph = saved
            raise
        qids: list[int] = []
        for plan, slot in zip(plans, slots):
            qid = self._next_qid
            self._next_qid += 1
            self._handles[qid] = slot
            self._plans[qid] = plan
            if internal:
                self._internal.add(qid)
            else:
                self.registered_total += 1
            if self._governor is not None:
                self._governor.on_register(qid, plan)
            qids.append(qid)
        return qids

    def _register_internal(self, plans: list[qp.QueryPlan]) -> list[int]:
        """Planner hook: register shared-subplan rows (e.g. the landmark
        index's SSSP fields) as internal engine queries."""
        return self._register_engine_plans(plans, internal=True)

    def _deregister_internal(self, qids) -> int:
        """Planner hook: retire internal subplan rows; returns bytes freed."""
        freed = 0
        for qid in list(qids):
            slot = self._handles.pop(qid)
            freed += self._impl.deregister_plan(slot)
            del self._plans[qid]
            self._internal.discard(qid)
            if self._governor is not None:
                self._governor.on_deregister(qid)
        return freed

    def deregister(self, handle: QueryHandle) -> int:
        """Retire a query: its difference rows are zeroed and the accounted
        bytes released are returned; the slot returns to the free pool.
        A planner-owned query releases through its rule (the shared index
        tears down with its last sharer)."""
        if handle.qid in self._internal:
            raise ValueError(
                "internal planner subqueries retire with their shared state"
            )
        if self._planner is not None and self._planner.owns(handle.qid):
            freed = self._planner.release(handle.qid)
            del self._plans[handle.qid]
            self.deregistered_total += 1
            self.bytes_freed_total += freed
            self._govern()
            return freed
        slot = self._slot(handle)
        freed = self._impl.deregister_plan(slot)
        del self._handles[handle.qid], self._plans[handle.qid]
        self.deregistered_total += 1
        self.bytes_freed_total += freed
        if self._governor is not None:
            self._governor.on_deregister(handle.qid)
        self._govern()
        return freed

    def _slot(self, handle: QueryHandle) -> int:
        if handle.qid not in self._handles:
            raise ValueError(f"handle {handle.qid} is not registered")
        return self._handles[handle.qid]

    def _check_family(self, plan: qp.QueryPlan, base: tuple) -> None:
        """Validate a plan against ``base`` (the session family, or the
        first plan of the opening batch).  Validation is pure — the family
        is committed by ``register_many`` only once its whole batch passes,
        so a rejected batch leaves the session untouched."""
        key = plan.family_key()
        if key != base:
            raise ValueError(
                "plan family mismatch: a session compiles ONE sweep shape "
                f"(semiring/max_iters/NFA); got {key} vs {base}. "
                "Open a second session for a different query family."
            )

    # ------------------------------------------------------- engine build
    def _build_engine(self, plans: list[qp.QueryPlan]) -> None:
        first_plan = plans[0]
        self._family_plan = first_plan
        if self._drop_spec is None:
            # representation inferred from the first drop-enabled plan of the
            # initial batch; later plans may use any selection params under
            # the same mode
            self._drop_spec = next(
                (p.drop for p in plans if p.drop.enabled()), first_plan.drop
            )
        if self._nfa is not None:
            self._egraph = self._build_product_graph()
        if self.engine_kind == "dense":
            kw = dict(self._kw)
            # size the slot pool for the opening batch — avoids a cascade of
            # geometric regrows before the first sweep even runs
            kw["min_slots"] = max(int(kw["min_slots"]), len(plans))
            # a plan whose Join node materializes its trace needs the VDC
            # join store allocated — the engine mode is derived from the
            # operator graph ("auto" joins inherit the session's mode kw)
            if any(p.join_policy() == "materialize" for p in plans):
                kw["mode"] = "vdc"
            self._impl = DenseEngine(
                self._egraph,
                first_plan,
                drop_spec=self._drop_spec,
                mesh=self.mesh,
                **kw,
            )
        elif self.engine_kind == "host":
            self._impl = SparseDiffIFE(
                self._egraph, max_iters=int(first_plan.max_iters)
            )
        else:
            cfg = engine_config_for(
                first_plan,
                num_queries=1,
                num_vertices=self._egraph.num_vertices,
                backend=self._kw["backend"],
                ell_block_v=self._kw["ell_block_v"],
                interpret=self._kw["interpret"],
            )
            self._impl = ScratchEngine(cfg, self._egraph)

    def _build_product_graph(self) -> DynamicGraph:
        nfa = self._nfa
        n, src, dst, w, _ = product_graph(self.graph, nfa.delta, nfa.num_states)
        cap = self._product_capacity
        if cap is None:
            per = max((len(v) for v in nfa.delta.values()), default=1)
            cap = max(16, self.graph.capacity * per)
        return DynamicGraph(
            n, list(zip(src.tolist(), dst.tolist(), w.tolist())), capacity=cap
        )

    def _translate(self, updates) -> list[tuple[int, int, int, float, int]]:
        """Base-graph δE → product-graph δE (one edge per NFA transition)."""
        out = []
        for (u, v, lbl, w, sign) in updates:
            for (s, s2) in self._nfa.delta.get(int(lbl), ()):
                out.append(
                    (
                        int(u) * self._nfa.num_states + s,
                        int(v) * self._nfa.num_states + s2,
                        0,
                        1.0,
                        int(sign),
                    )
                )
        return out

    # ------------------------------------------------------------ ingestion
    def _ingest(self, updates, engine_call):
        """Shared ingestion path: count, route pre-engine updates to the
        base graph, translate through the NFA when the family has one, then
        hand the batch to ``engine_call``."""
        updates = list(updates)
        base_updates = updates  # pre-NFA δE, for the planner's twin feeds
        self.updates_applied += len(updates)
        if self._impl is None:
            # no engine yet → no product graph either: updates land on the
            # base graph, which any later engine build snapshots
            self.graph.apply_batch(updates)
            if self._planner is not None:
                self._planner.on_updates(base_updates)
            return None
        with obs_trace.span(
            "update_batch",
            "update_batch",
            pid="session",
            engine=self.engine_kind,
            num_updates=len(updates),
            queries=self.num_queries,
        ):
            if self._nfa is not None:
                self.graph.apply_batch(updates)
                updates = self._translate(updates)
                if not updates:
                    self._govern()
                    return self.last_stats
            out = engine_call(updates)
            if self._planner is not None:
                # engine maintenance (incl. the internal index rows) ran —
                # rules now refresh their rewritten queries' runtimes
                self._planner.on_updates(base_updates)
            self._govern()
        return out

    def apply_updates(self, updates):
        """Ingest one δE batch and maintain every registered query."""
        return self._ingest(updates, self._impl_apply)

    def _impl_apply(self, updates):
        return self._impl.apply_updates(updates)

    def apply_updates_batched(self, updates, batch_size: int | None = None):
        """Stream a δE log through the engine's batched path (the dense
        engine's donated-buffer chunks; host/scratch fall back to one batch)."""
        return self._ingest(
            updates,
            lambda u: self._impl.apply_updates_batched(u, batch_size=batch_size),
        )

    # ------------------------------------------------------------------ api
    def answers(self, handle: QueryHandle) -> np.ndarray:
        """The query's final vertex states. [V] ([V·|S|] for RPQ plans —
        see :meth:`reachable`).  Planner-rewritten queries answer through
        their owning rule's runtime (e.g. the landmark pruned-scratch
        subquery — exact at the plan's target vertex)."""
        if self._planner is not None and self._planner.owns(handle.qid):
            return self._planner.answers(handle.qid)
        return self._impl.answers_row(self._slot(handle))

    def reachable(self, handle: QueryHandle) -> np.ndarray:
        """RPQ answer extraction: bool [V_base] — which base vertices match."""
        plan = self._plans[handle.qid]
        if plan.nfa is None:
            raise ValueError("reachable() applies to RPQ plans")
        d = self.answers(handle).reshape(
            self.graph.num_vertices, plan.nfa.num_states
        )
        return np.isfinite(d[:, list(plan.nfa.accept)]).any(axis=-1)

    def aggregate(self, handle: QueryHandle) -> dict:
        """Evaluate the plan's Aggregate operator over the query's answers.

        Stateless post-processing (the node owns no difference store): RPQ
        answers are first reduced to base-vertex space (min over NFA
        states).  ``topk`` returns the k best finite values with their
        vertices; ``histogram`` buckets the finite values into equal-width
        bins and counts the unreachable rest.
        """
        plan = self._plans[self._require_qid(handle)]
        node = plan.aggregate
        if node is None:
            raise ValueError("plan has no aggregate operator")
        vals = self.answers(handle)
        if plan.nfa is not None:
            # a product vertex only matches the RPQ at an ACCEPTING state —
            # reduce over those columns alone (as reachable() does), else
            # partial-path prefixes pollute the aggregate
            vals = vals.reshape(
                self.graph.num_vertices, plan.nfa.num_states
            )[:, list(plan.nfa.accept)].min(axis=1)
        finite = np.isfinite(vals)
        out = {"op": node.op_id, "agg": node.agg}
        if node.agg == "target":
            out["vertex"] = int(node.vertex)
            out["value"] = float(vals[int(node.vertex)])
            return out
        if node.agg == "topk":
            idx = np.nonzero(finite)[0]
            order = idx[np.argsort(vals[idx], kind="stable")][: node.k]
            out["vertices"] = [int(i) for i in order]
            out["values"] = [float(vals[i]) for i in order]
            return out
        if node.agg == "histogram":
            f = vals[finite]
            counts, edges = np.histogram(
                f, bins=node.bins
            ) if f.size else (np.zeros(node.bins, int), np.arange(node.bins + 1.0))
            out["counts"] = [int(c) for c in counts]
            out["edges"] = [float(e) for e in edges]
            out["unreachable"] = int((~finite).sum())
            return out
        raise ValueError(f"unknown aggregate {node.agg!r}")

    def _public_qids(self) -> list[int]:
        """Ascending qids of client-registered queries (planner-internal
        subplan rows excluded)."""
        return [q for q in sorted(self._plans) if q not in self._internal]

    def handles(self) -> list[QueryHandle]:
        return [
            QueryHandle(qid=q, plan=self._plans[q]) for q in self._public_qids()
        ]

    def answers_snapshot(self) -> dict[int, np.ndarray]:
        """qid → an owned copy of every registered query's answers.

        The serving tier's epoch view: taken between chunk applies, the
        copies stay immutable while the next chunk folds in on another
        thread, so concurrent readers never observe a half-applied δE
        chunk (DESIGN.md §14)."""
        out: dict[int, np.ndarray] = {}
        if self._impl is not None:
            out = {
                qid: np.array(self._impl.answers_row(slot), copy=True)
                for qid, slot in self._handles.items()
                if qid not in self._internal
            }
        if self._planner is not None:
            out.update(self._planner.answers_snapshot())
        return out

    def nbytes(self) -> int:
        total = 0 if self._impl is None else self._impl.nbytes()
        if self._planner is not None:
            total += self._planner.extra_nbytes()
        return total

    def nbytes_per_query(self) -> list[int]:
        """Accounted bytes per registered query, aligned with
        :meth:`handles` (ascending qid) — the ``[Q]`` breakdown the memory
        governor meters.  Planner-rewritten queries read 0 here: their
        shared state is accounted under the internal index rows and the
        ``(PLANNER_QID, op)`` pseudo-operator."""
        per = self._nbytes_per_query_map()
        return [per[qid] for qid in self._public_qids()]

    def nbytes_per_operator(self) -> list[dict[str, int]]:
        """Per-query bytes refined to the operators owning difference
        stores, aligned with :meth:`handles` (ascending qid).  Every
        droppable operator of the plan graph appears (0 bytes when its
        store is dropped or the engine never materializes it); per query
        the operator bytes sum to :meth:`nbytes_per_query`'s entry."""
        per = self._nbytes_per_op_map()
        out = []
        for qid in self._public_qids():
            ops = {
                op: bytes_ for (q, op), bytes_ in per.items() if q == qid
            }
            out.append(ops)
        return out

    def _nbytes_per_query_map(self) -> dict[int, int]:
        out: dict[int, int] = {}
        if self._impl is not None:
            by_slot = self._impl.nbytes_per_query()
            out = {
                qid: by_slot.get(slot, 0) for qid, slot in self._handles.items()
            }
        if self._planner is not None:
            for qid in self._planner.owned:
                out[qid] = 0
        return out

    def _nbytes_per_op_map(self) -> dict[tuple[int, str], int]:
        """(qid, op_id) → accounted bytes — the governor's victim table.
        Internal subplan rows appear under their own qids; rule-owned
        shared state adds ``(PLANNER_QID, op)`` pseudo-rows."""
        out: dict[tuple[int, str], int] = {}
        if self._impl is not None:
            by_slot = self._impl.nbytes_per_operator()
            for qid, slot in self._handles.items():
                ops = dict(by_slot.get(slot, {"iterate": 0}))
                for op in self._plans[qid].droppable_ops():
                    ops.setdefault(op, 0)  # e.g. a JOD engine's (empty) join op
                for op, bytes_ in ops.items():
                    out[(qid, op)] = int(bytes_)
        if self._planner is not None:
            out.update(self._planner.pseudo_ops())
        return out

    def _recompute_cost_map(self) -> dict[int, int]:
        if self._impl is None:
            return {}
        by_slot = self._impl.recompute_cost_per_query()
        return {qid: by_slot.get(slot, 0) for qid, slot in self._handles.items()}

    def _recompute_cost_op_map(self) -> dict[tuple[int, str], int]:
        out: dict[tuple[int, str], int] = {}
        if self._impl is not None:
            by_slot = self._impl.recompute_cost_per_operator()
            for qid, slot in self._handles.items():
                ops = dict(by_slot.get(slot, {"iterate": 0}))
                for op in self._plans[qid].droppable_ops():
                    ops.setdefault(op, 0)
                for op, cost in ops.items():
                    out[(qid, op)] = int(cost)
        if self._planner is not None:
            out.update(self._planner.pseudo_costs())
        return out

    # --------------------------------------------------------- drop policy
    def set_drop_policy(
        self, handle: QueryHandle, cfg: dr.DropConfig, op: str = "iterate"
    ) -> int:
        """Rewrite ONE operator's drop policy of a live query mid-stream
        (the governor's primitive, exposed for manual tuning).

        ``op="iterate"`` (default) is the §5 selection rewrite: the engine
        sheds stored diffs the new policy selects.  ``op="join"`` drops the
        query's join trace completely (an enabled config) or re-materializes
        it (a disabled one).  Returns the bytes released."""
        return self._set_op_drop_policy_qid(self._require_qid(handle), op, cfg)

    def _require_qid(self, handle: QueryHandle) -> int:
        if handle.qid in self._handles:
            return handle.qid
        if self._planner is not None and self._planner.owns(handle.qid):
            return handle.qid
        raise ValueError(f"handle {handle.qid} is not registered")

    def _set_drop_policy_qid(self, qid: int, cfg: dr.DropConfig) -> int:
        return self._set_op_drop_policy_qid(qid, "iterate", cfg)

    def _set_op_drop_policy_qid(
        self, qid: int, op: str, cfg: dr.DropConfig
    ) -> int:
        if qid < 0:
            # governor rung for planner-owned shared state: an enabled
            # config sheds it (landmark de-materialization), a disabled one
            # re-materializes — routed to the rule owning the pseudo-op
            freed = self._ensure_planner().set_pseudo_policy(op, cfg)
            self.bytes_shed_total += max(int(freed), 0)
            return int(freed)
        if qid not in self._handles:
            raise ValueError(
                f"query {qid} answers through a planner rewrite and owns no "
                "engine difference store; its shared state is governed as a "
                "(PLANNER_QID, op) pseudo-operator"
            )
        freed = self._impl.set_drop_params(self._handles[qid], cfg, op_id=op)
        plan = self._plans[qid]
        if any(n.op_id == op for n in plan.ops):
            self._plans[qid] = plan.with_op_drop(op, cfg)
        # else: the engine's implicit operator (e.g. a legacy plan's join
        # trace under mode="vdc") — engine state changed, plan graph has no
        # node to annotate
        self.bytes_shed_total += max(int(freed), 0)
        return int(freed)

    def _det_overflow_shed(self) -> int:
        """DroppedVT records lost to Det-Drop evictions during sheds (the
        governor's escalation guard folds these in; sweep-time losses arrive
        via MaintainStats)."""
        return int(getattr(self._impl, "det_overflow_shed", 0))

    # ------------------------------------------------------------ governor
    @property
    def governor(self) -> MemoryGovernor | None:
        return self._governor

    @property
    def budget_bytes(self) -> int | None:
        return None if self._governor is None else self._governor.budget_bytes

    def _govern(self) -> None:
        if self._governor is None or self._impl is None or self._governing:
            return
        if not self._handles and (
            self._planner is None or not self._planner.owned
        ):
            return
        # the guard makes enforcement non-reentrant: a de-escalation that
        # re-materializes a planner index registers internal plans, and
        # that path must not recurse into enforce()
        self._governing = True
        try:
            self._governor.enforce(self)
        finally:
            self._governing = False

    @property
    def num_queries(self) -> int:
        return len(self._plans) - len(self._internal)

    @property
    def last_stats(self):
        return getattr(self._impl, "last_stats", None)

    def publish_metrics(self, registry=None):
        """Scrape this session into the (default) obs metrics registry —
        gauges overwrite, counters advance; see ``repro.obs.probes``.
        Returns the registry (for ``snapshot()`` / ``prometheus_text()``)."""
        return publish_session_metrics(self, registry)

    def stats(self) -> dict:
        """Session/engine counters for serving telemetry."""
        out = {
            "engine": self.engine_kind,
            "active_queries": self.num_queries,
            "registered_total": self.registered_total,
            "deregistered_total": self.deregistered_total,
            "updates_applied": self.updates_applied,
            "bytes_freed_total": self.bytes_freed_total,
            "bytes_shed_total": self.bytes_shed_total,
            "nbytes": self.nbytes(),
            "nbytes_per_query": self.nbytes_per_query(),
            "nbytes_per_operator": self.nbytes_per_operator(),
            "query_qids": self._public_qids(),
        }
        if self._governor is not None:
            out["governor"] = self._governor.snapshot(self)
        if self._planner is not None:
            out["planner"] = self._planner.snapshot()
        if isinstance(self._impl, DenseEngine):
            out["slot_capacity"] = self._impl.impl.slot_capacity
            out["shards"] = self._impl.impl.num_shards
        ls = self.last_stats
        if isinstance(ls, MaintainStats):
            out["last_maintain"] = maintain_stats_dict(ls)
        if self._runtime:
            rt: dict = {}
            det = self._runtime.get("straggler")
            if det is not None:
                rt["straggler"] = {
                    "observed": det.seen,
                    "ewma_s": det.ewma,
                    "events": [dataclasses.asdict(e) for e in det.events],
                }
            sup = self._runtime.get("supervisor")
            if sup is not None:
                rt["fault"] = sup.metrics()
            out["runtime"] = rt
        return out

    @property
    def num_shards(self) -> int:
        if isinstance(self._impl, DenseEngine):
            return self._impl.impl.num_shards
        return 1

    def nbytes_per_device(self) -> list[int]:
        if isinstance(self._impl, DenseEngine):
            return self._impl.impl.nbytes_per_device()
        return [self.nbytes()]

    # ------------------------------------------------------------ durability
    def attach_runtime(self, *, straggler=None, supervisor=None) -> None:
        """Register serving-runtime observers; they surface in
        ``stats()["runtime"]`` (straggler events / recovery metrics)."""
        if straggler is not None:
            self._runtime["straggler"] = straggler
        if supervisor is not None:
            self._runtime["supervisor"] = supervisor

    def state_dict(self, *, extra: dict | None = None) -> tuple[dict, dict]:
        """(arrays, meta): everything needed to rebuild this session.

        Arrays carry the graph(s) and the engine's difference trace; meta
        (JSON-able, rides in the checkpoint manifest) carries plans, handle
        table, qid cursor, counters, drop/governor state, and ``extra`` (the
        caller's update-log cursor).  What is NOT saved is recomputed
        deterministically at restore: host adjacency dicts, init rows, the
        mesh-dependent shard/cell layout, compiled dispatch.
        """
        arrays: dict[str, np.ndarray] = {}
        g_arrays, g_meta = self.graph.state_dict()
        arrays.update({f"graph/{k}": v for k, v in g_arrays.items()})
        c = {
            "registered_total": self.registered_total,
            "deregistered_total": self.deregistered_total,
            "updates_applied": self.updates_applied,
            "bytes_freed_total": self.bytes_freed_total,
            "bytes_shed_total": self.bytes_shed_total,
        }
        meta: dict = {
            "format": CHECKPOINT_FORMAT,
            "engine": self.engine_kind,
            "kw": dict(self._kw),
            "drop_spec": (
                None
                if self._drop_spec is None
                else dataclasses.asdict(self._drop_spec)
            ),
            "product_capacity": self._product_capacity,
            "graph": g_meta,
            "egraph": None,
            "family_plan": None,
            "plans": {str(q): p.to_json() for q, p in self._plans.items()},
            "handles": {str(q): int(s) for q, s in self._handles.items()},
            "next_qid": self._next_qid,
            "counters": c,
            "engine_state": self._impl is not None,
            "engine_meta": None,
            "governor": None,
            "optimize": self._optimize,
            "internal": sorted(self._internal),
            "planner": None,
            "user": extra,
        }
        if self._planner is not None:
            p_arrays, p_meta = self._planner.state_dict()
            arrays.update(p_arrays)
            meta["planner"] = p_meta
        if self._impl is not None:
            meta["family_plan"] = self._family_plan.to_json()
            if self._nfa is not None:
                e_arrays, e_meta = self._egraph.state_dict()
                arrays.update({f"egraph/{k}": v for k, v in e_arrays.items()})
                meta["egraph"] = e_meta
            impl = (
                self._impl.impl
                if isinstance(self._impl, DenseEngine)
                else self._impl
            )
            en_arrays, en_meta = impl.export_state()
            if isinstance(self._impl, DenseEngine):
                en_meta["mode"] = impl.cfg.mode
            arrays.update({f"engine/{k}": v for k, v in en_arrays.items()})
            meta["engine_meta"] = en_meta
        if self._governor is not None:
            meta["governor"] = self._governor.state_dict()
        return arrays, meta

    def checkpoint(
        self, directory: str, *, step: int | None = None,
        extra: dict | None = None,
    ) -> str:
        """Synchronous atomic snapshot into ``directory``; returns the step
        dir.  ``step`` defaults to the cumulative ingested-update count; pass
        ``extra`` for the serving loop's log cursor.  (The recovery
        supervisor drives the async keep-N path via
        :class:`~repro.checkpoint.CheckpointManager` instead.)"""
        arrays, meta = self.state_dict(extra=extra)
        step = self.updates_applied if step is None else int(step)
        return ckpt_store.save_checkpoint(directory, step, arrays, meta=meta)

    @classmethod
    def restore(
        cls, directory: str, *, step: int | None = None, mesh=None,
    ) -> "CQPSession":
        """Rebuild a session from the latest (or ``step``'s) checkpoint.

        ``mesh`` is the *current* mesh — restore reshards the engine carries
        onto it (``runtime/elastic.reshard``), so a checkpoint taken at 8
        shards restores at 1 or 4.  Replaying the same update-log suffix then
        yields answers bit-identical to an uninterrupted run (min-family
        semirings; see DESIGN.md §12).  ``session.restore_info`` carries the
        restored step and the saver's ``extra`` cursor.
        """
        arrays, manifest, step = ckpt_store.load_checkpoint(directory, step)
        meta = manifest.get("meta")
        if meta is None:
            raise ValueError(
                f"checkpoint in {directory} carries no session meta — was it "
                "written by CQPSession.checkpoint / the recovery supervisor?"
            )
        sess = cls._from_state(arrays, meta, mesh=mesh)
        sess.restore_info = {"step": step, "extra": meta.get("user")}
        return sess

    @classmethod
    def _from_state(cls, arrays: dict, meta: dict, *, mesh=None) -> "CQPSession":
        """Rebuild a session from ``state_dict`` output (the body of
        :meth:`restore`, reusable for nested sessions — a planner's
        reverse-graph twin restores through this without a checkpoint
        directory)."""
        if int(meta.get("format", 0)) != CHECKPOINT_FORMAT:
            raise ValueError(
                f"unsupported session checkpoint format {meta.get('format')!r}"
            )

        def sub(prefix: str) -> dict:
            return {
                k[len(prefix):]: v
                for k, v in arrays.items()
                if k.startswith(prefix)
            }

        graph = DynamicGraph.from_state(meta["graph"], sub("graph/"))
        drop = (
            None
            if meta["drop_spec"] is None
            else dr.DropConfig(**meta["drop_spec"])
        )
        gov = meta["governor"]
        gcfg = None
        if gov is not None:
            cfg_d = dict(gov["cfg"])
            cfg_d["ladder_p"] = tuple(cfg_d["ladder_p"])
            gcfg = GovernorConfig(**cfg_d)
        sess = cls(
            graph,
            engine=meta["engine"],
            mesh=mesh,
            drop=drop,
            product_capacity=meta["product_capacity"],
            budget_bytes=None if gov is None else int(gov["budget_bytes"]),
            governor=gcfg,
            optimize=meta.get("optimize", "none"),
            **meta["kw"],
        )
        sess._plans = {
            int(q): qp.QueryPlan.from_json(p) for q, p in meta["plans"].items()
        }
        sess._handles = {int(q): int(s) for q, s in meta["handles"].items()}
        sess._next_qid = int(meta["next_qid"])
        for name, val in meta["counters"].items():
            setattr(sess, name, int(val))
        if meta["engine_state"]:
            first = qp.QueryPlan.from_json(meta["family_plan"])
            sess._family_plan = first
            sess._family = first.family_key()
            sess._nfa = first.nfa
            if meta["egraph"] is not None:
                sess._egraph = DynamicGraph.from_state(
                    meta["egraph"], sub("egraph/")
                )
            else:
                sess._egraph = graph
            em = meta["engine_meta"]
            en_arrays = sub("engine/")
            if sess.engine_kind == "dense":
                if sess._drop_spec is None:
                    sess._drop_spec = first.drop
                kw = dict(sess._kw)
                # the saved pool size is itself a power of two, so min_slots
                # = slot_capacity reconstructs the exact q_cap (and with it
                # the saved free list's meaning); an all-inactive pool skips
                # the constructor sweep, so import lands on untouched state
                kw["min_slots"] = int(em["slot_capacity"])
                kw["mode"] = em["mode"]
                eng = DenseEngine(
                    sess._egraph,
                    first,
                    drop_spec=sess._drop_spec,
                    mesh=mesh,
                    **kw,
                )
                eng.impl.import_state(en_arrays, em)
                sess._impl = eng
            elif sess.engine_kind == "host":
                imp = SparseDiffIFE(
                    sess._egraph, max_iters=int(first.max_iters)
                )
                imp.import_state(en_arrays, em)
                sess._impl = imp
            else:
                cfg = engine_config_for(
                    first,
                    num_queries=1,
                    num_vertices=sess._egraph.num_vertices,
                    backend=sess._kw["backend"],
                    ell_block_v=sess._kw["ell_block_v"],
                    interpret=sess._kw["interpret"],
                )
                imp = ScratchEngine(cfg, sess._egraph)
                imp.import_state(en_arrays, em)
                sess._impl = imp
        elif sess._handles:
            # a session checkpointed before its first engine build: engine
            # handles exist only if an engine did — corrupt meta
            raise ValueError("checkpoint has live plans but no engine state")
        if gov is not None:
            sess._governor.load_state(gov)
        sess._internal = {int(q) for q in meta.get("internal", [])}
        pm = meta.get("planner")
        if pm is not None:
            sess._ensure_planner().load_state(pm, arrays)
        return sess
