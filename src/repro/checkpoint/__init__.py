"""Sharded, atomic, async checkpointing with elastic restore."""

from repro.checkpoint.store import (  # noqa: F401
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)
