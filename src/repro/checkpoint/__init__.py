"""Sharded, atomic, async checkpointing with elastic restore."""

from repro.checkpoint.store import (  # noqa: F401
    CheckpointManager,
    load_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
