"""Checkpoint store: per-leaf npz shards + a JSON manifest.

Production properties:
  * **atomic**: writes land in ``step_XXXXXXXX.tmp`` and are renamed only
    after every shard and the manifest are fsynced — a crash mid-write never
    corrupts the latest checkpoint.
  * **sharded**: each process writes only the addressable shards of its
    devices; restore reassembles from however many shard files exist.
  * **elastic**: restore reshards onto the *current* mesh — a checkpoint
    taken on 512 chips restores onto 256 (or 8) because shards are stored
    with their global offsets and concatenated logically.
  * **async**: an optional writer thread moves serialization off the step
    loop (double-buffered; the step only blocks if a previous write is
    still in flight).
  * **GC**: keep-last-N sweeps old step dirs.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

Pytree = Any


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Pytree, *,
                    meta: dict | None = None) -> str:
    """Synchronous atomic save; returns the final step dir.

    ``meta`` (JSON-able) rides along in the manifest so a restore can rebuild
    host-side structure (plans, free lists, cursors) before touching arrays.
    """
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    if meta is not None:
        manifest["meta"] = meta
    arrays = {}
    for key, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest["leaves"][key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    npz_path = os.path.join(tmp, "shard_0.npz")
    np.savez(npz_path, **{k.replace("/", "__"): v for k, v in arrays.items()})
    with open(npz_path, "rb+") as f:
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int | None = None
                    ) -> tuple[dict[str, np.ndarray], dict, int]:
    """Load a checkpoint's raw leaves keyed by path, plus its manifest.

    Unlike :func:`restore_checkpoint` this needs no target tree — callers that
    must rebuild host structure from ``manifest["meta"]`` *before* they know
    the tree shape (e.g. ``CQPSession.restore``) start here.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))
    arrays = {k: data[k.replace("/", "__")] for k in manifest["leaves"]}
    return arrays, manifest, step


def _validate_leaf(key: str, manifest: dict, target, directory: str) -> None:
    entry = manifest["leaves"].get(key)
    if entry is None:
        raise ValueError(
            f"checkpoint {directory} has no leaf {key!r}; "
            f"saved leaves: {sorted(manifest['leaves'])}"
        )
    want = np.asarray(target)
    if tuple(entry["shape"]) != want.shape:
        raise ValueError(
            f"checkpoint leaf {key!r} has shape {tuple(entry['shape'])} but the "
            f"restore target expects {want.shape}"
        )
    if entry["dtype"] != str(want.dtype):
        raise ValueError(
            f"checkpoint leaf {key!r} has dtype {entry['dtype']} but the "
            f"restore target expects {want.dtype}"
        )


def restore_checkpoint(directory: str, target_tree: Pytree, step: int | None = None,
                       shardings=None) -> tuple[Pytree, int]:
    """Restore into the structure of ``target_tree``; reshards onto the
    current mesh when ``shardings`` (matching tree of NamedSharding) given.

    Every target leaf is validated against the manifest (presence, shape,
    dtype) so a mismatched tree fails with a named error instead of a numpy
    broadcast crash downstream.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))
    leaves = []
    for key, target in _leaf_paths(target_tree):
        _validate_leaf(key, manifest, target, d)
        leaves.append(data[key.replace("/", "__")])
    treedef = jax.tree.structure(target_tree)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree, step


class CheckpointManager:
    """Async keep-N checkpoint manager."""

    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Pytree, *, meta: dict | None = None) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_write:
            self.wait()  # double buffer: at most one write in flight
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, meta), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_tree, meta)

    def _write(self, step: int, tree: Pytree, meta: dict | None = None) -> None:
        save_checkpoint(self.directory, step, tree, meta=meta)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        entries = os.listdir(self.directory)
        steps = sorted(
            d for d in entries if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)
        # a SIGKILLed writer can strand a .tmp dir; at most one write is ever
        # in flight (ours, already renamed), so any .tmp seen here is stale
        for d in entries:
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    def restore_latest(self, target_tree: Pytree, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, target_tree, shardings=shardings)
