"""Deterministic synthetic token/feature pipelines.

Batches are keyed on (seed, step) so a restarted run replays the exact
failed step — the property the fault supervisor relies on.
"""

from __future__ import annotations

import numpy as np


def lm_batch(step: int, *, batch: int, seq_len: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    tokens = rng.integers(0, vocab, size=(batch, seq_len + 1), dtype=np.int32)
    return tokens[:, :-1], tokens[:, 1:]


def mind_batch(step: int, *, batch: int, seq_len: int, num_items: int,
               num_negatives: int = 20, seed: int = 0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 1]))
    behavior = rng.integers(0, num_items, size=(batch, seq_len), dtype=np.int32)
    valid = rng.random((batch, seq_len)) < 0.9
    target = rng.integers(0, num_items, size=batch, dtype=np.int32)
    neg = rng.integers(0, num_items, size=(batch, num_negatives), dtype=np.int32)
    return behavior, valid, target, neg
