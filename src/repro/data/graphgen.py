"""Synthetic dynamic-graph workloads mirroring the paper's setup (§6.1).

The paper shuffles each dataset, loads 90% as the initial graph and streams
the remaining 10% as updates.  We generate power-law graphs (LiveJournal/
Orkut-like), uniform graphs (Patents-like) and labelled graphs (LDBC-like),
then split them the same way.  All generators are seeded and deterministic.
"""

from __future__ import annotations

import numpy as np

Edge = tuple  # (u, v, w[, label])


def powerlaw_graph(
    num_vertices: int,
    num_edges: int,
    *,
    seed: int = 0,
    weighted: bool = True,
    exponent: float = 1.2,
    num_labels: int = 0,
) -> list[Edge]:
    """Directed multigraph-free power-law graph (preferential endpoints)."""
    rng = np.random.default_rng(seed)
    # Zipfian vertex popularity for destination choice → heavy-tailed in-degree
    ranks = np.arange(1, num_vertices + 1, dtype=np.float64)
    probs = ranks ** (-exponent)
    probs /= probs.sum()
    perm = rng.permutation(num_vertices)
    seen: set[tuple[int, int]] = set()
    edges: list[Edge] = []
    while len(edges) < num_edges:
        u = int(perm[rng.choice(num_vertices, p=probs)])
        v = int(perm[rng.choice(num_vertices, p=probs)])
        if u == v or (u, v) in seen:
            continue
        seen.add((u, v))
        w = float(rng.integers(1, 11)) if weighted else 1.0
        if num_labels:
            edges.append((u, v, w, int(rng.integers(1, num_labels + 1))))
        else:
            edges.append((u, v, w))
    return edges


def uniform_graph(
    num_vertices: int, num_edges: int, *, seed: int = 0, weighted: bool = True
) -> list[Edge]:
    rng = np.random.default_rng(seed)
    seen: set[tuple[int, int]] = set()
    edges: list[Edge] = []
    while len(edges) < num_edges:
        u, v = (int(x) for x in rng.integers(0, num_vertices, 2))
        if u == v or (u, v) in seen:
            continue
        seen.add((u, v))
        edges.append((u, v, float(rng.integers(1, 11)) if weighted else 1.0))
    return edges


def split_90_10(edges: list[Edge], *, seed: int = 0) -> tuple[list[Edge], list[Edge]]:
    """Paper §6.1: shuffle, 90% initial graph, 10% update stream."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(edges))
    cut = int(len(edges) * 0.9)
    return [edges[i] for i in order[:cut]], [edges[i] for i in order[cut:]]


def update_stream(
    existing: list[Edge],
    num_vertices: int,
    *,
    num_batches: int,
    batch_size: int = 1,
    delete_fraction: float = 0.0,
    insert_pool: list[Edge] | None = None,
    seed: int = 0,
) -> list[list[tuple[int, int, int, float, int]]]:
    """Batched update stream: inserts from a pool (or fresh random edges) and
    deletes of currently-present edges, in the paper's (u,v,l,w,±) form."""
    rng = np.random.default_rng(seed)
    present = {(int(e[0]), int(e[1])): e for e in existing}
    pool = list(insert_pool or [])
    batches = []
    for _ in range(num_batches):
        batch = []
        for _ in range(batch_size):
            if present and rng.random() < delete_fraction:
                key = list(present)[int(rng.integers(len(present)))]
                e = present.pop(key)
                lbl = int(e[3]) if len(e) > 3 else 0
                batch.append((key[0], key[1], lbl, float(e[2]), -1))
            else:
                if pool:
                    e = pool.pop()
                    key = (int(e[0]), int(e[1]))
                    if key in present:
                        continue
                    lbl = int(e[3]) if len(e) > 3 else 0
                    present[key] = e
                    batch.append((key[0], key[1], lbl, float(e[2]), +1))
                else:
                    u, v = (int(x) for x in rng.integers(0, num_vertices, 2))
                    if u == v or (u, v) in present:
                        continue
                    w = float(rng.integers(1, 11))
                    present[(u, v)] = (u, v, w)
                    batch.append((u, v, 0, w, +1))
        if batch:
            batches.append(batch)
    return batches


def ldbc_like_graph(
    num_vertices: int, num_edges: int, *, seed: int = 0, num_labels: int = 4
) -> list[Edge]:
    """Labelled social-network-like graph (stand-in for LDBC SNB): label 1 ~
    Knows (recursive, vertex-clustered), labels 2..L ~ Likes/ReplyOf/etc."""
    return powerlaw_graph(
        num_vertices, num_edges, seed=seed, weighted=False, num_labels=num_labels
    )
