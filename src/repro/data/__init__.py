"""Data pipelines: synthetic LM tokens, graph generators, update streams,
neighbour samplers, and the LDBC-like labelled graph generator for RPQs."""
