"""Neighbour sampler for minibatch GNN training (GraphSAGE-style fanout).

``minibatch_lg`` requires a real sampler: seed nodes → fanout-bounded
neighbour expansion per hop → fixed-shape padded subgraph (static shapes for
the TPU).  The sampler runs on the host over CSR adjacency; the incremental
variant keeps per-seed K-hop frontiers fresh under edge updates using the
paper's Diff-IFE K-hop engine as its index (see
examples/incremental_gnn_sampling.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # [V+1]
    indices: np.ndarray  # [E]
    num_nodes: int

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray, num_nodes: int) -> "CSRGraph":
        order = np.argsort(src, kind="stable")
        src_s, dst_s = src[order], dst[order]
        indptr = np.zeros(num_nodes + 1, np.int64)
        np.add.at(indptr, src_s + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(indptr=indptr, indices=dst_s.astype(np.int32), num_nodes=num_nodes)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]


@dataclasses.dataclass
class SampledSubgraph:
    """Fixed-shape padded subgraph in *local* node ids; node 0.. are seeds."""

    node_ids: np.ndarray  # int32 [N_max] global ids (padded with -1)
    edge_src: np.ndarray  # int32 [E_max] local ids (padding points at N_max sentinel? no: masked)
    edge_dst: np.ndarray  # int32 [E_max]
    node_mask: np.ndarray  # bool [N_max]
    edge_mask: np.ndarray  # bool [E_max]
    num_seeds: int


def sample_subgraph(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    *,
    max_nodes: int,
    max_edges: int,
    rng: np.random.Generator,
) -> SampledSubgraph:
    """Layer-wise fanout sampling; returns a padded block subgraph."""
    local: dict[int, int] = {int(s): i for i, s in enumerate(seeds)}
    nodes = [int(s) for s in seeds]
    e_src: list[int] = []
    e_dst: list[int] = []
    frontier = list(seeds)
    for fan in fanouts:
        nxt: list[int] = []
        for v in frontier:
            nbrs = g.neighbors(int(v))
            if len(nbrs) > fan:
                nbrs = rng.choice(nbrs, size=fan, replace=False)
            for u in nbrs:
                u = int(u)
                if u not in local:
                    if len(nodes) >= max_nodes:
                        continue
                    local[u] = len(nodes)
                    nodes.append(u)
                if len(e_src) < max_edges:
                    # message flows u → v (neighbour into seed side)
                    e_src.append(local[u])
                    e_dst.append(local[int(v)])
                    nxt.append(u)
        frontier = nxt
        if not frontier:
            break
    n, e = len(nodes), len(e_src)
    node_ids = np.full(max_nodes, -1, np.int32)
    node_ids[:n] = nodes
    src = np.zeros(max_edges, np.int32)
    dst = np.zeros(max_edges, np.int32)
    src[:e], dst[:e] = e_src, e_dst
    return SampledSubgraph(
        node_ids=node_ids,
        edge_src=src,
        edge_dst=dst,
        node_mask=np.arange(max_nodes) < n,
        edge_mask=np.arange(max_edges) < e,
        num_seeds=len(seeds),
    )
