"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — the dry-run sets
``XLA_FLAGS`` before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1×1 mesh over the single local device (smoke tests/benchmarks)."""
    return jax.make_mesh((1, 1), ("data", "model"))
