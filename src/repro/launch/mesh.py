"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — the dry-run sets
``XLA_FLAGS`` before any jax initialization.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1×1 mesh over the single local device (smoke tests/benchmarks)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(num_shards: int | None = None):
    """``(n, 1)`` mesh over the first n local devices — the vertex-sharded
    sweep's ``data`` axis, with a unit ``model`` axis reserved for a future
    Q-axis split.

    This is the shape CI exercises under host emulation
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``); on a real pod
    slice the same specs drive ``make_production_mesh``'s ``data`` axis.
    """
    devs = jax.devices()
    n = len(devs) if num_shards is None else int(num_shards)
    if n > len(devs):
        raise ValueError(f"asked for {n} shards but only {len(devs)} devices")
    return jax.sharding.Mesh(np.array(devs[:n]).reshape(n, 1), ("data", "model"))
