import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  512 placeholder host devices back the production
meshes: 16×16 ("data","model") single-pod and 2×16×16 ("pod","data","model")
multi-pod.  No full-scale array is ever allocated — inputs are
ShapeDtypeStructs; ``compiled.memory_analysis()`` proves the program fits
and ``cost_analysis()`` + HLO collective parsing feed §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax  # noqa: E402  (import order is the point)

from repro.configs import ARCH_NAMES, get_arch
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "dryrun")


def _lm_extrapolated_costs(arch, cfg, shape_name, mesh) -> dict | None:
    """Exact flop/byte/collective totals for scanned-layer LMs.

    XLA's cost_analysis counts a scan body once, so the scanned full-L module
    under-reports per-layer work by ~L×.  Lowering UNROLLED L=1 and L=2
    variants is cheap, and their difference is exactly one layer's cost
    (matmuls, grads, and that layer's optimizer share):
        total(L) = c(1) + (L-1) · (c(2) - c(1)).
    """
    import dataclasses as dc

    if arch.family != "lm":
        return None
    from repro.configs.lm_harness import build_lm_cell

    costs = []
    for nl in (1, 2):
        c = dc.replace(cfg, num_layers=nl, scan_layers=False)
        cell = build_lm_cell(c, shape_name, mesh, force_accum=1)
        lowered = cell.lower()
        compiled = lowered.compile()
        r = hlo_analysis.analyse(cell.name, lowered, compiled, mesh.size, 0.0)
        costs.append((r.hlo_flops, r.hlo_bytes, r.coll_bytes))
    (f1, b1, c1), (f2, b2, c2) = costs
    L = cfg.num_layers
    return {
        "hlo_flops": f1 + (L - 1) * (f2 - f1),
        "hlo_bytes": b1 + (L - 1) * (b2 - b1),
        "coll_bytes": c1 + (L - 1) * (c2 - c1),
    }


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, *, verbose=True) -> dict:
    arch = get_arch(arch_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = arch.full()
    t0 = time.time()
    with mesh:
        cell = arch.build_cell(cfg, shape_name, mesh)
        lowered = cell.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        roof = hlo_analysis.analyse(
            cell.name, lowered, compiled, mesh.size, cell.model_flops
        )
        fixed = _lm_extrapolated_costs(arch, cfg, shape_name, mesh)
        if fixed is not None:
            roof.hlo_flops = fixed["hlo_flops"]
            roof.hlo_bytes = fixed["hlo_bytes"]
            roof.coll_bytes = fixed["coll_bytes"]
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": mesh.size,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": str(mem),
        "per_device_bytes": roof.per_device_hbm_bytes,
        "roofline": roof.to_dict(),
        "status": "ok",
    }
    if verbose:
        print(f"[dryrun] {cell.name} mesh={rec['mesh']} OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {mem}")
        print(f"  cost: flops={roof.hlo_flops:.3e} bytes={roof.hlo_bytes:.3e} "
              f"coll={roof.coll_bytes:.3e} bottleneck={roof.bottleneck}")
    return rec


def save(rec: dict) -> None:
    os.makedirs(REPORT_DIR, exist_ok=True)
    key = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}".replace("/", "_")
    with open(os.path.join(REPORT_DIR, key + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_NAMES + [None])
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    jobs = []
    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        spec = get_arch(a)
        shapes = [args.shape] if args.shape else list(spec.shapes)
        for s in shapes:
            for mp in {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]:
                jobs.append((a, s, mp))

    failures = 0
    for a, s, mp in jobs:
        try:
            rec = run_cell(a, s, mp)
        except Exception as e:  # noqa: BLE001
            failures += 1
            rec = {
                "arch": a, "shape": s,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"[dryrun] {a}:{s} mesh={rec['mesh']} FAILED: {rec['error']}")
            if not args.continue_on_error:
                save(rec)
                raise
        save(rec)
    print(f"[dryrun] done: {len(jobs) - failures}/{len(jobs)} ok")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
