"""Continuous-query serving driver: stream an update log through a session.

The serving shape of the paper's CQP, engine-agnostic via
:class:`repro.core.session.CQPSession`: Q registered queries, one δE log
streamed in fixed-shape chunks of B updates, and a *query-churn* scenario —
``--register-at K`` registers a fresh query before chunk K (its trace is
initialized in-engine), ``--deregister-at K`` retires the oldest live query
and reclaims its difference bytes.  Reports updates/sec, p50/p99 per-chunk
maintenance latency, peak diff-store bytes, and churn-event latencies.

``--engine`` selects the executor behind the same session API:

    dense    the TPU engine (donated-buffer batched chunks; --mesh shards it)
    host     the paper's pointer machine (work ∝ affected set, on the host)
    scratch  from-scratch re-execution baseline

``--budget-bytes`` puts the stream under the memory governor (DESIGN.md
§10): a global accounted-byte budget enforced online by escalating each
query along the drop-policy ladder; ``--governor det|prob`` picks the
provisioned DroppedVT representation.  The JSON report then carries the
per-query byte breakdown, the governor's action log, and its headroom.

``--plan-file plans.json`` registers operator-graph plans loaded from JSON
(the ``QueryPlan.to_json`` schema — DESIGN.md §11) instead of the synthetic
``--query`` batch; the JSON report carries ``nbytes_per_operator``, the
per-(query, operator) byte breakdown, either way.

Examples::

    PYTHONPATH=src python -m repro.launch.cqp_serve --smoke
    PYTHONPATH=src python -m repro.launch.cqp_serve \
        --v 512 --e 2048 --queries 16 --updates 256 --batch 32 --backend ell
    # operator-graph plans from JSON (e.g. an RPQ with a materialized join)
    PYTHONPATH=src python -m repro.launch.cqp_serve --smoke --json \
        --plan-file plans.json --backend coo
    # churn: register before chunk 2, deregister before chunk 4, on all engines
    for eng in dense host scratch; do
      PYTHONPATH=src python -m repro.launch.cqp_serve --smoke --json \
          --engine $eng --register-at 2 --deregister-at 4
    done
    # closed-loop memory budget (Bloom DroppedVT, 4 KiB global)
    PYTHONPATH=src python -m repro.launch.cqp_serve --smoke --json \
        --budget-bytes 4096 --governor prob
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.cqp_serve --smoke --mesh data
    # durability drill: checkpoint every 2 chunks, inject a fault before
    # chunk 3, restore + replay — answers match the uninterrupted run
    PYTHONPATH=src python -m repro.launch.cqp_serve --smoke --json \
        --checkpoint-dir /tmp/cqp_ckpt --checkpoint-every 2 --inject-fault-at 3
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from collections import Counter

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.metrics import PhaseRecorder, summarize_latency_s


def make_mesh(kind: str, shards: int | None):
    """Resolve --mesh into a jax Mesh (imports jax lazily: --emulate-devices
    must set XLA_FLAGS before any backend initialization)."""
    from repro.launch.mesh import (
        make_data_mesh,
        make_production_mesh,
        make_smoke_mesh,
    )

    if kind == "none":
        return None
    if kind == "smoke":
        return make_smoke_mesh()
    if kind == "data":
        return make_data_mesh(shards)
    return make_production_mesh()


def load_plan_file(path: str):
    """Operator-graph plans from JSON: a list of plan objects (or
    ``{"plans": [...]}``), each ``{"kind": ..., "nodes": [...]}`` in the
    :meth:`repro.core.plan.QueryPlan.to_json` schema.  All plans must share
    one family (one session compiles one sweep shape)."""
    from repro.core.plan import QueryPlan

    with open(path) as fh:
        payload = json.load(fh)
    if isinstance(payload, dict):
        payload = payload.get("plans", [payload])
    if not payload:
        raise SystemExit(f"plan file {path!r} holds no plans")
    try:
        plans = [QueryPlan.from_json(obj) for obj in payload]
    except (KeyError, ValueError) as exc:
        raise SystemExit(f"plan file {path!r}: {exc}") from exc
    return plans


def initial_plans(args):
    """The query batch registered before the stream starts."""
    from repro.core import plan

    if args.plan_file is not None:
        plans = load_plan_file(args.plan_file)
        args.queries = len(plans)
        return plans
    if args.query == "sssp":
        return [
            plan.sssp(s, max_iters=args.max_iters) for s in range(args.queries)
        ]
    if args.query == "spsp":
        # source/target pairs half the vertex space apart — the planner's
        # landmark pass (--optimize) rewrites these onto a shared index
        return [
            plan.spsp(s, (s + args.v // 2) % args.v, max_iters=args.max_iters)
            for s in range(args.queries)
        ]
    if args.query == "khop":
        return [
            plan.khop(s, k=min(6, args.max_iters)) for s in range(args.queries)
        ]
    if args.query == "pagerank":
        args.queries = 1  # PageRank is a single batch computation (§6.1.2)
        return [plan.pagerank(iters=min(10, args.max_iters))]
    raise SystemExit(f"unknown query {args.query!r}")


def churn_plan(args, seq: int):
    """The query a --register-at event brings in (same family, new source)."""
    from repro.core import plan

    source = (args.queries + seq) % args.v
    if args.query == "sssp":
        return plan.sssp(source, max_iters=args.max_iters)
    if args.query == "spsp":
        return plan.spsp(
            source, (source + args.v // 2) % args.v, max_iters=args.max_iters
        )
    if args.query == "khop":
        return plan.khop(source, k=min(6, args.max_iters))
    return plan.pagerank(iters=min(10, args.max_iters))


def build_log(args):
    """The run's deterministic workload, fully derived from the args/seed —
    a restore rebuilds the identical log and replays its suffix."""
    from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream

    edges = powerlaw_graph(args.v, args.e, seed=args.seed)
    initial, pool = split_90_10(edges, seed=args.seed)
    stream = update_stream(
        initial,
        args.v,
        num_batches=max(1, args.updates // max(args.batch, 1)),
        batch_size=args.batch,
        insert_pool=pool,
        delete_fraction=args.delete_fraction,
        seed=args.seed + 1,
    )
    log = [u for batch in stream for u in batch]
    return edges, initial, log


def build_session(args):
    from repro.core.graph import DynamicGraph
    from repro.core.session import CQPSession

    edges, initial, log = build_log(args)
    graph = DynamicGraph(args.v, initial, capacity=len(edges) * 4 + 64)
    mesh = make_mesh(args.mesh, args.shards)
    if mesh is not None and args.engine != "dense":
        raise SystemExit("--mesh shards the dense engine only")
    plans = initial_plans(args)
    gov_kw = {}
    if args.budget_bytes is not None:
        from repro.core.governor import GovernorConfig

        gov_kw = dict(
            budget_bytes=args.budget_bytes,
            governor=GovernorConfig(
                representation=args.governor, bloom_bits=args.governor_bloom_bits
            ),
        )
    session = CQPSession(
        graph,
        engine=args.engine,
        mesh=mesh,
        backend=args.backend,
        batch_capacity=args.batch,
        min_slots=len(plans),
        optimize=args.optimize,
        **gov_kw,
    )
    handles = session.register_many(plans)
    return session, handles, log


def serve(args) -> dict:
    if getattr(args, "trace_out", None):
        # install a live tracer before any engine work so session/engine/
        # governor/recovery spans land in the exported Chrome trace
        obs_trace.set_tracer(obs_trace.Tracer())
    t0 = time.perf_counter()
    restore_latency = None
    start_chunk = 0
    if args.restore:
        from repro.core.session import CQPSession

        mesh = make_mesh(args.mesh, args.shards)
        if mesh is not None and args.engine != "dense":
            raise SystemExit("--mesh shards the dense engine only")
        session = CQPSession.restore(args.checkpoint_dir, mesh=mesh)
        initial_plans(args)  # normalize args.queries (plan files / pagerank)
        handles = session.handles()
        extra = (session.restore_info or {}).get("extra") or {}
        start_chunk = int(extra.get("next_chunk", 0))
        _, _, log = build_log(args)
        restore_latency = time.perf_counter() - t0
    else:
        session, handles, log = build_session(args)
    t_init = time.perf_counter() - t0

    b = args.batch
    chunks = [log[i : i + b] for i in range(0, len(log), b)]
    if not chunks:
        raise SystemExit("empty update log — raise --updates")
    if start_chunk > len(chunks):
        raise SystemExit(
            f"checkpoint cursor {start_chunk} past the {len(chunks)}-chunk "
            "log — restore with the args the checkpointed run used"
        )
    # repeated flags at the same chunk index fire that many events
    register_at = Counter(args.register_at or [])
    deregister_at = Counter(args.deregister_at or [])
    for k in list(register_at) + list(deregister_at):
        if not (0 < k < len(chunks)):
            raise SystemExit(
                f"churn index {k} outside the mid-stream range "
                f"1..{len(chunks) - 1} ({len(chunks)} chunks)"
            )

    def dev_peak(s):
        # unsharded, per-device == total: don't pay a second per-chunk fetch
        return max(s.nbytes_per_device()) if s.num_shards > 1 else s.nbytes()

    # governor settling window: the first SETTLE post-warmup chunks may run
    # over budget while policies escalate; the peak after it must respect it
    settle = 2
    # mutable run metrics, shared with the per-chunk closure: a fault
    # restart swaps the session object, so nothing below closes over it
    M = {
        "handles": handles,
        "lat": [],
        "reg_ms": [],
        "dereg_ms": [],
        "bytes_freed": 0,
        "served": 0,
        "warmup_served": 0,
        "peak": session.nbytes(),
        "peak_dev": dev_peak(session),
        "t_compile": 0.0,
        "t_serve": 0.0,
        # replay determinism: a restored session derives the next churn
        # source from how many churn registers already happened
        "churn_seq": max(session.registered_total - args.queries, 0),
        "settled_peak": 0,
        "settled_samples": 0,
    }

    def run_chunk(s, k, chunk):
        if k == 0 and M["t_compile"] == 0.0:
            # warmup chunk: traces + compiles the batched step (reported
            # separately; churn indices are validated mid-stream only)
            t0 = time.perf_counter()
            s.apply_updates_batched(chunk, batch_size=b)
            M["t_compile"] = time.perf_counter() - t0
            M["served"] += len(chunk)
            M["warmup_served"] = len(chunk)
        else:
            for _ in range(register_at.get(k, 0)):
                t0 = time.perf_counter()
                M["handles"].append(s.register(churn_plan(args, M["churn_seq"])))
                M["reg_ms"].append((time.perf_counter() - t0) * 1e3)
                M["churn_seq"] += 1
            for _ in range(deregister_at.get(k, 0)):
                if not M["handles"]:
                    break
                t0 = time.perf_counter()
                M["bytes_freed"] += s.deregister(M["handles"].pop(0))
                M["dereg_ms"].append((time.perf_counter() - t0) * 1e3)
            t0 = time.perf_counter()
            s.apply_updates_batched(chunk, batch_size=b)
            dt = time.perf_counter() - t0
            M["lat"].append(dt)
            M["t_serve"] += dt
            M["served"] += len(chunk)
        M["peak"] = max(M["peak"], s.nbytes())
        M["peak_dev"] = max(M["peak_dev"], dev_peak(s))
        if k > settle:
            M["settled_peak"] = max(M["settled_peak"], s.nbytes())
            M["settled_samples"] += 1

    sup = det = None
    if args.checkpoint_dir is not None:
        from repro.core.session import CQPSession
        from repro.runtime.fault import FaultPolicy, InjectedFault
        from repro.runtime.recovery import RecoverySupervisor
        from repro.runtime.straggler import StragglerDetector

        det = StragglerDetector()
        fired: set[int] = set()
        inject_at = set(args.inject_fault_at or [])

        def injector(k: int) -> None:
            if k in inject_at and k not in fired:
                fired.add(k)  # one-shot: the drill must recover, not loop
                raise InjectedFault(f"injected fault before chunk {k}")

        def restore_fn(directory):
            if directory is None:
                # no checkpoint landed before the fault: genesis replay
                s, M["handles"], _ = build_session(args)
                start = 0
            else:
                s = CQPSession.restore(
                    directory, mesh=make_mesh(args.mesh, args.shards)
                )
                M["handles"] = s.handles()
                extra = (s.restore_info or {}).get("extra") or {}
                start = int(extra.get("next_chunk", 0))
            M["churn_seq"] = max(s.registered_total - args.queries, 0)
            s.attach_runtime(straggler=det, supervisor=sup)
            return s, start

        sup = RecoverySupervisor(
            args.checkpoint_dir,
            FaultPolicy(
                max_restarts=args.max_restarts,
                checkpoint_every=args.checkpoint_every,
                backoff_s=args.backoff_s,
            ),
            keep=args.checkpoint_keep,
            restore_fn=restore_fn,
            fault_injector=injector,
            straggler=det,
        )
        session.attach_runtime(straggler=det, supervisor=sup)
        session = sup.run(session, chunks, run_chunk, start_chunk=start_chunk)
    else:
        for k in range(start_chunk, len(chunks)):
            run_chunk(session, k, chunks[k])

    if M["settled_samples"] == 0:
        # stream shorter than the settling window: judge the final state
        # rather than vacuously reporting a respected budget
        M["settled_peak"] = session.nbytes()

    steady = bool(M["lat"])
    if not steady:
        # single-chunk log: the only measurement includes trace+compile
        print(
            "warning: update log fits one chunk — latencies include compile; "
            "raise --updates past --batch for steady-state numbers"
        )
    lat_s = M["lat"] if steady else [M["t_compile"]]
    latency = summarize_latency_s(lat_s)
    served = M["served"]
    reg_ms, dereg_ms = M["reg_ms"], M["dereg_ms"]
    bytes_freed = M["bytes_freed"]
    t_compile = M["t_compile"]
    phases = PhaseRecorder()
    phases.extend("maintain", lat_s)
    phases.extend("register", [x / 1e3 for x in reg_ms])
    phases.extend("deregister", [x / 1e3 for x in dereg_ms])
    if sup is not None:
        phases.extend("checkpoint", sup.checkpoint_s)
    out = {
        "engine": args.engine,
        "queries": args.queries,
        "final_queries": session.num_queries,
        "batch": b,
        "backend": args.backend,
        "updates_served": served,
        "updates_per_sec": (
            (served - M["warmup_served"]) / max(M["t_serve"], 1e-9)
            if steady
            else served / max(t_compile, 1e-9)
        ),
        # flat p50/p99 keys kept for existing consumers; the full
        # percentile set (incl. p999) is the shared `latency` block
        "p50_ms": latency["p50_ms"],
        "p99_ms": latency["p99_ms"],
        "latency": latency,
        "phases": phases.summary(),
        "steady_state": steady,
        "peak_diff_bytes": int(M["peak"]),
        "shards": session.num_shards,
        "peak_diff_bytes_per_device": int(M["peak_dev"]),
        "registers": len(reg_ms),
        "deregisters": len(dereg_ms),
        "register_ms": [float(x) for x in reg_ms],
        "deregister_ms": [float(x) for x in dereg_ms],
        "bytes_freed": int(bytes_freed),
        "nbytes_per_query": [int(x) for x in session.nbytes_per_query()],
        "nbytes_per_operator": [
            {op: int(b) for op, b in ops.items()}
            for ops in session.nbytes_per_operator()
        ],
        "init_s": t_init,
        "compile_s": t_compile,
    }
    if sup is not None:
        rec = sup.metrics()
        rec["checkpoint_dir"] = args.checkpoint_dir
        rec["checkpoint_every"] = args.checkpoint_every
        rec["live_nbytes"] = int(session.nbytes())
        rec["restore_latency_s"] = restore_latency
        rec["straggler_events"] = len(det.events)
        out["recovery"] = rec
        runtime = session.stats().get("runtime")
        if runtime is not None:
            out["runtime"] = runtime
    if session.governor is not None:
        gov = session.governor
        out["governor"] = {
            **gov.snapshot(session),
            "representation": gov.cfg.representation,
            "settled_peak_bytes": int(M["settled_peak"]),
            "budget_respected": bool(M["settled_peak"] <= gov.budget_bytes),
        }
    planner_stats = session.stats().get("planner")
    if planner_stats is not None:
        out["planner"] = planner_stats
    print(
        f"cqp_serve[{args.query}/{args.engine}/{args.backend}] "
        f"Q={args.queries}→{out['final_queries']} B={b}: "
        f"{out['updates_per_sec']:.1f} updates/sec over {served} updates"
    )
    print(
        f"  maintenance latency p50={out['p50_ms']:.2f} ms "
        f"p99={out['p99_ms']:.2f} ms per {b}-update chunk"
        + ("" if steady else " (includes compile)")
    )
    if reg_ms or dereg_ms:
        print(
            f"  churn: {len(reg_ms)} register(s) "
            f"({sum(reg_ms):.1f} ms total, in-engine re-trace), "
            f"{len(dereg_ms)} deregister(s) freeing {bytes_freed} diff bytes"
        )
    print(
        f"  peak diff-store bytes={out['peak_diff_bytes']} "
        f"per-device={out['peak_diff_bytes_per_device']} "
        f"over {out['shards']} shard(s) "
        f"(init {t_init:.2f}s, first-chunk compile {t_compile:.2f}s)"
    )
    if "governor" in out:
        g = out["governor"]
        print(
            f"  governor[{g['representation']}]: budget={g['budget_bytes']} "
            f"settled-peak={g['settled_peak_bytes']} "
            f"headroom={g['headroom_bytes']} "
            f"({'respected' if g['budget_respected'] else 'VIOLATED'}; "
            f"{g['escalations']} escalation(s), "
            f"{g['deescalations']} de-escalation(s))"
        )
    if "planner" in out:
        p = out["planner"]
        lmk = p.get("landmark", {})
        print(
            f"  planner[{p['mode']}]: {p['rewrites_total']} rewrite(s), "
            f"landmark index live={lmk.get('live')} "
            f"bytes={lmk.get('index_nbytes', 0)} "
            f"(sheds={lmk.get('sheds_total', 0)}, "
            f"remats={lmk.get('remats_total', 0)})"
        )
    if "recovery" in out:
        r = out["recovery"]
        ckpt_s = sum(r["checkpoint_s"])
        print(
            f"  recovery: {r['checkpoints']} checkpoint(s) "
            f"({ckpt_s * 1e3:.1f} ms total, {r['checkpoint_bytes']} bytes "
            f"vs {r['live_nbytes']} live), {r['restarts']} restart(s), "
            f"{r['replayed_chunks']} chunk(s) replayed, "
            f"{r['straggler_events']} straggler event(s)"
        )
    if getattr(args, "metrics_out", None) or getattr(args, "trace_out", None):
        session.publish_metrics()  # final scrape of the DC probes
    if getattr(args, "metrics_out", None):
        with open(args.metrics_out, "w") as fh:
            json.dump(obs_metrics.get_registry().snapshot(), fh, indent=1)
        print(f"  metrics snapshot -> {args.metrics_out}")
    if getattr(args, "trace_out", None):
        n = obs_trace.get_tracer().export(args.trace_out)
        out["trace_events"] = n
        print(f"  trace: {n} event(s) -> {args.trace_out} "
              "(load in ui.perfetto.dev)")
    if args.json:
        print(json.dumps(out))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--v", type=int, default=512)
    ap.add_argument("--e", type=int, default=2048)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--updates", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--max-iters", type=int, default=48)
    ap.add_argument("--delete-fraction", type=float, default=0.2)
    ap.add_argument(
        "--query",
        choices=("sssp", "spsp", "khop", "pagerank"),
        default="sssp",
    )
    ap.add_argument(
        "--optimize",
        choices=("none", "auto", "always"),
        default="none",
        help="plan optimizer mode (repro.planner): auto rewrites matching "
        "plans when the cost model says the rewrite pays (e.g. --query spsp "
        "onto the shared landmark index, DESIGN.md §16); always bypasses "
        "the cost gate",
    )
    ap.add_argument(
        "--plan-file",
        default=None,
        metavar="PLANS_JSON",
        help="register operator-graph plans loaded from a JSON file "
        "(QueryPlan.to_json schema) instead of the --query/--queries batch; "
        "the synthetic stream carries edge label 0, so RPQ plans should "
        "match label 0",
    )
    ap.add_argument(
        "--engine",
        choices=("dense", "host", "scratch"),
        default="dense",
        help="executor behind the session API (CQPSession)",
    )
    ap.add_argument(
        "--backend",
        choices=("coo", "ell", "fused"),
        default="ell",
        help="sweep aggregator: coo=segment-reduce, ell=Pallas SpMV, "
        "fused=maintenance megakernel (one pallas_call per iteration)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--register-at",
        type=int,
        action="append",
        default=None,
        metavar="CHUNK",
        help="register one extra query before streaming chunk CHUNK "
        "(repeatable; 1-based mid-stream index)",
    )
    ap.add_argument(
        "--deregister-at",
        type=int,
        action="append",
        default=None,
        metavar="CHUNK",
        help="deregister the oldest live query before chunk CHUNK (repeatable)",
    )
    ap.add_argument(
        "--budget-bytes",
        type=int,
        default=None,
        help="global accounted-byte budget enforced by the memory governor "
        "(escalates per-query drop policies online; DESIGN.md §10)",
    )
    ap.add_argument(
        "--governor",
        choices=("det", "prob"),
        default="prob",
        help="DroppedVT representation the governor provisions "
        "(det: ≤4 B/record floor ~ half the static bytes; prob: fixed "
        "Bloom rows, deepest reclamation)",
    )
    ap.add_argument(
        "--governor-bloom-bits",
        type=int,
        default=1 << 9,
        help="per-query Bloom bits for --governor prob (64 B packed default)",
    )
    ap.add_argument(
        "--smoke", action="store_true", help="tiny CPU-friendly end-to-end run"
    )
    ap.add_argument(
        "--mesh",
        choices=("none", "smoke", "data", "production"),
        default="none",
        help="mesh to serve on: 'data' shards the sweep over the local "
        "devices' data axis (see --emulate-devices), 'production' is the "
        "16x16 pod mesh",
    )
    ap.add_argument(
        "--shards", type=int, default=None,
        help="data-axis size for --mesh data (default: all local devices)",
    )
    ap.add_argument(
        "--emulate-devices", type=int, default=0,
        help="emulate N host devices (sets XLA_FLAGS before jax init; "
        "equivalent to XLA_FLAGS=--xla_force_host_platform_device_count=N)",
    )
    ap.add_argument(
        "--checkpoint-dir",
        default=None,
        metavar="DIR",
        help="enable durability: periodic session checkpoints into DIR via "
        "the async keep-N CheckpointManager (DESIGN.md §12)",
    )
    ap.add_argument(
        "--checkpoint-every",
        type=int,
        default=4,
        metavar="CHUNKS",
        help="checkpoint every K streamed chunks (0 disables the periodic "
        "snapshots while keeping the recovery supervisor active)",
    )
    ap.add_argument(
        "--checkpoint-keep", type=int, default=3,
        help="checkpoints retained on disk (older ones are GCed)",
    )
    ap.add_argument(
        "--restore",
        action="store_true",
        help="restore the latest checkpoint from --checkpoint-dir and "
        "resume at its saved log cursor (the CLI args must match the "
        "checkpointed run so the rebuilt log is identical)",
    )
    ap.add_argument(
        "--inject-fault-at",
        type=int,
        action="append",
        default=None,
        metavar="CHUNK",
        help="recovery drill: raise InjectedFault before chunk CHUNK "
        "(one-shot, repeatable); the supervisor restores the latest "
        "checkpoint and replays the log suffix",
    )
    ap.add_argument(
        "--max-restarts", type=int, default=5,
        help="restarts tolerated before the fault is re-raised",
    )
    ap.add_argument(
        "--backoff-s", type=float, default=0.0,
        help="delay before each restart",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        metavar="TRACE_JSON",
        help="enable the structured tracer and export a Chrome-trace JSON "
        "(loadable in ui.perfetto.dev / chrome://tracing) with spans for "
        "update batches, sweep iterations, kernel dispatches, repairs, "
        "governor actions, and checkpoints (DESIGN.md §15)",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="METRICS_JSON",
        help="write a JSON snapshot of the obs metrics registry (counters / "
        "gauges / histograms incl. the DC probes) at end of run",
    )
    ap.add_argument("--json", action="store_true", help="emit a JSON result line")
    args = ap.parse_args()
    if args.batch < 1:
        ap.error("--batch must be >= 1")
    if args.restore and args.checkpoint_dir is None:
        ap.error("--restore needs --checkpoint-dir")
    if args.inject_fault_at and args.checkpoint_dir is None:
        ap.error("--inject-fault-at needs --checkpoint-dir (the drill "
                 "restores from it)")
    if args.plan_file is not None and args.register_at:
        ap.error(
            "--register-at derives churn plans from --query and cannot "
            "be combined with --plan-file (one session, one family)"
        )
    if args.emulate_devices:
        if "jax" in sys.modules:
            ap.error("--emulate-devices must run before jax is imported")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.emulate_devices}"
        ).strip()
    if args.smoke:
        args.v, args.e = min(args.v, 64), min(args.e, 256)
        args.queries = min(args.queries, 4)
        args.updates, args.batch = min(args.updates, 32), min(args.batch, 8)
        args.max_iters = min(args.max_iters, 24)
    serve(args)


if __name__ == "__main__":
    main()
