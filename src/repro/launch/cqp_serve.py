"""Continuous-query serving driver: stream an update log through the engine.

The serving shape of the paper's CQP: Q registered queries (batched in the
engine's leading axis — one compiled sweep serves all of them), one δE log
streamed in fixed-shape chunks of B updates through the donated-buffer
batched step (``DiffIFE.apply_updates_batched``).  Reports updates/sec,
p50/p99 per-chunk maintenance latency, and peak diff-store bytes — the
throughput/memory trade the paper's Table 1 frames.

With ``--mesh data`` the engine shards every per-vertex carry over the mesh
``data`` axis (``shard_map`` sweep, DESIGN.md §8); run under host emulation
to exercise it without a pod:

    PYTHONPATH=src python -m repro.launch.cqp_serve --smoke
    PYTHONPATH=src python -m repro.launch.cqp_serve \
        --v 512 --e 2048 --queries 16 --updates 256 --batch 32 --backend ell
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.cqp_serve --smoke --mesh data
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def make_mesh(kind: str, shards: int | None):
    """Resolve --mesh into a jax Mesh (imports jax lazily: --emulate-devices
    must set XLA_FLAGS before any backend initialization)."""
    from repro.launch.mesh import (
        make_data_mesh,
        make_production_mesh,
        make_smoke_mesh,
    )

    if kind == "none":
        return None
    if kind == "smoke":
        return make_smoke_mesh()
    if kind == "data":
        return make_data_mesh(shards)
    return make_production_mesh()


def build_engine(args):
    from repro.core import queries as q
    from repro.core.graph import DynamicGraph
    from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream

    edges = powerlaw_graph(args.v, args.e, seed=args.seed)
    initial, pool = split_90_10(edges, seed=args.seed)
    stream = update_stream(
        initial,
        args.v,
        num_batches=max(1, args.updates // max(args.batch, 1)),
        batch_size=args.batch,
        insert_pool=pool,
        delete_fraction=args.delete_fraction,
        seed=args.seed + 1,
    )
    log = [u for batch in stream for u in batch]
    graph = DynamicGraph(args.v, initial, capacity=len(edges) * 4 + 64)
    sources = list(range(args.queries))
    mesh = make_mesh(args.mesh, args.shards)
    kw = dict(backend=args.backend, batch_capacity=args.batch, mesh=mesh)
    if args.query == "sssp":
        eng = q.sssp(graph, sources, max_iters=args.max_iters, **kw)
    elif args.query == "khop":
        eng = q.khop(graph, sources, k=min(6, args.max_iters), **kw)
    elif args.query == "pagerank":
        args.queries = 1  # PageRank is a single batch computation (paper §6.1.2)
        eng = q.pagerank(graph, iters=min(10, args.max_iters), **kw)
    else:
        raise SystemExit(f"unknown query {args.query!r}")
    return eng, log


def serve(args) -> dict:
    t0 = time.perf_counter()
    eng, log = build_engine(args)
    t_init = time.perf_counter() - t0

    b = args.batch
    chunks = [log[i : i + b] for i in range(0, len(log), b)]
    if not chunks:
        raise SystemExit("empty update log — raise --updates")

    # warmup chunk: traces + compiles the batched step (reported separately)
    t0 = time.perf_counter()
    eng.apply_updates_batched(chunks[0], batch_size=b)
    t_compile = time.perf_counter() - t0

    # unsharded, per-device == total: don't pay a second per-chunk fetch
    dev_peak = (
        (lambda: max(eng.nbytes_per_device()))
        if eng.num_shards > 1
        else eng.nbytes
    )
    lat_s: list[float] = []
    peak_bytes = eng.nbytes()
    peak_dev_bytes = dev_peak()
    served = len(chunks[0])
    t_serve0 = time.perf_counter()
    for chunk in chunks[1:]:
        t0 = time.perf_counter()
        eng.apply_updates_batched(chunk, batch_size=b)  # stats sync the device
        lat_s.append(time.perf_counter() - t0)
        served += len(chunk)
        peak_bytes = max(peak_bytes, eng.nbytes())
        peak_dev_bytes = max(peak_dev_bytes, dev_peak())
    t_serve = time.perf_counter() - t_serve0

    steady = bool(lat_s)
    if not steady:
        # single-chunk log: the only measurement includes trace+compile
        print(
            "warning: update log fits one chunk — latencies include compile; "
            "raise --updates past --batch for steady-state numbers"
        )
    lat = np.asarray(lat_s if steady else [t_compile])
    out = {
        "queries": args.queries,
        "batch": b,
        "backend": args.backend,
        "updates_served": served,
        "updates_per_sec": (
            (served - len(chunks[0])) / t_serve if steady else served / t_compile
        ),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "steady_state": steady,
        "peak_diff_bytes": int(peak_bytes),
        "shards": eng.num_shards,
        "peak_diff_bytes_per_device": int(peak_dev_bytes),
        "init_s": t_init,
        "compile_s": t_compile,
    }
    print(
        f"cqp_serve[{args.query}/{args.backend}] Q={args.queries} B={b}: "
        f"{out['updates_per_sec']:.1f} updates/sec over {served} updates"
    )
    print(
        f"  maintenance latency p50={out['p50_ms']:.2f} ms "
        f"p99={out['p99_ms']:.2f} ms per {b}-update chunk"
        + ("" if steady else " (includes compile)")
    )
    print(
        f"  peak diff-store bytes={out['peak_diff_bytes']} "
        f"per-device={out['peak_diff_bytes_per_device']} "
        f"over {out['shards']} shard(s) "
        f"(init {t_init:.2f}s, first-chunk compile {t_compile:.2f}s)"
    )
    if args.json:
        print(json.dumps(out))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--v", type=int, default=512)
    ap.add_argument("--e", type=int, default=2048)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--updates", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--max-iters", type=int, default=48)
    ap.add_argument("--delete-fraction", type=float, default=0.2)
    ap.add_argument("--query", choices=("sssp", "khop", "pagerank"), default="sssp")
    ap.add_argument("--backend", choices=("coo", "ell"), default="ell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--smoke", action="store_true", help="tiny CPU-friendly end-to-end run"
    )
    ap.add_argument(
        "--mesh",
        choices=("none", "smoke", "data", "production"),
        default="none",
        help="mesh to serve on: 'data' shards the sweep over the local "
        "devices' data axis (see --emulate-devices), 'production' is the "
        "16x16 pod mesh",
    )
    ap.add_argument(
        "--shards", type=int, default=None,
        help="data-axis size for --mesh data (default: all local devices)",
    )
    ap.add_argument(
        "--emulate-devices", type=int, default=0,
        help="emulate N host devices (sets XLA_FLAGS before jax init; "
        "equivalent to XLA_FLAGS=--xla_force_host_platform_device_count=N)",
    )
    ap.add_argument("--json", action="store_true", help="emit a JSON result line")
    args = ap.parse_args()
    if args.batch < 1:
        ap.error("--batch must be >= 1")
    if args.emulate_devices:
        if "jax" in sys.modules:
            ap.error("--emulate-devices must run before jax is imported")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.emulate_devices}"
        ).strip()
    if args.smoke:
        args.v, args.e = min(args.v, 64), min(args.e, 256)
        args.queries = min(args.queries, 4)
        args.updates, args.batch = min(args.updates, 32), min(args.batch, 8)
        args.max_iters = min(args.max_iters, 24)
    serve(args)


if __name__ == "__main__":
    main()
