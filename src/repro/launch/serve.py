"""Deprecated shim — this module moved to :mod:`repro.launch.model_serve`.

``launch/serve.py`` historically held the LM/MIND *model*-serving demo; the
name now collides with the CQP serving tier, so the demo lives at
``repro.launch.model_serve`` and this shim re-exports it with a
``DeprecationWarning``.

If you are looking for *continuous-query* serving — tenants, admission
control, overload shedding over a :class:`~repro.core.session.CQPSession` —
that is :mod:`repro.serving` (``python -m repro.serving.server``).
"""

from __future__ import annotations

import warnings

from repro.launch.model_serve import lm_serve, main, mind_serve  # noqa: F401

warnings.warn(
    "repro.launch.serve moved to repro.launch.model_serve (CQP query "
    "serving lives in repro.serving)",
    DeprecationWarning,
    stacklevel=2,
)

if __name__ == "__main__":
    main()
