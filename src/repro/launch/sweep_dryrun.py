import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Maintenance-sweep dry-run: lower + compile the stitched and fused sweep.

The env line above MUST run before jax initializes (the emulated 8-device
mesh backs the ``shards=8`` cells).  Produces ``reports/dryrun/*.json`` in
the same schema as ``repro.launch.dryrun`` so ``benchmarks/roofline.py``
aggregates both: per cell, the record carries lower/compile wall time,
``compiled.memory_analysis()``, and the :mod:`repro.launch.hlo_analysis`
roofline terms (compute vs memory vs collective seconds, bottleneck class,
useful-FLOP ratio).

Cells: ``backend ∈ {ell, fused} × shards ∈ {1, 8}`` over a synthetic
uniform graph.  The model-FLOP baseline is the sweep's algorithmic work,
``2·E·Q`` per iteration (one multiply-add per edge message per query) —
everything else the stitched path does (diff-store rewrites, Bloom probes)
is maintenance overhead the fused kernel folds into one pass, which is why
the fused cell sits at the memory roof, not the compute roof.

    PYTHONPATH=src python -m repro.launch.sweep_dryrun --v 512 --e 2048
    PYTHONPATH=src python -m benchmarks.roofline --markdown
"""

import argparse
import json
import time
import traceback

import numpy as np

from repro.launch import hlo_analysis

REPORT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "reports", "dryrun"
)


def _graph(v: int, e: int, seed: int = 0):
    from repro.core.graph import DynamicGraph

    rng = np.random.default_rng(seed)
    seen = {}
    while len(seen) < e:
        u, w = int(rng.integers(0, v)), int(rng.integers(0, v))
        if u != w:
            seen[(u, w)] = (u, w, 0, float(rng.integers(1, 10)), +1)
    return DynamicGraph(v, list(seen.values()), capacity=2 * e)


def run_cell(
    backend: str,
    shards: int,
    *,
    v: int,
    e: int,
    num_queries: int,
    max_iters: int,
    verbose: bool = True,
) -> dict:
    import jax
    import jax.numpy as jnp

    import repro.core.queries as q
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(shards) if shards > 1 else None
    sources = [int(s) for s in np.linspace(0, v - 1, num_queries)]
    t0 = time.time()
    eng = q.sssp(
        _graph(v, e),
        sources,
        max_iters=max_iters,
        backend=backend,
        mesh=mesh,
    )
    dirty = jnp.ones((v,), bool)
    lowered = eng._maintain.lower(eng.state, eng.g, dirty)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    # algorithmic work per sweep iteration: one op per edge message per query
    model_flops = 2.0 * e * num_queries
    roof = hlo_analysis.analyse(
        f"sweep-{backend}", lowered, compiled, shards, model_flops
    )
    rec = {
        "arch": f"sweep-{backend}",
        "shape": f"v{v}-e{e}-q{num_queries}",
        "mesh": f"1x{shards}" if shards > 1 else "single",
        "num_devices": shards,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": str(mem),
        "per_device_bytes": roof.per_device_hbm_bytes,
        "roofline": roof.to_dict(),
        "status": "ok",
    }
    if verbose:
        print(
            f"[sweep-dryrun] {rec['arch']} {rec['shape']} mesh={rec['mesh']} OK "
            f"(lower {t_lower:.1f}s compile {t_compile:.1f}s "
            f"bottleneck={roof.bottleneck})"
        )
    return rec


def save(rec: dict) -> None:
    os.makedirs(REPORT_DIR, exist_ok=True)
    key = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}".replace("/", "_")
    with open(os.path.join(REPORT_DIR, key + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--v", type=int, default=512)
    ap.add_argument("--e", type=int, default=2048)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--max-iters", type=int, default=32)
    ap.add_argument(
        "--backend",
        default="both",
        choices=["ell", "fused", "both"],
        help="stitched (ell), fused megakernel, or both",
    )
    ap.add_argument("--shards", default="1,8")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    backends = ["ell", "fused"] if args.backend == "both" else [args.backend]
    shard_list = [int(s) for s in args.shards.split(",")]
    import jax

    for backend in backends:
        for shards in shard_list:
            if shards > jax.device_count():
                print(
                    f"[sweep-dryrun] skip shards={shards}: only "
                    f"{jax.device_count()} devices visible"
                )
                continue
            try:
                rec = run_cell(
                    backend,
                    shards,
                    v=args.v,
                    e=args.e,
                    num_queries=args.queries,
                    max_iters=args.max_iters,
                )
            except Exception as exc:  # noqa: BLE001 — recorded per cell
                if not args.continue_on_error:
                    raise
                traceback.print_exc()
                rec = {
                    "arch": f"sweep-{backend}",
                    "shape": f"v{args.v}-e{args.e}-q{args.queries}",
                    "mesh": f"1x{shards}" if shards > 1 else "single",
                    "num_devices": shards,
                    "status": f"error: {exc}",
                }
            save(rec)


if __name__ == "__main__":
    main()
