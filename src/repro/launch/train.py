"""Training driver: any registered arch × shape on any mesh, with the full
production runtime — sharded params/optimizer, checkpoint/restart under the
fault supervisor, straggler detection, elastic batch splitting.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --steps 20 --ckpt-dir /tmp/ckpt

``--smoke`` runs the arch's reduced config with real (small) arrays on the
local device mesh; full configs are launched the same way on real TPU pods
(the dry-run proves the lowering; this driver is what a pod would execute).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_arch
from repro.data.synthetic import lm_batch, mind_batch
from repro.optim import adamw_init
from repro.runtime.fault import FaultPolicy, StepResult, Supervisor
from repro.runtime.straggler import StragglerDetector, StepTimer


def _lm_setup(arch, cfg, batch=4, seq=32):
    from repro.configs.lm_harness import make_train_step

    params = jax.jit(lambda: __import__("repro.models.transformer", fromlist=["x"]).init_params(cfg, jax.random.PRNGKey(0)))()
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg))

    def data(step):
        t, l = lm_batch(step, batch=batch, seq_len=seq, vocab=cfg.vocab_size)
        return (jnp.asarray(t), jnp.asarray(l))

    return (params, opt), step_fn, data


def _gnn_setup(arch, cfg):
    from repro.configs.gnn_harness import make_gnn_train_step
    from repro.models.gnn import common as g

    rng = np.random.default_rng(0)
    geometric = arch.name in ("dimenet", "equiformer-v2")
    batch = g.random_graph_batch(rng, 64, 256, getattr(cfg, "d_in", 16),
                                 edge_feat_dim=8, geometric=geometric)
    if arch.name == "pna":
        from repro.models.gnn import pna as m
        loss = lambda c, p, b: m.loss_fn(c, p, b)

    elif arch.name == "gatedgcn":
        from repro.models.gnn import gatedgcn as m
        loss = lambda c, p, b: m.loss_fn(c, p, b)

    elif arch.name == "dimenet":
        from repro.models.gnn import dimenet as m
        tri = m.build_triplets(np.asarray(batch.edge_src), np.asarray(batch.edge_dst),
                               np.asarray(batch.edge_mask), 1024)
        tri = tuple(jnp.asarray(t) for t in tri)
        loss = lambda c, p, b, t=tri: m.loss_fn(c, p, b, t)

    else:
        from repro.models.gnn import equiformer_v2 as m
        loss = lambda c, p, b: m.loss_fn(c, p, b)

    params = m.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_gnn_train_step(lambda p, b: loss(cfg, p, b)))

    def data(step):
        return (batch,)

    return (params, opt), step_fn, data


def _mind_setup(arch, cfg, batch=32):
    from repro.models.recsys import mind as m
    from repro.optim import adamw_update

    params = m.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt_state, behavior, valid, target, neg):
        loss, grads = jax.value_and_grad(
            lambda p: m.loss_fn(cfg, p, behavior, valid, target, neg)
        )(params)
        p2, o2, gn = adamw_update(params, grads, opt_state, lr=1e-3)
        return p2, o2, {"loss": loss, "gnorm": gn}

    def data(step):
        b, v, t, n = mind_batch(step, batch=batch, seq_len=cfg.seq_len,
                                num_items=cfg.num_items)
        return tuple(jnp.asarray(x) for x in (b, v, t, n))

    return (params, opt), step_fn, data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-fault-at", type=int, default=-1)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.smoke()
    if arch.family == "lm":
        state, step_fn, data = _lm_setup(arch, cfg)
    elif arch.family == "gnn":
        state, step_fn, data = _gnn_setup(arch, cfg)
    elif arch.family == "recsys":
        state, step_fn, data = _mind_setup(arch, cfg)
    else:
        raise SystemExit("use examples/continuous_queries.py for diff-ife")

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    detector = StragglerDetector()
    injected = {"done": False}

    def injector(step):
        from repro.runtime.fault import InjectedFault

        if step == args.inject_fault_at and not injected["done"]:
            injected["done"] = True
            raise InjectedFault(f"simulated device failure at step {step}")

    sup = Supervisor(
        ckpt,
        FaultPolicy(checkpoint_every=args.ckpt_every),
        fault_injector=injector if args.inject_fault_at >= 0 else None,
    )

    def one_step(state, step):
        params, opt = state
        with StepTimer(detector) as t:
            params, opt, metrics = step_fn(params, opt, *data(step))
            jax.block_until_ready(metrics["loss"])
        straggled = t.finish(step)
        if step % 5 == 0 or straggled:
            print(f"step {step}: loss={float(metrics['loss']):.4f}"
                  + (" [straggler]" if straggled else ""))
        return StepResult(state=(params, opt), metrics=metrics)

    t0 = time.time()
    state, last = sup.run(state, one_step, num_steps=args.steps)
    print(f"done: {last} steps in {time.time() - t0:.1f}s, "
          f"restarts={sup.restarts}, events={sup.history}")


if __name__ == "__main__":
    main()
