"""Roofline-term extraction from lowered/compiled artifacts.

``cost_analysis`` provides HLO FLOPs and bytes accessed; collective bytes
are NOT in cost_analysis, so we parse the (post-SPMD) HLO text and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %x = (f32[16,128]{1,0}, f32[4]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9_]+\[[^=]*?\)?)\s+"
    + r"(" + "|".join(_COLLECTIVES) + r")\b"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum of result bytes per collective kind (+ 'total')."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        out[m.group(2)] += _shape_bytes(m.group(1))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def collective_counts(hlo_text: str) -> dict[str, int]:
    out = {}
    for k in _COLLECTIVES:
        out[k] = len(re.findall(rf"\b{k}\b", hlo_text))
    return out


@dataclasses.dataclass
class Roofline:
    """Roofline terms from the PER-DEVICE compiled module.

    ``cost_analysis()`` on a partitioned program reports one device's flops
    and bytes (verified empirically: a (data×model)-sharded matmul reports
    2MNK/num_devices), and the parsed HLO is the per-device program, so all
    three terms are per-chip seconds directly.  CAVEAT: XLA counts a
    while/scan body ONCE — scanned-layer models must be lowered unrolled for
    truthful flop totals (TransformerConfig.scan_layers=False in the
    dry-run); for iteration-bounded loops (diff-ife) the terms are per sweep
    iteration, which is the natural unit there.
    """

    name: str
    num_chips: int
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    coll_bytes: float  # per device
    model_flops: float  # GLOBAL useful flops (6·N·D style)
    per_device_hbm_bytes: float | None = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        if not self.hlo_flops:
            return 0.0
        return self.model_flops / (self.hlo_flops * self.num_chips)

    @property
    def roofline_fraction(self) -> float:
        """model-FLOPs time / achievable step time (max of the 3 terms)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0 or not self.model_flops:
            return 0.0
        return (self.model_flops / (self.num_chips * PEAK_FLOPS)) / t

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "num_chips": self.num_chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_hbm_bytes": self.per_device_hbm_bytes,
        }


def analyse(name: str, lowered, compiled, num_chips: int, model_flops: float) -> Roofline:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)["total"]
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
    except Exception:
        pass
    return Roofline(
        name=name,
        num_chips=num_chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        coll_bytes=float(coll),
        model_flops=model_flops,
        per_device_hbm_bytes=mem,
    )
