"""Model-serving driver: batched decode for LM archs / batched scoring for
MIND.  (This serves neural *models* — to serve continuous graph queries see
the async multi-tenant tier in :mod:`repro.serving`.)

    PYTHONPATH=src python -m repro.launch.model_serve --arch llama3.2-1b \
        --smoke --batch 4 --prompt-len 16 --gen 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_arch
from repro.models import transformer as tf


def lm_serve(arch, batch: int, prompt_len: int, gen: int) -> None:
    cfg = arch.smoke()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)

    decode = jax.jit(lambda p, c, t, pos: tf.decode_step(cfg, p, c, t, pos))
    cache = tf.init_cache(cfg, batch, prompt_len + gen)

    # prefill via decode loop (smoke scale); production uses prefill_32k cell
    t0 = time.time()
    tok = prompts[:, 0]
    for t in range(prompt_len + gen - 1):
        logits, cache = decode(params, cache, tok, jnp.full((batch,), t, jnp.int32))
        if t + 1 < prompt_len:
            tok = prompts[:, t + 1]
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"served {batch} seqs × {gen} new tokens in {dt:.2f}s "
          f"({batch * gen / dt:.1f} tok/s, smoke config)")


def mind_serve(arch, batch: int) -> None:
    from repro.models.recsys import mind as m

    cfg = arch.smoke()
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    beh = jnp.asarray(rng.integers(0, cfg.num_items, (batch, cfg.seq_len)), jnp.int32)
    valid = jnp.ones((batch, cfg.seq_len), bool)
    cands = jnp.asarray(rng.integers(0, cfg.num_items, (batch, 64)), jnp.int32)
    score = jax.jit(lambda p, b, v, c: m.serve_scores(cfg, p, b, v, c))
    t0 = time.time()
    s = score(params, beh, valid, cands)
    jax.block_until_ready(s)
    print(f"scored {batch}×64 candidates in {time.time() - t0:.3f}s; top: "
          f"{np.asarray(jnp.argmax(s, -1))[:4]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.family == "lm":
        lm_serve(arch, args.batch, args.prompt_len, args.gen)
    elif arch.family == "recsys":
        mind_serve(arch, args.batch)
    else:
        raise SystemExit(f"{arch.name} has no serving path")


if __name__ == "__main__":
    main()
