"""AdamW with global-norm clipping, built for sharded param trees.

Moment tensors inherit the params' sharding (same tree structure → GSPMD
propagates the NamedSharding), giving ZeRO-like distributed optimizer state
for free when params are FSDP-sharded.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    *,
    lr: float | jnp.ndarray = 1e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / c1, v / c2
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm
