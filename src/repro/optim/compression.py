"""Gradient compression with error feedback (distributed-optimization trick).

int8 stochastic-rounding quantization of gradients before the data-parallel
all-reduce, with per-tensor scales and an error-feedback accumulator so the
quantization bias does not accumulate across steps.  Under pjit the quantized
tensors are what cross the ICI — 4× fewer collective bytes on the gradient
reduce at the cost of one extra VPU pass.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, rng: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scaled = x / scale
    noise = jax.random.uniform(rng, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, errors, rng: jax.Array):
    """Returns (quantized tree, scales tree, new error-feedback tree)."""
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(errors) if errors is not None else [0.0] * len(leaves)
    rngs = jax.random.split(rng, len(leaves))
    qs, scales, new_errs = [], [], []
    for g, e, r in zip(leaves, err_leaves, rngs):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected, r)
        qs.append(q)
        scales.append(s)
        new_errs.append(corrected - dequantize_int8(q, s))
    return (
        jax.tree.unflatten(treedef, qs),
        jax.tree.unflatten(treedef, scales),
        jax.tree.unflatten(treedef, new_errs),
    )


def decompress_grads(qs, scales):
    return jax.tree.map(dequantize_int8, qs, scales)


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
