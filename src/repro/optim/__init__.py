"""Optimizers and distributed-optimization tricks."""

from repro.optim.adamw import adamw_init, adamw_update  # noqa: F401
from repro.optim.schedules import cosine_with_warmup  # noqa: F401
