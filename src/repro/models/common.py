"""Shared model primitives: norms, RoPE, activations, param trees with
logical sharding axes, chunked (flash-style) attention in pure JAX.

Parameters are plain dict pytrees.  Every leaf is created through
:func:`param`, which records a tuple of *logical axis names* in a parallel
specs tree; ``runtime.mesh_rules`` maps logical axes → mesh axes at jit time.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
import jax.numpy as jnp

Array = jnp.ndarray
PyTree = Any

# --------------------------------------------------- activation sharding
# Model code is mesh-agnostic: it annotates activations with *logical* axes
# via `constrain`; the launcher installs the active mesh around tracing so
# the annotation resolves to with_sharding_constraint, and smoke tests (no
# mesh) make it a no-op.
_ACTIVATION_MESH: list = [None]


@contextlib.contextmanager
def activation_mesh(mesh):
    prev = _ACTIVATION_MESH[0]
    _ACTIVATION_MESH[0] = mesh
    try:
        yield
    finally:
        _ACTIVATION_MESH[0] = prev


def constrain(x: Array, *logical_axes) -> Array:
    mesh = _ACTIVATION_MESH[0]
    if mesh is None:
        return x
    from repro.runtime import mesh_rules

    spec = mesh_rules.logical_to_spec(tuple(logical_axes), mesh)
    return jax.lax.with_sharding_constraint(x, spec)

# ---------------------------------------------------------------- param trees


class ParamFactory:
    """Creates params and records logical-axis specs side by side."""

    def __init__(self, rng: jax.Array, dtype=jnp.float32) -> None:
        self._rng = rng
        self.dtype = dtype
        self.specs: dict = {}

    def _next(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def param(self, tree: dict, specs: dict, name: str, shape, axes, *, scale=None, zeros=False):
        assert len(shape) == len(axes), (name, shape, axes)
        if zeros:
            tree[name] = jnp.zeros(shape, self.dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            s = scale if scale is not None else fan_in**-0.5
            tree[name] = (jax.random.normal(self._next(), shape) * s).astype(self.dtype)
        specs[name] = axes
        return tree[name]


def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def swiglu(x: Array, wg: Array, wi: Array, wo: Array) -> Array:
    return (jax.nn.silu(x @ wg) * (x @ wi)) @ wo


# ---------------------------------------------------------------------- RoPE
def rope_freqs(dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x [..., S, D] with D even; positions [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------- chunked attention
def chunked_attention(
    q: Array,  # [B, Hq, Sq, D]
    k: Array,  # [B, Hkv, Sk, D]
    v: Array,  # [B, Hkv, Sk, Dv]
    *,
    causal: bool = True,
    q_offset: Array | int = 0,  # absolute position of q[..., 0, :]
    block_q: int = 512,
    block_k: int = 1024,
    kv_valid_len: Array | None = None,  # mask KV positions ≥ this (decode cache)
) -> Array:
    """Flash-style online-softmax attention in pure JAX (lax.scan over KV
    blocks inside a scan over Q blocks).  Peak memory O(Bq·Bk) per (B, H)
    instead of O(Sq·Sk): this is what lets 32k prefill and 32k-cache decode
    lower within HBM on the production mesh.  GQA via head grouping."""
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape[0], v.shape[1], v.shape[2], v.shape[3]
    group = hq // k.shape[1]
    bq, bk = min(block_q, sq), min(block_k, sk)
    nq, nk = -(-sq // bq), -(-sk // bk)
    qpad, kpad = nq * bq - sq, nk * bk - sk
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    scale = d**-0.5
    kg = k.reshape(b, k.shape[1], nk, bk, d)
    vg = v.reshape(b, v.shape[1], nk, bk, dv)
    valid = jnp.asarray(kv_valid_len if kv_valid_len is not None else sk)

    def q_block(iq):
        qb = jax.lax.dynamic_slice_in_dim(q, iq * bq, bq, axis=2) * scale
        qb32 = qb.astype(jnp.float32)
        rows = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(carry, ik):
            m, l, acc = carry
            kb = kg[:, :, ik].astype(jnp.float32)  # [B, Hkv, Bk, D]
            vb = vg[:, :, ik].astype(jnp.float32)
            # group query heads onto their KV head
            qh = qb32.reshape(b, k.shape[1], group, bq, d)
            s = jnp.einsum("bngqd,bnkd->bngqk", qh, kb)  # [B,Hkv,G,Bq,Bk]
            cols = ik * bk + jnp.arange(bk)
            mask = cols[None, :] <= rows[:, None] if causal else jnp.ones((bq, bk), bool)
            mask = mask & (cols < valid)[None, :]
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            # (measured in §Perf: casting p to bf16 here ADDS traffic at the
            # HLO level — the f32 p is still materialized for the row sum —
            # so the PV product stays f32; the true fix is the fused Pallas
            # flash kernel, where p never leaves VMEM.)
            acc_new = acc * alpha[..., None] + jnp.einsum("bngqk,bnkd->bngqd", p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, k.shape[1], group, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, k.shape[1], group, bq), jnp.float32)
        a0 = jnp.zeros((b, k.shape[1], group, bq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, hq, bq, dv).astype(q.dtype)

    if nq == 1:
        out = q_block(0)
    else:
        out = jax.lax.map(q_block, jnp.arange(nq))  # [nq, B, Hq, Bq, Dv]
        out = jnp.moveaxis(out, 0, 2).reshape(b, hq, nq * bq, dv)
    return out[:, :, :sq]


def dlse_decode_attention(
    q: Array,  # [B, Hq, 1, D]   (replicated over model inside the map)
    ck: Array,  # [B, Hkv, S, D]  kv_seq sharded over "model"
    cv: Array,  # [B, Hkv, S, D]
    kv_valid_len: Array,  # scalar — #valid cache positions
) -> Array:
    """Distributed log-sum-exp decode attention (§Perf, decode cells).

    The KV cache stays sequence-sharded over the model axis; every device
    computes partial softmax stats (m, l, acc) on its local chunk and the
    combine crosses the ICI as one pmax + two psums of [B, Hq, D]-sized
    tensors — KBs per layer instead of gathering the multi-GB cache.
    """
    mesh = _ACTIVATION_MESH[0]
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, hq, _, d = q.shape
    hkv = ck.shape[1]
    group = hq // hkv
    batch_ax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bspec = batch_ax if len(batch_ax) > 1 else batch_ax[0]

    def body(q_l, ck_l, cv_l, valid):
        # local chunk: [B_loc, Hkv, S/tp, D]
        s_loc = ck_l.shape[2]
        off = jax.lax.axis_index("model") * s_loc
        qh = q_l.reshape(q_l.shape[0], hkv, group, d).astype(jnp.float32)
        k = ck_l.astype(jnp.float32)
        v = cv_l.astype(jnp.float32)
        scores = jnp.einsum("bngd,bnsd->bngs", qh, k) * (d**-0.5)
        pos_ok = (off + jnp.arange(s_loc)) < valid
        scores = jnp.where(pos_ok[None, None, None, :], scores, -1e30)
        m = scores.max(axis=-1)  # [B, Hkv, G]
        m_glob = jax.lax.pmax(m, "model")
        p = jnp.exp(scores - m_glob[..., None])
        l = jax.lax.psum(p.sum(axis=-1), "model")
        acc = jax.lax.psum(jnp.einsum("bngs,bnsd->bngd", p, v), "model")
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(q_l.shape[0], hq, 1, d).astype(q_l.dtype)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, None, "model", None),
            P(bspec, None, "model", None),
            P(),
        ),
        out_specs=P(bspec, None, None, None),
        check_rep=False,
    )(q, ck, cv, kv_valid_len)


def dlse_mla_decode_attention(
    q: Array,  # [B, H, 1, nd+rd]
    ckv: Array,  # [B, S, kvr] latents, kv_seq sharded over "model"
    krope: Array,  # [B, S, rd]
    wuk: Array,  # [kvr, H*nd]
    wuv: Array,  # [kvr, H*vd]
    kv_valid_len: Array,
    *,
    nope_dim: int,
    v_dim: int,
) -> Array:
    """MLA variant of the distributed-LSE decode: each device expands only
    its LOCAL latent chunk (ckv @ wuk/wuv) — the S×H×d expansion never
    crosses the ICI either, on top of the KV gather it already saves."""
    mesh = _ACTIVATION_MESH[0]
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, h, _, qk = q.shape
    rd = qk - nope_dim
    batch_ax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bspec = batch_ax if len(batch_ax) > 1 else batch_ax[0]

    def body(q_l, ckv_l, krope_l, wuk_l, wuv_l, valid):
        s_loc = ckv_l.shape[1]
        off = jax.lax.axis_index("model") * s_loc
        k_nope = (ckv_l @ wuk_l).reshape(-1, s_loc, h, nope_dim)
        v = (ckv_l @ wuv_l).reshape(-1, s_loc, h, v_dim).astype(jnp.float32)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_l[:, :, None], (*krope_l.shape[:2], h, rd))],
            axis=-1,
        ).astype(jnp.float32)  # [B, S_loc, H, qk]
        qf = q_l[:, :, 0].astype(jnp.float32)  # [B, H, qk]
        scores = jnp.einsum("bhd,bshd->bhs", qf, k) * (qk**-0.5)
        pos_ok = (off + jnp.arange(s_loc)) < valid
        scores = jnp.where(pos_ok[None, None, :], scores, -1e30)
        m = scores.max(axis=-1)
        m_glob = jax.lax.pmax(m, "model")
        p = jnp.exp(scores - m_glob[..., None])
        l = jax.lax.psum(p.sum(axis=-1), "model")
        acc = jax.lax.psum(jnp.einsum("bhs,bshd->bhd", p, v), "model")
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out[:, :, None, :].astype(q_l.dtype)  # [B, H, 1, vd]

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, "model", None),
            P(bspec, "model", None),
            P(None, None),
            P(None, None),
            P(),
        ),
        out_specs=P(bspec, None, None, None),
        check_rep=False,
    )(q, ckv, krope, wuk, wuv, kv_valid_len)


def cross_entropy_loss(logits: Array, labels: Array) -> Array:
    """Mean token cross-entropy; logits [..., vocab], labels [...] int32."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return (logz - gold).mean()
