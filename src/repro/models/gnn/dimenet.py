"""DimeNet (arXiv:2003.03123): directional message passing on edge triplets.

Messages live on directed edges; interaction blocks aggregate, for each edge
a = (j→i), over incoming edges b = (k→j), modulating by a joint
radial × angular basis of (d_kj, ∠kji) — the quadratic "triplet gather"
kernel regime.  Bases: Bessel RBF (n_radial=6) and spherical basis from
spherical Bessel × Legendre (n_spherical=7); the bilinear interaction uses an
n_bilinear=8 bottleneck.  Triplet lists are precomputed host-side with a
per-graph cap (fixed shapes for the TPU).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import common as g

Array = jnp.ndarray

# first zeros of spherical Bessel j_l, l = 0..7 (n-th zero ≈ first + (n-1)π)
_J_ZEROS = np.array([3.14159, 4.49341, 5.76346, 6.98793, 8.18256, 9.35581, 10.51284, 11.65703])


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    num_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    num_species: int = 16
    num_targets: int = 1


# ------------------------------------------------------------------- bases
def bessel_rbf(d: Array, n_radial: int, cutoff: float) -> Array:
    """sqrt(2/c)·sin(nπ d/c)/d — DimeNet's radial Bessel basis. [E, n]"""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(d, 1e-6)[:, None]
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d


def _sph_bessel(l_max: int, x: Array) -> Array:
    """j_l(x) for l = 0..l_max via upward recurrence. [..., l_max+1]

    The upward recurrence is unstable for x ≲ l (error amplified by
    (2l−1)!!/x^l), so the argument is clamped at 1 and values below a
    per-degree threshold are zeroed — j_l(x) < 1e-3 there anyway.  Padded
    triplets (d = 0) are masked by t_mask upstream.
    """
    xs = jnp.maximum(x, 1.0)
    j0 = jnp.sin(xs) / xs
    j1 = jnp.sin(xs) / xs**2 - jnp.cos(xs) / xs
    js = [j0, j1]
    for l in range(1, l_max):
        js.append((2 * l + 1) / xs * js[l] - js[l - 1])
    out = jnp.stack(js[: l_max + 1], axis=-1)
    thresh = jnp.asarray([max(l - 1.0, 0.0) for l in range(l_max + 1)], jnp.float32)
    return jnp.where(x[..., None] >= thresh, out, 0.0)


def _legendre(l_max: int, c: Array) -> Array:
    """P_l(c) for l = 0..l_max. [..., l_max+1]"""
    ps = [jnp.ones_like(c), c]
    for l in range(1, l_max):
        ps.append(((2 * l + 1) * c * ps[l] - l * ps[l - 1]) / (l + 1))
    return jnp.stack(ps[: l_max + 1], axis=-1)


def spherical_basis(d: Array, cos_angle: Array, cfg: DimeNetConfig) -> Array:
    """Joint radial-angular basis. [T, n_spherical * n_radial]"""
    zeros = _J_ZEROS[: cfg.n_spherical, None] + np.arange(cfg.n_radial)[None, :] * np.pi
    zeros = jnp.asarray(zeros, jnp.float32)  # [S, R]
    x = d[:, None, None] / cfg.cutoff * zeros[None]  # [T, S, R]
    jl = _sph_bessel(cfg.n_spherical - 1, x.reshape(-1, cfg.n_radial))  # fused
    # evaluate j_l at its own l row: select diag over the stacked l axis
    jl = jl.reshape(d.shape[0], cfg.n_spherical, cfg.n_radial, cfg.n_spherical)
    jl = jnp.take_along_axis(
        jl, jnp.arange(cfg.n_spherical)[None, :, None, None], axis=-1
    )[..., 0]
    pl = _legendre(cfg.n_spherical - 1, cos_angle)  # [T, S]
    return (jl * pl[:, :, None]).reshape(d.shape[0], -1)


# ------------------------------------------------------------------ triplets
def build_triplets(
    src: np.ndarray, dst: np.ndarray, mask: np.ndarray, max_triplets: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side: (b_idx, a_idx, t_mask) — edge b=(k→j) feeds edge a=(j→i)."""
    by_dst: dict[int, list[int]] = {}
    for e in np.nonzero(mask)[0]:
        by_dst.setdefault(int(dst[e]), []).append(int(e))
    b_idx, a_idx = [], []
    for a in np.nonzero(mask)[0]:
        j = int(src[a])
        for b in by_dst.get(j, ()):  # b = (k → j)
            if int(src[b]) == int(dst[a]):  # exclude k == i backtrack
                continue
            b_idx.append(b)
            a_idx.append(int(a))
            if len(b_idx) >= max_triplets:
                break
        if len(b_idx) >= max_triplets:
            break
    t = len(b_idx)
    pad = max_triplets - t
    return (
        np.asarray(b_idx + [0] * pad, np.int32),
        np.asarray(a_idx + [0] * pad, np.int32),
        np.asarray([True] * t + [False] * pad),
    )


# -------------------------------------------------------------------- params
def init_params(cfg: DimeNetConfig, rng: jax.Array) -> dict:
    d, nb = cfg.d_hidden, cfg.n_bilinear
    nsr = cfg.n_spherical * cfg.n_radial
    k = iter(jax.random.split(rng, 8 + 8 * cfg.num_blocks))
    rnd = lambda *shape: jax.random.normal(next(k), shape) * shape[0] ** -0.5
    p = {
        "species_emb": jax.random.normal(next(k), (cfg.num_species, d)) * 0.5,
        "emb_rbf": rnd(cfg.n_radial, d),
        "emb_w": rnd(3 * d, d),
        "emb_b": jnp.zeros((d,)),
        "blocks": [],
        "out_rbf": rnd(cfg.n_radial, d),
        "head_w": rnd(d, cfg.num_targets),
        "head_b": jnp.zeros((cfg.num_targets,)),
    }
    for _ in range(cfg.num_blocks):
        p["blocks"].append(
            {
                "w_msg": rnd(d, d),
                "w_down": rnd(d, nb),
                "w_sbf": rnd(nsr, nb),
                "w_up": rnd(nb, d),
                "w_rbf_gate": rnd(cfg.n_radial, d),
                "upd_w1": rnd(d, d),
                "upd_b1": jnp.zeros((d,)),
                "upd_w2": rnd(d, d),
                "upd_b2": jnp.zeros((d,)),
                "out_w": rnd(d, d),
            }
        )
    return p


# ------------------------------------------------------------------- forward
def forward(
    cfg: DimeNetConfig,
    params: dict,
    batch: g.GraphBatch,
    triplets: tuple[Array, Array, Array],
) -> Array:
    """Returns per-node scalar predictions [N, num_targets] (masked sum is
    the molecule-level target)."""
    n = batch.num_nodes
    src, dst = batch.edge_src, batch.edge_dst
    b_idx, a_idx, t_mask = triplets

    # species from labels (molecule graphs store atomic numbers in labels)
    z = params["species_emb"][jnp.clip(batch.labels, 0, params["species_emb"].shape[0] - 1)]
    rvec = batch.pos[dst] - batch.pos[src]  # [E, 3]
    dist = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    rbf = bessel_rbf(dist, cfg.n_radial, cfg.cutoff) * batch.edge_mask[:, None]

    m = jnp.concatenate([z[src], z[dst], rbf @ params["emb_rbf"]], axis=-1)
    m = jax.nn.silu(m @ params["emb_w"] + params["emb_b"])  # [E, d]

    # triplet geometry: angle between edge b=(k→j) and a=(j→i)
    ra = rvec[a_idx]
    rb = -rvec[b_idx]  # point from j to k
    cosang = (ra * rb).sum(-1) / jnp.maximum(
        jnp.linalg.norm(ra, axis=-1) * jnp.linalg.norm(rb, axis=-1), 1e-6
    )
    sbf = spherical_basis(dist[b_idx], cosang, cfg) * t_mask[:, None]

    h_out = jnp.zeros((n, cfg.d_hidden))

    def block_fn(carry, w):
        m_, h_ = carry
        mt = jax.nn.silu(m_ @ w["w_msg"])
        a_feat = (mt[b_idx] @ w["w_down"]) * (sbf @ w["w_sbf"])  # [T, nb]
        agg = jax.ops.segment_sum(a_feat, a_idx, m_.shape[0]) @ w["w_up"]
        gate = rbf @ w["w_rbf_gate"]
        upd = jax.nn.silu((mt + agg * gate) @ w["upd_w1"] + w["upd_b1"])
        m_ = m_ + jax.nn.silu(upd @ w["upd_w2"] + w["upd_b2"])
        h_ = h_ + jax.ops.segment_sum(m_ * (rbf @ params["out_rbf"]), dst, n) @ w["out_w"]
        return m_, h_

    block_fn = jax.checkpoint(block_fn)  # remat the O(T) triplet tensors
    for w in params["blocks"]:
        m, h_out = block_fn((m, h_out), w)

    pred = jax.nn.silu(h_out) @ params["head_w"] + params["head_b"]
    return pred * batch.node_mask[:, None]


def loss_fn(cfg, params, batch, triplets) -> Array:
    pred = forward(cfg, params, batch, triplets)
    target = (batch.labels.astype(jnp.float32) * batch.node_mask)[:, None] * 0.01
    return jnp.mean((pred - target) ** 2)
