"""Real Wigner-D rotation matrices for spherical-harmonic (irrep) features.

EquiformerV2's eSCN trick needs, per edge, the rotation that aligns the edge
vector with +z.  Acting on *real* spherical harmonics of degree l, a rotation
R_z(α)R_y(β) has the block form  D_l = C_l · e^{-iα m} · d_l(β) · C_l^H
where d_l(β) = exp(-iβ J_y).  We eigendecompose J_y once per l on the host
(numpy) so the per-edge cost is a batched complex diagonal product — no
per-edge matrix exponentials.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@functools.lru_cache(maxsize=None)
def _jy_eig(l: int) -> tuple[np.ndarray, np.ndarray]:
    """Eigendecomposition of J_y in the complex |l m⟩ basis: J_y = V Λ V^H."""
    m = np.arange(-l, l + 1)
    dim = 2 * l + 1
    jp = np.zeros((dim, dim), complex)  # J_+ |l m⟩ = c |l m+1⟩
    for i in range(dim - 1):
        mm = m[i]
        jp[i + 1, i] = np.sqrt(l * (l + 1) - mm * (mm + 1))
    jm = jp.conj().T
    jy = (jp - jm) / 2j
    lam, v = np.linalg.eigh(jy)
    return lam, v


@functools.lru_cache(maxsize=None)
def _real_to_complex(l: int) -> np.ndarray:
    """Unitary C with  Y_real = C · Y_complex  (Condon–Shortley)."""
    dim = 2 * l + 1
    c = np.zeros((dim, dim), complex)
    s2 = 1.0 / np.sqrt(2.0)
    for i, mm in enumerate(range(-l, l + 1)):
        if mm < 0:
            c[i, l + mm] = 1j * s2
            c[i, l - mm] = -1j * s2 * (-1) ** mm
        elif mm == 0:
            c[i, l] = 1.0
        else:
            c[i, l - mm] = s2
            c[i, l + mm] = s2 * (-1) ** mm
    return c


def wigner_d_real(l: int, alpha: Array, beta: Array) -> Array:
    """Real-basis Wigner D_l(R_z(α)R_y(β)) for batched angles. [..., 2l+1, 2l+1]

    Rows/cols are ordered m = -l..l in the real convention of
    :func:`real_sph_harm`.
    """
    lam, v = _jy_eig(l)
    c = _real_to_complex(l)
    m = np.arange(-l, l + 1)
    lam_j = jnp.asarray(lam)
    v_j = jnp.asarray(v)
    c_j = jnp.asarray(c)
    # d(β) = V e^{-iβΛ} V^H
    phase = jnp.exp(-1j * beta[..., None] * lam_j)  # [..., dim]
    d_beta = jnp.einsum("ik,...k,jk->...ij", v_j, phase, v_j.conj())
    # +iαm: verified against the l=1 coordinate rotation (real basis y,z,x)
    ez = jnp.exp(1j * alpha[..., None] * jnp.asarray(m))  # [..., dim]
    d_cplx = ez[..., :, None] * d_beta  # R_z(α) is diagonal in m
    d_real = jnp.einsum("ab,...bc,dc->...ad", c_j, d_cplx, c_j.conj())
    return jnp.real(d_real).astype(jnp.float32)


def align_to_z_angles(rvec: Array) -> tuple[Array, Array]:
    """(α, β) such that R_z(α)R_y(β) maps the unit edge vector onto +z.

    With r = (sinβ' cosα', sinβ' sinα', cosβ'), the inverse alignment uses
    β = -β', α applied after: we return angles for the rotation r → +z,
    i.e. R_y(-β') R_z(-α') r = +z, expressed as (alpha=-α', beta=-β') with
    the z-rotation applied *first* in wigner_d_real's R_z(α)R_y(β) order
    being the y-rotation... practical contract: ``wigner_d_real(l, 0, -beta')
    @ wigner_d_real(l, -alpha', 0)`` aligns; we fold both here.
    """
    r = rvec / jnp.maximum(jnp.linalg.norm(rvec, axis=-1, keepdims=True), 1e-9)
    beta_p = jnp.arccos(jnp.clip(r[..., 2], -1.0, 1.0))
    alpha_p = jnp.arctan2(r[..., 1], r[..., 0])
    return alpha_p, beta_p


def rotate_block(
    feats: Array, d_mats: dict[int, Array], l_max: int, inverse: bool = False
) -> Array:
    """Apply per-l Wigner blocks to irrep features [..., (l_max+1)^2, C]."""
    out = []
    off = 0
    for l in range(l_max + 1):
        dim = 2 * l + 1
        blk = feats[..., off : off + dim, :]
        d = d_mats[l]
        if inverse:
            d = jnp.swapaxes(d, -1, -2)  # orthogonal → inverse = transpose
        out.append(jnp.einsum("...ij,...jc->...ic", d, blk))
        off += dim
    return jnp.concatenate(out, axis=-2)
