"""EquiformerV2 (arXiv:2306.12059): equivariant graph attention with eSCN.

Node features are real-spherical-harmonic irreps ``x [N, (l_max+1)², C]``.
Per edge, features are rotated into the edge-aligned frame (Wigner-D — see
``wigner.py``); there the tensor-product convolution collapses to SO(2)
linear maps that couple only components of equal |m|, and eSCN's m_max
truncation (m ≤ 2) drops the rest — the O(L⁶) → O(L³) reduction.  Attention
weights come from the invariant (m=0) channel; messages are attention-
aggregated, rotated back, and fed through an equivariant gated FFN.

Config: n_layers=12, d_hidden=128, l_max=6, m_max=2, 8 heads.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import common as g
from repro.models.gnn.wigner import align_to_z_angles, wigner_d_real

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    num_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    num_heads: int = 8
    num_species: int = 16
    num_targets: int = 1
    cutoff: float = 5.0
    n_radial: int = 8
    # process edges in chunks of this size (bounds the [chunk, K, C] message
    # tensors on huge graphs; 0 = single pass).  Softmax runs as two chunked
    # passes (max, then exp-sum+aggregate) — 2× edge compute for O(chunk) mem.
    edge_chunk: int = 0

    @property
    def num_components(self) -> int:
        return (self.l_max + 1) ** 2


def _l_index(l_max: int) -> np.ndarray:
    """Component index → its degree l."""
    out = []
    for l in range(l_max + 1):
        out += [l] * (2 * l + 1)
    return np.asarray(out, np.int32)


def _m_slots(l_max: int, m: int) -> tuple[np.ndarray, np.ndarray]:
    """Component indices of (+m, −m) across degrees l ≥ m (real basis:
    index of (l, m) is l² + l + m)."""
    ls = np.arange(m, l_max + 1)
    return (ls * ls + ls + m).astype(np.int32), (ls * ls + ls - m).astype(np.int32)


# -------------------------------------------------------------------- params
def init_params(cfg: EquiformerV2Config, rng: jax.Array) -> dict:
    c, lm = cfg.d_hidden, cfg.l_max
    k = iter(jax.random.split(rng, 8 + 12 * cfg.num_layers))
    rnd = lambda *shape: jax.random.normal(next(k), shape) * shape[-2] ** -0.5
    p = {
        "species_emb": jax.random.normal(next(k), (cfg.num_species, c)) * 0.5,
        "edge_rbf_w": rnd(cfg.n_radial, c),
        "layers": [],
        "head_w1": rnd(c, c),
        "head_b1": jnp.zeros((c,)),
        "head_w2": rnd(c, cfg.num_targets),
    }
    for _ in range(cfg.num_layers):
        lay = {"ln_g": jnp.ones((lm + 1, c))}
        # SO(2) maps per m ≤ m_max, full mixing over (l ≥ m, channel)
        n0 = lm + 1
        lay["so2_w0"] = rnd(n0 * c, n0 * c)
        for m in range(1, cfg.m_max + 1):
            nl = lm + 1 - m
            lay[f"so2_wr{m}"] = rnd(nl * c, nl * c)
            lay[f"so2_wi{m}"] = jax.random.normal(next(k), (nl * c, nl * c)) * (nl * c) ** -0.5
        lay["alpha_w"] = rnd(n0 * c, cfg.num_heads)
        lay["val_w"] = rnd(c, c)  # per-channel value mix (shared across lm)
        lay["out_w"] = rnd(c, c)
        # gated equivariant FFN
        lay["ffn_gate_w"] = rnd(c, (lm + 1) * c)
        lay["ffn_mix"] = jax.random.normal(next(k), (lm + 1, c, c)) * c**-0.5
        lay["ffn_b"] = jnp.zeros((c,))
        p["layers"].append(lay)
    return p


def _equi_layernorm(x: Array, gamma: Array, l_of: Array, eps=1e-5) -> Array:
    """Per-degree RMS over (m, channel); scalars keep their mean. [N, K, C]"""
    sq = jnp.square(x)
    # mean square per degree: segment over components
    per_l = jax.ops.segment_sum(jnp.moveaxis(sq, 1, 0), l_of, gamma.shape[0])
    counts = jax.ops.segment_sum(jnp.ones_like(l_of, jnp.float32), l_of, gamma.shape[0])
    rms = jnp.sqrt(jnp.moveaxis(per_l, 0, 1) / counts[None, :, None] + eps)  # [N, L, C]
    return x / rms[:, l_of] * gamma[None, l_of]


def _so2_conv(cfg: EquiformerV2Config, w: dict, msg: Array) -> Array:
    """SO(2) linear conv in the edge frame; m > m_max components dropped."""
    e, k, c = msg.shape
    out = jnp.zeros_like(msg)
    # m = 0 block
    p0, _ = _m_slots(cfg.l_max, 0)
    x0 = msg[:, p0].reshape(e, -1)
    out = out.at[:, p0].set((x0 @ w["so2_w0"]).reshape(e, -1, c))
    # m > 0 blocks: complex-structured 2-channel maps
    for m in range(1, cfg.m_max + 1):
        pp, pm = _m_slots(cfg.l_max, m)
        xp = msg[:, pp].reshape(e, -1)
        xm = msg[:, pm].reshape(e, -1)
        wr, wi = w[f"so2_wr{m}"], w[f"so2_wi{m}"]
        yp = xp @ wr - xm @ wi
        ym = xp @ wi + xm @ wr
        out = out.at[:, pp].set(yp.reshape(e, -1, c))
        out = out.at[:, pm].set(ym.reshape(e, -1, c))
    return out


def _ffn(cfg, w, x, l_of):
    s = x[:, 0, :]  # scalars
    gates = jax.nn.sigmoid((s @ w["ffn_gate_w"]).reshape(-1, cfg.l_max + 1, x.shape[-1]))
    y = x * gates[:, l_of]
    y = jnp.einsum("nkc,kcd->nkd", y, w["ffn_mix"][l_of])
    y = y.at[:, 0, :].add(w["ffn_b"])
    y = y.at[:, 0, :].set(jax.nn.silu(y[:, 0, :]))
    return x + y


def forward(cfg: EquiformerV2Config, params: dict, batch: g.GraphBatch) -> Array:
    n = batch.num_nodes
    l_of = jnp.asarray(_l_index(cfg.l_max))
    x = jnp.zeros((n, cfg.num_components, cfg.d_hidden))
    z = params["species_emb"][jnp.clip(batch.labels, 0, params["species_emb"].shape[0] - 1)]
    x = x.at[:, 0, :].set(z)

    layer = _attention_layer_chunked if cfg.edge_chunk else _attention_layer_exact

    def block(x_, w_):
        x_ = layer(cfg, w_, x_, batch, l_of)
        return _ffn(cfg, w_, x_, l_of)

    block = jax.checkpoint(block)  # remat: per-layer edge tensors recomputed
    from repro.models.common import constrain

    for w in params["layers"]:
        # the remat-saved residual is one [N, K, C] per layer — keep it
        # node-sharded or it is saved replicated (measured: 839 GiB/device
        # → ~53 GiB on ogb_products)
        x = constrain(x, "graph_nodes", None, None)
        x = block(x, dict(w, edge_rbf_w=params["edge_rbf_w"]))

    s = x[:, 0, :]
    out = jax.nn.silu(s @ params["head_w1"] + params["head_b1"]) @ params["head_w2"]
    return out * batch.node_mask[:, None]


def _edge_geometry(cfg: EquiformerV2Config, batch: g.GraphBatch, src, dst, mask):
    """Wigner alignment blocks + radial basis for an edge (chunk)."""
    rvec = batch.pos[dst] - batch.pos[src]
    alpha, beta = align_to_z_angles(rvec)
    d_mats = {}
    for l in range(cfg.l_max + 1):
        d_y = wigner_d_real(l, jnp.zeros_like(beta), -beta)
        d_z = wigner_d_real(l, -alpha, jnp.zeros_like(alpha))
        d_mats[l] = jnp.einsum("eij,ejk->eik", d_y, d_z)  # R_y(-β)·R_z(-α)
    dist = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    nr = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    rbf = jnp.sin(nr * jnp.pi * dist[:, None] / cfg.cutoff) / jnp.maximum(dist, 1e-6)[:, None]
    return d_mats, rbf * mask[:, None]


def _rot_blocks(cfg, d_mats, feats, inverse=False):
    out, off = [], 0
    for l in range(cfg.l_max + 1):
        dim = 2 * l + 1
        d = d_mats[l]
        if inverse:
            d = jnp.swapaxes(d, -1, -2)
        out.append(jnp.einsum("eij,ejc->eic", d, feats[:, off : off + dim, :]))
        off += dim
    return jnp.concatenate(out, axis=-2)


def _edge_messages(cfg, w, xs, batch, src, dst, mask):
    """Per-edge: geometry → rotate → SO(2) conv → (msg, attn logits)."""
    d_mats, rbf = _edge_geometry(cfg, batch, src, dst, mask)
    msg = _rot_blocks(cfg, d_mats, xs[src])
    msg = msg.at[:, 0].add(rbf @ w["edge_rbf_w"])
    msg = _so2_conv(cfg, w, msg)
    p0, _ = _m_slots(cfg.l_max, 0)
    inv = jax.nn.silu(msg[:, p0].reshape(msg.shape[0], -1))
    logits = jnp.where(mask[:, None], inv @ w["alpha_w"], -1e30)
    return msg, logits, d_mats


def _attention_layer_exact(cfg, w, x, batch, l_of):
    """Rotate → SO(2) conv → attention → rotate back per edge → aggregate."""
    n = x.shape[0]
    src, dst = batch.edge_src, batch.edge_dst
    xs = _equi_layernorm(x, w["ln_g"], l_of)
    msg, logits, d_mats = _edge_messages(cfg, w, xs, batch, src, dst, batch.edge_mask)

    lmax_per_dst = jax.ops.segment_max(logits, dst, n)
    ex = jnp.exp(logits - lmax_per_dst[dst])
    denom = jax.ops.segment_sum(ex, dst, n)
    alpha = ex / jnp.maximum(denom[dst], 1e-9)

    e_, k_, c_ = msg.shape
    h = cfg.num_heads
    val = (msg @ w["val_w"]).reshape(e_, k_, h, c_ // h)
    val = (val * alpha[:, None, :, None]).reshape(e_, k_, c_)
    val = val * batch.edge_mask[:, None, None]
    val = _rot_blocks(cfg, d_mats, val, inverse=True)  # back to global frame
    agg = jax.ops.segment_sum(val, dst, n)
    return x + agg @ w["out_w"]


def _attention_layer_chunked(cfg, w, x, batch, l_of):
    """Memory-bounded variant for huge graphs: edges in fixed chunks.

    Pass 1 accumulates per-destination softmax max and denominator; pass 2
    recomputes messages per chunk and aggregates.  Peak edge tensors are
    O(edge_chunk · K · C) instead of O(E · K · C).
    """
    n = x.shape[0]
    e = batch.num_edges
    ch = cfg.edge_chunk
    nch = -(-e // ch)
    pad = nch * ch - e
    src = jnp.pad(batch.edge_src, (0, pad))
    dst = jnp.pad(batch.edge_dst, (0, pad))
    mask = jnp.pad(batch.edge_mask, (0, pad))
    xs = _equi_layernorm(x, w["ln_g"], l_of)
    k_, c_ = cfg.num_components, cfg.d_hidden
    h = cfg.num_heads

    def chunk_ids(i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * ch, ch)
        return sl(src), sl(dst), sl(mask)

    # NOTE: both scan bodies are rematerialized — without this the scans
    # save one [chunk, K, C] message tensor per step for the backward and
    # the chunking buys nothing (measured: 80 TB/device on ogb_products).
    @jax.checkpoint
    def pass1(carry, i):
        lmax, lsum = carry
        s, d, m = chunk_ids(i)
        _, logits, _ = _edge_messages(cfg, w, xs, batch, s, d, m)
        up = jax.ops.segment_max(logits, d, n)
        lmax_new = jnp.maximum(lmax, up)
        return (lmax_new, lsum), None

    lmax0 = jnp.full((n, h), -1e30)
    (lmax, _), _ = jax.lax.scan(pass1, (lmax0, None), jnp.arange(nch))

    @jax.checkpoint
    def pass2(carry, i):
        denom, agg = carry
        s, d, m = chunk_ids(i)
        msg, logits, d_mats = _edge_messages(cfg, w, xs, batch, s, d, m)
        ex = jnp.exp(logits - lmax[d]) * m[:, None]
        denom = denom + jax.ops.segment_sum(ex, d, n)
        val = (msg @ w["val_w"]).reshape(ch, k_, h, c_ // h)
        val = (val * ex[:, None, :, None]).reshape(ch, k_, c_)
        val = _rot_blocks(cfg, d_mats, val, inverse=True)
        agg = agg + jax.ops.segment_sum(val, d, n)
        return (denom, agg), None

    (denom, agg), _ = jax.lax.scan(
        pass2, (jnp.zeros((n, h)), jnp.zeros((n, k_, c_))), jnp.arange(nch)
    )
    # normalize: heads were folded into channels; expand denom per head
    agg = agg.reshape(n, k_, h, c_ // h) / jnp.maximum(denom, 1e-9)[:, None, :, None]
    agg = agg.reshape(n, k_, c_)
    return x + agg @ w["out_w"]


def loss_fn(cfg, params, batch) -> Array:
    pred = forward(cfg, params, batch)
    target = (batch.labels.astype(jnp.float32) * batch.node_mask)[:, None] * 0.01
    return jnp.mean((pred - target) ** 2)
