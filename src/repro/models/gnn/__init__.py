"""GNN architectures: PNA, GatedGCN (SpMM/SDDMM regime), DimeNet (triplet
regime), EquiformerV2 (irrep/eSCN regime)."""
