"""GatedGCN (arXiv:2003.00982): anisotropic gated message passing.

    ê_ij = C e_ij + D h_i + E h_j          (edge gate features)
    η_ij = σ(ê_ij) / (Σ_{j'∈N(i)} σ(ê_ij') + ε)
    h_i' = h_i + ReLU(LN(A h_i + Σ_j η_ij ⊙ (B h_j)))

Config: n_layers=16, d_hidden=70, gated aggregator.  Edge features are
updated residually alongside nodes (the benchmark-standard variant).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as g

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    num_layers: int = 16
    d_hidden: int = 70
    d_in: int = 128
    d_edge: int = 8
    num_classes: int = 16


def init_params(cfg: GatedGCNConfig, rng: jax.Array) -> dict:
    d = cfg.d_hidden
    k = iter(jax.random.split(rng, 6 + 5 * cfg.num_layers))
    p = {
        "enc_w": jax.random.normal(next(k), (cfg.d_in, d)) * cfg.d_in**-0.5,
        "enc_b": jnp.zeros((d,)),
        "edge_enc_w": jax.random.normal(next(k), (cfg.d_edge, d)) * cfg.d_edge**-0.5,
        "edge_enc_b": jnp.zeros((d,)),
        "layers": [],
        "head_w": jax.random.normal(next(k), (d, cfg.num_classes)) * d**-0.5,
        "head_b": jnp.zeros((cfg.num_classes,)),
    }
    for _ in range(cfg.num_layers):
        p["layers"].append(
            {name: jax.random.normal(next(k), (d, d)) * d**-0.5 for name in "ABCDE"}
            | {
                "ln_g": jnp.ones((d,)),
                "ln_b": jnp.zeros((d,)),
                "ln_ge": jnp.ones((d,)),
                "ln_be": jnp.zeros((d,)),
            }
        )
    return p


def _layer(w: dict, h: Array, e: Array, batch: g.GraphBatch):
    n = h.shape[0]
    src, dst = batch.edge_src, batch.edge_dst
    e_hat = e @ w["C"] + h[dst] @ w["D"] + h[src] @ w["E"]  # [E, d]
    sig = jax.nn.sigmoid(e_hat) * batch.edge_mask[:, None]
    denom = jax.ops.segment_sum(sig, dst, n) + 1e-6  # [N, d]
    msgs = jax.ops.segment_sum(sig * (h[src] @ w["B"]), dst, n)
    upd = h @ w["A"] + msgs / denom
    h_new = h + jax.nn.relu(_ln(upd, w["ln_g"], w["ln_b"]))
    e_new = e + jax.nn.relu(_ln(e_hat, w["ln_ge"], w["ln_be"]))
    return h_new, e_new


def _ln(x, gamma, beta, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def forward(cfg: GatedGCNConfig, params: dict, batch: g.GraphBatch) -> Array:
    h = batch.node_feat[:, : cfg.d_in] @ params["enc_w"] + params["enc_b"]
    e = batch.edge_feat[:, : cfg.d_edge] @ params["edge_enc_w"] + params["edge_enc_b"]
    step = jax.checkpoint(lambda he, w_: _layer(w_, he[0], he[1], batch))  # remat
    for w in params["layers"]:
        h, e = step((h, e), w)
    return h @ params["head_w"] + params["head_b"]


def loss_fn(cfg: GatedGCNConfig, params: dict, batch: g.GraphBatch) -> Array:
    logits = forward(cfg, params, batch)
    return g.node_classification_loss(logits, batch.labels, batch.node_mask)
