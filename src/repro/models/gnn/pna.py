"""Principal Neighbourhood Aggregation (arXiv:2004.05718).

Per layer: edge messages from [h_u ‖ h_v ‖ e_uv] MLP, aggregated with
{mean, max, min, std} and scaled by {identity, amplification, attenuation}
(log-degree scalers), concatenated (12 × d) and projected back to d, with
residual connection.  Config: n_layers=4, d_hidden=75.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as g

Array = jnp.ndarray

AGGREGATORS = ("mean", "max", "min", "std")
SCALERS = ("identity", "amplification", "attenuation")


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    num_layers: int = 4
    d_hidden: int = 75
    d_in: int = 128
    d_edge: int = 8
    num_classes: int = 16
    avg_deg_log: float = 2.0  # δ: E[log(deg+1)] over the training set


def init_params(cfg: PNAConfig, rng: jax.Array) -> dict:
    k = iter(jax.random.split(rng, 4 + 8 * cfg.num_layers))
    d = cfg.d_hidden
    n_agg = len(AGGREGATORS) * len(SCALERS)
    p = {
        "enc_w": jax.random.normal(next(k), (cfg.d_in, d)) * cfg.d_in**-0.5,
        "enc_b": jnp.zeros((d,)),
        "layers": [],
        "head_w": jax.random.normal(next(k), (d, cfg.num_classes)) * d**-0.5,
        "head_b": jnp.zeros((cfg.num_classes,)),
    }
    for _ in range(cfg.num_layers):
        p["layers"].append(
            {
                # message MLP over [h_u, h_v, e]
                "msg_w1": jax.random.normal(next(k), (2 * d + cfg.d_edge, d)) * (2 * d) ** -0.5,
                "msg_b1": jnp.zeros((d,)),
                "msg_w2": jax.random.normal(next(k), (d, d)) * d**-0.5,
                "msg_b2": jnp.zeros((d,)),
                # post-aggregation projection (12 aggregations ‖ self)
                "upd_w": jax.random.normal(next(k), ((n_agg + 1) * d, d)) * ((n_agg + 1) * d) ** -0.5,
                "upd_b": jnp.zeros((d,)),
                "ln_g": jnp.ones((d,)),
                "ln_b": jnp.zeros((d,)),
            }
        )
    return p


def _layer(cfg: PNAConfig, w: dict, h: Array, batch: g.GraphBatch) -> Array:
    n = h.shape[0]
    src, dst = batch.edge_src, batch.edge_dst
    m_in = jnp.concatenate([h[src], h[dst], batch.edge_feat[:, : cfg.d_edge]], axis=-1)
    m = g.mlp(m_in, [w["msg_w1"], w["msg_w2"]], [w["msg_b1"], w["msg_b2"]])
    m = jnp.where(batch.edge_mask[:, None], m, 0.0)

    deg = g.degrees(dst, batch.edge_mask, n)  # [N]
    mean = jax.ops.segment_sum(m, dst, n) / jnp.maximum(deg, 1.0)[:, None]
    mx = jax.ops.segment_max(jnp.where(batch.edge_mask[:, None], m, -1e30), dst, n)
    mx = jnp.where(deg[:, None] > 0, mx, 0.0)
    mn = jax.ops.segment_min(jnp.where(batch.edge_mask[:, None], m, 1e30), dst, n)
    mn = jnp.where(deg[:, None] > 0, mn, 0.0)
    sq = jax.ops.segment_sum(m * m, dst, n) / jnp.maximum(deg, 1.0)[:, None]
    std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-5)

    aggs = [mean, mx, mn, std]
    logd = jnp.log(deg + 1.0)[:, None]
    amp = logd / cfg.avg_deg_log
    att = cfg.avg_deg_log / jnp.maximum(logd, 1e-3)
    scaled = []
    for a in aggs:
        scaled += [a, a * amp, a * att]
    z = jnp.concatenate(scaled + [h], axis=-1)
    out = z @ w["upd_w"] + w["upd_b"]
    out = _layer_norm(out, w["ln_g"], w["ln_b"])
    return h + jax.nn.relu(out)


def _layer_norm(x, gamma, beta, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def forward(cfg: PNAConfig, params: dict, batch: g.GraphBatch) -> Array:
    h = jax.nn.relu(batch.node_feat[:, : cfg.d_in] @ params["enc_w"] + params["enc_b"])
    step = jax.checkpoint(lambda h_, w_: _layer(cfg, w_, h_, batch))  # remat:
    # backward recomputes each layer; saved state is one [N, d] per layer
    for w in params["layers"]:
        h = step(h, w)
    return h @ params["head_w"] + params["head_b"]


def loss_fn(cfg: PNAConfig, params: dict, batch: g.GraphBatch) -> Array:
    logits = forward(cfg, params, batch)
    return g.node_classification_loss(logits, batch.labels, batch.node_mask)
