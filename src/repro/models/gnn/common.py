"""Shared GNN machinery: fixed-shape graph batches and segment message passing.

JAX sparse is BCOO-only, so all message passing is explicit gather →
edge-compute → ``jax.ops.segment_{sum,max,min}`` scatter over the edge index.
Graphs are padded to static (N, E): padded edges point at a sacrificial node
(index N) and are masked; padded nodes carry zeros.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


class GraphBatch(NamedTuple):
    """Fixed-shape (possibly block-diagonal batched) graph."""

    node_feat: Array  # f32 [N, F]
    edge_src: Array  # int32 [E]
    edge_dst: Array  # int32 [E]
    edge_feat: Array  # f32 [E, Fe] (zeros if unused)
    node_mask: Array  # bool [N]
    edge_mask: Array  # bool [E]
    pos: Array  # f32 [N, 3] (zeros for non-geometric graphs)
    labels: Array  # int32 [N] node labels (or graph labels scattered to node 0)

    @property
    def num_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def num_edges(self) -> int:
        return self.edge_src.shape[0]


def segment_mean(data: Array, segment_ids: Array, num_segments: int) -> Array:
    s = jax.ops.segment_sum(data, segment_ids, num_segments)
    n = jax.ops.segment_sum(jnp.ones(data.shape[:1], data.dtype), segment_ids, num_segments)
    return s / jnp.maximum(n, 1.0)[..., None] if data.ndim > 1 else s / jnp.maximum(n, 1.0)


def degrees(edge_dst: Array, edge_mask: Array, num_nodes: int) -> Array:
    ones = jnp.where(edge_mask, 1.0, 0.0)
    return jax.ops.segment_sum(ones, edge_dst, num_nodes)


def mlp(x: Array, ws: list[Array], bs: list[Array], act=jax.nn.relu) -> Array:
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = x @ w + b
        if i < len(ws) - 1:
            x = act(x)
    return x


def random_graph_batch(
    rng: np.random.Generator,
    num_nodes: int,
    num_edges: int,
    feat_dim: int,
    *,
    edge_feat_dim: int = 0,
    num_classes: int = 8,
    geometric: bool = False,
) -> GraphBatch:
    """Synthetic padded graph for smoke tests and benchmarks."""
    src = rng.integers(0, num_nodes, num_edges).astype(np.int32)
    dst = rng.integers(0, num_nodes, num_edges).astype(np.int32)
    return GraphBatch(
        node_feat=jnp.asarray(rng.standard_normal((num_nodes, feat_dim)), jnp.float32),
        edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        edge_feat=jnp.asarray(
            rng.standard_normal((num_edges, max(edge_feat_dim, 1))), jnp.float32
        ),
        node_mask=jnp.ones(num_nodes, bool),
        edge_mask=jnp.ones(num_edges, bool),
        pos=jnp.asarray(
            rng.standard_normal((num_nodes, 3)) if geometric else np.zeros((num_nodes, 3)),
            jnp.float32,
        ),
        labels=jnp.asarray(rng.integers(0, num_classes, num_nodes), jnp.int32),
    )


def node_classification_loss(logits: Array, labels: Array, mask: Array) -> Array:
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    per = (logz - gold) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1.0)
