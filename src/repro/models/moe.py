"""Mixture-of-experts layer: top-k routing with sort-based capacity dispatch.

TPU-native dispatch: tokens are argsorted by expert id, sliced into per-expert
capacity buckets ``[E, C, d]`` (dropped on overflow — capacity_factor sizes
C), pushed through batched expert matmuls (one einsum on the MXU), and
combined back with the router gates.  No host-side raggedness; everything is
fixed-shape so it lowers for any mesh with experts sharded over ``model``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def topk_routing(logits: Array, k: int) -> tuple[Array, Array]:
    """logits [T, E] → (gates [T, k] softmaxed over the top-k, idx [T, k])."""
    gates, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates.astype(jnp.float32), axis=-1)
    return gates, idx


def dispatch_indices(idx: Array, num_experts: int, capacity: int):
    """Compute per-(token, choice) slot assignment.

    Returns (slot [T*k] int32 in [0, E*C) or -1 if dropped, order info for
    combine).  Stable sort by expert id; position within the expert group is
    the running rank; ranks ≥ C are dropped (classic capacity dropping).
    """
    tk = idx.size
    flat = idx.reshape(-1)
    order = jnp.argsort(flat, stable=True)  # token-choice ids sorted by expert
    sorted_e = flat[order]
    # rank within each expert group = index - start(group)
    group_start = jnp.searchsorted(sorted_e, jnp.arange(num_experts), side="left")
    rank = jnp.arange(tk) - group_start[sorted_e]
    slot_sorted = jnp.where(rank < capacity, sorted_e * capacity + rank, -1)
    # scatter back to token-choice order
    slot = jnp.zeros((tk,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    return slot


def moe_ffn(
    x: Array,  # [T, d] tokens
    router_w: Array,  # [d, E_pad]
    we_g: Array,  # [E_pad, d, f]
    we_i: Array,  # [E_pad, d, f]
    we_o: Array,  # [E_pad, f, d]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    num_experts: int | None = None,  # logical count; E_pad-E are sharding
    # padding (router logits masked to -inf, so they never receive tokens)
) -> tuple[Array, Array]:
    """Returns (output [T, d], aux load-balancing loss)."""
    t, d = x.shape
    e = router_w.shape[-1]  # padded
    e_logical = num_experts or e
    logits = (x @ router_w).astype(jnp.float32)
    if e_logical < e:
        logits = jnp.where(jnp.arange(e) < e_logical, logits, -1e30)
    gates, idx = topk_routing(logits, top_k)  # [T, k]
    capacity = max(1, int(capacity_factor * t * top_k / e_logical))

    slot = dispatch_indices(idx, e, capacity)  # [T*k]
    valid = slot >= 0
    # dropped choices target a sacrificial trailing slot (sliced off below) so
    # they can never clobber slot 0
    safe_slot = jnp.where(valid, slot, e * capacity)

    # dispatch: [E*C, d] buffer, dropped choices masked out
    xk = jnp.repeat(x, top_k, axis=0)  # [T*k, d]
    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    buf = buf.at[safe_slot].set(xk)
    h = buf[:-1].reshape(e, capacity, d)

    # batched expert FFN (SwiGLU) — one MXU einsum per projection
    a = jnp.einsum("ecd,edf->ecf", h, we_g)
    b = jnp.einsum("ecd,edf->ecf", h, we_i)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(a) * b, we_o).reshape(e * capacity, d)

    # combine: gather each choice's slot output, weight by its gate
    yk = y[jnp.where(valid, slot, 0)] * valid[:, None]  # [T*k, d]
    out = (yk.reshape(t, top_k, d) * gates[..., None].astype(x.dtype)).sum(axis=1)

    # Switch-style load-balance aux loss
    me = jax.nn.softmax(logits, axis=-1).mean(axis=0)  # [E_pad]
    ce = jnp.zeros((e,)).at[idx.reshape(-1)].add(1.0) / (t * top_k)
    aux = e_logical * jnp.sum(me * ce)
    return out.astype(x.dtype), aux
