"""Model definitions for the assigned architectures (LM / GNN / RecSys)."""
