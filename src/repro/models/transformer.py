"""Decoder-only transformer family covering all five assigned LM archs.

One parametric definition supports:
  * GQA attention with optional QKV bias (qwen2-72b, llama3.2-1b)
  * MLA — multi-head latent attention with compressed KV cache (minicpm3-4b)
  * MoE FFN with shared experts (qwen2-moe-a2.7b)
  * dense+MoE hybrid residual (arctic-480b)

Layers are stacked ``[L, ...]`` and scanned (compact HLO at 80 layers) with
optional remat.  Params carry logical axes ("embed", "heads", "mlp",
"experts", "vocab", "layers") resolved to mesh axes by runtime.mesh_rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.moe import moe_ffn

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    attention: str = "gqa"  # gqa | mla
    qkv_bias: bool = False
    # MLA dims (minicpm3)
    q_rank: int = 0
    kv_rank: int = 0
    nope_dim: int = 0
    rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    moe: bool = False
    num_experts: int = 0
    num_experts_padded: int = 0  # pad expert arrays for sharding divisibility
    top_k: int = 0
    d_ff_expert: int = 0
    d_ff_shared: int = 0  # qwen2-moe shared experts (0 = none)
    dense_residual: bool = False  # arctic: dense FFN ∥ MoE
    capacity_factor: float = 1.25
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # scan=True gives compact HLO (fast compiles); unrolled is required for
    # truthful cost_analysis flop totals (XLA counts a scan body once) and
    # exposes cross-layer fusion/overlap to the scheduler.
    scan_layers: bool = True
    attn_block_q: int = 512
    attn_block_k: int = 1024

    @property
    def qk_dim(self) -> int:
        return self.nope_dim + self.rope_dim if self.attention == "mla" else self.head_dim

    @property
    def v_dim(self) -> int:
        return self.v_head_dim if self.attention == "mla" else self.head_dim

    def num_params(self) -> int:
        import numpy as np

        specs = jax.eval_shape(lambda: init_params(self, jax.random.PRNGKey(0)))
        return int(sum(np.prod(l.shape) for l in jax.tree.leaves(specs)))

    def num_active_params(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        n = self.num_params()
        if not self.moe:
            return n
        per_expert = 3 * self.d_model * self.d_ff_expert
        inactive = (self.num_experts - self.top_k) * per_expert * self.num_layers
        return n - inactive


# ------------------------------------------------------------------- params
def init_params(cfg: TransformerConfig, rng: jax.Array) -> dict:
    f = cm.ParamFactory(rng, dtype=cfg.dtype)
    p: dict = {}
    s: dict = {}
    L, d = cfg.num_layers, cfg.d_model
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    lay: dict = {}
    ls: dict = {}
    f.param(lay, ls, "attn_norm", (L, d), ("layers", "embed"), scale=1.0)
    if cfg.attention == "gqa":
        f.param(lay, ls, "wq", (L, d, hq * dh), ("layers", "embed", "heads"))
        f.param(lay, ls, "wk", (L, d, hkv * dh), ("layers", "embed", "heads"))
        f.param(lay, ls, "wv", (L, d, hkv * dh), ("layers", "embed", "heads"))
        f.param(lay, ls, "wo", (L, hq * dh, d), ("layers", "heads", "embed"))
        if cfg.qkv_bias:
            f.param(lay, ls, "bq", (L, hq * dh), ("layers", "heads"), zeros=True)
            f.param(lay, ls, "bk", (L, hkv * dh), ("layers", "heads"), zeros=True)
            f.param(lay, ls, "bv", (L, hkv * dh), ("layers", "heads"), zeros=True)
    else:  # mla
        qk, vd = cfg.nope_dim + cfg.rope_dim, cfg.v_head_dim
        f.param(lay, ls, "wdq", (L, d, cfg.q_rank), ("layers", "embed", "mlp"))
        f.param(lay, ls, "q_norm", (L, cfg.q_rank), ("layers", "mlp"), scale=1.0)
        f.param(lay, ls, "wuq", (L, cfg.q_rank, hq * qk), ("layers", "mlp", "heads"))
        f.param(lay, ls, "wdkv", (L, d, cfg.kv_rank + cfg.rope_dim), ("layers", "embed", "mlp"))
        f.param(lay, ls, "kv_norm", (L, cfg.kv_rank), ("layers", "mlp"), scale=1.0)
        f.param(lay, ls, "wuk", (L, cfg.kv_rank, hq * cfg.nope_dim), ("layers", "mlp", "heads"))
        f.param(lay, ls, "wuv", (L, cfg.kv_rank, hq * vd), ("layers", "mlp", "heads"))
        f.param(lay, ls, "wo", (L, hq * vd, d), ("layers", "heads", "embed"))
    f.param(lay, ls, "mlp_norm", (L, d), ("layers", "embed"), scale=1.0)
    if cfg.moe:
        e, fe = cfg.num_experts_padded or cfg.num_experts, cfg.d_ff_expert
        f.param(lay, ls, "router", (L, d, e), ("layers", "embed", "experts"))
        f.param(lay, ls, "we_g", (L, e, d, fe), ("layers", "experts", "embed", "mlp"))
        f.param(lay, ls, "we_i", (L, e, d, fe), ("layers", "experts", "embed", "mlp"))
        f.param(lay, ls, "we_o", (L, e, fe, d), ("layers", "experts", "mlp", "embed"))
        if cfg.d_ff_shared:
            f.param(lay, ls, "ws_g", (L, d, cfg.d_ff_shared), ("layers", "embed", "mlp"))
            f.param(lay, ls, "ws_i", (L, d, cfg.d_ff_shared), ("layers", "embed", "mlp"))
            f.param(lay, ls, "ws_o", (L, cfg.d_ff_shared, d), ("layers", "mlp", "embed"))
            f.param(lay, ls, "shared_gate", (L, d), ("layers", "embed"), zeros=True)
    if (not cfg.moe) or cfg.dense_residual:
        f.param(lay, ls, "wg", (L, d, cfg.d_ff), ("layers", "embed", "mlp"))
        f.param(lay, ls, "wi", (L, d, cfg.d_ff), ("layers", "embed", "mlp"))
        f.param(lay, ls, "wo_mlp", (L, cfg.d_ff, d), ("layers", "mlp", "embed"))
    p["layers"] = lay
    s["layers"] = ls
    f.param(p, s, "embed", (cfg.vocab_size, d), ("vocab", "embed"), scale=1.0)
    f.param(p, s, "final_norm", (d,), ("embed",), scale=1.0)
    f.param(p, s, "lm_head", (d, cfg.vocab_size), ("embed", "vocab"))
    init_params.last_specs = s
    return p


def param_specs(cfg: TransformerConfig) -> dict:
    """Logical-axis tree matching init_params' structure (no allocation)."""
    jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return init_params.last_specs


# ------------------------------------------------------------------ attention
def _attention(cfg: TransformerConfig, w: dict, x: Array, positions: Array,
               cache=None, layer_idx=None):
    """Returns (attn_out [B,S,d], new_cache_entry)."""
    b, sq, d = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    if cfg.attention == "gqa":
        q = x @ w["wq"]
        k = x @ w["wk"]
        v = x @ w["wv"]
        if cfg.qkv_bias:
            q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
        q = q.reshape(b, sq, hq, dh).transpose(0, 2, 1, 3)
        k = k.reshape(b, sq, hkv, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, sq, hkv, dh).transpose(0, 2, 1, 3)
        q = cm.apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = cm.apply_rope(k, positions[:, None, :], cfg.rope_theta)
        if cache is None:
            out = cm.chunked_attention(
                q, k, v, causal=True,
                block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
            )
            new_cache = (k, v)
        else:
            ck, cv = cache  # [B, Hkv, Smax, dh]
            pos = positions[:, 0]  # decode: one token per row
            ck = _cache_insert(ck, k, pos)
            cv = _cache_insert(cv, v, pos)
            if cm._ACTIVATION_MESH[0] is not None and "model" in cm._ACTIVATION_MESH[0].axis_names:
                # seq-sharded KV + distributed-LSE combine (§Perf): the
                # cache never crosses the ICI, only [B, Hq, D] stats do.
                out = cm.dlse_decode_attention(q, ck, cv, pos[0] + 1)
            else:
                out = cm.chunked_attention(
                    q, ck, cv, causal=False,
                    q_offset=pos, kv_valid_len=pos[0] + 1,
                    block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
                )
            new_cache = (ck, cv)
        out = out.transpose(0, 2, 1, 3).reshape(b, sq, hq * dh)
        return out @ w["wo"], new_cache

    # ----- MLA (minicpm3): compressed latent KV -----
    qk, vd, nd, rd = cfg.qk_dim, cfg.v_head_dim, cfg.nope_dim, cfg.rope_dim
    cq = cm.rms_norm(x @ w["wdq"], w["q_norm"])
    q = (cq @ w["wuq"]).reshape(b, sq, hq, qk).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = cm.apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv_low = x @ w["wdkv"]  # [B, S, kvr + rd]
    ckv_new = cm.rms_norm(kv_low[..., : cfg.kv_rank], w["kv_norm"])
    krope_new = cm.apply_rope(
        kv_low[..., None, cfg.kv_rank:].transpose(0, 2, 1, 3), positions[:, None, :],
        cfg.rope_theta,
    )[:, 0]  # [B, S, rd] shared across heads

    if cache is None:
        ckv, krope, kv_len = ckv_new, krope_new, None
        new_cache = (ckv_new, krope_new)
    else:
        ckv, krope = cache  # [B, Smax, kvr], [B, Smax, rd]
        pos = positions[:, 0]
        ckv = _cache_insert_seq(ckv, ckv_new, pos)
        krope = _cache_insert_seq(krope, krope_new, pos)
        kv_len = pos[0] + 1
        new_cache = (ckv, krope)

    if (
        cache is not None
        and cm._ACTIVATION_MESH[0] is not None
        and "model" in cm._ACTIVATION_MESH[0].axis_names
    ):
        # decode with seq-sharded latents: expansion AND attention stay
        # device-local; only [B, H, vd] softmax stats cross the ICI (§Perf)
        out = cm.dlse_mla_decode_attention(
            q, ckv, krope, w["wuk"], w["wuv"], kv_len,
            nope_dim=nd, v_dim=vd,
        )
    else:
        sk = ckv.shape[1]
        k_nope = (ckv @ w["wuk"]).reshape(b, sk, hq, nd).transpose(0, 2, 1, 3)
        v = (ckv @ w["wuv"]).reshape(b, sk, hq, vd).transpose(0, 2, 1, 3)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, None], (b, hq, sk, rd))], axis=-1
        )
        out = cm.chunked_attention(
            q, k, v, causal=(cache is None),
            q_offset=positions[:, 0] if cache is not None else 0,
            kv_valid_len=kv_len,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        )
    out = out.transpose(0, 2, 1, 3).reshape(b, sq, hq * vd)
    return out @ w["wo"], new_cache


def _cache_insert(cache: Array, new: Array, pos: Array) -> Array:
    """cache [B, H, Smax, D] ← new [B, H, 1, D] at per-batch position pos."""
    b, h, smax, d = cache.shape
    onehot = (jnp.arange(smax)[None] == pos[:, None])[:, None, :, None]
    return jnp.where(onehot, new.astype(cache.dtype), cache)


def _cache_insert_seq(cache: Array, new: Array, pos: Array) -> Array:
    """cache [B, Smax, D] ← new [B, 1, D] at per-batch position pos."""
    b, smax, d = cache.shape
    onehot = (jnp.arange(smax)[None] == pos[:, None])[:, :, None]
    return jnp.where(onehot, new.astype(cache.dtype), cache)


# ---------------------------------------------------------------------- MLP
def _mlp(cfg: TransformerConfig, w: dict, x: Array) -> tuple[Array, Array]:
    b, s, d = x.shape
    aux = jnp.zeros((), jnp.float32)
    out = jnp.zeros_like(x)
    if cfg.moe:
        flat = x.reshape(b * s, d)
        moe_out, aux = moe_ffn(
            flat, w["router"], w["we_g"], w["we_i"], w["we_o"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            num_experts=cfg.num_experts,
        )
        out = out + moe_out.reshape(b, s, d)
        if cfg.d_ff_shared:
            shared = cm.swiglu(x, w["ws_g"], w["ws_i"], w["ws_o"])
            gate = jax.nn.sigmoid((x * w["shared_gate"]).sum(-1, keepdims=True))
            out = out + gate.astype(x.dtype) * shared
    if (not cfg.moe) or cfg.dense_residual:
        out = out + cm.swiglu(x, w["wg"], w["wi"], w["wo_mlp"])
    return out, aux


# ------------------------------------------------------------------- forward
def forward(
    cfg: TransformerConfig,
    params: dict,
    tokens: Array,  # int32 [B, S]
    *,
    cache: Any = None,  # stacked per-layer cache (decode) or None
    positions: Array | None = None,  # [B, S] absolute positions
):
    """Returns (logits [B, S, vocab], new_cache, aux_loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = params["embed"][tokens].astype(cfg.dtype)
    x = cm.constrain(x, "batch", None, None)

    def layer(carry, scanned):
        h, aux = carry
        w, cache_l = scanned
        attn_in = cm.rms_norm(h, w["attn_norm"])
        attn_out, new_cache_l = _attention(cfg, w, attn_in, positions, cache_l)
        h = h + attn_out
        mlp_out, aux_l = _mlp(cfg, w, cm.rms_norm(h, w["mlp_norm"]))
        return (h + mlp_out, aux + aux_l), new_cache_l

    layer_fn = jax.checkpoint(layer) if cfg.remat else layer
    if cfg.scan_layers:
        (x, aux), new_cache = jax.lax.scan(
            layer_fn, (x, jnp.zeros((), jnp.float32)), (params["layers"], cache)
        )
    else:
        carry = (x, jnp.zeros((), jnp.float32))
        caches = []
        for l in range(cfg.num_layers):
            w_l = jax.tree.map(lambda a: a[l], params["layers"])
            cache_l = jax.tree.map(lambda a: a[l], cache) if cache is not None else None
            carry, cache_out = layer_fn(carry, (w_l, cache_l))
            caches.append(cache_out)
        x, aux = carry
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    x = cm.rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    # vocab-sharded logits: keeps the f32 softmax/CE working set at
    # [B/dp, S, V/tp] per device instead of a replicated [B/dp, S, V]
    logits = cm.constrain(logits, "batch", None, "vocab")
    return logits, new_cache, aux


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int):
    """Stacked decode cache (zeros); shapes match forward's scan."""
    L = cfg.num_layers
    if cfg.attention == "gqa":
        shape = (L, batch, cfg.num_kv_heads, max_seq, cfg.head_dim)
        return (jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))
    return (
        jnp.zeros((L, batch, max_seq, cfg.kv_rank), cfg.dtype),
        jnp.zeros((L, batch, max_seq, cfg.rope_dim), cfg.dtype),
    )


def cache_specs(cfg: TransformerConfig):
    """Logical axes for the decode cache.

    §Perf: kv_seq over model + distributed-LSE attention (GQA path) — the
    cache fits (85 GB / 256 chips) AND never crosses the ICI; only
    [B, Hq, D] softmax stats are psum'd.  (Batch-only sharding was measured
    7.1× better on collectives but does not fit HBM; see EXPERIMENTS.md.)
    """
    if cfg.attention == "gqa":
        ax = ("layers", "batch", None, "kv_seq", None)
        return (ax, ax)
    return (("layers", "batch", "kv_seq", None), ("layers", "batch", "kv_seq", None))


def decode_step(cfg: TransformerConfig, params: dict, cache, tokens: Array, pos: Array):
    """One-token decode: tokens [B], pos [B] → (logits [B, vocab], cache)."""
    positions = pos[:, None]
    logits, new_cache, _ = forward(
        cfg, params, tokens[:, None], cache=cache, positions=positions
    )
    return logits[:, 0], new_cache


def loss_fn(cfg: TransformerConfig, params: dict, tokens: Array, labels: Array):
    logits, _, aux = forward(cfg, params, tokens)
    return cm.cross_entropy_loss(logits, labels) + 0.01 * aux
