"""RecSys: MIND multi-interest retrieval + the EmbeddingBag substrate."""
