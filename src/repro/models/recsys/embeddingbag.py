"""EmbeddingBag for JAX: the hot path of every recsys model.

JAX has no native EmbeddingBag and no CSR sparse — lookups are explicit
``jnp.take`` gathers and bag reduction is ``jax.ops.segment_sum`` (or a
dense reshape-reduce when bags are fixed-length).  The table's row axis is
the sharded ("table_rows" → model) dimension: each chip gathers its local
rows and the partial bag sums meet in one reduce-scatter — the same
communication pattern as a parameter-server embedding shard.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def embedding_bag_fixed(
    table: Array,  # [R, D] (row-sharded)
    indices: Array,  # int32 [B, L]  fixed-length bags
    weights: Array | None = None,  # f32 [B, L] per-item weights
    *,
    mode: str = "sum",
    valid: Array | None = None,  # bool [B, L] padding mask
) -> Array:
    """Fixed-length-bag lookup: gather [B, L, D] → reduce L. [B, D]"""
    emb = jnp.take(table, indices, axis=0)  # [B, L, D]
    if weights is not None:
        emb = emb * weights[..., None]
    if valid is not None:
        emb = jnp.where(valid[..., None], emb, 0.0)
    if mode == "sum":
        return emb.sum(axis=1)
    if mode == "mean":
        n = (
            valid.sum(axis=1, keepdims=True).astype(emb.dtype)
            if valid is not None
            else jnp.float32(indices.shape[1])
        )
        return emb.sum(axis=1) / jnp.maximum(n, 1.0)
    raise ValueError(mode)


def embedding_bag_ragged(
    table: Array,  # [R, D]
    indices: Array,  # int32 [T] flattened item ids
    bag_ids: Array,  # int32 [T] which bag each item belongs to
    num_bags: int,
    *,
    mode: str = "sum",
) -> Array:
    """Ragged bags via segment_sum (CSR-style offsets → bag_ids). [B, D]"""
    emb = jnp.take(table, indices, axis=0)  # [T, D]
    s = jax.ops.segment_sum(emb, bag_ids, num_bags)
    if mode == "sum":
        return s
    n = jax.ops.segment_sum(jnp.ones_like(bag_ids, emb.dtype), bag_ids, num_bags)
    return s / jnp.maximum(n, 1.0)[:, None]
