"""MIND: Multi-Interest Network with Dynamic routing (arXiv:1904.08030).

User behaviour sequence → item EmbeddingBag lookups → Behaviour-to-Interest
(B2I) capsule routing (3 iterations, squash nonlinearity, shared bilinear
map) → K=4 interest capsules → label-aware attention for training / max-dot
scoring for retrieval.

Shapes: huge sparse item table (the hot path — ``embeddingbag``), tiny dense
compute.  ``retrieval_cand`` scores one user against 10⁶ candidates with a
single [K, D] × [D, N] matmul (batched-dot, never a loop).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    num_items: int = 8_388_608  # sparse table rows
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    hidden: int = 256


def init_params(cfg: MINDConfig, rng: jax.Array) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d = cfg.embed_dim
    return {
        "item_table": jax.random.normal(k1, (cfg.num_items, d)) * 0.01,
        "bilinear_s": jax.random.normal(k2, (d, d)) * d**-0.5,  # shared B2I map
        "mlp_w1": jax.random.normal(k3, (d, cfg.hidden)) * d**-0.5,
        "mlp_b1": jnp.zeros((cfg.hidden,)),
        "mlp_w2": jax.random.normal(k4, (cfg.hidden, d)) * cfg.hidden**-0.5,
        "mlp_b2": jnp.zeros((d,)),
    }


def _squash(x: Array, axis: int = -1) -> Array:
    n2 = jnp.sum(jnp.square(x), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def user_interests(cfg: MINDConfig, params: dict, behavior: Array, valid: Array) -> Array:
    """behavior int32 [B, L], valid bool [B, L] → interests [B, K, D].

    B2I dynamic routing: logits b_kj updated by agreement ⟨u_k, ŝ_j⟩ over
    ``capsule_iters`` rounds; behaviour capsules ŝ_j = S e_j (shared S).
    """
    emb = jnp.take(params["item_table"], behavior, axis=0)  # [B, L, D]
    emb = jnp.where(valid[..., None], emb, 0.0)
    s_hat = emb @ params["bilinear_s"]  # [B, L, D]

    b, l, d = s_hat.shape
    k = cfg.n_interests
    logits = jnp.zeros((b, k, l))

    def routing_iter(logits, _):
        w = jax.nn.softmax(logits, axis=1)  # over interests
        w = jnp.where(valid[:, None, :], w, 0.0)
        u = _squash(jnp.einsum("bkl,bld->bkd", w, s_hat))
        logits_new = logits + jnp.einsum("bkd,bld->bkl", u, s_hat)
        return logits_new, u

    logits, us = jax.lax.scan(routing_iter, logits, None, length=cfg.capsule_iters)
    u = us[-1]  # [B, K, D]
    h = jax.nn.relu(u @ params["mlp_w1"] + params["mlp_b1"])
    return u + h @ params["mlp_w2"] + params["mlp_b2"]  # residual interest MLP


def label_aware_attention(interests: Array, target_emb: Array, p: float = 2.0) -> Array:
    """Train-time pooling: softmax(⟨u_k, e_t⟩^p) weighted interests. [B, D]"""
    scores = jnp.einsum("bkd,bd->bk", interests, target_emb)
    w = jax.nn.softmax(jnp.power(jnp.abs(scores) + 1e-9, p) * jnp.sign(scores), axis=-1)
    return jnp.einsum("bk,bkd->bd", w, interests)


def loss_fn(
    cfg: MINDConfig,
    params: dict,
    behavior: Array,  # [B, L]
    valid: Array,  # [B, L]
    target: Array,  # [B] positive item ids
    negatives: Array,  # [B, M] sampled negative ids
) -> Array:
    """Sampled-softmax training loss."""
    interests = user_interests(cfg, params, behavior, valid)
    t_emb = jnp.take(params["item_table"], target, axis=0)
    user = label_aware_attention(interests, t_emb)  # [B, D]
    n_emb = jnp.take(params["item_table"], negatives, axis=0)  # [B, M, D]
    pos = jnp.einsum("bd,bd->b", user, t_emb)
    neg = jnp.einsum("bd,bmd->bm", user, n_emb)
    logits = jnp.concatenate([pos[:, None], neg], axis=1)
    return -jax.nn.log_softmax(logits, axis=1)[:, 0].mean()


def serve_scores(cfg: MINDConfig, params: dict, behavior: Array, valid: Array,
                 candidates: Array) -> Array:
    """Online/offline scoring: [B] users × their [B, C] candidates → [B, C]."""
    interests = user_interests(cfg, params, behavior, valid)
    c_emb = jnp.take(params["item_table"], candidates, axis=0)  # [B, C, D]
    scores = jnp.einsum("bkd,bcd->bkc", interests, c_emb)
    return scores.max(axis=1)  # max over interests (MIND retrieval rule)


def retrieval_scores(cfg: MINDConfig, params: dict, behavior: Array, valid: Array,
                     candidates: Array) -> Array:
    """One query against a 10⁶-candidate slab: single [K,D]×[D,C] matmul. [B, C]"""
    interests = user_interests(cfg, params, behavior, valid)  # [B, K, D]
    c_emb = jnp.take(params["item_table"], candidates, axis=0)  # [C, D]
    scores = jnp.einsum("bkd,cd->bkc", interests, c_emb)
    return scores.max(axis=1)
