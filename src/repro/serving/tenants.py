"""Per-tenant registries: query tickets, byte budgets, and rate quotas.

A serving tier multiplexes many *tenants* over one :class:`CQPSession`.
Each tenant owns a set of registered queries (addressed by stable
:class:`QueryTicket` ids that survive fault recovery, unlike engine slots
or session qids), an optional **isolated byte budget** (its queries'
accounted difference bytes, enforced through the session's existing
``set_drop_policy`` / ``nbytes_per_query`` hooks — a per-tenant
mini-governor walking the same :class:`GovernorConfig` ladder the global
memory governor uses), a **rate quota** (token-bucket admitted updates/sec),
and a **priority** that orders the admission controller's degradation
ladder (low priority degrades first, restores last).

Degradation is tenant-granular: one rung moves *all* of the tenant's
queries one step along ``ladder.rung_config`` — escalation sheds stored
diffs in place (answers stay exact via repair-on-access, DESIGN.md §10),
so memory pressure falls immediately without deregistering anyone.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core import dropping as dr
from repro.core.governor import GovernorConfig


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's serving contract."""

    tenant_id: str
    priority: int = 1  # higher = more important; degraded last, shed last
    budget_bytes: int | None = None  # isolated accounted-byte budget
    rate_per_s: float | None = None  # sustained admitted updates/sec
    burst: int = 64  # token-bucket capacity (updates)

    def __post_init__(self):
        if self.budget_bytes is not None and self.budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive (or None)")
        if self.rate_per_s is not None and self.rate_per_s <= 0:
            raise ValueError("rate_per_s must be positive (or None)")
        if self.burst < 1:
            raise ValueError("burst must be >= 1")


@dataclasses.dataclass(frozen=True)
class QueryTicket:
    """Stable handle for one tenant query — survives fault recovery (the
    session-level qid behind it may change when a crashed loop rebuilds
    from genesis; the ticket does not)."""

    ticket_id: int
    tenant_id: str


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/sec, ``burst`` capacity."""

    def __init__(self, rate: float, burst: int) -> None:
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._last: float | None = None

    def take(self, n: int, now: float) -> bool:
        if self._last is not None:
            self.tokens = min(
                self.burst, self.tokens + (now - self._last) * self.rate
            )
        self._last = now
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False

    def state_dict(self) -> dict:
        return {"tokens": self.tokens}

    def load_state(self, state: dict) -> None:
        self.tokens = float(state["tokens"])
        self._last = None


@dataclasses.dataclass
class TenantState:
    """Mutable per-tenant serving state."""

    spec: TenantSpec
    bucket: TokenBucket | None
    level: int = 0  # degradation rung (0 = registered policies)
    watermark: int = 0  # admitted-stream seq the tenant's writes reach
    # ticket_id → session qid (rebuilt after recovery)
    qids: dict[int, int] = dataclasses.field(default_factory=dict)
    # ticket_id → the query's registered (level-0) drop policy
    base: dict[int, dr.DropConfig] = dataclasses.field(default_factory=dict)
    submitted_updates: int = 0
    admitted_updates: int = 0
    rejected_updates: int = 0
    rejected_registers: int = 0
    nbytes: int = 0  # last metered accounted bytes


class TenantRegistry:
    """The serving tier's tenant table.

    Owns tenancy state only — the *decisions* (admit/queue/reject) live in
    :class:`repro.serving.admission.AdmissionController`; the registry
    provides the levers (degrade/restore one tenant one rung, enforce a
    tenant's own byte budget) and the meters (per-tenant bytes, quotas).
    """

    def __init__(
        self,
        ladder: GovernorConfig | None = None,
        *,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.ladder = ladder or GovernorConfig(representation="prob")
        self.clock = clock
        self._tenants: dict[str, TenantState] = {}
        self._next_ticket = 0
        self.actions: list[dict] = []  # degrade/restore/budget log
        # degradations in order, for last-in-first-out restore
        self._degrade_stack: list[str] = []

    # ------------------------------------------------------------ tenancy
    def add(self, spec: TenantSpec) -> TenantState:
        if spec.tenant_id in self._tenants:
            raise ValueError(f"tenant {spec.tenant_id!r} already registered")
        bucket = (
            None
            if spec.rate_per_s is None
            else TokenBucket(spec.rate_per_s, spec.burst)
        )
        st = TenantState(spec=spec, bucket=bucket)
        self._tenants[spec.tenant_id] = st
        return st

    def remove(self, tenant_id: str) -> list[int]:
        """Drop a tenant; returns the session qids its tickets held."""
        st = self.require(tenant_id)
        del self._tenants[tenant_id]
        self._degrade_stack = [t for t in self._degrade_stack if t != tenant_id]
        return list(st.qids.values())

    def require(self, tenant_id: str) -> TenantState:
        if tenant_id not in self._tenants:
            raise ValueError(f"unknown tenant {tenant_id!r}")
        return self._tenants[tenant_id]

    def tenants(self) -> list[TenantState]:
        return [self._tenants[t] for t in sorted(self._tenants)]

    def by_priority(self) -> list[TenantState]:
        """Ascending priority (degrade-first order), tenant_id tiebreak."""
        return sorted(
            self._tenants.values(), key=lambda s: (s.spec.priority, s.spec.tenant_id)
        )

    # ------------------------------------------------------------ tickets
    def new_ticket(self, tenant_id: str) -> QueryTicket:
        self.require(tenant_id)
        t = QueryTicket(ticket_id=self._next_ticket, tenant_id=tenant_id)
        self._next_ticket += 1
        return t

    def attach(
        self, ticket: QueryTicket, qid: int, base_drop: dr.DropConfig
    ) -> None:
        st = self.require(ticket.tenant_id)
        st.qids[ticket.ticket_id] = int(qid)
        st.base[ticket.ticket_id] = base_drop

    def detach(self, ticket: QueryTicket) -> int:
        st = self.require(ticket.tenant_id)
        st.base.pop(ticket.ticket_id, None)
        return st.qids.pop(ticket.ticket_id)

    def qid_of(self, ticket: QueryTicket) -> int:
        st = self.require(ticket.tenant_id)
        if ticket.ticket_id not in st.qids:
            raise ValueError(f"ticket {ticket.ticket_id} is not registered")
        return st.qids[ticket.ticket_id]

    def remap_qids(self, mapping: dict[int, int]) -> None:
        """Rewrite ticket → qid after a genesis rebuild reassigned qids."""
        for st in self._tenants.values():
            st.qids = {t: mapping.get(q, q) for t, q in st.qids.items()}

    def all_qids(self) -> dict[int, str]:
        """qid → tenant_id over every live ticket."""
        return {
            q: tid
            for tid, st in self._tenants.items()
            for q in st.qids.values()
        }

    # ------------------------------------------------------------- quotas
    def allow_rate(self, tenant_id: str, n: int) -> bool:
        """Spend ``n`` updates from the tenant's token bucket (always
        allowed for tenants with no rate quota)."""
        st = self.require(tenant_id)
        if st.bucket is None:
            return True
        return st.bucket.take(n, self.clock())

    # ------------------------------------------------------------- meters
    def bytes_by_tenant(self, session) -> dict[str, int]:
        """Per-tenant accounted difference bytes, via the session's public
        per-query meter (``nbytes_per_query`` aligned with ``handles``)."""
        per_qid = {
            h.qid: b
            for h, b in zip(session.handles(), session.nbytes_per_query())
        }
        out: dict[str, int] = {}
        for tid, st in self._tenants.items():
            st.nbytes = sum(per_qid.get(q, 0) for q in st.qids.values())
            out[tid] = st.nbytes
        return out

    # ------------------------------------------------- degradation ladder
    def _handles_by_qid(self, session) -> dict[int, object]:
        return {h.qid: h for h in session.handles()}

    def _apply_level(self, session, st: TenantState, level: int) -> int:
        """Rewrite every query of ``st`` to the ladder rung ``level``;
        returns the accounted bytes released (negative = regrown)."""
        handles = self._handles_by_qid(session)
        freed = 0
        for ticket_id, qid in st.qids.items():
            base = st.base.get(ticket_id, dr.DropConfig())
            cfg = self.ladder.rung_config(level, base)
            freed += session.set_drop_policy(handles[qid], cfg)
        return freed

    def degrade(self, session, tenant_id: str, reason: str) -> dict | None:
        """Escalate one tenant one rung down the drop ladder (sheds stored
        diffs in place); returns the action record, or None at the top."""
        st = self.require(tenant_id)
        if st.level >= self.ladder.top_level or not st.qids:
            return None
        freed = self._apply_level(session, st, st.level + 1)
        action = {
            "kind": "degrade",
            "tenant": tenant_id,
            "level_from": st.level,
            "level_to": st.level + 1,
            "bytes_freed": int(freed),
            "reason": reason,
        }
        st.level += 1
        self._degrade_stack.append(tenant_id)
        self.actions.append(action)
        return action

    def restore_one(self, session, reason: str) -> dict | None:
        """Undo the most recent degradation one rung (LIFO, so the
        lowest-priority tenants — degraded first — are restored last)."""
        while self._degrade_stack:
            tid = self._degrade_stack.pop()
            st = self._tenants.get(tid)
            if st is not None and st.level > 0:
                freed = self._apply_level(session, st, st.level - 1)
                action = {
                    "kind": "restore",
                    "tenant": tid,
                    "level_from": st.level,
                    "level_to": st.level - 1,
                    "bytes_freed": int(freed),
                    "reason": reason,
                }
                st.level -= 1
                self.actions.append(action)
                return action
        return None

    def next_degradable(self) -> TenantState | None:
        """The lowest-priority tenant with ladder headroom left."""
        for st in self.by_priority():
            if st.level < self.ladder.top_level and st.qids:
                return st
        return None

    def fully_degraded(self) -> bool:
        return self.next_degradable() is None

    def enforce_budgets(self, session) -> list[dict]:
        """Per-tenant budget enforcement: while a tenant's accounted bytes
        exceed *its own* budget and it has rungs left, walk it down the
        ladder.  Isolation: only the over-budget tenant's queries are
        rewritten — a co-tenant blowing its budget never degrades yours."""
        actions: list[dict] = []
        for tid, nbytes in sorted(self.bytes_by_tenant(session).items()):
            st = self._tenants[tid]
            if st.spec.budget_bytes is None:
                continue
            while (
                st.nbytes > st.spec.budget_bytes
                and st.level < self.ladder.top_level
                and st.qids
            ):
                action = self.degrade(session, tid, "tenant budget")
                if action is None:
                    break
                actions.append(action)
                st.nbytes = max(st.nbytes - max(action["bytes_freed"], 0), 0)
        return actions

    # --------------------------------------------------------- durability
    def state_dict(self) -> dict:
        """JSON-able registry state for the checkpoint manifest's ``extra``
        block — a cross-process restore rebuilds tenancy from this."""

        def spec_dict(spec: TenantSpec) -> dict:
            return dataclasses.asdict(spec)

        return {
            "next_ticket": self._next_ticket,
            "degrade_stack": list(self._degrade_stack),
            "tenants": [
                {
                    "spec": spec_dict(st.spec),
                    "level": st.level,
                    "watermark": st.watermark,
                    "qids": {str(t): q for t, q in st.qids.items()},
                    "base": {
                        str(t): dataclasses.asdict(b)
                        for t, b in st.base.items()
                    },
                    "bucket": (
                        None if st.bucket is None else st.bucket.state_dict()
                    ),
                    "counters": {
                        "submitted_updates": st.submitted_updates,
                        "admitted_updates": st.admitted_updates,
                        "rejected_updates": st.rejected_updates,
                        "rejected_registers": st.rejected_registers,
                    },
                }
                for st in self.tenants()
            ],
        }

    def load_state(self, state: dict) -> None:
        self._next_ticket = int(state["next_ticket"])
        self._degrade_stack = list(state["degrade_stack"])
        self._tenants = {}
        for entry in state["tenants"]:
            spec = TenantSpec(**entry["spec"])
            st = self.add(spec)
            st.level = int(entry["level"])
            st.watermark = int(entry["watermark"])
            st.qids = {int(t): int(q) for t, q in entry["qids"].items()}
            st.base = {
                int(t): dr.DropConfig(**b) for t, b in entry["base"].items()
            }
            if st.bucket is not None and entry["bucket"] is not None:
                st.bucket.load_state(entry["bucket"])
            for k, v in entry["counters"].items():
                setattr(st, k, int(v))

    def snapshot(self) -> dict:
        """Per-tenant counters for ``server.stats()`` / JSON reports."""
        return {
            tid: {
                "priority": st.spec.priority,
                "budget_bytes": st.spec.budget_bytes,
                "rate_per_s": st.spec.rate_per_s,
                "level": st.level,
                "queries": len(st.qids),
                "nbytes": st.nbytes,
                "watermark": st.watermark,
                "submitted_updates": st.submitted_updates,
                "admitted_updates": st.admitted_updates,
                "rejected_updates": st.rejected_updates,
                "rejected_registers": st.rejected_registers,
            }
            for tid, st in sorted(self._tenants.items())
        }
