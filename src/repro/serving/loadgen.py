"""Multi-tenant open-loop load generator for the CQP serving tier.

Open-loop: each tenant's submission times are drawn up front from a seeded
Poisson process (exponential inter-arrivals at ``rate_per_s``) and scheduled
against the wall clock — arrivals do NOT wait for earlier ones to finish, so
an overloaded server sees the offered rate, not its own throughput echoed
back (the closed-loop trap).  Every arrival submits one batch of δE updates
and then issues a read-your-writes read; the generator records per-tenant
read latency, freshness lag, and rejection counts.

``python -m repro.serving.loadgen`` drives a synthetic powerlaw workload and
writes the per-tenant JSON under ``reports/serving/``.
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import os
import time

import numpy as np

from repro.serving.metrics import summarize_latency_s
from repro.serving.server import CQPServer
from repro.serving.tenants import TenantSpec


@dataclasses.dataclass(frozen=True)
class TenantLoad:
    """One tenant's offered load."""

    spec: TenantSpec
    arrival_rate_per_s: float  # submissions/sec (open-loop)
    updates_per_arrival: int = 8
    arrivals: int = 32

    def __post_init__(self):
        if self.arrival_rate_per_s <= 0:
            raise ValueError("arrival_rate_per_s must be positive")
        if self.updates_per_arrival < 1 or self.arrivals < 1:
            raise ValueError("updates_per_arrival and arrivals must be >= 1")


def tenant_update_streams(
    initial: list,
    num_vertices: int,
    tenants: int,
    *,
    num_batches: int,
    batch_size: int,
    delete_fraction: float = 0.1,
    insert_pool: list | None = None,
    seed: int = 0,
) -> dict[str, list]:
    """Per-tenant δE streams that stay valid under ANY interleaving which
    preserves each tenant's own submission order.

    ``update_stream`` assumes in-order application: its deletions target
    currently-present edges, including edges inserted *earlier in the same
    stream*.  Round-robin-splitting one stream across concurrently
    submitting tenants can therefore reorder a delete ahead of its insert —
    an invalid stream the differential engines make no promises about.
    Here each tenant instead gets a disjoint edge universe: its own slice
    of the initial edges for deletions plus a private, globally-fresh
    insert pool.  No cross-tenant interleaving can then violate the
    insert-absent / delete-present contract.
    """
    rng = np.random.default_rng(seed)
    taken = {(int(e[0]), int(e[1])) for e in initial}
    need = num_batches * batch_size  # upper bound: a stream of all inserts
    if tenants * need > num_vertices * (num_vertices - 1) - len(taken):
        raise ValueError("vertex-pair space too small for disjoint pools")
    pools: list[list] = [[] for _ in range(tenants)]
    for j, e in enumerate(insert_pool or []):
        key = (int(e[0]), int(e[1]))
        if key in taken:
            continue
        taken.add(key)
        pools[j % tenants].append(e)
    short = [i for i in range(tenants) if len(pools[i]) < need]
    while short:
        u, v = (int(x) for x in rng.integers(0, num_vertices, 2))
        if u == v or (u, v) in taken:
            continue
        taken.add((u, v))
        i = short[0]
        pools[i].append((u, v, float(rng.integers(1, 11))))
        if len(pools[i]) >= need:
            short.pop(0)
    from repro.data.graphgen import update_stream

    return {
        f"tenant{i}": update_stream(
            initial[i::tenants],
            num_vertices,
            num_batches=num_batches,
            batch_size=batch_size,
            delete_fraction=delete_fraction,
            insert_pool=pools[i],
            seed=seed + 101 * i + 1,
        )
        for i in range(tenants)
    }


def arrival_schedule(load: TenantLoad, seed: int) -> np.ndarray:
    """Absolute arrival offsets (seconds) for one tenant — exponential
    inter-arrivals, deterministic under the seed."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / load.arrival_rate_per_s, size=load.arrivals)
    return np.cumsum(gaps)


async def _drive_tenant(
    server: CQPServer,
    load: TenantLoad,
    ticket,
    updates: list,
    t_start: float,
    schedule: np.ndarray,
    read_timeout_s: float | None,
) -> dict:
    tid = load.spec.tenant_id
    n = load.updates_per_arrival
    submitted = admitted = rejected = 0
    for i, offset in enumerate(schedule):
        delay = (t_start + float(offset)) - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        batch = updates[(i * n) % max(len(updates), 1) :][:n]
        if not batch:
            break
        res = server.submit(tid, batch)
        submitted += len(batch)
        if res.admitted:
            admitted += len(batch)
        else:
            rejected += len(batch)
        await server.read(ticket, timeout_s=read_timeout_s)
    return {
        "tenant": tid,
        "submitted_updates": submitted,
        "admitted_updates": admitted,
        "rejected_updates": rejected,
        "rejection_rate": rejected / submitted if submitted else 0.0,
    }


async def run_load(
    server: CQPServer,
    loads: list[TenantLoad],
    tickets: dict[str, object],
    updates_by_tenant: dict[str, list],
    *,
    seed: int = 0,
    read_timeout_s: float | None = None,
) -> dict:
    """Run every tenant's open-loop schedule concurrently; returns the
    per-tenant report (generator counters merged with the server's
    latency/freshness meters)."""
    t_start = time.perf_counter()
    results = await asyncio.gather(
        *(
            _drive_tenant(
                server,
                load,
                tickets[load.spec.tenant_id],
                updates_by_tenant[load.spec.tenant_id],
                t_start,
                arrival_schedule(load, seed + 7919 * i),
                read_timeout_s,
            )
            for i, load in enumerate(loads)
        )
    )
    await server.drain()
    wall_s = time.perf_counter() - t_start
    stats = server.stats()
    per_tenant = {}
    for r in results:
        tid = r["tenant"]
        per_tenant[tid] = {
            **r,
            "read_latency": stats["tenants"][tid]["read_latency"],
            "freshness_lag_updates": stats["tenants"][tid][
                "freshness_lag_updates"
            ],
            "stale_reads": stats["tenants"][tid]["stale_reads"],
            "degrade_level": stats["tenants"][tid]["level"],
        }
    return {
        "wall_s": wall_s,
        "offered_updates_per_s": sum(
            ld.arrival_rate_per_s * ld.updates_per_arrival for ld in loads
        ),
        "tenants": per_tenant,
        "admission": stats["admission"],
        "actions": stats["actions"],
        "read_latency": summarize_latency_s(
            server.metrics.samples("read")
        ),
        "epochs": stats["epochs"],
        "covered_updates": stats["covered_updates"],
    }


# ---------------------------------------------------------------------- CLI
def main(argv=None) -> int:
    from repro.core import plan
    from repro.core.governor import GovernorConfig
    from repro.data.graphgen import powerlaw_graph, split_90_10
    from repro.serving.server import (
        ServerConfig,
        SLOConfig,
        build_serving_session,
    )
    from repro.core.graph import DynamicGraph

    ap = argparse.ArgumentParser(
        description="Open-loop multi-tenant CQP load generator"
    )
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--v", type=int, default=256)
    ap.add_argument("--e", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--arrivals", type=int, default=24)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="per-tenant submissions/sec")
    ap.add_argument("--budget-bytes", type=int, default=None,
                    help="per-tenant isolated byte budget")
    ap.add_argument("--quota-rate", type=float, default=None,
                    help="per-tenant admitted-updates/sec token-bucket rate")
    ap.add_argument("--engine", default="dense", choices=["dense", "host"])
    ap.add_argument("--max-iters", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-admission", action="store_true")
    ap.add_argument("--out", default=os.path.join("reports", "serving"),
                    help="output directory for the JSON report")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    if args.smoke:
        args.v, args.e = min(args.v, 64), min(args.e, 256)
        args.arrivals = min(args.arrivals, 8)
        args.max_iters = min(args.max_iters, 16)

    edges = powerlaw_graph(args.v, args.e, seed=args.seed)
    initial, pool = split_90_10(edges, seed=args.seed)
    streams = tenant_update_streams(
        initial, args.v, args.tenants,
        num_batches=args.arrivals, batch_size=args.batch,
        insert_pool=pool, delete_fraction=0.1, seed=args.seed + 1,
    )
    updates_by_tenant = {
        tid: [u for b in batches for u in b]
        for tid, batches in streams.items()
    }

    ladder = GovernorConfig(representation="prob")
    session = build_serving_session(
        DynamicGraph(args.v, initial, capacity=len(edges) * 4 + 64),
        ladder=ladder,
        engine=args.engine,
        batch_capacity=args.batch,
        min_slots=args.tenants,
    )
    server = CQPServer(
        session,
        config=ServerConfig(
            chunk_updates=args.batch,
            admission=not args.no_admission,
            slo=SLOConfig(backlog_high_updates=8 * args.batch),
            drop_ladder=ladder,
        ),
    )

    async def run() -> dict:
        async with server:
            loads, tickets = [], {}
            for i in range(args.tenants):
                tid = f"tenant{i}"
                spec = TenantSpec(
                    tenant_id=tid,
                    priority=i + 1,
                    budget_bytes=args.budget_bytes,
                    rate_per_s=args.quota_rate,
                )
                server.add_tenant(spec)
                tickets[tid] = await server.register_query(
                    tid, plan.sssp(i % args.v, max_iters=args.max_iters)
                )
                loads.append(
                    TenantLoad(
                        spec=spec,
                        arrival_rate_per_s=args.rate,
                        updates_per_arrival=args.batch,
                        arrivals=args.arrivals,
                    )
                )
            return await run_load(
                server, loads, tickets, updates_by_tenant, seed=args.seed
            )

    report = asyncio.run(run())
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "loadgen.json")
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, default=str)
    print("loadgen JSON:", json.dumps({
        "wall_s": round(report["wall_s"], 3),
        "epochs": report["epochs"],
        "covered_updates": report["covered_updates"],
        "rejection_rates": {
            t: round(r["rejection_rate"], 4)
            for t, r in report["tenants"].items()
        },
        "read_p99_ms": report["read_latency"]["p99_ms"],
    }))
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
