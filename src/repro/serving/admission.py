"""SLO-based admission control with a graceful-degradation ladder.

The serving loop feeds the controller one observation per epoch (per-chunk
maintenance wall time, governor byte headroom, ingest backlog); the
controller keeps a sliding p99 window plus EWMAs of both signals and
classifies the tier as calm or overloaded.  Requests are then **admitted**,
**queued**, or **rejected**:

* update submissions — admitted into the ingest queue, or rejected when the
  tier is shedding (rate-quota rejections are the tenant's own contract and
  can fire any time);
* query registrations — admitted at the next epoch boundary, queued while
  the tier is overloaded (re-evaluated every epoch), rejected while
  shedding.

**Degrade before rejecting.**  An overloaded epoch first escalates the
lowest-priority tenant one rung down the drop-policy ladder
(:meth:`TenantRegistry.degrade` — sheds stored diffs, answers stay exact
via repair-on-access).  Only when *every* tenant sits at the top rung does
the controller enter shedding mode and start rejecting work — so the
action log always shows the full degradation ladder before the first
overload rejection.  Calm epochs past the cooldown undo degradations one
rung at a time (LIFO); shedding ends only once the overload stays clear
through the cooldown (hysteresis — an instant clear would re-admit a burst
that immediately re-overloads and the oscillation inflates read tails).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.serving.tenants import TenantRegistry


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Admission thresholds.

    ``p99_target_ms`` is the maintenance-latency SLO (None disables the
    latency trigger); ``backlog_high_updates`` is the ingest-queue
    high-water mark; ``min_headroom_frac`` the governor-headroom floor
    (0 disables it — the right value when the session runs no byte
    budget)."""

    p99_target_ms: float | None = None
    backlog_high_updates: int = 64
    min_headroom_frac: float = 0.0
    latency_window: int = 64
    ewma_alpha: float = 0.2
    cooldown_epochs: int = 2

    def __post_init__(self):
        if self.p99_target_ms is not None and self.p99_target_ms <= 0:
            raise ValueError("p99_target_ms must be positive (or None)")
        if not (0.0 <= self.min_headroom_frac < 1.0):
            raise ValueError("min_headroom_frac must be in [0, 1)")
        if self.latency_window < 1:
            raise ValueError("latency_window must be >= 1")
        if not (0.0 < self.ewma_alpha <= 1.0):
            raise ValueError("ewma_alpha must be in (0, 1]")


@dataclasses.dataclass(frozen=True)
class Decision:
    action: str  # "admit" | "queue" | "reject"
    reason: str

    @property
    def admitted(self) -> bool:
        return self.action == "admit"


ADMIT = Decision("admit", "ok")


class AdmissionRejected(Exception):
    """A submission or registration the controller refused.

    Deliberately NOT a ``RuntimeError`` — the serving loop treats
    ``RuntimeError`` as a recoverable engine fault, and a policy rejection
    must never trigger checkpoint restore."""

    def __init__(self, decision: Decision) -> None:
        super().__init__(f"{decision.action}: {decision.reason}")
        self.decision = decision


class AdmissionController:
    """One admission state machine per serving loop."""

    def __init__(self, cfg: SLOConfig, registry: TenantRegistry) -> None:
        self.cfg = cfg
        self.registry = registry
        self._window: deque[float] = deque(maxlen=cfg.latency_window)
        self.latency_ewma_s: float | None = None
        self.headroom_ewma: float | None = None
        self.backlog = 0
        self.shedding = False
        self._calm_epochs = 0
        self.epochs = 0
        self.rejected_updates = 0
        self.rejected_registers = 0
        self.straggler_sheds = 0

    # ------------------------------------------------------------- signals
    def observe_epoch(
        self,
        maintain_s: float,
        *,
        headroom_frac: float | None = None,
        backlog_updates: int = 0,
    ) -> None:
        """Fold one epoch's signals in (called by the loop after every
        applied chunk, before :meth:`regulate`)."""
        a = self.cfg.ewma_alpha
        self._window.append(float(maintain_s))
        self.latency_ewma_s = (
            maintain_s
            if self.latency_ewma_s is None
            else (1 - a) * self.latency_ewma_s + a * maintain_s
        )
        if headroom_frac is not None:
            self.headroom_ewma = (
                headroom_frac
                if self.headroom_ewma is None
                else (1 - a) * self.headroom_ewma + a * headroom_frac
            )
        self.backlog = int(backlog_updates)
        self.epochs += 1

    def p99_ms(self) -> float:
        if not self._window:
            return 0.0
        return float(np.percentile(np.asarray(self._window), 99.0) * 1e3)

    def overloaded(self) -> bool:
        lat = (
            self.cfg.p99_target_ms is not None
            and self.p99_ms() > self.cfg.p99_target_ms
        )
        backlog = self.backlog > self.cfg.backlog_high_updates
        headroom = (
            self.cfg.min_headroom_frac > 0.0
            and self.headroom_ewma is not None
            and self.headroom_ewma < self.cfg.min_headroom_frac
        )
        return lat or backlog or headroom

    # ------------------------------------------------------------ decisions
    def admit_updates(
        self, tenant_id: str, n: int, *, backlog_updates: int | None = None
    ) -> Decision:
        """Admission for one update submission of ``n`` updates.

        ``backlog_updates`` is the live ingest-queue depth at submission
        time.  When the ladder is already fully degraded and the live
        backlog breaches the high-water mark, shedding re-engages
        immediately — between epoch boundaries — so a recovery probe after
        a calm spell admits at most one high-water mark's worth of work
        before the gate closes again (an unbounded probe burst would
        inflate the admitted tenants' read tails)."""
        st = self.registry.require(tenant_id)
        st.submitted_updates += n
        if not self.registry.allow_rate(tenant_id, n):
            st.rejected_updates += n
            self.rejected_updates += n
            return Decision("reject", "rate quota")
        if (
            not self.shedding
            and backlog_updates is not None
            and backlog_updates > self.cfg.backlog_high_updates
            and self.registry.fully_degraded()
        ):
            self.shedding = True
            self._calm_epochs = 0
        if self.shedding:
            st.rejected_updates += n
            self.rejected_updates += n
            return Decision("reject", "overload shed")
        st.admitted_updates += n
        return ADMIT

    def admit_register(self, tenant_id: str) -> Decision:
        """Admission for one query registration."""
        st = self.registry.require(tenant_id)
        if self.shedding:
            st.rejected_registers += 1
            self.rejected_registers += 1
            return Decision("reject", "overload shed")
        if self.overloaded():
            return Decision("queue", "overloaded")
        return ADMIT

    # --------------------------------------------------------------- ladder
    def regulate(self, session) -> list[dict]:
        """One per-epoch control pass: degrade under overload (one rung per
        epoch), shed only past the ladder, restore when calm."""
        actions: list[dict] = []
        if self.overloaded():
            self._calm_epochs = 0
            target = self.registry.next_degradable()
            if target is not None:
                action = self.registry.degrade(
                    session, target.spec.tenant_id, "admission overload"
                )
                if action is not None:
                    actions.append(action)
            else:
                # ladder exhausted: now — and only now — reject new work
                self.shedding = True
        else:
            self._calm_epochs += 1
            # hysteresis: shedding persists through the cooldown — the
            # drained backlog must HOLD calm before new work is re-admitted.
            # Clearing the moment one epoch looks calm re-admits a burst
            # that immediately re-overloads, and the resulting backlog
            # oscillation inflates the admitted tenants' read tails.
            if self._calm_epochs > self.cfg.cooldown_epochs:
                self.shedding = False
                action = self.registry.restore_one(session, "calm")
                if action is not None:
                    actions.append(action)
                    self._calm_epochs = 0
        return actions

    def force_shed(self, session, reason: str) -> dict | None:
        """An out-of-band escalation (the straggler detector's hook): one
        ladder step immediately, shedding if the ladder is exhausted."""
        self.straggler_sheds += 1
        target = self.registry.next_degradable()
        if target is None:
            self.shedding = True
            return None
        return self.registry.degrade(session, target.spec.tenant_id, reason)

    # ------------------------------------------------------------ reporting
    def snapshot(self) -> dict:
        return {
            "epochs": self.epochs,
            "p99_ms": self.p99_ms(),
            "latency_ewma_ms": (
                None
                if self.latency_ewma_s is None
                else self.latency_ewma_s * 1e3
            ),
            "headroom_ewma": self.headroom_ewma,
            "backlog": self.backlog,
            "shedding": self.shedding,
            "calm_epochs": self._calm_epochs,
            "rejected_updates": self.rejected_updates,
            "rejected_registers": self.rejected_registers,
            "straggler_sheds": self.straggler_sheds,
            "p99_target_ms": self.cfg.p99_target_ms,
        }

    def state_dict(self) -> dict:
        return {
            "window": list(self._window),
            "latency_ewma_s": self.latency_ewma_s,
            "headroom_ewma": self.headroom_ewma,
            "shedding": self.shedding,
            "calm_epochs": self._calm_epochs,
            "epochs": self.epochs,
            "rejected_updates": self.rejected_updates,
            "rejected_registers": self.rejected_registers,
            "straggler_sheds": self.straggler_sheds,
        }

    def load_state(self, state: dict) -> None:
        self._window = deque(
            (float(x) for x in state["window"]), maxlen=self.cfg.latency_window
        )
        self.latency_ewma_s = state["latency_ewma_s"]
        self.headroom_ewma = state["headroom_ewma"]
        self.shedding = bool(state["shedding"])
        self._calm_epochs = int(state["calm_epochs"])
        self.epochs = int(state["epochs"])
        self.rejected_updates = int(state["rejected_updates"])
        self.rejected_registers = int(state["rejected_registers"])
        self.straggler_sheds = int(state["straggler_sheds"])
