"""Shared serving metrics: percentile summaries and per-phase breakdowns.

Every serving surface in the repo reports the same latency shape — p50/p99
(and now p999) percentiles over a sample list, plus a per-phase breakdown of
where a serving loop spent its time (ingest / maintain / checkpoint / …).
Before this module the percentile math and JSON assembly lived duplicated in
``launch/cqp_serve.py``; both that driver and the async serving tier
(:mod:`repro.serving.server`) now report through here, so the two emit
field-compatible JSON.
"""

from __future__ import annotations

import numpy as np

from repro.obs import metrics as obs_metrics

# the serving tier's canonical percentile set
PERCENTILES: tuple[float, ...] = (50.0, 99.0, 99.9)


def summarize_samples(
    samples, *, scale: float = 1.0, suffix: str = ""
) -> dict:
    """Percentile summary of a sample list.

    Returns ``{count, p50, p99, p999, mean, max}`` (keys carry ``suffix``;
    values are multiplied by ``scale``).  An empty sample list yields a
    zeroed summary rather than NaNs, so reports stay JSON-clean when a
    phase never ran.
    """
    arr = np.asarray(list(samples), dtype=np.float64)
    if arr.size == 0:
        vals = {"p50": 0.0, "p99": 0.0, "p999": 0.0, "mean": 0.0, "max": 0.0}
    else:
        p50, p99, p999 = (float(np.percentile(arr, q)) for q in PERCENTILES)
        vals = {
            "p50": p50,
            "p99": p99,
            "p999": p999,
            "mean": float(arr.mean()),
            "max": float(arr.max()),
        }
    out = {"count": int(arr.size)}
    out.update({k + suffix: v * scale for k, v in vals.items()})
    return out


def summarize_latency_s(samples_s) -> dict:
    """Latency summary of samples in seconds, reported in milliseconds:
    ``{count, p50_ms, p99_ms, p999_ms, mean_ms, max_ms}``."""
    return summarize_samples(samples_s, scale=1e3, suffix="_ms")


class PhaseRecorder:
    """Per-phase latency samples for one serving loop.

    Phases are free-form strings (the drivers use ``ingest`` / ``maintain``
    / ``checkpoint`` / ``register`` / ``deregister`` / ``read``); each
    :meth:`record` appends one wall-time sample.  :meth:`summary` renders
    the per-phase percentile breakdown plus each phase's total seconds —
    the JSON block both serving drivers attach as ``"phases"``.
    """

    def __init__(self) -> None:
        self._samples: dict[str, list[float]] = {}
        # the recorder is an obs-registry consumer: every sample also lands
        # in one shared histogram (labelled by phase), so the Prometheus /
        # JSON-snapshot surfaces see the same distribution this summary
        # renders as percentiles
        self._hist = obs_metrics.get_registry().histogram(
            "serving_phase_seconds", "serving-loop phase wall time"
        )

    def record(self, phase: str, seconds: float) -> None:
        self._samples.setdefault(phase, []).append(float(seconds))
        self._hist.observe(float(seconds), phase=phase)

    def extend(self, phase: str, seconds_list) -> None:
        seconds_list = [float(s) for s in seconds_list]
        self._samples.setdefault(phase, []).extend(seconds_list)
        for s in seconds_list:
            self._hist.observe(s, phase=phase)

    def samples(self, phase: str) -> list[float]:
        return list(self._samples.get(phase, ()))

    def total_s(self, phase: str) -> float:
        return float(sum(self._samples.get(phase, ())))

    def summary(self) -> dict:
        return {
            phase: {
                **summarize_latency_s(samples),
                "total_s": float(sum(samples)),
            }
            for phase, samples in sorted(self._samples.items())
        }
