"""Async multi-tenant CQP serving tier (DESIGN.md §14).

A long-running asyncio front end over :class:`repro.core.session.CQPSession`:

* :mod:`repro.serving.server` — the ingest loop (batched δE folds through
  ``apply_updates_batched``) with snapshot-consistent epoch reads, wired to
  the recovery supervisor, straggler detector, and checkpoint/restore;
* :mod:`repro.serving.tenants` — per-tenant registries: query tickets,
  isolated governor byte budgets, and rate quotas;
* :mod:`repro.serving.admission` — SLO-based admission control with a
  graceful-degradation ladder (degrade low-priority tenants before
  rejecting anyone);
* :mod:`repro.serving.loadgen` — multi-tenant open-loop load generator;
* :mod:`repro.serving.metrics` — shared latency/percentile reporting.
"""

# Lazy re-exports (PEP 562): importing `repro.serving.metrics` or
# `.tenants` must NOT pull in `.server` (whose CQPSession import
# initializes jax — launch drivers with --emulate-devices import the
# light modules before the backend may exist).
import importlib

_EXPORTS = {
    "AdmissionController": "admission",
    "AdmissionRejected": "admission",
    "Decision": "admission",
    "SLOConfig": "admission",
    "PhaseRecorder": "metrics",
    "summarize_latency_s": "metrics",
    "CQPServer": "server",
    "ReadResult": "server",
    "ServerConfig": "server",
    "SubmitResult": "server",
    "build_serving_session": "server",
    "QueryTicket": "tenants",
    "TenantRegistry": "tenants",
    "TenantSpec": "tenants",
}


def __getattr__(name: str):
    if name in _EXPORTS:
        module = importlib.import_module(f"repro.serving.{_EXPORTS[name]}")
        value = getattr(module, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module 'repro.serving' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))


__all__ = [
    "AdmissionController",
    "AdmissionRejected",
    "CQPServer",
    "Decision",
    "PhaseRecorder",
    "QueryTicket",
    "ReadResult",
    "SLOConfig",
    "ServerConfig",
    "SubmitResult",
    "TenantRegistry",
    "TenantSpec",
    "build_serving_session",
    "summarize_latency_s",
]
