"""The async multi-tenant CQP serving loop (DESIGN.md §14).

One :class:`CQPServer` owns one :class:`~repro.core.session.CQPSession` and
multiplexes many tenants over it:

* **Single-writer ingest.**  Admitted δE updates land in an in-memory queue;
  an asyncio ingest loop drains them into fixed-size chunks and folds each
  through ``apply_updates_batched`` on an executor thread — the event loop
  (and every reader coroutine) stays responsive during the fold.
* **Snapshot-consistent epoch reads.**  After every applied chunk the loop
  refreshes an *epoch view*: owned copies of each query's answers
  (``session.answers_snapshot()``).  Reads serve from the view, never the
  live engine, so a reader can never observe a half-applied chunk.
* **Read-your-writes freshness.**  Each admitted submission advances its
  tenant's watermark (admitted-stream sequence number).  ``read`` waits
  until the covered sequence reaches the watermark — or times out and
  serves the current epoch marked ``fresh=False``.  Under admission control
  the backlog is bounded, so reads are fast *and* fresh; the no-admission
  control run lets the backlog grow without bound and reads degrade into
  stale timeouts (the overload experiment in ``benchmarks/fig_serving_slo``).
* **Admission + tenancy.**  Per-epoch maintenance latency, governor
  headroom, and backlog feed :class:`AdmissionController`; per-tenant byte
  budgets are enforced by :meth:`TenantRegistry.enforce_budgets`.  A
  straggler event escalates the degradation ladder out-of-band (exactly
  once per event — the detector's policy hook is registered once).
* **Fault recovery.**  Engine faults inside a chunk apply restore the
  latest checkpoint through :class:`RecoverySupervisor` (or rebuild from
  genesis), replay the post-checkpoint control ops (register/deregister)
  and δE chunks from the in-memory logs, and resume — registered tenants
  and tickets survive; answers are bit-identical to an uninterrupted run.

``python -m repro.serving.server`` runs a deterministic scripted scenario
(the CI smoke: register N tenants, stream updates, optionally inject one
fault mid-stream, restore, verify exactness against a scratch oracle,
deregister everyone) and prints a JSON report.
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import time
from collections import deque
from typing import Callable

import numpy as np

from repro.core import dropping as dr
from repro.core import plan as qp
from repro.core.graph import DynamicGraph
from repro.core.governor import GovernorConfig
from repro.core.session import CQPSession
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime.fault import FaultPolicy, InjectedFault
from repro.runtime.recovery import RecoverySupervisor
from repro.runtime.straggler import StragglerDetector
from repro.serving.admission import (
    ADMIT,
    AdmissionController,
    AdmissionRejected,
    Decision,
    SLOConfig,
)
from repro.serving.metrics import PhaseRecorder, summarize_latency_s
from repro.serving.tenants import QueryTicket, TenantRegistry, TenantSpec


# --------------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Serving-loop knobs."""

    chunk_updates: int = 32  # ingest chunk size (and engine batch size)
    flush_interval_s: float = 0.0  # linger to let a partial chunk fill
    read_timeout_s: float = 2.0  # read-your-writes barrier timeout
    admission: bool = True  # False = control run (no admission/shedding)
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)
    drop_ladder: GovernorConfig | None = None  # degradation ladder
    checkpoint_every: int = 0  # chunks between checkpoints (0 = never)
    checkpoint_keep: int = 3
    max_restarts: int = 5
    backoff_s: float = 0.0
    straggler_threshold: float = 4.0
    straggler_warmup: int = 3
    # observability: periodic scrape of the session into the obs metrics
    # registry every `obs_every` epochs, with optional file sinks — the
    # trace flush rewrites `trace_out` (Chrome-trace JSON) and the metrics
    # scrape rewrites `metrics_out` (registry JSON snapshot) in place, so
    # the files are valid mid-run and final on stop()
    obs_every: int = 8
    trace_out: str | None = None
    metrics_out: str | None = None

    def __post_init__(self):
        if self.chunk_updates < 1:
            raise ValueError("chunk_updates must be >= 1")
        if self.read_timeout_s <= 0:
            raise ValueError("read_timeout_s must be positive")
        if self.obs_every < 1:
            raise ValueError("obs_every must be >= 1")


@dataclasses.dataclass(frozen=True)
class SubmitResult:
    admitted: bool
    reason: str
    watermark: int  # the tenant's read-your-writes barrier after this submit


@dataclasses.dataclass(frozen=True)
class ReadResult:
    values: np.ndarray  # an owned epoch-view copy — never the live engine
    epoch: int
    covered: int  # admitted-stream prefix the view reflects
    required: int  # the tenant watermark this read targeted
    fresh: bool  # covered >= required (False = barrier timed out)
    wait_s: float


def build_serving_session(
    graph: DynamicGraph,
    *,
    ladder: GovernorConfig | None = None,
    engine: str = "dense",
    **kw,
) -> CQPSession:
    """A ``CQPSession`` provisioned for serving.

    Dense engines can only *enable* dropping on a query whose DroppedVT
    representation was provisioned at build time — so a serving session
    (whose admission ladder degrades queries mid-stream) must be built with
    the ladder's p=0 representation installed.  This helper mirrors what
    ``budget_bytes`` does for the global governor, without attaching one
    (the per-tenant mini-governors and the global governor would fight over
    the same DropParams rows)."""
    ladder = ladder or GovernorConfig(representation="prob")
    if engine == "dense" and kw.get("drop") is None:
        kw["drop"] = ladder.representation_config()
    return CQPSession(graph, engine=engine, **kw)


# --------------------------------------------------------------------- server
class CQPServer:
    """Async serving front end over one ``CQPSession``.

    Not thread-safe: all public coroutines must run on the event loop that
    ``start`` was called from (the engine itself runs on an executor
    thread, but all bookkeeping is loop-confined)."""

    def __init__(
        self,
        session: CQPSession,
        *,
        config: ServerConfig | None = None,
        session_factory: Callable[[], CQPSession] | None = None,
        checkpoint_dir: str | None = None,
        mesh=None,
        fault_injector: Callable[[int], None] | None = None,
        delay_injector: Callable[[int], float] | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ) -> None:
        self.config = config or ServerConfig()
        self.session = session
        self.session_factory = session_factory
        self.mesh = mesh if mesh is not None else session.mesh
        self.clock = clock
        self.fault_injector = fault_injector
        self.delay_injector = delay_injector

        spec = getattr(session, "_drop_spec", None)
        self._can_degrade = (
            session.engine_kind != "dense"
            or (spec is not None and spec.enabled())
        )
        if self.config.admission and not self._can_degrade:
            raise ValueError(
                "admission control degrades queries mid-stream; build the "
                "dense session with a DroppedVT representation provisioned "
                "(repro.serving.build_serving_session)"
            )
        ladder = self.config.drop_ladder or GovernorConfig(
            representation=(spec.mode if self._can_degrade and spec else "prob")
        )
        if (
            self._can_degrade
            and spec is not None
            and spec.enabled()
            and ladder.representation != spec.mode
        ):
            ladder = dataclasses.replace(ladder, representation=spec.mode)
        self.registry = TenantRegistry(ladder)
        self.admission = AdmissionController(self.config.slo, self.registry)
        self.metrics = PhaseRecorder()
        self.straggler = StragglerDetector(
            threshold=self.config.straggler_threshold,
            warmup=self.config.straggler_warmup,
        )
        # the detector fires every registered policy once per event; the
        # server registers exactly ONE — double-registration would walk the
        # ladder twice per straggler
        self.straggler.on_straggler(self._on_straggler)

        policy = FaultPolicy(
            max_restarts=self.config.max_restarts,
            checkpoint_every=self.config.checkpoint_every,
            backoff_s=self.config.backoff_s,
        )
        self.supervisor: RecoverySupervisor | None = None
        if checkpoint_dir is not None:
            self.supervisor = RecoverySupervisor(
                checkpoint_dir,
                policy,
                keep=self.config.checkpoint_keep,
                restore_fn=self._restore_fn,
                straggler=self.straggler,
            )
        else:
            self._policy = policy
            self._restarts = 0
        session.attach_runtime(
            straggler=self.straggler, supervisor=self.supervisor
        )

        # ingest state (loop-confined)
        self._queue: deque = deque()  # admitted updates not yet applied
        self._control: deque = deque()  # boundary ops: (kind, payload, future)
        self._chunk_log: list[list] = []  # applied chunks, in order
        self._control_log: list[dict] = []  # register/deregister replay log
        self._plans: dict[int, qp.QueryPlan] = {}  # ticket_id → plan
        self._pending_registers: deque = deque()  # queued (overload) registers
        self._admitted_total = 0  # admitted-stream sequence
        self._covered = 0  # applied prefix of the admitted stream
        self._epoch = 0
        self._view: dict[int, np.ndarray] = {}  # ticket_id → answers copy
        self._waiters: list[tuple[int, asyncio.Future]] = []
        self._wake: asyncio.Event | None = None
        self._idle: asyncio.Event | None = None
        self._stopping = False
        self._failure: BaseException | None = None
        self._task: asyncio.Task | None = None
        self.faults = 0
        self._read_wait: dict[str, list[float]] = {}
        self._read_lag: dict[str, list[int]] = {}
        self._stale_reads: dict[str, int] = {}

    # -------------------------------------------------------------- lifecycle
    async def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("server already started")
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._idle.set()
        self._task = asyncio.create_task(self._ingest_loop(), name="cqp-ingest")

    async def stop(self) -> None:
        """Drain the queue, stop the loop, finish in-flight checkpoints."""
        if self._task is None:
            return
        self._stopping = True
        self._wake.set()
        try:
            await self._task
        finally:
            self._task = None
        if self.supervisor is not None:
            self.supervisor.manager.wait()
        self._obs_scrape()  # final flush: sinks reflect the drained state
        if self._failure is not None:
            raise self._failure

    async def drain(self) -> None:
        """Wait until every admitted update and control op is applied."""
        self._raise_if_failed()
        while self._queue or self._control or not self._idle.is_set():
            await self._idle.wait()
            self._raise_if_failed()

    async def __aenter__(self) -> "CQPServer":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        if exc[0] is None:
            await self.stop()
        else:  # don't mask the body's exception with a drain failure
            self._stopping = True
            if self._wake is not None:
                self._wake.set()
            if self._task is not None:
                await asyncio.gather(self._task, return_exceptions=True)
                self._task = None

    def _raise_if_failed(self) -> None:
        if self._failure is not None:
            raise self._failure

    # ---------------------------------------------------------------- tenancy
    def add_tenant(self, spec: TenantSpec):
        if spec.budget_bytes is not None:
            if getattr(self.session, "_governor", None) is not None:
                raise ValueError(
                    "tenant byte budgets and a session-global MemoryGovernor "
                    "both rewrite drop policies and would fight; use one or "
                    "the other (the governor can still feed the admission "
                    "headroom signal without tenant budgets)"
                )
            if not self._can_degrade:
                raise ValueError(
                    "tenant budget_bytes needs a DroppedVT representation "
                    "provisioned (repro.serving.build_serving_session)"
                )
        return self.registry.add(spec)

    async def remove_tenant(self, tenant_id: str) -> None:
        """Deregister every live query of the tenant (at epoch boundaries —
        never while a chunk is folding in), then drop it."""
        st = self.registry.require(tenant_id)
        for ticket_id in list(st.qids):
            await self.deregister_query(QueryTicket(ticket_id, tenant_id))
        self.registry.remove(tenant_id)

    def _detach_ticket(self, ticket: QueryTicket) -> int:
        qid = self.registry.qid_of(ticket)
        handle = next(h for h in self.session.handles() if h.qid == qid)
        t0 = self.clock()
        freed = self.session.deregister(handle)
        self.metrics.record("deregister", self.clock() - t0)
        self.registry.detach(ticket)
        self._plans.pop(ticket.ticket_id, None)
        self._view.pop(ticket.ticket_id, None)
        self._control_log.append(
            {"cursor": len(self._chunk_log), "kind": "deregister",
             "ticket_id": ticket.ticket_id, "tenant_id": ticket.tenant_id,
             "qid": qid}
        )
        return freed

    # ----------------------------------------------------------- registration
    async def register_query(
        self, tenant_id: str, plan: qp.QueryPlan
    ) -> QueryTicket:
        """Admit (or queue, or reject) one query registration.

        Raises :class:`AdmissionRejected` when the tier is shedding.  A
        queued registration resolves at the first calm epoch boundary (or
        rejects if shedding starts first)."""
        self._raise_if_failed()
        self.registry.require(tenant_id)
        decision = (
            self.admission.admit_register(tenant_id)
            if self.config.admission
            else ADMIT
        )
        obs_trace.instant(
            "register_query",
            "admission",
            pid="serving",
            tid=tenant_id,
            tenant=tenant_id,
            action=decision.action,
            reason=decision.reason,
        )
        if decision.action == "reject":
            raise AdmissionRejected(decision)
        fut = asyncio.get_running_loop().create_future()
        if decision.action == "queue":
            self._pending_registers.append((tenant_id, plan, fut))
        else:
            self._control.append(("register", (tenant_id, plan), fut))
        self._wake.set()
        self._idle.clear()
        return await fut

    async def deregister_query(self, ticket: QueryTicket) -> int:
        """Retire a ticket's query at the next epoch boundary; returns the
        accounted bytes released."""
        self._raise_if_failed()
        self.registry.qid_of(ticket)  # validate now, not at the boundary
        fut = asyncio.get_running_loop().create_future()
        self._control.append(("deregister", ticket, fut))
        self._wake.set()
        self._idle.clear()
        return await fut

    # --------------------------------------------------------------- ingest
    def submit(self, tenant_id: str, updates) -> SubmitResult:
        """Submit δE updates for one tenant (synchronous — admission is a
        pure in-memory decision).  Admitted updates advance the tenant's
        read-your-writes watermark."""
        self._raise_if_failed()
        updates = list(updates)
        st = self.registry.require(tenant_id)
        if self.config.admission:
            decision = self.admission.admit_updates(
                tenant_id, len(updates), backlog_updates=len(self._queue)
            )
        else:
            st.submitted_updates += len(updates)
            st.admitted_updates += len(updates)
            decision = ADMIT
        obs_trace.instant(
            "submit",
            "admission",
            pid="serving",
            tid=tenant_id,
            tenant=tenant_id,
            num_updates=len(updates),
            admitted=decision.admitted,
            reason=decision.reason,
        )
        if not decision.admitted:
            return SubmitResult(False, decision.reason, st.watermark)
        self._admitted_total += len(updates)
        st.watermark = self._admitted_total
        self._queue.extend(updates)
        if self._wake is not None:
            self._wake.set()
            self._idle.clear()
        return SubmitResult(True, decision.reason, st.watermark)

    # ----------------------------------------------------------------- reads
    async def read(
        self,
        ticket: QueryTicket,
        *,
        timeout_s: float | None = None,
        require: int | None = None,
    ) -> ReadResult:
        """Serve the ticket's answers from the epoch view.

        Waits (up to ``timeout_s``) until the applied prefix covers the
        tenant's watermark — read-your-writes.  On timeout the current
        epoch is served anyway, marked ``fresh=False``."""
        self._raise_if_failed()
        t0 = self.clock()
        st = self.registry.require(ticket.tenant_id)
        required = st.watermark if require is None else int(require)
        if self._covered < required:
            fut = asyncio.get_running_loop().create_future()
            self._waiters.append((required, fut))
            limit = (
                self.config.read_timeout_s if timeout_s is None else timeout_s
            )
            try:
                await asyncio.wait_for(fut, limit)
            except asyncio.TimeoutError:
                pass
        self._raise_if_failed()
        values = self._view.get(ticket.ticket_id)
        if values is None:
            raise ValueError(
                f"ticket {ticket.ticket_id} has no registered query"
            )
        wait_s = self.clock() - t0
        covered = self._covered
        fresh = covered >= required
        tid = ticket.tenant_id
        self.metrics.record("read", wait_s)
        self._read_wait.setdefault(tid, []).append(wait_s)
        self._read_lag.setdefault(tid, []).append(max(required - covered, 0))
        if not fresh:
            self._stale_reads[tid] = self._stale_reads.get(tid, 0) + 1
        return ReadResult(
            values=values, epoch=self._epoch, covered=covered,
            required=required, fresh=fresh, wait_s=wait_s,
        )

    # ------------------------------------------------------------ the loop
    async def _ingest_loop(self) -> None:
        try:
            while True:
                await self._wait_for_work()
                if (
                    self._stopping
                    and not self._queue
                    and not self._control
                ):
                    break
                t0 = self.clock()
                await self._run_control_ops()
                chunk = [
                    self._queue.popleft()
                    for _ in range(
                        min(len(self._queue), self.config.chunk_updates)
                    )
                ]
                self.metrics.record("ingest", self.clock() - t0)
                if chunk:
                    await self._apply_chunk(chunk)
                if not self._queue and not self._control:
                    self._idle.set()
        except BaseException as e:
            self._failure = e
            self._fail_waiters(e)
            self._idle.set()
            raise
        finally:
            self._idle.set()

    async def _wait_for_work(self) -> None:
        while not self._stopping and not self._queue and not self._control:
            self._idle.set()
            self._wake.clear()
            await self._wake.wait()
        if (
            not self._stopping
            and self.config.flush_interval_s > 0
            and not self._control
            and 0 < len(self._queue) < self.config.chunk_updates
        ):
            await asyncio.sleep(self.config.flush_interval_s)

    async def _run_control_ops(self) -> None:
        loop = asyncio.get_running_loop()
        while self._control:
            kind, payload, fut = self._control.popleft()
            try:
                if kind == "register":
                    tenant_id, plan = payload
                    t0 = self.clock()
                    handle = await loop.run_in_executor(
                        None, self.session.register, plan
                    )
                    self.metrics.record("register", self.clock() - t0)
                    ticket = self.registry.new_ticket(tenant_id)
                    base = plan.drop if plan.drop is not None else dr.DropConfig()
                    self.registry.attach(ticket, handle.qid, base)
                    self._plans[ticket.ticket_id] = plan
                    self._control_log.append(
                        {"cursor": len(self._chunk_log), "kind": "register",
                         "ticket_id": ticket.ticket_id,
                         "tenant_id": tenant_id, "qid": handle.qid}
                    )
                    st = self.registry.require(tenant_id)
                    if st.level > 0:  # join the tenant at its current rung
                        self.registry._apply_level(self.session, st, st.level)
                    # the registration sweep computed answers — view them now
                    self._view[ticket.ticket_id] = np.array(
                        self.session.answers(handle), copy=True
                    )
                    if not fut.done():
                        fut.set_result(ticket)
                elif kind == "deregister":
                    freed = self._detach_ticket(payload)
                    if not fut.done():
                        fut.set_result(freed)
                else:  # pragma: no cover - defensive
                    raise ValueError(f"unknown control op {kind!r}")
            except AdmissionRejected as e:
                if not fut.done():
                    fut.set_exception(e)
            except Exception as e:  # noqa: BLE001 - surface to the caller
                if not fut.done():
                    fut.set_exception(e)

    def _apply_sync(self, chunk: list, k: int) -> None:
        if self.delay_injector is not None:
            delay = self.delay_injector(k)
            if delay:
                time.sleep(delay)
        self.session.apply_updates_batched(
            chunk, batch_size=self.config.chunk_updates
        )

    async def _apply_chunk(self, chunk: list) -> None:
        loop = asyncio.get_running_loop()
        k = len(self._chunk_log)
        while True:
            try:
                if self.fault_injector is not None:
                    self.fault_injector(k)
                t0 = self.clock()
                await loop.run_in_executor(None, self._apply_sync, chunk, k)
                maintain_s = self.clock() - t0
                break
            except (InjectedFault, RuntimeError) as e:
                await self._recover(e, k)
        self._chunk_log.append(chunk)
        self._covered += len(chunk)
        self._epoch += 1
        self.metrics.record("maintain", maintain_s)
        self._refresh_view()
        self.straggler.observe(k, maintain_s)
        if self.config.admission:
            self.admission.observe_epoch(
                maintain_s,
                headroom_frac=self._headroom_frac(),
                backlog_updates=len(self._queue),
            )
            self.admission.regulate(self.session)
            self._settle_pending_registers()
        self.registry.enforce_budgets(self.session)
        self._notify_waiters()
        await self._maybe_checkpoint()
        if self._epoch % max(int(self.config.obs_every), 1) == 0:
            self._obs_scrape()

    def _obs_scrape(self) -> None:
        """Periodic observability tick: publish the session into the obs
        registry, then rewrite the configured file sinks (per-epoch trace
        flush + metrics snapshot).  Sink errors never take down serving."""
        try:
            self.session.publish_metrics()
            reg = obs_metrics.get_registry()
            reg.gauge("serving_epoch", "applied epoch counter").set(self._epoch)
            reg.gauge("serving_queue_depth", "admitted updates not yet applied").set(
                len(self._queue)
            )
            reg.gauge(
                "serving_covered_updates", "applied prefix of the admitted stream"
            ).set(self._covered)
            if self.config.metrics_out:
                with open(self.config.metrics_out, "w") as f:
                    json.dump(reg.snapshot(), f, indent=1)
            if self.config.trace_out:
                obs_trace.get_tracer().export(self.config.trace_out)
        except Exception:  # pragma: no cover - diagnostics must not kill serving
            pass

    def _headroom_frac(self) -> float | None:
        governor = getattr(self.session, "_governor", None)
        if governor is None:
            return None
        return governor.headroom_fraction(self.session)

    def _refresh_view(self) -> None:
        by_qid = self.session.answers_snapshot()
        for st in self.registry.tenants():
            for ticket_id, qid in st.qids.items():
                if qid in by_qid:
                    self._view[ticket_id] = by_qid[qid]

    def _notify_waiters(self) -> None:
        still = []
        for required, fut in self._waiters:
            if fut.done():
                continue
            if self._covered >= required:
                fut.set_result(self._covered)
            else:
                still.append((required, fut))
        self._waiters = still

    def _fail_waiters(self, exc: BaseException) -> None:
        for _, fut in self._waiters:
            if not fut.done():
                fut.set_exception(exc)
        self._waiters = []
        for _, _, fut in self._pending_registers:
            if not fut.done():
                fut.set_exception(exc)
        self._pending_registers.clear()
        while self._control:
            _, _, fut = self._control.popleft()
            if not fut.done():
                fut.set_exception(exc)

    def _settle_pending_registers(self) -> None:
        if not self._pending_registers:
            return
        if self.admission.shedding:
            while self._pending_registers:
                tenant_id, _, fut = self._pending_registers.popleft()
                st = self.registry.require(tenant_id)
                st.rejected_registers += 1
                self.admission.rejected_registers += 1
                if not fut.done():
                    fut.set_exception(
                        AdmissionRejected(Decision("reject", "overload shed"))
                    )
        elif not self.admission.overloaded():
            while self._pending_registers:
                tenant_id, plan, fut = self._pending_registers.popleft()
                self._control.append(("register", (tenant_id, plan), fut))

    # ------------------------------------------------------------ durability
    def _serving_extra(self) -> dict:
        return {
            "serving": {
                "tenants": self.registry.state_dict(),
                "admission": self.admission.state_dict(),
                "admitted_total": self._admitted_total,
                "covered": self._covered,
                "epoch": self._epoch,
            }
        }

    async def _maybe_checkpoint(self) -> None:
        if self.supervisor is None or not self.config.checkpoint_every:
            return
        k = len(self._chunk_log)
        if k % self.config.checkpoint_every != 0:
            return
        loop = asyncio.get_running_loop()
        t0 = self.clock()
        await loop.run_in_executor(
            None,
            lambda: self.supervisor.checkpoint(
                self.session, k, extra=self._serving_extra()
            ),
        )
        self.metrics.record("checkpoint", self.clock() - t0)

    def checkpoint_now(self) -> None:
        """Synchronous on-demand checkpoint (drain the loop first)."""
        if self.supervisor is None:
            raise RuntimeError("server was built without a checkpoint_dir")
        self.supervisor.checkpoint(
            self.session, len(self._chunk_log), extra=self._serving_extra()
        )

    def _restore_fn(self, directory: str | None) -> tuple[CQPSession, int]:
        if directory is None:
            return self._genesis()
        session = CQPSession.restore(directory, mesh=self.mesh)
        extra = (session.restore_info or {}).get("extra") or {}
        return session, int(extra.get("next_chunk", 0))

    def _genesis(self) -> tuple[CQPSession, int]:
        """Rebuild from scratch: a fresh session with every live query
        re-registered in ticket order; ticket → qid mappings are remapped
        (qids are NOT stable across a genesis rebuild — tickets are)."""
        if self.session_factory is None:
            raise RuntimeError(
                "no checkpoint on disk and no session_factory to rebuild "
                "from genesis"
            )
        session = self.session_factory()
        mapping: dict[int, int] = {}
        for st in self.registry.tenants():
            for ticket_id in sorted(st.qids):
                handle = session.register(self._plans[ticket_id])
                mapping[st.qids[ticket_id]] = handle.qid
        self.registry.remap_qids(mapping)
        return session, 0

    async def _recover(self, exc: BaseException, k: int) -> None:
        """Restore (checkpoint or genesis), replay control ops + chunks up
        to the failed chunk, resume.  Raises once restarts are exhausted."""
        self.faults += 1
        loop = asyncio.get_running_loop()
        if self.supervisor is not None:
            self.supervisor.record_fault(k, exc)
            session, cursor = await loop.run_in_executor(
                None, lambda: self.supervisor.restore_latest(fault_chunk=k)
            )
        else:
            self._restarts += 1
            if self._restarts > self._policy.max_restarts:
                raise exc
            if self._policy.backoff_s:
                await asyncio.sleep(self._policy.backoff_s)
            session, cursor = self._genesis()
        await loop.run_in_executor(
            None, self._adopt_session, session, cursor
        )

    def _adopt_session(self, session: CQPSession, cursor: int) -> None:
        # 1. replay the control ops the restored state predates.  The
        # checkpoint carries the session's qid cursor, so re-running the
        # post-checkpoint registers in order reassigns the SAME qids the
        # originals got; the genesis path instead re-registered every live
        # ticket already (remapped qids), so its replay is a no-op — both
        # cases fall out of the `have` membership checks below.
        have = {h.qid for h in session.handles()}
        for op in self._control_log:
            if op["cursor"] <= cursor and cursor > 0:
                continue
            if op["kind"] == "register":
                ticket_id = op["ticket_id"]
                st = self.registry.require(op["tenant_id"])
                if ticket_id not in st.qids:
                    continue  # later deregistered — replay will drop it too
                if st.qids[ticket_id] in have:
                    continue  # already present (checkpoint or genesis)
                handle = session.register(self._plans[ticket_id])
                st.qids[ticket_id] = handle.qid
                have.add(handle.qid)
            else:
                qid = op["qid"]
                if qid in have:
                    handle = next(
                        h for h in session.handles() if h.qid == qid
                    )
                    session.deregister(handle)
                    have.discard(qid)
        # 2. re-apply degradation rungs the checkpoint predates
        for st in self.registry.tenants():
            if st.level > 0 and st.qids:
                self.registry._apply_level(session, st, st.level)
        # 3. replay the δE chunk log suffix
        for chunk in self._chunk_log[cursor:]:
            session.apply_updates_batched(
                chunk, batch_size=self.config.chunk_updates
            )
        session.attach_runtime(
            straggler=self.straggler, supervisor=self.supervisor
        )
        self.session = session
        self._refresh_view()

    # ------------------------------------------------------------- runtime
    def _on_straggler(self, event) -> None:
        """The straggler policy: one out-of-band ladder escalation."""
        if self.config.admission:
            self.admission.force_shed(
                self.session, f"straggler@{event.step}"
            )

    # ------------------------------------------------------------ reporting
    def applied_updates(self) -> list:
        """The applied δE prefix, flattened — the scratch oracle's input."""
        return [u for chunk in self._chunk_log for u in chunk]

    def stats(self) -> dict:
        per_tenant = self.registry.snapshot()
        for tid in per_tenant:
            per_tenant[tid]["read_latency"] = summarize_latency_s(
                self._read_wait.get(tid, ())
            )
            lags = self._read_lag.get(tid, ())
            per_tenant[tid]["freshness_lag_updates"] = {
                "mean": float(np.mean(lags)) if lags else 0.0,
                "max": int(max(lags)) if lags else 0,
            }
            per_tenant[tid]["stale_reads"] = self._stale_reads.get(tid, 0)
        out = {
            "epochs": self._epoch,
            "covered_updates": self._covered,
            "admitted_total": self._admitted_total,
            "queue_depth": len(self._queue),
            "chunks_applied": len(self._chunk_log),
            "faults": self.faults,
            "tenants": per_tenant,
            "admission": self.admission.snapshot(),
            "actions": list(self.registry.actions),
            "phases": self.metrics.summary(),
            "straggler_events": len(self.straggler.events),
            "session": self.session.stats(),
        }
        if self.supervisor is not None:
            out["recovery"] = self.supervisor.metrics()
        return out


# ------------------------------------------------------------------ CLI smoke
def _scripted_scenario(args: argparse.Namespace) -> dict:
    """Deterministic multi-tenant scenario (the CI smoke): register one
    SSSP query per tenant, stream the update log round-robin, optionally
    inject one fault mid-stream (restore + replay), verify every served
    answer against a scratch oracle, deregister everyone."""
    from repro.core import plan
    from repro.data.graphgen import powerlaw_graph, split_90_10, update_stream
    from repro.launch.cqp_serve import make_mesh

    edges = powerlaw_graph(args.v, args.e, seed=args.seed)
    initial, pool = split_90_10(edges, seed=args.seed)
    stream = update_stream(
        initial,
        args.v,
        num_batches=max(1, args.updates // max(args.batch, 1)),
        batch_size=args.batch,
        insert_pool=pool,
        delete_fraction=0.1,
        seed=args.seed + 1,
    )
    log = [u for batch in stream for u in batch]
    mesh = make_mesh(args.mesh, args.shards)
    ladder = GovernorConfig(representation="prob")

    def fresh_graph() -> DynamicGraph:
        return DynamicGraph(args.v, initial, capacity=len(edges) * 4 + 64)

    def factory() -> CQPSession:
        return build_serving_session(
            fresh_graph(),
            ladder=ladder,
            engine=args.engine,
            mesh=mesh,
            batch_capacity=args.batch,
            min_slots=args.tenants,
        )

    if args.trace_out:
        obs_trace.set_tracer(obs_trace.Tracer())
    cfg = ServerConfig(
        chunk_updates=args.batch,
        admission=not args.no_admission,
        slo=SLOConfig(backlog_high_updates=max(8 * args.batch, 256)),
        drop_ladder=ladder,
        checkpoint_every=args.checkpoint_every,
        max_restarts=3,
        trace_out=args.trace_out,
        metrics_out=args.metrics_out,
    )
    fault_at = args.inject_fault_at
    fired = {"done": False}

    def injector(k: int) -> None:
        if fault_at is not None and k == fault_at and not fired["done"]:
            fired["done"] = True
            raise InjectedFault(f"scripted fault at chunk {k}")

    async def run() -> dict:
        server = CQPServer(
            factory(),
            config=cfg,
            session_factory=factory,
            checkpoint_dir=args.checkpoint_dir,
            mesh=mesh,
            fault_injector=injector if fault_at is not None else None,
        )
        async with server:
            tickets = []
            for i in range(args.tenants):
                tid = f"tenant{i}"
                server.add_tenant(TenantSpec(tenant_id=tid, priority=i + 1))
                ticket = await server.register_query(
                    tid, plan.sssp(i % args.v, max_iters=args.max_iters)
                )
                tickets.append((tid, ticket))
            # round-robin the update stream across tenants
            for i in range(0, len(log), args.batch):
                tid, _ = tickets[(i // args.batch) % len(tickets)]
                server.submit(tid, log[i : i + args.batch])
            await server.drain()
            reads = [
                await server.read(ticket, timeout_s=60.0)
                for _, ticket in tickets
            ]
            fresh = all(r.fresh for r in reads)
            # scratch oracle over the applied log — every served answer exact
            oracle = CQPSession(fresh_graph(), engine="scratch")
            handles = [
                oracle.register(server._plans[t.ticket_id])
                for _, t in tickets
            ]
            oracle.apply_updates_batched(server.applied_updates())
            exact = all(
                np.allclose(r.values, oracle.answers(h), equal_nan=True)
                for r, h in zip(reads, handles)
            )
            for tid, _ in tickets:
                await server.remove_tenant(tid)
            stats = server.stats()
        stats["exact"] = bool(exact)
        stats["ok"] = bool(
            exact
            and fresh
            and stats["session"]["active_queries"] == 0
            and (fault_at is None or stats["faults"] >= 1)
        )
        return stats

    return asyncio.run(run())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Async multi-tenant CQP serving scenario "
        "(python -m repro.serving.server)"
    )
    ap.add_argument("--smoke", action="store_true", help="tiny deterministic run")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--v", type=int, default=256)
    ap.add_argument("--e", type=int, default=1024)
    ap.add_argument("--updates", type=int, default=192)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-iters", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="dense", choices=["dense", "host"])
    ap.add_argument(
        "--mesh", default="none", choices=["none", "smoke", "data"],
        help="dense-engine mesh (set XLA_FLAGS="
        "--xla_force_host_platform_device_count=N to emulate devices)",
    )
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--inject-fault-at", type=int, default=None)
    ap.add_argument("--no-admission", action="store_true",
                    help="control run: no admission/shedding")
    ap.add_argument("--trace-out", default=None, metavar="TRACE_JSON",
                    help="enable the tracer; flush a Chrome-trace JSON "
                    "per obs scrape (DESIGN.md §15)")
    ap.add_argument("--metrics-out", default=None, metavar="METRICS_JSON",
                    help="write obs registry snapshots per scrape")
    ap.add_argument("--json", action="store_true", help="print the full stats")
    args = ap.parse_args(argv)
    if args.smoke:
        args.v = min(args.v, 64)
        args.e = min(args.e, 256)
        args.updates = min(args.updates, 96)
        args.max_iters = min(args.max_iters, 16)
    stats = _scripted_scenario(args)
    summary = {
        "ok": stats["ok"],
        "exact": stats["exact"],
        "tenants": args.tenants,
        "epochs": stats["epochs"],
        "covered_updates": stats["covered_updates"],
        "faults": stats["faults"],
        "restores": len(stats.get("recovery", {}).get("restores", [])),
    }
    print("serving smoke JSON:", json.dumps(summary))
    if args.json:
        print(json.dumps(stats, indent=2, default=str))
    return 0 if stats["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
