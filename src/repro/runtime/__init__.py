"""Distributed runtime: mesh rules, fault tolerance, stragglers, elasticity."""
