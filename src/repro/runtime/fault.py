"""Fault-tolerant training supervisor: checkpoint/restart + failure injection.

``Supervisor.run`` drives a step function under a restart policy: on device
failure (real ``XlaRuntimeError`` or injected ``InjectedFault``) it restores
the latest checkpoint, rebuilds program state (optionally on a shrunken
mesh via ``elastic``), and resumes.  Deterministic data order is preserved
by keying the input pipeline on the step counter, so a restart replays the
exact failed step.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.fault")


class InjectedFault(RuntimeError):
    """Simulated device/host failure for tests and drills."""


@dataclasses.dataclass
class FaultPolicy:
    max_restarts: int = 5
    checkpoint_every: int = 50
    backoff_s: float = 0.0  # delay before restart (0 in tests)


@dataclasses.dataclass
class StepResult:
    state: object
    metrics: dict


class Supervisor:
    """Wraps a training loop with checkpoint/restart fault handling."""

    def __init__(
        self,
        ckpt: CheckpointManager,
        policy: FaultPolicy | None = None,
        *,
        fault_injector: Callable[[int], None] | None = None,
        on_restart: Callable[[object, int], object] | None = None,
    ) -> None:
        self.ckpt = ckpt
        # a `FaultPolicy()` default argument would be one shared mutable
        # instance across every Supervisor; build a fresh one per instance
        self.policy = policy if policy is not None else FaultPolicy()
        self.fault_injector = fault_injector
        self.on_restart = on_restart
        self.restarts = 0
        self.history: list[str] = []

    def run(
        self,
        state,
        step_fn: Callable[[object, int], StepResult],
        *,
        start_step: int = 0,
        num_steps: int,
    ):
        """Run ``num_steps`` steps with checkpointing and restart-on-fault."""
        step = start_step
        while step < start_step + num_steps:
            try:
                if self.fault_injector is not None:
                    self.fault_injector(step)
                res = step_fn(state, step)
                state = res.state
                if (step + 1) % self.policy.checkpoint_every == 0:
                    self.ckpt.save(step + 1, state)
                    self.history.append(f"ckpt@{step + 1}")
                step += 1
            except (InjectedFault, RuntimeError) as e:  # XlaRuntimeError ⊂ RuntimeError
                self.restarts += 1
                self.history.append(f"fault@{step}:{type(e).__name__}")
                log.warning("step %d failed (%s); restart %d", step, e, self.restarts)
                if self.restarts > self.policy.max_restarts:
                    raise
                if self.policy.backoff_s:
                    time.sleep(self.policy.backoff_s)
                try:
                    state, restored_step = self.ckpt.restore_latest(state)
                    step = restored_step
                except FileNotFoundError:
                    step = start_step  # no checkpoint yet → restart from scratch
                if self.on_restart is not None:
                    state = self.on_restart(state, step)
                self.history.append(f"resume@{step}")
        self.ckpt.wait()
        return state, step
