"""Straggler detection and mitigation hooks.

At 1000+ nodes a single slow host gates every synchronous collective.  The
detector keeps a per-step wall-time EWMA; a step slower than
``threshold × EWMA`` raises a straggler event, to which registered policies
react (re-dispatch the microbatch, exclude-and-shrink via the elastic data
axis, or just log for the fleet scheduler to act on).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    ewma_s: float


class StragglerDetector:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1, warmup: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: float | None = None
        self.seen = 0
        self.events: list[StragglerEvent] = []
        self.policies: list[Callable[[StragglerEvent], None]] = []

    def on_straggler(self, policy: Callable[[StragglerEvent], None]) -> None:
        self.policies.append(policy)

    def observe(self, step: int, duration_s: float) -> bool:
        """Record one step; returns True if it was flagged as a straggler."""
        self.seen += 1
        if self.ewma is None:
            self.ewma = duration_s
            return False
        flagged = (
            self.seen > self.warmup and duration_s > self.threshold * self.ewma
        )
        if flagged:
            ev = StragglerEvent(step, duration_s, self.ewma)
            self.events.append(ev)
            for p in self.policies:
                p(ev)
        else:
            # stragglers don't poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * duration_s
        return flagged


class StepTimer:
    def __init__(self, detector: StragglerDetector):
        self.detector = detector
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def finish(self, step: int) -> bool:
        return self.detector.observe(step, time.perf_counter() - self._t0)

    def __exit__(self, *exc):
        return False
