"""Logical-axis → mesh-axis rules (MaxText-style) and sharding helpers.

Model code annotates every param/activation dim with a *logical* axis name;
this module resolves those names against the active mesh so the same model
lowers on the single-pod (16, 16) ("data", "model") mesh, the multi-pod
(2, 16, 16) ("pod", "data", "model") mesh, or any smoke-test mesh.  Axes
absent from the mesh resolve to replicated.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name → preferred mesh axes, first present wins; tuples shard one
# logical dim over multiple mesh axes.
DEFAULT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"), ("data",)),  # DP over pod×data
    "layers": ((),),  # scanned; never sharded
    "embed": (("data",),),  # FSDP param shard
    "heads": (("model",),),  # TP
    "mlp": (("model",),),
    "vocab": (("model",),),
    "experts": (("model",),),  # EP
    "kv_seq": (("model",),),  # decode cache: sequence-parallel KV
    "table_rows": (("model",),),  # recsys embedding rows
    "graph_nodes": (("model",),),  # GNN node states
    "graph_edges": (("pod", "data"), ("data",)),  # edge-parallel
    "q_vertices": (("pod", "data"), ("data",)),  # DC: concurrent queries
    "dc_vertices": (("model",),),  # DC: vertex/store axis
    # beyond-paper (§Perf): query axis over the WHOLE mesh → neighbour-state
    # gathers become device-local; only scalar horizon/frontier reductions
    # cross the ICI.  Queries are the paper's scalability axis, so this is
    # the natural embarrassingly-parallel decomposition.
    "q_all": (("pod", "data", "model"), ("data", "model")),
    "dc_local": ((),),  # vertex axis replicated (per-device full graph)
    "seq": ((),),  # activations: seq replicated (no SP by default)
}


def resolve_axis(logical: str | None, mesh: Mesh) -> tuple | None:
    if logical is None:
        return None
    options = DEFAULT_RULES.get(logical, ((),))
    for opt in options:
        if isinstance(opt, tuple) and len(opt) and isinstance(opt[0], tuple):
            opt = opt[0]
        if all(a in mesh.axis_names for a in opt):
            if len(opt) == 0:
                return None
            return opt if len(opt) > 1 else opt[0]
    return None


def logical_to_spec(axes: tuple, mesh: Mesh) -> P:
    """('layers','embed','heads') → PartitionSpec for this mesh."""
    used: set = set()
    parts = []
    for ax in axes:
        r = resolve_axis(ax, mesh)
        # one mesh axis may appear at most once in a spec
        if r is None:
            parts.append(None)
            continue
        rs = r if isinstance(r, tuple) else (r,)
        if any(a in used for a in rs):
            parts.append(None)
            continue
        used.update(rs)
        parts.append(r)
    return P(*parts)


def _is_axes_leaf(x) -> bool:
    # PartitionSpec is a tuple subclass whose elements are str/None — it would
    # satisfy the generic check below, so test for it explicitly first
    if isinstance(x, P):
        return True
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _clip_spec(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes the target mesh doesn't have (elastic restore)."""
    return P(*(ax if ax in mesh.axis_names else None for ax in spec))


def shardings_for(specs_tree, mesh: Mesh):
    """Map a tree of logical-axis tuples (or raw PartitionSpecs) to
    NamedShardings.  Raw specs pass through, clipped to the mesh's axes, so
    engine-internal spec trees (``_state_pspecs``) reshard via the same path
    as logical-axis trees."""
    def to_sharding(axes):
        if isinstance(axes, P):
            return NamedSharding(mesh, _clip_spec(axes, mesh))
        return NamedSharding(mesh, logical_to_spec(axes, mesh))

    return jax.tree.map(to_sharding, specs_tree, is_leaf=_is_axes_leaf)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def shard_batch_spec(mesh: Mesh) -> P:
    ax = resolve_axis("batch", mesh)
    return P(ax) if ax is not None else P()
