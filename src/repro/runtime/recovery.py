"""Recovery supervisor for the serving loop — ``fault.Supervisor``'s CQP twin.

The training supervisor restores a *state pytree*; a CQP restart must rebuild
a whole session (host graph, plans, engine, governor) and re-ingest the
suffix of the update log.  ``RecoverySupervisor`` owns that loop:

* periodic checkpoints every ``policy.checkpoint_every`` chunks through an
  async keep-N :class:`~repro.checkpoint.CheckpointManager`, with the log
  cursor riding in the manifest meta;
* on fault (``InjectedFault`` or any ``RuntimeError``): restart backoff,
  ``max_restarts`` exhaustion re-raises, then ``restore_fn`` rebuilds the
  session from the latest checkpoint (or from genesis when none landed yet)
  and the loop resumes at the restored cursor — deterministic replay makes
  the answers bit-identical to an uninterrupted run (DESIGN.md §12);
* an optional :class:`~repro.runtime.straggler.StragglerDetector` observes
  per-chunk wall time.

``restore_fn(directory | None) -> (session, next_chunk)`` is the caller's
rebuild hook: with a directory it should ``CQPSession.restore`` and read the
cursor from ``restore_info``; with ``None`` (no checkpoint on disk yet) it
rebuilds from genesis at chunk 0.
"""

from __future__ import annotations

import logging
import time
from typing import Callable

from repro.checkpoint import CheckpointManager
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime.fault import FaultPolicy, InjectedFault
from repro.runtime.straggler import StragglerDetector

log = logging.getLogger("repro.recovery")


class RecoverySupervisor:
    """Checkpoint/restart driver for a ``CQPSession`` serving loop."""

    def __init__(
        self,
        directory: str,
        policy: FaultPolicy | None = None,
        *,
        keep: int = 3,
        async_write: bool = True,
        restore_fn: Callable[[str | None], tuple[object, int]],
        fault_injector: Callable[[int], None] | None = None,
        straggler: StragglerDetector | None = None,
    ) -> None:
        self.manager = CheckpointManager(directory, keep=keep, async_write=async_write)
        self.policy = policy if policy is not None else FaultPolicy()
        self.restore_fn = restore_fn
        self.fault_injector = fault_injector
        self.straggler = straggler
        self.restarts = 0
        self.history: list[str] = []
        self.checkpoints = 0
        self.checkpoint_s: list[float] = []
        self.checkpoint_bytes = 0  # host bytes of the last snapshot taken
        self.restores: list[dict] = []

    # ------------------------------------------------------------------ api
    def checkpoint(
        self, session, next_chunk: int, *, extra: dict | None = None
    ) -> None:
        """Snapshot ``session`` with the log cursor ``next_chunk``; ``extra``
        entries ride along in the manifest meta (the serving tier stores its
        tenant registry there)."""
        t0 = time.perf_counter()
        with obs_trace.span(
            "checkpoint", "checkpoint", pid="recovery", next_chunk=int(next_chunk)
        ) as sp:
            user = {"next_chunk": int(next_chunk)}
            if extra:
                user.update(extra)
            arrays, meta = session.state_dict(extra=user)
            self.checkpoint_bytes = sum(int(a.nbytes) for a in arrays.values())
            self.manager.save(next_chunk, arrays, meta=meta)
            sp.set(nbytes=self.checkpoint_bytes)
        dt = time.perf_counter() - t0
        self.checkpoint_s.append(dt)
        self.checkpoints += 1
        self.history.append(f"ckpt@{next_chunk}")
        reg = obs_metrics.get_registry()
        reg.counter("cqp_checkpoints_total", "checkpoints written").inc()
        reg.counter(
            "cqp_checkpoint_bytes_total", "host bytes snapshotted"
        ).inc(self.checkpoint_bytes)
        reg.histogram(
            "cqp_checkpoint_seconds", "checkpoint write latency"
        ).observe(dt)
        reg.gauge(
            "cqp_checkpoint_last_bytes", "host bytes of the last snapshot"
        ).set(self.checkpoint_bytes)

    def run(
        self,
        session,
        chunks: list,
        step_fn: Callable[[object, int, object], None],
        *,
        start_chunk: int = 0,
    ):
        """Drive ``step_fn(session, k, chunks[k])`` over the log with
        checkpoint-every-K and restart-on-fault; returns the final session."""
        k = int(start_chunk)
        n = len(chunks)
        every = self.policy.checkpoint_every
        while k < n:
            try:
                if self.fault_injector is not None:
                    self.fault_injector(k)
                t0 = time.perf_counter()
                step_fn(session, k, chunks[k])
                if self.straggler is not None:
                    self.straggler.observe(k, time.perf_counter() - t0)
                k += 1
                if every and k % every == 0:
                    self.checkpoint(session, k)
            except (InjectedFault, RuntimeError) as e:
                self.record_fault(k, e)
                session, k = self.restore_latest(fault_chunk=k)
        self.manager.wait()
        return session

    def record_fault(self, chunk: int, exc: BaseException) -> None:
        """Account one serving-loop fault; re-raises it once the restart
        budget is spent, after sleeping the restart backoff otherwise."""
        self.restarts += 1
        self.history.append(f"fault@{chunk}:{type(exc).__name__}")
        log.warning(
            "chunk %d failed (%s); restart %d", chunk, exc, self.restarts
        )
        if self.restarts > self.policy.max_restarts:
            raise exc
        if self.policy.backoff_s:
            time.sleep(self.policy.backoff_s)

    def restore_latest(self, *, fault_chunk: int) -> tuple[object, int]:
        """Rebuild via ``restore_fn`` from the latest on-disk checkpoint
        (or genesis when none landed yet); returns (session, next_chunk).
        The async serving tier calls this directly — its ingest loop is not
        a static chunk list, so it cannot run under :meth:`run`."""
        self.manager.wait()  # never restore past an in-flight write
        t0 = time.perf_counter()
        with obs_trace.span(
            "restore", "checkpoint", pid="recovery", fault_chunk=int(fault_chunk)
        ) as sp:
            try:
                session, k = self.restore_fn(self.manager.directory)
            except FileNotFoundError:
                # no checkpoint landed yet → rebuild from genesis
                session, k = self.restore_fn(None)
            sp.set(resumed_chunk=int(k), replayed_chunks=int(fault_chunk - k))
        dt = time.perf_counter() - t0
        self.restores.append({
            "latency_s": dt,
            "resumed_chunk": int(k),
            "replayed_chunks": int(fault_chunk - k),
        })
        self.history.append(f"resume@{k}")
        reg = obs_metrics.get_registry()
        reg.counter("cqp_restores_total", "checkpoint restores").inc()
        reg.histogram(
            "cqp_restore_seconds", "restore latency (rebuild + replay cursor)"
        ).observe(dt)
        reg.counter(
            "cqp_replayed_chunks_total", "log chunks replayed after restores"
        ).inc(max(int(fault_chunk - k), 0))
        return session, k

    def metrics(self) -> dict:
        """Recovery counters for ``session.stats()["runtime"]`` / reports."""
        return {
            "restarts": self.restarts,
            "checkpoints": self.checkpoints,
            "checkpoint_s": list(self.checkpoint_s),
            "checkpoint_bytes": self.checkpoint_bytes,
            "restores": list(self.restores),
            "replayed_chunks": sum(r["replayed_chunks"] for r in self.restores),
            "history": list(self.history),
        }
