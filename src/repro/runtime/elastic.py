"""Elastic scaling: resize the data axis and reshard state deterministically.

Losing a pod slice (or adding one back) changes the device count; training
continues by rebuilding the mesh from surviving devices and resharding
params/optimizer state onto it.  Because checkpoints store *global* arrays
(see checkpoint.store), resharding is a device_put with the new sharding —
no shard surgery.  The global batch is re-split across the new data extent;
a fixed global batch keeps the optimizer trajectory comparable.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.runtime import mesh_rules


def build_mesh(devices=None, *, data: int | None = None, model: int | None = None,
               pod: int | None = None) -> Mesh:
    """Build the largest rectangular mesh from the given devices."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if pod:
        shape = (pod, data or 1, model or 1)
        axes = ("pod", "data", "model")
    else:
        if model is None:
            model = min(n, int(np.sqrt(n)))
            while n % model:
                model -= 1
        data = data or n // model
        shape = (data, model)
        axes = ("data", "model")
    need = int(np.prod(shape))
    assert need <= n, (shape, n)
    arr = np.asarray(devices[:need]).reshape(shape)
    return Mesh(arr, axes)


def reshard(tree, specs_tree, new_mesh: Mesh):
    """Move state onto a new mesh per its logical-axis specs."""
    shardings = mesh_rules.shardings_for(specs_tree, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s), tree, shardings
    )


def shrink_after_failure(mesh: Mesh, failed_devices: set) -> Mesh:
    """Rebuild the mesh without failed devices, shrinking the data axis."""
    survivors = [d for d in mesh.devices.flat if d not in failed_devices]
    model = mesh.devices.shape[-1]
    data = len(survivors) // model
    assert data >= 1, "not enough survivors for one model replica"
    return build_mesh(survivors, data=data, model=model)


def split_global_batch(global_batch: int, mesh: Mesh) -> int:
    """Per-device batch under the current data extent (must divide)."""
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    assert global_batch % dp == 0, (global_batch, dp)
    return global_batch // dp
