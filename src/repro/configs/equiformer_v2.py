"""equiformer-v2 [gnn]: 12L, d=128, l_max=6, m_max=2, 8 heads, SO(2)-eSCN
equivariant graph attention. [arXiv:2306.12059; unverified]"""

import dataclasses

from repro.configs.common import ArchSpec
from repro.configs.gnn_harness import EQUIFORMER_CHUNKS, GNN_SHAPES, build_gnn_cell
from repro.models.gnn import equiformer_v2 as model


def full() -> model.EquiformerV2Config:
    return model.EquiformerV2Config(
        num_layers=12, d_hidden=128, l_max=6, m_max=2, num_heads=8
    )


def smoke() -> model.EquiformerV2Config:
    return model.EquiformerV2Config(
        num_layers=2, d_hidden=16, l_max=2, m_max=1, num_heads=2
    )


def _cfg_for_shape(cfg, shape_name, meta):
    return dataclasses.replace(cfg, edge_chunk=EQUIFORMER_CHUNKS[shape_name])


def build_cell(cfg, shape_name, mesh):
    return build_gnn_cell(
        "equiformer-v2", cfg, shape_name, mesh,
        init_params=model.init_params,
        loss_fn=model.loss_fn,
        cfg_for_shape=_cfg_for_shape,
    )


ARCH = ArchSpec(
    name="equiformer-v2", family="gnn", full=full, smoke=smoke,
    shapes=GNN_SHAPES, build_cell=build_cell,
    notes="eSCN: per-edge Wigner alignment + SO(2) conv (m<=2); edge-chunked "
    "two-pass softmax on ogb_products bounds message memory.",
)
