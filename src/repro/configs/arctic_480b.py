"""arctic-480b [moe]: 35L, d=7168, 56H (GQA kv=8), dense d_ff=4864 residual
∥ MoE 128 experts top-2 (expert d_ff=4864), vocab=32000.
[hf:Snowflake/snowflake-arctic-base; hf]"""

import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.configs.lm_harness import LM_SHAPES, build_lm_cell
from repro.models.transformer import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-480b",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,  # dense residual branch
        vocab_size=32000,
        attention="gqa",
        moe=True,
        num_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual=True,  # dense-MoE hybrid: dense FFN ∥ MoE every layer
        capacity_factor=1.25,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        attention="gqa",
        moe=True,
        num_experts=8,
        top_k=2,
        d_ff_expert=48,
        dense_residual=True,
        dtype=jnp.float32,
        attn_block_q=16,
        attn_block_k=16,
    )


ARCH = ArchSpec(
    name="arctic-480b",
    family="lm",
    full=full,
    smoke=smoke,
    shapes=LM_SHAPES,
    build_cell=build_lm_cell,
    notes="dense-MoE hybrid residual; EP over model axis. long_500k skipped.",
)
