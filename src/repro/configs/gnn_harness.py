"""Shared cell builders for the GNN architectures.

Shapes (assigned):
  full_graph_sm   N=2,708  E=10,556  d_feat=1,433   full-batch train
  minibatch_lg    base graph N=232,965 E=114.6M; sampled subgraph of
                  batch_nodes=1,024 seeds, fanout 15-10 → padded
                  (N=180,224, E=169,984) per step (real sampler: data/sampler)
  ogb_products    N=2,449,029  E=61,859,140  d_feat=100  full-batch-large
  molecule        128 graphs × (30 nodes, 64 edges), block-diagonal batch

Geometric archs (dimenet, equiformer-v2) take positions as inputs for every
shape; non-geometric shapes get synthesized coordinates (the arch is still
exercised end-to-end).  DimeNet additionally takes capped triplet lists.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.common import Cell, ShapeDef, Struct, replicated, tree_struct
from repro.models.gnn import common as g
from repro.optim import adamw_init, adamw_update
from repro.runtime import mesh_rules

GNN_SHAPES = {
    "full_graph_sm": ShapeDef("train", dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    "minibatch_lg": ShapeDef(
        "train",
        dict(
            n_nodes=180224, n_edges=169984, d_feat=602, sampled=True,
            base_nodes=232965, base_edges=114615892, batch_nodes=1024, fanout=(15, 10),
        ),
    ),
    "ogb_products": ShapeDef("train", dict(n_nodes=2449029, n_edges=61859140, d_feat=100)),
    "molecule": ShapeDef("train", dict(n_nodes=3840, n_edges=8192, d_feat=16, geometric=True)),
}

# edge-chunk sizes for the memory-bounded equiformer path on big shapes
EQUIFORMER_CHUNKS = {"ogb_products": 524288, "minibatch_lg": 0, "full_graph_sm": 0, "molecule": 0}
# triplet caps for dimenet (quadratic regime must be bounded)
DIMENET_TRIPLET_CAP = {
    "full_graph_sm": 8 * 10556,
    "minibatch_lg": 4 * 169984,
    "ogb_products": 61859140,  # 1× E cap on the huge graph
    "molecule": 65536,
}


def _pad(x: int, m: int = 512) -> int:
    """Real dataset sizes (Cora 2708, ogbn-products 2449029, …) are not
    shard-divisible; pad to the 512-device LCM — padded nodes/edges are
    masked, so semantics are unchanged."""
    return -(-x // m) * m


def batch_structs(meta: dict) -> g.GraphBatch:
    n, e = _pad(meta["n_nodes"]), _pad(meta["n_edges"])
    f = meta["d_feat"]
    return g.GraphBatch(
        node_feat=Struct((n, f), jnp.float32),
        edge_src=Struct((e,), jnp.int32),
        edge_dst=Struct((e,), jnp.int32),
        edge_feat=Struct((e, 8), jnp.float32),
        node_mask=Struct((n,), jnp.bool_),
        edge_mask=Struct((e,), jnp.bool_),
        pos=Struct((n, 3), jnp.float32),
        labels=Struct((n,), jnp.int32),
    )


def batch_shardings(mesh: Mesh) -> g.GraphBatch:
    nodes = NamedSharding(mesh, mesh_rules.logical_to_spec(("graph_nodes",), mesh))
    nodes2 = NamedSharding(mesh, mesh_rules.logical_to_spec(("graph_nodes", None), mesh))
    edges = NamedSharding(mesh, mesh_rules.logical_to_spec(("graph_edges",), mesh))
    edges2 = NamedSharding(mesh, mesh_rules.logical_to_spec(("graph_edges", None), mesh))
    return g.GraphBatch(
        node_feat=nodes2,
        edge_src=edges,
        edge_dst=edges,
        edge_feat=edges2,
        node_mask=nodes,
        edge_mask=edges,
        pos=nodes2,
        labels=nodes,
    )


def make_gnn_train_step(loss_fn):
    def train_step(params, opt_state, *batch_args):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, *batch_args))(params)
        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, lr=1e-3)
        return new_params, new_opt, {"loss": loss, "gnorm": gnorm}

    return train_step


def model_flops_estimate(arch_name: str, cfg, meta: dict) -> float:
    """Analytic useful-FLOP count (global, train step ≈ 3× forward matmuls).

    2MNK per matmul; gathers/segment reductions are counted as memory, not
    compute (they do no MXU work).
    """
    n, e = meta["n_nodes"], meta["n_edges"]
    d = cfg.d_hidden
    L = getattr(cfg, "num_layers", getattr(cfg, "num_blocks", 1))
    if arch_name == "pna":
        de = cfg.d_edge
        fwd = L * (e * 2 * d * (2 * d + de + d) + n * 2 * (13 * d) * d)
        fwd += n * 2 * meta["d_feat"] * d
    elif arch_name == "gatedgcn":
        fwd = L * (3 * e + 2 * n) * 2 * d * d + n * 2 * meta["d_feat"] * d
    elif arch_name == "dimenet":
        t = meta.get("triplets", 4 * e)
        nb, nsr = cfg.n_bilinear, cfg.n_spherical * cfg.n_radial
        fwd = L * (4 * e * 2 * d * d + t * 2 * nb * (d + nsr))
    elif arch_name == "equiformer-v2":
        K = cfg.num_components
        sum_sq = sum((2 * l + 1) ** 2 for l in range(cfg.l_max + 1))
        so2 = 2 * ((cfg.l_max + 1) * d) ** 2 + sum(
            2 * 2 * ((cfg.l_max + 1 - m) * d) ** 2 for m in range(1, cfg.m_max + 1)
        )
        fwd = L * e * (2 * 2 * sum_sq * d + so2 + 2 * K * d * d)
    else:
        return 0.0
    return 3.0 * float(fwd)  # fwd + bwd ≈ 3× forward


def build_gnn_cell(
    arch_name: str,
    cfg,
    shape_name: str,
    mesh: Mesh,
    *,
    init_params,
    loss_fn,
    cfg_for_shape=None,
    extra_args=None,
    extra_shardings=None,
) -> Cell:
    meta = GNN_SHAPES[shape_name].meta
    if cfg_for_shape is not None:
        cfg = cfg_for_shape(cfg, shape_name, meta)
    ps = tree_struct(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    # GNN params are small → replicated (grads all-reduce over the mesh)
    psh = jax.tree.map(lambda _: replicated(mesh), ps)
    os_ = tree_struct(adamw_init, ps)
    osh = jax.tree.map(lambda _: replicated(mesh), os_)
    bst = batch_structs(meta)
    bsh = batch_shardings(mesh)
    args = (ps, os_, bst) + tuple(extra_args or ())
    in_sh = (psh, osh, bsh) + tuple(extra_shardings or ())

    step = make_gnn_train_step(lambda p, *a: loss_fn(cfg, p, *a))
    return Cell(
        f"{arch_name}:{shape_name}", step, args, in_sh, mesh=mesh,
        model_flops=model_flops_estimate(arch_name, cfg, meta),
    )
