"""qwen2-72b [dense]: 80L, d=8192, 64H (GQA kv=8), d_ff=29568, vocab=152064.
GQA with QKV bias.  [arXiv:2407.10671; hf]"""

import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.configs.lm_harness import LM_SHAPES, build_lm_cell
from repro.models.transformer import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-72b",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        attention="gqa",
        qkv_bias=True,
        rope_theta=1e6,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-72b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attention="gqa",
        qkv_bias=True,
        dtype=jnp.float32,
        attn_block_q=16,
        attn_block_k=16,
    )


ARCH = ArchSpec(
    name="qwen2-72b",
    family="lm",
    full=full,
    smoke=smoke,
    shapes=LM_SHAPES,
    build_cell=build_lm_cell,
    notes="long_500k skipped: full-softmax attention (DESIGN.md).",
)
