"""dimenet [gnn]: 6 blocks, d=128, n_bilinear=8, n_spherical=7, n_radial=6.
Triplet (quadratic) kernel regime with per-shape caps.
[arXiv:2003.03123; unverified]"""

import jax.numpy as jnp

from repro.configs.common import ArchSpec, Struct
from repro.configs.gnn_harness import (
    DIMENET_TRIPLET_CAP,
    GNN_SHAPES,
    build_gnn_cell,
)
from repro.models.gnn import dimenet as model
from repro.runtime import mesh_rules
from jax.sharding import NamedSharding


def full() -> model.DimeNetConfig:
    return model.DimeNetConfig(
        num_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7, n_radial=6
    )


def smoke() -> model.DimeNetConfig:
    return model.DimeNetConfig(num_blocks=2, d_hidden=16, n_bilinear=4)


def build_cell(cfg, shape_name, mesh):
    cap = -(-DIMENET_TRIPLET_CAP[shape_name] // 512) * 512  # shard-divisible
    tri_structs = (
        Struct((cap,), jnp.int32),
        Struct((cap,), jnp.int32),
        Struct((cap,), jnp.bool_),
    )
    tsh = NamedSharding(mesh, mesh_rules.logical_to_spec(("graph_edges",), mesh))
    return build_gnn_cell(
        "dimenet", cfg, shape_name, mesh,
        init_params=model.init_params,
        loss_fn=lambda c, p, b, t: model.loss_fn(c, p, b, t),
        extra_args=(tri_structs,),
        extra_shardings=((tsh, tsh, tsh),),
    )


ARCH = ArchSpec(
    name="dimenet", family="gnn", full=full, smoke=smoke,
    shapes=GNN_SHAPES, build_cell=build_cell,
    notes="triplet lists capped per shape (quadratic regime bounded); "
    "non-geometric shapes get synthesized coordinates.",
)
