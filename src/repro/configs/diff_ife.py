"""diff_ife [dc]: the paper's engine as a production arch.

``maintain_step`` — one δE maintenance sweep over Q concurrent queries — is
lowered and compiled on the production mesh like every other architecture.
Queries shard over (pod, data); the vertex axis of the difference store and
frontier shards over model.  The cross-shard term is the neighbour-state
gather in the IFE SpMV (cur[:, src]) and the segment reduction back — the
collective-bound cell the paper's JOD/IFE structure produces at scale.

Production sizing: Q=8,192 concurrent queries × V=1,048,576 vertices ×
E=16,777,216 edges, S=8 change points — the dense store is ~550 GB global,
~2.1 GB per chip on 256 chips.
"""

import dataclasses
from functools import partial

import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.common import ArchSpec, Cell, ShapeDef, Struct, replicated, tree_struct
from repro.core import diffstore as ds
from repro.core import dropping as dr
from repro.core import engine as eng
from repro.core import semiring as sr
from repro.runtime import mesh_rules


@dataclasses.dataclass(frozen=True)
class DiffIFESizing:
    num_queries: int = 8192
    num_vertices: int = 1_048_576
    num_edges: int = 16_777_216
    store_capacity: int = 8
    max_iters: int = 16


SHAPES = {
    "maintain_q8k": ShapeDef("maintain", dict()),
    "maintain_burst": ShapeDef("maintain", dict(queries=1024)),
    # §Perf hillclimb winner: query axis sharded over the WHOLE mesh, vertex
    # axis device-local → the IFE gather/scatter never crosses the ICI.
    "maintain_q8k_qpar": ShapeDef("maintain", dict(query_parallel=True)),
}


def full() -> DiffIFESizing:
    return DiffIFESizing()


def smoke() -> DiffIFESizing:
    return DiffIFESizing(num_queries=4, num_vertices=64, num_edges=256,
                         store_capacity=4, max_iters=8)


def _engine_cfg(z: DiffIFESizing, num_queries=None) -> eng.EngineConfig:
    return eng.EngineConfig(
        num_queries=num_queries or z.num_queries,
        num_vertices=z.num_vertices,
        max_iters=z.max_iters,
        semiring=sr.min_plus(),
        mode="jod",
        store_capacity=z.store_capacity,
        drop=dr.DropConfig(),
    )


def build_cell(z: DiffIFESizing, shape_name: str, mesh) -> Cell:
    meta = SHAPES[shape_name].meta
    cfg = _engine_cfg(z, meta.get("queries"))
    q, v, e = cfg.num_queries, cfg.num_vertices, z.num_edges

    state_structs = tree_struct(
        lambda: eng.make_state(cfg, jnp.zeros((q, v), jnp.float32), e)
    )
    g_structs = eng.GraphArrays(
        src=Struct((e,), jnp.int32),
        dst=Struct((e,), jnp.int32),
        weight=Struct((e,), jnp.float32),
        valid=Struct((e,), jnp.bool_),
        out_degree=Struct((v,), jnp.int32),
        in_degree=Struct((v,), jnp.int32),
    )

    q_ax = "q_all" if meta.get("query_parallel") else "q_vertices"
    v_ax = "dc_local" if meta.get("query_parallel") else "dc_vertices"
    qv = NamedSharding(mesh, mesh_rules.logical_to_spec((q_ax, v_ax), mesh))
    qvs = NamedSharding(
        mesh, mesh_rules.logical_to_spec((q_ax, v_ax, None), mesh)
    )
    vx = NamedSharding(mesh, mesh_rules.logical_to_spec((v_ax,), mesh))
    rep = replicated(mesh)

    state_sh = eng.EngineState(
        dstore=ds.DiffStore(iters=qvs, vals=qvs, count=qv),
        jstore=None,
        drop=dr.DropState(det=None, flt=None, det_overflow=rep, max_iter=rep),
        init=qv,
        cur=qv,
        repair_counts=qv,
    )
    g_sh = eng.GraphArrays(src=rep, dst=rep, weight=rep, valid=rep,
                           out_degree=vx, in_degree=vx)

    fn = partial(eng.maintain, cfg)
    args = (state_structs, g_structs, Struct((v,), jnp.bool_))
    in_sh = (state_sh, g_sh, vx)
    return Cell(f"diff-ife:{shape_name}", fn, args, in_sh, mesh=mesh)


ARCH = ArchSpec(
    name="diff-ife", family="dc", full=full, smoke=smoke,
    shapes=SHAPES, build_cell=build_cell,
    notes="The paper's own engine: one maintenance sweep per δE batch, "
    "Q-batched, lowered on the production mesh.",
)
