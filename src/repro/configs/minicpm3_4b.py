"""minicpm3-4b [dense]: 62L, d=2560, 40H, d_ff=6400, vocab=73448 — MLA
(multi-head latent attention, compressed KV cache).
[hf:openbmb/MiniCPM3-4B; hf]"""

import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.configs.lm_harness import LM_SHAPES, build_lm_cell
from repro.models.transformer import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm3-4b",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        head_dim=96,  # nope+rope
        d_ff=6400,
        # true vocab 73448, padded to 73728 (= 16*4608) for sharding
        # divisibility on the 16-way model axis; extra rows are dead
        vocab_size=73728,
        attention="mla",
        q_rank=768,
        kv_rank=256,
        nope_dim=64,
        rope_dim=32,
        v_head_dim=64,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm3-4b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=24,
        d_ff=128,
        vocab_size=256,
        attention="mla",
        q_rank=32,
        kv_rank=16,
        nope_dim=16,
        rope_dim=8,
        v_head_dim=16,
        dtype=jnp.float32,
        attn_block_q=16,
        attn_block_k=16,
    )


ARCH = ArchSpec(
    name="minicpm3-4b",
    family="lm",
    full=full,
    smoke=smoke,
    shapes=LM_SHAPES,
    build_cell=build_lm_cell,
    notes="MLA: decode cache stores (c_kv, k_rope) latents, not full K/V. "
    "long_500k skipped: full-softmax attention.",
)
