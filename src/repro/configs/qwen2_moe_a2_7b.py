"""qwen2-moe-a2.7b [moe]: 24L, d=2048, 16H (kv=16), expert d_ff=1408,
vocab=151936, 60 routed experts top-4 + shared experts (d_ff 5632).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.configs.lm_harness import LM_SHAPES, build_lm_cell
from repro.models.transformer import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-moe-a2.7b",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=0,
        vocab_size=151936,
        attention="gqa",
        qkv_bias=True,
        moe=True,
        num_experts=60,
        num_experts_padded=64,  # sharding pad; router masks 60..63 to -inf
        top_k=4,
        d_ff_expert=1408,
        d_ff_shared=5632,  # 4 shared experts fused into one 4×1408 SwiGLU
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-moe-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=0,
        vocab_size=256,
        attention="gqa",
        qkv_bias=True,
        moe=True,
        num_experts=8,
        top_k=2,
        d_ff_expert=32,
        d_ff_shared=64,
        dtype=jnp.float32,
        attn_block_q=16,
        attn_block_k=16,
    )


ARCH = ArchSpec(
    name="qwen2-moe-a2.7b",
    family="lm",
    full=full,
    smoke=smoke,
    shapes=LM_SHAPES,
    build_cell=build_lm_cell,
    notes="4 shared + 60 routed top-4; shared experts fused into one SwiGLU "
    "of width 4x1408=5632 with a sigmoid shared-expert gate. long_500k skipped.",
)
