"""gatedgcn [gnn]: 16L, d=70, gated aggregator. [arXiv:2003.00982; paper]"""

import dataclasses

from repro.configs.common import ArchSpec
from repro.configs.gnn_harness import GNN_SHAPES, build_gnn_cell
from repro.models.gnn import gatedgcn as model


def full() -> model.GatedGCNConfig:
    return model.GatedGCNConfig(num_layers=16, d_hidden=70, d_in=128, num_classes=47)


def smoke() -> model.GatedGCNConfig:
    return model.GatedGCNConfig(num_layers=2, d_hidden=16, d_in=16, num_classes=4)


def _cfg_for_shape(cfg, shape_name, meta):
    return dataclasses.replace(cfg, d_in=min(cfg.d_in, meta["d_feat"]))


def build_cell(cfg, shape_name, mesh):
    return build_gnn_cell(
        "gatedgcn", cfg, shape_name, mesh,
        init_params=model.init_params,
        loss_fn=model.loss_fn,
        cfg_for_shape=_cfg_for_shape,
    )


ARCH = ArchSpec(
    name="gatedgcn", family="gnn", full=full, smoke=smoke,
    shapes=GNN_SHAPES, build_cell=build_cell,
)
