"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib

_MODULES = {
    "qwen2-72b": "repro.configs.qwen2_72b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "pna": "repro.configs.pna",
    "gatedgcn": "repro.configs.gatedgcn",
    "dimenet": "repro.configs.dimenet",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "mind": "repro.configs.mind",
    "diff-ife": "repro.configs.diff_ife",
}

ARCH_NAMES = list(_MODULES)


def get_arch(name: str):
    key = name.replace("_", "-").lower()
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(_MODULES[key]).ARCH


def all_archs():
    return [get_arch(n) for n in ARCH_NAMES]
