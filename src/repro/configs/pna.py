"""pna [gnn]: 4L, d=75, aggregators mean-max-min-std, scalers id-amp-atten.
[arXiv:2004.05718; paper]"""

import dataclasses

from repro.configs.common import ArchSpec
from repro.configs.gnn_harness import GNN_SHAPES, build_gnn_cell
from repro.models.gnn import pna as model


def full() -> model.PNAConfig:
    return model.PNAConfig(num_layers=4, d_hidden=75, d_in=128, num_classes=47)


def smoke() -> model.PNAConfig:
    return model.PNAConfig(num_layers=2, d_hidden=16, d_in=16, num_classes=4)


def _cfg_for_shape(cfg, shape_name, meta):
    return dataclasses.replace(cfg, d_in=min(cfg.d_in, meta["d_feat"]))


def build_cell(cfg, shape_name, mesh):
    return build_gnn_cell(
        "pna", cfg, shape_name, mesh,
        init_params=model.init_params,
        loss_fn=model.loss_fn,
        cfg_for_shape=_cfg_for_shape,
    )


ARCH = ArchSpec(
    name="pna", family="gnn", full=full, smoke=smoke,
    shapes=GNN_SHAPES, build_cell=build_cell,
)
